"""Serve a live YCSB stream through the online transaction service.

Open-loop Poisson clients feed admission-controlled bounded queues; the
epoch batcher double-buffers host batch formation against device execution;
every transaction is stamped enqueue→commit-fence.  Three scenarios:

  1. steady state  — sustained txn/s + measured p50/p99 latency;
  2. burst + skew  — bursty arrivals over a Zipfian hot-key workload;
  3. overload      — 20x capacity: admission sheds, queues stay bounded.

    PYTHONPATH=src python examples/serve_txn.py [--quick]
"""
import sys

import numpy as np

from repro.core.engine import StarEngine
from repro.db import ycsb
from repro.service import (AdmissionConfig, OpenLoopClient, TxnService,
                           YCSBSource)

QUICK = "--quick" in sys.argv
DUR = 0.5 if QUICK else 2.0


def serve(name, cfg, rate, process="poisson", policy="shed", duration=DUR,
          part_cap=256, master_cap=512):
    eng = StarEngine(cfg.n_partitions, cfg.records_per_partition)
    client = OpenLoopClient(YCSBSource(cfg, seed=1), rate_txn_s=rate,
                            process=process, seed=7)
    svc = TxnService(eng, [client],
                     AdmissionConfig(part_cap, master_cap, policy),
                     slots_per_partition=32, master_lanes=32)
    out = svc.run(duration_s=duration)
    assert eng.replica_consistent(), "replica diverged!"
    print(f"\n=== {name} (offered {rate:.0f} txn/s, {process}) ===")
    print(f"  sustained    : {out['throughput_txn_s']:8.0f} txn/s "
          f"({out['committed']} committed / {out['epochs']} epochs)")
    print(f"  latency      : p50 {out['p50_ms']:6.1f} ms   "
          f"p99 {out['p99_ms']:6.1f} ms   p99.9 {out['p999_ms']:6.1f} ms")
    print(f"  admission    : {out['admitted']} admitted, {out['shed']} shed, "
          f"{out['backpressured']} backpressured, "
          f"{out['rerouted']} rerouted")
    print(f"  queue depth  : part≤{out['max_part_depth']} "
          f"master≤{out['max_master_depth']}   "
          f"ingest overlapped {1e3 * out['ingest_overlap_s']:.0f} ms "
          f"under device exec")
    return out


base = ycsb.YCSBConfig(n_partitions=4, records_per_partition=1024,
                       cross_ratio=0.10)

# 1. steady state: the headline numbers
steady = serve("steady state", base, rate=1500.0)

# 2. bursty arrivals on a hot-key Zipfian mix
skew = ycsb.YCSBConfig(n_partitions=4, records_per_partition=1024,
                       cross_ratio=0.10, zipf_theta=0.9)
serve("burst + zipf(0.9) skew", skew, rate=1000.0, process="bursty")

# 3. overload: 20x the sustainable rate — shed, never unbounded
over = serve("overload 20x", base, rate=30_000.0, part_cap=64, master_cap=128,
             duration=DUR / 2)
assert over["shed"] > 0, "overload must shed"
assert over["max_part_depth"] <= 64 and over["max_master_depth"] <= 128

print("\nall scenarios served; replicas bit-identical at every fence ✓")
