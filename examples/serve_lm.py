"""Batched serving with the slot-cache decode path + STAR-style hot swap:
a newer committed checkpoint replaces the serving params mid-stream via the
Thomas-rule tid check (stale loads are rejected).

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine

cfg = get_arch("starcoder2-7b", smoke=True)
params_v1 = tf.init_params(cfg, jax.random.key(0))
eng = ServeEngine(cfg, params_v1, max_len=96)

prompts = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size,
                             dtype=jnp.int32)
out1 = eng.generate(prompts, 16)
print("v1 tokens:", out1[0].tolist())

# a newer training epoch commits; swap in (tid = committed step)
params_v2 = tf.init_params(cfg, jax.random.key(7))
assert eng.load_params(params_v2, tid=100)
assert not eng.load_params(params_v1, tid=50)       # stale: rejected
out2 = eng.generate(prompts, 16)
print("v2 tokens:", out2[0].tolist())
print(f"stats: {eng.stats}")
