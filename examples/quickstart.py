"""Quickstart: the two faces of this framework in ~60 seconds on CPU.

1. The paper's engine — STAR phase-switched transactions on YCSB, with
   replica consistency verified through the replication streams.
2. The training runtime — a reduced LM trained a few steps under STAR-DP
   epoch-commit semantics, with a mid-run failure + revert.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_arch
from repro.core.engine import StarEngine
from repro.db import ycsb
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer, TrainerConfig

# --- 1. STAR transaction engine ------------------------------------------
print("== STAR engine (YCSB, 4 partitions) ==")
cfg = ycsb.YCSBConfig(n_partitions=4, records_per_partition=1000)
eng = StarEngine(cfg.n_partitions, cfg.records_per_partition)
for epoch in range(3):
    m = eng.run_epoch(ycsb.make_batch(cfg, 256, seed=epoch))
    print(f" epoch {epoch}: singles={m['committed_single']} "
          f"cross={m['committed_cross']} tau_p={m['tau_p_ms']:.2f}ms "
          f"tau_s={m['tau_s_ms']:.2f}ms")
assert eng.replica_consistent()
print(" replica bit-consistent with master after fences ✓")

plan = eng.inject_failure({2})
print(f" injected failure of node 2 -> case {plan.case.name}, "
      f"mode {plan.run_mode}; reverted to last committed epoch")
eng.run_epoch(ycsb.make_batch(cfg, 256, seed=99))
assert eng.replica_consistent()
print(" recovered and committed a fresh epoch ✓")

# --- 2. STAR-DP trainer ---------------------------------------------------
print("== STAR-DP trainer (reduced glm4-9b) ==")
arch = get_arch("glm4-9b", smoke=True)
tr = Trainer(arch, make_host_mesh(), TrainerConfig(seq_len=64, batch=4,
                                                   steps_per_epoch=4))
m = tr.run(8)
print(f" step {m['step']}: loss {m['loss']:.3f}")
tr.run(2)                      # uncommitted progress...
back = tr.inject_failure()     # ...lost on failure; revert to the fence
print(f" failure -> reverted to committed step {back}")
m = tr.run(4)
print(f" resumed: step {m['step']} loss {m['loss']:.3f} ✓")
