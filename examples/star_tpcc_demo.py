"""The paper end-to-end: STAR on TPC-C — phase switching, hybrid replication
savings, epoch fences, failure + recovery across all four §4.5.3 cases.

    PYTHONPATH=src python examples/star_tpcc_demo.py
"""
import numpy as np

from repro.core.engine import StarEngine
from repro.core.fault import ClusterConfig, classify_failure
from repro.db import tpcc

cfg = tpcc.TPCCConfig(n_partitions=4, n_items=2000, cust_per_district=200,
                      order_ring=128)
state = tpcc.TPCCState(cfg)
rng = np.random.default_rng(0)
eng = StarEngine(cfg.n_partitions, cfg.rows_per_partition,
                 init_val=tpcc.init_values(cfg, rng),
                 cluster=ClusterConfig(f=2, k=6, n_partitions=6))

for epoch in range(4):
    m = eng.run_epoch(tpcc.make_batch(cfg, state, 256, seed=epoch))
    print(f"epoch {epoch}: NewOrder+Payment singles={m['committed_single']} "
          f"cross={m['committed_cross']} tau_p={m['tau_p_ms']:.1f}ms "
          f"tau_s={m['tau_s_ms']:.1f}ms")

s = eng.stats
print(f"\nhybrid replication: {s.op_bytes_hybrid/1e3:.1f} KB shipped vs "
      f"{s.value_bytes_if_not_hybrid/1e3:.1f} KB value-replicated "
      f"({s.value_bytes_if_not_hybrid/max(s.op_bytes_hybrid,1):.1f}x saving)")
assert eng.replica_consistent()
print("replica consistent ✓")

print("\nfailure-case classification (f=2, k=6, paper §4.5.3):")
for failed, label in [({2}, "one partial node"), ({0, 1}, "both full nodes"),
                      (set(range(2, 8)), "all partial nodes"),
                      (set(range(8)), "everything")]:
    c = classify_failure(eng.cluster, failed)
    print(f"  fail {sorted(failed)} -> case {c.value} ({c.name})")

plan = eng.inject_failure({3})
print(f"\ninjected failure -> {plan.case.name}, run_mode={plan.run_mode}, "
      f"remastered {len(plan.remaster)} partitions")
eng.run_epoch(tpcc.make_batch(cfg, state, 128, seed=999))
assert eng.replica_consistent()
print("post-recovery epoch committed ✓")

# --------------------------------------------------------------------------
# the FULL five-transaction mix (45/43/4/4/4) — what the paper could not run:
# OrderStatus/Delivery/StockLevel ride the ordered secondary indexes
# --------------------------------------------------------------------------
print("\nfull TPC-C mix over the storage engine (ordered indexes):")
fcfg = tpcc.TPCCConfig(n_partitions=4, n_items=2000, cust_per_district=200,
                       order_ring=128, mix="full", delivery_gen_lag=256)
fstate = tpcc.TPCCState(fcfg)
frng = np.random.default_rng(1)
feng = StarEngine(fcfg.n_partitions, fcfg.rows_per_partition,
                  init_val=tpcc.init_values(fcfg, frng, state=fstate),
                  indexes=tpcc.index_specs(fcfg))
for epoch in range(4):
    m = feng.run_epoch(tpcc.make_batch(fcfg, fstate, 256, seed=epoch))
    print(f"epoch {epoch}: singles={m['committed_single']} "
          f"cross={m['committed_cross']} "
          f"net-fence={m['t_fence_net_s']*1e6:.0f}us")
assert feng.replica_consistent(), "records AND indexes replicate bit-equal"
undeliv = sum(len(q) for wq in fstate.undelivered for q in wq)
print(f"Delivery consumed oldest NEW-ORDERs via index scans "
      f"({undeliv} still undelivered, {feng.stats.consume_skips} skips)")
print("replica consistent (records + ordered indexes) ✓")
