"""Serve a live YCSB stream through the DISTRIBUTED cluster runtime.

Run with forced host devices (one device == one paper node):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/serve_cluster.py [--quick]

Open-loop Poisson clients feed node-sharded admission (per-node bounded
queues on top of the per-partition caps); the epoch batcher double-buffers
host batch formation against the mesh execution (shard_map partitioned
phase with zero collectives, psum fence, single-master phase on the full
replica).  Mid-run, a FaultInjector kills node 2: the coordinator detects
the missed fence, reverts the in-flight epoch, classifies the failure
(§4.5), restores the node's partitions from the full replica, and the
service keeps serving — recovery latency and per-node skew appear in the
summary.
"""
import sys

import jax

from repro.cluster import ClusterRuntime, ClusterTxnService
from repro.core.fault import FaultInjector
from repro.db import ycsb
from repro.service import AdmissionConfig, OpenLoopClient, YCSBSource

QUICK = "--quick" in sys.argv


def main():
    n = jax.device_count()
    if n < 2:
        print("NOTE: run with XLA_FLAGS=--xla_force_host_platform_device_"
              "count=4 to simulate a multi-node cluster; continuing with "
              f"{n} device(s).")
    mesh = jax.make_mesh((n,), ("part",))
    P = 2 * n                                   # two partitions per node
    cfg = ycsb.YCSBConfig(n_partitions=P, records_per_partition=256)

    inj = FaultInjector()
    inj.schedule_kill(node=min(2, n - 1), epoch=8)
    rt = ClusterRuntime(mesh, P, 256, injector=inj)
    client = OpenLoopClient(YCSBSource(cfg, seed=1), rate_txn_s=800.0,
                            seed=7)
    svc = ClusterTxnService(rt, [client],
                            AdmissionConfig(64, 64, node_queue_cap=96),
                            slots_per_partition=16, master_lanes=16)
    out = svc.run(duration_s=0.8 if QUICK else 2.5)
    assert rt.replica_consistent(), "replicas diverged!"

    print(f"\n=== cluster service over {n} node(s), {P} partitions ===")
    print(f"  sustained      : {out['throughput_txn_s']:8.0f} txn/s "
          f"({out['committed']} committed / {out['epochs']} epochs)")
    print(f"  latency        : p50 {out['p50_ms']:6.1f} ms   "
          f"p99 {out['p99_ms']:6.1f} ms")
    print(f"  per-node commit: {out['node_committed']}")
    print(f"  per-node shed  : {out['node_shed']}  "
          f"(queue depth max {out['node_queue_depth_max']})")
    print(f"  fence-wait EMA : {out['fence_wait_ema_ms']} ms")
    if out["recoveries"]:
        ev = svc.recovery_events[0]
        print(f"  RECOVERY       : epoch {ev.epoch} lost node(s) "
              f"{list(ev.failed)} -> {ev.case.name} "
              f"({ev.run_mode}), recovered in "
              f"{ev.t_recovery_s * 1e3:.1f} ms, view {ev.view}")
    print("  replicas bit-identical at the final fence: OK")


if __name__ == "__main__":
    main()
