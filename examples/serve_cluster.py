"""Serve a live transaction stream through the DISTRIBUTED cluster runtime.

Run with forced host devices (one device == one paper node):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/serve_cluster.py --mix full [--quick]

``--mix full`` (the default) serves the five-transaction TPC-C mix
(45/43/4/4/4) — ordered secondary indexes sharded with the mesh, Delivery
consuming through index range scans, consume feedback re-queueing skipped
districts.  ``--mix ycsb`` serves the original YCSB stream.

Open-loop Poisson clients feed node-sharded admission (per-node bounded
queues on top of the per-partition caps); the epoch batcher double-buffers
host batch formation against the mesh execution (shard_map partitioned
phase with zero collectives, the §5 op-stream slabs shipping to the full
replica and the physical secondary homes DURING the phase, psum fence
waiting only on the unshipped tail, single-master phase on the full
replica).  Mid-run, a FaultInjector kills node 2: the coordinator detects
the missed fence, reverts the in-flight epoch (discarding the consumed
stream slabs), classifies the failure (§4.5), restores the node's
partitions from a surviving copy, and the service keeps serving —
recovery latency, per-node skew, and the overlapped-vs-fence stream bytes
appear in the summary.

``--read-tier`` additionally serves declared-read-only transactions
(OrderStatus/StockLevel) from the bounded-staleness replica tier: a read
lane in admission, snapshot reads off the full + secondary copies between
fences, ``--max-staleness K`` bounding how many fences a serving snapshot
may trail (0 = fence-fresh; reads that can't meet the bound fall back to
the OCC path, never go stale):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/serve_cluster.py \\
        --mix full --read-tier --max-staleness 2 [--quick]

``--analytics`` (full mix only) attaches the HTAP lane: columnar
materialized views maintained incrementally from the engine's ChangeLog
(the same ordered op stream the replicas replay), promoted and stamped
at every commit fence, serving a CH-benCHmark-style query mix between
fences — top revenue districts, stock-below-threshold, undelivered
backlog, and fence-granular revenue time-travel — without touching the
OCC phases:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/serve_cluster.py \\
        --mix full --analytics [--quick]
"""
import argparse

import numpy as np

import jax

from repro.cluster import ClusterRuntime, ClusterTxnService
from repro.core.fault import FaultInjector
from repro.db import tpcc, ycsb
from repro.obs import Tracer, set_tracer
from repro.service import (AdmissionConfig, OpenLoopClient, TPCCSource,
                           YCSBSource)

_ap = argparse.ArgumentParser(description=__doc__)
_ap.add_argument("--quick", action="store_true")
_ap.add_argument("--mix", default="full", choices=("full", "ycsb"))
_ap.add_argument("--read-tier", action="store_true",
                 help="serve declared-read-only txns from replica "
                 "snapshots between fences (bounded staleness)")
_ap.add_argument("--max-staleness", type=int, default=2, metavar="K",
                 help="freshness bound in fence epochs for snapshot reads "
                 "(0 = fence-fresh from the full copy)")
_ap.add_argument("--analytics", action="store_true",
                 help="attach the HTAP lane: ChangeLog-maintained "
                 "materialized views + CH-style query mix (full mix only)")
_ap.add_argument("--trace", metavar="OUT.json", default=None,
                 help="export a Chrome/Perfetto trace of the run (epoch/"
                 "phase/slab/fence/SM-round/recovery spans) to this path")
_ap.add_argument("--metrics", metavar="OUT.jsonl", default=None,
                 help="export the per-epoch MetricsRegistry snapshots "
                 "as JSON lines to this path")
_ap.add_argument("--kill-epoch", type=int, default=8, metavar="E",
                 help="epoch at which the FaultInjector kills a node "
                 "(lower it so --quick runs still exercise recovery)")
_ARGS = _ap.parse_args()
QUICK, MIX = _ARGS.quick, _ARGS.mix
READ_TIER, MAX_STALENESS = _ARGS.read_tier, _ARGS.max_staleness
ANALYTICS = _ARGS.analytics
TRACE, METRICS = _ARGS.trace, _ARGS.metrics
if ANALYTICS and MIX != "full":
    _ap.error("--analytics requires --mix full (TPC-C views)")


def main():
    tracer = None
    if TRACE:
        tracer = Tracer(capacity=1 << 18, enabled=True)
        set_tracer(tracer)
    n = jax.device_count()
    if n < 2:
        print("NOTE: run with XLA_FLAGS=--xla_force_host_platform_device_"
              "count=4 to simulate a multi-node cluster; continuing with "
              f"{n} device(s).")
    mesh = jax.make_mesh((n,), ("part",))
    inj = FaultInjector()
    inj.schedule_kill(node=min(2, n - 1), epoch=_ARGS.kill_epoch)

    feedback = None
    if MIX == "full":
        P = n                                   # one warehouse per node
        cfg = tpcc.TPCCConfig(n_partitions=P, n_items=400,
                              cust_per_district=40, order_ring=64,
                              mix="full", delivery_gen_lag=256)
        state = tpcc.TPCCState(cfg)
        init = tpcc.init_values(cfg, np.random.default_rng(7), state=state)
        rt = ClusterRuntime(mesh, P, cfg.rows_per_partition, init_val=init,
                            indexes=tpcc.index_specs(cfg), injector=inj)
        client = OpenLoopClient(TPCCSource(cfg, state=state, seed=1),
                                rate_txn_s=600.0, seed=7)
        feedback = lambda b, m: tpcc.apply_consume_feedback(state, b, m)  # noqa: E731
    else:
        P = 2 * n                               # two partitions per node
        cfg = ycsb.YCSBConfig(n_partitions=P, records_per_partition=256)
        rt = ClusterRuntime(mesh, P, 256, injector=inj)
        client = OpenLoopClient(YCSBSource(cfg, seed=1), rate_txn_s=800.0,
                                seed=7)
    tier = None
    if READ_TIER:
        from repro.reads import ReadTier
        tier = ReadTier(max_staleness_epochs=MAX_STALENESS,
                        sec_refresh_every=2)
    lane = None
    if ANALYTICS:
        from repro.changelog import AnalyticsLane
        lane = AnalyticsLane(cfg, stock_threshold=40, retain=8)
    svc = ClusterTxnService(rt, [client],
                            AdmissionConfig(64, 64, node_queue_cap=96),
                            slots_per_partition=16, master_lanes=16,
                            feedback=feedback, read_tier=tier,
                            analytics=lane)
    out = svc.run(duration_s=0.8 if QUICK else 2.5)
    assert rt.replica_consistent(), "replicas diverged!"

    print(f"\n=== cluster service over {n} node(s), {P} partitions, "
          f"mix={MIX} ===")
    print(f"  sustained      : {out['throughput_txn_s']:8.0f} txn/s "
          f"({out['committed']} committed / {out['epochs']} epochs)")
    print(f"  latency        : p50 {out['p50_ms']:6.1f} ms   "
          f"p99 {out['p99_ms']:6.1f} ms")
    print(f"  per-node commit: {out['node_committed']}")
    print(f"  per-node shed  : {out['node_shed']}  "
          f"(queue depth max {out['node_queue_depth_max']})")
    print(f"  fence-wait EMA : {out['fence_wait_ema_ms']} ms")
    total = out["op_bytes_overlapped"] + out["op_bytes_fence"]
    if total:
        print(f"  op stream      : {out['op_bytes_overlapped']} B overlapped"
              f" / {out['op_bytes_fence']} B at the fence "
              f"({100 * out['op_bytes_overlapped'] / total:.0f}% hidden, "
              f"{out['slabs_shipped']} slabs)")
    if out["recoveries"]:
        ev = svc.recovery_events[0]
        src = ("disk" if ev.reloaded_from_disk
               else "secondary copy" if ev.restored_from_secondary
               else "full replica")
        print(f"  RECOVERY       : epoch {ev.epoch} lost node(s) "
              f"{list(ev.failed)} -> {ev.case.name} "
              f"({ev.run_mode}, restored from {src}), recovered in "
              f"{ev.t_recovery_s * 1e3:.1f} ms, view {ev.view}")
    if READ_TIER and MIX == "full":
        combined = out["combined_txn_s"]
        print(f"  read tier      : {out['read_served']} snapshot reads at "
              f"{out['read_txn_s']:.0f} txn/s "
              f"(p50 {out['read_p50_ms']:.1f} ms, "
              f"p99 {out['read_p99_ms']:.1f} ms)")
        print(f"  read freshness : max {out['read_max_freshness']} epoch(s) "
              f"(bound {MAX_STALENESS}), by replica {out['read_by_replica']},"
              f" {out['read_fallbacks']} OCC fallbacks, "
              f"{out['read_shed']} shed, "
              f"{out['read_replicas_removed']} replica(s) purged on failure")
        print(f"  combined       : {combined:8.0f} txn/s "
              f"(write {out['write_txn_s']:.0f} + read {out['read_txn_s']:.0f})")
        # CI gate: the tier must actually serve, never past the bound, and
        # combined throughput must clear a collapse floor
        assert out["read_served"] > 0, "read tier served nothing"
        assert out["read_stale_violations"] == 0, \
            f"stale-bound violations: {out['read_stale_violations']}"
        assert out["read_max_freshness"] <= MAX_STALENESS, out
        # loose floor (the injected kill + recovery dominates --quick runs):
        # catches collapse-to-zero, not host speed
        assert combined > 10, f"combined throughput collapsed: {combined}"
        print("  read tier: OK (served > 0, zero stale-bound violations)")
    if ANALYTICS:
        print(f"  analytics      : {out['analytics_serves']} serves / "
              f"{out['analytics_queries']} queries "
              f"(q p50 {out['analytics_q_p50_ms']:.3f} ms, "
              f"p99 {out['analytics_q_p99_ms']:.3f} ms)")
        print(f"  mv maintenance : {out['analytics_mv_slabs']} slabs, "
              f"{out['analytics_mv_writes']} writes, "
              f"{out['analytics_mv_commits']} commits, "
              f"{out['analytics_mv_reverts']} reverts, "
              f"{out['analytics_retained_epochs']} fences retained")
        # CI gate: the lane must actually serve, every serve fence-fresh,
        # and the freshest stamp must bit-equal a from-scratch recompute
        # of the committed full-replica state (end-to-end, post-recovery)
        assert out["analytics_serves"] > 0, "analytics lane served nothing"
        assert out["analytics_max_epoch_lag"] == 0, out
        epoch, aggs = lane.views.latest()
        want = lane.views.recompute(rt.committed_state()[0])
        for k in ("revenue", "stock_low", "undelivered", "order_latency"):
            assert np.array_equal(aggs[k], want[k]), k
        assert epoch == rt.committed_epoch
        print("  analytics: OK (served > 0, fence-fresh, final stamp "
              "bit-equal to recompute)")
    print("  replicas bit-identical at the final fence: OK "
          "(records + indexes + secondaries)")
    if TRACE:
        n_ev = tracer.export_chrome(TRACE)
        print(f"  trace          : {n_ev} events -> {TRACE} "
              f"({tracer.dropped} dropped)")
    if METRICS:
        n_snap = svc.metrics.export_jsonl(METRICS)
        print(f"  metrics        : {n_snap} epoch snapshots -> {METRICS}")


if __name__ == "__main__":
    main()
