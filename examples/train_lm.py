"""End-to-end driver: train a ~110M-parameter LM with the full runtime —
sharded step, STAR-DP epoch commits, disk checkpointing, resume.

Full run (a few hundred steps, the deliverable configuration):
    PYTHONPATH=src python examples/train_lm.py --steps 300
Quick verification:
    PYTHONPATH=src python examples/train_lm.py --steps 10 --seq 128 --batch 4
"""
import argparse

from repro.configs.base import ArchConfig, BLOCK_ATTN_MLP
from repro.launch.mesh import make_host_mesh
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

# ~110M params: GPT-2-small-scale llama-style decoder
LM110M = ArchConfig(
    name="demo-110m", family="dense", source="examples/train_lm.py",
    block=BLOCK_ATTN_MLP,
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=2048, vocab_size=32000,
    mlp_act="silu", mlp_gated=True, attn_chunk=256,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/star_dp_110m")
    ap.add_argument("--steps-per-epoch", type=int, default=20)
    args = ap.parse_args()

    print(f"model: {LM110M.n_params()/1e6:.0f}M params")
    tr = Trainer(LM110M, make_host_mesh(), TrainerConfig(
        seq_len=args.seq, batch=args.batch, checkpoint_dir=args.ckpt,
        steps_per_epoch=args.steps_per_epoch,
        hp=AdamWConfig(lr=6e-4, warmup_steps=50)))
    meta = tr.restore_from_disk()
    if meta:
        print(f"resumed from committed step {meta['step']}")
    while tr.step < args.steps:
        m = tr.run(min(args.steps_per_epoch, args.steps - tr.step))
        print(f"step {m['step']:5d} loss {m['loss']:.4f} "
              f"gnorm {m['grad_norm']:.3f}", flush=True)
    print(f"done: {tr.step} steps, {tr.commit_log.fences} commits, "
          f"{tr.straggler_events} straggler events")


if __name__ == "__main__":
    main()
