"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [figure ...]
"""
import sys

from benchmarks import (fig03_model, fig10_improvement, fig11_throughput,
                        fig12_latency, fig13_calvin, fig13_scalability,
                        fig14_overhead, fig15_replication, fig16_scalability,
                        roofline_report)
from benchmarks.common import emit

ALL = {
    "fig03": fig03_model, "fig10": fig10_improvement,
    "fig11": fig11_throughput, "fig12": fig12_latency,
    "fig13": fig13_calvin, "fig13_scal": fig13_scalability,
    "fig14": fig14_overhead, "fig15": fig15_replication,
    "fig16": fig16_scalability, "roofline": roofline_report,
}


def main() -> None:
    which = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    for name in which:
        emit(ALL[name].run())


if __name__ == '__main__':
    main()
