"""Figure 16: scalability with cluster size.

STAR saturates (network-bound) while Dist.* scale linearly from a lower base
— the paper's crossover estimate (~30-40 nodes) is recomputed from our
calibrated model.
"""
from benchmarks.common import get_envelope_calibration
from repro.baselines.cost_model import dist_throughput, star_throughput


def run():
    rows = []
    for wl in ("ycsb", "tpcc"):
        cal = get_envelope_calibration(wl, cross=0.1)
        star = {}
        for n in (1, 2, 4, 8, 16):
            star[n] = star_throughput(n, 0.1, cal)
            occ = dist_throughput(n, 0.1, cal, "occ")
            rows.append((f"fig16/{wl}_n{n}_star", 0.0, round(star[n])))
            rows.append((f"fig16/{wl}_n{n}_dist_occ", 0.0, round(occ)))
        rows.append((f"fig16/{wl}_star_8v2_speedup", 0.0,
                     round(star[8] / star[2], 2)))
        # crossover: smallest n where ideal-scaling Dist.OCC beats STAR(n)
        per_node = dist_throughput(1, 0.1, cal, "occ")
        crossover = next((n for n in range(2, 101)
                          if per_node * n > star_throughput(min(n, 16), 0.1, cal)),
                         None)
        rows.append((f"fig16/{wl}_dist_crossover_nodes", 0.0, crossover))
    return rows
