"""Figure 15: replication strategies + fault tolerance.

(a) MEASURED: TPC-C epochs through the real engine; hybrid (operation)
    replication bytes vs value replication bytes — the paper's ~order-of-
    magnitude reduction; plus SYNC-STAR throughput degradation (model).
(b) MEASURED: disk-logging overhead — engine epochs with WAL flushes on/off.
"""
import tempfile
import time

import numpy as np

from benchmarks.common import get_envelope_calibration
from repro.baselines.cost_model import star_throughput
from repro.core.engine import StarEngine
from repro.db import tpcc
from repro.db.wal import WriteAheadLog


def run():
    rows = []
    cfg = tpcc.TPCCConfig(n_partitions=4, n_items=2000, cust_per_district=200,
                          order_ring=128)
    state = tpcc.TPCCState(cfg)
    rng = np.random.default_rng(0)
    eng = StarEngine(cfg.n_partitions, cfg.rows_per_partition,
                     init_val=tpcc.init_values(cfg, rng))
    batches = [tpcc.make_batch(cfg, state, 256, seed=i) for i in range(4)]
    for b in batches[:2]:
        eng.run_epoch(b)            # warm the jits
    t0 = time.perf_counter()
    for b in batches[2:]:
        eng.run_epoch(b)
    t_no_wal = time.perf_counter() - t0
    s = eng.stats
    ratio = s.value_bytes_if_not_hybrid / max(s.op_bytes_hybrid, 1)
    rows.append(("fig15/tpcc_value_bytes", 0.0, s.value_bytes_if_not_hybrid))
    rows.append(("fig15/tpcc_hybrid_bytes", 0.0, s.op_bytes_hybrid))
    rows.append(("fig15/tpcc_hybrid_reduction_x", 0.0, round(ratio, 2)))
    # §5 in-phase op-stream shipping: how much of the partitioned stream
    # overlapped execution vs waited at the fence (the real hiding ratio —
    # the paper claims the fence cost is negligible; now it's measured)
    ovl, fence = s.op_bytes_overlapped, s.op_bytes_fence
    assert ovl + fence == s.op_bytes_hybrid, (ovl, fence, s.op_bytes_hybrid)
    rows.append(("fig15/tpcc_stream_overlapped_bytes", 0.0, ovl))
    rows.append(("fig15/tpcc_stream_fence_bytes", 0.0, fence))
    rows.append(("fig15/tpcc_stream_overlap_frac", 0.0,
                 round(ovl / max(ovl + fence, 1), 4)))
    assert eng.replica_consistent()

    # full five-transaction mix: index-maintenance ops now hit the fence's
    # byte model too (they rode the op stream uncounted before)
    cfg_f = tpcc.TPCCConfig(n_partitions=2, n_items=400,
                            cust_per_district=40, order_ring=64,
                            mix="full", delivery_gen_lag=256)
    state_f = tpcc.TPCCState(cfg_f)
    init_f = tpcc.init_values(cfg_f, np.random.default_rng(3), state=state_f)
    eng_f = StarEngine(cfg_f.n_partitions, cfg_f.rows_per_partition,
                       init_val=init_f, indexes=tpcc.index_specs(cfg_f))
    for i in range(3):
        eng_f.run_epoch(tpcc.make_batch(cfg_f, state_f, 128, seed=40 + i))
    sf = eng_f.stats
    assert sf.index_op_bytes > 0
    assert sf.op_bytes_overlapped + sf.op_bytes_fence == sf.op_bytes_hybrid
    rows.append(("fig15/tpcc_full_index_op_bytes", 0.0, sf.index_op_bytes))
    rows.append(("fig15/tpcc_full_overlap_frac", 0.0,
                 round(sf.op_bytes_overlapped
                       / max(sf.op_bytes_hybrid, 1), 4)))
    assert eng_f.replica_consistent()

    # SYNC STAR vs STAR (model, calibrated)
    cal = get_envelope_calibration("tpcc")
    for P in (0.02, 0.1, 0.5, 0.9):
        a = star_throughput(4, P, cal, sync_replication=False)
        b = star_throughput(4, P, cal, sync_replication=True)
        rows.append((f"fig15/sync_star_slowdown_P{P:g}", 0.0, round(a / b, 2)))
        h = star_throughput(4, P, cal, hybrid=True)
        nv = star_throughput(4, P, cal, hybrid=False)
        rows.append((f"fig15/hybrid_gain_P{P:g}", 0.0, round(h / nv, 2)))

    # disk logging overhead (measured WAL flush on the same write volume)
    with tempfile.TemporaryDirectory() as d:
        wal = WriteAheadLog(d, 0)
        state2 = tpcc.TPCCState(cfg)
        eng2 = StarEngine(cfg.n_partitions, cfg.rows_per_partition,
                          init_val=tpcc.init_values(cfg, rng))
        bs = [tpcc.make_batch(cfg, state2, 256, seed=10 + i) for i in range(4)]
        for b in bs[:2]:
            eng2.run_epoch(b)
        t0 = time.perf_counter()
        for i, b in enumerate(bs[2:]):
            eng2.run_epoch(b)
            k = np.asarray(b["ptxn"]["kind"])
            wal.append(np.asarray(b["ptxn"]["row"]),
                       np.asarray(b["ptxn"]["delta"]),
                       np.broadcast_to(np.uint32(2 * i + 2), k.shape).copy(),
                       k > 0)
            wal.flush(epoch=i)
        t_wal = time.perf_counter() - t0
        wal.close()
    overhead = max(t_wal / max(t_no_wal, 1e-9) - 1.0, 0.0)
    rows.append(("fig15/disk_logging_overhead", t_wal * 1e6 / 2,
                 round(overhead, 3)))
    return rows
