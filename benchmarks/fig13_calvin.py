"""Figure 13: STAR vs Calvin-{2,4,6} (deterministic database).

Measured: the deterministic executor (lock-order commit, no aborts) runs for
real — run_single_master(deterministic=True); cluster numbers via the
calibrated model (lock-manager threads vs worker threads trade-off).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import get_calibration, timed
from repro.baselines.cost_model import calvin_throughput, star_throughput
from repro.core.single_master import run_single_master


def run():
    rows = []
    # real deterministic execution micro-benchmark
    rng = np.random.default_rng(0)
    B, Mops, N = 256, 8, 4096
    txns = {
        "valid": jnp.ones(B, bool),
        "row": jnp.asarray(np.stack([rng.choice(N, Mops, replace=False)
                                     for _ in range(B)]), jnp.int32),
        "kind": jnp.asarray(rng.integers(0, 4, (B, Mops)), jnp.int32),
        "delta": jnp.asarray(rng.integers(-9, 9, (B, Mops, 10)), jnp.int32),
        "user_abort": jnp.zeros(B, bool),
    }
    val = jnp.zeros((N, 10), jnp.int32)
    tid = jnp.zeros((N,), jnp.uint32)
    fn = jax.jit(lambda: run_single_master(val, tid, txns, jnp.uint32(1),
                                           max_rounds=16, deterministic=True))
    us, out = timed(fn)
    committed = int(out[3]["committed"])
    rows.append(("fig13/calvin_exec_us_per_txn", us * 1e6 / B,
                 f"committed={committed}/{B}"))

    for wl in ("ycsb", "tpcc"):
        cal = get_calibration(wl)
        for P in (0.0, 0.1, 0.5, 0.9):
            star = star_throughput(4, P, cal)
            best_calvin = 0.0
            for x in (2, 4, 6):
                thr = calvin_throughput(4, P, cal, lock_threads=x)
                rows.append((f"fig13/{wl}_P{P:g}_calvin{x}", 0.0, round(thr)))
                best_calvin = max(best_calvin, thr)
            rows.append((f"fig13/{wl}_P{P:g}_star", 0.0, round(star)))
            rows.append((f"fig13/{wl}_P{P:g}_star_over_best_calvin", 0.0,
                         round(star / best_calvin, 2)))
    return rows
