"""Shared helpers for the per-figure benchmarks.

Row format everywhere: (name, us_per_call, derived) — us_per_call is a real
measured wall time on this host where the row is measurement-backed, 0.0 for
purely analytical rows; `derived` is the figure's headline quantity.
"""
from __future__ import annotations

import time

import jax


def timed(fn, *args, reps=3, warmup=1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return (time.perf_counter() - t0) / reps, out


_CAL_CACHE = {}


def get_calibration(workload: str, cross: float = 0.5):
    """Cached host calibration (jit compiles are slow on 1 core)."""
    key = (workload, cross)
    if key not in _CAL_CACHE:
        from repro.baselines.calibrate import calibrate
        _CAL_CACHE[key] = calibrate(workload, n_partitions=4, n_txns=1024,
                                    cross_ratio=cross)
    return _CAL_CACHE[key]


def get_envelope_calibration(workload: str, cross: float = 0.5):
    """Paper-envelope variant: measured retry factor + replication bytes, but
    per-txn CPU costs rescaled to the paper's C++/Silo scale (~10 us/txn,
    §7.1: 12 workers x 2.5 GHz) — this host's vectorized 1-core per-txn cost
    is ~10x that, which would understate K = t_c/t_s and with it every
    cross-system ratio. EXPERIMENTS.md reports both calibrations."""
    import dataclasses
    cal = get_calibration(workload, cross)
    scale = 10e-6 / cal.t_single_cpu
    return dataclasses.replace(
        cal, t_single_cpu=10e-6, t_cross_cpu=max(cal.t_cross_cpu * scale, 12e-6))


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
