"""Roofline summary rows from the dry-run artifacts (EXPERIMENTS.md §Roofline
reads the full JSONs; this emits the headline terms per cell) plus the OCC
round traffic model: bytes touched per single-master round for the jnp
reference vs the fused Pallas layout (repro.kernels.occ.ops.occ_round_bytes)
at paper-scale TPC-C shapes — the memory-bandwidth argument for the fusion."""
import glob
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def occ_rows():
    from repro.db import tpcc
    from repro.kernels.occ.ops import occ_round_bytes
    from repro.launch.roofline import HBM_BW

    rows = []
    for label, P, B in (("tpcc_p4_b128", 4, 128), ("tpcc_p16_b512", 16, 512)):
        cfg = tpcc.TPCCConfig(n_partitions=P, mix="full")
        caps = [s.capacity for s in tpcc.index_specs(cfg)]
        bts = occ_round_bytes(B=B, M=tpcc.M, K=12, C=tpcc.C,
                              n_rows=P * cfg.rows_per_partition,
                              index_caps=caps, n_indexes_P=P)
        for k in ("jnp", "pallas"):
            rows.append((f"roofline/occ_round/{label}/{k}",
                         bts[k] / HBM_BW * 1e6,          # us at v5e HBM bw
                         f"{bts[k] / 1e6:.1f}MB"))
        rows.append((f"roofline/occ_round/{label}/fusion_traffic_x", 0.0,
                     round(bts["jnp"] / max(bts["pallas"], 1), 1)))
    return rows


def index_merge_rows():
    """Index-maintenance traffic per vmapped merge call, three generations:
    the original full-segment argsort merge, the gather-form jnp merge and
    the fused Pallas kernel (repro.kernels.index_merge.index_merge_bytes)."""
    from repro.kernels.index_merge.ops import index_merge_bytes
    from repro.launch.roofline import HBM_BW

    rows = []
    for label, P, cap, Q in (("tpcc_p4_ol", 4, 11520, 1536),
                             ("tpcc_p16_ol", 16, 11520, 1536),
                             ("tpcc_p4_big", 4, 65536, 1536)):
        bts = index_merge_bytes(P, cap, Q)
        for k in ("argsort", "jnp", "pallas"):
            rows.append((f"roofline/index_merge/{label}/{k}",
                         bts[k] / HBM_BW * 1e6,          # us at v5e HBM bw
                         f"{bts[k] / 1e6:.1f}MB"))
        rows.append((f"roofline/index_merge/{label}/fusion_traffic_x", 0.0,
                     round(bts["jnp"] / max(bts["pallas"], 1), 1)))
    return rows


def run():
    rows = []
    for f in sorted(glob.glob(str(RESULTS / "*pod16x16.json"))):
        r = json.loads(Path(f).read_text())
        cell = f"{r['arch']}/{r['shape']}"
        if r["status"] != "ok":
            rows.append((f"roofline/{cell}", 0.0, r["status"]))
            continue
        ro = r["roofline"]
        dom = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        rows.append((f"roofline/{cell}/{ro['bottleneck']}", 0.0,
                     f"{dom * 1e3:.1f}ms useful={ro['useful_flops_ratio']:.2f}"))
    rows += occ_rows()
    rows += index_merge_rows()
    return rows
