"""Roofline summary rows from the dry-run artifacts (EXPERIMENTS.md §Roofline
reads the full JSONs; this emits the headline terms per cell)."""
import glob
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run():
    rows = []
    for f in sorted(glob.glob(str(RESULTS / "*pod16x16.json"))):
        r = json.loads(Path(f).read_text())
        cell = f"{r['arch']}/{r['shape']}"
        if r["status"] != "ok":
            rows.append((f"roofline/{cell}", 0.0, r["status"]))
            continue
        ro = r["roofline"]
        dom = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        rows.append((f"roofline/{cell}/{ro['bottleneck']}", 0.0,
                     f"{dom * 1e3:.1f}ms useful={ro['useful_flops_ratio']:.2f}"))
    return rows
