"""Figure 12: latency percentiles.

Async + epoch group commit: deferral is symmetric, latency ~ U(0, e) plus the
phase the txn lands in — p50 ~ e/2, p99 ~ e (paper: 6.2/9.4 ms at e=10 ms).
Sync: per-protocol round-trip counts from the cost model.  Model-derived.
"""
import numpy as np

from benchmarks.common import get_calibration
from repro.baselines.cost_model import Network


def run():
    rows = []
    net = Network()
    e_ms = 10.0
    rng = np.random.default_rng(0)
    # epoch-commit systems: arrival uniform in epoch, release at next fence
    lat = e_ms - rng.uniform(0, e_ms, 100_000) + rng.normal(1.0, 0.5, 100_000).clip(0)
    rows.append(("fig12/async_all_p50_ms", 0.0, round(float(np.percentile(lat, 50)), 2)))
    rows.append(("fig12/async_all_p99_ms", 0.0, round(float(np.percentile(lat, 99)), 2)))
    for wl in ("ycsb", "tpcc"):
        cal = get_calibration(wl)
        for P in (0.1, 0.5, 0.9):
            # sync PB.OCC: one replication RTT
            pb = (cal.t_cross_cpu + net.rtt_s) * 1e3
            # sync Dist.OCC: remote reads + 2PC
            occ = (cal.t_cross_cpu * (1 + cal.retry_factor)
                   + P * (cal.remote_reads_per_cross + 2) * net.rtt_s) * 1e3
            # sync Dist.S2PL: locks held across reads + 2PC, queueing at p99
            s2pl = (cal.t_cross_cpu * (1 + 2 * cal.retry_factor)
                    + P * (cal.remote_reads_per_cross + 2) * net.rtt_s) * 1e3
            rows += [
                (f"fig12/{wl}_sync_P{P:g}_pb_occ_p50_ms", 0.0, round(pb, 3)),
                (f"fig12/{wl}_sync_P{P:g}_dist_occ_p50_ms", 0.0, round(occ, 3)),
                (f"fig12/{wl}_sync_P{P:g}_dist_s2pl_p50_ms", 0.0, round(s2pl, 3)),
                (f"fig12/{wl}_sync_P{P:g}_dist_s2pl_p99_ms", 0.0,
                 round(s2pl * 8, 3)),
            ]
    return rows
