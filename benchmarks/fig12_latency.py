"""Figure 12: latency percentiles.

Async + epoch group commit: MEASURED through the online service layer — an
open-loop Poisson YCSB stream is admitted, batched, executed, and stamped
enqueue→commit-fence; the percentiles below are real end-to-end times on
this host (paper: p50 ~ e/2, p99 ~ e at e=10 ms on theirs).
Sync: per-protocol round-trip counts from the cost model (model-derived).
"""
import numpy as np

from benchmarks.common import get_calibration
from repro.baselines.cost_model import Network


def _measure_async_service(duration_s=1.5, rate=1500.0):
    from repro.core.engine import StarEngine
    from repro.db import ycsb
    from repro.service import (AdmissionConfig, OpenLoopClient, TxnService,
                               YCSBSource)
    cfg = ycsb.YCSBConfig(n_partitions=4, records_per_partition=1024,
                          cross_ratio=0.10)
    eng = StarEngine(4, 1024)
    client = OpenLoopClient(YCSBSource(cfg, seed=1), rate_txn_s=rate, seed=7)
    svc = TxnService(eng, [client], AdmissionConfig(256, 512),
                     slots_per_partition=32, master_lanes=32)
    out = svc.run(duration_s=duration_s)
    out["queue_delay_ms"] = eng.controller.queue_delay_ms
    # phase attribution off the registry time series (first → last epoch
    # snapshot: excludes warmup/compile), not hand-merged stats fields
    snaps = svc.metrics.snapshots
    s0, s1 = (snaps[0], snaps[-1]) if len(snaps) > 1 else ({}, svc.metrics.latest())
    phases = {ph: s1[f"engine.{ph}_time_s"] - s0.get(f"engine.{ph}_time_s", 0.0)
              for ph in ("part", "sm", "fence")}
    tot = max(sum(phases.values()), 1e-9)
    out["phase_pct"] = {ph: round(100.0 * t / tot, 1)
                        for ph, t in phases.items()}
    return out


def _measure_read_tier_split(duration_s=2.0, rate=250.0, max_staleness=2):
    """TPC-C full mix through TxnService WITH the read tier: the write path
    (NewOrder/Payment/Delivery, enqueue -> commit fence) and the read path
    (OrderStatus/StockLevel, enqueue -> snapshot serve) each get their own
    measured percentiles — the latency half of the read/write split whose
    throughput half fig11 reports.  The offered rate is set WELL below this
    host's full-mix capacity: at overload the percentiles measure queue
    buildup, not the serving paths."""
    import numpy as np

    from repro.core.engine import StarEngine
    from repro.db import tpcc
    from repro.reads import ReadTier
    from repro.service import (AdmissionConfig, OpenLoopClient, TPCCSource,
                               TxnService)
    cfg = tpcc.TPCCConfig(n_partitions=4, n_items=400, cust_per_district=40,
                          order_ring=64, mix="full", delivery_gen_lag=256)
    state = tpcc.TPCCState(cfg)
    init = tpcc.init_values(cfg, np.random.default_rng(7), state=state)
    eng = StarEngine(4, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg))
    client = OpenLoopClient(TPCCSource(cfg, state=state, seed=1),
                            rate_txn_s=rate, seed=7)
    tier = ReadTier(max_staleness_epochs=max_staleness, sec_refresh_every=2)
    svc = TxnService(eng, [client], AdmissionConfig(64, 64),
                     slots_per_partition=16, master_lanes=16,
                     feedback=lambda b, m: tpcc.apply_consume_feedback(
                         state, b, m),
                     read_tier=tier)
    return svc.run(duration_s=duration_s)


def run():
    rows = []
    net = Network()
    # epoch-commit: measured percentiles through the service layer
    m = _measure_async_service()
    epoch_us = 1e6 * m["epoch_time_s"] / max(m["epochs"], 1)
    rows.append(("fig12/async_all_p50_ms", epoch_us, round(m["p50_ms"], 2)))
    rows.append(("fig12/async_all_p99_ms", epoch_us, round(m["p99_ms"], 2)))
    rows.append(("fig12/async_all_p999_ms", epoch_us, round(m["p999_ms"], 2)))
    rows.append(("fig12/async_throughput_txn_s", epoch_us,
                 round(m["throughput_txn_s"], 1)))
    rows.append(("fig12/async_queue_delay_ms", epoch_us,
                 round(m["queue_delay_ms"], 2)))
    for ph, pct in m["phase_pct"].items():
        rows.append((f"fig12/async_phase_{ph}_pct", 0.0, pct))
    # read-tier split: write path vs bounded-staleness snapshot-read path
    rt = _measure_read_tier_split()
    rows += [
        ("fig12/read_tier_write_p50_ms", 0.0, round(rt["p50_ms"], 2)),
        ("fig12/read_tier_write_p99_ms", 0.0, round(rt["p99_ms"], 2)),
        ("fig12/read_tier_read_p50_ms", 0.0, round(rt["read_p50_ms"], 2)),
        ("fig12/read_tier_read_p99_ms", 0.0, round(rt["read_p99_ms"], 2)),
        ("fig12/read_tier_write_txn_s", 0.0, round(rt["write_txn_s"], 1)),
        ("fig12/read_tier_read_txn_s", 0.0, round(rt["read_txn_s"], 1)),
        ("fig12/read_tier_read_served", 0.0, rt["read_served"]),
        ("fig12/read_tier_max_freshness", 0.0, rt["read_max_freshness"]),
    ]
    for wl in ("ycsb", "tpcc"):
        cal = get_calibration(wl)
        for P in (0.1, 0.5, 0.9):
            # sync PB.OCC: one replication RTT
            pb = (cal.t_cross_cpu + net.rtt_s) * 1e3
            # sync Dist.OCC: remote reads + 2PC
            occ = (cal.t_cross_cpu * (1 + cal.retry_factor)
                   + P * (cal.remote_reads_per_cross + 2) * net.rtt_s) * 1e3
            # sync Dist.S2PL: locks held across reads + 2PC, queueing at p99
            s2pl = (cal.t_cross_cpu * (1 + 2 * cal.retry_factor)
                    + P * (cal.remote_reads_per_cross + 2) * net.rtt_s) * 1e3
            rows += [
                (f"fig12/{wl}_sync_P{P:g}_pb_occ_p50_ms", 0.0, round(pb, 3)),
                (f"fig12/{wl}_sync_P{P:g}_dist_occ_p50_ms", 0.0, round(occ, 3)),
                (f"fig12/{wl}_sync_P{P:g}_dist_s2pl_p50_ms", 0.0, round(s2pl, 3)),
                (f"fig12/{wl}_sync_P{P:g}_dist_s2pl_p99_ms", 0.0,
                 round(s2pl * 8, 3)),
            ]
    return rows


def main():
    """``--smoke``: tiny measured service run only — a CI gate that the
    online path still serves traffic with sane latency (interpret-friendly:
    no workload calibration, one small open-loop run)."""
    import argparse
    import sys

    from benchmarks.common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if not args.smoke:
        emit(run())
        return
    m = _measure_async_service(duration_s=0.5, rate=400.0)
    emit([("fig12/smoke_p50_ms", 0.0, round(m["p50_ms"], 2)),
          ("fig12/smoke_p99_ms", 0.0, round(m["p99_ms"], 2)),
          ("fig12/smoke_throughput_txn_s", 0.0,
           round(m["throughput_txn_s"], 1))])
    if not (m["committed"] > 0 and m["p50_ms"] > 0):
        sys.exit(f"service smoke failed: {m}")
    print(f"SMOKE OK committed={m['committed']} p50={m['p50_ms']:.1f}ms")


if __name__ == "__main__":
    main()
