"""Figure 14: phase-transition overhead vs iteration time e (1..100 ms) and
vs cluster size — overhead = 1 - thr(e)/thr(200 ms).  Fence cost = measured
in-process fence + modeled coordination round trips (grows with n: variance
of communication delays, paper §7.4)."""
from benchmarks.common import get_calibration
from repro.baselines.cost_model import Network, star_throughput


def run():
    rows = []
    cal = get_calibration("ycsb", cross=0.1)
    ref = star_throughput(4, 0.1, cal, iteration_s=0.200)
    for e_ms in (1, 2, 5, 10, 20, 50, 100):
        thr = star_throughput(4, 0.1, cal, iteration_s=e_ms / 1e3)
        rows.append((f"fig14/overhead_e{e_ms}ms", 0.0,
                     round(1 - thr / ref, 4)))
    # vs nodes at e = 10 and 20 ms (fence rtt scaled by log n for stragglers)
    for n in (2, 4, 8, 16):
        import math
        net = Network(rtt_s=100e-6 * (1 + 0.5 * math.log2(n)))
        ref_n = star_throughput(n, 0.1, cal, net=net, iteration_s=0.200)
        for e_ms in (10, 20):
            thr = star_throughput(n, 0.1, cal, net=net, iteration_s=e_ms / 1e3)
            rows.append((f"fig14/overhead_n{n}_e{e_ms}ms", 0.0,
                         round(1 - thr / ref_n, 4)))
    return rows
