"""Figure 10: STAR improvement over partitioning-based (varying K) and
non-partitioned systems on n=4 — analytical (exact) + crossover check."""
from repro.core.analytical import (improvement_over_nonpartitioned,
                                   improvement_over_partitioning)


def run():
    n = 4
    rows = []
    for K in (2, 4, 8, 16, 32):
        for P in (0.05, 0.1, 0.3, 0.5, 0.9):
            rows.append((f"fig10/vs_partitioning_K{K}_P{P:g}", 0.0,
                         round(float(improvement_over_partitioning(n, P, K)), 4)))
    for P in (0.05, 0.1, 0.3, 0.5, 0.9):
        rows.append((f"fig10/vs_nonpartitioned_P{P:g}", 0.0,
                     round(float(improvement_over_nonpartitioned(n, P)), 4)))
    # paper claim: crossover exactly at K = n
    rows.append(("fig10/crossover_at_K_eq_n", 0.0,
                 round(float(improvement_over_partitioning(n, 0.5, n)), 4)))
    return rows
