"""Figure 3: speedup of STAR's asymmetric replication over single-node
execution, I(n) = n/(nP - P + 1) — analytical (exact)."""
from repro.core.analytical import star_speedup


def run():
    rows = []
    for n in (2, 4, 8, 16):
        for P in (0.0, 0.01, 0.05, 0.1, 0.2, 0.5, 1.0):
            rows.append((f"fig03/speedup_n{n}_P{P:g}", 0.0,
                         round(float(star_speedup(n, P)), 4)))
    return rows
