"""Figure 11: throughput of STAR vs PB.OCC / Dist.OCC / Dist.S2PL on YCSB and
TPC-C, async (epoch group commit) and sync replication, varying the
cross-partition fraction.

Measured: per-txn CPU cost + OCC retry factor from the real executors on this
host.  Modeled: 4-node cluster wall clock through the calibrated network
envelope (cost_model.py).  Paper claims checked: STAR ~= Dist.* at P=0;
STAR > both at P>=10%; up to ~10x at high P; PB.OCC flat in P.

``--mix full`` additionally MEASURES the full five-transaction TPC-C mix
(45/43/4/4/4 over the ordered-index storage engine) end to end through
``StarEngine.run_epoch`` and reports its throughput alongside the paper's
NewOrder+Payment mix — the workload the paper could not run:

    PYTHONPATH=src python -m benchmarks.fig11_throughput --mix full [--smoke]
"""
import time

from benchmarks.common import get_calibration, get_envelope_calibration
from repro.baselines.cost_model import (dist_throughput, pb_occ_throughput,
                                        star_throughput)


def measure_tpcc_mix(mix: str, n_txns: int = 512, epochs: int = 4,
                     smoke: bool = False, kernel: str = "jnp"):
    """Run the REAL engine over `mix` and return measured throughput rows.

    Wall clock covers the two device phases + fences (jit warm); throughput
    is committed transactions per second of engine time on this host.
    ``kernel`` selects the executor dispatch: "jnp" (reference) or "pallas"
    (fused OCC kernels — interpreted off-TPU, bit-identical results).
    """
    import numpy as np
    from repro.core.engine import StarEngine
    from repro.db import tpcc

    if smoke:
        n_txns, epochs = 128, 2
    cfg = tpcc.TPCCConfig(n_partitions=4, n_items=1000 if smoke else 4000,
                          cust_per_district=100, order_ring=128, mix=mix,
                          delivery_gen_lag=n_txns)
    state = tpcc.TPCCState(cfg)
    rng = np.random.default_rng(0)
    init = tpcc.init_values(cfg, rng, state=state)
    eng = StarEngine(cfg.n_partitions, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg) if mix == "full" else None,
                     kernel=kernel)
    wb = tpcc.make_batch(cfg, state, n_txns, seed=1000)
    wm = eng.run_epoch(wb)                               # warm jit
    if mix == "full":      # resolve the warm batch's Delivery claims too
        tpcc.apply_consume_feedback(state, wb, wm)
    warm = eng.stats.part_time_s + eng.stats.sm_time_s   # exclude jit compile
    warm_sm, warm_rounds = eng.stats.sm_time_s, eng.stats.sm_rounds
    t0 = time.perf_counter()
    committed = 0
    for ep in range(epochs):
        batch = tpcc.make_batch(cfg, state, n_txns, seed=ep)
        m = eng.run_epoch(batch)
        committed += m["committed_single"] + m["committed_cross"]
        if mix == "full":        # consume feedback: re-queue skipped districts
            tpcc.apply_consume_feedback(state, batch, m)
    elapsed = eng.stats.part_time_s + eng.stats.sm_time_s - warm
    wall = time.perf_counter() - t0
    assert eng.replica_consistent(), "replica diverged under measurement"
    thr = committed / max(elapsed, 1e-9)
    tag = f"{mix}_{kernel}"
    rows = [
        (f"fig11/tpcc_measured_mix_{tag}_txn_s", 1e6 * wall / max(committed, 1),
         round(thr)),
        (f"fig11/tpcc_measured_mix_{tag}_committed", 0.0, committed),
        (f"fig11/tpcc_measured_mix_{tag}_consume_skips", 0.0,
         eng.stats.consume_skips),
    ]
    if eng.stats.sm_rounds > warm_rounds:     # per-round OCC kernel time
        rows.append((f"fig11/tpcc_measured_mix_{tag}_sm_round_us",
                     1e6 * (eng.stats.sm_time_s - warm_sm)
                     / (eng.stats.sm_rounds - warm_rounds), 0))
    return rows


def run(mix: str | None = None, smoke: bool = False, kernel: str = "jnp"):
    rows = []
    if mix is not None:
        # measure the requested mix; "full" also measures the paper's
        # NewOrder+Payment mix alongside for direct comparison
        rows += measure_tpcc_mix(mix, smoke=smoke, kernel=kernel)
        if mix == "full":
            rows += measure_tpcc_mix("standard2", smoke=smoke, kernel=kernel)
    if smoke:
        return rows
    n = 4
    for wl in ("ycsb", "tpcc"):
        cal = get_calibration(wl)
        us = cal.t_cross_cpu * 1e6
        for sync in (False, True):
            tag = "sync" if sync else "async"
            for P in (0.0, 0.1, 0.3, 0.5, 0.7, 0.9):
                star = star_throughput(n, P, cal, sync_replication=sync)
                pb = pb_occ_throughput(P, cal, sync_replication=sync)
                occ = dist_throughput(n, P, cal, "occ", sync_replication=sync)
                s2pl = dist_throughput(n, P, cal, "s2pl", sync_replication=sync)
                rows += [
                    (f"fig11/{wl}_{tag}_P{P:g}_star", us, round(star)),
                    (f"fig11/{wl}_{tag}_P{P:g}_pb_occ", us, round(pb)),
                    (f"fig11/{wl}_{tag}_P{P:g}_dist_occ", us, round(occ)),
                    (f"fig11/{wl}_{tag}_P{P:g}_dist_s2pl", us, round(s2pl)),
                ]
        # claim checks at P = 10% (async) — host calibration
        star10 = star_throughput(n, 0.1, cal)
        rows.append((f"fig11/{wl}_claim_star_over_dist_occ_P10", 0.0,
                     round(star10 / dist_throughput(n, 0.1, cal, "occ"), 2)))
        rows.append((f"fig11/{wl}_claim_star_over_pb_P90", 0.0,
                     round(star_throughput(n, 0.9, cal)
                           / pb_occ_throughput(0.9, cal), 2)))
        # paper-envelope calibration (Silo-scale per-txn CPU)
        env = get_envelope_calibration(wl)
        for P in (0.0, 0.1, 0.5, 0.9):
            rows += [
                (f"fig11/{wl}_env_P{P:g}_star", 0.0,
                 round(star_throughput(n, P, env))),
                (f"fig11/{wl}_env_P{P:g}_pb_occ", 0.0,
                 round(pb_occ_throughput(P, env))),
                (f"fig11/{wl}_env_P{P:g}_dist_occ", 0.0,
                 round(dist_throughput(n, P, env, "occ"))),
                (f"fig11/{wl}_env_P{P:g}_dist_s2pl", 0.0,
                 round(dist_throughput(n, P, env, "s2pl"))),
            ]
        rows.append((f"fig11/{wl}_env_claim_star_over_dist_occ_P10", 0.0,
                     round(star_throughput(n, 0.1, env)
                           / dist_throughput(n, 0.1, env, "occ"), 2)))
        rows.append((f"fig11/{wl}_env_claim_star_over_dist_sync_P10", 0.0,
                     round(star_throughput(n, 0.1, env)
                           / dist_throughput(n, 0.1, env, "occ",
                                             sync_replication=True), 2)))
        rows.append((f"fig11/{wl}_env_claim_star_over_pb2node", 0.0,
                     round(star_throughput(n, 0.1, env)
                           / pb_occ_throughput(0.1, env), 2)))
    return rows


def main():
    import argparse

    from benchmarks.common import emit
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mix", choices=["full", "standard2"], default=None,
                    help="also MEASURE this TPC-C mix through the engine")
    ap.add_argument("--kernel", choices=["jnp", "pallas"], default="jnp",
                    help="executor dispatch for the measured mixes: jnp "
                    "reference or the fused Pallas OCC kernels "
                    "(interpret mode off-TPU; bit-identical)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale, measured rows only; fails the build "
                    "when throughput collapses (CI regression gate)")
    args = ap.parse_args()
    rows = run(mix=args.mix or ("full" if args.smoke else None),
               smoke=args.smoke, kernel=args.kernel)
    print("name,us_per_call,derived")
    emit(rows)
    if args.smoke:
        thr = {r[0]: r[2] for r in rows
               if r[0].endswith("_txn_s") or r[0].endswith("_committed")}
        rates = {k: v for k, v in thr.items() if k.endswith("_txn_s")}
        commits = {k: v for k, v in thr.items() if k.endswith("_committed")}
        # loose floors: catch collapse/regression-to-zero, not host speed
        assert rates and all(v > 5 for v in rates.values()), \
            f"throughput collapsed: {thr}"
        assert all(v > 100 for v in commits.values()), thr
        print("SMOKE OK " + " ".join(f"{k.split('_mix_')[1]}" for k in rates))


if __name__ == "__main__":
    main()
