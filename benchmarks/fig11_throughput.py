"""Figure 11: throughput of STAR vs PB.OCC / Dist.OCC / Dist.S2PL on YCSB and
TPC-C, async (epoch group commit) and sync replication, varying the
cross-partition fraction.

Measured: per-txn CPU cost + OCC retry factor from the real executors on this
host.  Modeled: 4-node cluster wall clock through the calibrated network
envelope (cost_model.py).  Paper claims checked: STAR ~= Dist.* at P=0;
STAR > both at P>=10%; up to ~10x at high P; PB.OCC flat in P.

``--mix full`` additionally MEASURES the full five-transaction TPC-C mix
(45/43/4/4/4 over the ordered-index storage engine) end to end through
``StarEngine.run_epoch`` and reports its throughput alongside the paper's
NewOrder+Payment mix — the workload the paper could not run:

    PYTHONPATH=src python -m benchmarks.fig11_throughput --mix full [--smoke]
"""
import time

from benchmarks.common import get_calibration, get_envelope_calibration
from repro.baselines.cost_model import (dist_throughput, pb_occ_throughput,
                                        star_throughput)


def measure_tpcc_mix(mix: str, n_txns: int = 512, epochs: int = 4,
                     smoke: bool = False, kernel: str = "jnp"):
    """Run the REAL engine over `mix` and return measured throughput rows.

    Wall clock covers the two device phases + fences (jit warm); throughput
    is committed transactions per second of engine time on this host.
    ``kernel`` selects the executor dispatch: "jnp" (reference) or "pallas"
    (fused OCC kernels — interpreted off-TPU, bit-identical results).
    """
    import numpy as np
    from repro.core.engine import StarEngine
    from repro.db import tpcc
    from repro.obs import MetricsRegistry

    if smoke:
        n_txns, epochs = 128, 2
    cfg = tpcc.TPCCConfig(n_partitions=4, n_items=1000 if smoke else 4000,
                          cust_per_district=100, order_ring=128, mix=mix,
                          delivery_gen_lag=n_txns)
    state = tpcc.TPCCState(cfg)
    rng = np.random.default_rng(0)
    init = tpcc.init_values(cfg, rng, state=state)
    eng = StarEngine(cfg.n_partitions, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg) if mix == "full" else None,
                     kernel=kernel)
    reg = MetricsRegistry()
    reg.register_object("engine", eng.stats)
    wb = tpcc.make_batch(cfg, state, n_txns, seed=1000)
    wm = eng.run_epoch(wb)                               # warm jit
    if mix == "full":      # resolve the warm batch's Delivery claims too
        tpcc.apply_consume_feedback(state, wb, wm)
    reg.snapshot(0)                  # post-warm baseline time-series point
    warm = eng.stats.part_time_s + eng.stats.sm_time_s   # exclude jit compile
    warm_sm, warm_rounds = eng.stats.sm_time_s, eng.stats.sm_rounds
    t0 = time.perf_counter()
    committed = 0
    for ep in range(epochs):
        batch = tpcc.make_batch(cfg, state, n_txns, seed=ep)
        m = eng.run_epoch(batch)
        committed += m["committed_single"] + m["committed_cross"]
        if mix == "full":        # consume feedback: re-queue skipped districts
            tpcc.apply_consume_feedback(state, batch, m)
        reg.snapshot(ep + 1)
    elapsed = eng.stats.part_time_s + eng.stats.sm_time_s - warm
    wall = time.perf_counter() - t0
    assert eng.replica_consistent(), "replica diverged under measurement"
    thr = committed / max(elapsed, 1e-9)
    tag = f"{mix}_{kernel}"
    rows = [
        (f"fig11/tpcc_measured_mix_{tag}_txn_s", 1e6 * wall / max(committed, 1),
         round(thr)),
        (f"fig11/tpcc_measured_mix_{tag}_committed", 0.0, committed),
        (f"fig11/tpcc_measured_mix_{tag}_consume_skips", 0.0,
         eng.stats.consume_skips),
    ]
    if eng.stats.sm_rounds > warm_rounds:     # per-round OCC kernel time
        sm_us = (1e6 * (eng.stats.sm_time_s - warm_sm)
                 / (eng.stats.sm_rounds - warm_rounds))
        # stamp the measured value into the derived column too: the BENCH
        # snapshot's "rows" dict keeps derived values only, and a literal 0
        # here once shipped `sm_round_us: 0` per mix while the headline
        # showed the real number
        assert sm_us > 0.0, (mix, kernel, eng.stats)
        rows.append((f"fig11/tpcc_measured_mix_{tag}_sm_round_us",
                     sm_us, round(sm_us, 3)))
    # §5 op-stream shipping split: fence-exposed bytes (for BENCH snapshot)
    rows.append((f"fig11/tpcc_measured_mix_{tag}_op_bytes_fence", 0.0,
                 int(eng.stats.op_bytes_fence)))
    rows.append((f"fig11/tpcc_measured_mix_{tag}_op_bytes_overlapped", 0.0,
                 int(eng.stats.op_bytes_overlapped)))
    # phase breakdown off the registry time series (post-warm baseline vs
    # final snapshot), not hand-merged stats fields
    s0, s1 = reg.snapshots[0], reg.snapshots[-1]
    t_part = s1["engine.part_time_s"] - s0["engine.part_time_s"]
    t_sm = s1["engine.sm_time_s"] - s0["engine.sm_time_s"]
    t_fence = s1["engine.fence_time_s"] - s0["engine.fence_time_s"]
    tot = max(t_part + t_sm + t_fence, 1e-9)
    for ph, t in (("part", t_part), ("sm", t_sm), ("fence", t_fence)):
        rows.append((f"fig11/tpcc_measured_mix_{tag}_phase_{ph}_pct", 0.0,
                     round(100.0 * t / tot, 1)))
    return rows


def measure_read_tier(n_txns: int = 2048, epochs: int = 2, smoke: bool = False,
                      max_staleness: int = 2):
    """Full-mix TPC-C at equal offered load, read tier OFF vs ON.

    OFF: every transaction (including the read-only OrderStatus/StockLevel
    ~8%) burns a partitioned/OCC slot through ``run_epoch``.  ON: the same
    per-epoch request stream (identical seeds; read-only txns write nothing
    so the committed DB state evolves bit-identically) is split — writes run
    through the engine, declared-read-only txns are served lock-free from
    the replica snapshot catalog between fences.  Reports the read/write
    split and the combined-throughput comparison the read tier exists for.

    The full-scale default of 2048-txn epochs puts the engine in the
    work-dominated regime where a read-only txn's marginal slot cost is
    real (~ms); at smoke scale the epoch is fixed-overhead-bound and the
    on-vs-off difference sits inside host noise, so smoke only gates the
    scale-independent invariants (see main()).
    """
    import numpy as np
    from repro.core.engine import StarEngine
    from repro.db import tpcc
    from repro.reads import SnapshotCatalog, SnapshotReadExecutor

    if smoke:
        n_txns, epochs = 128, 2

    def build():
        cfg = tpcc.TPCCConfig(n_partitions=4,
                              n_items=1000 if smoke else 4000,
                              cust_per_district=100, order_ring=128,
                              mix="full", delivery_gen_lag=n_txns)
        state = tpcc.TPCCState(cfg)
        rng = np.random.default_rng(0)
        init = tpcc.init_values(cfg, rng, state=state)
        eng = StarEngine(cfg.n_partitions, cfg.rows_per_partition,
                         init_val=init, indexes=tpcc.index_specs(cfg))
        return cfg, state, eng

    def run_pass(serve_reads: bool):
        """One full pass over the offered stream.  Both passes replay the
        same seeds over a fresh engine+state, so the committed DB evolves
        bit-identically (read-only txns write nothing) and every per-epoch
        batch shape is deterministic — running each pass TWICE and timing
        only the second run keeps jit compiles and other one-time costs
        out of the measured region for both sides equally."""
        cfg, state, eng = build()
        execu = SnapshotReadExecutor() if serve_reads else None
        catalog = (SnapshotCatalog(cfg.n_partitions, retain=max_staleness + 2)
                   if serve_reads else None)
        wb = tpcc.make_batch(cfg, state, n_txns, seed=1000)
        tpcc.apply_consume_feedback(state, wb, eng.run_epoch(wb))
        if serve_reads:
            for v in eng.read_views():
                catalog.stamp(v)
        warm = eng.stats.part_time_s + eng.stats.sm_time_s
        committed = reads = 0
        read_s = 0.0
        for ep in range(epochs):
            raw = tpcc.make_raw(cfg, state, n_txns, np.random.default_rng(ep))
            ro = raw["read_only"]
            if serve_reads:     # writes only reach the engine (thinner T)
                batch = tpcc.make_batch(
                    cfg, state, 0, raw={k: v[~ro] for k, v in raw.items()})
            else:
                batch = tpcc.make_batch(cfg, state, 0, raw=raw)
            m = eng.run_epoch(batch)
            committed += m["committed_single"] + m["committed_cross"]
            tpcc.apply_consume_feedback(state, batch, m)
            if not serve_reads:
                continue
            for v in eng.read_views():   # fence passed: refresh catalog
                catalog.stamp(v)
            # serve the read lane: group by home partition onto the
            # least-loaded fresh-enough replica, one batched gather each
            sel = np.nonzero(ro)[0]
            homes = raw["home"][sel]
            t0 = time.perf_counter()
            for p in np.unique(homes):
                grp = sel[homes == p]
                _ent, _ep, snap, arow = catalog.choose(
                    int(p), max_staleness, weight=len(grp))
                out = execu.run(snap, np.full(len(grp), arow, np.int32),
                                raw["rows"][grp], raw["kinds"][grp],
                                raw["deltas"][grp])
                np.asarray(out["val"])        # block until served
            read_s += time.perf_counter() - t0
            reads += len(sel)
        assert eng.replica_consistent()
        return (committed, reads,
                eng.stats.part_time_s + eng.stats.sm_time_s - warm, read_s)

    # One untimed shape-warm run per pass (absorbs jit compiles), then
    # best-of-N timed runs, INTERLEAVED so slow host stretches (frequency
    # drift, scheduler contention) land on both sides: min-time filters
    # the additive noise that otherwise swamps the ~read-share-sized
    # structural difference.
    reps = 3
    run_pass(False)
    run_pass(True)
    offs, ons = [], []
    for _ in range(reps):
        offs.append(run_pass(False))
        ons.append(run_pass(True))
    off_committed = offs[0][0]
    off_s = min(r[2] for r in offs)
    on_write, on_read = ons[0][0], ons[0][1]
    write_s = min(r[2] for r in ons)
    read_s = min(r[3] for r in ons)

    thr_off = off_committed / max(off_s, 1e-9)
    thr_on = (on_write + on_read) / max(write_s + read_s, 1e-9)
    return [
        ("fig11/tpcc_read_tier_off_txn_s", 1e6 * off_s / max(off_committed, 1),
         round(thr_off)),
        ("fig11/tpcc_read_tier_on_txn_s", 1e6 * (write_s + read_s)
         / max(on_write + on_read, 1), round(thr_on)),
        ("fig11/tpcc_read_tier_write_txn_s", 0.0,
         round(on_write / max(write_s, 1e-9))),
        ("fig11/tpcc_read_tier_read_txn_s", 0.0,
         round(on_read / max(read_s, 1e-9))),
        ("fig11/tpcc_read_tier_off_committed", 0.0, int(off_committed)),
        ("fig11/tpcc_read_tier_on_committed", 0.0, int(on_write + on_read)),
        ("fig11/tpcc_read_tier_read_served", 0.0, int(on_read)),
        ("fig11/tpcc_read_tier_speedup_pct", 0.0,
         round(100.0 * (thr_on / max(thr_off, 1e-9) - 1.0), 1)),
    ]


def run(mix: str | None = None, smoke: bool = False, kernel: str = "jnp"):
    rows = []
    if mix is not None:
        # measure the requested mix; "full" also measures the paper's
        # NewOrder+Payment mix alongside for direct comparison
        rows += measure_tpcc_mix(mix, smoke=smoke, kernel=kernel)
        if mix == "full":
            rows += measure_tpcc_mix("standard2", smoke=smoke, kernel=kernel)
            rows += measure_read_tier(smoke=smoke)
    if smoke:
        return rows
    n = 4
    for wl in ("ycsb", "tpcc"):
        cal = get_calibration(wl)
        us = cal.t_cross_cpu * 1e6
        for sync in (False, True):
            tag = "sync" if sync else "async"
            for P in (0.0, 0.1, 0.3, 0.5, 0.7, 0.9):
                star = star_throughput(n, P, cal, sync_replication=sync)
                pb = pb_occ_throughput(P, cal, sync_replication=sync)
                occ = dist_throughput(n, P, cal, "occ", sync_replication=sync)
                s2pl = dist_throughput(n, P, cal, "s2pl", sync_replication=sync)
                rows += [
                    (f"fig11/{wl}_{tag}_P{P:g}_star", us, round(star)),
                    (f"fig11/{wl}_{tag}_P{P:g}_pb_occ", us, round(pb)),
                    (f"fig11/{wl}_{tag}_P{P:g}_dist_occ", us, round(occ)),
                    (f"fig11/{wl}_{tag}_P{P:g}_dist_s2pl", us, round(s2pl)),
                ]
        # claim checks at P = 10% (async) — host calibration
        star10 = star_throughput(n, 0.1, cal)
        rows.append((f"fig11/{wl}_claim_star_over_dist_occ_P10", 0.0,
                     round(star10 / dist_throughput(n, 0.1, cal, "occ"), 2)))
        rows.append((f"fig11/{wl}_claim_star_over_pb_P90", 0.0,
                     round(star_throughput(n, 0.9, cal)
                           / pb_occ_throughput(0.9, cal), 2)))
        # paper-envelope calibration (Silo-scale per-txn CPU)
        env = get_envelope_calibration(wl)
        for P in (0.0, 0.1, 0.5, 0.9):
            rows += [
                (f"fig11/{wl}_env_P{P:g}_star", 0.0,
                 round(star_throughput(n, P, env))),
                (f"fig11/{wl}_env_P{P:g}_pb_occ", 0.0,
                 round(pb_occ_throughput(P, env))),
                (f"fig11/{wl}_env_P{P:g}_dist_occ", 0.0,
                 round(dist_throughput(n, P, env, "occ"))),
                (f"fig11/{wl}_env_P{P:g}_dist_s2pl", 0.0,
                 round(dist_throughput(n, P, env, "s2pl"))),
            ]
        rows.append((f"fig11/{wl}_env_claim_star_over_dist_occ_P10", 0.0,
                     round(star_throughput(n, 0.1, env)
                           / dist_throughput(n, 0.1, env, "occ"), 2)))
        rows.append((f"fig11/{wl}_env_claim_star_over_dist_sync_P10", 0.0,
                     round(star_throughput(n, 0.1, env)
                           / dist_throughput(n, 0.1, env, "occ",
                                             sync_replication=True), 2)))
        rows.append((f"fig11/{wl}_env_claim_star_over_pb2node", 0.0,
                     round(star_throughput(n, 0.1, env)
                           / pb_occ_throughput(0.1, env), 2)))
    return rows


def main():
    import argparse

    from benchmarks.common import emit
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mix", choices=["full", "standard2"], default=None,
                    help="also MEASURE this TPC-C mix through the engine")
    ap.add_argument("--kernel", choices=["jnp", "pallas"], default="jnp",
                    help="executor dispatch for the measured mixes: jnp "
                    "reference or the fused Pallas OCC kernels "
                    "(interpret mode off-TPU; bit-identical)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale, measured rows only; fails the build "
                    "when throughput collapses (CI regression gate)")
    ap.add_argument("--bench-json", metavar="PATH", default=None,
                    help="write the measured-row snapshot (full-mix txn/s, "
                    "read-tier split, SM round us, fence-exposed bytes) as "
                    "JSON, e.g. BENCH_fig11.json")
    args = ap.parse_args()
    rows = run(mix=args.mix or ("full" if args.smoke else None),
               smoke=args.smoke, kernel=args.kernel)
    print("name,us_per_call,derived")
    emit(rows)
    if args.bench_json:
        import json
        d = {r[0]: r[2] for r in rows if r[0].startswith("fig11/tpcc_")}
        us = {r[0]: round(r[1], 3) for r in rows
              if r[0].startswith("fig11/tpcc_") and r[1]}
        k = args.kernel
        bench = {
            "schema": 1,
            "full_mix_txn_s": d.get(f"fig11/tpcc_measured_mix_full_{k}_txn_s"),
            "read_tier_on_txn_s": d.get("fig11/tpcc_read_tier_on_txn_s"),
            "read_tier_off_txn_s": d.get("fig11/tpcc_read_tier_off_txn_s"),
            "read_txn_s": d.get("fig11/tpcc_read_tier_read_txn_s"),
            "write_txn_s": d.get("fig11/tpcc_read_tier_write_txn_s"),
            "sm_round_us": us.get(
                f"fig11/tpcc_measured_mix_full_{k}_sm_round_us"),
            "fence_exposed_bytes": d.get(
                f"fig11/tpcc_measured_mix_full_{k}_op_bytes_fence"),
            "rows": d, "us_per_call": us,
        }
        with open(args.bench_json, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.bench_json}")
    if args.smoke:
        thr = {r[0]: r[2] for r in rows
               if r[0].endswith("_txn_s") or r[0].endswith("_committed")}
        rates = {k: v for k, v in thr.items() if k.endswith("_txn_s")}
        commits = {k: v for k, v in thr.items() if k.endswith("_committed")}
        # loose floors: catch collapse/regression-to-zero, not host speed
        assert rates and all(v > 5 for v in rates.values()), \
            f"throughput collapsed: {thr}"
        assert all(v > 100 for v in commits.values()), thr
        # per-mix SM-round attribution must survive into the derived column
        # (regression gate for the sm_round_us: 0 snapshot bug)
        sm_rows = {r[0]: r[2] for r in rows
                   if r[0].endswith("_sm_round_us")}
        assert sm_rows and all(v > 0 for v in sm_rows.values()), \
            f"per-mix sm_round attribution lost: {sm_rows}"
        if "fig11/tpcc_read_tier_read_txn_s" in rates:
            # Scale-independent invariants only: serving a read from a
            # snapshot must be much cheaper than committing a write through
            # the engine, and on-vs-off must not collapse.  The strict
            # on > off comparison is a FULL-scale result (2048-txn epochs,
            # work-dominated regime) — at smoke scale both passes are
            # fixed-overhead-bound and the ~0% difference is host noise.
            assert (rates["fig11/tpcc_read_tier_read_txn_s"]
                    > rates["fig11/tpcc_read_tier_write_txn_s"]), \
                f"snapshot reads slower than engine writes: {thr}"
            spd = next(r[2] for r in rows
                       if r[0] == "fig11/tpcc_read_tier_speedup_pct")
            assert spd > -15, \
                f"read tier collapsed vs baseline: {spd}% {thr}"
        print("SMOKE OK "
              + " ".join(k.split("tpcc_")[1] for k in sorted(rates)))


if __name__ == "__main__":
    main()
