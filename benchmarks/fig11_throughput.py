"""Figure 11: throughput of STAR vs PB.OCC / Dist.OCC / Dist.S2PL on YCSB and
TPC-C, async (epoch group commit) and sync replication, varying the
cross-partition fraction.

Measured: per-txn CPU cost + OCC retry factor from the real executors on this
host.  Modeled: 4-node cluster wall clock through the calibrated network
envelope (cost_model.py).  Paper claims checked: STAR ~= Dist.* at P=0;
STAR > both at P>=10%; up to ~10x at high P; PB.OCC flat in P.
"""
from benchmarks.common import get_calibration, get_envelope_calibration
from repro.baselines.cost_model import (dist_throughput, pb_occ_throughput,
                                        star_throughput)


def run():
    rows = []
    n = 4
    for wl in ("ycsb", "tpcc"):
        cal = get_calibration(wl)
        us = cal.t_cross_cpu * 1e6
        for sync in (False, True):
            tag = "sync" if sync else "async"
            for P in (0.0, 0.1, 0.3, 0.5, 0.7, 0.9):
                star = star_throughput(n, P, cal, sync_replication=sync)
                pb = pb_occ_throughput(P, cal, sync_replication=sync)
                occ = dist_throughput(n, P, cal, "occ", sync_replication=sync)
                s2pl = dist_throughput(n, P, cal, "s2pl", sync_replication=sync)
                rows += [
                    (f"fig11/{wl}_{tag}_P{P:g}_star", us, round(star)),
                    (f"fig11/{wl}_{tag}_P{P:g}_pb_occ", us, round(pb)),
                    (f"fig11/{wl}_{tag}_P{P:g}_dist_occ", us, round(occ)),
                    (f"fig11/{wl}_{tag}_P{P:g}_dist_s2pl", us, round(s2pl)),
                ]
        # claim checks at P = 10% (async) — host calibration
        star10 = star_throughput(n, 0.1, cal)
        rows.append((f"fig11/{wl}_claim_star_over_dist_occ_P10", 0.0,
                     round(star10 / dist_throughput(n, 0.1, cal, "occ"), 2)))
        rows.append((f"fig11/{wl}_claim_star_over_pb_P90", 0.0,
                     round(star_throughput(n, 0.9, cal)
                           / pb_occ_throughput(0.9, cal), 2)))
        # paper-envelope calibration (Silo-scale per-txn CPU)
        env = get_envelope_calibration(wl)
        for P in (0.0, 0.1, 0.5, 0.9):
            rows += [
                (f"fig11/{wl}_env_P{P:g}_star", 0.0,
                 round(star_throughput(n, P, env))),
                (f"fig11/{wl}_env_P{P:g}_pb_occ", 0.0,
                 round(pb_occ_throughput(P, env))),
                (f"fig11/{wl}_env_P{P:g}_dist_occ", 0.0,
                 round(dist_throughput(n, P, env, "occ"))),
                (f"fig11/{wl}_env_P{P:g}_dist_s2pl", 0.0,
                 round(dist_throughput(n, P, env, "s2pl"))),
            ]
        rows.append((f"fig11/{wl}_env_claim_star_over_dist_occ_P10", 0.0,
                     round(star_throughput(n, 0.1, env)
                           / dist_throughput(n, 0.1, env, "occ"), 2)))
        rows.append((f"fig11/{wl}_env_claim_star_over_dist_sync_P10", 0.0,
                     round(star_throughput(n, 0.1, env)
                           / dist_throughput(n, 0.1, env, "occ",
                                             sync_replication=True), 2)))
        rows.append((f"fig11/{wl}_env_claim_star_over_pb2node", 0.0,
                     round(star_throughput(n, 0.1, env)
                           / pb_occ_throughput(0.1, env), 2)))
    return rows
