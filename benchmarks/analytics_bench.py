"""Tracked perf harness for the HTAP analytics lane (ChangeLog MVs).

The analytics lane answers CH-benCHmark-style queries from columnar
materialized views maintained incrementally off the SAME ordered op
stream the replicas replay (``repro.changelog``).  This harness runs the
full five-transaction TPC-C mix on a ``StarEngine`` with the views
subscribed and emits:

* MV maintenance cost per stream event — ``mv_apply_slab`` (the scan
  scatter over one partitioned slab) and ``mv_apply_master`` (the Thomas
  merge of the single-master stream) — measured wall time per call plus
  the headline **apply throughput in writes/s** (the tracked regression
  floor: CI fails if it collapses below ``FLOOR_WRITES_S``);
* the fence stamp cost (aggregates off the committed projection) and the
  per-serve latency of the query mix (``lane.serve``);
* the correctness gates the numbers are only meaningful under: at EVERY
  fence the stamped aggregates bit-equal a from-scratch recompute of the
  engine's committed state, and time-travel returns exactly the recorded
  stamps for every retained fence.

``--bench-json BENCH_analytics.json`` writes the schema-versioned
snapshot (the committed tracking artifact, like BENCH_kernels.json).
``--smoke`` runs a small shape + all gates for CI; ``--validate`` runs
the bit-equality gates only.

    PYTHONPATH=src python -m benchmarks.analytics_bench --smoke
    PYTHONPATH=src python -m benchmarks.analytics_bench --bench-json BENCH_analytics.json
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timed

SCHEMA = 1
#: tracked floor on MV apply throughput (writes applied per second of
#: maintenance time) — a collapse gate, far below any healthy host
FLOOR_WRITES_S = 5_000.0


class _Capture:
    """ChangeLog subscriber that keeps the published stream events so the
    timing loop can re-apply them against fresh views."""

    def __init__(self):
        self.slabs = []        # (log, info)
        self.masters = []      # stream dicts

    def on_slab(self, log, info):
        self.slabs.append((log, dict(info)))

    def on_master(self, stream):
        self.masters.append(stream)


def _mk(P, epochs, B, seed=7):
    from repro.core.engine import StarEngine
    from repro.db import tpcc
    cfg = tpcc.TPCCConfig(n_partitions=P, n_items=400, cust_per_district=40,
                          order_ring=64, mix="full", delivery_gen_lag=256)
    state = tpcc.TPCCState(cfg)
    init = tpcc.init_values(cfg, np.random.default_rng(seed), state=state)
    eng = StarEngine(P, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg), n_slabs=4)
    return cfg, state, eng


def _drive(cfg, state, eng, lane, epochs, B, check=True):
    """Run the mix with the lane attached; gate bit-equality per fence."""
    from repro.db import tpcc
    views = lane.views
    oracle = {eng.committed_epoch: views.recompute(eng.committed_state()[0])}
    for ep in range(epochs):
        batch = tpcc.make_batch(cfg, state, B, seed=ep)
        m = eng.run_epoch(batch)
        tpcc.apply_consume_feedback(state, batch, m)
        lane.serve(eng.committed_epoch)
        if not check:
            continue
        epoch, aggs = views.latest()
        assert epoch == eng.committed_epoch, (epoch, eng.committed_epoch)
        want = views.recompute(eng.committed_state()[0])
        for k in ("revenue", "stock_low", "undelivered", "order_latency"):
            assert np.array_equal(aggs[k], want[k]), \
                f"MV {k} diverged from recompute at fence {epoch}"
        oracle[epoch] = want
    if check:
        retained = views.retained_epochs()
        assert retained, "no fence stamps retained"
        for e in retained:
            tt = views.time_travel(e)
            for k, v in oracle[e].items():
                assert np.array_equal(tt[k], v), (e, k)
        assert views.time_travel(-1) is None
    return eng.replica_consistent()


def run(smoke: bool = False):
    from repro.changelog import AnalyticsLane, MaterializedViews
    P, epochs, B, reps = (2, 3, 96, 2) if smoke else (4, 8, 192, 5)
    cfg, state, eng = _mk(P, epochs, B)
    lane = AnalyticsLane(cfg, stock_threshold=40, retain=4)
    assert lane.ensure_attached(eng)
    cap = eng.changelog.subscribe(_Capture())
    assert _drive(cfg, state, eng, lane, epochs, B), "replicas diverged"
    lbl = f"analytics/p{P}_b{B}"
    rows = []

    # -- MV maintenance cost: re-apply captured stream events ------------
    views = MaterializedViews(cfg, stock_threshold=40, retain=4)
    val, tid = eng.committed_state()
    views.on_reset(val, tid, 0)
    slab_log, slab_info = max(
        cap.slabs, key=lambda e: int(np.asarray(e[0]["write"]).sum()))
    w_slab = int(np.asarray(slab_log["write"]).sum())
    us_slab, _ = timed(lambda: (views.on_slab(slab_log, slab_info),
                                views.proj)[1], reps=reps)
    us_slab *= 1e6
    rows.append((f"{lbl}/mv_apply_slab", us_slab, f"{w_slab}w"))

    w_sm = us_sm = 0
    if cap.masters:
        sm = max(cap.masters,
                 key=lambda s: int(np.asarray(s["log"]["write"]).sum()))
        w_sm = int(np.asarray(sm["log"]["write"]).sum())
        us_sm, _ = timed(lambda: (views.on_master(sm), views.proj)[1],
                         reps=reps)
        us_sm *= 1e6
        rows.append((f"{lbl}/mv_apply_master", us_sm, f"{w_sm}w"))

    # headline: writes applied per second of maintenance wall time
    writes_s = (w_slab + w_sm) / ((us_slab + us_sm) * 1e-6)
    rows.append((f"{lbl}/mv_apply_writes_per_s", 0.0, round(writes_s, 1)))

    # -- fence stamp + query serve ---------------------------------------
    proj = np.asarray(views.proj)
    us_stamp, _ = timed(lambda: views._aggregates(proj), reps=reps)
    rows.append((f"{lbl}/fence_stamp", us_stamp * 1e6, "4 aggregates"))
    us_serve, _ = timed(
        lambda: lane.serve(eng.committed_epoch) or {"epoch": 0}, reps=reps)
    rows.append((f"{lbl}/query_serve", us_serve * 1e6,
                 f"{len(lane.QUERIES)}q mix"))
    s = lane.summary()
    rows.append((f"{lbl}/q_p50_ms", 0.0, round(s["analytics_q_p50_ms"], 4)))
    rows.append((f"{lbl}/q_p99_ms", 0.0, round(s["analytics_q_p99_ms"], 4)))
    rows.append((f"{lbl}/mv_slabs", 0.0, s["analytics_mv_slabs"]))
    rows.append((f"{lbl}/mv_writes", 0.0, s["analytics_mv_writes"]))
    return rows, writes_s


def validate():
    """Bit-equality gates only: every fence stamp == recompute, and
    time-travel returns exactly the recorded stamps."""
    from repro.changelog import AnalyticsLane
    cfg, state, eng = _mk(2, 2, 96)
    lane = AnalyticsLane(cfg, stock_threshold=40, retain=4)
    assert lane.ensure_attached(eng)
    assert _drive(cfg, state, eng, lane, 2, 96), "replicas diverged"
    print("BIT-EQUAL OK mv == recompute at every fence, time-travel exact")


def main():
    import argparse
    import json

    from benchmarks.common import emit
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shape + bit-equality + throughput floor (CI)")
    ap.add_argument("--validate", action="store_true",
                    help="bit-equality gates only")
    ap.add_argument("--bench-json", metavar="PATH", default=None,
                    help="write the snapshot, e.g. BENCH_analytics.json")
    args = ap.parse_args()
    if args.validate:
        validate()
        return
    rows, writes_s = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    emit(rows)
    # the tracked claim: MV maintenance keeps up — apply throughput must
    # clear the collapse floor (measured on the heaviest captured events)
    assert writes_s >= FLOOR_WRITES_S, \
        f"MV apply throughput collapsed: {writes_s:.0f} < {FLOOR_WRITES_S}"
    if args.bench_json:
        bench = {
            "schema": SCHEMA,
            "smoke": bool(args.smoke),
            "floor_writes_per_s": FLOOR_WRITES_S,
            "mv_apply_writes_per_s": round(writes_s, 1),
            "rows": {r[0]: r[2] for r in rows},
            "us_per_call": {r[0]: round(r[1], 3) for r in rows if r[1]},
        }
        with open(args.bench_json, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.bench_json}")
    if args.smoke:
        print(f"SMOKE OK mv_apply_writes_per_s={writes_s:.0f} "
              f"(floor {FLOOR_WRITES_S:.0f})")


if __name__ == "__main__":
    main()
