"""Tracked per-kernel perf harness for the Pallas OCC/index kernels.

For each kernel — ``occ_round`` (the 3-launch lock/validate/install
pipeline), ``scan_window`` (the scalar-prefetch index probe) and
``index_merge`` (the fused delete-compact + rank + scatter merge) — at
TPC-C shapes P ∈ {4, 16} × index cap ∈ {11520, 65536}, this emits:

* measured wall time per call (interpret mode on this host — no TPU in
  the container; on hardware the same rows track the lowered kernels),
* modeled HBM bytes per call for each dispatch generation
  (``occ_round_bytes`` / ``index_merge_bytes`` — the jnp reference's
  whole-segment gathers vs the fused kernels' resident-segment streams),
* the roofline fraction: modeled-bytes/HBM_BW ideal time over measured
  wall time (≪1 in interpret mode by construction; meaningful on TPU).

``--bench-json BENCH_kernels.json`` writes the schema-versioned snapshot
(the committed tracking artifact, like BENCH_fig11.json).  ``--smoke``
runs tiny shapes + bit-equality parity and gates the modeled traffic
claim (fused merge ≥ 2x less HBM traffic than the jnp gather merge at
TPC-C scale) for CI; ``--validate`` runs the parity checks only.

    PYTHONPATH=src python -m benchmarks.kernel_bench --smoke
    PYTHONPATH=src python -m benchmarks.kernel_bench --bench-json BENCH_kernels.json
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import timed

SCHEMA = 1
SHAPES = [(4, 11520), (16, 11520), (4, 65536), (16, 65536)]
B, M, K, C = 128, 24, 12, 10           # single-master lane batch shape
Q_MERGE = 1536                         # per-partition merge ops per call


def _mb(b):
    return f"{b / 1e6:.1f}MB"


def _roofline_us(nbytes):
    from repro.launch.roofline import HBM_BW
    return nbytes / HBM_BW * 1e6


# ---------------------------------------------------------------------------
# workload builders (seeded, numpy-side)
# ---------------------------------------------------------------------------
def _merge_args(rng, P, cap, Q):
    from repro.storage.index import SENTINEL
    key = np.full((P, cap), SENTINEL, np.int32)
    n_live = cap // 2
    for p in range(P):
        key[p, :n_live] = np.sort(rng.choice(cap * 4, n_live, replace=False))
    live = key != SENTINEL
    prow = np.where(live, rng.integers(0, cap, (P, cap)), 0).astype(np.int32)
    tid = np.where(live, rng.integers(1, 99, (P, cap)), 0).astype(np.uint32)
    Kd = Ki = Q // 2
    del_pq = np.stack([rng.choice(key[p, :n_live], Kd) for p in range(P)])
    ins_pq = rng.integers(0, cap * 4, (P, Ki)).astype(np.int32)
    prow_pq = rng.integers(0, cap, (P, Ki)).astype(np.int32)
    tid_pq = rng.integers(1, 99, (P, Ki)).astype(np.uint32)
    return tuple(jnp.asarray(a) for a in
                 (key, prow, tid, del_pq.astype(np.int32), ins_pq,
                  prow_pq, tid_pq))


def _scan_args(rng, P, cap, Q, n_slots):
    S = P * cap
    fk = np.sort(rng.integers(0, cap * 4, (P, cap)).astype(np.int32),
                 axis=1).reshape(S)
    ft = rng.integers(0, 99, S).astype(np.uint32)
    q = rng.integers(0, cap * 4, Q).astype(np.int32)
    seg_base = (rng.integers(0, P, Q) * cap).astype(np.int32)
    seg_cap = np.full(Q, cap, np.int32)
    n_iters = int(cap).bit_length() + 1
    return tuple(jnp.asarray(a) for a in (fk, ft, q, seg_base, seg_cap)), \
        n_iters


def _scan_window_jnp(flat_key, flat_tid, q, seg_base, seg_cap, n_slots):
    """The reference probe's traffic shape: gather each query's WHOLE
    segment, searchsorted, then the window gather (cf.
    ref.locate_index_ops_ref) — what the fused kernel replaces."""
    import jax
    cap = int(seg_cap[0])
    seg = flat_key[seg_base[:, None] + jnp.arange(cap, dtype=jnp.int32)]
    pos = jax.vmap(jnp.searchsorted)(seg, q).astype(jnp.int32)
    window = pos[:, None] + jnp.arange(n_slots, dtype=jnp.int32)
    slots = jnp.clip(window, 0, seg_cap[:, None] - 1)
    gidx = seg_base[:, None] + slots
    return pos, flat_key[gidx], flat_tid[gidx]


def scan_window_bytes(P, cap, Q, n_slots):
    """Modeled HBM bytes per probe call (int32/uint32 words): the jnp
    reference gathers (Q, cap) keys; the kernel streams the resident
    segments once + O(log cap + n_slots) elements per query."""
    W = 4
    n_iters = int(cap).bit_length() + 1
    return {"jnp": W * (Q * cap + 3 * Q + 2 * Q * n_slots),
            "pallas": W * (2 * P * cap + Q * (n_iters + 3 + 2 * n_slots))}


def _occ_args(rng, P, cap, n_rows, b, m, k, c, scan_l):
    val = jnp.asarray(rng.integers(0, 100, (n_rows, c)), jnp.int32)
    tidw = jnp.asarray(rng.integers(0, 50, n_rows), jnp.uint32)
    rows = jnp.asarray(
        np.stack([rng.choice(n_rows, m, replace=False) for _ in range(b)]),
        jnp.int32)
    kind = jnp.asarray(rng.integers(0, 4, (b, m)), jnp.int32)
    delta = jnp.asarray(rng.integers(-3, 3, (b, m, c)), jnp.int32)
    wmask = jnp.asarray(rng.random((b, m)) < 0.5)
    amask = wmask | jnp.asarray(rng.random((b, m)) < 0.5)
    active = jnp.asarray(rng.random(b) < 0.9)
    last_tid = jnp.asarray(rng.integers(0, 50, b), jnp.uint32)
    NT = n_rows + P * cap
    ix = {"claim_addr": jnp.asarray(
              rng.integers(n_rows, NT, (b, k)), jnp.int32),
          "claim_tid": jnp.asarray(rng.integers(0, 50, (b, k)), jnp.uint32),
          "scan_addr": jnp.asarray(
              rng.integers(n_rows, NT + 1, (b, k, scan_l + 1)), jnp.int32),
          "scan_tid": jnp.asarray(
              rng.integers(0, 50, (b, k, scan_l + 1)), jnp.uint32),
          "scan_valid": jnp.asarray(rng.random((b, k, scan_l + 1)) < 0.5),
          "no_addr": NT}
    has_claim = jnp.asarray(rng.random((b, k)) < 0.5)
    return (val, tidw, rows, kind, delta, wmask, amask, active, last_tid,
            ix, has_claim, NT)


# ---------------------------------------------------------------------------
# per-kernel benches
# ---------------------------------------------------------------------------
def bench_index_merge(P, cap, Q, reps):
    from repro.kernels.index_merge.ops import index_merge, index_merge_bytes
    rng = np.random.default_rng(0)
    args = _merge_args(rng, P, cap, Q)
    bts = index_merge_bytes(P, cap, Q)
    lbl = f"kernels/index_merge/p{P}_cap{cap}"
    rows = [(f"{lbl}/argsort_modeled", 0.0, _mb(bts["argsort"]))]
    for kern in ("jnp", "pallas"):
        us, _ = timed(lambda k=kern: index_merge(*args, use_pallas=k ==
                                                 "pallas"), reps=reps)
        us *= 1e6
        frac = _roofline_us(bts[kern]) / max(us, 1e-9)
        rows += [(f"{lbl}/{kern}", us, _mb(bts[kern])),
                 (f"{lbl}/{kern}_roofline_frac", 0.0, round(frac, 5))]
    rows.append((f"{lbl}/traffic_x", 0.0,
                 round(bts["jnp"] / bts["pallas"], 1)))
    return rows


def bench_scan_window(P, cap, Q, n_slots, reps):
    from repro.kernels.occ.kernel import scan_window_pallas
    rng = np.random.default_rng(1)
    args, n_iters = _scan_args(rng, P, cap, Q, n_slots)
    bts = scan_window_bytes(P, cap, Q, n_slots)
    lbl = f"kernels/scan_window/p{P}_cap{cap}"
    rows = []
    runs = {"jnp": lambda: _scan_window_jnp(*args, n_slots),
            "pallas": lambda: scan_window_pallas(
                *args, n_slots=n_slots, n_iters=n_iters, interpret=True)}
    for kern, fn in runs.items():
        us, _ = timed(fn, reps=reps)
        us *= 1e6
        frac = _roofline_us(bts[kern]) / max(us, 1e-9)
        rows += [(f"{lbl}/{kern}", us, _mb(bts[kern])),
                 (f"{lbl}/{kern}_roofline_frac", 0.0, round(frac, 5))]
    rows.append((f"{lbl}/traffic_x", 0.0,
                 round(bts["jnp"] / bts["pallas"], 1)))
    return rows


def bench_occ_round(P, cap, n_rows, b, m, k, c, reps):
    from repro.kernels.occ.ops import occ_round, occ_round_bytes
    from repro.storage.index import SCAN_L
    rng = np.random.default_rng(2)
    (val, tidw, rows_a, kind, delta, wmask, amask, active, last_tid,
     ix, has_claim, NT) = _occ_args(rng, P, cap, n_rows, b, m, k, c, SCAN_L)
    bts = occ_round_bytes(B=b, M=m, K=k, C=c, n_rows=n_rows,
                          index_caps=[cap], n_indexes_P=P)
    lbl = f"kernels/occ_round/p{P}_cap{cap}"
    rows = []
    for kern in ("jnp", "pallas"):
        us, _ = timed(lambda kn=kern: occ_round(
            val, tidw, rows_a, kind, delta, wmask, amask, active,
            jnp.uint32(2), last_tid, ix, has_claim, kernel=kn), reps=reps)
        us *= 1e6
        frac = _roofline_us(bts[kern]) / max(us, 1e-9)
        rows += [(f"{lbl}/{kern}", us, _mb(bts[kern])),
                 (f"{lbl}/{kern}_roofline_frac", 0.0, round(frac, 5))]
    rows.append((f"{lbl}/traffic_x", 0.0,
                 round(bts["jnp"] / bts["pallas"], 1)))
    return rows


def model_rows():
    """The modeled-traffic claim rows at full TPC-C shapes — computed in
    every mode (smoke included): the ≥2x fused-merge claim gates on these."""
    from repro.kernels.index_merge.ops import index_merge_bytes
    rows = []
    for P, cap in SHAPES:
        bts = index_merge_bytes(P, cap, Q_MERGE)
        rows.append((f"kernels/index_merge/p{P}_cap{cap}/modeled_traffic_x",
                     0.0, round(bts["jnp"] / bts["pallas"], 1)))
    return rows


def validate():
    """Bit-equality parity at moderate shapes (all three kernels)."""
    import jax
    from repro.kernels.index_merge.ops import index_merge
    from repro.kernels.occ.kernel import scan_window_pallas
    from repro.kernels.occ.ops import occ_round
    from repro.storage.index import SCAN_L

    rng = np.random.default_rng(9)
    P, cap, Q = 3, 96, 24
    args = _merge_args(rng, P, cap, Q)
    a, b_ = index_merge(*args, use_pallas=False), \
        index_merge(*args, use_pallas=True)
    assert all(bool(jnp.array_equal(x, y)) for x, y in zip(a, b_)), \
        "index_merge parity"

    sargs, n_iters = _scan_args(rng, P, cap, Q, SCAN_L + 1)
    a = _scan_window_jnp(*sargs, SCAN_L + 1)
    b_ = scan_window_pallas(*sargs, n_slots=SCAN_L + 1, n_iters=n_iters,
                            interpret=True)
    assert all(bool(jnp.array_equal(x, y)) for x, y in zip(a, b_)), \
        "scan_window parity"

    (val, tidw, rows_a, kind, delta, wmask, amask, active, last_tid,
     ix, has_claim, NT) = _occ_args(rng, P, cap, 64, 16, 6, 4, 5, SCAN_L)
    outs = [occ_round(val, tidw, rows_a, kind, delta, wmask, amask, active,
                      jnp.uint32(2), last_tid, ix, has_claim, kernel=kn)
            for kn in ("jnp", "pallas")]
    assert all(bool(jnp.array_equal(x, y)) for x, y in zip(*outs)), \
        "occ_round parity"
    print("PARITY OK index_merge scan_window occ_round")


def run(smoke: bool = False):
    from repro.storage.index import SCAN_L
    rows = model_rows()
    if smoke:
        shapes, q, b, reps = [(2, 512)], 64, 8, 1
        m, k = 6, 4
    else:
        shapes, q, b, reps = SHAPES, Q_MERGE, B, 3
        m, k = M, K
    for P, cap in shapes:
        rows += bench_index_merge(P, cap, q, reps)
        rows += bench_scan_window(P, cap, q, SCAN_L + 1, reps)
        rows += bench_occ_round(P, cap, min(2880 * P, 4 * cap), b, m, k, C,
                                reps)
    return rows


def main():
    import argparse
    import json

    from benchmarks.common import emit
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + parity + traffic-claim gate (CI)")
    ap.add_argument("--validate", action="store_true",
                    help="bit-equality parity checks only")
    ap.add_argument("--bench-json", metavar="PATH", default=None,
                    help="write the snapshot, e.g. BENCH_kernels.json")
    args = ap.parse_args()
    if args.validate:
        validate()
        return
    if args.smoke:
        validate()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    emit(rows)
    # the tracked claim: fused merge moves ≥2x less modeled HBM traffic
    # than the jnp gather merge per vmapped call at TPC-C scale
    ratios = {r[0]: r[2] for r in rows if r[0].endswith("modeled_traffic_x")}
    assert ratios and all(v >= 2.0 for v in ratios.values()), \
        f"fused-merge traffic claim regressed: {ratios}"
    if args.bench_json:
        bench = {
            "schema": SCHEMA,
            "shapes": [list(s) for s in SHAPES],
            "smoke": bool(args.smoke),
            "merge_traffic_x": {k.split("/")[2]: v for k, v in
                                ratios.items()},
            "rows": {r[0]: r[2] for r in rows},
            "us_per_call": {r[0]: round(r[1], 3) for r in rows if r[1]},
        }
        with open(args.bench_json, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.bench_json}")
    if args.smoke:
        back = {r[0] for r in rows}
        assert any(n.startswith("kernels/index_merge/") for n in back)
        assert any(n.startswith("kernels/scan_window/") for n in back)
        assert any(n.startswith("kernels/occ_round/") for n in back)
        print("SMOKE OK " + " ".join(sorted(ratios)))


if __name__ == "__main__":
    main()
