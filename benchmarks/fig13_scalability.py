"""Figure 13 (repro): cluster scalability with node count + live recovery.

Weak scaling in the TPC-C/YCSB tradition — every node brings its own
partitions and its own offered load — over *forced host devices* (the
device-count trick: each subprocess restarts jax with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``, N ∈ {1, 2, 4, 8};
one device == one paper node).  Each worker runs the REAL distributed
runtime (`repro.cluster.ClusterRuntime`: shard_map partitioned phase with
zero collectives, slab-streamed op-stream shipping to the full replica
DURING execution, psum fence waiting only on the unshipped tail slab,
single-master phase on the full replica's device) and reports measured
partitioned-phase throughput plus the §5 stream-byte split — bytes
overlapped with execution vs bytes exposed at the fence; the parent
asserts the cluster metric grows monotonically from N=1 to N=8 AND that
the fence-exposed bytes under streaming are strictly lower than the
ship-everything-at-the-fence baseline (``--slabs 1``, the pre-streaming
behavior) on the N=4 configuration.

Measurement contract (small host, simulated nodes): the N simulated
devices timeshare this host's cores and the runtime enqueues their
per-epoch executions from one thread, so the measured WALL time of the
partitioned phase scales ~linearly in N even though the phase is
coordination-free (verified: zero collectives in its HLO).  The figure
therefore reports two numbers per N: ``part_txn_s_wall`` (committed /
median wall phase time — flat on a 2-core container, by construction) and
the headline ``part_txn_s`` on the simulated-cluster clock — committed /
(median wall time / N), i.e. each node's own share of the timesliced
execution, the time a real node with its own CPU would take.  The cluster
metric is NOT a tautology: if per-node efficiency degraded with scale
(contention, skew, coordination creep), per-node time would grow with N
and the curve would flatten or dip — which the monotonicity gate would
catch.

The second scenario kills one node mid-run: the coordinator detects the
missed fence, reverts the in-flight epoch (discarding the stream slabs
the replicas consumed — slab high-watermark), classifies the failure into
a §4.5 ``RecoveryCase``, restores the node's partition block from the
full replica (real donor copy — the block is scribbled first),
re-executes, and the run reports the measured recovery latency with
``replica_consistent()`` holding at the next fence.

``--mix full`` runs the five-transaction TPC-C mix (ordered indexes,
Delivery/OrderStatus/StockLevel scans) through the cluster runtime
instead of YCSB — the CI full-mix smoke drives a 4-node kill-one-node
pass this way with a regression floor on the overlapped-bytes fraction.

    PYTHONPATH=src python -m benchmarks.fig13_scalability [--smoke]
    PYTHONPATH=src python -m benchmarks.fig13_scalability --full-smoke
"""
import argparse
import json
import os
import subprocess
import sys

NODE_COUNTS = (1, 2, 4, 8)


# ---------------------------------------------------------------------------
# worker: one process == one cluster size (jax restarts with N devices)
# ---------------------------------------------------------------------------
def worker(args):
    import jax
    import numpy as np

    from repro.cluster import ClusterRuntime
    from repro.core.fault import FaultInjector
    from repro.obs import MetricsRegistry

    N = jax.device_count()
    P = N * args.ppn
    mesh = jax.make_mesh((N,), ("part",))
    inj = None
    if args.kill:
        node, ep = (int(x) for x in args.kill.split(":"))
        inj = FaultInjector()
        inj.schedule_kill(node, ep)

    def pad(a, axis, target):
        w = [(0, 0)] * a.ndim
        w[axis] = (0, target - a.shape[axis])
        return np.pad(a, w)

    txns = args.txns_per_node * N                 # weak scaling
    # fixed device shapes across epochs (the service batcher's invariant):
    # per-epoch draws vary T/B slightly, and letting the pow2 pad wobble
    # would recompile the mesh programs mid-measurement
    T_fix = 1 << (args.txns_per_node // args.ppn + 8).bit_length()
    B_fix = 1 << max(16, int(txns * 0.3)).bit_length()

    if args.mix == "full":
        from repro.db import tpcc
        cfg = tpcc.TPCCConfig(n_partitions=P, n_items=400,
                              cust_per_district=40, order_ring=64,
                              mix="full", delivery_gen_lag=256)
        state = tpcc.TPCCState(cfg)
        init = tpcc.init_values(cfg, np.random.default_rng(7), state=state)
        rt = ClusterRuntime(mesh, P, cfg.rows_per_partition, init_val=init,
                            indexes=tpcc.index_specs(cfg), injector=inj,
                            n_slabs=args.slabs)

        def make(seed):
            b = tpcc.make_batch(cfg, state, txns, seed=seed)
            T = b["ptxn"]["row"].shape[1]
            assert T <= T_fix, (T, T_fix, "raise T_fix for this scale")
            b["ptxn"] = {k: pad(v, 1, T_fix) for k, v in b["ptxn"].items()}
            b["p_row_bytes"] = pad(b["p_row_bytes"], 1, T_fix)
            b["p_op_bytes"] = pad(b["p_op_bytes"], 1, T_fix)
            b["cross"] = {k: pad(v, 0, B_fix) for k, v in b["cross"].items()}
            b["c_row_bytes"] = pad(b["c_row_bytes"], 0, B_fix)
            b["c_op_bytes"] = pad(b["c_op_bytes"], 0, B_fix)
            return b
    else:
        from repro.db import ycsb
        cfg = ycsb.YCSBConfig(n_partitions=P,
                              records_per_partition=args.rows)
        rt = ClusterRuntime(mesh, P, args.rows, injector=inj,
                            n_slabs=args.slabs)

        def make(seed):
            b = ycsb.make_batch(cfg, txns, seed=seed)
            b["ptxn"] = {k: pad(v, 1, T_fix) for k, v in b["ptxn"].items()}
            b["cross"] = {k: pad(v, 0, B_fix) for k, v in b["cross"].items()}
            return b

    rt.run_epoch(make(999))                       # jit warm
    recoveries = []
    # per-epoch registry snapshots ride the RESULT JSON back to the sweep
    # parent: engine stats + the per-node fence-wait/committed arrays +
    # the recovery ledger, under the same namespaces the service exports
    reg = MetricsRegistry()
    reg.register_object("engine", rt.stats)

    def _node_metrics():
        out = {}
        for k in range(N):
            out[f"node{k}.committed"] = int(rt.eng.node_committed[k])
            out[f"node{k}.fence_wait_s"] = float(rt.eng.node_fence_wait_s[k])
        out["recoveries"] = len(recoveries)
        out["recovery_latency_s"] = sum(r["t_recovery_ms"]
                                        for r in recoveries) / 1e3
        return out

    reg.register_provider("cluster", _node_metrics)
    consistent_after_recovery = True
    t_parts, commits = [], []
    for ep in range(args.epochs):
        c0, p0 = rt.stats.committed_single, rt.stats.part_time_s
        m = rt.run_epoch(make(ep))
        t_parts.append(rt.stats.part_time_s - p0)
        commits.append(rt.stats.committed_single - c0)
        if "recovery" in m:
            ev = m["recovery"]
            recoveries.append({"case": ev.case.name,
                               "run_mode": ev.run_mode,
                               "failed": list(ev.failed),
                               "lost_blocks": list(ev.lost_blocks),
                               "restored_from_secondary":
                                   list(ev.restored_from_secondary),
                               "slabs_discarded": ev.slabs_discarded,
                               "t_recovery_ms":
                                   round(ev.t_recovery_s * 1e3, 2)})
            consistent_after_recovery = rt.replica_consistent()
        reg.snapshot(ep)
    # median-of-epochs after dropping the settle epochs (thread pools and
    # caches are still warming in the first couple): the 2-core host's
    # scheduler adds heavy upper tails, the median is the robust estimate
    settle = min(2, len(t_parts) - 1)
    part_s = float(np.median(t_parts[settle:]))
    committed = float(np.median(commits[settle:]))
    node_c = rt.eng.node_committed.astype(int)
    s = rt.stats
    stream_total = int(s.op_bytes_overlapped + s.op_bytes_fence)
    print("RESULT " + json.dumps({
        "n_nodes": N,
        "committed_single": int(sum(commits)),
        "part_s": round(sum(t_parts), 4),
        "epoch_part_ms": [round(t * 1e3, 2) for t in t_parts],
        # simulated-cluster clock: each node's share of the timesliced
        # wall execution (see module docstring for the contract)
        "part_txn_s": round(committed / max(part_s / N, 1e-9)),
        "part_txn_s_wall": round(committed / max(part_s, 1e-9)),
        "node_committed": node_c.tolist(),
        "node_fence_wait_ms":
            [round(x * 1e3, 2) for x in rt.eng.node_fence_wait_s],
        "fence_wait_ema_ms": round(rt.controller.fence_wait_ms, 3),
        # §5 op-stream shipping: overlapped vs fence-exposed bytes
        "op_bytes_overlapped": int(s.op_bytes_overlapped),
        "op_bytes_fence": int(s.op_bytes_fence),
        "overlap_frac": round(s.op_bytes_overlapped / stream_total, 4)
        if stream_total else 0.0,
        "index_op_bytes": int(s.index_op_bytes),
        "slabs_shipped": int(s.slabs_shipped),
        "recoveries": recoveries,
        "consistent": bool(rt.replica_consistent()
                           and consistent_after_recovery),
        "metrics": reg.snapshots,
    }))


def _spawn(n_devices: int, extra: list[str]) -> dict:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_devices}")
    cmd = [sys.executable, "-m", "benchmarks.fig13_scalability", "--worker",
           *extra]
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=480)
    # a child that dies (OOM, assert, import error) must fail the sweep
    # LOUDLY — a silent hole in the curve reads as a missing data point
    if out.returncode != 0:
        sys.stderr.write(f"fig13 worker FAILED (N={n_devices}, "
                         f"exit {out.returncode}): {' '.join(cmd)}\n")
        sys.stderr.write("---- child stderr ----\n")
        sys.stderr.write(out.stderr[-8000:] + "\n")
        raise RuntimeError(
            f"fig13 worker exited {out.returncode} at N={n_devices}")
    lines = [ln for ln in out.stdout.splitlines()
             if ln.startswith("RESULT ")]
    if not lines:
        sys.stderr.write("---- child stdout ----\n" + out.stdout[-4000:]
                         + "\n---- child stderr ----\n"
                         + out.stderr[-4000:] + "\n")
        raise RuntimeError(
            f"fig13 worker (N={n_devices}) produced no RESULT line")
    return json.loads(lines[-1][len("RESULT "):])


# ---------------------------------------------------------------------------
def run():
    """benchmarks.run entry point: full-scale sweep, rows only."""
    return sweep(smoke=False)[0]


def sweep(smoke: bool = False, sweep_json: str | None = None):
    if smoke:
        scale = ["--rows", "64", "--txns-per-node", "48", "--epochs", "10"]
        repeats = 2
    else:
        scale = ["--rows", "256", "--txns-per-node", "64", "--epochs", "16"]
        repeats = 3
    rows, thr = [], {}
    results = {}
    for n in NODE_COUNTS:
        # best-of-k fresh processes: run-to-run variance on a small shared
        # host (scheduler state, pool warm-up) dwarfs in-run noise; the
        # best run is the least-interfered estimate of the machine
        best = None
        for _ in range(repeats):
            cand = _spawn(n, scale)
            assert cand["consistent"], f"replicas diverged at N={n}"
            if best is None or cand["part_txn_s"] > best["part_txn_s"]:
                best = cand
        r = best
        results[n] = r
        thr[n] = r["part_txn_s"]
        rows.append((f"fig13/scal_n{n}_part_txn_s",
                     1e6 * r["part_s"] / max(r["committed_single"], 1),
                     r["part_txn_s"]))
        rows.append((f"fig13/scal_n{n}_part_txn_s_wall", 0.0,
                     r["part_txn_s_wall"]))
        skew = (max(r["node_committed"]) / max(min(r["node_committed"]), 1)
                if r["node_committed"] else 1.0)
        rows.append((f"fig13/scal_n{n}_node_skew", 0.0, round(skew, 2)))
        rows.append((f"fig13/scal_n{n}_overlap_frac", 0.0,
                     r["overlap_frac"]))
    mono = all(thr[a] < thr[b]
               for a, b in zip(NODE_COUNTS, NODE_COUNTS[1:]))
    rows.append(("fig13/scal_monotonic_1_to_8", 0.0, int(mono)))
    rows.append(("fig13/scal_speedup_8_over_1", 0.0,
                 round(thr[8] / max(thr[1], 1), 2)))

    # ---- N=4: in-phase streaming vs the fence-time-replay baseline -----
    # --slabs 1 ships the whole epoch stream at the fence (the PR-4
    # behavior); streamed fence-exposed bytes must be strictly lower
    base = _spawn(4, scale + ["--slabs", "1"])
    streamed = results[4]
    assert base["consistent"], "baseline replicas diverged"
    assert base["op_bytes_fence"] > 0, base
    assert streamed["op_bytes_fence"] < base["op_bytes_fence"], \
        (streamed["op_bytes_fence"], base["op_bytes_fence"])
    rows.append(("fig13/stream_n4_fence_bytes", 0.0,
                 streamed["op_bytes_fence"]))
    rows.append(("fig13/stream_n4_fence_bytes_baseline", 0.0,
                 base["op_bytes_fence"]))
    rows.append(("fig13/stream_n4_overlapped_bytes", 0.0,
                 streamed["op_bytes_overlapped"]))

    # ---- kill one node mid-run at N=8: classified recovery, consistent --
    r = _spawn(8, scale + ["--kill", "3:3"])
    assert r["consistent"], "replicas diverged after recovery"
    assert len(r["recoveries"]) == 1, r["recoveries"]
    ev = r["recoveries"][0]
    rows.append(("fig13/recovery_case_phase_switching", 0.0,
                 int(ev["case"] == "PHASE_SWITCHING")))
    rows.append(("fig13/recovery_latency_ms", 1e3 * ev["t_recovery_ms"],
                 ev["t_recovery_ms"]))
    rows.append(("fig13/recovery_consistent_next_fence", 0.0,
                 int(r["consistent"])))
    rows.append(("fig13/recovery_run_throughput_txn_s", 0.0,
                 r["part_txn_s"]))
    if sweep_json:
        # persist every child's full telemetry — per-epoch registry
        # snapshots (engine.* + cluster.node*.* + recovery ledger)
        # included — so perf trajectories survive the sweep
        with open(sweep_json, "w") as f:
            json.dump({"schema": 1,
                       "nodes": {str(n): results[n] for n in NODE_COUNTS},
                       "baseline_n4_slabs1": base,
                       "kill_n8": r}, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {sweep_json}")
    return rows, thr, ev


def full_mix_smoke(sweep_json: str | None = None):
    """CI regression gate: the five-transaction TPC-C mix on a 4-node
    cluster with a mid-run node kill — recovery classified, replicas
    (records + index segments) consistent, and a floor on the
    overlapped-bytes fraction (> 0: the op stream really ships in-phase)."""
    scale = ["--mix", "full", "--txns-per-node", "40", "--epochs", "8",
             "--ppn", "1", "--kill", "1:3"]
    r = _spawn(4, scale)
    assert r["consistent"], "full-mix replicas diverged"
    assert len(r["recoveries"]) == 1, r["recoveries"]
    ev = r["recoveries"][0]
    assert ev["case"] == "PHASE_SWITCHING", ev
    assert r["overlap_frac"] > 0, r["overlap_frac"]
    assert r["index_op_bytes"] > 0, "index ops must hit the byte model"
    rows = [
        ("fig13/fullmix_committed", 0.0, r["committed_single"]),
        ("fig13/fullmix_overlap_frac", 0.0, r["overlap_frac"]),
        ("fig13/fullmix_index_op_bytes", 0.0, r["index_op_bytes"]),
        ("fig13/fullmix_recovery_classified", 0.0, 1),
        ("fig13/fullmix_consistent", 0.0, int(r["consistent"])),
    ]
    if sweep_json:
        with open(sweep_json, "w") as f:
            json.dump({"schema": 1, "fullmix_n4_kill": r},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {sweep_json}")
    return rows, r, ev


def main():
    from benchmarks.common import emit
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale; asserts the monotonic-scaling and "
                    "recovery floors (CI regression gate)")
    ap.add_argument("--full-smoke", action="store_true", dest="full_smoke",
                    help="4-node full-TPC-C-mix smoke: kill-one-node "
                    "recovery + overlapped-bytes floor (CI gate)")
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--ppn", type=int, default=2, help=argparse.SUPPRESS)
    ap.add_argument("--rows", type=int, default=256, help=argparse.SUPPRESS)
    ap.add_argument("--txns-per-node", type=int, default=96,
                    dest="txns_per_node", help=argparse.SUPPRESS)
    ap.add_argument("--epochs", type=int, default=6, help=argparse.SUPPRESS)
    ap.add_argument("--kill", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--slabs", type=int, default=4, help=argparse.SUPPRESS)
    ap.add_argument("--mix", default="ycsb", choices=("ycsb", "full"),
                    help=argparse.SUPPRESS)
    ap.add_argument("--sweep-json", metavar="PATH", default=None,
                    dest="sweep_json",
                    help="persist every child's RESULT JSON — per-epoch "
                    "registry snapshots (engine.* / cluster.node*.*) and "
                    "recovery stats included — to this file")
    args = ap.parse_args()
    if args.worker:
        worker(args)
        return
    if args.full_smoke:
        rows, r, ev = full_mix_smoke(sweep_json=args.sweep_json)
        print("name,us_per_call,derived")
        emit(rows)
        print(f"FULL-MIX SMOKE OK committed={r['committed_single']} "
              f"overlap_frac={r['overlap_frac']} "
              f"recovery={ev['t_recovery_ms']}ms")
        return
    rows, thr, ev = sweep(smoke=args.smoke, sweep_json=args.sweep_json)
    print("name,us_per_call,derived")
    emit(rows)
    if args.smoke:
        assert all(t > 5 for t in thr.values()), f"throughput collapsed: {thr}"
        mono = [thr[a] < thr[b]
                for a, b in zip(NODE_COUNTS, NODE_COUNTS[1:])]
        assert all(mono), f"partitioned-phase scaling not monotonic: {thr}"
        assert ev["case"] == "PHASE_SWITCHING", ev
        assert ev["t_recovery_ms"] > 0, ev
        print(f"SMOKE OK thr={thr} recovery={ev['t_recovery_ms']}ms")


if __name__ == "__main__":
    main()
