"""Model-level semantic tests: decode==prefill consistency, windows, MLA
absorption, mamba2 chunked==sequential, MoE dispatch oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data import make_batch
from repro.models import transformer as tf


def _next_token_logits_full(cfg, params, tokens):
    """Teacher-forced forward: logits at the last position."""
    logits, _, _, _ = tf.forward(params, {"tokens": tokens}, cfg)
    return logits[:, -1]


@pytest.mark.parametrize("arch", ["glm4-9b", "minicpm3-4b", "starcoder2-7b",
                                  "mamba2-130m", "hymba-1.5b"])
def test_decode_matches_teacher_forcing(arch):
    """prefill(S) then decode(1 token) == forward(S+1)[-1] — exercises slot
    caches, rings, MLA absorption and SSM state carry in one property."""
    cfg = get_arch(arch, smoke=True)
    if cfg.ssm_state:
        cfg = type(cfg)(**{**cfg.__dict__, "ssm_chunk": 8})
    params = tf.init_params(cfg, jax.random.key(0))
    S = 32
    tokens = jax.random.randint(jax.random.key(1), (2, S + 1), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    _, cache = tf.prefill(params, {"tokens": tokens[:, :S]}, cfg,
                          alloc_len=S + 4)
    logits_dec, _ = tf.decode_step(params, cache, tokens[:, S:S + 1], cfg)
    logits_full = _next_token_logits_full(cfg, params, tokens)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full, np.float32), atol=0.15, rtol=0.05)


def test_sliding_window_matches_truncated_context():
    """With window w, logits at position t depend only on the last w tokens."""
    cfg = get_arch("starcoder2-7b", smoke=True)        # window 32
    params = tf.init_params(cfg, jax.random.key(0))
    w = cfg.sliding_window
    S = 3 * w
    tokens = jax.random.randint(jax.random.key(2), (1, S), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    logits_full, _, _, _ = tf.forward(params, {"tokens": tokens}, cfg)
    # NOTE: depth stacks windows (receptive field grows per layer), so use a
    # 1-layer view for the strict property
    import dataclasses
    cfg1 = dataclasses.replace(cfg, n_layers=1)
    params1 = jax.tree.map(lambda a: a[:1] if a.ndim > 1 or a.shape[0] == cfg.n_layers
                           else a, params, is_leaf=None)
    params1 = {**params, "layers": jax.tree.map(lambda a: a[:1], params["layers"])}
    lf, _, _, _ = tf.forward(params1, {"tokens": tokens}, cfg1)
    lt, _, _, _ = tf.forward(params1, {"tokens": tokens[:, -w:]}, cfg1)
    np.testing.assert_allclose(np.asarray(lf[0, -1], np.float32),
                               np.asarray(lt[0, -1], np.float32),
                               atol=2e-2, rtol=2e-2)


def test_mamba2_chunk_invariance():
    """SSD output independent of chunk size (8 vs full-sequence 64)."""
    import dataclasses
    from repro.models.mamba2 import init_mamba2, mamba2_forward
    cfg8 = dataclasses.replace(get_arch("mamba2-130m", smoke=True), ssm_chunk=8)
    cfg64 = dataclasses.replace(cfg8, ssm_chunk=64)
    p = init_mamba2(jax.random.key(0), cfg8, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg8.d_model))
    y8, _ = mamba2_forward(p, x, cfg8)
    y64, _ = mamba2_forward(p, x, cfg64)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), atol=1e-3,
                               rtol=1e-3)


def test_moe_matches_dense_oracle():
    """Sort-based dispatch == exact per-token expert mixture (big capacity)."""
    import dataclasses
    from repro.models.moe import init_moe, moe_apply, route
    cfg = dataclasses.replace(get_arch("granite-moe-1b-a400m", smoke=True),
                              capacity_factor=8.0)    # no drops
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    T = 40
    x = jax.random.normal(jax.random.key(1), (T, cfg.d_model))
    y, aux = moe_apply(p, x, cfg, 0, cfg.n_experts)
    w, ids, _ = route(p["router"], x, cfg)
    up_all = jnp.einsum("td,edf->tef", x, p["w_up"])
    gate_all = jax.nn.silu(jnp.einsum("td,edf->tef", x, p["w_gate"]))
    out_all = jnp.einsum("tef,efd->ted", gate_all * up_all, p["w_down"])
    expect = jnp.zeros_like(x)
    for j in range(cfg.top_k):
        expect = expect + w[:, j, None] * jnp.take_along_axis(
            out_all, ids[:, j, None, None], axis=1)[:, 0]
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect), atol=2e-4,
                               rtol=2e-3)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 some tokens drop but output stays finite and
    aux loss reflects imbalance."""
    import dataclasses
    from repro.models.moe import init_moe, moe_apply
    cfg = dataclasses.replace(get_arch("dbrx-132b", smoke=True),
                              capacity_factor=1.0)
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (64, cfg.d_model))
    y, aux = moe_apply(p, x, cfg, 0, cfg.n_experts)
    assert bool(jnp.all(jnp.isfinite(y))) and float(aux) > 0


def test_vlm_patches_prepended():
    cfg = get_arch("internvl2-26b", smoke=True)
    params = tf.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, "train", 32, 2)
    x, pos, mask = tf.embed_inputs(params, batch, cfg)
    assert x.shape[1] == 32                        # patches + text
    assert float(mask[0, 0]) == 0.0 and float(mask[0, -1]) == 1.0


def test_encoder_bidirectional():
    """HuBERT attends to future frames: flipping late input changes early
    outputs."""
    cfg = get_arch("hubert-xlarge", smoke=True)
    params = tf.init_params(cfg, jax.random.key(0))
    b1 = make_batch(cfg, "train", 32, 1, seed=0)
    frames2 = b1["frames"].at[:, -1].add(10.0)
    l1, _, _, _ = tf.forward(params, b1, cfg)
    l2, _, _, _ = tf.forward(params, {**b1, "frames": frames2}, cfg)
    assert not bool(jnp.allclose(l1[:, 0], l2[:, 0]))
