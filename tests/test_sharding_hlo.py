"""Sharding-spec well-formedness for every arch + HLO analyzer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, SHAPES, cell_applicable, get_arch
from repro.data.pipeline import input_specs
from repro.launch import hlo_analysis as ha


class FakeMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


def _axes_of(spec):
    out = []
    for e in spec:
        if e is None:
            continue
        out.extend(e if isinstance(e, tuple) else (e,))
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_specs_wellformed(arch):
    from repro.launch.sharding import param_specs
    from repro.models.transformer import params_shape
    cfg = get_arch(arch)
    shapes = params_shape(cfg)
    specs = param_specs(cfg, shapes, FakeMesh())
    for (path, spec), (_, shape) in zip(
            jax.tree_util.tree_leaves_with_path(specs, is_leaf=lambda x: hasattr(x, "index")),
            jax.tree_util.tree_leaves_with_path(shapes)):
        axes = _axes_of(spec)
        assert len(axes) == len(set(axes)), f"{path}: axis reused in {spec}"
        assert len(tuple(spec)) <= len(shape.shape)
        for dim, entry in zip(shape.shape, tuple(spec)):
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                if ax is not None:
                    assert dim % FakeMesh.shape[ax] == 0, \
                        f"{path}: dim {dim} not divisible by {ax}"


def test_cell_applicability_table():
    rows = {(a, s): cell_applicable(get_arch(a), SHAPES[s])[0]
            for a in ALL_ARCHS for s in SHAPES}
    assert sum(rows.values()) == 32          # 40 cells, 8 documented skips
    assert not rows[("hubert-xlarge", "decode_32k")]
    assert not rows[("glm4-9b", "long_500k")]
    assert rows[("starcoder2-7b", "long_500k")]      # sliding window
    assert rows[("mamba2-130m", "long_500k")]
    assert rows[("hymba-1.5b", "long_500k")]


def test_input_specs_decode_shape():
    cfg = get_arch("glm4-9b")
    spec = input_specs(cfg, SHAPES["decode_32k"])
    assert spec["tokens"].shape == (128, 1)
    spec = input_specs(cfg, SHAPES["train_4k"])
    assert spec["tokens"].shape == (256, 4096)


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------
def test_scan_trip_count_flops_exact():
    def f(a, b):
        def body(c, _):
            return jnp.tanh(c @ b), None
        c, _ = jax.lax.scan(body, a, None, length=7)
        return c
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 256), jnp.float32)
    hlo = jax.jit(f).lower(a, b).compile().as_text()
    tot = ha.analyze(hlo)
    assert tot.flops == 2 * 128 * 256 * 256 * 7
    assert tot.max_trip == 7


def test_nested_scan_multiplies():
    def f(a, b):
        def outer(c, _):
            def inner(d, _):
                return d @ b, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        c, _ = jax.lax.scan(outer, a, None, length=5)
        return c
    a = jnp.zeros((64, 64), jnp.float32)
    b = jnp.zeros((64, 64), jnp.float32)
    hlo = jax.jit(f).lower(a, b).compile().as_text()
    tot = ha.analyze(hlo)
    assert tot.flops == 2 * 64 * 64 * 64 * 15            # 5 * 3


def test_collective_bytes_parsed():
    import os
    # uses however many devices exist (1 is fine: psum still lowers)
    mesh = jax.make_mesh((jax.device_count(),), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return jax.lax.with_sharding_constraint(
            x * 2, NamedSharding(mesh, P()))
    x = jnp.zeros((1024,), jnp.float32)
    hlo = (jax.jit(f, in_shardings=NamedSharding(mesh, P("x")))
           .lower(x).compile().as_text())
    tot = ha.analyze(hlo)
    if jax.device_count() > 1:
        assert tot.collective_bytes > 0
