"""Trainer: loss goes down, epoch revert, disk resume, elastic reshard."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.mesh import make_host_mesh
from repro.train.star_dp import (ReplicationStats, merge_replicas,
                                 merge_tensor_groups)
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def trainer(tmp_path_factory):
    from repro.train.optimizer import AdamWConfig
    cfg = get_arch("glm4-9b", smoke=True)
    tcfg = TrainerConfig(seq_len=64, batch=4, steps_per_epoch=4,
                         checkpoint_dir=str(tmp_path_factory.mktemp("ckpt")),
                         hp=AdamWConfig(lr=1e-3, warmup_steps=5))
    return Trainer(cfg, make_host_mesh(), tcfg)


def test_loss_decreases(trainer):
    first = trainer.run(2)
    last = trainer.run(14)
    hist = trainer.metrics_history
    early = np.mean([m["loss"] for m in hist[:4]])
    late = np.mean([m["loss"] for m in hist[-4:]])
    assert np.isfinite(late) and late < early


def test_epoch_revert_resumes_identically(trainer):
    committed_step = trainer.commit_log.committed.step
    committed_params = jax.tree.map(np.asarray, trainer.commit_log.committed.params)
    trainer.run(2)                       # uncommitted progress
    back = trainer.inject_failure()
    assert back == committed_step
    now = jax.tree.map(np.asarray, trainer.params)
    for a, b in zip(jax.tree.leaves(committed_params), jax.tree.leaves(now)):
        assert np.array_equal(a, b)
    # replay the lost steps: training continues from the commit point
    trainer.run(2)
    assert trainer.step == committed_step + 2


def test_disk_resume(trainer):
    # run to a fence so a checkpoint exists, then restore
    while trainer.step % trainer.tcfg.steps_per_epoch != 0:
        trainer.run(1)
    step = trainer.step
    params_at_ckpt = jax.tree.map(np.asarray, trainer.params)
    trainer.run(3)
    meta = trainer.restore_from_disk()
    assert meta["step"] == step
    now = jax.tree.map(np.asarray, trainer.params)
    for a, b in zip(jax.tree.leaves(params_at_ckpt), jax.tree.leaves(now)):
        assert np.array_equal(a, b)


def test_elastic_reshard(trainer):
    before = jax.tree.map(np.asarray, trainer.params)
    trainer.reshard(make_host_mesh())            # new mesh (same host size)
    after = jax.tree.map(np.asarray, trainer.params)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        assert np.array_equal(a, b)
    trainer.run(1)                               # still trains


def test_merge_replicas_thomas_rule():
    p_old, p_new = {"w": np.zeros(2)}, {"w": np.ones(2)}
    merged, tid = merge_replicas(p_old, 5, p_new, 7)
    assert tid == 7 and merged is p_new
    merged, tid = merge_replicas(p_new, 7, p_old, 5)   # stale ignored
    assert tid == 7 and merged is p_new


def test_merge_tensor_groups_out_of_order():
    a = {"embed": ("v1", 3)}
    b = {"embed": ("v2", 5), "mlp": ("m1", 2)}
    m1 = merge_tensor_groups(a, b)
    m2 = merge_tensor_groups(b, a)                     # reversed arrival
    assert m1 == m2 == {"embed": ("v2", 5), "mlp": ("m1", 2)}


def test_hybrid_replication_report_moe():
    cfg = get_arch("granite-moe-1b-a400m", smoke=True)
    tr = Trainer(cfg, make_host_mesh(),
                 TrainerConfig(seq_len=32, batch=2, steps_per_epoch=4))
    stats = tr.replication_report()
    assert isinstance(stats, ReplicationStats)
    assert stats.value_bytes >= stats.op_bytes > 0
