"""Thomas write rule properties (§3, §5) — the core replication invariant."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import replication as repl

C = 4


def _mk_writes(rng, n_rows, n_writes):
    rows = rng.integers(0, n_rows, n_writes).astype(np.int32)
    tids = rng.integers(1, 1000, n_writes).astype(np.uint32) * 2  # unlocked
    vals = rng.integers(0, 100, (n_writes, C)).astype(np.int32)
    # same (row, tid) must imply same value (true in the system)
    uniq = {}
    for i in range(n_writes):
        key = (int(rows[i]), int(tids[i]))
        if key in uniq:
            vals[i] = vals[uniq[key]]
        else:
            uniq[key] = i
    return rows, vals, tids


@given(st.integers(0, 10_000), st.integers(1, 60))
@settings(max_examples=40, deadline=None)
def test_order_independence(seed, n_writes):
    """Applying any permutation of the write stream converges identically."""
    rng = np.random.default_rng(seed)
    n_rows = 16
    rows, vals, tids = _mk_writes(rng, n_rows, n_writes)
    val0 = jnp.zeros((n_rows, C), jnp.int32)
    tid0 = jnp.zeros((n_rows,), jnp.uint32)

    v_a, t_a, _ = repl.thomas_apply(val0, tid0, jnp.asarray(rows),
                                    jnp.asarray(vals), jnp.asarray(tids))
    perm = rng.permutation(n_writes)
    v_b, t_b, _ = repl.thomas_apply(val0, tid0, jnp.asarray(rows[perm]),
                                    jnp.asarray(vals[perm]),
                                    jnp.asarray(tids[perm]))
    assert jnp.array_equal(v_a, v_b)
    assert jnp.array_equal(t_a, t_b)


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_incremental_equals_batch(seed):
    """Applying the stream in two chunks == one batch (async replication)."""
    rng = np.random.default_rng(seed)
    rows, vals, tids = _mk_writes(rng, 8, 40)
    val0 = jnp.zeros((8, C), jnp.int32)
    tid0 = jnp.zeros((8,), jnp.uint32)
    v1, t1, _ = repl.thomas_apply(val0, tid0, jnp.asarray(rows),
                                  jnp.asarray(vals), jnp.asarray(tids))
    va, ta, _ = repl.thomas_apply(val0, tid0, jnp.asarray(rows[:20]),
                                  jnp.asarray(vals[:20]), jnp.asarray(tids[:20]))
    vb, tb, _ = repl.thomas_apply(va, ta, jnp.asarray(rows[20:]),
                                  jnp.asarray(vals[20:]), jnp.asarray(tids[20:]))
    assert jnp.array_equal(v1, vb)
    assert jnp.array_equal(t1, tb)


def test_stale_write_dropped():
    val = jnp.zeros((4, C), jnp.int32)
    tid = jnp.asarray([10, 10, 10, 10], jnp.uint32)
    v, t, applied = repl.thomas_apply(
        val, tid, jnp.asarray([0, 1], jnp.int32),
        jnp.ones((2, C), jnp.int32), jnp.asarray([8, 12], jnp.uint32))
    assert not bool(applied[0]) and bool(applied[1])
    assert int(v[0, 0]) == 0 and int(v[1, 0]) == 1
    assert int(t[0]) == 10 and int(t[1]) == 12
