"""Durability (WAL + fuzzy checkpoint + recovery) and the hash index."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.db import hashtable as ht
from repro.db.wal import WriteAheadLog, recover, write_checkpoint


def test_wal_checkpoint_recover_bit_identical(tmp_path):
    rng = np.random.default_rng(0)
    N, C = 64, 4
    val = rng.integers(0, 100, (N, C)).astype(np.int32)
    tid = (rng.integers(1, 50, N).astype(np.uint32)) * 2
    write_checkpoint(tmp_path, val, tid, epoch=3)

    # post-checkpoint writes land in the WAL (epochs 3..5)
    wal = WriteAheadLog(tmp_path, worker_id=0)
    cur_val, cur_tid = val.copy(), tid.copy()
    for epoch in (3, 4, 5):
        rows = rng.choice(N, 10, replace=False)
        vals = rng.integers(0, 100, (10, C)).astype(np.int32)
        tids = (np.full(10, 1000 * epoch, np.uint32)
                + np.arange(10).astype(np.uint32)) * 2
        cur_val[rows] = vals
        cur_tid[rows] = tids
        wal.append(rows, vals, tids, np.ones(10, bool))
        wal.flush(epoch)
    wal.close()

    rec_val, rec_tid, e_c = recover(tmp_path)
    assert e_c == 3
    assert np.array_equal(np.array(rec_val), cur_val)
    assert np.array_equal(np.array(rec_tid), cur_tid)


def test_recovery_replay_any_order(tmp_path):
    """Two WALs with interleaved epochs: Thomas rule makes replay order-free."""
    N, C = 16, 3
    val = np.zeros((N, C), np.int32)
    tid = np.zeros(N, np.uint32)
    write_checkpoint(tmp_path, val, tid, epoch=1)
    w0 = WriteAheadLog(tmp_path, worker_id=0)
    w1 = WriteAheadLog(tmp_path, worker_id=1)
    # worker 1 writes the NEWER tid for row 0, worker 0 the older
    w0.append([0], np.full((1, C), 7, np.int32), np.asarray([4], np.uint32),
              [True])
    w1.append([0], np.full((1, C), 9, np.int32), np.asarray([8], np.uint32),
              [True])
    w0.flush(1); w1.flush(1); w0.close(); w1.close()
    rec_val, rec_tid, _ = recover(tmp_path)
    assert int(rec_val[0, 0]) == 9 and int(rec_tid[0]) == 8


@given(st.integers(0, 1000), st.integers(1, 200))
@settings(max_examples=25, deadline=None)
def test_hash_index_roundtrip(seed, n_keys):
    rng = np.random.default_rng(seed)
    keys = rng.choice(100_000, n_keys, replace=False).astype(np.int32)
    rows = np.arange(n_keys, dtype=np.int32)
    idx = ht.make_index(1024)
    idx = ht.insert(idx, jnp.asarray(keys), jnp.asarray(rows))
    got = ht.lookup(idx, jnp.asarray(keys))
    assert np.array_equal(np.array(got), rows)
    # absent keys miss
    absent = keys + 100_000
    miss = ht.lookup(idx, jnp.asarray(absent.astype(np.int32)))
    assert np.all(np.array(miss) == -1)


# ---------------------------------------------------------------------------
# live-execution durability: engine → WAL → recover, end to end
# ---------------------------------------------------------------------------
def test_engine_durability_recover_bit_identical_every_fence(tmp_path):
    """StarEngine with durability attached: committed epochs stream to
    per-worker WALs (flushed inside the fence) with cadence checkpoints.
    After EVERY fence, recovering from disk — with the (file, chunk) replay
    order shuffled differently each time — must be bit-identical to the
    surviving replica."""
    from repro.core.engine import StarEngine
    from repro.db import ycsb
    from repro.db.wal import Durability

    cfg = ycsb.YCSBConfig(n_partitions=4, records_per_partition=64)
    dur = Durability(tmp_path, n_workers=4, checkpoint_every=3)
    eng = StarEngine(4, 64, durability=dur)
    for ep in range(7):
        eng.run_epoch(ycsb.make_batch(cfg, 96, seed=ep))
        assert eng.replica_consistent()
        rv, rt, e_c = recover(tmp_path, shuffle_seed=1000 + ep)
        assert np.array_equal(np.asarray(rv),
                              np.asarray(eng.replica_store.val)), ep
        assert np.array_equal(np.asarray(rt),
                              np.asarray(eng.replica_store.tid)), ep
    assert dur.checkpoints >= 1, "cadence checkpoint never fired"
    assert dur.entries_logged > 0
    dur.close()


def test_engine_durability_crash_recover_resume(tmp_path):
    """Crash after epoch e: a fresh engine reloads checkpoint+logs (out of
    order), resumes at e+1, and stays recoverable at every later fence —
    the §4.5.1 UNAVAILABLE path end to end."""
    from repro.core.engine import StarEngine
    from repro.db import ycsb
    from repro.db.wal import Durability

    cfg = ycsb.YCSBConfig(n_partitions=2, records_per_partition=48)
    dur = Durability(tmp_path, n_workers=2, checkpoint_every=2)
    eng = StarEngine(2, 48, durability=dur)
    for ep in range(4):
        eng.run_epoch(ycsb.make_batch(cfg, 64, seed=ep))
    committed_val = np.asarray(eng.store.snapshot["val"]).copy()
    committed_tid = np.asarray(eng.store.snapshot["tid"]).copy()
    dur.close()                                     # crash: process gone

    rv, rt, e_c = recover(tmp_path, shuffle_seed=7)
    assert np.array_equal(np.asarray(rv), committed_val)
    assert np.array_equal(np.asarray(rt), committed_tid)

    # resume: reload the recovered state into a fresh engine (same log
    # directory — the reopened WALs append) and keep serving
    dur2 = Durability(tmp_path, n_workers=2, checkpoint_every=2)
    eng2 = StarEngine(2, 48, durability=dur2)
    eng2.store.val = jnp.asarray(rv)
    eng2.store.tid = jnp.asarray(rt)
    eng2.store.snapshot_commit()
    eng2.replica_store.load_state(eng2.store.snapshot)
    eng2.epoch = 5                                  # past the crash epoch
    for ep in range(4, 7):
        eng2.run_epoch(ycsb.make_batch(cfg, 64, seed=ep))
        assert eng2.replica_consistent()
        rv2, rt2, _ = recover(tmp_path, shuffle_seed=ep)
        assert np.array_equal(np.asarray(rv2),
                              np.asarray(eng2.replica_store.val)), ep
        assert np.array_equal(np.asarray(rt2),
                              np.asarray(eng2.replica_store.tid)), ep
    dur2.close()


def test_engine_index_durability_recover_full_every_fence(tmp_path):
    """StarEngine with ordered indexes AND durability (previously mutually
    exclusive): the ordered index-op stream WALs per worker alongside the
    record post-images, and ``recover_full`` rebuilds records + every
    index segment bit-identical to the surviving replica at every fence
    under the full five-transaction TPC-C mix."""
    from repro.core.engine import StarEngine
    from repro.db import tpcc
    from repro.db.wal import Durability, recover_full

    cfg = tpcc.TPCCConfig(n_partitions=2, n_items=400, cust_per_district=40,
                          order_ring=64, mix="full", delivery_gen_lag=256)
    state = tpcc.TPCCState(cfg)
    init = tpcc.init_values(cfg, np.random.default_rng(11), state=state)
    dur = Durability(tmp_path, n_workers=2, checkpoint_every=3)
    eng = StarEngine(cfg.n_partitions, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg), durability=dur)
    for ep in range(5):
        eng.run_epoch(tpcc.make_batch(cfg, state, 128, seed=ep))
        assert eng.replica_consistent()
        rv, rt, ridx, e_c = recover_full(tmp_path, shuffle_seed=50 + ep)
        assert np.array_equal(np.asarray(rv),
                              np.asarray(eng.replica_store.val)), ep
        assert np.array_equal(np.asarray(rt),
                              np.asarray(eng.replica_store.tid)), ep
        assert ridx is not None and len(ridx) == 3
        for i in range(3):
            for k in ("key", "prow", "tid"):
                assert np.array_equal(
                    np.asarray(ridx[i][k]),
                    np.asarray(eng.replica_store.indexes[i][k])), (ep, i, k)
    assert dur.checkpoints >= 1, "cadence checkpoint never fired"
    dur.close()
