"""Multi-device distribution tests (8 forced host devices, subprocess).

The dry-run proper runs at 512 devices; here an 8-device (2, 4) mesh runs
REAL computation end-to-end: a sharded train step on a reduced arch, and the
STAR partitioned phase under shard_map — proving the distribution logic, not
just its lowering.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_8dev():
    out = _run("""
        import jax, numpy as np
        assert jax.device_count() == 8
        from repro.configs import get_arch
        from repro.launch.mesh import make_host_mesh
        from repro.train.trainer import Trainer, TrainerConfig
        cfg = get_arch("glm4-9b", smoke=True)
        mesh = make_host_mesh(data=2, model=4)
        tr = Trainer(cfg, mesh, TrainerConfig(seq_len=64, batch=4,
                                              steps_per_epoch=2))
        m = tr.run(4)
        assert np.isfinite(m["loss"]), m
        print("OK", m["loss"])
    """)
    assert "OK" in out


def test_moe_expert_parallel_8dev_matches_single():
    """Expert-parallel shard_map result == single-device result."""
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_arch
        from repro.launch.mesh import make_host_mesh
        from repro.models.moe import init_moe, moe_forward
        import dataclasses
        cfg = dataclasses.replace(get_arch("granite-moe-1b-a400m", smoke=True),
                                  capacity_factor=8.0)
        mesh = make_host_mesh(data=2, model=4)
        p = init_moe(jax.random.key(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
        y1, _ = moe_forward(p, x, cfg, mesh=None)
        # mesh is passed explicitly; jax.set_mesh only exists on newer jax
        import contextlib
        ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") \
            else contextlib.nullcontext()
        with ctx:
            y2, _ = jax.jit(lambda p, x: moe_forward(p, x, cfg, mesh=mesh))(p, x)
        err = float(jnp.max(jnp.abs(y1 - y2)))
        assert err < 2e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_star_partitioned_phase_shard_map_8dev():
    """Partitioned phase via shard_map over 8 device-partitions == vmap."""
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.core.partitioned import run_partitioned
        from repro.db import ycsb
        cfg = ycsb.YCSBConfig(n_partitions=8, records_per_partition=200)
        batch = ycsb.make_batch(cfg, 256, seed=0)
        ptxn = jax.tree.map(jnp.asarray, batch["ptxn"])
        P_, R = 8, cfg.records_per_partition
        val = jnp.zeros((P_, R, 10), jnp.int32)
        tid = jnp.zeros((P_, R), jnp.uint32)
        epoch = jnp.uint32(1)
        v1, t1, out1, _ = run_partitioned(val, tid, ptxn, epoch)

        mesh = jax.make_mesh((8,), ("part",))
        def body(val, tid, ptxn):
            v, t, o, s = run_partitioned(val, tid, ptxn, epoch)
            return v, t
        shmap = shard_map(body, mesh,
            in_specs=(P("part"), P("part"),
                      jax.tree.map(lambda _: P("part"), ptxn)),
            out_specs=(P("part"), P("part")))
        v2, t2 = jax.jit(shmap)(val, tid, ptxn)
        assert jnp.array_equal(v1, v2) and jnp.array_equal(t1, t2)
        print("OK shard_map partitioned phase matches")
    """)
    assert "OK" in out
