"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property suite needs hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention import ops as fa
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mamba2_ssd.ops import ssd
from repro.kernels.mamba2_ssd.ref import ssd_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.thomas_merge.ops import thomas_merge
from repro.kernels.thomas_merge.ref import thomas_merge_ref


# ---------------------------------------------------------------------------
# thomas_merge
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000), st.integers(1, 300), st.integers(8, 130))
@settings(max_examples=15, deadline=None)
def test_thomas_merge_sweep(seed, K, N):
    rng = np.random.default_rng(seed)
    C = int(rng.integers(1, 8))
    val = jnp.asarray(rng.integers(0, 50, (N, C)), jnp.int32)
    tid = jnp.asarray(rng.integers(0, 30, N).astype(np.uint32) * 2)
    rows = rng.integers(-1, N, K).astype(np.int32)
    tids = (rng.integers(1, 60, K).astype(np.uint32)) * 2
    vals = rng.integers(0, 99, (K, C)).astype(np.int32)
    seen = {}
    for i in range(K):  # same (row, tid) -> same value (system invariant)
        key = (int(rows[i]), int(tids[i]))
        if key in seen:
            vals[i] = vals[seen[key]]
        else:
            seen[key] = i
    v1, t1 = thomas_merge_ref(val, tid, jnp.asarray(rows), jnp.asarray(vals),
                              jnp.asarray(tids))
    v2, t2 = thomas_merge(val, tid, jnp.asarray(rows), jnp.asarray(vals),
                          jnp.asarray(tids), block_rows=64, block_k=64)
    assert jnp.array_equal(v1, v2) and jnp.array_equal(t1, t2)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
@pytest.mark.parametrize("S,H,Hkv,D", [(256, 4, 2, 64), (128, 2, 2, 32),
                                       (512, 4, 1, 16)])
def test_flash_attention_sweep(dtype, causal, window, S, H, Hkv, D):
    rng = np.random.default_rng(0)
    B = 2
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), dtype)
    out = fa.mha(q, k, v, causal=causal, window=window, block_q=64, block_k=64)
    ref = fa.mha_ref(q, k, v, causal=causal, window=window)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_decode_ring_cache():
    """Slot-cache decode with a wrapped ring buffer matches the oracle."""
    rng = np.random.default_rng(1)
    B, S_alloc, H, Hkv, D = 2, 128, 4, 2, 32
    pos = 200                                   # cache wrapped (200 > 128)
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S_alloc, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S_alloc, Hkv, D)), jnp.float32)
    slot_pos = np.full(S_alloc, -1, np.int32)
    for p in range(pos - S_alloc, pos):
        slot_pos[p % S_alloc] = p
    slot_pos = jnp.asarray(slot_pos)
    out = fa.decode(q, k, v, slot_pos, pos, window=100, block_k=64)
    kf = jnp.repeat(k, 2, 2).transpose(0, 2, 1, 3).reshape(B * H, S_alloc, D)
    vf = jnp.repeat(v, 2, 2).transpose(0, 2, 1, 3).reshape(B * H, S_alloc, D)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, 1, D)
    ref = flash_attention_ref(qf, kf, vf, jnp.asarray([pos], jnp.int32),
                              slot_pos, causal=True, window=100)
    np.testing.assert_allclose(
        np.asarray(out.transpose(0, 2, 1, 3).reshape(B * H, 1, D)),
        np.asarray(ref), atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# mamba2 SSD
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,P,N,chunk", [(128, 16, 8, 32), (256, 32, 16, 64),
                                         (64, 8, 128, 64)])
def test_ssd_sweep(S, P, N, chunk):
    rng = np.random.default_rng(0)
    BH = 3
    xdt = jnp.asarray(rng.standard_normal((BH, S, P)), jnp.float32)
    logd = jnp.asarray(-np.abs(rng.standard_normal((BH, S))) * 0.2, jnp.float32)
    Bv = jnp.asarray(rng.standard_normal((BH, S, N)), jnp.float32)
    Cv = jnp.asarray(rng.standard_normal((BH, S, N)), jnp.float32)
    y1, h1 = ssd_ref(xdt, logd, Bv, Cv)
    y2, h2 = ssd(xdt, logd, Bv, Cv, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4,
                               rtol=1e-4)


def test_ssd_matches_model_layer():
    """Kernel agrees with the model's chunked jnp implementation end-to-end."""
    from repro.configs import get_arch
    from repro.models.mamba2 import mamba2_forward, init_mamba2
    cfg = get_arch("mamba2-130m", smoke=True)
    params = init_mamba2(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model), jnp.float32)
    y_model, _ = mamba2_forward(params, x, cfg)
    assert jnp.all(jnp.isfinite(y_model))


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,D", [(64, 128), (256, 512), (32, 64)])
def test_rmsnorm_sweep(T, D, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((T, D)), dtype)
    w = jnp.asarray(rng.standard_normal(D), dtype)
    r = jnp.asarray(rng.standard_normal((T, D)), dtype)
    (y1, r1) = rmsnorm(x, w, r)
    (y2, r2) = rmsnorm_ref(x, w, r)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(r1, np.float32),
                               np.asarray(r2, np.float32), atol=tol, rtol=tol)


def test_thomas_merge_engine_integration():
    """Kernel == engine's jnp replication path on a real OCC write log."""
    from repro.core.replication import thomas_apply
    rng = np.random.default_rng(3)
    N, C, K = 200, 10, 333
    val = jnp.asarray(rng.integers(0, 50, (N, C)), jnp.int32)
    tid = jnp.asarray(rng.integers(0, 9, N).astype(np.uint32) * 2)
    rows = rng.integers(-1, N, K).astype(np.int32)
    tids = (rng.integers(1, 200, K).astype(np.uint32)) * 2
    vals = rng.integers(0, 99, (K, C)).astype(np.int32)
    seen = {}
    for i in range(K):
        key = (int(rows[i]), int(tids[i]))
        if key in seen:
            vals[i] = vals[seen[key]]
        else:
            seen[key] = i
    v1, t1, _ = thomas_apply(val, tid, jnp.asarray(rows), jnp.asarray(vals),
                             jnp.asarray(tids))
    v2, t2 = thomas_merge(val, tid, jnp.asarray(rows), jnp.asarray(vals),
                          jnp.asarray(tids))
    assert jnp.array_equal(v1, v2) and jnp.array_equal(t1, t2)
