"""Partitioned-phase executor: serial per-partition semantics (§4.1)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.ops import READ, apply_op
from repro.core.partitioned import run_partitioned
from repro.core.tid import tid_epoch

C, M = 6, 4


def _ptxns(rng, P, T, n_rows):
    return {
        "valid": rng.random((P, T)) < 0.9,
        "row": np.stack([[rng.choice(n_rows, M, replace=False)
                          for _ in range(T)] for _ in range(P)]).astype(np.int32),
        "kind": rng.integers(0, 4, (P, T, M)).astype(np.int32),
        "delta": rng.integers(-9, 9, (P, T, M, C)).astype(np.int32),
        "user_abort": rng.random((P, T)) < 0.05,
    }


def _serial_ref(val, ptxn):
    """Pure-python per-partition serial execution."""
    val = np.array(val)
    P, T, _ = ptxn["row"].shape
    for p in range(P):
        for t in range(T):
            if not ptxn["valid"][p, t] or ptxn["user_abort"][p, t]:
                continue
            rows = ptxn["row"][p, t]
            old = jnp.asarray(val[p, rows])
            new = np.array(apply_op(jnp.asarray(ptxn["kind"][p, t]), old,
                                    jnp.asarray(ptxn["delta"][p, t])))
            w = ptxn["kind"][p, t] > READ
            val[p, rows[w]] = new[w]
    return val


@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_matches_serial_reference(seed, P, T):
    rng = np.random.default_rng(seed)
    n_rows = 16
    ptxn = _ptxns(rng, P, T, n_rows)
    val0 = jnp.asarray(rng.integers(0, 50, (P, n_rows, C)), jnp.int32)
    tid0 = jnp.zeros((P, n_rows), jnp.uint32)
    val, tidw, out, stats = run_partitioned(
        val0, tid0, jax.tree.map(jnp.asarray, ptxn), jnp.uint32(3))
    assert np.array_equal(np.array(val), _serial_ref(val0, ptxn))
    # every written record is tagged with a TID in the current epoch
    written = np.array(tidw) != 0
    assert np.all(np.array(tid_epoch(jnp.asarray(tidw)))[written] == 3)


def test_op_replication_replay_matches():
    """Ordered replay of the partitioned log reproduces the primary (§5)."""
    from repro.core.replication import replay_operations
    rng = np.random.default_rng(1)
    P, T, n_rows = 2, 6, 12
    ptxn = _ptxns(rng, P, T, n_rows)
    val0 = jnp.asarray(rng.integers(0, 50, (P, n_rows, C)), jnp.int32)
    tid0 = jnp.zeros((P, n_rows), jnp.uint32)
    val, tidw, out, _ = run_partitioned(
        val0, tid0, jax.tree.map(jnp.asarray, ptxn), jnp.uint32(1))
    rval, rtid = jax.vmap(replay_operations)(val0, tid0, out["log"])
    assert jnp.array_equal(val, rval)
    assert jnp.array_equal(tidw, rtid)
