"""Guarded hypothesis import: the real package when installed, else a seeded
deterministic fallback so tier-1 still collects and every test body runs.

Install the real property suite with `pip install -r requirements-dev.txt`.
Only the strategies this repo uses (integers, floats) are emulated.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import numpy as _np

    _FALLBACK_EXAMPLES = 8

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _Floats:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            return float(rng.uniform(self.lo, self.hi))

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        integers = staticmethod(_Integers)
        floats = staticmethod(_Floats)

    def given(*strats):
        def deco(fn):
            # zero-arg wrapper (no functools.wraps: pytest must NOT see the
            # sampled params in the signature, or it would seek fixtures)
            def run():
                rng = _np.random.default_rng(0)
                for _ in range(_FALLBACK_EXAMPLES):
                    fn(*[s.sample(rng) for s in strats])
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return deco

    def settings(**_kw):
        return lambda fn: fn
