"""Bounded-staleness read tier: snapshot catalog, read-lane admission,
executor parity, and the freshness-bound property.

The property under test (ISSUE 6 acceptance): any read served at freshness
bound k is bit-equal to the engine's committed state at SOME fence within
the last k epochs — never torn, never a future/in-flight epoch — and a
read that cannot meet the bound is re-routed to the OCC path, never served
stale.  The cluster variant re-checks the property across a mid-stream
kill of the full-replica node (§4.5 case 2: FALLBACK_DIST_CC), where the
killed node's hosted secondary leaves the catalog until recovery
re-materializes it.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path
from types import SimpleNamespace

import numpy as np

from repro.core.engine import StarEngine
from repro.db import tpcc
from repro.reads import ReadTier, SnapshotCatalog, reference_read
from repro.service.admission import AdmissionConfig, AdmissionController
from tests._hyp import given, settings, st

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# read-lane admission + P + 2 rejection attribution
# ---------------------------------------------------------------------------
def _read_req(n, home_p, P=2, M=2, C=3, read_only=True):
    return {
        "parts": np.full((n, M), home_p, np.int32),
        "rows": np.tile(np.arange(M, dtype=np.int32), (n, 1)),
        "kinds": np.zeros((n, M), np.int32),
        "deltas": np.zeros((n, M, C), np.int32),
        "user_abort": np.zeros(n, bool),
        "home": np.full(n, home_p, np.int32),
        "read_only": np.full(n, read_only, bool),
        "txn_id": np.arange(n, dtype=np.int64),
        "tenant": np.zeros(n, np.int32),
        "arrival_s": np.zeros(n),
    }


def test_read_lane_admission_caps_and_shed_attribution():
    """Declared-read-only singles route to the bounded read lane; overflow
    sheds are attributed to the read-lane slot (index P + 1) — and the
    attribution array is ALWAYS sized P + 2 so per-node accounting
    (ClusterTxnService.node_shed) can index it explicitly."""
    adm = AdmissionController(2, 64, max_ops=2, n_cols=3,
                              cfg=AdmissionConfig(64, 64, read_queue_cap=2),
                              read_lane=True)
    assert adm.stats.rejected_by_queue.shape == (2 + 2,)
    rejected = adm.offer(_read_req(5, home_p=1), 0.0)
    assert rejected.sum() == 3                       # cap 2 admitted
    assert adm.read_depth() == 2
    assert len(adm.part_queues[1]) == 0              # bypassed the OCC queue
    assert adm.stats.rejected_by_queue.tolist() == [0, 0, 0, 3]
    assert adm.stats.max_read_depth == 2
    # FIFO drain hands the admitted slots to the tier
    slots = adm.drain_reads(10)
    assert len(slots) == 2 and adm.read_depth() == 0
    # staleness-bound fallback: back to the FRONT of the home OCC queue
    adm.requeue_reads_occ(slots)
    assert list(adm.part_queues[1]) == slots
    assert adm.depth() == 2


def test_read_lane_disabled_routes_reads_to_occ():
    """Without a read tier the same declared-read-only request takes the
    normal partition queue; the attribution layout stays P + 2."""
    adm = AdmissionController(2, 64, max_ops=2, n_cols=3)
    rejected = adm.offer(_read_req(3, home_p=0), 0.0)
    assert not rejected.any()
    assert adm.read_depth() == 0
    assert len(adm.part_queues[0]) == 3
    assert adm.stats.rejected_by_queue.shape == (2 + 2,)
    assert adm.stats.rejected_by_queue[3] == 0


def test_fallback_never_serves_without_eligible_replica():
    """An EMPTY catalog (no replica inside any bound) must serve nothing:
    every drained read re-enters its home partition queue, order intact."""
    adm = AdmissionController(2, 64, max_ops=2, n_cols=3, read_lane=True)
    assert not adm.offer(_read_req(3, home_p=1), 0.0).any()
    queued = list(adm.read_queue)
    tier = ReadTier(max_staleness_epochs=4)
    results = tier.serve(adm)
    assert results == []
    assert tier.stats.served == 0 and tier.stats.fallbacks == 3
    assert adm.read_depth() == 0
    assert list(adm.part_queues[1]) == queued        # FIFO preserved


# ---------------------------------------------------------------------------
# snapshot catalog lifecycle
# ---------------------------------------------------------------------------
def _view(rid, epoch, P=2, kind="secondary", node=1):
    return {"id": rid, "kind": kind, "node": node, "epoch": epoch,
            "watermark": (epoch, 0), "cover": np.ones(P, bool),
            "row_of_partition": np.arange(P), "val": np.zeros((P, 4, 2)),
            "tid": np.zeros((P, 4)), "idx": []}


def test_catalog_ring_freshness_choose_and_remove():
    cat = SnapshotCatalog(2, retain=2)
    for e in (1, 2, 3):
        cat.stamp(_view("sec1", e))
    assert len(cat.entries["sec1"].snaps) == 2       # ring bounded
    assert cat.freshness("sec1") == 0
    cat.stamp(_view("full", 3, kind="full", node=0))
    cat.announce_epoch(4)                            # nobody refreshed
    assert cat.freshness("sec1") == 1 and cat.freshness("full") == 1
    assert cat.eligible(0, 0) == []                  # bound 0: none fresh
    got = cat.choose(0, 1, weight=2)
    assert got is not None and got[0].serves == 2
    # least-served balancing: next choice goes to the other replica
    other = cat.choose(0, 1, weight=1)
    assert other[0].replica_id != got[0].replica_id
    # node death purges the entry AND its retained snapshots
    assert cat.remove("sec1") and cat.freshness("sec1") is None
    assert not cat.remove("sec1")                    # idempotent


# ---------------------------------------------------------------------------
# engine-backed fixture: full-mix TPC-C, every fence state recorded
# ---------------------------------------------------------------------------
_FX = None


def _engine_fixture():
    """Run 5 full-mix epochs once, recording every replica view's committed
    state per fence (numpy copies — the oracle the property compares
    against).  5 epochs (odd) leaves the secondary view one fence stale
    under the cadence-2 refresh, so k=0 vs k>=1 really differ."""
    global _FX
    if _FX is not None:
        return _FX
    cfg = tpcc.TPCCConfig(n_partitions=4, n_items=400, cust_per_district=40,
                          order_ring=64, mix="full", delivery_gen_lag=96)
    state = tpcc.TPCCState(cfg)
    init = tpcc.init_values(cfg, np.random.default_rng(0), state=state)
    eng = StarEngine(4, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg))
    tier = ReadTier(max_staleness_epochs=3, sec_refresh_every=2)
    recorded, reads = {}, []

    def record():
        for v in eng.read_views():
            recorded[(v["id"], int(v["epoch"]))] = {
                "val": np.asarray(v["val"]).copy(),
                "tid": np.asarray(v["tid"]).copy(),
                "idx": [{k: np.asarray(ix[k]).copy()
                         for k in ("key", "prow", "tid")}
                        for ix in (v.get("idx") or [])]}

    tier.observe_epoch(eng)
    record()
    for ep in range(5):
        raw = tpcc.make_raw(cfg, state, 96, np.random.default_rng(ep))
        batch = tpcc.make_batch(cfg, state, 0, raw=raw)
        m = eng.run_epoch(batch)
        tpcc.apply_consume_feedback(state, batch, m)
        tier.observe_epoch(eng)
        record()
        sel = raw["read_only"]
        reads.append({k: raw[k][sel] for k in
                      ("parts", "rows", "kinds", "deltas", "user_abort",
                       "home")})
    _FX = SimpleNamespace(
        cfg=cfg, eng=eng, tier=tier, recorded=recorded,
        reads={k: np.concatenate([r[k] for r in reads]) for k in reads[0]},
        final_epoch=int(eng.committed_epoch))
    assert _FX.reads["home"].shape[0] > 0, "mix drew no read-only txns"
    return _FX


def _offer_reads(fx, pick):
    n = pick.size
    adm = AdmissionController(4, fx.cfg.rows_per_partition,
                              max_ops=fx.reads["rows"].shape[1],
                              n_cols=fx.reads["deltas"].shape[2],
                              read_lane=True)
    req = {k: v[pick] for k, v in fx.reads.items()}
    req.update(read_only=np.ones(n, bool),
               txn_id=np.arange(n, dtype=np.int64),
               tenant=np.zeros(n, np.int32), arrival_s=np.zeros(n))
    assert not adm.offer(req, 0.0).any()
    return adm


def _check_results(fx, tier, adm, results, k):
    pool = adm.pool
    cur = tier.catalog.current_epoch
    for r in results:
        assert 0 <= r["freshness"] <= k, r        # never future, never past k
        assert r["freshness"] == cur - r["epoch"]
        if k == 0:
            assert r["epoch"] == fx.final_epoch   # fence-fresh serving
        ent = tier.catalog.entries[r["replica"]]
        arow = ent.row_of_partition[pool.home[r["slots"]].astype(np.int64)]
        exp = reference_read(fx.recorded[(r["replica"], r["epoch"])], arow,
                             pool.row[r["slots"]], pool.kind[r["slots"]],
                             pool.delta[r["slots"]])
        for key, want in exp.items():             # bit-equal to the fence
            assert np.array_equal(np.asarray(r["out"][key]), want), \
                (r["replica"], r["epoch"], key)


def test_k0_reads_bit_equal_current_fence():
    """k = 0: every read is served from a snapshot of exactly the current
    committed fence, bit-equal to the recorded engine state."""
    fx = _engine_fixture()
    fx.tier.k = 0
    adm = _offer_reads(fx, np.arange(fx.reads["home"].shape[0]))
    results = fx.tier.serve(adm)
    assert sum(r["slots"].size for r in results) == fx.reads["home"].shape[0]
    assert fx.tier.stats.stale_violations == 0
    _check_results(fx, fx.tier, adm, results, k=0)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 3), st.integers(0, 2 ** 31 - 1))
def test_freshness_bound_property(k, seed):
    """Any read served at bound k is bit-equal to a recorded fence within
    the last k epochs; nothing is dropped (served + fallbacks == offered)
    and nothing is ever served past the bound."""
    fx = _engine_fixture()
    tier = fx.tier
    tier.k = int(k)
    rng = np.random.default_rng(seed)
    total = fx.reads["home"].shape[0]
    pick = rng.choice(total, size=int(rng.integers(1, total + 1)),
                      replace=False)
    adm = _offer_reads(fx, pick)
    before = tier.stats.fallbacks
    results = tier.serve(adm)
    served = sum(r["slots"].size for r in results)
    # the full copy is stamped every fence, so nothing needs the fallback
    assert served == pick.size and tier.stats.fallbacks == before
    assert tier.stats.stale_violations == 0
    _check_results(fx, tier, adm, results, k=int(k))


# ---------------------------------------------------------------------------
# cluster: property holds across a mid-stream kill + case-2 recovery
# ---------------------------------------------------------------------------
def test_cluster_read_property_across_midstream_kill_case2():
    """Kill the full-replica node MID-STREAM (aborted at slab 1).  The
    coordinator classifies FALLBACK_DIST_CC (§4.5 case 2); the killed
    node's hosted secondary AND the full copy leave the catalog (their
    snapshots died with the node) until recovery re-materializes and the
    next fence re-stamps them.  Every read served before, during, and
    after stays bit-equal to a committed fence within the bound — the
    reverted in-flight epoch is never visible."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.cluster import ClusterRuntime
        from repro.core.fault import FaultInjector, RecoveryCase
        from repro.db import tpcc
        from repro.reads import ReadTier, reference_read
        from repro.service.admission import AdmissionController

        P = 8
        cfg = tpcc.TPCCConfig(n_partitions=P, n_items=400,
                              cust_per_district=40, order_ring=64,
                              mix="full", delivery_gen_lag=96)
        state = tpcc.TPCCState(cfg)
        init = tpcc.init_values(cfg, np.random.default_rng(0), state=state)
        mesh = jax.make_mesh((4,), ("part",), devices=jax.devices()[:4])
        inj = FaultInjector()
        inj.schedule_kill(0, epoch=3, slab=1)     # full-replica node, mid-stream
        rt = ClusterRuntime(mesh, P, cfg.rows_per_partition, init_val=init,
                            indexes=tpcc.index_specs(cfg), injector=inj)
        tier = ReadTier(max_staleness_epochs=2, sec_refresh_every=2)
        tier.observe_epoch(rt)
        recorded, events, removed_seen = {}, [], False

        def record():
            for v in rt.read_views():
                recorded[(v["id"], int(v["epoch"]))] = {
                    "val": np.asarray(v["val"]).copy(),
                    "tid": np.asarray(v["tid"]).copy(),
                    "idx": [{k: np.asarray(ix[k]).copy()
                             for k in ("key", "prow", "tid")}
                            for ix in (v.get("idx") or [])]}

        record()
        for ep in range(6):
            raw = tpcc.make_raw(cfg, state, 96, np.random.default_rng(ep))
            batch = tpcc.make_batch(cfg, state, 0, raw=raw)
            m = rt.run_epoch(batch)
            tpcc.apply_consume_feedback(state, batch, m)
            if "recovery" in m:
                events.append(m["recovery"])
            tier.observe_epoch(rt, m)
            record()
            sel = np.nonzero(raw["read_only"])[0]
            if not sel.size:
                continue
            adm = AdmissionController(P, cfg.rows_per_partition,
                                      max_ops=raw["rows"].shape[1],
                                      n_cols=raw["deltas"].shape[2],
                                      read_lane=True)
            n = sel.size
            req = {k: raw[k][sel] for k in
                   ("parts", "rows", "kinds", "deltas", "user_abort",
                    "home", "read_only")}
            req.update(txn_id=np.arange(n, dtype=np.int64),
                       tenant=np.zeros(n, np.int32), arrival_s=np.zeros(n))
            assert not adm.offer(req, 0.0).any()
            results = tier.serve(adm)
            pool = adm.pool
            cur = tier.catalog.current_epoch
            for r in results:
                assert 0 <= r["freshness"] <= 2, r
                assert r["freshness"] == cur - r["epoch"]
                ent = tier.catalog.entries[r["replica"]]
                arow = ent.row_of_partition[
                    pool.home[r["slots"]].astype(np.int64)]
                exp = reference_read(recorded[(r["replica"], r["epoch"])],
                                     arow, pool.row[r["slots"]],
                                     pool.kind[r["slots"]],
                                     pool.delta[r["slots"]])
                for key, want in exp.items():
                    assert np.array_equal(np.asarray(r["out"][key]), want), \
                        (r["replica"], r["epoch"], key)
            assert rt.replica_consistent(), ep

        [ev] = events
        assert ev.case is RecoveryCase.FALLBACK_DIST_CC, ev
        assert ev.aborted_at_slab == 1, ev
        assert tier.stats.replicas_removed >= 2      # sec0 + the full copy
        assert "full" in tier.catalog.entries        # re-registered post-recovery
        assert "sec0" in tier.catalog.entries
        assert tier.stats.stale_violations == 0
        assert tier.stats.served > 0
        print("OK case2 reads", tier.stats.served,
              "removed", tier.stats.replicas_removed)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK case2 reads" in out.stdout
