"""ChangeLog subsystem: the one ordered op stream + its subscribers.

Covers this PR's tentpole and satellites:

* the pinned byte invariant (overlapped + fence == total == sum of slab
  sizes), ONCE, against the ChangeLog attribution — moved here from the
  per-engine copies;
* subscriber protocol ordering, explicit ledger overflow (drop-oldest
  with a counter, surfaced through engine stats) and revert correctness
  near the bound;
* materialized-view property: every stamped fence aggregate bit-equals a
  from-scratch recompute over committed state, and ``time_travel(e)``
  returns exactly the recorded fence-e snapshot;
* mid-epoch slab-watermark reads: k=0 serves only partitions no
  published slab wrote (bit-equal to the committed snapshot); dirty
  partitions defer to the fence, order intact;
* cluster (subprocess, forced host devices): the MV property holds at
  every fence across a MID-STREAM kill + case-2 recovery, and the
  analytics lane answers its query mix from the stamps.
"""
import os
import subprocess
import sys
import textwrap
from collections import Counter
from pathlib import Path

import numpy as np

from repro.changelog import ChangeLog, MaterializedViews
from repro.core.engine import StarEngine
from repro.db import tpcc, ycsb
from repro.reads import ReadTier, reference_read
from repro.service.admission import AdmissionController
from tests._hyp import given, settings, st

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# the pinned byte invariant — one copy, against the changelog attribution
# ---------------------------------------------------------------------------
def _mk_engine(n_slabs):
    cfg = tpcc.TPCCConfig(n_partitions=2, n_items=400, cust_per_district=40,
                          order_ring=64, mix="full", delivery_gen_lag=256)
    state = tpcc.TPCCState(cfg)
    init = tpcc.init_values(cfg, np.random.default_rng(5), state=state)
    eng = StarEngine(cfg.n_partitions, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg), n_slabs=n_slabs)
    return cfg, state, eng


def test_stream_bytes_pin_slab_sizes_and_count_index_ops():
    """Modeled stream bytes == sum of stream slab sizes: the overlapped +
    fence-exposed split partitions exactly the epoch's op-stream bytes,
    and the n_slabs=1 baseline (ship everything at the fence) sees the
    identical total with ALL of it fence-exposed.  Index op bytes must be
    non-zero under the full mix.  Both engines' stats derive from ONE
    ChangeLog.attribute source, so this invariant lives here once."""
    cfg4, st4, eng4 = _mk_engine(n_slabs=4)
    cfg1, st1, eng1 = _mk_engine(n_slabs=1)
    for ep in range(3):
        m4 = eng4.run_epoch(tpcc.make_batch(cfg4, st4, 128, seed=ep))
        m1 = eng1.run_epoch(tpcc.make_batch(cfg1, st1, 128, seed=ep))
        # per-epoch: the split partitions the epoch's stream bytes
        assert m4["op_bytes_overlapped"] + m4["op_bytes_fence"] == \
            m1["op_bytes_overlapped"] + m1["op_bytes_fence"]
        assert m1["op_bytes_overlapped"] == 0          # baseline: no overlap
    s4, s1 = eng4.stats, eng1.stats
    # totals: overlapped + fence == sum of all slab sizes == hybrid stream
    assert s4.op_bytes_overlapped + s4.op_bytes_fence == s4.op_bytes_hybrid
    assert s1.op_bytes_fence == s1.op_bytes_hybrid
    assert s4.op_bytes_hybrid == s1.op_bytes_hybrid    # same workload
    # streaming strictly lowers the fence-exposed bytes vs the baseline
    assert 0 < s4.op_bytes_fence < s1.op_bytes_fence
    assert s4.op_bytes_overlapped > 0
    # index ops hit the byte model (previously uncounted in t_fence_net_s)
    assert s4.index_op_bytes > 0
    assert s4.index_op_bytes == s1.index_op_bytes
    assert eng4.replica_consistent() and eng1.replica_consistent()


def test_attribution_partitions_totals_on_any_frame():
    """Attribution's overlapped/fence split partitions the total for any
    slab frame, and the no-byte-table batch attributes to zero."""
    clog = ChangeLog(4)
    a = clog.attribute({"row_bytes": None}, None, False, lambda x: x)
    assert a.total == 0 and a.overlapped == 0 and a.fence == 0
    assert clog.slab_bounds(10) == [0, 2, 5, 7, 10]
    assert ChangeLog(1).slab_bounds(10) == [0, 10]
    assert ChangeLog(8).slab_bounds(3) == [0, 1, 2, 3]   # S capped at T


# ---------------------------------------------------------------------------
# subscriber protocol + explicit ledger overflow (satellite: bounded ledger)
# ---------------------------------------------------------------------------
class _Spy:
    def __init__(self):
        self.events = []

    def on_slab(self, log, info):
        self.events.append(("slab", info["epoch"], info["slab"]))

    def on_master(self, stream):
        self.events.append(("master",))

    def on_commit(self, epoch, record):
        self.events.append(("commit", epoch, record["part"] is not None))

    def on_revert(self, epoch, n_slabs):
        self.events.append(("revert", epoch, n_slabs))


def _toy_log(P=2, T=3):
    return {"row": np.zeros((P, T), np.int32),
            "val": np.zeros((P, T, 2), np.int32),
            "tid": np.zeros((P, T), np.uint32),
            "write": np.zeros((P, T, 1), bool)}


def test_ledger_overflow_explicit_and_revert_near_bound():
    """Ledger growth past the cap is EXPLICIT drop-oldest with a counter
    (it used to be silent truncation), and a revert near the bound
    discards exactly the in-flight slabs — the ledger keeps each
    committed (epoch, slab) exactly once."""
    clog = ChangeLog(4, ledger_cap=8)
    spy = clog.subscribe(_Spy())
    for ep in (1, 2, 3):
        for _ in range(4):
            clog.publish_slab(_toy_log(), ep)
        assert clog.slab_hwm == 4
        assert clog.commit(ep) == (4, 4 if ep == 3 else 0)
    assert clog.ledger_dropped == 4                  # epoch 1 dropped, counted
    assert clog.ledger == [(2, s) for s in range(4)] + \
        [(3, s) for s in range(4)]
    assert clog.watermark(3) == (3, 4)
    # revert near the bound: in-flight slabs discarded, ledger untouched
    clog.publish_slab(_toy_log(), 4)
    clog.publish_slab(_toy_log(), 4)
    assert clog.revert(4) == 2
    assert clog.slab_hwm == 0 and len(clog.ledger) == 8
    assert clog.watermark(3) == (3, 4)               # watermark unmoved
    # re-publish + commit: exactly-once entries, overflow counted again
    for _ in range(4):
        clog.publish_slab(_toy_log(), 4)
    clog.publish_master(_toy_log())
    assert clog.commit(4) == (4, 4)
    assert clog.ledger_dropped == 8
    assert max(Counter(clog.ledger).values()) == 1
    assert clog.watermark(4) == (4, 4)
    # subscriber saw everything, in stream order
    kinds = [e[0] for e in spy.events]
    assert kinds == ["slab"] * 4 + ["commit"] + ["slab"] * 4 + ["commit"] \
        + ["slab"] * 4 + ["commit"] + ["slab"] * 2 + ["revert"] \
        + ["slab"] * 4 + ["master", "commit"]
    assert ("revert", 4, 2) in spy.events
    # slab indices restart from 0 after the revert (exactly-once re-stream)
    post = [e for e in spy.events if e[0] == "slab" and e[1] == 4]
    assert [s for _, _, s in post] == [0, 1, 0, 1, 2, 3]


def test_engine_surfaces_ledger_drops_in_stats():
    """Overflow is visible at the engine surface: stats.ledger_dropped
    mirrors the changelog counter and the watermark stays coherent."""
    cfg = ycsb.YCSBConfig(n_partitions=2, records_per_partition=128)
    eng = StarEngine(2, 128, n_slabs=4)
    # the single-host engine retires ONE slab per epoch (the whole epoch
    # log published at once); cap 2 overflows on the third commit
    eng.changelog.ledger_cap = 2
    for ep in range(4):
        eng.run_epoch(ycsb.make_batch(cfg, 128, seed=ep))
    assert eng.stats.ledger_dropped == eng.changelog.ledger_dropped == 2
    assert len(eng.changelog.ledger) == 2
    # only the newest committed epochs survive; watermark coherent
    assert [e for e, _ in eng.changelog.ledger] == \
        [eng.committed_epoch - 1, eng.committed_epoch]
    assert eng.changelog.watermark(eng.committed_epoch) == \
        (eng.committed_epoch, 1)
    assert eng.replica_consistent()


# ---------------------------------------------------------------------------
# materialized views: bit-equality + time-travel property (hypothesis)
# ---------------------------------------------------------------------------
_MV = None


def _mv_fixture():
    """One full-mix engine with the MVs subscribed from the initial
    committed state; examples advance it one epoch at a time."""
    global _MV
    if _MV is None:
        cfg, state, eng = _mk_engine(n_slabs=4)
        views = MaterializedViews(cfg, stock_threshold=40, retain=4)
        eng.changelog.subscribe(views)
        val, tid = eng.committed_state()
        views.on_reset(val, tid, eng.committed_epoch)
        _MV = {"cfg": cfg, "state": state, "eng": eng, "views": views,
               "oracle": {eng.committed_epoch: views.recompute(val)}}
    return _MV


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_mv_bit_equal_recompute_and_time_travel(seed):
    """Every stamped fence aggregate bit-equals the from-scratch numpy
    recompute over the engine's committed state, and time_travel(e)
    returns exactly the stamp recorded at fence e (or None once
    evicted)."""
    fx = _mv_fixture()
    cfg, state, eng, views = fx["cfg"], fx["state"], fx["eng"], fx["views"]
    batch = tpcc.make_batch(cfg, state, 96, seed=int(seed) % 100_000)
    m = eng.run_epoch(batch)
    tpcc.apply_consume_feedback(state, batch, m)
    epoch, aggs = views.latest()
    assert epoch == eng.committed_epoch
    want = views.recompute(eng.committed_state()[0])
    for k in ("revenue", "stock_low", "undelivered", "order_latency"):
        assert aggs[k].dtype == want[k].dtype, k
        assert np.array_equal(aggs[k], want[k]), k
    fx["oracle"][epoch] = {k: v.copy() for k, v in want.items()}
    # fence-granular time-travel: exactly the recorded stamps, bounded
    retained = views.retained_epochs()
    assert len(retained) <= 4 and retained[-1] == epoch
    for e in retained:
        tt = views.time_travel(e)
        for k, v in fx["oracle"][e].items():
            assert np.array_equal(tt[k], v), (e, k)
    evicted = [e for e in fx["oracle"] if e not in retained]
    for e in evicted:
        assert views.time_travel(e) is None


def test_mv_revert_snaps_back_to_committed():
    """A §4.5 revert snaps the working projection back to committed: the
    stamps stay bit-equal to the committed state through the failure and
    at the next fence (nothing uncommitted leaks into the aggregates)."""
    cfg, state, eng = _mk_engine(n_slabs=4)
    views = MaterializedViews(cfg, stock_threshold=40, retain=4)
    eng.changelog.subscribe(views)
    val, tid = eng.committed_state()
    views.on_reset(val, tid, eng.committed_epoch)
    batch = tpcc.make_batch(cfg, state, 96, seed=0)
    m = eng.run_epoch(batch)
    tpcc.apply_consume_feedback(state, batch, m)
    eng.inject_failure({0})                          # scribble + revert
    assert views.reverts == 1
    epoch, aggs = views.latest()
    want = views.recompute(eng.committed_state()[0])
    for k in ("revenue", "stock_low", "undelivered", "order_latency"):
        assert np.array_equal(aggs[k], want[k]), k
    # the next committed fence still matches the oracle
    batch = tpcc.make_batch(cfg, state, 96, seed=1)
    m = eng.run_epoch(batch)
    tpcc.apply_consume_feedback(state, batch, m)
    epoch, aggs = views.latest()
    assert epoch == eng.committed_epoch
    want = views.recompute(eng.committed_state()[0])
    for k in ("revenue", "stock_low", "undelivered", "order_latency"):
        assert np.array_equal(aggs[k], want[k]), k
    assert eng.replica_consistent()


# ---------------------------------------------------------------------------
# mid-epoch slab-watermark reads (satellite: k=0 below the watermark)
# ---------------------------------------------------------------------------
def _stamp_view(tier, P, R, epoch, rng):
    view = {"id": "full", "kind": "full", "node": 0, "epoch": epoch,
            "watermark": (epoch, 0), "cover": np.ones(P, bool),
            "row_of_partition": np.arange(P, dtype=np.int64),
            "val": rng.integers(0, 100, (P, R, 3)).astype(np.int32),
            "tid": np.zeros((P, R), np.uint32), "idx": []}
    tier.catalog.P = P
    tier.catalog.stamp(view)
    return view


def _read_req(n, home_p, M=2, C=3):
    return {"parts": np.full((n, M), home_p, np.int32),
            "rows": np.tile(np.arange(M, dtype=np.int32), (n, 1)),
            "kinds": np.zeros((n, M), np.int32),
            "deltas": np.zeros((n, M, C), np.int32),
            "user_abort": np.zeros(n, bool),
            "home": np.full(n, home_p, np.int32),
            "read_only": np.ones(n, bool),
            "txn_id": np.arange(n, dtype=np.int64),
            "tenant": np.zeros(n, np.int32),
            "arrival_s": np.zeros(n)}


def test_mid_epoch_reads_serve_below_watermark_defer_dirty():
    """DURING an epoch, k=0 reads of partitions no published slab wrote
    serve bit-equal to the committed snapshot; reads of dirty partitions
    re-enter the read lane's FRONT (order intact) and serve at the
    fence.  Without an attached changelog, mid-epoch mode serves
    nothing."""
    P, R = 2, 8
    tier = ReadTier(max_staleness_epochs=0)
    adm = AdmissionController(P, R, max_ops=2, n_cols=3, read_lane=True)
    rng = np.random.default_rng(3)
    view = _stamp_view(tier, P, R, epoch=5, rng=rng)

    # no changelog attached: mid-epoch serving is off, lane untouched
    assert not adm.offer(_read_req(2, home_p=0), 0.0).any()
    assert tier.serve(adm, mid_epoch=True) == []
    assert adm.read_depth() == 2

    clog = ChangeLog(n_slabs=4)
    tier.attach_changelog(clog)
    assert not adm.offer(_read_req(3, home_p=1), 0.0).any()
    deferred_order = [s for s in adm.read_queue
                      if adm.pool.home[s] == 1]

    # slab 0 dirties partition 1 only
    log = _toy_log(P=P, T=3)
    log["write"][1, 0, 0] = True
    clog.publish_slab(log, epoch=6)

    results = tier.serve(adm, mid_epoch=True)
    pool = adm.pool
    served = np.concatenate([r["slots"] for r in results])
    assert (pool.home[served] == 0).all()            # clean partition only
    assert tier.stats.mid_epoch_served == 2
    assert tier.stats.mid_epoch_deferred == 3
    assert tier.stats.stale_violations == 0
    for r in results:
        assert r["freshness"] == 0                   # k=0: fence-fresh
        ent = tier.catalog.entries[r["replica"]]
        arow = ent.row_of_partition[pool.home[r["slots"]].astype(np.int64)]
        exp = reference_read({"val": view["val"], "tid": view["tid"],
                              "idx": []}, arow, pool.row[r["slots"]],
                             pool.kind[r["slots"]], pool.delta[r["slots"]])
        for key, want in exp.items():                # bit-equal committed
            assert np.array_equal(np.asarray(r["out"][key]), want), key
    # deferred reads sit at the FRONT of the read lane, order intact
    assert list(adm.read_queue)[:3] == deferred_order

    # fence: commit resets the gate; the deferred reads now serve
    clog.commit(6)
    tier.catalog.announce_epoch(6)
    tier.catalog.stamp(dict(view, epoch=6, watermark=(6, 4)))
    results = tier.serve(adm, mid_epoch=True)
    assert sum(r["slots"].size for r in results) == 3
    assert tier.stats.mid_epoch_served == 5
    assert adm.read_depth() == 0


def test_mid_epoch_gate_resets_on_revert():
    """A §4.5 revert clears the accumulated dirty set — the re-executed
    epoch's watermark starts clean."""
    clog = ChangeLog(n_slabs=2)
    tier = ReadTier()
    tier.attach_changelog(clog)
    log = _toy_log()
    log["write"][0, 1, 0] = True
    clog.publish_slab(log, epoch=2)
    assert tier._gate.dirty is not None and tier._gate.dirty[0]
    clog.revert(2)
    assert tier._gate.dirty is None
    clog.publish_slab(_toy_log(), epoch=2)
    assert not tier._gate.dirty.any()
    clog.commit(2)
    assert tier._gate.dirty is None


# ---------------------------------------------------------------------------
# cluster: MV property across a MID-STREAM kill + case-2 recovery
# ---------------------------------------------------------------------------
def test_cluster_mv_bit_equal_across_midstream_kill_case2():
    """The analytics lane rides ClusterRuntime under the full TPC-C mix.
    Killing the full-replica node MID-STREAM (aborted at slab 1) forces
    the §4.5 case-2 path (FALLBACK_DIST_CC): the doomed epoch's slabs had
    already updated the working projection, the revert snaps it back to
    committed, and every subsequent fence stamp STILL bit-equals the
    from-scratch recompute — plus fence-granular time-travel to every
    retained epoch and a live query mix off the stamps."""
    out = _run("""
        import jax, numpy as np
        from repro.changelog import AnalyticsLane
        from repro.cluster import ClusterRuntime
        from repro.core.fault import FaultInjector, RecoveryCase
        from repro.db import tpcc
        P = 8
        cfg = tpcc.TPCCConfig(n_partitions=P, n_items=400,
                              cust_per_district=40, order_ring=64,
                              mix="full", delivery_gen_lag=96)
        state = tpcc.TPCCState(cfg)
        init = tpcc.init_values(cfg, np.random.default_rng(0), state=state)
        mesh = jax.make_mesh((4,), ("part",), devices=jax.devices()[:4])
        inj = FaultInjector()
        inj.schedule_kill(0, epoch=3, slab=1)   # full holder, mid-stream
        rt = ClusterRuntime(mesh, P, cfg.rows_per_partition, init_val=init,
                            indexes=tpcc.index_specs(cfg), injector=inj)
        lane = AnalyticsLane(cfg, stock_threshold=40, retain=4)
        assert lane.ensure_attached(rt)
        views = lane.views
        oracle = {rt.committed_epoch:
                  views.recompute(rt.committed_state()[0])}
        events = []
        for ep in range(6):
            batch = tpcc.make_batch(cfg, state, 96, seed=ep)
            m = rt.run_epoch(batch)
            tpcc.apply_consume_feedback(state, batch, m)
            if "recovery" in m: events.append(m["recovery"])
            out = lane.serve(rt.committed_epoch)
            epoch, aggs = views.latest()
            assert epoch == rt.committed_epoch, (epoch, rt.committed_epoch)
            want = views.recompute(rt.committed_state()[0])
            for k in ("revenue", "stock_low", "undelivered", "order_latency"):
                assert np.array_equal(aggs[k], want[k]), (ep, k)
            oracle[epoch] = {k: v.copy() for k, v in want.items()}
            # the query mix answers from the stamp it just verified
            assert out["epoch"] == epoch
            assert out["stock_low"]["total"] == int(want["stock_low"].sum())
            assert out["undelivered"]["total"] == \\
                int((want["undelivered"]).sum())
            top = out["top_revenue"]
            flat = want["revenue"].reshape(-1)
            assert top[0][2] == int(flat.max())
            assert rt.replica_consistent(), ep
        for e in views.retained_epochs():
            tt = views.time_travel(e)
            for k, v in oracle[e].items():
                assert np.array_equal(tt[k], v), (e, k)
        [ev] = events
        assert ev.case is RecoveryCase.FALLBACK_DIST_CC, ev
        assert ev.aborted_at_slab == 1, ev
        assert views.reverts == 1                 # the doomed epoch
        assert views.slabs_applied > views.commits
        s = lane.summary()
        assert s["analytics_serves"] == 6
        assert s["analytics_max_epoch_lag"] == 0
        print("OK cluster mv", views.slabs_applied, s["analytics_queries"])
    """, devices=4)
    assert "OK cluster mv" in out
