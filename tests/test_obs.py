"""Unified observability layer: tracer, registry, and their wiring.

* trace-export schema: the Chrome/Perfetto ``trace_event`` JSON a real
  engine run exports is loadable — every event has ph X/i, microsecond
  ts, non-negative dur, and complete spans NEST per (pid, tid): any two
  either disjoint or contained, with the whole-epoch span containing the
  phase spans;
* registry bit-match: per-epoch snapshots taken by the service layer
  read the SAME live stats dataclasses — the final snapshot equals every
  legacy ``EngineStats``/``ServiceStats`` field exactly, on a full-mix
  TPC-C run;
* overhead budget: with tracing DISABLED (the default), the per-call
  cost of the instrumentation points times a generous spans-per-epoch
  count stays under 2% of a measured epoch;
* recovery span tree (subprocess, forced host devices): a mid-run node
  kill exports classify → revert → restore → re-master → re-execute
  spans, all nested inside one ``recovery`` span.
"""
import json
import os
import subprocess
import sys
import textwrap
import time
from dataclasses import fields
from pathlib import Path

import numpy as np

from repro.core.engine import StarEngine
from repro.db import tpcc
from repro.obs import MetricsRegistry, Tracer, set_tracer
from repro.obs.trace import get_tracer

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def _small_engine():
    cfg = tpcc.TPCCConfig(n_partitions=2, n_items=400, cust_per_district=40,
                          order_ring=64, mix="full", delivery_gen_lag=256)
    state = tpcc.TPCCState(cfg)
    init = tpcc.init_values(cfg, np.random.default_rng(5), state=state)
    eng = StarEngine(cfg.n_partitions, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg))
    return cfg, state, eng


# ---------------------------------------------------------------------------
# trace export schema + nesting
# ---------------------------------------------------------------------------
EPS = 0.05        # us; absorbs the 3-decimal export rounding at boundaries


def _contained(a, b):
    """Complete event a inside complete event b (closed interval)."""
    return (a["ts"] >= b["ts"] - EPS
            and a["ts"] + a["dur"] <= b["ts"] + b["dur"] + EPS)


def test_trace_export_schema_and_nesting(tmp_path):
    tracer = Tracer(enabled=True)
    old = set_tracer(tracer)
    try:
        cfg, state, eng = _small_engine()
        for ep in range(3):
            batch = tpcc.make_batch(cfg, state, 96, seed=ep)
            m = eng.run_epoch(batch)
            tpcc.apply_consume_feedback(state, batch, m)
    finally:
        set_tracer(old)

    path = tmp_path / "trace.json"
    n = tracer.export_chrome(str(path))
    assert n > 0 and tracer.dropped == 0
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and len(evs) == n
    names = {e["name"] for e in evs}
    # the stack's load-bearing spans are all present
    for want in ("engine.epoch", "engine.partitioned", "engine.fence",
                 "engine.single_master", "changelog.slab_ship",
                 "changelog.commit"):
        assert want in names, (want, sorted(names))
    for e in evs:
        assert e["ph"] in ("X", "i"), e
        assert isinstance(e["ts"], (int, float))
        assert {"pid", "tid", "name", "cat"} <= e.keys()
        if e["ph"] == "X":
            assert e["dur"] >= 0, e           # no negative durations
    # sorted by ts (stable Perfetto ingestion)
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)
    # the engine span hierarchy nests per (pid, tid): pairwise disjoint
    # or contained (other categories may straddle measured-window edges)
    by_tid = {}
    for e in evs:
        if e["ph"] == "X" and e["name"].startswith("engine."):
            by_tid.setdefault((e["pid"], e["tid"]), []).append(e)
    assert by_tid
    for group in by_tid.values():
        for i, a in enumerate(group):
            for b in group[i + 1:]:
                disjoint = (a["ts"] + a["dur"] <= b["ts"] + EPS
                            or b["ts"] + b["dur"] <= a["ts"] + EPS)
                assert disjoint or _contained(a, b) or _contained(b, a), \
                    (a, b)
    # every phase span sits inside a whole-epoch span
    epochs = [e for e in evs if e["name"] == "engine.epoch"]
    for e in evs:
        if e["name"] in ("engine.partitioned", "engine.single_master"):
            assert any(_contained(e, ep) for ep in epochs), e


def test_trace_instants_and_kernel_counts():
    from repro.obs.trace import kernel_launch, kernel_launch_counts
    before = kernel_launch_counts().get("test.k", 0)
    kernel_launch("test.k", lanes=8)
    kernel_launch("test.k", lanes=8)
    assert kernel_launch_counts()["test.k"] == before + 2


def test_ring_buffer_bounded_drop_oldest():
    tr = Tracer(capacity=16, enabled=True)
    for i in range(64):
        tr.instant(f"e{i}")
    assert len(tr.events()) == 16
    assert tr.dropped == 48
    assert tr.events()[0]["name"] == "e48"     # oldest dropped
    assert tr.to_chrome()["otherData"]["dropped_events"] == 48


# ---------------------------------------------------------------------------
# registry: bit-match with the legacy stats dataclasses
# ---------------------------------------------------------------------------
def test_registry_snapshot_bit_matches_legacy_stats():
    from repro.service import (AdmissionConfig, OpenLoopClient, TPCCSource,
                               TxnService)
    cfg = tpcc.TPCCConfig(n_partitions=2, n_items=400, cust_per_district=40,
                          order_ring=64, mix="full", delivery_gen_lag=256)
    state = tpcc.TPCCState(cfg)
    init = tpcc.init_values(cfg, np.random.default_rng(7), state=state)
    eng = StarEngine(2, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg))
    client = OpenLoopClient(TPCCSource(cfg, state=state, seed=1),
                            rate_txn_s=400.0, seed=7)
    svc = TxnService(eng, [client], AdmissionConfig(64, 64),
                     slots_per_partition=16, master_lanes=16,
                     feedback=lambda b, m: tpcc.apply_consume_feedback(
                         state, b, m))
    out = svc.run(duration_s=0.4)
    assert out["committed"] > 0
    snaps = svc.metrics.snapshots
    assert len(snaps) == svc.stats.epochs          # one point per epoch
    last = snaps[-1]
    # live-object registration: the final snapshot equals every numeric
    # legacy field EXACTLY (same objects read at snapshot time)
    for f in fields(eng.stats):
        v = getattr(eng.stats, f.name)
        if isinstance(v, (int, float)):
            assert last[f"engine.{f.name}"] == v, f.name
    for f in fields(svc.stats):
        v = getattr(svc.stats, f.name)
        if isinstance(v, (int, float)):
            assert last[f"service.{f.name}"] == v, f.name
    for f in fields(svc.admission.stats):
        v = getattr(svc.admission.stats, f.name)
        if isinstance(v, (int, float)):
            assert last[f"admission.{f.name}"] == v, f.name
    # kernel-launch counters surface under kernels.*
    assert any(k.startswith("kernels.occ.") for k in last), sorted(last)[:20]
    # the time series is per-epoch monotonic where the stats are counters
    ep = [s["engine.epochs"] for s in snaps]
    assert ep == sorted(ep)


def test_registry_exporters(tmp_path):
    reg = MetricsRegistry()
    reg.counter_add("a.count", 3)
    reg.gauge_set("a.gauge", 1.5)
    reg.hist_observe("a.lat_s", 0.004)
    reg.hist_observe("a.lat_s", 0.3)
    reg.snapshot(0)
    reg.counter_add("a.count", 1)
    reg.snapshot(1)
    p = tmp_path / "m.jsonl"
    n = reg.export_jsonl(str(p))
    lines = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert n == len(lines) == 2
    assert lines[0]["a.count"] == 3 and lines[1]["a.count"] == 4
    assert lines[1]["epoch"] == 1
    txt = reg.export_prometheus()
    assert "# TYPE a_count gauge" in txt
    assert 'a_lat_s_bucket{le="+Inf"} 2' in txt
    assert "a_lat_s_count 2" in txt


# ---------------------------------------------------------------------------
# disabled-path overhead budget
# ---------------------------------------------------------------------------
def test_disabled_tracer_overhead_under_budget():
    """The default (disabled) tracer must cost <= 2% of epoch time for a
    generous per-epoch span count.  Measured as per-call cost of the real
    disabled entry points times a 4x-headroom span budget."""
    from repro.obs import trace as obs
    assert not get_tracer().enabled          # the default is off

    cfg, state, eng = _small_engine()
    eng.run_epoch(tpcc.make_batch(cfg, state, 96, seed=99))   # warm jit
    t0 = time.perf_counter()
    eng.run_epoch(tpcc.make_batch(cfg, state, 96, seed=100))
    epoch_s = time.perf_counter() - t0

    reps = 20000
    t0 = time.perf_counter()
    for _ in range(reps):
        with obs.span("x", cat="y", epoch=1):
            pass
        obs.complete("x", "y", 0.0, 1.0, epoch=1)
        obs.instant("x", "y")
    per_call_s = (time.perf_counter() - t0) / (3 * reps)

    # spans per epoch, with ~4x headroom over what the engine actually
    # emits (epoch + 2 phases + 2 fences + per-slab ship/commit + rounds
    # + service/read/analytics spans)
    spans_per_epoch = 256
    overhead = per_call_s * spans_per_epoch
    assert overhead <= 0.02 * epoch_s, \
        (f"disabled tracing {overhead * 1e6:.1f}us/epoch vs "
         f"epoch {epoch_s * 1e3:.2f}ms")


# ---------------------------------------------------------------------------
# recovery span tree across a mid-run kill (subprocess cluster)
# ---------------------------------------------------------------------------
def test_recovery_span_tree_exported():
    out = _run("""
        import json
        import numpy as np
        import jax
        from repro.cluster import ClusterRuntime
        from repro.core.fault import FaultInjector
        from repro.db import ycsb
        from repro.obs import Tracer, set_tracer

        tracer = Tracer(enabled=True)
        set_tracer(tracer)
        n = jax.device_count()
        mesh = jax.make_mesh((n,), ("part",))
        inj = FaultInjector(); inj.schedule_kill(node=1, epoch=1)
        P = 2 * n
        cfg = ycsb.YCSBConfig(n_partitions=P, records_per_partition=64)
        rt = ClusterRuntime(mesh, P, 64, injector=inj)
        for ep in range(3):
            rt.run_epoch(ycsb.make_batch(cfg, 64, seed=ep))
        assert rt.replica_consistent()
        doc = tracer.to_chrome()
        print("TRACE " + json.dumps(doc["traceEvents"]))
    """, devices=2)
    line = [ln for ln in out.splitlines() if ln.startswith("TRACE ")][-1]
    evs = json.loads(line[len("TRACE "):])
    spans = {e["name"]: e for e in evs if e["ph"] == "X"}
    # the full §4.5 recovery tree made it into the export
    for want in ("recovery", "recovery.classify", "recovery.revert",
                 "recovery.restore", "recovery.remaster",
                 "recovery.reexecute"):
        assert want in spans, (want, sorted(spans))
    root = spans["recovery"]
    for child in ("recovery.classify", "recovery.revert",
                  "recovery.restore", "recovery.remaster",
                  "recovery.reexecute"):
        c = spans[child]
        assert c["tid"] == root["tid"]
        assert _contained(c, root), (child, c, root)
    assert root["args"]["case"] == "PHASE_SWITCHING"
