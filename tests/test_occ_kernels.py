"""Fused Pallas OCC kernels (interpret mode) vs the jnp ref.py oracle.

Bit-for-bit parity on random op batches — including lock-conflict
interleavings (many lanes claiming the same rows) and phantom-abort
interleavings (inserts landing inside concurrently scanned ranges) — for:

* the full single-master executor (``kernel="pallas"`` vs ``"jnp"``),
* ``locate_index_ops`` (searchsorted + SCAN_L window probe),
* the partitioned executor / ``step_index_ops``,
* ``segment_scan(use_pallas=True)`` and ``StorageEngine.range_scan``.

Property-driven via tests/_hyp.py (real hypothesis when installed, seeded
fallback otherwise), so tier-1 runs the sweep either way.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.ops import (DELETE_IDX, IDX_OPS, INSERT_IDX, IX_EXPECT,
                            IX_HI, IX_ID, IX_KEY, IX_PROW, SCAN_CONSUME,
                            SCAN_READ)
from repro.core.partitioned import run_partitioned
from repro.core.single_master import run_single_master
from repro.kernels.occ.ops import locate_index_ops, step_index_ops
from repro.storage import IndexSpec, SENTINEL, make_index, segment_scan
from repro.storage.index import full_key

C = 10
M = 24


def _tree_equal(a, b):
    eq = jax.tree.map(lambda x, y: bool(jnp.array_equal(x, y)), a, b)
    return all(jax.tree.leaves(eq))


def _random_index_workload(rng, B, P, n_rows, caps, conflict_rows):
    """Random txn batch mixing primary ops with scan/insert/delete/consume
    index ops over overlapping key ranges — lock conflicts on the primary
    rows (drawn from a small pool) AND phantom conflicts on the scans
    (inserts inside scanned ranges) arise by construction."""
    # per-txn rows are drawn WITHOUT replacement (the generators' documented
    # invariant: at most one op per row per txn — duplicate-row scatters
    # would be order-unspecified); the small shared pool still produces
    # dense cross-lane lock conflicts
    pool = max(conflict_rows, M)
    rows = np.stack([rng.choice(pool, M, replace=False)
                     for _ in range(B)]).astype(np.int32)
    kinds = rng.integers(0, 4, (B, M)).astype(np.int32)
    deltas = rng.integers(-50, 50, (B, M, C)).astype(np.int32)
    deltas[..., -1] = 0                        # guard column: unguarded
    index = [make_index(IndexSpec(f"ix{i}", c), P)
             for i, c in enumerate(caps)]
    # seed some live entries so scans/consumes/deletes have targets
    for i, c in enumerate(caps):
        n_seed = int(rng.integers(0, min(c, 6)))
        for _ in range(n_seed):
            p = int(rng.integers(0, P))
            k = int(full_key(p, int(rng.integers(0, 60))))
            pos = int(jnp.searchsorted(index[i]["key"][p], k))
            if pos < c and int(index[i]["key"][p, pos]) != k:
                key = index[i]["key"].at[p].set(
                    jnp.sort(index[i]["key"][p].at[c - 1].set(k)))
                index[i] = {"key": key, "prow": index[i]["prow"],
                            "tid": index[i]["tid"]}
    for b in range(B):
        for k in range(int(rng.integers(0, IDX_OPS // 2))):
            iid = int(rng.integers(0, len(caps)))
            p = int(rng.integers(0, P))
            base = int(full_key(p, 0))
            r = rng.random()
            deltas[b, k] = 0
            if r < 0.35:
                kinds[b, k] = INSERT_IDX
                deltas[b, k, IX_KEY] = base + int(rng.integers(0, 60))
                deltas[b, k, IX_PROW] = int(rng.integers(0, n_rows))
            elif r < 0.6:
                kinds[b, k] = SCAN_READ
                lo = base + int(rng.integers(0, 40))
                deltas[b, k, IX_KEY] = lo
                deltas[b, k, IX_HI] = lo + int(rng.integers(1, 40))
            elif r < 0.8:
                kinds[b, k] = SCAN_CONSUME
                deltas[b, k, IX_KEY] = base
                deltas[b, k, IX_HI] = base + 60
                deltas[b, k, IX_EXPECT] = base + int(rng.integers(0, 60))
                rows[b, k] = pool + k      # tombstone row, txn-unique
            else:
                kinds[b, k] = DELETE_IDX
                deltas[b, k, IX_KEY] = base + int(rng.integers(0, 60))
            deltas[b, k, IX_ID] = iid
    txns = {"valid": rng.random(B) < 0.95, "row": rows, "kind": kinds,
            "delta": deltas, "user_abort": rng.random(B) < 0.1}
    return jax.tree.map(jnp.asarray, txns), index


@given(st.integers(0, 100_000))
@settings(max_examples=12, deadline=None)
def test_single_master_pallas_parity_random(seed):
    """Full executor parity: state, logs, stats, index — conflicts and
    phantom interleavings included."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(2, 12))
    P = int(rng.integers(1, 3))
    caps = [int(rng.integers(4, 20)) for _ in range(int(rng.integers(1, 3)))]
    n_rows = 64 * P
    txns, index = _random_index_workload(rng, B, P, n_rows, caps,
                                         conflict_rows=n_rows // 4)
    val0 = jnp.asarray(rng.integers(0, 50, (n_rows, C)), jnp.int32)
    tid0 = jnp.asarray(rng.integers(0, 5, n_rows).astype(np.uint32) * 2)
    outs = {}
    for kern in ("jnp", "pallas"):
        outs[kern] = run_single_master(
            val0, tid0, txns, jnp.uint32(2), max_rounds=4,
            index=[dict(i) for i in index], kernel=kern)
    (v1, t1, o1, s1), (v2, t2, o2, s2) = outs["jnp"], outs["pallas"]
    assert jnp.array_equal(v1, v2) and jnp.array_equal(t1, t2)
    assert _tree_equal(o1, o2)
    assert _tree_equal(s1, s2)


@given(st.integers(0, 100_000))
@settings(max_examples=10, deadline=None)
def test_locate_index_ops_parity(seed):
    rng = np.random.default_rng(seed)
    B, P = int(rng.integers(1, 8)), int(rng.integers(1, 4))
    caps = [int(rng.integers(4, 24)) for _ in range(int(rng.integers(1, 4)))]
    n_rows = 32 * P
    txns, index = _random_index_workload(rng, B, P, n_rows, caps,
                                         conflict_rows=8)
    K = min(IDX_OPS, M)
    a = locate_index_ops(index, txns["kind"][:, :K], txns["delta"][:, :K],
                         n_rows, kernel="jnp")
    b = locate_index_ops(index, txns["kind"][:, :K], txns["delta"][:, :K],
                         n_rows, kernel="pallas")
    assert a["no_addr"] == b["no_addr"]
    assert _tree_equal({k: v for k, v in a.items() if k != "no_addr"},
                       {k: v for k, v in b.items() if k != "no_addr"})


def test_phantom_abort_parity():
    """The canonical phantom interleaving (insert into a concurrently
    scanned range) produces identical abort/commit rounds on both paths."""
    index = [make_index(IndexSpec("ix", 16), 1)]
    rows = np.zeros((2, M), np.int32)
    kinds = np.full((2, M), 0, np.int32)
    deltas = np.zeros((2, M, C), np.int32)
    kinds[0, 0] = INSERT_IDX
    deltas[0, 0, IX_KEY] = 50
    deltas[0, 0, IX_PROW] = 3
    kinds[1, 0] = SCAN_READ
    deltas[1, 0, IX_KEY] = 0
    deltas[1, 0, IX_HI] = 100
    txns = jax.tree.map(jnp.asarray, {
        "valid": np.ones(2, bool), "row": rows, "kind": kinds,
        "delta": deltas, "user_abort": np.zeros(2, bool)})
    val0 = jnp.zeros((64, C), jnp.int32)
    tid0 = jnp.zeros((64,), jnp.uint32)
    res = {}
    for kern in ("jnp", "pallas"):
        res[kern] = run_single_master(val0, tid0, txns, jnp.uint32(1),
                                      max_rounds=3,
                                      index=[dict(index[0])], kernel=kern)
    o1, o2 = res["jnp"][2], res["pallas"][2]
    assert _tree_equal(o1, o2)
    # and the phantom really aborted the scanner in round 0 on both
    assert int(np.asarray(o1["committed_round"])[1]) > 0


@given(st.integers(0, 100_000))
@settings(max_examples=8, deadline=None)
def test_partitioned_pallas_parity_random(seed):
    rng = np.random.default_rng(seed)
    P, T = int(rng.integers(1, 4)), int(rng.integers(1, 5))
    caps = [int(rng.integers(4, 16))]
    R = 64
    txns, index = _random_index_workload(rng, P * T, P, R, caps,
                                         conflict_rows=R // 2)
    ptxn = {k: jnp.asarray(np.asarray(v).reshape((P, T) + v.shape[1:]))
            for k, v in txns.items()}
    # rows are partition-local in the partitioned executor
    val0 = jnp.asarray(rng.integers(0, 50, (P, R, C)), jnp.int32)
    tid0 = jnp.zeros((P, R), jnp.uint32)
    outs = {}
    for kern in ("jnp", "pallas"):
        outs[kern] = run_partitioned(val0, tid0, ptxn, jnp.uint32(1),
                                     index=[dict(i) for i in index],
                                     kernel=kern)
    (v1, t1, o1, s1), (v2, t2, o2, s2) = outs["jnp"], outs["pallas"]
    assert jnp.array_equal(v1, v2) and jnp.array_equal(t1, t2)
    assert _tree_equal(o1, o2)
    assert _tree_equal(s1, s2)


@given(st.integers(0, 100_000))
@settings(max_examples=10, deadline=None)
def test_step_index_ops_parity(seed):
    rng = np.random.default_rng(seed)
    P = int(rng.integers(1, 5))
    caps = [int(rng.integers(4, 24)) for _ in range(int(rng.integers(1, 3)))]
    txns, index = _random_index_workload(rng, P, P, 32, caps,
                                         conflict_rows=8)
    K = min(IDX_OPS, M)
    a = step_index_ops(index, txns["kind"][:, :K], txns["delta"][:, :K],
                       kernel="jnp")
    b = step_index_ops(index, txns["kind"][:, :K], txns["delta"][:, :K],
                       kernel="pallas")
    assert _tree_equal(a, b)


@given(st.integers(0, 100_000), st.integers(0, 80), st.integers(1, 60))
@settings(max_examples=15, deadline=None)
def test_segment_scan_pallas_parity(seed, lo, width):
    rng = np.random.default_rng(seed)
    cap = int(rng.integers(4, 64))
    n_live = int(rng.integers(0, cap))
    keys = np.full(cap, SENTINEL, np.int32)
    keys[:n_live] = np.sort(rng.choice(100, n_live, replace=False))
    a = segment_scan(jnp.asarray(keys), jnp.int32(lo), jnp.int32(lo + width))
    b = segment_scan(jnp.asarray(keys), jnp.int32(lo), jnp.int32(lo + width),
                     use_pallas=True)
    assert _tree_equal(tuple(a), tuple(b))


def test_storage_engine_range_scan_pallas():
    from repro.storage import StorageEngine
    eng = StorageEngine(2, 8, n_cols=4, index_specs=[IndexSpec("ix", 16)])
    idx = eng.indexes[0]
    idx["key"] = idx["key"].at[1, 0].set((1 << 24) | 7)
    idx["prow"] = idx["prow"].at[1, 0].set(5)
    a = eng.range_scan("ix", 1, (1 << 24) | 0, (1 << 24) | 100)
    b = eng.range_scan("ix", 1, (1 << 24) | 0, (1 << 24) | 100,
                       use_pallas=True)
    assert _tree_equal(tuple(a), tuple(b))
    assert bool(b[3][0]) and int(b[0][0]) == ((1 << 24) | 7)


# ---------------------------------------------------------------------------
# fused index-merge kernel vs the gather-form oracle
# ---------------------------------------------------------------------------
def _random_merge_batch(rng, P, cap, Kd, Ki, key_space=10_000):
    """Random sorted segments (SENTINEL-padded, canonical free slots) plus a
    random delete/insert batch — deletes mix live hits with misses, inserts
    mix fresh keys with masked (SENTINEL) slots."""
    key = np.full((P, cap), SENTINEL, np.int32)
    for p in range(P):
        n_live = int(rng.integers(0, cap + 1))
        key[p, :n_live] = np.sort(
            rng.choice(key_space, n_live, replace=False)).astype(np.int32)
    live = key != SENTINEL
    prow = np.where(live, rng.integers(0, 1000, (P, cap)), 0).astype(np.int32)
    tid = np.where(live, rng.integers(1, 99, (P, cap)), 0).astype(np.uint32)

    del_pq = np.full((P, Kd), SENTINEL, np.int32)
    for p in range(P):
        for j in range(Kd):
            r = rng.random()
            if r < 0.4 and live[p].any():
                del_pq[p, j] = rng.choice(key[p][live[p]])   # live hit
            elif r < 0.7:
                del_pq[p, j] = int(rng.integers(0, key_space))  # maybe miss
    ins_pq = np.full((P, Ki), SENTINEL, np.int32)
    mask = rng.random((P, Ki)) < 0.8
    ins_pq[mask] = rng.integers(0, key_space, int(mask.sum()))
    prow_pq = np.where(ins_pq != SENTINEL,
                       rng.integers(0, 1000, (P, Ki)), 0).astype(np.int32)
    tid_pq = np.where(ins_pq != SENTINEL,
                      rng.integers(1, 99, (P, Ki)), 0).astype(np.uint32)
    return tuple(jnp.asarray(a) for a in
                 (key, prow, tid, del_pq, ins_pq.astype(np.int32),
                  prow_pq, tid_pq))


@given(st.integers(0, 100_000))
@settings(max_examples=15, deadline=None)
def test_index_merge_pallas_parity_random(seed):
    """Fused kernel == gather-form oracle bit-exact: keys, prows, TIDs and
    overflow counts — overflow (dropped live keys) and empty segments
    arise by construction from small caps + dense inserts."""
    from repro.kernels.index_merge.ops import index_merge
    from repro.kernels.index_merge.ref import segment_merge_ref
    rng = np.random.default_rng(seed)
    P = int(rng.integers(1, 4))
    cap = int(rng.integers(2, 32))
    Kd = int(rng.integers(1, 8))
    Ki = int(rng.integers(1, 8))
    batch = _random_merge_batch(rng, P, cap, Kd, Ki, key_space=60)
    ref = jax.vmap(segment_merge_ref)(*batch)
    for kwargs in ({"use_pallas": False},
                   {"use_pallas": True},
                   {"use_pallas": True, "block_slots": 8}):  # multi-tile
        out = index_merge(*batch, **kwargs)
        assert _tree_equal(tuple(ref), tuple(out)), kwargs


def test_index_merge_edge_cases():
    """Deterministic corners: overflow dropping live keys, the all-SENTINEL
    segment, delete-only and insert-only batches."""
    from repro.kernels.index_merge.ops import index_merge
    from repro.kernels.index_merge.ref import segment_merge_ref
    cap = 6
    # full segment + 4 inserts -> overflow 4, tail live keys dropped
    key = jnp.asarray([[10, 20, 30, 40, 50, 60]], jnp.int32)
    prow = jnp.arange(6, dtype=jnp.int32)[None]
    tid = jnp.arange(1, 7, dtype=jnp.uint32)[None]
    dels = jnp.full((1, 2), SENTINEL, jnp.int32)
    ins = jnp.asarray([[5, 15, 25, 35]], jnp.int32)
    ipr = jnp.asarray([[9, 9, 9, 9]], jnp.int32)
    itd = jnp.asarray([[7, 7, 7, 7]], jnp.uint32)
    ref = jax.vmap(segment_merge_ref)(key, prow, tid, dels, ins, ipr, itd)
    out = index_merge(key, prow, tid, dels, ins, ipr, itd, use_pallas=True)
    assert _tree_equal(tuple(ref), tuple(out))
    assert int(out[3][0]) == 4

    # all-SENTINEL segment: inserts land from slot 0
    empty = jnp.full((1, cap), SENTINEL, jnp.int32)
    z = jnp.zeros((1, cap), jnp.int32)
    zt = jnp.zeros((1, cap), jnp.uint32)
    ref = jax.vmap(segment_merge_ref)(empty, z, zt, dels, ins, ipr, itd)
    out = index_merge(empty, z, zt, dels, ins, ipr, itd, use_pallas=True)
    assert _tree_equal(tuple(ref), tuple(out))
    assert int(out[0][0, 0]) == 5 and int(out[3][0]) == 0

    # delete-only (Ki == 0 pad path) and insert-only (Kd == 0 pad path)
    d2 = jnp.asarray([[20, 40]], jnp.int32)
    e_i = jnp.zeros((1, 0), jnp.int32)
    out = index_merge(key, prow, tid, d2, e_i, e_i, e_i.astype(jnp.uint32),
                      use_pallas=True)
    ref = jax.vmap(segment_merge_ref)(
        key, prow, tid, d2, jnp.full((1, 1), SENTINEL, jnp.int32),
        jnp.zeros((1, 1), jnp.int32), jnp.zeros((1, 1), jnp.uint32))
    assert _tree_equal(tuple(ref), tuple(out))
    e_d = jnp.zeros((1, 0), jnp.int32)
    out = index_merge(key, prow, tid, e_d, ins, ipr, itd, use_pallas=True)
    ref = jax.vmap(segment_merge_ref)(
        key, prow, tid, jnp.full((1, 1), SENTINEL, jnp.int32), ins, ipr, itd)
    assert _tree_equal(tuple(ref), tuple(out))


def test_index_merge_vmapped_tpcc_scale():
    """A TPC-C-sized segment batch (cap=11520) under jax.vmap over the
    pallas dispatch — the shape the ORDER-LINE index replays at."""
    from repro.kernels.index_merge.ops import index_merge
    from repro.kernels.index_merge.ref import segment_merge_ref
    rng = np.random.default_rng(7)
    P, cap, Kd, Ki = 4, 11520, 16, 16
    batches = [_random_merge_batch(rng, P, cap, Kd, Ki, key_space=50_000)
               for _ in range(2)]
    stacked = tuple(jnp.stack([b[i] for b in batches]) for i in range(7))
    ref = jax.vmap(lambda *a: jax.vmap(segment_merge_ref)(*a))(*stacked)
    out = jax.vmap(lambda *a: index_merge(*a, use_pallas=True))(*stacked)
    assert _tree_equal(tuple(ref), tuple(out))


# ---------------------------------------------------------------------------
# tiled OCC grids: forced multi-tile blocks == auto single-tile == oracle
# ---------------------------------------------------------------------------
@given(st.integers(0, 100_000))
@settings(max_examples=8, deadline=None)
def test_occ_round_tiled_grid_parity(seed):
    """The 3-launch pipeline with forced small blocks (multi-tile lock,
    lane and row grids, with padding remainders) matches the auto
    single-tile blocks bit-for-bit, Silo and deterministic modes both."""
    from repro.kernels.occ.kernel import occ_round_pallas
    rng = np.random.default_rng(seed)
    N, B, Mm = 37, 11, 4
    val = jnp.asarray(rng.integers(0, 100, (N, C)), jnp.int32)
    tidw = jnp.asarray(rng.integers(0, 50, (N,)), jnp.uint32)
    rows = jnp.asarray(
        np.stack([rng.choice(N, Mm, replace=False) for _ in range(B)]),
        jnp.int32)
    kind = jnp.asarray(rng.integers(0, 4, (B, Mm)), jnp.int32)
    delta = jnp.asarray(rng.integers(-3, 3, (B, Mm, C)), jnp.int32)
    wmask = jnp.asarray(rng.random((B, Mm)) < 0.5)
    amask = wmask | jnp.asarray(rng.random((B, Mm)) < 0.5)
    active = jnp.asarray(rng.random((B,)) < 0.8)
    epoch_arr = jnp.asarray([3], jnp.uint32)
    last_tid = jnp.asarray(rng.integers(0, 50, (B,)), jnp.uint32)
    K, L1, S = 3, 4, 20
    NT = N + S
    ix = (jnp.asarray(rng.integers(N, NT, (B, K)), jnp.int32),
          jnp.asarray(rng.integers(0, 50, (B, K)), jnp.uint32),
          jnp.asarray(rng.integers(N, NT + 1, (B, K, L1)), jnp.int32),
          jnp.asarray(rng.integers(0, 50, (B, K, L1)), jnp.uint32),
          jnp.asarray(rng.random((B, K, L1)) < 0.5),
          jnp.asarray(rng.random((B, K)) < 0.5))
    args = (val, tidw, rows, kind, delta, wmask, amask, active, epoch_arr,
            last_tid)
    for det in (False, True):
        for ixa, nt in ((None, N), (ix, NT)):
            base = occ_round_pallas(*args, ixa, NT=nt, deterministic=det)
            tiled = occ_round_pallas(*args, ixa, NT=nt, deterministic=det,
                                     block_nt=8, block_b=4, block_rows=16)
            assert all(bool(jnp.array_equal(a, b))
                       for a, b in zip(base, tiled)), (det, nt)


def test_scan_window_block_q_parity():
    """Query-block grid (scalar-prefetched probe streams) with a padded
    remainder block matches the single-tile launch."""
    from repro.kernels.occ.kernel import scan_window_pallas
    rng = np.random.default_rng(3)
    S, Q = 64, 13
    fk = jnp.sort(jnp.asarray(rng.integers(0, 1000, (S,)), jnp.int32))
    ft = jnp.asarray(rng.integers(0, 50, (S,)), jnp.uint32)
    q = jnp.asarray(rng.integers(0, 1000, (Q,)), jnp.int32)
    sb = jnp.zeros((Q,), jnp.int32)
    sc = jnp.full((Q,), S, jnp.int32)
    a = scan_window_pallas(fk, ft, q, sb, sc, n_slots=3, n_iters=7)
    b = scan_window_pallas(fk, ft, q, sb, sc, n_slots=3, n_iters=7,
                           block_q=4)
    assert _tree_equal(tuple(a), tuple(b))
