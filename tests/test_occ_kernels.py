"""Fused Pallas OCC kernels (interpret mode) vs the jnp ref.py oracle.

Bit-for-bit parity on random op batches — including lock-conflict
interleavings (many lanes claiming the same rows) and phantom-abort
interleavings (inserts landing inside concurrently scanned ranges) — for:

* the full single-master executor (``kernel="pallas"`` vs ``"jnp"``),
* ``locate_index_ops`` (searchsorted + SCAN_L window probe),
* the partitioned executor / ``step_index_ops``,
* ``segment_scan(use_pallas=True)`` and ``StorageEngine.range_scan``.

Property-driven via tests/_hyp.py (real hypothesis when installed, seeded
fallback otherwise), so tier-1 runs the sweep either way.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.ops import (DELETE_IDX, IDX_OPS, INSERT_IDX, IX_EXPECT,
                            IX_HI, IX_ID, IX_KEY, IX_PROW, SCAN_CONSUME,
                            SCAN_READ)
from repro.core.partitioned import run_partitioned
from repro.core.single_master import run_single_master
from repro.kernels.occ.ops import locate_index_ops, step_index_ops
from repro.storage import IndexSpec, SENTINEL, make_index, segment_scan
from repro.storage.index import full_key

C = 10
M = 24


def _tree_equal(a, b):
    eq = jax.tree.map(lambda x, y: bool(jnp.array_equal(x, y)), a, b)
    return all(jax.tree.leaves(eq))


def _random_index_workload(rng, B, P, n_rows, caps, conflict_rows):
    """Random txn batch mixing primary ops with scan/insert/delete/consume
    index ops over overlapping key ranges — lock conflicts on the primary
    rows (drawn from a small pool) AND phantom conflicts on the scans
    (inserts inside scanned ranges) arise by construction."""
    # per-txn rows are drawn WITHOUT replacement (the generators' documented
    # invariant: at most one op per row per txn — duplicate-row scatters
    # would be order-unspecified); the small shared pool still produces
    # dense cross-lane lock conflicts
    pool = max(conflict_rows, M)
    rows = np.stack([rng.choice(pool, M, replace=False)
                     for _ in range(B)]).astype(np.int32)
    kinds = rng.integers(0, 4, (B, M)).astype(np.int32)
    deltas = rng.integers(-50, 50, (B, M, C)).astype(np.int32)
    deltas[..., -1] = 0                        # guard column: unguarded
    index = [make_index(IndexSpec(f"ix{i}", c), P)
             for i, c in enumerate(caps)]
    # seed some live entries so scans/consumes/deletes have targets
    for i, c in enumerate(caps):
        n_seed = int(rng.integers(0, min(c, 6)))
        for _ in range(n_seed):
            p = int(rng.integers(0, P))
            k = int(full_key(p, int(rng.integers(0, 60))))
            pos = int(jnp.searchsorted(index[i]["key"][p], k))
            if pos < c and int(index[i]["key"][p, pos]) != k:
                key = index[i]["key"].at[p].set(
                    jnp.sort(index[i]["key"][p].at[c - 1].set(k)))
                index[i] = {"key": key, "prow": index[i]["prow"],
                            "tid": index[i]["tid"]}
    for b in range(B):
        for k in range(int(rng.integers(0, IDX_OPS // 2))):
            iid = int(rng.integers(0, len(caps)))
            p = int(rng.integers(0, P))
            base = int(full_key(p, 0))
            r = rng.random()
            deltas[b, k] = 0
            if r < 0.35:
                kinds[b, k] = INSERT_IDX
                deltas[b, k, IX_KEY] = base + int(rng.integers(0, 60))
                deltas[b, k, IX_PROW] = int(rng.integers(0, n_rows))
            elif r < 0.6:
                kinds[b, k] = SCAN_READ
                lo = base + int(rng.integers(0, 40))
                deltas[b, k, IX_KEY] = lo
                deltas[b, k, IX_HI] = lo + int(rng.integers(1, 40))
            elif r < 0.8:
                kinds[b, k] = SCAN_CONSUME
                deltas[b, k, IX_KEY] = base
                deltas[b, k, IX_HI] = base + 60
                deltas[b, k, IX_EXPECT] = base + int(rng.integers(0, 60))
                rows[b, k] = pool + k      # tombstone row, txn-unique
            else:
                kinds[b, k] = DELETE_IDX
                deltas[b, k, IX_KEY] = base + int(rng.integers(0, 60))
            deltas[b, k, IX_ID] = iid
    txns = {"valid": rng.random(B) < 0.95, "row": rows, "kind": kinds,
            "delta": deltas, "user_abort": rng.random(B) < 0.1}
    return jax.tree.map(jnp.asarray, txns), index


@given(st.integers(0, 100_000))
@settings(max_examples=12, deadline=None)
def test_single_master_pallas_parity_random(seed):
    """Full executor parity: state, logs, stats, index — conflicts and
    phantom interleavings included."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(2, 12))
    P = int(rng.integers(1, 3))
    caps = [int(rng.integers(4, 20)) for _ in range(int(rng.integers(1, 3)))]
    n_rows = 64 * P
    txns, index = _random_index_workload(rng, B, P, n_rows, caps,
                                         conflict_rows=n_rows // 4)
    val0 = jnp.asarray(rng.integers(0, 50, (n_rows, C)), jnp.int32)
    tid0 = jnp.asarray(rng.integers(0, 5, n_rows).astype(np.uint32) * 2)
    outs = {}
    for kern in ("jnp", "pallas"):
        outs[kern] = run_single_master(
            val0, tid0, txns, jnp.uint32(2), max_rounds=4,
            index=[dict(i) for i in index], kernel=kern)
    (v1, t1, o1, s1), (v2, t2, o2, s2) = outs["jnp"], outs["pallas"]
    assert jnp.array_equal(v1, v2) and jnp.array_equal(t1, t2)
    assert _tree_equal(o1, o2)
    assert _tree_equal(s1, s2)


@given(st.integers(0, 100_000))
@settings(max_examples=10, deadline=None)
def test_locate_index_ops_parity(seed):
    rng = np.random.default_rng(seed)
    B, P = int(rng.integers(1, 8)), int(rng.integers(1, 4))
    caps = [int(rng.integers(4, 24)) for _ in range(int(rng.integers(1, 4)))]
    n_rows = 32 * P
    txns, index = _random_index_workload(rng, B, P, n_rows, caps,
                                         conflict_rows=8)
    K = min(IDX_OPS, M)
    a = locate_index_ops(index, txns["kind"][:, :K], txns["delta"][:, :K],
                         n_rows, kernel="jnp")
    b = locate_index_ops(index, txns["kind"][:, :K], txns["delta"][:, :K],
                         n_rows, kernel="pallas")
    assert a["no_addr"] == b["no_addr"]
    assert _tree_equal({k: v for k, v in a.items() if k != "no_addr"},
                       {k: v for k, v in b.items() if k != "no_addr"})


def test_phantom_abort_parity():
    """The canonical phantom interleaving (insert into a concurrently
    scanned range) produces identical abort/commit rounds on both paths."""
    index = [make_index(IndexSpec("ix", 16), 1)]
    rows = np.zeros((2, M), np.int32)
    kinds = np.full((2, M), 0, np.int32)
    deltas = np.zeros((2, M, C), np.int32)
    kinds[0, 0] = INSERT_IDX
    deltas[0, 0, IX_KEY] = 50
    deltas[0, 0, IX_PROW] = 3
    kinds[1, 0] = SCAN_READ
    deltas[1, 0, IX_KEY] = 0
    deltas[1, 0, IX_HI] = 100
    txns = jax.tree.map(jnp.asarray, {
        "valid": np.ones(2, bool), "row": rows, "kind": kinds,
        "delta": deltas, "user_abort": np.zeros(2, bool)})
    val0 = jnp.zeros((64, C), jnp.int32)
    tid0 = jnp.zeros((64,), jnp.uint32)
    res = {}
    for kern in ("jnp", "pallas"):
        res[kern] = run_single_master(val0, tid0, txns, jnp.uint32(1),
                                      max_rounds=3,
                                      index=[dict(index[0])], kernel=kern)
    o1, o2 = res["jnp"][2], res["pallas"][2]
    assert _tree_equal(o1, o2)
    # and the phantom really aborted the scanner in round 0 on both
    assert int(np.asarray(o1["committed_round"])[1]) > 0


@given(st.integers(0, 100_000))
@settings(max_examples=8, deadline=None)
def test_partitioned_pallas_parity_random(seed):
    rng = np.random.default_rng(seed)
    P, T = int(rng.integers(1, 4)), int(rng.integers(1, 5))
    caps = [int(rng.integers(4, 16))]
    R = 64
    txns, index = _random_index_workload(rng, P * T, P, R, caps,
                                         conflict_rows=R // 2)
    ptxn = {k: jnp.asarray(np.asarray(v).reshape((P, T) + v.shape[1:]))
            for k, v in txns.items()}
    # rows are partition-local in the partitioned executor
    val0 = jnp.asarray(rng.integers(0, 50, (P, R, C)), jnp.int32)
    tid0 = jnp.zeros((P, R), jnp.uint32)
    outs = {}
    for kern in ("jnp", "pallas"):
        outs[kern] = run_partitioned(val0, tid0, ptxn, jnp.uint32(1),
                                     index=[dict(i) for i in index],
                                     kernel=kern)
    (v1, t1, o1, s1), (v2, t2, o2, s2) = outs["jnp"], outs["pallas"]
    assert jnp.array_equal(v1, v2) and jnp.array_equal(t1, t2)
    assert _tree_equal(o1, o2)
    assert _tree_equal(s1, s2)


@given(st.integers(0, 100_000))
@settings(max_examples=10, deadline=None)
def test_step_index_ops_parity(seed):
    rng = np.random.default_rng(seed)
    P = int(rng.integers(1, 5))
    caps = [int(rng.integers(4, 24)) for _ in range(int(rng.integers(1, 3)))]
    txns, index = _random_index_workload(rng, P, P, 32, caps,
                                         conflict_rows=8)
    K = min(IDX_OPS, M)
    a = step_index_ops(index, txns["kind"][:, :K], txns["delta"][:, :K],
                       kernel="jnp")
    b = step_index_ops(index, txns["kind"][:, :K], txns["delta"][:, :K],
                       kernel="pallas")
    assert _tree_equal(a, b)


@given(st.integers(0, 100_000), st.integers(0, 80), st.integers(1, 60))
@settings(max_examples=15, deadline=None)
def test_segment_scan_pallas_parity(seed, lo, width):
    rng = np.random.default_rng(seed)
    cap = int(rng.integers(4, 64))
    n_live = int(rng.integers(0, cap))
    keys = np.full(cap, SENTINEL, np.int32)
    keys[:n_live] = np.sort(rng.choice(100, n_live, replace=False))
    a = segment_scan(jnp.asarray(keys), jnp.int32(lo), jnp.int32(lo + width))
    b = segment_scan(jnp.asarray(keys), jnp.int32(lo), jnp.int32(lo + width),
                     use_pallas=True)
    assert _tree_equal(tuple(a), tuple(b))


def test_storage_engine_range_scan_pallas():
    from repro.storage import StorageEngine
    eng = StorageEngine(2, 8, n_cols=4, index_specs=[IndexSpec("ix", 16)])
    idx = eng.indexes[0]
    idx["key"] = idx["key"].at[1, 0].set((1 << 24) | 7)
    idx["prow"] = idx["prow"].at[1, 0].set(5)
    a = eng.range_scan("ix", 1, (1 << 24) | 0, (1 << 24) | 100)
    b = eng.range_scan("ix", 1, (1 << 24) | 0, (1 << 24) | 100,
                       use_pallas=True)
    assert _tree_equal(tuple(a), tuple(b))
    assert bool(b[3][0]) and int(b[0][0]) == ((1 << 24) | 7)
