"""Distributed cluster runtime (8 forced host devices, subprocess):
sharded TxnService over the mesh, live failure injection, §4.5 recovery.

Each test boots a 4-node mesh (ppn=2: 8 partitions on 4 devices) in a
subprocess with forced host devices, exactly like tests/test_cluster_router,
and drives the ClusterRuntime — revert at the fence, RecoveryCase
classification, donor copy / full-replica rebuild / disk reload — asserting
``replica_consistent()`` at every fence after recovery.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_runtime_parity_and_case1_failover():
    """ClusterRuntime (ppn=2) matches StarEngine commit counts; killing one
    partial node mid-run classifies PHASE_SWITCHING, restores the node's
    block from the full replica (a real donor copy — the block was
    scribbled), and the replicas are bit-identical at the next fence."""
    out = _run("""
        import jax, numpy as np
        from repro.cluster import ClusterRuntime
        from repro.core.engine import StarEngine
        from repro.core.fault import FaultInjector, RecoveryCase
        from repro.db import ycsb
        cfg = ycsb.YCSBConfig(n_partitions=8, records_per_partition=128)
        mesh = jax.make_mesh((4,), ("part",), devices=jax.devices()[:4])
        inj = FaultInjector(); inj.schedule_kill(2, epoch=3)
        rt = ClusterRuntime(mesh, 8, 128, injector=inj)
        eng = StarEngine(8, 128)
        events = []
        for ep in range(5):
            batch = ycsb.make_batch(cfg, 128, seed=ep)
            mc = rt.run_epoch(batch)
            ms = eng.run_epoch(batch)
            assert mc["committed_single"] == ms["committed_single"], (ep, mc, ms)
            assert mc["committed_cross"] == ms["committed_cross"], (ep, mc, ms)
            assert rt.replica_consistent(), ep
            if "recovery" in mc: events.append(mc["recovery"])
        assert np.array_equal(np.asarray(rt.eng.full_val),
                              np.asarray(eng.master["val"]))
        [ev] = events
        assert ev.case is RecoveryCase.PHASE_SWITCHING, ev
        assert ev.run_mode == "star" and ev.failed == (2,)
        assert ev.t_recovery_s > 0 and ev.reverted_to == 2
        assert rt.coordinator.view >= 3      # failure + rejoin reconfigs
        assert inj.killed == set()           # node rejoined
        print("OK case1", round(ev.t_recovery_s * 1e3, 1), "ms")
    """)
    assert "OK case1" in out


def test_runtime_unavailable_reloads_from_disk():
    """Killing the full-replica node plus both homes of a partition block
    leaves neither a full replica nor a complete partial set: UNAVAILABLE.
    The runtime reloads checkpoint + per-node logs from disk (the blocks
    and the full copy were scribbled — only the disk bytes can be the
    source) and resumes bit-identical."""
    out = _run("""
        import jax, numpy as np, tempfile
        from repro.cluster import ClusterRuntime
        from repro.core.fault import FaultInjector, RecoveryCase
        from repro.db import ycsb
        from repro.db.wal import Durability
        cfg = ycsb.YCSBConfig(n_partitions=8, records_per_partition=128)
        mesh = jax.make_mesh((4,), ("part",), devices=jax.devices()[:4])
        inj = FaultInjector()
        for n in (0, 1, 2): inj.schedule_kill(n, epoch=4)
        with tempfile.TemporaryDirectory() as d:
            dur = Durability(d, n_workers=4, checkpoint_every=2)
            rt = ClusterRuntime(mesh, 8, 128, injector=inj, durability=dur)
            events = []
            for ep in range(6):
                m = rt.run_epoch(ycsb.make_batch(cfg, 128, seed=ep))
                assert rt.replica_consistent(), ep
                if "recovery" in m: events.append(m["recovery"])
            [ev] = events
            assert ev.case is RecoveryCase.UNAVAILABLE, ev
            assert ev.reloaded_from_disk and ev.run_mode == "halt"
            assert set(ev.lost_blocks) == {0, 1}
            assert dur.checkpoints >= 1 and dur.entries_logged > 0
            print("OK unavailable", round(ev.t_recovery_s * 1e3), "ms")
    """)
    assert "OK unavailable" in out


def test_cluster_service_node_sharded_with_failure():
    """The online service over the mesh: node-sharded admission (per-node
    queue caps), double-buffered batching into shard_map, a mid-run node
    kill recovered live, and per-node telemetry in the summary."""
    out = _run("""
        import jax, numpy as np
        from repro.cluster import ClusterRuntime, ClusterTxnService
        from repro.core.fault import FaultInjector
        from repro.db import ycsb
        from repro.service import AdmissionConfig, OpenLoopClient, YCSBSource
        cfg = ycsb.YCSBConfig(n_partitions=8, records_per_partition=128)
        mesh = jax.make_mesh((4,), ("part",), devices=jax.devices()[:4])
        inj = FaultInjector(); inj.schedule_kill(3, epoch=6)
        rt = ClusterRuntime(mesh, 8, 128, injector=inj)
        client = OpenLoopClient(YCSBSource(cfg, seed=1), rate_txn_s=800.0,
                                seed=7)
        svc = ClusterTxnService(rt, [client],
                                AdmissionConfig(64, 64, node_queue_cap=96),
                                slots_per_partition=16, master_lanes=16)
        out = svc.run(duration_s=1.0)
        assert rt.replica_consistent()
        assert out["committed"] > 0
        assert out["recoveries"] == 1 and out["recovery_latency_s"][0] > 0
        assert len(out["node_committed"]) == 4
        assert sum(out["node_committed"]) == rt.stats.committed_single
        assert len(out["node_queue_depth_max"]) == 4
        assert len(out["node_fence_wait_s"]) == 4
        print("OK service", out["committed"], out["recovery_latency_s"])
    """)
    assert "OK service" in out
