"""Gradient compression (error feedback) + TPC-C semantic invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.compression import (CompressedAllReduce, int8_decode,
                                     int8_encode, topk_decode, topk_encode)


def test_topk_roundtrip_exact_on_sparse():
    g = jnp.zeros((1000,)).at[jnp.asarray([3, 500, 999])].set(
        jnp.asarray([5.0, -2.0, 1.0]))
    idx, vals, shape = topk_encode(g, frac=0.003)
    out = topk_decode(idx, vals, shape, jnp.float32)
    assert jnp.allclose(out, g)


def test_int8_bounded_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(4096), jnp.float32)
    q, s = int8_encode(g)
    out = int8_decode(q, s, jnp.float32)
    assert float(jnp.max(jnp.abs(out - g))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_converges():
    """Sum of compressed messages approaches the sum of true gradients —
    error feedback ships the residual eventually (no information is lost)."""
    rng = np.random.default_rng(1)
    comp = CompressedAllReduce("topk", frac=0.05)
    true_sum = np.zeros(256, np.float32)
    sent_sum = np.zeros(256, np.float32)
    g = jnp.asarray(rng.standard_normal(256), jnp.float32)  # constant grad
    for _ in range(120):
        true_sum += np.asarray(g)
        sent_sum += np.asarray(comp({"w": g})["w"])
    # error feedback bounds the lag to ~1/frac rounds' worth of gradient
    rel = np.linalg.norm(sent_sum - true_sum) / np.linalg.norm(true_sum)
    assert rel < 0.2, rel
    lag = np.linalg.norm(sent_sum - true_sum) / np.linalg.norm(np.asarray(g))
    assert lag < 1.5 / comp.frac, lag
    assert comp.stats.ratio > 5.0          # ~20x fewer bytes at frac=5%


def test_trainer_step_with_compression_trains():
    from repro.configs import get_arch
    from repro.data import make_batch
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as tf
    from repro.train.compression import CompressedAllReduce
    from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state
    cfg = get_arch("mamba2-130m", smoke=True)
    params = tf.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    comp = CompressedAllReduce("int8")
    losses = []
    for step in range(6):
        batch = make_batch(cfg, "train", 64, 4, seed=step)
        (loss, _), grads = jax.value_and_grad(
            lambda p: tf.loss_fn(p, batch, cfg), has_aux=True)(params)
        grads = comp(grads)
        params, opt, _ = adamw_update(params, grads, opt,
                                      AdamWConfig(lr=1e-3, warmup_steps=2))
        losses.append(float(loss))
    assert np.isfinite(losses).all() and losses[-1] < losses[0]
    # bf16 grads -> int8: 2x; fp32 grads would give 4x
    assert comp.stats.ratio > 1.9


# ---------------------------------------------------------------------------
# TPC-C semantic invariants through the full engine
# ---------------------------------------------------------------------------
def test_tpcc_invariants_after_epochs():
    from repro.core.engine import StarEngine
    from repro.db import tpcc
    cfg = tpcc.TPCCConfig(n_partitions=2, n_items=500, cust_per_district=50,
                          order_ring=128, neworder_abort=0.0)
    state = tpcc.TPCCState(cfg)
    rng = np.random.default_rng(0)
    init = tpcc.init_values(cfg, rng)
    eng = StarEngine(cfg.n_partitions, cfg.rows_per_partition, init_val=init)
    n_neworder = 0
    for ep in range(3):
        batch = tpcc.make_batch(cfg, state, 200, seed=ep)
        m = eng.run_epoch(batch)
        n_neworder += (m["committed_single"] + m["committed_cross"] + 1) // 2
    val = np.asarray(eng.master["val"])

    # (1) district next_o_id advanced exactly once per committed NewOrder
    d = val[:, cfg.off_district:cfg.off_district + tpcc.N_DIST, 0]
    assert int((d - 3001).sum()) == int(state.next_o_id.sum() - 3001 * 2 * tpcc.N_DIST)

    # (2) money conservation: sum(w_ytd) == sum(d_ytd) == sum paid by customers
    w_ytd = val[:, cfg.off_warehouse, 0].astype(np.int64).sum()
    d_ytd = val[:, cfg.off_district:cfg.off_district + tpcc.N_DIST, 1].astype(np.int64).sum()
    cust = val[:, cfg.off_customer:cfg.off_customer
               + tpcc.N_DIST * cfg.cust_per_district]
    c_paid = cust[:, :, 3].astype(np.int64).sum()
    assert w_ytd == d_ytd == c_paid

    # (3) customer balance decreased by exactly the total paid
    c_bal = cust[:, :, 2].astype(np.int64).sum()
    assert c_bal == -c_paid

    # (4) stock ytd equals total quantity ordered; order_cnt counts line items
    stock = val[:, cfg.off_stock:cfg.off_stock + cfg.n_items]
    assert stock[:, :, 1].sum() >= stock[:, :, 2].sum()   # qty >= 1 per line

    # (5) replica still bit-identical
    assert eng.replica_consistent()
