"""Distributed cluster engine (shard_map, 8 devices) + the §4.3 router.

core/cluster.py drives the mesh through ``repro.compat.shard_map``, which
resolves to ``jax.shard_map`` (newer jax) or ``jax.experimental.shard_map``
(the pinned container's 0.4.x) — these tests run, not skip, on both.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.router import Router
from repro.core.ops import ADD, READ, SET

SRC = str(Path(__file__).resolve().parents[1] / "src")

def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_cluster_engine_8dev_matches_single_process():
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core.cluster import ClusterStarEngine
        from repro.core.engine import StarEngine
        from repro.db import ycsb
        cfg = ycsb.YCSBConfig(n_partitions=8, records_per_partition=256)
        mesh = jax.make_mesh((8,), ("part",))
        eng_c = ClusterStarEngine(mesh, 8, 256)
        eng_s = StarEngine(8, 256)
        for ep in range(2):
            batch = ycsb.make_batch(cfg, 192, seed=ep)
            mc = eng_c.run_epoch(batch)
            ms = eng_s.run_epoch(batch)
            assert mc["committed_single"] == ms["committed_single"], (mc, ms)
            assert mc["committed_cross"] == ms["committed_cross"], (mc, ms)
        assert eng_c.consistent(), "partial vs full replica mismatch"
        # state equality across implementations
        assert np.array_equal(np.asarray(eng_c.full_val),
                              np.asarray(eng_s.master["val"]))
        print("OK cluster==single", mc)
    """)
    assert "OK cluster==single" in out


def test_partitioned_phase_zero_collectives_8dev():
    """Compile-time proof of the paper's §4.1 claim on a real 8-way mesh."""
    out = _run("""
        import jax
        from repro.core.cluster import ClusterStarEngine
        from repro.db import ycsb
        cfg = ycsb.YCSBConfig(n_partitions=8, records_per_partition=128)
        mesh = jax.make_mesh((8,), ("part",))
        eng = ClusterStarEngine(mesh, 8, 128)
        batch = ycsb.make_batch(cfg, 128, seed=0)
        assert eng.partitioned_phase_has_no_collectives(batch)
        print("OK zero collectives")
    """)
    assert "OK zero collectives" in out


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------
def _mk_txn(parts_list, M=4, C=10):
    B = len(parts_list)
    parts = np.zeros((B, M), np.int32)
    rows = np.zeros((B, M), np.int32)
    kinds = np.full((B, M), READ, np.int32)
    deltas = np.zeros((B, M, C), np.int32)
    for i, ps in enumerate(parts_list):
        for j, p in enumerate(ps):
            parts[i, j] = p
            rows[i, j] = j
            kinds[i, j] = SET if j == 0 else READ
        parts[i, len(ps):] = ps[0]
    return parts, rows, kinds, deltas


def test_router_classifies_and_routes():
    r = Router(n_partitions=4, rows_per_partition=100, max_ops=4)
    parts, rows, kinds, deltas = _mk_txn(
        [[0, 0, 0], [1, 1], [2, 3], [0, 2, 3], [3, 3, 3]])
    batch = r.route(parts, rows, kinds, deltas)
    assert batch["n_single"] == 3 and batch["n_cross"] == 2
    assert r.stats.singles == 3 and r.stats.cross == 2
    # cross rows are globalized: partition * R + row
    assert (batch["cross"]["row"] // 100 == parts[[2, 3]]).all()
    # singles landed on their home partitions
    assert batch["ptxn"]["valid"][0].sum() == 1
    assert batch["ptxn"]["valid"][1].sum() == 1
    assert batch["ptxn"]["valid"][3].sum() == 1


def test_router_reroute_misdeclared_single():
    """§4.3 re-route: txns declared single-partition but touching a remote
    partition are detected, sent to the master (cross) queue, and counted."""
    r = Router(n_partitions=4, rows_per_partition=100, max_ops=4)
    parts, rows, kinds, deltas = _mk_txn(
        [[0, 0, 0],      # honest single
         [1, 1, 2],      # declared single on 1, touches 2 -> re-route
         [2, 3]])        # honest cross, undeclared
    declared = np.array([0, 1, -1])
    is_cross, home = r.classify(parts, kinds, declared)
    assert is_cross.tolist() == [False, True, True]
    assert r.stats.rerouted == 1
    # and through route(): the re-routed txn lands in the master queue
    r2 = Router(n_partitions=4, rows_per_partition=100, max_ops=4)
    batch = r2.route(parts, rows, kinds, deltas, declared_home=declared)
    assert batch["n_single"] == 1 and batch["n_cross"] == 2
    assert r2.stats.rerouted == 1


def test_router_feeds_engine():
    from repro.core.engine import StarEngine
    rng = np.random.default_rng(0)
    r = Router(n_partitions=4, rows_per_partition=64, max_ops=4)
    B = 64
    home = rng.integers(0, 4, B)
    parts = np.repeat(home[:, None], 4, 1).astype(np.int32)
    cross = rng.random(B) < 0.3
    parts[cross, 1] = (parts[cross, 1] + 1) % 4
    rows = np.stack([rng.choice(64, 4, replace=False) for _ in range(B)]
                    ).astype(np.int32)
    kinds = rng.integers(0, 3, (B, 4)).astype(np.int32)
    deltas = rng.integers(-5, 5, (B, 4, 10)).astype(np.int32)
    batch = r.route(parts, rows, kinds, deltas)
    eng = StarEngine(4, 64)
    m = eng.run_epoch(batch)
    assert m["committed_single"] == batch["n_single"]
    assert m["committed_cross"] == batch["n_cross"]
    assert eng.replica_consistent()
