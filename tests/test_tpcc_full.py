"""Full five-transaction TPC-C mix end-to-end (45/43/4/4/4).

The acceptance bar for the storage-engine refactor: the full mix runs
through ``StarEngine.run_epoch`` with ``replica_consistent()`` (records AND
indexes) holding at every fence; Delivery consumes the oldest undelivered
NEW-ORDER through an index range scan (device/host undelivered sets stay
equal, oldest-first); and the money adds up — every customer balance delta
equals delivered order amounts minus payment debits (an economic invariant
that fails if any scan consumed the wrong order or any guard misfired).
"""
import numpy as np
import pytest

from repro.core.engine import StarEngine
from repro.core.ops import PAY_CUST
from repro.db import tpcc
from repro.storage import SENTINEL


def _mk(n_partitions, **kw):
    cfg = tpcc.TPCCConfig(n_partitions=n_partitions, n_items=400,
                          cust_per_district=40, order_ring=64, mix="full",
                          delivery_gen_lag=256, **kw)
    state = tpcc.TPCCState(cfg)
    rng = np.random.default_rng(7)
    init = tpcc.init_values(cfg, rng, state=state)
    eng = StarEngine(cfg.n_partitions, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg))
    return cfg, state, eng, init


def _live(index_arrays):
    return np.asarray(index_arrays["key"]) != SENTINEL


def test_full_mix_replica_consistent_every_fence():
    cfg, state, eng, _ = _mk(2)
    for ep in range(5):
        batch = tpcc.make_batch(cfg, state, 192, seed=ep)
        m = eng.run_epoch(batch)
        assert eng.replica_consistent(), f"replica diverged at epoch {ep}"
        assert m["committed_single"] > 0
    assert eng.stats.committed_cross > 0, "cross NewOrder/Payment exercised"
    # all three indexes were populated and maintained
    for i in range(3):
        assert _live(eng.store.indexes[i]).sum() > 0


def test_delivery_consumes_oldest_via_index():
    """Device undelivered set == host queue, oldest-first, per district."""
    cfg, state, eng, _ = _mk(1)      # P=1: generation order == commit order
    for ep in range(6):
        eng.run_epoch(tpcc.make_batch(cfg, state, 256, seed=100 + ep))
        assert eng.replica_consistent()
    assert eng.stats.consume_skips == 0, \
        "single-partition full mix must never mispredict a consume"
    no = eng.store.indexes[tpcc.NO_IDX]
    keys = np.asarray(no["key"])[0]
    live = keys[keys != SENTINEL]
    host = []
    for d in range(tpcc.N_DIST):
        q = state.undelivered[0][d]
        # host queues are oldest-first: Delivery pops index 0
        assert [e[0] for e in q] == sorted(e[0] for e in q)
        host += [tpcc._key_no(0, d, o % (1 << tpcc.D_SHIFT))
                 for o, *_ in q]
    assert sorted(host) == sorted(int(k) for k in live), \
        "device undelivered index == host undelivered queues"
    n_orders = int(state.next_o_id.sum()) - 3001 * tpcc.N_DIST
    assert 0 < len(live) < n_orders, "some orders delivered, some pending"


def test_full_mix_money_conserved():
    """Σ customer balance deltas = Σ delivered amounts − Σ payment debits
    (P=1, so every transaction commits in generation order)."""
    cfg, state, eng, init = _mk(1)
    pay_total = 0
    for ep in range(6):
        raw = tpcc.make_raw(cfg, state, 256, np.random.default_rng(200 + ep))
        pay = raw["kinds"] == PAY_CUST
        pay_total += int(raw["deltas"][..., 3][pay].sum())   # ytd = +amount
        eng.run_epoch(tpcc.make_batch(cfg, state, 0, raw=raw))
        assert eng.replica_consistent()
    assert eng.stats.consume_skips == 0
    remaining = sum(a for wq in state.undelivered for q in wq
                    for _, _, a, _, _ in q)
    delivered = state.pushed_amount - remaining - state.evicted_amount
    cust = slice(cfg.off_customer,
                 cfg.off_customer + tpcc.N_DIST * cfg.cust_per_district)
    bal = np.asarray(eng.store.val)[0, cust, 2].astype(np.int64)
    init_bal = np.asarray(init)[0, cust, 2].astype(np.int64)
    assert int((bal - init_bal).sum()) == delivered - pay_total


def test_order_status_scan_finds_latest_order():
    cfg, state, eng, _ = _mk(1)
    for ep in range(3):
        eng.run_epoch(tpcc.make_batch(cfg, state, 256, seed=300 + ep))
    # pick a customer the host knows ordered recently (and not yet evicted)
    w = 0
    ring = cfg.order_ring
    cand = np.argwhere(state.last_o[w] >= 0)
    assert cand.size, "some customer ordered"
    d = c = o = None
    for dd, cc in cand:
        oo = int(state.last_o[w, dd, cc])
        if oo >= int(state.next_o_id[w, dd]) - ring:
            d, c, o = int(dd), int(cc), oo
    assert o is not None
    slot = o % ring
    keys, prows, tids, mask = eng.store.range_scan(
        "orders_by_cust", w, tpcc._key_cust(w, d, c, 0),
        tpcc._key_cust(w, d, c + 1, 0))
    m = np.asarray(mask)
    assert m.any(), "customer's retained orders are indexed"
    got_keys = set(int(k) for k in np.asarray(keys)[m])
    assert tpcc._key_cust(w, d, c, slot) in got_keys, \
        "the latest order's index entry is in the scanned range"
    i = list(np.asarray(keys)).index(tpcc._key_cust(w, d, c, slot))
    assert int(np.asarray(prows)[i]) == cfg.off_orders + d * ring + slot, \
        "scan resolves to the order's primary row"


def test_full_mix_failure_revert_keeps_indexes_consistent():
    cfg, state, eng, _ = _mk(2)
    eng.run_epoch(tpcc.make_batch(cfg, state, 192, seed=400))
    snap_keys = np.asarray(eng.store.indexes[0]["key"]).copy()
    eng.inject_failure({1})
    assert np.array_equal(np.asarray(eng.store.indexes[0]["key"]), snap_keys)
    eng.run_epoch(tpcc.make_batch(cfg, state, 192, seed=401))
    assert eng.replica_consistent()


def test_aborted_neworders_leak_no_index_entries():
    """Regression for DESIGN.md desync (a): user-aborted NewOrders used to
    strand their ring-eviction DELETE_IDX ops, leaking stale index entries.
    Now an aborted NewOrder draws no o_id and carries no index ops, so with
    a HIGH abort rate the live index contents still match the host mirror
    EXACTLY — under the shrunk (no longer 2x) index capacity, in strict
    overflow mode."""
    cfg = tpcc.TPCCConfig(n_partitions=1, n_items=400, cust_per_district=40,
                          order_ring=64, mix="full", delivery_gen_lag=256,
                          neworder_abort=0.3)
    state = tpcc.TPCCState(cfg)
    rng = np.random.default_rng(11)
    init = tpcc.init_values(cfg, rng, state=state)
    eng = StarEngine(cfg.n_partitions, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg), strict_index=True)
    aborted = 0
    for ep in range(6):
        batch = tpcc.make_batch(cfg, state, 256, seed=500 + ep)
        m = eng.run_epoch(batch)
        tpcc.apply_consume_feedback(state, batch, m)
        assert eng.replica_consistent()
    assert eng.stats.user_aborts > 20, "abort path exercised"
    assert eng.stats.consume_skips == 0
    assert eng.stats.index_overflow == 0
    ring = cfg.order_ring
    # neworder index == host undelivered queues, ZERO stale extras
    no_live = np.asarray(eng.store.indexes[tpcc.NO_IDX]["key"])[0]
    no_live = sorted(int(k) for k in no_live[no_live != SENTINEL])
    host = sorted(tpcc._key_no(0, d, o % (1 << tpcc.D_SHIFT))
                  for d in range(tpcc.N_DIST)
                  for o, *_ in state.undelivered[0][d])
    assert no_live == host
    # orders_by_id == exactly the retained committed orders per district:
    # every o_id in [next_o - ring, next_o) was committed (aborts draw none)
    oid_live = np.asarray(eng.store.indexes[tpcc.OID_IDX]["key"])[0]
    oid_live = sorted(int(k) for k in oid_live[oid_live != SENTINEL])
    expect = sorted(
        tpcc._key_no(0, d, o % (1 << tpcc.D_SHIFT))
        for d in range(tpcc.N_DIST)
        for o in range(max(3001, int(state.next_o_id[0, d]) - ring),
                       int(state.next_o_id[0, d])))
    assert oid_live == expect, "stale entries leaked by aborted NewOrders"
    # orders_by_cust carries exactly one entry per retained order too
    cust_live = np.asarray(eng.store.indexes[tpcc.CUST_IDX]["key"])[0]
    assert int((cust_live != SENTINEL).sum()) == len(expect)


def test_consume_skip_requeues_district():
    """A Delivery district skipped on EXPECT mismatch is fed back to the
    host mirror: the claimed order returns to the FRONT of the undelivered
    queue instead of being silently dropped (counted only)."""
    cfg, state, eng, _ = _mk(1)
    for ep in range(2):
        eng.run_epoch(tpcc.make_batch(cfg, state, 256, seed=600 + ep))
    # plant a prediction the device cannot satisfy: a bogus oldest order
    # (o_id 3000 predates the initial 3001, so its key is never indexed)
    d = next(d for d in range(tpcc.N_DIST) if state.undelivered[0][d])
    bogus = 3000
    state.undelivered[0][d].insert(0, (bogus, 0, 0, -10**9, False))
    skips0 = eng.stats.consume_skips
    requeued = 0
    for ep in range(4):
        batch = tpcc.make_batch(cfg, state, 256, seed=700 + ep)
        m = eng.run_epoch(batch)
        requeued += tpcc.apply_consume_feedback(state, batch, m)
        assert eng.replica_consistent()
        if requeued:
            break
    assert eng.stats.consume_skips > skips0, "mismatch produced a skip"
    assert requeued >= 1, "skipped district was re-queued, not just counted"
    assert state.undelivered[0][d][0][0] == bogus, \
        "the claimed order is back at the front of its district queue"


def test_index_capacity_shrunk_headroom():
    """The 2x abort-leak headroom is gone: capacity is one slot per
    retained order plus small starvation headroom."""
    cfg = tpcc.TPCCConfig(n_partitions=1, order_ring=64, mix="full")
    assert cfg.index_capacity < 2 * tpcc.N_DIST * cfg.order_ring
    assert cfg.index_capacity >= tpcc.N_DIST * cfg.order_ring
