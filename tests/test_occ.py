"""Serializability of the single-master OCC executor (§4.2, §4.4).

The witness order is (commit round, lane): replaying committed transactions
serially in that order must reproduce the executor's final database state —
for random conflicting workloads (hypothesis-driven).
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.ops import ADD, APPEND, READ, SET, apply_op
from repro.core.single_master import run_single_master

C = 6
M = 4


def _random_txns(rng, B, n_rows):
    # one op per row per txn (the generators' documented invariant)
    rows = np.stack([rng.choice(n_rows, M, replace=False) for _ in range(B)]
                    ).astype(np.int32)
    kinds = rng.integers(0, 4, (B, M)).astype(np.int32)
    deltas = rng.integers(-50, 50, (B, M, C)).astype(np.int32)
    return {
        "valid": np.ones(B, bool),
        "row": rows, "kind": kinds, "delta": deltas,
        "user_abort": np.zeros(B, bool),
    }


def _serial_replay(val, txns, order):
    val = np.array(val)
    for i in order:
        rows = txns["row"][i]
        old = jnp.asarray(val[rows])
        new = np.array(apply_op(jnp.asarray(txns["kind"][i]), old,
                                jnp.asarray(txns["delta"][i])))
        w = txns["kind"][i] > READ
        # later ops in the SAME txn see earlier ops' writes only if rows
        # differ; duplicates within a txn use the same pre-state (matches
        # the executor's gather-once semantics)
        val[rows[w]] = new[w]
    return val


@given(st.integers(0, 10_000), st.integers(4, 48), st.integers(4, 24))
@settings(max_examples=25, deadline=None)
def test_serializable(seed, B, n_rows):
    rng = np.random.default_rng(seed)
    txns = _random_txns(rng, B, n_rows)
    val0 = jnp.asarray(rng.integers(0, 100, (n_rows, C)), jnp.int32)
    tid0 = jnp.zeros((n_rows,), jnp.uint32)

    val, tidw, out, stats = run_single_master(
        val0, tid0, jax.tree.map(jnp.asarray, txns), jnp.uint32(1),
        max_rounds=B)
    committed = np.array(out["committed"])
    cround = np.array(out["committed_round"])
    assert committed.all(), "all txns must commit within B rounds"

    order = sorted(range(B), key=lambda i: (cround[i], i))
    expect = _serial_replay(val0, txns, order)
    assert np.array_equal(np.array(val), expect)


def test_conflicting_writers_one_per_round():
    """Two writers to the same row never commit in the same round."""
    txns = {
        "valid": np.ones(2, bool),
        "row": np.tile(np.arange(M, dtype=np.int32), (2, 1)),
        "kind": np.full((2, M), ADD, np.int32),
        "delta": np.ones((2, M, C), np.int32),
        "user_abort": np.zeros(2, bool),
    }
    val0 = jnp.zeros((4, C), jnp.int32)
    tid0 = jnp.zeros((4,), jnp.uint32)
    val, _, out, stats = run_single_master(
        val0, tid0, jax.tree.map(jnp.asarray, txns), jnp.uint32(1), max_rounds=4)
    cr = np.array(out["committed_round"])
    assert cr[0] != cr[1]
    assert int(stats["retries"]) >= 1
    assert np.array(out["committed"]).all()
    assert np.array_equal(np.array(val), np.full((4, C), 2))


def test_user_abort_skipped():
    txns = {
        "valid": np.ones(2, bool),
        "row": np.zeros((2, M), np.int32),
        "kind": np.full((2, M), SET, np.int32),
        "delta": np.ones((2, M, C), np.int32),
        "user_abort": np.array([True, False]),
    }
    val0 = jnp.zeros((2, C), jnp.int32)
    val, _, out, stats = run_single_master(
        val0, jnp.zeros((2,), jnp.uint32), jax.tree.map(jnp.asarray, txns),
        jnp.uint32(1), max_rounds=2)
    assert int(stats["user_aborts"]) == 1
    assert not bool(out["committed"][0]) and bool(out["committed"][1])


def test_deterministic_calvin_mode_no_retries():
    rng = np.random.default_rng(7)
    txns = _random_txns(rng, 16, 8)
    val0 = jnp.zeros((8, C), jnp.int32)
    val, _, out, stats = run_single_master(
        val0, jnp.zeros((8,), jnp.uint32), jax.tree.map(jnp.asarray, txns),
        jnp.uint32(1), max_rounds=16, deterministic=True)
    assert np.array(out["committed"]).all()
    # deterministic order == lane order: replay matches
    order = sorted(range(16), key=lambda i: (np.array(out["committed_round"])[i], i))
    expect = _serial_replay(val0, txns, order)
    assert np.array_equal(np.array(val), expect)
