"""Per-arch smoke tests (task requirement): reduced config of each family,
one forward/train step on CPU, assert output shapes + no NaNs; decode for
autoregressive archs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_arch
from repro.data import make_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_fn
from repro.models import (decode_step, forward, init_params, loss_fn, prefill)
from repro.train.optimizer import init_opt_state

S, B = 64, 2


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch, mesh):
    cfg = get_arch(arch, smoke=True)
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, "train", S, B)

    logits, _, _, _ = forward(params, batch, cfg)
    n_text = batch["labels"].shape[1] if "labels" in batch else S
    assert logits.shape[0] == B and logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    step = jax.jit(make_train_fn(cfg, mesh))
    opt = init_opt_state(params)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(new_opt["step"]) == 1
    # params actually moved
    moved = any(
        not jnp.array_equal(a, b)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if not get_arch(a, smoke=True).is_encoder])
def test_prefill_decode(arch):
    cfg = get_arch(arch, smoke=True)
    params = init_params(cfg, jax.random.key(0))
    pb = make_batch(cfg, "prefill", S, B)
    logits, cache = jax.jit(
        lambda p, b: prefill(p, b, cfg, alloc_len=S + 8))(params, pb)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t: decode_step(p, c, t, cfg))(params, cache, tok)
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1
    # pad-vocab logits are masked out of sampling
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(jnp.max(logits2[..., cfg.vocab_size:])) <= -1e29
