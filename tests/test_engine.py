"""End-to-end engine behaviour: epochs, replicas, hybrid bytes, faults."""
import numpy as np
import pytest

from repro.core.engine import StarEngine
from repro.core.fault import ClusterConfig, RecoveryCase, classify_failure
from repro.db import tpcc, ycsb


@pytest.fixture(scope="module")
def ycsb_engine():
    cfg = ycsb.YCSBConfig(n_partitions=4, records_per_partition=500)
    eng = StarEngine(cfg.n_partitions, cfg.records_per_partition)
    for ep in range(3):
        eng.run_epoch(ycsb.make_batch(cfg, 192, seed=ep))
    return eng


def test_replica_consistent_after_epochs(ycsb_engine):
    assert ycsb_engine.replica_consistent()


def test_epoch_advances(ycsb_engine):
    assert ycsb_engine.epoch == 4
    assert ycsb_engine.stats.fences == 6


def test_controller_solves_eq12(ycsb_engine):
    tau_p, tau_s = ycsb_engine.controller.plan()
    e = ycsb_engine.controller.e_ms
    assert abs(tau_p + tau_s - e) < 1e-9                     # Eq (1)
    t_p, t_s = ycsb_engine.controller.t_p, ycsb_engine.controller.t_s
    P = ycsb_engine.controller.frac_cross
    if P > 0 and t_s > 0:
        lhs = tau_s * t_s / (tau_p * t_p + tau_s * t_s)      # Eq (2)
        assert abs(lhs - P) < 1e-6


def test_tpcc_hybrid_replication_saves_bytes():
    cfg = tpcc.TPCCConfig(n_partitions=2, n_items=500, cust_per_district=50,
                          order_ring=64)
    state = tpcc.TPCCState(cfg)
    rng = np.random.default_rng(0)
    eng = StarEngine(cfg.n_partitions, cfg.rows_per_partition,
                     init_val=tpcc.init_values(cfg, rng))
    for ep in range(2):
        eng.run_epoch(tpcc.make_batch(cfg, state, 128, seed=ep))
    assert eng.replica_consistent()
    assert eng.stats.value_bytes_if_not_hybrid > 3 * eng.stats.op_bytes_hybrid


def test_ycsb_no_hybrid_savings(ycsb_engine):
    """Paper §7.5: YCSB writes update the whole record — no savings."""
    s = ycsb_engine.stats
    assert s.op_bytes_hybrid >= 0.9 * s.value_bytes_if_not_hybrid


def test_failure_revert_and_continue():
    cfg = ycsb.YCSBConfig(n_partitions=4, records_per_partition=300)
    eng = StarEngine(cfg.n_partitions, cfg.records_per_partition,
                     cluster=ClusterConfig(f=1, k=4, n_partitions=4))
    eng.run_epoch(ycsb.make_batch(cfg, 128, seed=0))
    snap = np.array(eng.snapshot["val"])
    plan = eng.inject_failure({2})
    assert plan.case == RecoveryCase.PHASE_SWITCHING
    assert np.array_equal(np.array(eng.master["val"]), snap)
    eng.run_epoch(ycsb.make_batch(cfg, 128, seed=1))
    assert eng.replica_consistent()


def test_failure_case_enumeration_f2_k6():
    """Paper §4.5.3: all 2^8-1 = 255 failure patterns of f=2, k=6 classify
    into the four cases; spot-check the boundaries."""
    cfg = ClusterConfig(f=2, k=6, n_partitions=6, replicas_per_partition=2)
    counts = {c: 0 for c in RecoveryCase}
    for mask in range(1, 256):
        failed = {i for i in range(8) if mask & (1 << i)}
        counts[classify_failure(cfg, failed)] += 1
    assert sum(counts.values()) == 255
    assert all(v > 0 for v in counts.values())
    # no full replica nodes alive and no complete partial set -> case 4
    assert classify_failure(cfg, set(range(8))) == RecoveryCase.UNAVAILABLE
    # only full replicas fail -> case 2 (fall back to distributed CC)
    assert classify_failure(cfg, {0, 1}) == RecoveryCase.FALLBACK_DIST_CC
    # all partial nodes fail -> case 3 (full replica only)
    assert classify_failure(cfg, set(range(2, 8))) == RecoveryCase.FULL_ONLY
    # one partial fails, its partition still has a live secondary -> case 1
    assert classify_failure(cfg, {3}) == RecoveryCase.PHASE_SWITCHING


def test_fence_models_network_lag(ycsb_engine):
    """The replication fence ships the epoch's stream bytes through the
    cost-model Network envelope: t_fence_net_s > 2 barrier RTTs whenever
    bytes moved, and it accumulates in the engine stats."""
    from repro.baselines.cost_model import Network
    net = Network()
    cfg = ycsb.YCSBConfig(n_partitions=2, records_per_partition=200)
    eng = StarEngine(2, 200, net=net)
    m = eng.run_epoch(ycsb.make_batch(cfg, 128, seed=3))
    floor = 2 * 2 * net.rtt_s                  # two fences, 2 RTTs each
    assert m["t_fence_net_s"] >= floor
    assert m["t_fence_net_s"] > floor, "stream bytes must add transfer time"
    assert eng.stats.fence_net_s >= m["t_fence_net_s"]


def test_engine_adaptive_epoch_flag():
    eng = StarEngine(2, 64, adaptive_epoch=True, iteration_ms=10.0)
    assert eng.controller.adaptive
    for _ in range(40):
        eng.controller.observe_latency(30.0, 35.0)
    assert eng.controller.e_ms > 15.0
