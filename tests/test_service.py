"""Online service layer: clients, admission, batching, measured latency."""
import numpy as np
import pytest

from repro.core.engine import StarEngine
from repro.core.router import Router, scatter_singles
from repro.db import tpcc, ycsb
from repro.service import (AdmissionConfig, AdmissionController,
                           BACKPRESSURE, ClosedLoopClient, LatencyRecorder,
                           OpenLoopClient, TPCCSource, TxnService, YCSBSource)
from repro.service.batcher import EpochBatcher
from repro.service.latency import COMMITTED, USER_ABORTED


def _ycsb_service(rate=2000.0, policy="shed", part_cap=256, master_cap=512,
                  slots=16, lanes=16, process="poisson", cross=0.1):
    cfg = ycsb.YCSBConfig(n_partitions=4, records_per_partition=256,
                          cross_ratio=cross)
    eng = StarEngine(4, 256)
    client = OpenLoopClient(YCSBSource(cfg, seed=1), rate_txn_s=rate,
                            process=process, seed=7)
    svc = TxnService(eng, [client],
                     AdmissionConfig(part_cap, master_cap, policy),
                     slots_per_partition=slots, master_lanes=lanes)
    return svc, eng, client


# ---------------------------------------------------------------------------
# end-to-end
# ---------------------------------------------------------------------------
def test_open_loop_end_to_end():
    svc, eng, client = _ycsb_service(rate=1500.0)
    out = svc.run(duration_s=0.6)
    assert out["epochs"] > 0 and out["committed"] > 0
    assert out["throughput_txn_s"] > 0
    # measured percentiles, ordered and finite
    assert 0 < out["p50_ms"] <= out["p99_ms"] <= out["p999_ms"] < 1e5
    # conservation: every offered txn is committed, aborted, or shed
    # (queues fully drain after the deadline)
    assert svc.admission.depth() == 0
    assert out["offered"] == out["committed"] + out["user_aborted"] + out["shed"]
    assert eng.replica_consistent()


def test_overload_sheds_not_unbounded():
    """Offered load >> capacity: admission sheds, queues stay bounded."""
    svc, eng, _ = _ycsb_service(rate=100_000.0, part_cap=32, master_cap=64,
                                slots=8, lanes=8)
    out = svc.run(duration_s=0.4)
    assert out["shed"] > 0
    assert out["max_part_depth"] <= 32
    assert out["max_master_depth"] <= 64
    assert out["committed"] > 0          # it keeps serving under overload
    assert eng.replica_consistent()


def test_backpressure_defers_instead_of_shedding():
    svc, eng, client = _ycsb_service(rate=50_000.0, policy=BACKPRESSURE,
                                     part_cap=32, master_cap=64,
                                     slots=8, lanes=8)
    out = svc.run(duration_s=0.3)
    assert out["shed"] == 0
    assert out["backpressured"] > 0
    assert out["max_part_depth"] <= 32 and out["max_master_depth"] <= 64
    # deferred requests either eventually commit or sit in the bounded
    # client retry buffer — never silently vanish
    retry_n = 0 if client.retry is None else client.retry["parts"].shape[0]
    assert retry_n <= client.retry_cap


def test_closed_loop_bounds_in_flight():
    cfg = ycsb.YCSBConfig(n_partitions=4, records_per_partition=256)
    eng = StarEngine(4, 256)
    client = ClosedLoopClient(YCSBSource(cfg, seed=3), n_outstanding=24,
                              tenant=5)
    svc = TxnService(eng, [client], AdmissionConfig(64, 64),
                     slots_per_partition=16, master_lanes=16)
    out = svc.run(duration_s=0.4)
    assert out["committed"] > 24          # several generations completed
    assert client.in_flight + len(client._due) == 24
    assert svc.recorder.committed(tenant=5) == out["committed"]


def test_closed_loop_slots_survive_shedding():
    """Shed requests must return to the closed-loop window (client sees an
    error and reissues) — never leak outstanding slots."""
    cfg = ycsb.YCSBConfig(n_partitions=2, records_per_partition=128)
    eng = StarEngine(2, 128)
    client = ClosedLoopClient(YCSBSource(cfg, seed=4), n_outstanding=48,
                              tenant=3)
    svc = TxnService(eng, [client], AdmissionConfig(4, 4),
                     slots_per_partition=4, master_lanes=4)
    out = svc.run(duration_s=0.4)
    assert out["shed"] > 0                   # queues really were overrun
    assert out["committed"] > 0              # and the client kept serving
    assert client.in_flight + len(client._due) == 48


def test_multi_tenant_mix():
    cfg = ycsb.YCSBConfig(n_partitions=4, records_per_partition=256)
    eng = StarEngine(4, 256)
    c0 = OpenLoopClient(YCSBSource(cfg, seed=1), 600.0, tenant=0, seed=1)
    c1 = OpenLoopClient(YCSBSource(cfg, seed=2), 300.0, tenant=1, seed=2,
                        process="bursty")
    svc = TxnService(eng, [c0, c1], AdmissionConfig(256, 256),
                     slots_per_partition=16, master_lanes=16)
    svc.run(duration_s=0.5)
    p0 = svc.recorder.percentiles(tenant=0)
    p1 = svc.recorder.percentiles(tenant=1)
    assert p0.n > 0 and p1.n > 0
    assert p0.n + p1.n == svc.recorder.committed()


def test_tpcc_open_loop():
    cfg = tpcc.TPCCConfig(n_partitions=2, n_items=200, cust_per_district=20,
                          order_ring=64)
    eng = StarEngine(2, cfg.rows_per_partition,
                     init_val=tpcc.init_values(cfg, np.random.default_rng(0)))
    client = OpenLoopClient(TPCCSource(cfg, seed=2), rate_txn_s=400.0)
    svc = TxnService(eng, [client], AdmissionConfig(64, 64),
                     slots_per_partition=8, master_lanes=8)
    out = svc.run(duration_s=0.4)
    assert out["committed"] > 0
    assert eng.replica_consistent()


def test_tpcc_full_mix_through_service():
    """The five-transaction mix served online: the service layer needs no
    changes — scan/index ops ride the same request arrays — and the replica
    (records + indexes) stays bit-equal at the end of the run."""
    cfg = tpcc.TPCCConfig(n_partitions=2, n_items=200, cust_per_district=20,
                          order_ring=64, mix="full", delivery_gen_lag=64)
    state = tpcc.TPCCState(cfg)
    rng = np.random.default_rng(0)
    init = tpcc.init_values(cfg, rng, state=state)
    eng = StarEngine(2, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg))
    client = OpenLoopClient(TPCCSource(cfg, state=state, seed=2),
                            rate_txn_s=400.0)
    svc = TxnService(eng, [client], AdmissionConfig(64, 64),
                     slots_per_partition=8, master_lanes=8,
                     feedback=lambda b, m:      # service-level consume loop
                     tpcc.apply_consume_feedback(state, b, m))
    from repro.storage import SENTINEL

    def live_entries():
        return (np.asarray(eng.store.indexes[tpcc.OID_IDX]["key"])
                != SENTINEL).sum()

    out = svc.run(duration_s=0.5)
    # under heavy host load a 0.5 s window may drain few epochs — keep
    # serving until a NewOrder has maintained the index (bounded retries)
    for _ in range(3):
        if live_entries() > 0:
            break
        out = svc.run(duration_s=0.4, warmup_epochs=0)
    assert out["committed"] > 0
    assert eng.replica_consistent()
    assert live_entries() > 0, "NewOrders maintained the orders index online"


# ---------------------------------------------------------------------------
# router: vectorized + re-route path
# ---------------------------------------------------------------------------
def _reference_route(P, T, M, C, home, rows, kinds, deltas, user_abort):
    """The seed's per-txn Python loop — oracle for the vectorized scatter."""
    ptxn = {"valid": np.zeros((P, T), bool),
            "row": np.zeros((P, T, M), np.int32),
            "kind": np.zeros((P, T, M), np.int32),
            "delta": np.zeros((P, T, M, C), np.int32),
            "user_abort": np.zeros((P, T), bool)}
    fill = np.zeros(P, np.int32)
    overflow = []
    for i in range(home.shape[0]):
        p, t = int(home[i]), int(fill[home[i]])
        if t >= T:
            overflow.append(i)
            continue
        ptxn["valid"][p, t] = True
        ptxn["row"][p, t] = rows[i]
        ptxn["kind"][p, t] = kinds[i]
        ptxn["delta"][p, t] = deltas[i]
        ptxn["user_abort"][p, t] = user_abort[i]
        fill[p] += 1
    return ptxn, overflow


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_vectorized_scatter_matches_reference(seed):
    rng = np.random.default_rng(seed)
    P, T, M, C = 4, 8, 3, 2
    n = int(rng.integers(0, 64))
    home = rng.integers(0, P, n).astype(np.int32)
    rows = rng.integers(0, 50, (n, M)).astype(np.int32)
    kinds = rng.integers(0, 3, (n, M)).astype(np.int32)
    deltas = rng.integers(-5, 5, (n, M, C)).astype(np.int32)
    ua = rng.random(n) < 0.1
    got, _, _, ovf = scatter_singles(P, T, M, C, home, rows, kinds, deltas, ua)
    want, ovf_ref = _reference_route(P, T, M, C, home, rows, kinds, deltas, ua)
    for k in want:
        assert np.array_equal(got[k], want[k]), k
    assert sorted(ovf.tolist()) == sorted(ovf_ref)


def test_router_reroute_detected_and_deferred_to_master():
    """A txn *declared* single-partition whose ops touch a remote partition
    must be re-routed to the master queue and counted (paper §4.3)."""
    adm = AdmissionController(4, 100, max_ops=3, n_cols=2)
    parts = np.array([[1, 1, 1],     # honest single on partition 1
                      [2, 2, 3],     # declared single on 2, touches 3!
                      [0, 3, 0]],    # honest cross (undeclared, home=-1)
                     np.int32)
    req = {"parts": parts,
           "rows": np.array([[0, 1, 2]] * 3, np.int32),
           "kinds": np.zeros((3, 3), np.int32),
           "deltas": np.zeros((3, 3, 2), np.int32),
           "user_abort": np.zeros(3, bool),
           "home": np.array([1, 2, -1], np.int32),     # declared homes
           "txn_id": np.arange(3, dtype=np.int64),
           "tenant": np.zeros(3, np.int32),
           "arrival_s": np.zeros(3)}
    rejected = adm.offer(req, now_s=0.0)
    assert not rejected.any()
    # P partitions + master + read lane: the attribution array is ALWAYS
    # P + 2 (read-lane slot present even with the lane disabled) so shed
    # accounting can index rq[:P], rq[P], rq[P + 1] unconditionally
    assert adm.stats.rejected_by_queue.shape == (4 + 2,)
    assert adm.router.stats.rerouted == 1          # only the mis-declared one
    assert adm.router.stats.cross == 2             # rerouted + honest cross
    assert len(adm.master_queue) == 2
    assert len(adm.part_queues[1]) == 1 and len(adm.part_queues[2]) == 0
    # the mis-declared txn's rows were globalized for the master view
    slot = adm.master_queue[0]
    assert (adm.pool.row[slot] == parts[1] * 100 +
            np.array([0, 1, 2])).all()


def test_route_offline_api_overflow_and_stats():
    r = Router(n_partitions=2, rows_per_partition=64, max_ops=2)
    n = 10
    parts = np.zeros((n, 2), np.int32)               # all home partition 0
    batch = r.route(parts, np.zeros((n, 2), np.int32),
                    np.zeros((n, 2), np.int32),
                    np.zeros((n, 2, 10), np.int32), T=4)
    assert batch["n_single"] == 4
    assert batch["overflow_idx"].size == 6
    assert r.stats.deferred_epochs == 6


# ---------------------------------------------------------------------------
# batcher + engine plumbing
# ---------------------------------------------------------------------------
def test_batcher_fixed_shapes_and_fifo():
    adm = AdmissionController(2, 64, max_ops=2, n_cols=3,
                              cfg=AdmissionConfig(64, 64))
    n = 12
    rng = np.random.default_rng(0)
    home = rng.integers(0, 2, n).astype(np.int32)
    req = {"parts": np.repeat(home[:, None], 2, 1),
           "rows": rng.integers(0, 64, (n, 2)).astype(np.int32),
           "kinds": np.zeros((n, 2), np.int32),
           "deltas": np.zeros((n, 2, 3), np.int32),
           "user_abort": np.zeros(n, bool),
           "home": np.full(n, -1, np.int32),
           "txn_id": np.arange(n, dtype=np.int64),
           "tenant": np.zeros(n, np.int32),
           "arrival_s": np.zeros(n)}
    adm.offer(req, 0.0)
    b = EpochBatcher(adm, slots_per_partition=4, master_lanes=4)
    batch1, plan1 = b.form(1.0)
    assert batch1["ptxn"]["row"].shape == (2, 4, 2)
    assert batch1["cross"]["row"].shape == (4, 2)
    assert not batch1["cross"]["valid"].any()
    # FIFO: first formed batch holds the earliest-admitted txns per partition
    first_ids = adm.pool.txn_id[plan1.p_idx[plan1.p_idx >= 0]]
    batch2, plan2 = b.form(2.0)
    second_ids = adm.pool.txn_id[plan2.p_idx[plan2.p_idx >= 0]]
    for p in range(2):
        mine = np.sort(np.nonzero(home == p)[0])
        got = np.sort(np.concatenate(
            [adm.pool.txn_id[plan.p_idx[p][plan.p_idx[p] >= 0]]
             for plan in (plan1, plan2)]))
        assert np.array_equal(got, mine)
    assert plan1.total + plan2.total == n
    assert set(first_ids).isdisjoint(second_ids)
    # formation stamps the queue-delay clock
    assert (adm.pool.form_s[plan1.p_idx[plan1.p_idx >= 0]] == 1.0).all()


def test_engine_ingest_hook_and_commit_stamps():
    cfg = ycsb.YCSBConfig(n_partitions=2, records_per_partition=128)
    eng = StarEngine(2, 128)
    called = []
    m = eng.run_epoch(ycsb.make_batch(cfg, 64, seed=0),
                      ingest=lambda: called.append(1))
    assert called == [1]
    assert m["t_fence1_s"] <= m["t_fence2_s"]
    assert m["t_ingest_s"] >= 0
    # per-txn outcomes: committed singles count matches the mask
    assert int(m["p_committed"].sum()) == m["committed_single"]
    assert int(m["c_committed"].sum()) == m["committed_cross"]


# ---------------------------------------------------------------------------
# latency accounting + telemetry
# ---------------------------------------------------------------------------
def test_latency_recorder_percentiles():
    rec = LatencyRecorder()
    n = 1000
    arrival = np.zeros(n)
    commit = np.arange(1, n + 1) / 1000.0          # 1..1000 ms
    rec.record(np.zeros(n, np.int32), arrival, arrival, arrival, commit,
               np.full(n, COMMITTED, np.int32))
    p = rec.percentiles()
    assert p.n == n
    assert abs(p.p50_ms - 500.5) < 1.0
    assert abs(p.p99_ms - 990.01) < 1.0
    # aborted rows are excluded from commit percentiles
    rec.record(np.zeros(1, np.int32), [0.0], [0.0], [0.0], [9.9],
               np.array([USER_ABORTED], np.int32))
    assert rec.percentiles().n == n


def test_controller_receives_measured_latency():
    svc, eng, _ = _ycsb_service(rate=800.0)
    svc.run(duration_s=0.4)
    ctl = eng.controller
    assert ctl.measured_commit_ms > 0
    assert ctl.queue_delay_ms > 0
    # expected latency now reflects measurement, not the e/2 synthetic
    assert ctl.expected_mean_latency_ms() == ctl.measured_commit_ms


# ---------------------------------------------------------------------------
# workload skew
# ---------------------------------------------------------------------------
def test_zipf_skew_concentrates_access():
    cfg = ycsb.YCSBConfig(4, 10_000, zipf_theta=0.99)
    rows = ycsb.sample_rows(cfg, np.random.default_rng(0), (40_000,))
    frac_top1pct = (rows < 100).mean()
    assert frac_top1pct > 0.4                      # vs 0.01 under uniform
    # default stays uniform and draw-order identical to the seed generator
    cfg_u = ycsb.YCSBConfig(4, 10_000)
    got = ycsb.sample_rows(cfg_u, np.random.default_rng(5), (64,))
    want = np.random.default_rng(5).integers(0, 10_000, (64,)).astype(np.int32)
    assert np.array_equal(got, want)


def test_hot_key_scenario():
    cfg = ycsb.YCSBConfig(4, 10_000, hot_set_size=16, hot_access_frac=0.9)
    rows = ycsb.sample_rows(cfg, np.random.default_rng(0), (20_000,))
    assert (rows < 16).mean() > 0.85


def test_shed_neworders_unwound_mirror_matches_device():
    """Overload burst with shed admission on the full mix: shed NewOrders
    must unwind their host-mirror entries (undelivered push, claims,
    ledger) so that after the burst drains, the mirror's undelivered
    orders per district are EXACTLY the device's neworder-index live keys
    — the ROADMAP's "host mirror ahead of device" tail, closed."""
    from repro.storage import SENTINEL
    cfg = tpcc.TPCCConfig(n_partitions=2, n_items=200, cust_per_district=20,
                          order_ring=64, mix="full", delivery_gen_lag=64)
    state = tpcc.TPCCState(cfg)
    rng = np.random.default_rng(0)
    init = tpcc.init_values(cfg, rng, state=state)
    eng = StarEngine(2, cfg.rows_per_partition, init_val=init,
                     indexes=tpcc.index_specs(cfg))
    client = OpenLoopClient(TPCCSource(cfg, state=state, seed=3),
                            rate_txn_s=6000.0)        # far beyond capacity
    svc = TxnService(eng, [client],
                     AdmissionConfig(part_queue_cap=8, master_queue_cap=8,
                                     policy="shed"),
                     slots_per_partition=8, master_lanes=8,
                     feedback=lambda b, m:
                     tpcc.apply_consume_feedback(state, b, m))
    out = svc.run(duration_s=0.4)
    client.shutdown()      # unwind the never-offered lookahead + retries
    assert out["shed"] > 0, "burst did not overload admission"
    assert out["committed"] > 0
    assert eng.replica_consistent()
    # after the drain every claim is resolved (committed or re-queued)
    assert not state.pending_claims, state.pending_claims
    lo_mask = (1 << tpcc.D_SHIFT) - 1
    for w in range(cfg.n_partitions):
        seg = np.asarray(eng.store.indexes[tpcc.NO_IDX]["key"][w])
        for d in range(tpcc.N_DIST):
            mirror = sorted(tpcc._key_no(w, d, o % (lo_mask + 1))
                            for o, _, _, _, _ in state.undelivered[w][d])
            dev = sorted(int(k) for k in seg
                         if k != SENTINEL
                         and tpcc._key_no(w, d, 0) <= k
                         < tpcc._key_no(w, d + 1, 0))
            assert mirror == dev, (w, d, mirror, dev)
