"""Storage subsystem: ordered-index properties + range-scan OCC (phantoms).

Property tests (hypothesis via tests/_hyp.py): the jnp sorted-key index must
agree with a plain-python sorted-dict reference under random insert/delete
interleavings, and ``range_scan`` must return exactly the reference's range
answers.  OCC tests drive ``run_single_master`` directly: a scanned range
dirtied by a concurrent committed insert must abort the scanner (next-key
validation = phantom protection), and a consumed entry can be consumed once.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.ops import (DELETE_IDX, INSERT_IDX, IX_EXPECT, IX_HI, IX_ID,
                            IX_KEY, IX_PROW, READ, SCAN_CONSUME, SCAN_READ,
                            SET)
from repro.core.single_master import run_single_master
from repro.storage import (IndexSpec, SENTINEL, StorageEngine, make_index,
                           segment_apply, segment_scan)
from repro.storage.index import ReferenceIndex

C = 10
M = 16


def _apply_batch(key, prow, tid, dels, ins):
    """One segment_apply call from python-level batches (masked to width 8)."""
    W = 8
    dk = np.full(W, SENTINEL, np.int32)
    ik = np.full(W, SENTINEL, np.int32)
    ip = np.zeros(W, np.int32)
    it = np.zeros(W, np.uint32)
    dk[:len(dels)] = dels
    for j, (k, p, t) in enumerate(ins):
        ik[j], ip[j], it[j] = k, p, t
    return segment_apply(key, prow, tid, jnp.asarray(dk), jnp.asarray(ik),
                         jnp.asarray(ip), jnp.asarray(it))[:3]


@given(st.integers(0, 10_000), st.integers(1, 40))
@settings(max_examples=25, deadline=None)
def test_index_matches_reference_under_interleaving(seed, n_batches):
    """Random insert/delete batches: jnp index == numpy sorted reference."""
    rng = np.random.default_rng(seed)
    cap = 64
    key = jnp.full((cap,), SENTINEL, jnp.int32)
    prow = jnp.zeros((cap,), jnp.int32)
    tid = jnp.zeros((cap,), jnp.uint32)
    ref = ReferenceIndex()
    next_tid = 1
    for _ in range(n_batches):
        live = sorted(ref.entries)
        # deletes of existing + missing keys; inserts of fresh keys
        dels = []
        if live and rng.random() < 0.6:
            dels = [int(k) for k in
                    rng.choice(live, size=min(len(live), int(rng.integers(1, 4))),
                               replace=False)]
        if rng.random() < 0.3:
            dels.append(int(rng.integers(0, 1000)) + 2000)   # likely missing
        ins = []
        n_ins = int(rng.integers(0, 5))
        fresh = rng.choice(2000, size=n_ins, replace=False)
        for k in fresh:
            if int(k) in ref.entries or int(k) in dels:
                continue
            if len(ref.entries) - len([d for d in dels if d in ref.entries]) \
                    + len(ins) >= cap:
                break
            ins.append((int(k), int(rng.integers(0, 100)), next_tid))
            next_tid += 1
        key, prow, tid = _apply_batch(key, prow, tid, dels, ins)
        for d in dels:
            ref.delete(d)
        for k, p, t in ins:
            ref.insert(k, p, t)
        rk, rp, rt = ref.as_arrays(cap)
        assert np.array_equal(np.asarray(key), rk)
        assert np.array_equal(np.asarray(prow), rp)
        assert np.array_equal(np.asarray(tid), rt)


@given(st.integers(0, 10_000), st.integers(0, 900), st.integers(1, 200))
@settings(max_examples=25, deadline=None)
def test_range_scan_matches_reference(seed, lo, width):
    rng = np.random.default_rng(seed)
    cap = 64
    ref = ReferenceIndex()
    keys = rng.choice(1000, size=rng.integers(1, 40), replace=False)
    for i, k in enumerate(keys):
        ref.insert(int(k), i, i + 1)
    rk, rp, rt = ref.as_arrays(cap)
    hi = lo + width
    slots, keys_at, in_range = segment_scan(jnp.asarray(rk), jnp.int32(lo),
                                            jnp.int32(hi))
    got = [(int(keys_at[j]), int(rp[int(slots[j])]), int(rt[int(slots[j])]))
           for j in range(len(np.asarray(in_range))) if in_range[j]]
    expect = ref.range_scan(lo, hi, limit=len(np.asarray(slots)) - 1)
    assert got == expect


def test_storage_engine_point_and_range_ops():
    eng = StorageEngine(2, 8, n_cols=4,
                        index_specs=[IndexSpec("ix", 16)])
    parts = jnp.array([0, 1], jnp.int32)
    rows = jnp.array([3, 5], jnp.int32)
    vals = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    tids = jnp.array([2, 4], jnp.uint32)
    eng.point_write(parts, rows, vals, tids)
    v, t = eng.point_read(parts, rows)
    assert np.array_equal(np.asarray(v), np.asarray(vals))
    assert np.array_equal(np.asarray(t), np.asarray(tids))
    # index round trip through segment arrays + range_scan
    idx = eng.indexes[0]
    idx["key"] = idx["key"].at[1, 0].set((1 << 24) | 7)
    idx["prow"] = idx["prow"].at[1, 0].set(5)
    idx["tid"] = idx["tid"].at[1, 0].set(4)
    keys, prows, tids_, mask = eng.range_scan("ix", 1, (1 << 24) | 0,
                                              (1 << 24) | 100)
    assert bool(mask[0]) and int(keys[0]) == ((1 << 24) | 7) \
        and int(prows[0]) == 5
    assert not bool(mask[1:].any())


def test_segment_overflow_counted():
    """Capacity-exceeding merges report how many LIVE keys they dropped
    (largest-first) instead of losing them silently."""
    cap = 4
    key = jnp.asarray(np.array([1, 2, 3, SENTINEL], np.int32))
    prow = jnp.zeros((cap,), jnp.int32)
    tid = jnp.zeros((cap,), jnp.uint32)
    ins = np.full(8, SENTINEL, np.int32)
    ins[:3] = [5, 6, 7]                       # 3 live + 3 inserts > cap
    k, p, t, ov = segment_apply(
        key, prow, tid, jnp.full((8,), SENTINEL, jnp.int32),
        jnp.asarray(ins), jnp.zeros(8, jnp.int32), jnp.zeros(8, jnp.uint32))
    assert int(ov) == 2                       # keys 6 and 7 dropped
    assert np.asarray(k).tolist() == [1, 2, 3, 5]
    # no overflow when the batch fits
    k, p, t, ov = segment_apply(
        key, prow, tid, jnp.full((8,), SENTINEL, jnp.int32),
        jnp.full((8,), SENTINEL, jnp.int32), jnp.zeros(8, jnp.int32),
        jnp.zeros(8, jnp.uint32))
    assert int(ov) == 0


def _overflow_engine(strict):
    from repro.core.engine import StarEngine
    eng = StarEngine(1, 8, indexes=[IndexSpec("tiny", 4)], strict_index=strict)
    M_, C_ = 16, 10
    rows = np.zeros((1, 1, M_), np.int32)
    kinds = np.full((1, 1, M_), READ, np.int32)
    deltas = np.zeros((1, 1, M_, C_), np.int32)
    for k in range(6):                         # 6 inserts into capacity 4
        kinds[0, 0, k] = INSERT_IDX
        deltas[0, 0, k, IX_KEY] = 10 + k
    ptxn = {"valid": np.ones((1, 1), bool), "row": rows, "kind": kinds,
            "delta": deltas, "user_abort": np.zeros((1, 1), bool)}
    cross = {"valid": np.ones(0, bool), "row": np.zeros((0, M_), np.int32),
             "kind": np.zeros((0, M_), np.int32),
             "delta": np.zeros((0, M_, C_), np.int32),
             "user_abort": np.zeros(0, bool)}
    return eng, {"ptxn": ptxn, "cross": cross, "n_single": 1, "n_cross": 0}


def test_index_overflow_engine_stat_and_strict_mode():
    import pytest
    eng, batch = _overflow_engine(strict=False)
    m = eng.run_epoch(batch)
    assert m["index_overflow"] == 2 and eng.stats.index_overflow == 2
    assert eng.replica_consistent(), "overflow drop is replica-identical"
    eng, batch = _overflow_engine(strict=True)
    with pytest.raises(RuntimeError, match="overflow"):
        eng.run_epoch(batch)


def test_snapshot_revert_covers_indexes():
    eng = StorageEngine(1, 4, n_cols=4, index_specs=[IndexSpec("ix", 8)])
    eng.snapshot_commit()
    eng.val = eng.val.at[0, 0, 0].set(99)
    eng.indexes[0]["key"] = eng.indexes[0]["key"].at[0, 0].set(17)
    eng.revert_to_snapshot()
    assert int(eng.val[0, 0, 0]) == 0
    assert int(eng.indexes[0]["key"][0, 0]) == SENTINEL


# ---------------------------------------------------------------------------
# range-scan OCC: phantom protection in the single-master executor
# ---------------------------------------------------------------------------
def _txn_arrays(B):
    return (np.zeros((B, M), np.int32), np.full((B, M), READ, np.int32),
            np.zeros((B, M, C), np.int32))


def _run(txns, index, val=None, tid=None, n=64, max_rounds=4):
    val = val if val is not None else jnp.zeros((n, C), jnp.int32)
    tid = tid if tid is not None else jnp.zeros((n,), jnp.uint32)
    return run_single_master(val, tid, jax.tree.map(jnp.asarray, txns),
                             jnp.uint32(1), max_rounds=max_rounds,
                             index=index)


def test_phantom_insert_aborts_scanner():
    """A scanned range dirtied by a concurrent committed insert aborts the
    scanning transaction (next-key validation)."""
    index = [make_index(IndexSpec("ix", 16), 1)]
    rows, kinds, deltas = _txn_arrays(2)
    kinds[0, 0] = INSERT_IDX
    deltas[0, 0, IX_KEY] = 50
    deltas[0, 0, IX_PROW] = 3
    kinds[1, 0] = SCAN_READ
    deltas[1, 0, IX_KEY] = 0
    deltas[1, 0, IX_HI] = 100
    txns = {"valid": np.ones(2, bool), "row": rows, "kind": kinds,
            "delta": deltas, "user_abort": np.zeros(2, bool)}
    # one round only: the scanner must NOT commit alongside the insert
    _, _, out, _ = _run(txns, index, max_rounds=1)
    assert bool(out["committed"][0]) and not bool(out["committed"][1])
    # with retries allowed it commits in a later round, seeing the insert
    _, _, out, stats = _run(txns, index, max_rounds=4)
    assert np.asarray(out["committed"]).all()
    assert int(np.asarray(out["committed_round"])[1]) > 0
    assert int(stats["retries"]) >= 1


def test_scan_outside_range_no_conflict():
    """An insert beyond the scanned range does not abort the scanner."""
    index = [make_index(IndexSpec("ix", 16), 1)]
    # pre-populate keys 10, 20 so the scan window has a real boundary
    rows, kinds, deltas = _txn_arrays(1)
    kinds[0, 0] = INSERT_IDX
    deltas[0, 0, IX_KEY] = 10
    kinds[0, 1] = INSERT_IDX
    deltas[0, 1, IX_KEY] = 20
    setup = {"valid": np.ones(1, bool), "row": rows, "kind": kinds,
             "delta": deltas, "user_abort": np.zeros(1, bool)}
    _, _, out, _ = _run(setup, index, max_rounds=1)
    index = out["index"]
    rows, kinds, deltas = _txn_arrays(2)
    kinds[0, 0] = INSERT_IDX                  # insert key 500: outside scan
    deltas[0, 0, IX_KEY] = 500
    kinds[1, 0] = SCAN_READ                   # scan [0, 15): sees 10 only
    deltas[1, 0, IX_KEY] = 0
    deltas[1, 0, IX_HI] = 15
    txns = {"valid": np.ones(2, bool), "row": rows, "kind": kinds,
            "delta": deltas, "user_abort": np.zeros(2, bool)}
    _, _, out, _ = _run(txns, index, max_rounds=1)
    assert np.asarray(out["committed"]).all(), \
        "disjoint insert+scan must both commit in one round"


def test_consume_is_exclusive_and_ordered():
    """Two concurrent consumes of the same entry: exactly one wins per
    round; the loser retries and (strict oldest-first) skips once the entry
    is gone."""
    index = [make_index(IndexSpec("ix", 16), 1)]
    rows, kinds, deltas = _txn_arrays(1)
    kinds[0, 0] = INSERT_IDX
    deltas[0, 0, IX_KEY] = 7
    deltas[0, 0, IX_PROW] = 2
    setup = {"valid": np.ones(1, bool), "row": rows, "kind": kinds,
             "delta": deltas, "user_abort": np.zeros(1, bool)}
    _, _, out, _ = _run(setup, index, max_rounds=1)
    index = out["index"]

    rows, kinds, deltas = _txn_arrays(2)
    for b in range(2):
        kinds[b, 0] = SCAN_CONSUME
        deltas[b, 0, IX_KEY] = 0
        deltas[b, 0, IX_HI] = 100
        deltas[b, 0, IX_EXPECT] = 7
        rows[b, 0] = 2
    txns = {"valid": np.ones(2, bool), "row": rows, "kind": kinds,
            "delta": deltas, "user_abort": np.zeros(2, bool)}
    _, _, out, stats = _run(txns, index, max_rounds=3)
    committed = np.asarray(out["committed"])
    assert committed.all()                    # loser commits with a skip
    assert int(np.asarray(out["index"][0]["key"])[0, 0]) == SENTINEL
    assert int(stats["consume_skips"]) == 1   # second consume found nothing


def test_insert_scan_consume_roundtrip_with_primary():
    """Insert + primary write, then consume tombstones the primary row."""
    index = [make_index(IndexSpec("ix", 16), 1)]
    rows, kinds, deltas = _txn_arrays(1)
    kinds[0, 0] = INSERT_IDX
    deltas[0, 0, IX_KEY] = 9
    deltas[0, 0, IX_PROW] = 4
    kinds[0, 12] = SET
    rows[0, 12] = 4
    deltas[0, 12, :5] = 6
    t1 = {"valid": np.ones(1, bool), "row": rows, "kind": kinds,
          "delta": deltas, "user_abort": np.zeros(1, bool)}
    val, tidw, out, _ = _run(t1, index, max_rounds=1)
    assert int(val[4, 0]) == 6
    rows, kinds, deltas = _txn_arrays(1)
    kinds[0, 0] = SCAN_CONSUME
    deltas[0, 0, IX_KEY] = 0
    deltas[0, 0, IX_HI] = 100
    deltas[0, 0, IX_EXPECT] = 9
    rows[0, 0] = 4
    t2 = {"valid": np.ones(1, bool), "row": rows, "kind": kinds,
          "delta": deltas, "user_abort": np.zeros(1, bool)}
    val, tidw, out, _ = _run(t2, out["index"], val=val, tid=tidw, max_rounds=1)
    assert bool(out["committed"][0])
    assert int(val[4, 0]) == 0, "consume tombstones the primary row"


# ---------------------------------------------------------------------------
# sorted-run merge == the original concat+argsort maintenance (regression)
# ---------------------------------------------------------------------------
def _segment_apply_argsort(key, prow, tid, del_key, ins_key, ins_prow,
                           ins_tid):
    """The pre-optimization full-segment argsort merge, kept verbatim as the
    oracle for the gather-form sorted-run merge that replaced it."""
    cap = key.shape[0]
    pos = jnp.clip(jnp.searchsorted(key, del_key), 0, cap - 1)
    hit = (key[pos] == del_key) & (del_key != SENTINEL)
    tgt = jnp.where(hit, pos, cap)
    key = jnp.concatenate([key, jnp.array([SENTINEL], jnp.int32)]
                          ).at[tgt].set(SENTINEL)[:cap]
    k2 = jnp.concatenate([key, ins_key])
    p2 = jnp.concatenate([prow, ins_prow])
    t2 = jnp.concatenate([tid, ins_tid])
    order = jnp.argsort(k2)
    k2s = k2[order]
    overflow = jnp.sum(k2s[cap:] != SENTINEL, dtype=jnp.int32)
    order = order[:cap]
    k2, p2, t2 = k2s[:cap], p2[order], t2[order]
    live = k2 != SENTINEL
    return k2, jnp.where(live, p2, 0), jnp.where(live, t2, jnp.uint32(0)), \
        overflow


@given(st.integers(0, 100_000))
@settings(max_examples=40, deadline=None)
def test_segment_apply_merge_matches_argsort_oracle(seed):
    """Random segments incl. duplicate deletes, key ties between runs,
    overflow, and empty/full segments: the merge must be bit-identical to
    the old argsort maintenance (same keys, payloads, canonical free slots,
    and overflow count)."""
    rng = np.random.default_rng(seed)
    cap = int(rng.integers(4, 48))
    Kd = int(rng.integers(1, 10))
    Ki = int(rng.integers(1, 10))
    nlive = int(rng.integers(0, cap + 1))
    key = np.full(cap, SENTINEL, np.int32)
    key[:nlive] = np.sort(rng.choice(120, nlive, replace=False)).astype(
        np.int32)
    prow = rng.integers(0, 1000, cap).astype(np.int32) * (key != SENTINEL)
    tid = rng.integers(1, 99, cap).astype(np.uint32) * (key != SENTINEL)
    dk = rng.integers(0, 130, Kd).astype(np.int32)
    if nlive:                                  # guarantee some real hits
        n_hit = min(Kd, max(1, Kd // 2))
        dk[:n_hit] = key[rng.integers(0, nlive, n_hit)]
    dk[rng.random(Kd) < 0.2] = SENTINEL
    if Kd >= 2:
        dk[-1] = dk[0]                         # duplicate delete of one key
    ik = rng.integers(0, 130, Ki).astype(np.int32)
    ik[rng.random(Ki) < 0.3] = SENTINEL
    if nlive and Ki >= 2:
        ik[-1] = key[0]                        # tie with an existing key
    ip = rng.integers(0, 1000, Ki).astype(np.int32)
    it = rng.integers(1, 99, Ki).astype(np.uint32)
    args = tuple(jnp.asarray(a) for a in (key, prow, tid, dk, ik, ip, it))
    got = segment_apply(*args)
    want = _segment_apply_argsort(*args)
    for g, w, name in zip(got, want, ("key", "prow", "tid", "overflow")):
        assert np.array_equal(np.asarray(g), np.asarray(w)), (
            name, np.asarray(g), np.asarray(w))
