import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import tid as T


@given(st.integers(0, 255), st.integers(0, 2**23 - 1))
@settings(max_examples=200, deadline=None)
def test_pack_roundtrip(epoch, seq):
    t = T.make_tid(epoch, seq)
    assert int(T.tid_epoch(t)) == epoch
    assert int(T.tid_seq(t)) == seq
    assert not bool(T.tid_locked(t))


@given(st.integers(0, 255), st.integers(0, 2**23 - 1))
@settings(max_examples=100, deadline=None)
def test_lock_bit(epoch, seq):
    t = T.make_tid(epoch, seq)
    assert bool(T.tid_locked(T.tid_lock(t)))
    assert int(T.tid_unlock(T.tid_lock(t))) == int(t)


@given(st.integers(1, 255), st.integers(0, 2**20), st.integers(0, 2**20),
       st.integers(0, 255))
@settings(max_examples=200, deadline=None)
def test_next_tid_criteria(epoch, obs_seq, last_seq, obs_epoch):
    """Criteria (a) > observed, (b) > last, (c) in current epoch."""
    obs = T.make_tid(obs_epoch, obs_seq)
    last = T.make_tid(min(obs_epoch, epoch), last_seq)
    nt = T.next_tid(epoch, obs, last)
    assert int(T.tid_epoch(nt)) == epoch                      # (c)
    if obs_epoch <= epoch:
        assert int(nt) > int(T.tid_unlock(obs))               # (a)
    if int(T.tid_epoch(last)) <= epoch:
        assert int(nt) > int(T.tid_unlock(last))              # (b)


def test_epoch_dominates_order():
    a = T.make_tid(2, 1)
    b = T.make_tid(1, 2**23 - 1)
    assert int(a) > int(b)
