"""In-phase op-stream shipping + cluster index durability + physical
secondary partial replicas (this PR's tentpole), subprocess-driven on 4-8
forced host devices like tests/test_cluster_runtime.

Covers:
* full five-transaction TPC-C mix on ``ClusterRuntime`` bit-equal to the
  single-process ``StarEngine`` (records AND index segments) at every
  fence;
* mid-stream kill: the §4.5 revert discards the slabs the replicas
  consumed (slab high-watermark) and the re-executed epoch applies each
  slab to committed state exactly once;
* case-2 recovery restores a dead node's block from the PHYSICAL
  surviving secondary copy (the old committed-snapshot stand-in is gone);
* WAL-index crash recovery: UNAVAILABLE under the full mix reloads
  checkpoint + per-node logs (records and ordered index-op streams) and
  every subsequent fence stays bit-equal to an independently surviving
  replica.

The byte-attribution invariant (overlapped + fence == total == sum of
slab sizes, index ops counted) moved to tests/test_changelog.py — it is
pinned ONCE against the ChangeLog, the single attribution source.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# cluster runtime (subprocess, forced host devices)
# ---------------------------------------------------------------------------
def test_cluster_full_mix_bit_equal_to_star_engine():
    """The five-transaction TPC-C mix rides ClusterRuntime end-to-end:
    commit counts match StarEngine on the same batches, and records AND
    every index segment are bit-equal across the full replica, the
    sharded partials, the physical secondaries, and the single-process
    engine at every fence."""
    out = _run("""
        import jax, numpy as np
        from repro.cluster import ClusterRuntime
        from repro.core.engine import StarEngine
        from repro.db import tpcc
        cfg = tpcc.TPCCConfig(n_partitions=4, n_items=400,
                              cust_per_district=40, order_ring=64,
                              mix="full", delivery_gen_lag=256)
        s1, s2 = tpcc.TPCCState(cfg), tpcc.TPCCState(cfg)
        init1 = tpcc.init_values(cfg, np.random.default_rng(7), state=s1)
        init2 = tpcc.init_values(cfg, np.random.default_rng(7), state=s2)
        mesh = jax.make_mesh((4,), ("part",), devices=jax.devices()[:4])
        rt = ClusterRuntime(mesh, 4, cfg.rows_per_partition, init_val=init1,
                            indexes=tpcc.index_specs(cfg))
        eng = StarEngine(4, cfg.rows_per_partition, init_val=init2,
                         indexes=tpcc.index_specs(cfg))
        for ep in range(4):
            mc = rt.run_epoch(tpcc.make_batch(cfg, s1, 192, seed=ep))
            ms = eng.run_epoch(tpcc.make_batch(cfg, s2, 192, seed=ep))
            assert mc["committed_single"] == ms["committed_single"], ep
            assert mc["committed_cross"] == ms["committed_cross"], ep
            assert rt.replica_consistent(), ep
        assert np.array_equal(np.asarray(rt.eng.full_val),
                              np.asarray(eng.master["val"]))
        for i in range(3):
            for k in ("key", "prow", "tid"):
                assert np.array_equal(np.asarray(rt.eng.full_idx[i][k]),
                                      np.asarray(eng.store.indexes[i][k]))
        assert rt.stats.index_op_bytes > 0
        assert rt.stats.op_bytes_overlapped > 0
        print("OK fullmix", rt.stats.committed_single,
              rt.stats.op_bytes_overlapped, rt.stats.op_bytes_fence)
    """, devices=4)
    assert "OK fullmix" in out


def test_midstream_kill_discards_and_restreams_exactly_once():
    """A node killed MID-STREAM (after slab s shipped) aborts the epoch
    with a prefix of the op stream already consumed by the replicas; the
    revert discards exactly those slabs (high-watermark) and the
    re-executed epoch re-streams from slab 0 — every committed epoch's
    slabs applied exactly once, replicas bit-equal after."""
    out = _run("""
        import jax
        from collections import Counter
        from repro.cluster import ClusterRuntime
        from repro.core.fault import FaultInjector, RecoveryCase
        from repro.db import ycsb
        cfg = ycsb.YCSBConfig(n_partitions=8, records_per_partition=128)
        mesh = jax.make_mesh((4,), ("part",), devices=jax.devices()[:4])
        inj = FaultInjector(); inj.schedule_kill(2, epoch=3, slab=1)
        rt = ClusterRuntime(mesh, 8, 128, injector=inj)
        events = []
        for ep in range(5):
            m = rt.run_epoch(ycsb.make_batch(cfg, 128, seed=ep))
            assert rt.replica_consistent(), ep
            if "recovery" in m: events.append(m["recovery"])
        [ev] = events
        assert ev.case is RecoveryCase.PHASE_SWITCHING, ev
        assert ev.aborted_at_slab == 1, ev
        assert ev.slabs_discarded >= 1, ev
        # exactly-once: each committed epoch applied each slab once
        counts = Counter(rt.eng.slab_ledger)
        assert max(counts.values()) == 1, counts
        epochs = sorted({e for e, _ in rt.eng.slab_ledger})
        per_epoch = Counter(e for e, _ in rt.eng.slab_ledger)
        assert all(per_epoch[e] == per_epoch[epochs[0]] for e in epochs)
        assert rt.stats.slabs_discarded == ev.slabs_discarded
        print("OK midstream", ev.slabs_discarded, len(rt.eng.slab_ledger))
    """, devices=4)
    assert "OK midstream" in out


def test_case2_restores_block_from_physical_secondary():
    """Killing the full-replica holder (node 0) leaves no full replica but
    a complete partial set: FALLBACK_DIST_CC.  Node 0's primary block is
    physically scribbled and must be rebuilt from the PHYSICAL secondary
    copy node 1 hosts — recovery being bit-consistent afterwards proves
    the surviving copy (not a snapshot stand-in) was the source."""
    out = _run("""
        import jax
        from repro.cluster import ClusterRuntime
        from repro.core.fault import FaultInjector, RecoveryCase
        from repro.db import ycsb
        cfg = ycsb.YCSBConfig(n_partitions=8, records_per_partition=128)
        mesh = jax.make_mesh((4,), ("part",), devices=jax.devices()[:4])
        inj = FaultInjector(); inj.schedule_kill(0, epoch=3)
        rt = ClusterRuntime(mesh, 8, 128, injector=inj)
        events = []
        for ep in range(5):
            m = rt.run_epoch(ycsb.make_batch(cfg, 128, seed=10 + ep))
            assert rt.replica_consistent(), ep
            if "recovery" in m: events.append(m["recovery"])
        [ev] = events
        assert ev.case is RecoveryCase.FALLBACK_DIST_CC, ev
        assert ev.run_mode == "dist_cc"
        assert ev.restored_from_secondary == (0,), ev
        print("OK case2 secondary", ev.restored_from_secondary)
    """, devices=4)
    assert "OK case2 secondary" in out


def test_full_mix_wal_index_crash_recovery_bit_equal():
    """Crash after epoch e under the full TPC-C mix on ClusterRuntime
    (UNAVAILABLE: full holder + both homes of a block die), recover from
    per-node WAL + checkpoint (records AND ordered index-op streams), and
    assert records and all index segments bit-equal to an independently
    surviving replica (a StarEngine fed the same batches) at every
    subsequent fence."""
    out = _run("""
        import jax, numpy as np, tempfile
        from repro.cluster import ClusterRuntime
        from repro.core.engine import StarEngine
        from repro.core.fault import FaultInjector, RecoveryCase
        from repro.db import tpcc
        from repro.db.wal import Durability
        cfg = tpcc.TPCCConfig(n_partitions=4, n_items=400,
                              cust_per_district=40, order_ring=64,
                              mix="full", delivery_gen_lag=256)
        s1, s2 = tpcc.TPCCState(cfg), tpcc.TPCCState(cfg)
        init1 = tpcc.init_values(cfg, np.random.default_rng(7), state=s1)
        init2 = tpcc.init_values(cfg, np.random.default_rng(7), state=s2)
        mesh = jax.make_mesh((4,), ("part",), devices=jax.devices()[:4])
        inj = FaultInjector()
        for n in (0, 1, 2): inj.schedule_kill(n, epoch=4)
        eng = StarEngine(4, cfg.rows_per_partition, init_val=init2,
                         indexes=tpcc.index_specs(cfg))
        with tempfile.TemporaryDirectory() as d:
            dur = Durability(d, n_workers=4, checkpoint_every=2)
            rt = ClusterRuntime(mesh, 4, cfg.rows_per_partition,
                                init_val=init1,
                                indexes=tpcc.index_specs(cfg),
                                injector=inj, durability=dur)
            events = []
            for ep in range(6):
                m = rt.run_epoch(tpcc.make_batch(cfg, s1, 160, seed=ep))
                eng.run_epoch(tpcc.make_batch(cfg, s2, 160, seed=ep))
                assert rt.replica_consistent(), ep
                assert np.array_equal(np.asarray(rt.eng.full_val),
                                      np.asarray(eng.master["val"])), ep
                for i in range(3):
                    for k in ("key", "prow", "tid"):
                        assert np.array_equal(
                            np.asarray(rt.eng.full_idx[i][k]),
                            np.asarray(eng.store.indexes[i][k])), (ep, i, k)
                if "recovery" in m: events.append(m["recovery"])
            [ev] = events
            assert ev.case is RecoveryCase.UNAVAILABLE, ev
            assert ev.reloaded_from_disk and ev.run_mode == "halt"
            assert dur.checkpoints >= 1 and dur.entries_logged > 0
            print("OK walindex", dur.entries_logged)
    """, devices=4)
    assert "OK walindex" in out
