"""Phase controller (Eqs 1-2) and analytical model (Eqs 3-5, Figs 3/10)."""
import numpy as np
from _hyp import given, settings, st

from repro.core import analytical as an
from repro.core.phase_switch import solve_phase_times


@given(st.floats(0.0, 1.0), st.floats(1e3, 1e7), st.floats(1e3, 1e7),
       st.floats(1.0, 100.0))
@settings(max_examples=200, deadline=None)
def test_eq12_solution(P, tp, ts, e):
    tau_p, tau_s = solve_phase_times(e, tp, ts, P)
    assert abs(tau_p + tau_s - e) < 1e-6 * e
    assert tau_p >= 0 and tau_s >= 0
    if 0 < P < 1:
        lhs = tau_s * ts / (tau_p * tp + tau_s * ts)
        assert abs(lhs - P) < 1e-6


def test_p_zero_all_partitioned():
    tau_p, tau_s = solve_phase_times(10.0, 1e6, 1e6, 0.0)
    assert tau_p == 10.0 and tau_s == 0.0


def test_star_speedup_fig3():
    """I(n) = n/(nP - P + 1): P=0 -> n; P=1 -> 1."""
    for n in (2, 4, 8, 16):
        assert np.isclose(an.star_speedup(n, 0.0), n)
        assert np.isclose(an.star_speedup(n, 1.0), 1.0)
    # monotonically decreasing in P
    ps = np.linspace(0, 1, 11)
    sp = an.star_speedup(4, ps)
    assert np.all(np.diff(sp) < 0)


def test_crossover_fig10():
    """STAR beats partitioning-based systems iff K > n (§6.3)."""
    n = 4
    ps = np.linspace(0.05, 0.95, 10)
    better = an.improvement_over_partitioning(n, ps, K=n + 1) > 1
    worse = an.improvement_over_partitioning(n, ps, K=n - 1) < 1
    assert better.all() and worse.all()
    equal = an.improvement_over_partitioning(n, ps, K=n)
    assert np.allclose(equal, 1.0)


def test_consistency_eq3_eq5():
    n, n_s, n_c, t_s, t_c = 4, 900, 100, 1e-6, 8e-6
    P = n_c / (n_s + n_c)
    K = t_c / t_s
    ratio = an.t_partitioning(n, n_s, n_c, t_s, t_c) / an.t_star(n, n_s, n_c, t_s)
    assert np.isclose(ratio, an.improvement_over_partitioning(n, P, K))
    ratio2 = an.t_nonpartitioned(n, n_s, n_c, t_s) / an.t_star(n, n_s, n_c, t_s)
    assert np.isclose(ratio2, an.improvement_over_nonpartitioned(n, P))


def test_adaptive_epoch_tracks_queue_delay():
    """adaptive=True: e_ms steers toward 2x the measured queue-delay EMA
    (group-commit ideal: delay ~ e/2), clamped and smoothed."""
    from repro.core.phase_switch import PhaseController
    c = PhaseController(e_ms=10.0, adaptive=True)
    for _ in range(50):
        c.observe_latency(20.0)            # overloaded: 20 ms queue delay
    assert c.e_ms > 25.0, "epoch must grow toward 2 * 20 ms"
    assert c.e_ms <= c.e_max_ms
    for _ in range(80):
        c.observe_latency(0.5)             # underloaded: sub-ms delay
    assert c.e_ms < 5.0, "epoch must shrink when delay collapses"
    assert c.e_ms >= c.e_min_ms


def test_adaptive_epoch_off_by_default():
    """fig12 reproducibility: the fixed 10 ms default must not drift."""
    from repro.core.phase_switch import PhaseController
    c = PhaseController(e_ms=10.0)
    for _ in range(20):
        c.observe_latency(25.0, 30.0)
    assert c.e_ms == 10.0
