"""Regenerate the EXPERIMENTS.md roofline tables from results/dryrun/*.json."""
import glob
import json
from pathlib import Path

RES = Path("results/dryrun")


def table(mesh_tag, with_collcounts=False):
    rows = []
    for f in sorted(glob.glob(str(RES / f"*__{mesh_tag}.json"))):
        r = json.loads(Path(f).read_text())
        name = f"{r['arch']} × {r['shape']}"
        if r["status"] == "skipped":
            rows.append(f"| {name} | — | — | — | — | skipped | {r['reason']} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {name} | — | — | — | — | ERROR | {r.get('error','')[:60]} |")
            continue
        ro = r["roofline"]
        peak = r["mem"]["peak_bytes"] / 2**30
        fits = "✓" if r["mem"]["fits_16GiB"] else "✗"
        rows.append(
            f"| {name} | {ro['compute_s']*1e3:.1f} | {ro['memory_s']*1e3:.1f} | "
            f"{ro['collective_s']*1e3:.1f} | {peak:.1f} {fits} | {ro['bottleneck']} | "
            f"{ro['useful_flops_ratio']:.2f} |")
    return rows


hdr = ("| arch × shape | compute (ms) | memory (ms) | collective (ms) | "
       "peak GiB (≤16) | bottleneck | 6·N·D / HLO |\n"
       "|---|---|---|---|---|---|---|")
print("### single-pod 16×16\n")
print(hdr)
print("\n".join(table("pod16x16")))
print("\n### multi-pod 2×16×16 (pass/fail + terms)\n")
print(hdr)
print("\n".join(table("pod2x16x16")))
