"""Dev check: engine end-to-end on YCSB + TPC-C, replica consistency, fault."""
import numpy as np

from repro.core.engine import StarEngine
from repro.db import tpcc, ycsb

# YCSB
cfg = ycsb.YCSBConfig(n_partitions=4, records_per_partition=1000)
eng = StarEngine(cfg.n_partitions, cfg.records_per_partition)
for ep in range(3):
    batch = ycsb.make_batch(cfg, 256, seed=ep)
    m = eng.run_epoch(batch)
    print("ycsb epoch", ep, m)
assert eng.replica_consistent(), "ycsb replica mismatch"
print("ycsb replica consistent; stats:", eng.stats)

# TPC-C
tcfg = tpcc.TPCCConfig(n_partitions=4, n_items=1000, cust_per_district=100,
                       order_ring=64)
state = tpcc.TPCCState(tcfg)
rng = np.random.default_rng(0)
eng2 = StarEngine(tcfg.n_partitions, tcfg.rows_per_partition,
                  init_val=tpcc.init_values(tcfg, rng))
for ep in range(3):
    batch = tpcc.make_batch(tcfg, state, 200, seed=100 + ep)
    m = eng2.run_epoch(batch)
    print("tpcc epoch", ep, m)
assert eng2.replica_consistent(), "tpcc replica mismatch"
print("tpcc replica consistent")
print("hybrid op bytes:", eng2.stats.op_bytes_hybrid,
      "value bytes if not hybrid:", eng2.stats.value_bytes_if_not_hybrid,
      "ratio: %.1fx" % (eng2.stats.value_bytes_if_not_hybrid /
                        max(eng2.stats.op_bytes_hybrid, 1)))

# fault tolerance
plan = eng2.inject_failure({1, 2})
print("failure case:", plan.case, "mode:", plan.run_mode)
assert eng2.replica_consistent()
batch = tpcc.make_batch(tcfg, state, 100, seed=999)
eng2.run_epoch(batch)
assert eng2.replica_consistent()
print("post-recovery epoch ok")
