"""Quick dev check: every arch smoke config runs fwd + loss + prefill/decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_arch
from repro.data import make_batch
from repro.models import (decode_step, init_cache, init_params, loss_fn,
                          prefill)

S, B = 64, 2
which = sys.argv[1:] or ALL_ARCHS
for name in which:
    cfg = get_arch(name, smoke=True)
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, "train", S, B)
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
    assert jnp.isfinite(loss), (name, loss)
    line = f"{name}: loss={float(loss):.3f}"
    if not cfg.is_encoder:
        pb = make_batch(cfg, "prefill", S, B)
        logits, cache = jax.jit(lambda p, b: prefill(p, b, cfg, alloc_len=S + 8))(params, pb)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        logits2, cache = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))(params, cache, tok)
        assert jnp.all(jnp.isfinite(logits2.astype(jnp.float32))), name
        line += f" decode_logit0={float(logits2[0, 0, 0]):.3f}"
    print(line, flush=True)
print("OK")
