from repro.configs.base import (ALL_ARCHS, SHAPES, ArchConfig, ShapeCell,
                                cell_applicable, get_arch)

__all__ = ["ALL_ARCHS", "SHAPES", "ArchConfig", "ShapeCell",
           "cell_applicable", "get_arch"]
