"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B] — dense with MLA (latent KV)."""
from repro.configs.base import ArchConfig, BLOCK_MLA_MLP, register, shrink

FULL = ArchConfig(
    name="minicpm3-4b", family="dense", source="hf:openbmb/MiniCPM3-4B",
    block=BLOCK_MLA_MLP,
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_head=96,
    d_ff=6400, vocab_size=73448,
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64,
    rope_theta=10_000.0,
    mlp_act="silu", mlp_gated=True,
    pad_heads_to=48, fsdp=True,
)

SMOKE = shrink(
    FULL, pad_heads_to=0, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512, q_lora_rank=48, kv_lora_rank=32,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16, attn_chunk=64,
)

register(FULL, SMOKE)
