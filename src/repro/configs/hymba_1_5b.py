"""Hymba-1.5B [arXiv:2411.13676] — hybrid: parallel attention + mamba heads.

Published model mixes SWA layers with a few global-attention layers; we run
all layers with SWA (w=1024) + parallel SSM heads — noted in DESIGN.md — which
keeps the arch sub-quadratic so long_500k runs.
"""
from repro.configs.base import ArchConfig, BLOCK_HYMBA, register, shrink

FULL = ArchConfig(
    name="hymba-1.5b", family="hybrid", source="arXiv:2411.13676",
    block=BLOCK_HYMBA,
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
    d_ff=5504, vocab_size=32001,
    rope_theta=10_000.0, sliding_window=1024,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    mlp_act="silu", mlp_gated=True,
    pad_heads_to=32,
)

SMOKE = shrink(
    FULL, pad_heads_to=0, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab_size=512, sliding_window=32,
    ssm_state=8, ssm_head_dim=32, ssm_chunk=16, attn_chunk=64,
)

register(FULL, SMOKE)
