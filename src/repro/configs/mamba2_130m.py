"""Mamba2-130M [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.configs.base import ArchConfig, BLOCK_MAMBA2, register, shrink

FULL = ArchConfig(
    name="mamba2-130m", family="ssm", source="arXiv:2405.21060",
    block=BLOCK_MAMBA2,
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    ssm_chunk=256,
    batch_over_model=True,
)

SMOKE = shrink(
    FULL, n_layers=2, d_model=64, vocab_size=512,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
)

register(FULL, SMOKE)
