"""HuBERT-XLarge [arXiv:2106.07447] — encoder-only audio transformer.

The conv waveform frontend is a STUB per the task spec: ``input_specs()``
provides precomputed frame embeddings (dim 512, the conv stem's output width);
the backbone is the published 48L/1280d encoder with masked-unit prediction
over 504 k-means targets. Encoder-only: decode shapes are skipped.
"""
from repro.configs.base import ArchConfig, BLOCK_ATTN_MLP, register, shrink

FULL = ArchConfig(
    name="hubert-xlarge", family="audio", source="arXiv:2106.07447",
    block=BLOCK_ATTN_MLP,
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_head=80,
    d_ff=5120, vocab_size=504,
    causal=False,
    frontend="audio_stub", frontend_dim=512,
    mlp_act="gelu", mlp_gated=False,
)

SMOKE = shrink(
    FULL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
    d_ff=256, vocab_size=64, frontend_dim=32, attn_chunk=64,
)

register(FULL, SMOKE)
