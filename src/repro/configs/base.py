"""Architecture config schema + registry.

Every assigned architecture gets one module in ``repro.configs`` that
instantiates :class:`ArchConfig` with the published numbers and registers it
under its public id (``--arch <id>``).  ``smoke()`` returns a reduced config of
the same family for CPU tests; the full config is only ever *lowered* (dry-run,
no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional

BLOCK_ATTN_MLP = "attn_mlp"      # dense transformer (GQA / sliding window)
BLOCK_MLA_MLP = "mla_mlp"        # multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
BLOCK_ATTN_MOE = "attn_moe"      # GQA attention + routed MoE FFN
BLOCK_MAMBA2 = "mamba2"          # attention-free SSD block
BLOCK_HYMBA = "hymba"            # parallel attention + mamba heads (Hymba)


@dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    source: str                  # public-literature citation tag
    block: str = BLOCK_ATTN_MLP

    # trunk
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024

    # attention details
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0          # fraction of d_head that is rotary (GLM4 uses 0.5)
    sliding_window: Optional[int] = None
    causal: bool = True                  # False => encoder-only (HuBERT)
    pad_heads_to: int = 0                # padded-head TP (Megatron-style):
                                         # heads padded to a mesh-divisible
                                         # count; pad heads masked inert

    # MLA (only for block == mla_mlp)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE (only for block == attn_moe)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_d_ff: int = 0                    # per-expert hidden dim (defaults to d_ff)

    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # modality frontend stub
    frontend: str = "none"               # none | vision_stub | audio_stub
    frontend_dim: int = 0                # raw embedding dim delivered by the stub
    n_patches: int = 0                   # vision stub: patches per image

    # mlp flavour
    mlp_act: str = "silu"                # silu (SwiGLU) | gelu (GeGLU / plain)
    mlp_gated: bool = True
    tie_embeddings: bool = False

    # runtime policy
    fsdp: bool = False                   # ZeRO-3 style weight sharding over the data axis
    batch_over_model: bool = False       # archs whose heads can't TP: pure DP over all axes
    seq_shard: bool = True               # sequence-parallel residual stream between blocks
    remat: bool = True                   # activation checkpointing of each block
    microbatches: int = 1                # gradient-accumulation steps per update
    attn_chunk: int = 1024               # query-chunked attention block size (XLA-level flash)
    attn_scores_bf16: bool = False       # keep score tiles in bf16 (perf knob;
                                         # the Pallas flash kernel keeps f32
                                         # accum in VMEM with NO HBM score IO)
    pad_vocab_to: int = 512              # vocab padded for clean model-axis sharding
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def n_heads_padded(self) -> int:
        return max(self.n_heads, self.pad_heads_to) if self.pad_heads_to else self.n_heads

    @property
    def n_kv_heads_padded(self) -> int:
        if not self.pad_heads_to or self.n_heads == 0:
            return self.n_kv_heads
        g = max(self.n_heads // max(self.n_kv_heads, 1), 1)
        return -(-self.n_heads_padded // g)          # ceil(H_pad / G_real)

    def kv_index_map(self):
        """Static q-head -> kv-head index list under head padding."""
        g = max(self.n_heads // max(self.n_kv_heads, 1), 1)
        hp, kp = self.n_heads_padded, self.n_kv_heads_padded
        return [min(h // g, kp - 1) for h in range(hp)]

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_free(self) -> bool:
        return self.block == BLOCK_MAMBA2

    @property
    def sub_quadratic(self) -> bool:
        """True if serve-time cost per token is o(seq_len) state (long_500k eligible)."""
        return self.block in (BLOCK_MAMBA2, BLOCK_HYMBA) or self.sliding_window is not None

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def n_params(self) -> int:
        """Analytic parameter count (matches init; used for 6ND roofline terms)."""
        d, L = self.d_model, self.n_layers
        total = self.padded_vocab * d               # embed (padded, matches init)
        if not self.tie_embeddings:
            total += self.padded_vocab * d          # lm head
        if self.frontend != "none":
            total += self.frontend_dim * d
        per_layer = 2 * d                           # two norms
        if self.block in (BLOCK_ATTN_MLP, BLOCK_ATTN_MOE, BLOCK_HYMBA):
            per_layer += d * self.n_heads_padded * self.d_head          # wq
            per_layer += 2 * d * self.n_kv_heads_padded * self.d_head   # wk, wv
            per_layer += self.n_heads_padded * self.d_head * d          # wo
        if self.block == BLOCK_MLA_MLP:
            hp = self.n_heads_padded
            qd = self.qk_nope_head_dim + self.qk_rope_head_dim
            per_layer += d * self.q_lora_rank + self.q_lora_rank * hp * qd
            per_layer += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            per_layer += self.kv_lora_rank * hp * (self.qk_nope_head_dim + self.v_head_dim)
            per_layer += hp * self.v_head_dim * d
        if self.block in (BLOCK_ATTN_MLP, BLOCK_MLA_MLP, BLOCK_HYMBA):
            mult = 3 if self.mlp_gated else 2
            per_layer += mult * d * self.d_ff
        if self.block == BLOCK_ATTN_MOE:
            mult = 3 if self.mlp_gated else 2
            per_layer += d * self.n_experts                       # router
            per_layer += self.n_experts * mult * d * self.expert_d_ff
        if self.block in (BLOCK_MAMBA2, BLOCK_HYMBA):
            di, N, H = self.d_inner, self.ssm_state, self.n_ssm_heads
            per_layer += d * (2 * di + 2 * N + H)                 # in_proj (x,z) + B,C proj + dt
            per_layer += di * self.ssm_conv_width                 # depthwise conv
            per_layer += H + H                                    # A_log, D
            per_layer += di * d                                   # out proj
            per_layer += di                                       # gated norm
        return total + L * per_layer

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if self.block != BLOCK_ATTN_MOE:
            return self.n_params()
        mult = 3 if self.mlp_gated else 2
        expert = mult * self.d_model * self.expert_d_ff
        inactive = self.n_layers * (self.n_experts - self.top_k) * expert
        return self.n_params() - inactive


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, "ArchSpec"] = {}


@dataclass(frozen=True)
class ArchSpec:
    full: ArchConfig
    smoke: ArchConfig


def register(full: ArchConfig, smoke: ArchConfig) -> ArchSpec:
    spec = ArchSpec(full=full, smoke=smoke)
    _REGISTRY[full.name] = spec
    return spec


ALL_ARCHS = [
    "glm4-9b", "minicpm3-4b", "starcoder2-7b", "granite-8b", "internvl2-26b",
    "hymba-1.5b", "dbrx-132b", "granite-moe-1b-a400m", "hubert-xlarge",
    "mamba2-130m",
]


def get_arch(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _REGISTRY:
        mod = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    spec = _REGISTRY[name]
    return spec.smoke if smoke else spec.full


def shrink(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Helper to derive the reduced smoke config from the full config."""
    return replace(cfg, **overrides)


# ---------------------------------------------------------------------------
# assigned input shapes (same four cells for every LM arch)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Whether (arch x shape) is a valid dry-run cell, per the task spec."""
    if shape.kind == "decode" and cfg.is_encoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
