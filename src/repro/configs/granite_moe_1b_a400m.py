"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] —
fine-grained MoE: 32 experts, top-8, tiny per-expert FFN (512)."""
from repro.configs.base import ArchConfig, BLOCK_ATTN_MOE, register, shrink

FULL = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    block=BLOCK_ATTN_MOE,
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_head=64,
    d_ff=512, vocab_size=49155,
    rope_theta=10_000.0,
    n_experts=32, top_k=8, moe_d_ff=512, capacity_factor=1.25,
    mlp_act="silu", mlp_gated=True,
)

SMOKE = shrink(
    FULL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=64, moe_d_ff=64, vocab_size=512, n_experts=8, top_k=2,
    attn_chunk=64,
)

register(FULL, SMOKE)
