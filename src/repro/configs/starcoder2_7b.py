"""StarCoder2-7B [arXiv:2402.19173] — dense GQA kv=4, sliding-window 4096.

The published config uses sliding-window attention (w=4096), which makes the
arch sub-quadratic at serve time: the long_500k cell runs with a ring cache.
"""
from repro.configs.base import ArchConfig, BLOCK_ATTN_MLP, register, shrink

FULL = ArchConfig(
    name="starcoder2-7b", family="dense", source="arXiv:2402.19173",
    block=BLOCK_ATTN_MLP,
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_head=128,
    d_ff=18432, vocab_size=49152,
    rope_theta=100_000.0, sliding_window=4096,
    mlp_act="gelu", mlp_gated=False,
    pad_heads_to=48, fsdp=True,
)

SMOKE = shrink(
    FULL, pad_heads_to=0, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab_size=512, sliding_window=32, attn_chunk=64,
)

register(FULL, SMOKE)
