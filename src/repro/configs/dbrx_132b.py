"""DBRX-132B [hf:databricks/dbrx-base] — MoE 16 experts top-4, GQA kv=8."""
from repro.configs.base import ArchConfig, BLOCK_ATTN_MOE, register, shrink

FULL = ArchConfig(
    name="dbrx-132b", family="moe", source="hf:databricks/dbrx-base",
    block=BLOCK_ATTN_MOE,
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=10752, vocab_size=100352,
    rope_theta=500_000.0,
    n_experts=16, top_k=4, moe_d_ff=10752, capacity_factor=1.25,
    mlp_act="silu", mlp_gated=True,
    fsdp=True, microbatches=4,
)

SMOKE = shrink(
    FULL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=128, moe_d_ff=128, vocab_size=512, n_experts=4, top_k=2,
    attn_chunk=64, fsdp=False,
)

register(FULL, SMOKE)
