"""Granite-8B code [arXiv:2405.04324] — llama-arch dense, GQA kv=8."""
from repro.configs.base import ArchConfig, BLOCK_ATTN_MLP, register, shrink

FULL = ArchConfig(
    name="granite-8b", family="dense", source="arXiv:2405.04324",
    block=BLOCK_ATTN_MLP,
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=49152,
    rope_theta=10_000_000.0,
    mlp_act="silu", mlp_gated=True,
)

SMOKE = shrink(
    FULL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab_size=512, attn_chunk=64,
)

register(FULL, SMOKE)
