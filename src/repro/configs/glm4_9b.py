"""GLM-4-9B [hf:THUDM/glm-4-9b] — dense, GQA kv=2, partial RoPE."""
from repro.configs.base import ArchConfig, BLOCK_ATTN_MLP, register, shrink

FULL = ArchConfig(
    name="glm4-9b", family="dense", source="hf:THUDM/glm-4-9b",
    block=BLOCK_ATTN_MLP,
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_head=128,
    d_ff=13696, vocab_size=151552,
    rope_theta=10_000.0, rope_fraction=0.5,
    mlp_act="silu", mlp_gated=True,
)

SMOKE = shrink(
    FULL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab_size=512, attn_chunk=64,
)

register(FULL, SMOKE)
