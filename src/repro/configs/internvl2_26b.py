"""InternVL2-26B [arXiv:2404.16821] — VLM: InternViT frontend (stub) +
InternLM2-20B backbone (48L, d=6144, 48H GQA kv=8).

Per the task spec the modality frontend is a STUB: ``input_specs()`` supplies
precomputed patch embeddings (InternViT-6B hidden size 3200); the framework
projects them into the LM embedding space and runs the published backbone.
"""
from repro.configs.base import ArchConfig, BLOCK_ATTN_MLP, register, shrink

FULL = ArchConfig(
    name="internvl2-26b", family="vlm", source="arXiv:2404.16821",
    block=BLOCK_ATTN_MLP,
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab_size=92553,
    rope_theta=1_000_000.0,
    frontend="vision_stub", frontend_dim=3200, n_patches=256,
    mlp_act="silu", mlp_gated=True,
    fsdp=True, microbatches=2,
)

SMOKE = shrink(
    FULL, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab_size=512, frontend_dim=64, n_patches=8, attn_chunk=64,
    fsdp=False,
)

register(FULL, SMOKE)
