"""Version compatibility shims for the pinned container's jax.

``shard_map`` moved from ``jax.experimental.shard_map`` (jax 0.4.x, flag
``check_rep``) to ``jax.shard_map`` (newer jax, flag ``check_vma``).  Code
under ``src/`` calls this module's :func:`shard_map` so both jax versions
drive the same mesh programs; replication checking is disabled on both
paths (the engines manage replication explicitly).
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
