from repro.data.pipeline import input_specs, make_batch, synthetic_stream

__all__ = ["input_specs", "make_batch", "synthetic_stream"]
