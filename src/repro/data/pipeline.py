"""Deterministic synthetic data pipeline + dry-run input specs.

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins for every model
input of a given (arch, shape-cell) — weak-type-correct, shardable, zero
allocation — the dry-run lowers against these.  ``make_batch`` materializes
the same structure with real arrays for smoke tests and the example drivers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeCell


def _batch_tree(cfg: ArchConfig, kind: str, seq_len: int, batch: int):
    """Returns {name: (shape, dtype)} for the given step kind."""
    t = {}
    if cfg.frontend == "audio_stub":
        t["frames"] = ((batch, seq_len, cfg.frontend_dim), jnp.bfloat16)
        if kind == "train":
            t["labels"] = ((batch, seq_len), jnp.int32)
        return t
    if cfg.frontend == "vision_stub" and kind in ("train", "prefill"):
        n_text = seq_len - cfg.n_patches
        t["tokens"] = ((batch, n_text), jnp.int32)
        t["patch_embeds"] = ((batch, cfg.n_patches, cfg.frontend_dim), jnp.bfloat16)
        if kind == "train":
            t["labels"] = ((batch, n_text), jnp.int32)
        return t
    if kind == "decode":
        t["tokens"] = ((batch, 1), jnp.int32)
        return t
    t["tokens"] = ((batch, seq_len), jnp.int32)
    if kind == "train":
        t["labels"] = ((batch, seq_len), jnp.int32)
    return t


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    tree = _batch_tree(cfg, cell.kind, cell.seq_len, cell.global_batch)
    return {k: jax.ShapeDtypeStruct(shape, dtype) for k, (shape, dtype) in tree.items()}


# affine next-token map: t_{i+1} = (A*t_i + C) mod vocab.  A learnable
# language — the conditional distribution is a deterministic function of the
# current token — so train losses genuinely decrease below ln(vocab); i.i.d.
# uniform tokens (the previous stream) carry zero learnable signal and pin
# cross-entropy at chance level.
_AFF_A, _AFF_C = 31, 17


def _affine_chain(rng, batch: int, length: int, vocab: int):
    """(batch, length) token chains + the (batch, length) next-token labels."""
    toks = np.empty((batch, length + 1), np.int64)
    toks[:, 0] = rng.integers(0, vocab, batch)
    for i in range(length):
        toks[:, i + 1] = (_AFF_A * toks[:, i] + _AFF_C) % vocab
    return toks[:, :length].astype(np.int32), toks[:, 1:].astype(np.int32)


def make_batch(cfg: ArchConfig, kind: str, seq_len: int, batch: int,
               seed: int = 0) -> dict:
    tree = _batch_tree(cfg, kind, seq_len, batch)
    rng = np.random.default_rng(seed)
    out = {}
    if "tokens" in tree:
        toks, labels = _affine_chain(rng, tree["tokens"][0][0],
                                     tree["tokens"][0][1], cfg.vocab_size)
        out["tokens"] = jnp.asarray(toks)
        if "labels" in tree:
            out["labels"] = jnp.asarray(labels)
    for k, (shape, dtype) in tree.items():
        if k in out:
            continue
        if dtype == jnp.int32:
            out[k] = jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(shape), jnp.float32).astype(dtype)
    return out


def synthetic_stream(cfg: ArchConfig, seq_len: int, batch: int, n_steps: int,
                     seed: int = 0):
    """Deterministic stream of train batches (host-side, per-step seeds)."""
    for step in range(n_steps):
        yield make_batch(cfg, "train", seq_len, batch, seed=seed * 100_003 + step)
