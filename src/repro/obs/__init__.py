"""Unified observability layer: span tracing + one metrics namespace.

Two small, dependency-free pieces every layer of the stack reports into:

* :mod:`repro.obs.trace` — a low-overhead span **Tracer** (monotonic
  clock, thread-safe ring buffer, nested spans with categories and
  key/value args) exporting Chrome/Perfetto ``trace_event`` JSON.  The
  module-level tracer is DISABLED by default: every instrumentation
  point is a single attribute check + shared null context manager, with
  a tested overhead budget (≤2% of epoch time).

* :mod:`repro.obs.metrics` — a **MetricsRegistry** of counters, gauges
  and histograms under one dotted namespace (``engine.sm_rounds``,
  ``cluster.node3.fence_wait_s``, ``reads.mid_epoch_served``).  The
  existing stats dataclasses REGISTER into it (``register_object`` /
  ``register_provider``) instead of being hand-merged per benchmark;
  per-epoch ``snapshot()`` builds the time series that the JSON-lines
  and Prometheus-text exporters serialize.
"""
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import (Tracer, get_tracer, kernel_launch,
                             kernel_launch_counts, set_tracer, span)

__all__ = [
    "MetricsRegistry",
    "Tracer",
    "get_tracer",
    "set_tracer",
    "span",
    "kernel_launch",
    "kernel_launch_counts",
]
