"""One metrics registry: counters/gauges/histograms under a dotted namespace.

The existing stats dataclasses (``EngineStats``, ``ServiceStats``,
``ReadTierStats``, the per-node arrays in the cluster service) REGISTER
into a :class:`MetricsRegistry` instead of being hand-merged by every
benchmark:

* ``register_object("engine", eng.stats)`` — every numeric dataclass
  field becomes a gauge ``engine.<field>`` read live at snapshot time;
* ``register_provider("cluster", fn)`` — ``fn()`` returns a flat
  ``{name: value}`` dict merged under the prefix (how per-node arrays
  become ``cluster.node3.fence_wait_s``).

``snapshot(epoch)`` materializes one point of the per-epoch time series
(registered objects + providers + explicit counters/gauges/histograms);
``export_jsonl`` writes one JSON object per snapshot line and
``export_prometheus`` renders the LATEST values in Prometheus text
exposition format (dots → underscores).
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading

#: default histogram bucket upper bounds (seconds-ish scale); +Inf implied
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5)


class _Histogram:
    __slots__ = ("bounds", "counts", "total", "count")

    def __init__(self, bounds):
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.total = 0.0
        self.count = 0

    def observe(self, v: float):
        i = 0
        for b in self.bounds:
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.total += v
        self.count += 1

    def summary(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "buckets": dict(zip([*map(str, self.bounds), "+Inf"],
                                    _cumulative(self.counts)))}


def _cumulative(counts):
    out, acc = [], 0
    for c in counts:
        acc += c
        out.append(acc)
    return out


def _numeric(v):
    if isinstance(v, bool):
        return int(v)
    if isinstance(v, (int, float)) and not (isinstance(v, float)
                                            and math.isnan(v)):
        return v
    return None


class MetricsRegistry:
    """Namespaced counters/gauges/histograms + per-epoch snapshots."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._gauges: dict = {}
        self._hists: dict = {}
        self._objects: list = []      # (prefix, obj)
        self._providers: list = []    # (prefix, fn)
        self.snapshots: list = []

    # -- primitive instruments --------------------------------------------
    def counter_add(self, name: str, value=1):
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def gauge_set(self, name: str, value):
        with self._lock:
            self._gauges[name] = value

    def hist_observe(self, name: str, value: float, buckets=None):
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = _Histogram(buckets or DEFAULT_BUCKETS)
            h.observe(float(value))

    # -- registration: the stats dataclasses plug in here ------------------
    def register_object(self, prefix: str, obj) -> None:
        """Expose every numeric dataclass/attribute field as
        ``<prefix>.<field>`` gauges, read live at snapshot time."""
        self._objects.append((prefix, obj))

    def register_provider(self, prefix: str, fn) -> None:
        """``fn() -> {name: value}`` merged under ``<prefix>.`` at
        snapshot time (per-node arrays, lane summaries, launch counts)."""
        self._providers.append((prefix, fn))

    # -- reading -----------------------------------------------------------
    def _object_values(self, prefix, obj):
        if dataclasses.is_dataclass(obj):
            items = ((f.name, getattr(obj, f.name))
                     for f in dataclasses.fields(obj))
        else:
            items = ((k, v) for k, v in vars(obj).items()
                     if not k.startswith("_"))
        out = {}
        for k, v in items:
            n = _numeric(v)
            if n is not None:
                out[f"{prefix}.{k}"] = n
        return out

    def values(self) -> dict:
        """Flat ``{metric: value}`` of everything, read live."""
        out = {}
        for prefix, obj in self._objects:
            out.update(self._object_values(prefix, obj))
        for prefix, fn in self._providers:
            for k, v in (fn() or {}).items():
                n = _numeric(v)
                if n is not None:
                    out[f"{prefix}.{k}" if prefix else k] = n
        with self._lock:
            out.update(self._counters)
            out.update({k: v for k, v in self._gauges.items()
                        if _numeric(v) is not None})
            for k, h in self._hists.items():
                out[f"{k}.count"] = h.count
                out[f"{k}.sum"] = h.total
        return out

    def snapshot(self, epoch=None) -> dict:
        """Record one time-series point; returns it."""
        snap = {"epoch": epoch}
        snap.update(sorted(self.values().items()))
        self.snapshots.append(snap)
        return snap

    def latest(self) -> dict:
        return self.snapshots[-1] if self.snapshots else self.snapshot()

    # -- exporters ---------------------------------------------------------
    def export_jsonl(self, path: str) -> int:
        """One JSON object per snapshot line; returns the line count."""
        snaps = self.snapshots or [self.snapshot()]
        with open(path, "w") as f:
            for s in snaps:
                f.write(json.dumps(s) + "\n")
        return len(snaps)

    def export_prometheus(self) -> str:
        """Latest values in Prometheus text exposition format."""
        lines = []
        with self._lock:
            hist_keys = {f"{k}.count" for k in self._hists} \
                | {f"{k}.sum" for k in self._hists}
        vals = {k: v for k, v in self.values().items()
                if k not in hist_keys}
        for name in sorted(vals):
            pname = _prom_name(name)
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {_prom_value(vals[name])}")
        with self._lock:
            hists = dict(self._hists)
        for name in sorted(hists):
            h, pname = hists[name], _prom_name(name)
            lines.append(f"# TYPE {pname} histogram")
            for le, c in h.summary()["buckets"].items():
                lines.append(f'{pname}_bucket{{le="{le}"}} {c}')
            lines.append(f"{pname}_sum {_prom_value(h.total)}")
            lines.append(f"{pname}_count {h.count}")
        return "\n".join(lines) + "\n"

    def export_prometheus_file(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.export_prometheus())


def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return out if not out[:1].isdigit() else "_" + out


def _prom_value(v) -> str:
    return repr(int(v)) if isinstance(v, int) else repr(float(v))
