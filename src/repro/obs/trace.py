"""Low-overhead span tracer exporting Chrome/Perfetto trace_event JSON.

The whole epoch lifecycle — partitioned slabs, fence (tail-ship / psum /
WAL-sink), single-master rounds, replica replay, recovery — is wired
with ``with span("engine.partitioned", cat="phase", epoch=e):`` blocks.
When tracing is disabled (the default) each such block costs one method
call returning a shared null context manager; the budget is asserted in
``tests/test_obs.py`` (≤2% of measured epoch time).

Spans record ``time.perf_counter()`` begin/end (monotonic), nest per
thread, and land in a bounded thread-safe ring buffer (drop-oldest with
a counter).  ``export_chrome(path)`` writes the standard trace_event
JSON object (``ph:"X"`` complete events, microsecond timestamps) that
https://ui.perfetto.dev and ``chrome://tracing`` load directly.

Kernel-launch hooks: the Pallas dispatch wrappers in ``kernels/occ`` and
``kernels/index_merge`` call :func:`kernel_launch` — those functions run
under ``jax.jit`` so the hook fires at TRACE time (one mark per compiled
launch site, not per executed step); the marks carry the kernel name and
tile shape as args and also feed a process-wide launch counter that the
MetricsRegistry exposes under ``kernels.*``.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque


class _NullSpan:
    """Shared do-nothing context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):
        return self


_NULL = _NullSpan()


class _Span:
    __slots__ = ("_tr", "name", "cat", "args", "_t0")

    def __init__(self, tr, name, cat, args):
        self._tr = tr
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tr._emit(self.name, self.cat, self._t0, time.perf_counter(),
                       self.args)
        return False

    def set(self, **kw):
        """Attach/overwrite key-value args while the span is open."""
        if self.args is None:
            self.args = {}
        self.args.update(kw)
        return self


class Tracer:
    """Bounded thread-safe span recorder (drop-oldest ring buffer)."""

    def __init__(self, capacity: int = 65536, enabled: bool = False):
        self.enabled = enabled
        self.capacity = capacity
        self._buf = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._origin = time.perf_counter()
        self._emitted = 0
        self._tids = {}
        self._tid_next = itertools.count()

    # -- recording ---------------------------------------------------------
    def span(self, name: str, cat: str = "", **args):
        """Context manager timing a nested span; no-op when disabled."""
        if not self.enabled:
            return _NULL
        return _Span(self, name, cat, args or None)

    def instant(self, name: str, cat: str = "", **args):
        """Zero-duration mark (``ph:"i"``); no-op when disabled."""
        if not self.enabled:
            return
        t = time.perf_counter()
        self._emit(name, cat, t, None, args or None)

    def complete(self, name: str, cat: str = "", t0: float = 0.0,
                 t1: float = 0.0, **args):
        """Record an already-timed region (``perf_counter`` begin/end) —
        the hot paths that measure ``t0``/``t1`` anyway report through
        this instead of paying a context manager; no-op when disabled."""
        if not self.enabled:
            return
        self._emit(name, cat, t0, max(t1, t0), args or None)

    def _tid(self):
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = self._tids[ident] = next(self._tid_next)
        return tid

    def _emit(self, name, cat, t0, t1, args):
        with self._lock:
            self._buf.append((name, cat, t0 - self._origin,
                              None if t1 is None else t1 - t0,
                              self._tid(), args))
            self._emitted += 1

    # -- inspection --------------------------------------------------------
    @property
    def dropped(self) -> int:
        return self._emitted - len(self._buf)

    def events(self):
        """Recorded events as dicts (ts/dur in seconds since enable)."""
        with self._lock:
            raw = list(self._buf)
        return [{"name": n, "cat": c, "ts_s": ts, "dur_s": dur,
                 "tid": tid, "args": args or {}}
                for n, c, ts, dur, tid, args in raw]

    def clear(self):
        with self._lock:
            self._buf.clear()
            self._emitted = 0
            self._origin = time.perf_counter()

    # -- export ------------------------------------------------------------
    def to_chrome(self) -> dict:
        """The Chrome/Perfetto ``trace_event`` JSON object."""
        evs = []
        for e in self.events():
            rec = {"name": e["name"], "cat": e["cat"] or "default",
                   "pid": 0, "tid": e["tid"],
                   "ts": round(e["ts_s"] * 1e6, 3)}
            if e["dur_s"] is None:
                rec.update(ph="i", s="t")
            else:
                rec.update(ph="X", dur=round(e["dur_s"] * 1e6, 3))
            if e["args"]:
                rec["args"] = {k: _jsonable(v) for k, v in e["args"].items()}
            evs.append(rec)
        evs.sort(key=lambda r: r["ts"])
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export_chrome(self, path: str) -> int:
        """Write trace_event JSON; returns the number of events."""
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
            f.write("\n")
        return len(doc["traceEvents"])


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return int(v)
    except (TypeError, ValueError):
        return str(v)


# --------------------------------------------------------------------------
# module-level tracer: the one instrumentation points talk to
# --------------------------------------------------------------------------
_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the module tracer (tests, CLI ``--trace``); returns the old."""
    global _TRACER
    old, _TRACER = _TRACER, tracer
    return old


def span(name: str, cat: str = "", **args):
    """``with span("engine.partitioned", cat="phase", epoch=e): ...``"""
    return _TRACER.span(name, cat, **args)


def complete(name: str, cat: str = "", t0: float = 0.0, t1: float = 0.0,
             **args):
    return _TRACER.complete(name, cat, t0, t1, **args)


def instant(name: str, cat: str = "", **args):
    return _TRACER.instant(name, cat, **args)


# --------------------------------------------------------------------------
# kernel-launch hook (fires at jit-trace time — one mark per launch site)
# --------------------------------------------------------------------------
_KERNEL_LAUNCHES: dict = {}
_KERNEL_LOCK = threading.Lock()


def kernel_launch(kernel: str, **shape):
    """Per-kernel-launch hook for the Pallas dispatch wrappers."""
    with _KERNEL_LOCK:
        _KERNEL_LAUNCHES[kernel] = _KERNEL_LAUNCHES.get(kernel, 0) + 1
    if _TRACER.enabled:
        _TRACER.instant(f"kernel.{kernel}", cat="kernel", **shape)


def kernel_launch_counts() -> dict:
    """Traced-launch counts per kernel (``kernels.<name>`` namespace)."""
    with _KERNEL_LOCK:
        return dict(_KERNEL_LAUNCHES)
