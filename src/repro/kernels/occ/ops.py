"""Dispatch layer for the fused OCC kernels.

``kernel="jnp"`` routes to the reference implementations in ``ref.py`` (the
exact code that used to live inline in the executors — the parity oracle);
``kernel="pallas"`` routes to the fused Pallas kernels with
``interpret=True`` resolved automatically off-TPU, so tier-1 and CI run the
fused path on CPU.  Both paths return bit-identical results
(``tests/test_occ_kernels.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ops import (IX_EXPECT, IX_HI, IX_ID, IX_LO, SCAN_CONSUME,
                            is_index_kind, reads_index, writes_index)
from repro.kernels.occ import ref
from repro.kernels.occ.kernel import occ_round_pallas, scan_window_pallas
from repro.obs.trace import kernel_launch
from repro.storage.index import SCAN_L, SENTINEL, key_partition

KERNELS = ("jnp", "pallas")


def resolve_interpret(interpret):
    """None -> interpret off-TPU (the shared dispatch policy)."""
    return jax.default_backend() != "tpu" if interpret is None else interpret


def _flat_segments(index):
    """Static layout of the concatenated index segments: per-index flat
    offsets, caps, total slots, and the search-iteration bound."""
    P = index[0]["key"].shape[0]
    caps = [idx["key"].shape[1] for idx in index]
    offs = np.cumsum([0] + [P * c for c in caps])
    n_iters = int(max(caps)).bit_length() + 1
    return P, caps, [int(o) for o in offs], int(offs[-1]), n_iters


def _seg_select(caps, offs, sel, iid, part):
    """Per-op segment base/length in the concatenated key space.  Ops not
    matching any index resolve against segment 0 and are masked out by the
    caller (the same convention as the reference's p_g = 0 pass)."""
    seg_base = jnp.zeros(iid.shape, jnp.int32)
    seg_cap = jnp.full(iid.shape, caps[0], jnp.int32)
    for i, c in enumerate(caps):
        mine = sel & (iid == i)
        seg_base = jnp.where(mine, offs[i] + part * c, seg_base)
        seg_cap = jnp.where(mine, c, seg_cap)
    return seg_base, seg_cap


# ---------------------------------------------------------------------------
# index-op location (single-master): searchsorted + SCAN_L window
# ---------------------------------------------------------------------------
def _locate_index_ops_fused(index, kinds, delta, n_rows, interpret):
    B, K = kinds.shape
    P, caps, offs, S, n_iters = _flat_segments(index)
    no_addr = n_rows + S

    lo = delta[..., IX_LO]                                     # (B, K)
    hi = delta[..., IX_HI]
    iid = delta[..., IX_ID]
    p_of = jnp.clip(key_partition(lo), 0, P - 1)
    sel = is_index_kind(kinds) & (iid >= 0) & (iid < len(index))
    seg_base, seg_cap = _seg_select(caps, offs, sel, iid, p_of)

    flat_key = jnp.concatenate([ix["key"].reshape(-1) for ix in index])
    flat_tid = jnp.concatenate([ix["tid"].reshape(-1) for ix in index])
    pos0, keys_at, tids_at = scan_window_pallas(
        flat_key, flat_tid, lo.reshape(-1), seg_base.reshape(-1),
        seg_cap.reshape(-1), n_slots=SCAN_L + 1, n_iters=n_iters,
        interpret=interpret)
    pos0 = pos0.reshape(B, K)
    keys_at = keys_at.reshape(B, K, SCAN_L + 1)
    tids_at = tids_at.reshape(B, K, SCAN_L + 1)

    # identical mask algebra to ref.locate_index_ops_ref, now per-op instead
    # of per-index (the kernel already resolved each op's own segment)
    window = pos0[..., None] + jnp.arange(SCAN_L + 1, dtype=jnp.int32)
    slots = jnp.clip(window, 0, seg_cap[..., None] - 1)
    cmask = sel & writes_index(kinds)
    claim_addr = jnp.where(cmask, n_rows + seg_base
                           + jnp.clip(pos0, 0, seg_cap - 1),
                           no_addr).astype(jnp.int32)
    claim_tid = jnp.where(cmask, tids_at[..., 0], jnp.uint32(0))
    smask = sel & reads_index(kinds)
    in_or_boundary = jnp.concatenate(
        [jnp.ones((B, K, 1), bool), keys_at[..., :-1] < hi[..., None]],
        axis=-1) & (window < seg_cap[..., None])
    sv = smask[..., None] & in_or_boundary
    scan_addr = jnp.where(sv, n_rows + seg_base[..., None] + slots,
                          no_addr).astype(jnp.int32)
    scan_tid = jnp.where(sv, tids_at, jnp.uint32(0))
    first_key = jnp.where(sel, keys_at[..., 0], SENTINEL)
    consume_ok = (first_key == delta[..., IX_EXPECT]) & (first_key < hi) \
        & (first_key != SENTINEL)
    return {"claim_addr": claim_addr, "claim_tid": claim_tid,
            "scan_addr": scan_addr, "scan_tid": scan_tid,
            "scan_valid": sv, "consume_ok": consume_ok, "no_addr": no_addr}


def locate_index_ops(index, kinds, delta, n_rows, *, kernel="jnp",
                     interpret=None):
    """Resolve one round's index/scan ops (see ref.locate_index_ops_ref)."""
    kernel_launch("occ.locate_index_ops", backend=kernel,
                  lanes=int(kinds.shape[0]))
    if kernel == "jnp":
        return ref.locate_index_ops_ref(index, kinds, delta, n_rows)
    return _locate_index_ops_fused(index, kinds, delta, n_rows,
                                   resolve_interpret(interpret))


# ---------------------------------------------------------------------------
# one OCC round (single-master)
# ---------------------------------------------------------------------------
def occ_round(val, tidw, rows, kind, delta_v, wmask, amask, active, epoch,
              last_tid, ix=None, has_claim=None, deterministic=False, *,
              kernel="jnp", interpret=None):
    """One OCC round: gather → lock → validate → TID → install.  Returns
    (val', tidw', commit_now, new_tid, new, w)."""
    kernel_launch("occ.occ_round", backend=kernel,
                  lanes=int(rows.shape[0]), rows=int(val.shape[0]))
    if kernel == "jnp":
        return ref.occ_round_ref(val, tidw, rows, kind, delta_v, wmask,
                                 amask, active, epoch, last_tid, ix=ix,
                                 has_claim=has_claim,
                                 deterministic=deterministic)
    NT = val.shape[0] if ix is None else int(ix["no_addr"])
    ix_args = None
    if ix is not None:
        ix_args = (ix["claim_addr"], ix["claim_tid"], ix["scan_addr"],
                   ix["scan_tid"], ix["scan_valid"], has_claim)
    epoch_arr = jnp.asarray(epoch, jnp.uint32).reshape(1)
    return occ_round_pallas(val, tidw, rows, kind, delta_v, wmask, amask,
                            active, epoch_arr, last_tid, ix_args, NT=NT,
                            deterministic=deterministic,
                            interpret=resolve_interpret(interpret))


# ---------------------------------------------------------------------------
# per-queue-slot consume validation (partitioned)
# ---------------------------------------------------------------------------
def step_index_ops(index, kinds, delta, *, kernel="jnp", interpret=None):
    """Resolve one partitioned queue slot's index ops: (consume_ok (P, K),
    slot_tid (P, K))."""
    kernel_launch("occ.step_index_ops", backend=kernel,
                  partitions=int(kinds.shape[0]))
    if kernel == "jnp":
        return ref.step_index_ops_ref(index, kinds, delta)
    Pq, K = kinds.shape
    P, caps, offs, S, n_iters = _flat_segments(index)
    lo = delta[..., IX_LO]
    hi = delta[..., IX_HI]
    iid = delta[..., IX_ID]
    # partitioned executors probe their OWN partition's segment
    part = jnp.broadcast_to(jnp.arange(Pq, dtype=jnp.int32)[:, None],
                            (Pq, K))
    sel = (iid >= 0) & (iid < len(index))
    seg_base, seg_cap = _seg_select(caps, offs, sel, iid, part)
    flat_key = jnp.concatenate([ix["key"].reshape(-1) for ix in index])
    flat_tid = jnp.concatenate([ix["tid"].reshape(-1) for ix in index])
    pos0, keys_at, tids_at = scan_window_pallas(
        flat_key, flat_tid, lo.reshape(-1), seg_base.reshape(-1),
        seg_cap.reshape(-1), n_slots=1, n_iters=n_iters,
        interpret=resolve_interpret(interpret))
    first_key = keys_at.reshape(Pq, K)
    t_at = tids_at.reshape(Pq, K)
    ok = (first_key == delta[..., IX_EXPECT]) & (first_key < hi) \
        & (first_key != SENTINEL)
    consume_ok = jnp.where(sel & (kinds == SCAN_CONSUME), ok, True)
    slot_tid = jnp.where(sel, t_at, jnp.uint32(0))
    return consume_ok, slot_tid


# ---------------------------------------------------------------------------
# roofline accounting: bytes touched per OCC round, jnp vs fused layout
# ---------------------------------------------------------------------------
def occ_round_bytes(B, M, K, C, n_rows, index_caps, n_indexes_P,
                    scan_l: int = SCAN_L):
    """Model the per-round HBM traffic of the index probe + round for the
    two dispatch paths (int32/uint32 words = 4 bytes).  The jnp reference
    materializes a (B, K, cap) key+tid gather PER INDEX; the fused kernel
    touches the concatenated segments once plus O(log cap + L) gathered
    elements per op.  Used by benchmarks/roofline_report."""
    W = 4
    NT = n_rows + n_indexes_P * sum(index_caps)
    round_common = (B * M * (C + 1)            # old values + read TIDs
                    + 2 * (NT + 1)             # lock scatter + gather back
                    + B * M * (C + 1)) * W     # install post-images + TIDs
    jnp_probe = sum(2 * B * K * cap for cap in index_caps) * W
    n_iters = int(max(index_caps)).bit_length() + 1 if index_caps else 0
    fused_probe = (2 * n_indexes_P * sum(index_caps)       # resident segments
                   + B * K * (n_iters + 2 * (scan_l + 1))) * W
    return {"jnp": round_common + jnp_probe,
            "pallas": round_common + fused_probe}
