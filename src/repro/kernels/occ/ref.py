"""Pure-jnp oracles for the fused OCC kernels.

This is the code that used to live inline in ``core/single_master.py`` and
``core/partitioned.py`` — preserved verbatim as the parity reference the
Pallas kernels (``kernel.py``) must match bit-for-bit:

* :func:`locate_index_ops_ref` — resolve one round's index/scan ops against
  the ordered-index state: per-index ``jnp.searchsorted`` + a gathered
  ``SCAN_L + 1`` window.  This is the bandwidth hot spot the fused kernel
  kills: the reference materializes a ``(B, K, cap)`` segment gather per
  index before searching it.
* :func:`occ_round_ref` — one OCC round over the flat row+index-slot lock
  space: gather reads, scatter-min lock acquisition, Silo TID validation
  (or Calvin deterministic locking), TID generation, winner install.
* :func:`step_index_ops_ref` — the partitioned executor's per-queue-slot
  consume validation (searchsorted + first-key/TID gather, no window).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tid as tidlib
from repro.core.ops import (IX_EXPECT, IX_HI, IX_ID, IX_LO, SCAN_CONSUME,
                            apply_op, is_index_kind, reads_index, writes_index)
from repro.storage.index import SCAN_L, SENTINEL, key_partition


def locate_index_ops_ref(index, kinds, delta, n_rows):
    """Resolve index/scan ops of one round against the current index state.

    kinds: (B, K) int32; delta: (B, K, C).  Returns per-op claim addresses,
    scan-window addresses/validity, gathered TIDs and the first in-range key
    (consume validation), all in the flat row+index address space
    [0, n_rows + sum(P * cap_i)) with `no_addr` = the dump slot.
    """
    B, K = kinds.shape
    P = index[0]["key"].shape[0]
    caps = [idx["key"].shape[1] for idx in index]
    no_addr = n_rows + sum(P * c for c in caps)

    lo = delta[..., IX_LO]                                     # (B, K)
    hi = delta[..., IX_HI]
    iid = delta[..., IX_ID]
    p_of = jnp.clip(key_partition(lo), 0, P - 1)

    is_idx = is_index_kind(kinds)
    claim_addr = jnp.full((B, K), no_addr, jnp.int32)
    claim_tid = jnp.zeros((B, K), jnp.uint32)
    scan_addr = jnp.full((B, K, SCAN_L + 1), no_addr, jnp.int32)
    scan_tid = jnp.zeros((B, K, SCAN_L + 1), jnp.uint32)
    scan_valid = jnp.zeros((B, K, SCAN_L + 1), bool)
    first_key = jnp.full((B, K), SENTINEL, jnp.int32)

    base = n_rows
    ss = jax.vmap(jax.vmap(jnp.searchsorted))
    for i, idx in enumerate(index):
        cap = caps[i]
        mine = is_idx & (iid == i)
        p_g = jnp.where(mine, p_of, 0)
        segk = idx["key"][p_g]                                 # (B, K, cap)
        segt = idx["tid"][p_g]
        pos0 = ss(segk, lo)                                    # (B, K)
        window = pos0[..., None] + jnp.arange(SCAN_L + 1, dtype=jnp.int32)
        slots = jnp.clip(window, 0, cap - 1)
        keys_at = jnp.take_along_axis(segk, slots, axis=-1)    # (B, K, L+1)
        tids_at = jnp.take_along_axis(segt, slots, axis=-1)
        addr0 = base + p_of * cap
        # claim the position slot (insert/delete/consume): next-key locking
        cmask = mine & writes_index(kinds)
        cpos = jnp.clip(pos0, 0, cap - 1)
        claim_addr = jnp.where(cmask, addr0 + cpos, claim_addr)
        claim_tid = jnp.where(
            cmask, jnp.take_along_axis(segt, cpos[..., None], -1)[..., 0],
            claim_tid)
        # scan read set: in-range slots + exactly one boundary slot
        smask = mine & reads_index(kinds)
        in_or_boundary = jnp.concatenate(
            [jnp.ones((B, K, 1), bool), keys_at[..., :-1] < hi[..., None]],
            axis=-1) & (window < cap)
        sv = smask[..., None] & in_or_boundary
        scan_addr = jnp.where(sv, addr0[..., None] + slots, scan_addr)
        scan_tid = jnp.where(sv, tids_at, scan_tid)
        scan_valid = scan_valid | sv
        first_key = jnp.where(mine, keys_at[..., 0], first_key)
        base += P * cap

    consume_ok = (first_key == delta[..., IX_EXPECT]) & (first_key < hi) \
        & (first_key != SENTINEL)
    return {"claim_addr": claim_addr, "claim_tid": claim_tid,
            "scan_addr": scan_addr, "scan_tid": scan_tid,
            "scan_valid": scan_valid, "consume_ok": consume_ok,
            "no_addr": no_addr}


def occ_round_ref(val, tidw, rows, kind, delta_v, wmask, amask, active,
                  epoch, last_tid, ix=None, has_claim=None,
                  deterministic=False):
    """One OCC round: gather → lock (scatter-min) → validate → TID → install.

    val: (N, C) int32; tidw: (N,) uint32; rows/kind: (B, M); delta_v the
    guard-stripped op deltas; wmask/amask the guard-resolved primary write
    and read-validation masks; active (B,) the runnable-not-yet-committed
    lanes.  ix (optional) is the :func:`locate_index_ops_ref` dict with
    ``has_claim`` its active claim mask.  Returns
    (val', tidw', commit_now, new_tid, new, w).
    """
    N, C = val.shape
    B, M = rows.shape
    lanes = jnp.arange(B, dtype=jnp.int32)
    SENTINEL_LANE = jnp.int32(B)
    NT = N if ix is None else int(ix["no_addr"])

    old = val[rows]                                                 # (B,M,C)
    rtids = tidw[rows]                                              # (B,M)
    new = apply_op(kind, old, delta_v)

    # --- lock acquisition: scatter-min lane id over claimed rows/slots
    claim_lane = jnp.where(wmask, lanes[:, None], SENTINEL_LANE)
    lock = jnp.full((NT + 1,), SENTINEL_LANE, jnp.int32)
    lock = lock.at[jnp.where(wmask, rows, NT)].min(claim_lane)
    if ix is not None:
        lock = lock.at[jnp.where(has_claim, ix["claim_addr"], NT)].min(
            jnp.where(has_claim, lanes[:, None], SENTINEL_LANE))
    holder = lock[rows]                                             # (B,M)

    wins_all = jnp.all(jnp.where(wmask, holder == lanes[:, None], True), axis=1)
    if ix is not None:
        hold_ic = lock[ix["claim_addr"]]                            # (B,K)
        wins_all &= jnp.all(
            jnp.where(has_claim, hold_ic == lanes[:, None], True), axis=1)
    if deterministic:
        # Calvin: deterministic order, no read validation; a txn runs when
        # it holds all its locks (reads included) in global order
        rlock = jnp.full((NT + 1,), SENTINEL_LANE, jnp.int32)
        rlock = rlock.at[jnp.where(amask, rows, NT)].min(
            jnp.where(amask, lanes[:, None], SENTINEL_LANE))
        if ix is not None:
            sa = jnp.where(ix["scan_valid"] & active[:, None, None],
                           ix["scan_addr"], NT)
            rlock = rlock.at[sa].min(
                jnp.where(sa < NT, lanes[:, None, None], SENTINEL_LANE))
            rlock = rlock.at[jnp.where(has_claim, ix["claim_addr"], NT)
                             ].min(jnp.where(has_claim, lanes[:, None],
                                             SENTINEL_LANE))
        holder_any = rlock[rows]
        commit_now = active & jnp.all(
            jnp.where(amask, holder_any == lanes[:, None], True), axis=1)
        if ix is not None:
            commit_now &= jnp.all(jnp.where(
                ix["scan_valid"] & active[:, None, None],
                rlock[ix["scan_addr"]] == lanes[:, None, None], True),
                axis=(1, 2))
            commit_now &= jnp.all(jnp.where(
                has_claim, rlock[ix["claim_addr"]] == lanes[:, None],
                True), axis=1)
    else:
        # Silo validation: abort if an earlier lane writes anything I
        # read — rows AND scanned index slots (phantom protection)
        dirty = holder < lanes[:, None]                             # (B,M)
        read_ok = jnp.all(~(amask & dirty), axis=1)
        if ix is not None:
            sdirty = ix["scan_valid"] & active[:, None, None] \
                & (lock[ix["scan_addr"]] < lanes[:, None, None])
            read_ok &= ~jnp.any(sdirty, axis=(1, 2))
        commit_now = active & wins_all & read_ok

    # --- TID generation (criteria a, b, c)
    obs = jnp.max(jnp.where(amask, rtids, jnp.uint32(0)), axis=1)
    if ix is not None:
        obs = jnp.maximum(obs, jnp.max(
            jnp.where(ix["scan_valid"], ix["scan_tid"], jnp.uint32(0)),
            axis=(1, 2)))
        obs = jnp.maximum(obs, jnp.max(
            jnp.where(has_claim, ix["claim_tid"], jnp.uint32(0)), axis=1))
    new_tid = tidlib.next_tid(epoch, obs, last_tid)                 # (B,)

    # --- install: winners only (unique per row by construction)
    w = wmask & commit_now[:, None]
    wrows = jnp.where(w, rows, N)
    val_pad = jnp.concatenate([val, jnp.zeros((1, C), val.dtype)], 0)
    val = val_pad.at[wrows.reshape(-1)].set(
        new.reshape(-1, C))[:N]
    tid_pad = jnp.concatenate([tidw, jnp.zeros((1,), tidw.dtype)], 0)
    tidw = tid_pad.at[wrows.reshape(-1)].set(
        jnp.broadcast_to(new_tid[:, None], (B, M)).reshape(-1))[:N]
    return val, tidw, commit_now, new_tid, new, w


def step_index_ops_ref(index, kinds, delta):
    """Per-partition searchsorted resolution of one queue slot's index ops.

    kinds: (P, K); delta: (P, K, C).  Returns (consume_ok (P, K),
    slot_tid (P, K)) — the TID of each op's position slot (criterion a).
    """
    lo = delta[..., IX_LO]
    hi = delta[..., IX_HI]
    iid = delta[..., IX_ID]
    P, K = kinds.shape
    consume_ok = jnp.ones((P, K), bool)
    slot_tid = jnp.zeros((P, K), jnp.uint32)
    ss = jax.vmap(lambda seg, ks: jax.vmap(
        lambda k: jnp.searchsorted(seg, k))(ks))
    for i, idx in enumerate(index):
        cap = idx["key"].shape[1]
        pos0 = jnp.clip(ss(idx["key"], lo), 0, cap - 1)        # (P, K)
        first_key = jnp.take_along_axis(idx["key"], pos0, axis=1)
        t_at = jnp.take_along_axis(idx["tid"], pos0, axis=1)
        mine = iid == i
        ok = (first_key == delta[..., IX_EXPECT]) & (first_key < hi) \
            & (first_key != SENTINEL)
        consume_ok = jnp.where(mine & (kinds == SCAN_CONSUME), ok, consume_ok)
        slot_tid = jnp.where(mine, t_at, slot_tid)
    return consume_ok, slot_tid
