"""Pallas TPU kernels: fused OCC round + fused index scan window.

Two kernels cover the single-master hot path (ROADMAP "Pallas OCC kernels"):

* ``scan_window_pallas`` — the ordered-index probe.  The jnp reference
  resolves each op's range scan by materializing a ``(B, K, cap)`` gather of
  the whole segment per index before ``searchsorted`` — at TPC-C scale that
  is hundreds of MB of HBM traffic per OCC round.  The kernel keeps the
  concatenated segments resident (one ``(S,)`` key array + ``(S,)`` TID
  array), scalar-prefetches the per-query ``q``/``seg_base``/``seg_cap``
  streams to SMEM (``pltpu.PrefetchScalarGridSpec``) so each grid step can
  address its probe window before the DMA lands, runs a vectorized
  lower-bound binary search per op (``n_iters`` rounds of one gathered
  compare each) and gathers only the bounded ``n_slots`` window —
  O(B·K·(log cap + L)) elements touched instead of O(B·K·cap).  The grid
  tiles the query stream (``block_q``); on CPU the auto block is the whole
  stream (one grid step — interpret-mode cost unchanged).

* ``occ_round_pallas`` — one OCC round over the flat row+index-slot lock
  space, lowered as a three-launch pipeline so every launch tiles a
  hardware-sized grid instead of holding the whole round in one VMEM
  footprint:

    1. lock build   — grid over tiles of the flat ``NT+1`` lock space; each
                      tile scatter-mins the claim stream (lane ids of write
                      rows + index-slot claims) into a tile-local running
                      array with a dump slot, O(claims) work per tile.
    2. validate     — grid over lane blocks; ``val``/``tidw``/the built lock
                      array stay resident while each block gathers its
                      reads, applies ops, checks lock ownership + Silo read
                      validation (or Calvin deterministic locking) and
                      generates TIDs.
    3. install      — grid over row tiles; winner post-images scatter into
                      each tile through clipped tile-local addresses.

  ``min`` is commutative and winner rows are unique, so the tiling is
  bit-identical to the former monolithic launch for every block size.

Both kernels run under ``interpret=True`` on CPU (the tier-1/CI path — no
TPU in the container) with auto single-tile blocks, and are bit-identical
to ``ref.py`` by construction; ``tests/test_occ_kernels.py`` enforces this
on random op batches including lock-conflict and phantom-abort
interleavings, with forced multi-tile grids.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tid as tidlib
from repro.core.ops import apply_op


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# fused index scan window: binary search + bounded window gather
# ---------------------------------------------------------------------------
def _scan_window_kernel(q_ref, base_ref, cap_ref, key_ref, tid_ref,
                        pos_ref, keys_ref, tids_ref, *, n_slots, n_iters,
                        block_q):
    t = pl.program_id(0)
    fk = key_ref[...]                                  # (S,) int32, resident
    ft = tid_ref[...]                                  # (S,) uint32, resident
    # per-query streams live in SMEM (scalar prefetch): slice this grid
    # step's block
    sl = (pl.dslice(t * block_q, block_q),)
    q = pl.load(q_ref, sl)                             # (block_q,) query keys
    base = pl.load(base_ref, sl)                       # (block_q,) seg starts
    cap = pl.load(cap_ref, sl)                         # (block_q,) seg lens

    # vectorized lower bound: pos = first slot with seg[pos] >= q
    lo = jnp.zeros(q.shape, jnp.int32)
    hi = cap

    def body(_, lh):
        lo, hi = lh
        live = lo < hi
        mid = (lo + hi) // 2                           # in [lo, hi) ⊂ [0,cap)
        kmid = fk[base + jnp.minimum(mid, cap - 1)]
        right = live & (kmid < q)
        return (jnp.where(right, mid + 1, lo),
                jnp.where(live & ~right, mid, hi))

    lo, hi = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
    pos_ref[...] = lo
    window = lo[:, None] + jnp.arange(n_slots, dtype=jnp.int32)[None, :]
    slots = jnp.clip(window, 0, cap[:, None] - 1)
    gidx = base[:, None] + slots                       # (block_q, n_slots)
    keys_ref[...] = fk[gidx]
    tids_ref[...] = ft[gidx]


@functools.partial(jax.jit,
                   static_argnames=("n_slots", "n_iters", "interpret",
                                    "block_q"))
def scan_window_pallas(flat_key, flat_tid, q, seg_base, seg_cap, *,
                       n_slots: int, n_iters: int, interpret: bool = True,
                       block_q: int | None = None):
    """flat_key/flat_tid: (S,) concatenated sorted segments; q/seg_base/
    seg_cap: (Q,) per-query key, segment start offset and segment length.
    Returns (pos0 (Q,) == searchsorted-left, keys_at (Q, n_slots),
    tids_at (Q, n_slots)) with window slots clipped to the segment.

    ``block_q`` tiles the query stream over a grid (the per-query streams
    ride SMEM scalar prefetch); ``None`` = one tile covering all queries —
    the CPU/interpret default.
    """
    Q = q.shape[0]
    if block_q is None:
        block_q = Q
    Qp = _round_up(Q, block_q)
    if Qp != Q:
        # padded probes scan a 1-slot window at segment offset 0 — discarded
        q = jnp.concatenate([q, jnp.zeros((Qp - Q,), q.dtype)])
        seg_base = jnp.concatenate(
            [seg_base, jnp.zeros((Qp - Q,), seg_base.dtype)])
        seg_cap = jnp.concatenate(
            [seg_cap, jnp.ones((Qp - Q,), seg_cap.dtype)])
    kernel = functools.partial(_scan_window_kernel, n_slots=n_slots,
                               n_iters=n_iters, block_q=block_q)
    S = flat_key.shape[0]
    pos, keys, tids = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(Qp // block_q,),
            in_specs=[pl.BlockSpec((S,), lambda i, q, b, c: (0,)),
                      pl.BlockSpec((S,), lambda i, q, b, c: (0,))],
            out_specs=[
                pl.BlockSpec((block_q,), lambda i, q, b, c: (i,)),
                pl.BlockSpec((block_q, n_slots), lambda i, q, b, c: (i, 0)),
                pl.BlockSpec((block_q, n_slots), lambda i, q, b, c: (i, 0)),
            ]),
        out_shape=[jax.ShapeDtypeStruct((Qp,), jnp.int32),
                   jax.ShapeDtypeStruct((Qp, n_slots), flat_key.dtype),
                   jax.ShapeDtypeStruct((Qp, n_slots), flat_tid.dtype)],
        interpret=interpret,
    )(q, seg_base, seg_cap, flat_key, flat_tid)
    return pos[:Q], keys[:Q], tids[:Q]


# ---------------------------------------------------------------------------
# OCC round, launch 1/3: lock build over tiles of the flat lock space
# ---------------------------------------------------------------------------
def _lock_build_kernel(addr_ref, lane_ref, lock_ref, *, block_nt,
                       sentinel_lane):
    t = pl.program_id(0)
    base = t * block_nt
    local = addr_ref[...] - base                       # (Kc,)
    inside = (local >= 0) & (local < block_nt)
    tgt = jnp.where(inside, local, block_nt)           # dump slot block_nt
    run = jnp.full((block_nt + 1,), sentinel_lane, jnp.int32)
    run = run.at[tgt].min(lane_ref[...])
    lock_ref[...] = run[:block_nt]


def _lock_build(addr, lane, *, NT, B, block_nt, interpret):
    """Scatter-min lane ids over the flat (NT+1,) lock space, tiled.

    addr/lane: (Kc,) claim streams — masked claims carry addr == NT (the
    dump slot) and lane == B (the sentinel lane), so ``min`` ignores them.
    ``min`` is commutative: any tiling is bit-identical to one global
    scatter-min.
    """
    NTp = _round_up(NT + 1, block_nt)
    Kc = addr.shape[0]
    lock = pl.pallas_call(
        functools.partial(_lock_build_kernel, block_nt=block_nt,
                          sentinel_lane=B),
        grid=(NTp // block_nt,),
        in_specs=[pl.BlockSpec((Kc,), lambda i: (0,)),
                  pl.BlockSpec((Kc,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_nt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((NTp,), jnp.int32),
        interpret=interpret,
    )(addr, lane)
    return lock[:NT + 1]


# ---------------------------------------------------------------------------
# OCC round, launch 2/3: per-lane validate + TID generation over lane blocks
# ---------------------------------------------------------------------------
def _validate_kernel(val_ref, tidw_ref, lock_ref, rows_ref, kind_ref,
                     delta_ref, wmask_ref, amask_ref, active_ref, epoch_ref,
                     last_tid_ref, *rest, NT, block_b, deterministic,
                     has_ix):
    it = iter(rest)
    rlock_ref = next(it) if deterministic else None
    if has_ix:
        claim_addr_ref, claim_tid_ref = next(it), next(it)
        scan_addr_ref, scan_tid_ref = next(it), next(it)
        scan_valid_ref, has_claim_ref = next(it), next(it)
    commit_out, ntid_out, new_out, w_out = it

    val = val_ref[...]                                              # (N,C)
    tidw = tidw_ref[...]                                            # (N,)
    lock = lock_ref[...]                                            # (NT+1,)
    rows = rows_ref[...]                                            # (b,M)
    kind = kind_ref[...]
    delta_v = delta_ref[...]
    wmask = wmask_ref[...]
    amask = amask_ref[...]
    active = active_ref[...]                                        # (b,)
    epoch = epoch_ref[0]
    last_tid = last_tid_ref[...]

    t = pl.program_id(0)
    # global lane ids of this block — lock holders are global lane ids
    lanes = t * block_b + jnp.arange(block_b, dtype=jnp.int32)      # (b,)

    old = val[rows]                                                 # (b,M,C)
    rtids = tidw[rows]                                              # (b,M)
    new = apply_op(kind, old, delta_v)

    holder = lock[rows]                                             # (b,M)
    wins_all = jnp.all(jnp.where(wmask, holder == lanes[:, None], True),
                       axis=1)
    if has_ix:
        claim_addr = claim_addr_ref[...]                            # (b,K)
        claim_tid = claim_tid_ref[...]
        scan_addr = scan_addr_ref[...]                              # (b,K,L+1)
        scan_tid = scan_tid_ref[...]
        scan_valid = scan_valid_ref[...]
        has_claim = has_claim_ref[...]
        hold_ic = lock[claim_addr]                                  # (b,K)
        wins_all &= jnp.all(
            jnp.where(has_claim, hold_ic == lanes[:, None], True), axis=1)
    if deterministic:
        rlock = rlock_ref[...]                                      # (NT+1,)
        holder_any = rlock[rows]
        commit_now = active & jnp.all(
            jnp.where(amask, holder_any == lanes[:, None], True), axis=1)
        if has_ix:
            commit_now &= jnp.all(jnp.where(
                scan_valid & active[:, None, None],
                rlock[scan_addr] == lanes[:, None, None], True), axis=(1, 2))
            commit_now &= jnp.all(jnp.where(
                has_claim, rlock[claim_addr] == lanes[:, None], True), axis=1)
    else:
        dirty = holder < lanes[:, None]                             # (b,M)
        read_ok = jnp.all(~(amask & dirty), axis=1)
        if has_ix:
            sdirty = scan_valid & active[:, None, None] \
                & (lock[scan_addr] < lanes[:, None, None])
            read_ok &= ~jnp.any(sdirty, axis=(1, 2))
        commit_now = active & wins_all & read_ok

    # TID generation (criteria a, b, c)
    obs = jnp.max(jnp.where(amask, rtids, jnp.uint32(0)), axis=1)
    if has_ix:
        obs = jnp.maximum(obs, jnp.max(
            jnp.where(scan_valid, scan_tid, jnp.uint32(0)), axis=(1, 2)))
        obs = jnp.maximum(obs, jnp.max(
            jnp.where(has_claim, claim_tid, jnp.uint32(0)), axis=1))
    new_tid = tidlib.next_tid(epoch, obs, last_tid)                 # (b,)

    commit_out[...] = commit_now
    ntid_out[...] = new_tid
    new_out[...] = new
    w_out[...] = wmask & commit_now[:, None]


# ---------------------------------------------------------------------------
# OCC round, launch 3/3: winner install over row tiles
# ---------------------------------------------------------------------------
def _install_kernel(val_ref, tidw_ref, wrows_ref, newf_ref, wtid_ref,
                    val_out, tid_out, *, block_rows):
    t = pl.program_id(0)
    base = t * block_rows
    local = wrows_ref[...] - base                      # (B*M,)
    inside = (local >= 0) & (local < block_rows)
    tgt = jnp.where(inside, local, block_rows)         # dump row block_rows
    C = val_ref.shape[1]
    v = jnp.concatenate([val_ref[...],
                         jnp.zeros((1, C), val_ref.dtype)], 0)
    val_out[...] = v.at[tgt].set(newf_ref[...])[:block_rows]
    td = jnp.concatenate([tidw_ref[...],
                          jnp.zeros((1,), tidw_ref.dtype)], 0)
    tid_out[...] = td.at[tgt].set(wtid_ref[...])[:block_rows]


def _install(val, tidw, wrows, newf, wtids, *, block_rows, interpret):
    """Scatter winner post-images + TIDs into row tiles.  Winner rows are
    unique (one lock holder per row), so tile-local ``.set`` scatters are
    conflict-free; masked lanes address the per-tile dump row."""
    N, C = val.shape
    Np = _round_up(N, block_rows)
    if Np != N:
        val = jnp.concatenate(
            [val, jnp.zeros((Np - N, C), val.dtype)], 0)
        tidw = jnp.concatenate(
            [tidw, jnp.zeros((Np - N,), tidw.dtype)], 0)
    Kw = wrows.shape[0]
    val2, tid2 = pl.pallas_call(
        functools.partial(_install_kernel, block_rows=block_rows),
        grid=(Np // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows,), lambda i: (i,)),
                  pl.BlockSpec((Kw,), lambda i: (0,)),
                  pl.BlockSpec((Kw, C), lambda i: (0, 0)),
                  pl.BlockSpec((Kw,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((Np, C), val.dtype),
                   jax.ShapeDtypeStruct((Np,), tidw.dtype)],
        interpret=interpret,
    )(val, tidw, wrows, newf, wtids)
    return val2[:N], tid2[:N]


@functools.partial(jax.jit,
                   static_argnames=("NT", "deterministic", "interpret",
                                    "block_nt", "block_b", "block_rows"))
def occ_round_pallas(val, tidw, rows, kind, delta_v, wmask, amask, active,
                     epoch_arr, last_tid, ix_args=None, *, NT: int,
                     deterministic: bool = False, interpret: bool = True,
                     block_nt: int | None = None, block_b: int | None = None,
                     block_rows: int | None = None):
    """One OCC round as the lock-build → validate → install pipeline.

    ``ix_args`` (optional) is the tuple (claim_addr, claim_tid, scan_addr,
    scan_tid, scan_valid, has_claim); ``NT`` the flat lock-space size.
    ``block_nt``/``block_b``/``block_rows`` tile the lock space, the lane
    batch and the row space respectively; ``None`` = one tile (the
    CPU/interpret default, which degenerates to the former monolithic
    cost).  Returns (val', tidw', commit_now, new_tid, new, w) —
    bit-identical to ``ref.occ_round_ref`` for every block size.
    """
    N, C = val.shape
    B, M = rows.shape
    has_ix = ix_args is not None
    if block_nt is None:
        block_nt = NT + 1
    if block_b is None:
        block_b = B
    if block_rows is None:
        block_rows = N

    lanes = jnp.arange(B, dtype=jnp.int32)
    SB = jnp.int32(B)                                  # sentinel lane

    # --- launch 1: write/claim lock over the flat row+index-slot space ---
    addr = jnp.where(wmask, rows, NT).reshape(-1)
    lane = jnp.where(wmask, lanes[:, None], SB).reshape(-1)
    if has_ix:
        (claim_addr, claim_tid, scan_addr, scan_tid, scan_valid,
         has_claim) = ix_args
        addr = jnp.concatenate(
            [addr, jnp.where(has_claim, claim_addr, NT).reshape(-1)])
        lane = jnp.concatenate(
            [lane, jnp.where(has_claim, lanes[:, None], SB).reshape(-1)])
    lock = _lock_build(addr, lane, NT=NT, B=B, block_nt=block_nt,
                       interpret=interpret)

    rlock = None
    if deterministic:
        # Calvin-style: every access (reads included) claims its address
        raddr = jnp.where(amask, rows, NT).reshape(-1)
        rlane = jnp.where(amask, lanes[:, None], SB).reshape(-1)
        if has_ix:
            sa = jnp.where(scan_valid & active[:, None, None], scan_addr, NT)
            raddr = jnp.concatenate([
                raddr, sa.reshape(-1),
                jnp.where(has_claim, claim_addr, NT).reshape(-1)])
            rlane = jnp.concatenate([
                rlane,
                jnp.where(sa < NT, lanes[:, None, None], SB).reshape(-1),
                jnp.where(has_claim, lanes[:, None], SB).reshape(-1)])
        rlock = _lock_build(raddr, rlane, NT=NT, B=B, block_nt=block_nt,
                            interpret=interpret)

    # --- launch 2: validate + TID over lane blocks -----------------------
    Bp = _round_up(B, block_b)
    def pad_b(a):
        if Bp == B:
            return a
        pad = jnp.zeros((Bp - B,) + a.shape[1:], a.dtype)
        return jnp.concatenate([a, pad], 0)

    lane_args = [rows, kind, delta_v, wmask, amask, active, last_tid]
    if has_ix:
        lane_args += [claim_addr, claim_tid, scan_addr, scan_tid,
                      scan_valid, has_claim]
    lane_args = [pad_b(a) for a in lane_args]
    (rows_p, kind_p, delta_p, wmask_p, amask_p, active_p, last_p,
     *ix_p) = lane_args

    def lane_spec(a):
        bs = (block_b,) + a.shape[1:]
        nd = a.ndim
        return pl.BlockSpec(bs, lambda i, nd=nd: (i,) + (0,) * (nd - 1))

    in_specs = [pl.BlockSpec((N, C), lambda i: (0, 0)),      # val resident
                pl.BlockSpec((N,), lambda i: (0,)),          # tidw resident
                pl.BlockSpec((NT + 1,), lambda i: (0,))]     # lock resident
    args = [val, tidw, lock]
    if deterministic:
        pass  # rlock inserted after the per-lane refs in kernel arg order
    in_specs += [lane_spec(rows_p), lane_spec(kind_p), lane_spec(delta_p),
                 lane_spec(wmask_p), lane_spec(amask_p), lane_spec(active_p),
                 pl.BlockSpec((1,), lambda i: (0,)),         # epoch
                 lane_spec(last_p)]
    args += [rows_p, kind_p, delta_p, wmask_p, amask_p, active_p,
             epoch_arr, last_p]
    if deterministic:
        in_specs.append(pl.BlockSpec((NT + 1,), lambda i: (0,)))
        args.append(rlock)
    for a in ix_p:
        in_specs.append(lane_spec(a))
        args.append(a)

    kernel = functools.partial(_validate_kernel, NT=NT, block_b=block_b,
                               deterministic=deterministic, has_ix=has_ix)
    commit_now, new_tid, new, w = pl.pallas_call(
        kernel,
        grid=(Bp // block_b,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block_b,), lambda i: (i,)),
                   pl.BlockSpec((block_b,), lambda i: (i,)),
                   pl.BlockSpec((block_b, M, C), lambda i: (i, 0, 0)),
                   pl.BlockSpec((block_b, M), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Bp,), jnp.bool_),
                   jax.ShapeDtypeStruct((Bp,), jnp.uint32),
                   jax.ShapeDtypeStruct((Bp, M, C), val.dtype),
                   jax.ShapeDtypeStruct((Bp, M), jnp.bool_)],
        interpret=interpret,
    )(*args)
    commit_now, new_tid = commit_now[:B], new_tid[:B]
    new, w = new[:B], w[:B]

    # --- launch 3: winner install over row tiles -------------------------
    wrows = jnp.where(w, rows, N).reshape(-1)
    newf = new.reshape(-1, C)
    wtids = jnp.broadcast_to(new_tid[:, None], (B, M)).reshape(-1)
    val2, tid2 = _install(val, tidw, wrows, newf, wtids,
                          block_rows=block_rows, interpret=interpret)
    return val2, tid2, commit_now, new_tid, new, w
