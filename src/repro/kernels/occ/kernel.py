"""Pallas TPU kernels: fused OCC round + fused index scan window.

Two kernels cover the single-master hot path (ROADMAP "Pallas OCC kernels"):

* ``scan_window_pallas`` — the ordered-index probe.  The jnp reference
  resolves each op's range scan by materializing a ``(B, K, cap)`` gather of
  the whole segment per index before ``searchsorted`` — at TPC-C scale that
  is hundreds of MB of HBM traffic per OCC round.  The kernel keeps the
  concatenated segments resident (one ``(S,)`` key array + ``(S,)`` TID
  array), runs a vectorized lower-bound binary search per op (``n_iters``
  rounds of one gathered compare each) and gathers only the bounded
  ``n_slots`` window — O(B·K·(log cap + L)) elements touched instead of
  O(B·K·cap).

* ``occ_round_pallas`` — one fused OCC round over the flat row+index-slot
  lock space: gather reads + TIDs, apply ops, scatter-min lock acquisition,
  Silo read validation (or Calvin deterministic locking), TID generation,
  and winner install — one kernel launch per round with ``val``/``tidw``/
  the lock array all VMEM-resident for the whole round, instead of the
  reference's separate gather/scatter passes.

Both kernels run under ``interpret=True`` on CPU (the tier-1/CI path — no
TPU in the container) and are bit-identical to ``ref.py`` by construction;
``tests/test_occ_kernels.py`` enforces this on random op batches including
lock-conflict and phantom-abort interleavings.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import tid as tidlib
from repro.core.ops import apply_op


# ---------------------------------------------------------------------------
# fused index scan window: binary search + bounded window gather
# ---------------------------------------------------------------------------
def _scan_window_kernel(key_ref, tid_ref, q_ref, base_ref, cap_ref,
                        pos_ref, keys_ref, tids_ref, *, n_slots, n_iters):
    fk = key_ref[...]                                  # (S,) int32
    ft = tid_ref[...]                                  # (S,) uint32
    q = q_ref[...]                                     # (Q,) query keys
    base = base_ref[...]                               # (Q,) segment starts
    cap = cap_ref[...]                                 # (Q,) segment lengths

    # vectorized lower bound: pos = first slot with seg[pos] >= q
    lo = jnp.zeros(q.shape, jnp.int32)
    hi = cap

    def body(_, lh):
        lo, hi = lh
        live = lo < hi
        mid = (lo + hi) // 2                           # in [lo, hi) ⊂ [0,cap)
        kmid = fk[base + jnp.minimum(mid, cap - 1)]
        right = live & (kmid < q)
        return (jnp.where(right, mid + 1, lo),
                jnp.where(live & ~right, mid, hi))

    lo, hi = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
    pos_ref[...] = lo
    window = lo[:, None] + jnp.arange(n_slots, dtype=jnp.int32)[None, :]
    slots = jnp.clip(window, 0, cap[:, None] - 1)
    gidx = base[:, None] + slots                       # (Q, n_slots)
    keys_ref[...] = fk[gidx]
    tids_ref[...] = ft[gidx]


@functools.partial(jax.jit,
                   static_argnames=("n_slots", "n_iters", "interpret"))
def scan_window_pallas(flat_key, flat_tid, q, seg_base, seg_cap, *,
                       n_slots: int, n_iters: int, interpret: bool = True):
    """flat_key/flat_tid: (S,) concatenated sorted segments; q/seg_base/
    seg_cap: (Q,) per-query key, segment start offset and segment length.
    Returns (pos0 (Q,) == searchsorted-left, keys_at (Q, n_slots),
    tids_at (Q, n_slots)) with window slots clipped to the segment."""
    Q = q.shape[0]
    kernel = functools.partial(_scan_window_kernel, n_slots=n_slots,
                               n_iters=n_iters)
    return pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct((Q,), jnp.int32),
                   jax.ShapeDtypeStruct((Q, n_slots), flat_key.dtype),
                   jax.ShapeDtypeStruct((Q, n_slots), flat_tid.dtype)],
        interpret=interpret,
    )(flat_key, flat_tid, q, seg_base, seg_cap)


# ---------------------------------------------------------------------------
# fused OCC round: gather → lock → validate → TID → install, one launch
# ---------------------------------------------------------------------------
def _occ_round_kernel(val_ref, tidw_ref, rows_ref, kind_ref, delta_ref,
                      wmask_ref, amask_ref, active_ref, epoch_ref,
                      last_tid_ref, *rest, NT, deterministic, has_ix):
    if has_ix:
        (claim_addr_ref, claim_tid_ref, scan_addr_ref, scan_tid_ref,
         scan_valid_ref, has_claim_ref,
         val_out, tid_out, commit_out, ntid_out, new_out, w_out) = rest
    else:
        (val_out, tid_out, commit_out, ntid_out, new_out, w_out) = rest

    val = val_ref[...]                                              # (N,C)
    tidw = tidw_ref[...]                                            # (N,)
    rows = rows_ref[...]                                            # (B,M)
    kind = kind_ref[...]
    delta_v = delta_ref[...]
    wmask = wmask_ref[...]
    amask = amask_ref[...]
    active = active_ref[...]                                        # (B,)
    epoch = epoch_ref[0]
    last_tid = last_tid_ref[...]

    N, C = val.shape
    B, M = rows.shape
    lanes = jnp.arange(B, dtype=jnp.int32)
    SENTINEL_LANE = jnp.int32(B)

    old = val[rows]                                                 # (B,M,C)
    rtids = tidw[rows]                                              # (B,M)
    new = apply_op(kind, old, delta_v)

    # lock acquisition: scatter-min lane id over claimed rows/slots — the
    # lock array lives in VMEM for the whole round
    claim_lane = jnp.where(wmask, lanes[:, None], SENTINEL_LANE)
    lock = jnp.full((NT + 1,), SENTINEL_LANE, jnp.int32)
    lock = lock.at[jnp.where(wmask, rows, NT)].min(claim_lane)
    if has_ix:
        claim_addr = claim_addr_ref[...]                            # (B,K)
        claim_tid = claim_tid_ref[...]
        scan_addr = scan_addr_ref[...]                              # (B,K,L+1)
        scan_tid = scan_tid_ref[...]
        scan_valid = scan_valid_ref[...]
        has_claim = has_claim_ref[...]
        lock = lock.at[jnp.where(has_claim, claim_addr, NT)].min(
            jnp.where(has_claim, lanes[:, None], SENTINEL_LANE))
    holder = lock[rows]                                             # (B,M)

    wins_all = jnp.all(jnp.where(wmask, holder == lanes[:, None], True),
                       axis=1)
    if has_ix:
        hold_ic = lock[claim_addr]                                  # (B,K)
        wins_all &= jnp.all(
            jnp.where(has_claim, hold_ic == lanes[:, None], True), axis=1)
    if deterministic:
        rlock = jnp.full((NT + 1,), SENTINEL_LANE, jnp.int32)
        rlock = rlock.at[jnp.where(amask, rows, NT)].min(
            jnp.where(amask, lanes[:, None], SENTINEL_LANE))
        if has_ix:
            sa = jnp.where(scan_valid & active[:, None, None], scan_addr, NT)
            rlock = rlock.at[sa].min(
                jnp.where(sa < NT, lanes[:, None, None], SENTINEL_LANE))
            rlock = rlock.at[jnp.where(has_claim, claim_addr, NT)].min(
                jnp.where(has_claim, lanes[:, None], SENTINEL_LANE))
        holder_any = rlock[rows]
        commit_now = active & jnp.all(
            jnp.where(amask, holder_any == lanes[:, None], True), axis=1)
        if has_ix:
            commit_now &= jnp.all(jnp.where(
                scan_valid & active[:, None, None],
                rlock[scan_addr] == lanes[:, None, None], True), axis=(1, 2))
            commit_now &= jnp.all(jnp.where(
                has_claim, rlock[claim_addr] == lanes[:, None], True), axis=1)
    else:
        dirty = holder < lanes[:, None]                             # (B,M)
        read_ok = jnp.all(~(amask & dirty), axis=1)
        if has_ix:
            sdirty = scan_valid & active[:, None, None] \
                & (lock[scan_addr] < lanes[:, None, None])
            read_ok &= ~jnp.any(sdirty, axis=(1, 2))
        commit_now = active & wins_all & read_ok

    # TID generation (criteria a, b, c)
    obs = jnp.max(jnp.where(amask, rtids, jnp.uint32(0)), axis=1)
    if has_ix:
        obs = jnp.maximum(obs, jnp.max(
            jnp.where(scan_valid, scan_tid, jnp.uint32(0)), axis=(1, 2)))
        obs = jnp.maximum(obs, jnp.max(
            jnp.where(has_claim, claim_tid, jnp.uint32(0)), axis=1))
    new_tid = tidlib.next_tid(epoch, obs, last_tid)                 # (B,)

    # install: winners only (unique per row by construction)
    w = wmask & commit_now[:, None]
    wrows = jnp.where(w, rows, N)
    val_pad = jnp.concatenate([val, jnp.zeros((1, C), val.dtype)], 0)
    val_out[...] = val_pad.at[wrows.reshape(-1)].set(new.reshape(-1, C))[:N]
    tid_pad = jnp.concatenate([tidw, jnp.zeros((1,), tidw.dtype)], 0)
    tid_out[...] = tid_pad.at[wrows.reshape(-1)].set(
        jnp.broadcast_to(new_tid[:, None], (B, M)).reshape(-1))[:N]
    commit_out[...] = commit_now
    ntid_out[...] = new_tid
    new_out[...] = new
    w_out[...] = w


@functools.partial(jax.jit,
                   static_argnames=("NT", "deterministic", "interpret"))
def occ_round_pallas(val, tidw, rows, kind, delta_v, wmask, amask, active,
                     epoch_arr, last_tid, ix_args=None, *, NT: int,
                     deterministic: bool = False, interpret: bool = True):
    """One fused OCC round.  ``ix_args`` (optional) is the tuple
    (claim_addr, claim_tid, scan_addr, scan_tid, scan_valid, has_claim);
    ``NT`` the flat lock-space size.  Returns
    (val', tidw', commit_now, new_tid, new, w) — bit-identical to
    ``ref.occ_round_ref``."""
    N, C = val.shape
    B, M = rows.shape
    has_ix = ix_args is not None
    kernel = functools.partial(_occ_round_kernel, NT=NT,
                               deterministic=deterministic, has_ix=has_ix)
    args = [val, tidw, rows, kind, delta_v, wmask, amask, active,
            epoch_arr, last_tid]
    if has_ix:
        args += list(ix_args)
    return pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct((N, C), val.dtype),
                   jax.ShapeDtypeStruct((N,), tidw.dtype),
                   jax.ShapeDtypeStruct((B,), jnp.bool_),
                   jax.ShapeDtypeStruct((B,), jnp.uint32),
                   jax.ShapeDtypeStruct((B, M, C), val.dtype),
                   jax.ShapeDtypeStruct((B, M), jnp.bool_)],
        interpret=interpret,
    )(*args)
