"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel directory holds kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd public wrapper, interpret=True off-TPU) and ref.py
(pure-jnp oracle used by the allclose test sweeps):

* occ             -- fused single-master OCC round (gather/lock/validate/
                     install over the flat row+index lock space) + the
                     searchsorted/window index probe (SS4.2; ref.py is the
                     exact former inline executor code);
* index_merge     -- fused sorted-segment index maintenance (delete-compact
                     + both rank passes + merged scatter in one launch,
                     tiled over destination slots; ref.py is the exact
                     former storage/index.py segment_apply body);
* thomas_merge    -- replication-stream apply under the Thomas write rule
                     (the paper's replica-side hot loop, SS3/SS5);
* flash_attention -- online-softmax attention; causal / window / encoder /
                     slot-cache decode in one kernel; GQA via kv index_map;
* mamba2_ssd      -- chunked state-space-duality scan (Mamba-2 / Hymba);
* rmsnorm         -- fused residual-add + RMSNorm epilogue.
"""
from repro.kernels.flash_attention import ops as flash_attention
from repro.kernels.index_merge import ops as index_merge
from repro.kernels.mamba2_ssd import ops as mamba2_ssd
from repro.kernels.occ import ops as occ
from repro.kernels.rmsnorm import ops as rmsnorm
from repro.kernels.thomas_merge import ops as thomas_merge

__all__ = ["flash_attention", "index_merge", "mamba2_ssd", "occ",
           "rmsnorm", "thomas_merge"]
