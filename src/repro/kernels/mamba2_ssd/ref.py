"""Pure-jnp oracle for the SSD scan: sequential token-by-token recurrence.

h_t = h_{t-1} * exp(dt_t * A) + B_t ⊗ (x_t * dt_t);   y_t = C_t · h_t
(x pre-multiplied by dt and log-decay precomputed by the caller, matching the
kernel interface).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xdt, logd, Bv, Cv):
    """xdt: (BH, S, P) f32 (= x*dt); logd: (BH, S) f32 (= dt*A);
    Bv, Cv: (BH, S, N) f32 -> y (BH, S, P), h_final (BH, P, N)."""
    BH, S, P = xdt.shape
    N = Bv.shape[-1]

    def step(h, inp):
        x_t, ld_t, b_t, c_t = inp
        h = h * jnp.exp(ld_t)[:, None, None] + x_t[..., None] * b_t[:, None, :]
        y = jnp.einsum("bpn,bn->bp", h, c_t)
        return h, y

    h0 = jnp.zeros((BH, P, N), jnp.float32)
    hf, ys = jax.lax.scan(
        step, h0,
        (xdt.transpose(1, 0, 2), logd.transpose(1, 0),
         Bv.transpose(1, 0, 2), Cv.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2), hf
