"""Pallas TPU kernel: chunked SSD (state-space duality, Mamba-2).

Grid (BH, S/Q) with (parallel, arbitrary) semantics: the (P, N) fp32 state
lives in VMEM scratch and flows across chunk steps.  Per chunk the kernel
does the quadratic intra-chunk part on the MXU — L ⊙ (C Bᵀ) then @ (x·dt) —
plus the rank-1-per-token inter-chunk correction from the carried state, and
updates the state with the decay-weighted chunk contribution.  This maps the
SSD algorithm's "matmul-rich within chunks, recurrence across chunks"
structure directly onto MXU + VMEM (see DESIGN.md §Hardware adaptation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ssd_kernel(xdt_ref, logd_ref, b_ref, c_ref, y_ref, hfin_ref, h_ref,
                *, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xdt = xdt_ref[0]                                # (Q, P) f32
    logd = logd_ref[0]                              # (Q,)  f32
    Bv = b_ref[0]                                   # (Q, N)
    Cv = c_ref[0]                                   # (Q, N)
    Q = xdt.shape[0]

    cs = jnp.cumsum(logd)                           # (Q,)
    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j
    diff = cs[:, None] - cs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(Cv, Bv, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q, Q)
    y_intra = jax.lax.dot_general(CB * L, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: y_i += exp(cs_i) * C_i · h      (h: (P, N))
    h = h_ref[...]
    y_inter = jax.lax.dot_general(Cv, h, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (Q, P)
    y_ref[0] = (y_intra + y_inter * jnp.exp(cs)[:, None]).astype(y_ref.dtype)

    # state update: h' = h * exp(cs_Q) + Σ_j exp(cs_Q - cs_j) xdt_j ⊗ B_j
    decay_state = jnp.exp(cs[-1] - cs)              # (Q,)
    contrib = jax.lax.dot_general(xdt * decay_state[:, None], Bv,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (P, N)
    h_ref[...] = h * jnp.exp(cs[-1]) + contrib

    @pl.when(ci == n_chunks - 1)
    def _done():
        hfin_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(xdt, logd, Bv, Cv, *, chunk=128, interpret=False):
    """Shapes as in ref.py; S % chunk == 0."""
    BH, S, P = xdt.shape
    N = Bv.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk
    from jax.experimental.pallas import tpu as pltpu
    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks)
    y, hfin = pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, P, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), xdt.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, logd, Bv, Cv)
    return y, hfin
