"""Jit'd wrapper for the SSD kernel (interpret=True off-TPU)."""
from __future__ import annotations

import jax

from repro.kernels.mamba2_ssd.kernel import ssd_pallas
from repro.kernels.mamba2_ssd.ref import ssd_ref


def ssd(xdt, logd, Bv, Cv, *, chunk=128, use_pallas=True, interpret=None):
    if not use_pallas:
        return ssd_ref(xdt, logd, Bv, Cv)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssd_pallas(xdt, logd, Bv, Cv, chunk=chunk, interpret=interpret)
