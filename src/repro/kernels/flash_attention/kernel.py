"""Pallas TPU flash attention (FlashAttention-2 style online softmax).

Grid: (BH, Sq/block_q, Sk/block_k) with dimension semantics
(parallel, parallel, arbitrary) — the kv axis iterates innermost so the
(block_q, D) fp32 accumulator + running (m, l) live in VMEM scratch across kv
steps; softmax is re-scaled online (never materializing the (Sq, Sk) score
matrix — the XLA-level chunked attention this replaces holds a full
(block, Sk) f32 tile in HBM).

Positions are explicit refs: q_pos (Sq,), k_pos (Sk,) — so one kernel serves
causal training, bidirectional encoders, sliding windows (k_pos > q_pos - w)
and slot-indexed decode caches (k_pos = slot_pos, -1 masks empty slots).
GQA is handled by the kv index_map (kv head = q head // group), so kv tiles
are fetched once per group without materializing an expanded cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale, causal, window, n_k):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                    # (bq, D)
    k = k_ref[0]                                    # (bk, D)
    v = v_ref[0]
    qpos = qpos_ref[...]                            # (bq,) int32
    kpos = kpos_ref[...]                            # (bk,) int32

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    mask = (kpos[None, :] >= 0)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                             # (bq,)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                 # (bq, bk)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "groups", "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, q_pos, k_pos, *, groups=1, causal=True,
                           window=None, scale=None, block_q=256, block_k=256,
                           interpret=False):
    """q: (BH, Sq, D); k, v: (BH//groups, Sk, D); q_pos (Sq,), k_pos (Sk,)."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_q, n_k = Sq // block_q, Sk // block_k

    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki: (b // groups, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, qi, ki: (b // groups, ki, 0)),
            pl.BlockSpec((block_q,), lambda b, qi, ki: (qi,)),
            pl.BlockSpec((block_k,), lambda b, qi, ki: (ki,)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, q_pos, k_pos)
