"""Public wrappers: training/prefill attention and slot-cache decode.

Block sizes target TPU v5e VMEM: (block_q=256, block_k=256, D<=128) keeps
q/k/v tiles + fp32 accumulator around 0.5 MB — far under the ~16 MB budget,
leaving room for double buffering; both matmul dims are multiples of the
128-wide MXU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def _interp(interpret):
    return jax.default_backend() != "tpu" if interpret is None else interpret


def mha(q, k, v, *, causal=True, window=None, block_q=256, block_k=256,
        interpret=None):
    """q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    groups = H // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, k.shape[1], D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, v.shape[1], D)
    q_pos = jnp.arange(Sq, dtype=jnp.int32)
    k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    out = flash_attention_pallas(qf, kf, vf, q_pos, k_pos, groups=groups,
                                 causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=_interp(interpret))
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


def decode(q, k_cache, v_cache, slot_pos, pos, *, window=None, block_k=256,
           interpret=None):
    """q: (B, 1, H, D); caches: (B, S_alloc, Hkv, D); slot_pos: (S_alloc,)
    absolute positions per slot (-1 empty); pos: scalar current position."""
    B, _, H, D = q.shape
    Hkv = k_cache.shape[2]
    groups = H // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, 1, D)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, -1, D)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, -1, D)
    q_pos = jnp.full((1,), pos, jnp.int32)
    out = flash_attention_pallas(qf, kf, vf, q_pos,
                                 jnp.asarray(slot_pos, jnp.int32),
                                 groups=groups, causal=True, window=window,
                                 block_q=1, block_k=block_k,
                                 interpret=_interp(interpret))
    return out.reshape(B, H, 1, D).transpose(0, 2, 1, 3)


def mha_ref(q, k, v, *, causal=True, window=None):
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = jnp.repeat(k, H // Hkv, axis=2).transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    vf = jnp.repeat(v, H // Hkv, axis=2).transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    out = flash_attention_ref(qf, kf, vf, jnp.arange(Sq, dtype=jnp.int32),
                              jnp.arange(k.shape[1], dtype=jnp.int32),
                              causal=causal, window=window)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
