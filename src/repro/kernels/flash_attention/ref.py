"""Pure-jnp oracle: masked attention with explicit (q_pos, k_pos) positions.

Covers every mode the kernel serves: causal training, bidirectional encoding,
sliding windows, and slot-cache decode (k_pos = slot positions, -1 = empty).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, q_pos, k_pos, *, causal=True, window=None,
                        scale=None):
    """q: (BH, Sq, D); k, v: (BH, Sk, D); q_pos: (Sq,); k_pos: (Sk,)."""
    D = q.shape[-1]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = k_pos[None, :] >= 0
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)
