from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def rmsnorm(x, w, residual=None, *, eps=1e-5, use_pallas=True,
            interpret=None):
    if residual is None:
        residual = jnp.zeros_like(x)
    if not use_pallas:
        return rmsnorm_ref(x, w, residual, eps=eps)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return rmsnorm_pallas(x, w, residual, eps=eps, interpret=interpret)
