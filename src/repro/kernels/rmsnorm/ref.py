"""Pure-jnp oracle: RMSNorm with optional fused residual add."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, w, residual=None, eps: float = 1e-5):
    """x: (T, D); w: (D,); optional residual (T, D) added BEFORE the norm
    (the fused bias-add+norm epilogue). Returns (y, x+residual)."""
    if residual is not None:
        x = x + residual
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype), x
