"""Pallas TPU kernel: fused residual-add + RMSNorm.

Rows tiled over the grid; each program normalizes a (block_t, D) tile in
VMEM — one HBM read of x (+residual) and one write each of y and the updated
residual stream, instead of the 4-5 passes the unfused chain costs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, r_ref, y_ref, res_ref, *, eps):
    x = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)[None]
    y_ref[...] = y.astype(y_ref.dtype)
    res_ref[...] = x.astype(res_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "eps", "interpret"))
def rmsnorm_pallas(x, w, residual, *, block_t=256, eps=1e-5, interpret=False):
    T, D = x.shape
    block_t = min(block_t, T)
    assert T % block_t == 0
    kernel = functools.partial(_rms_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(T // block_t,),
        in_specs=[
            pl.BlockSpec((block_t, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((block_t, D), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, D), lambda i: (i, 0)),
            pl.BlockSpec((block_t, D), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((T, D), x.dtype),
                   jax.ShapeDtypeStruct((T, D), x.dtype)],
        interpret=interpret,
    )(x, w, residual)
