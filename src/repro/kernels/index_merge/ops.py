"""Dispatch + HBM traffic model for the fused index-merge kernel.

``index_merge`` is the batched entry point both executors and replica
replay reach through ``storage.index.apply_index_ops(use_pallas=...)``:
it hoists the oracle's per-segment stable insert argsort (Ki log Ki, done
once in jnp), pads empty op batches with inert SENTINEL columns, and
launches the fused kernel — or falls back to the vmapped jnp oracle.

``index_merge_bytes`` models the HBM bytes each implementation moves per
vmapped call so benchmarks/roofline_report.py and benchmarks/kernel_bench.py
print the traffic claim instead of asserting it.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.index_merge.kernel import index_merge_pallas
from repro.kernels.occ.ops import resolve_interpret
from repro.obs.trace import kernel_launch
from repro.storage.index import SENTINEL

W = 4                                  # int32/uint32 word bytes


def index_merge(key, prow, tid, del_pq, ins_pq, prow_pq, tid_pq, *,
                use_pallas=True, interpret=None, block_slots=None):
    """Apply one (P, Q) masked delete/insert batch to P sorted segments.

    key/prow/tid: (P, cap).  del_pq/ins_pq: (P, Q) int32 with SENTINEL =
    masked out; prow_pq/tid_pq the insert payloads (exactly the
    partition-aligned batches ``apply_index_ops`` builds).  Returns
    (key', prow', tid', overflow (P,)) — the pallas path is bit-identical
    to the vmapped jnp oracle (``ref.segment_merge_ref``).
    """
    kernel_launch("index_merge.index_merge",
                  backend="pallas" if use_pallas else "jnp",
                  segments=int(key.shape[0]), cap=int(key.shape[1]))
    if not use_pallas:
        from repro.kernels.index_merge.ref import segment_merge_ref
        return jax.vmap(segment_merge_ref)(key, prow, tid, del_pq, ins_pq,
                                           prow_pq, tid_pq)
    interpret = resolve_interpret(interpret)
    P = key.shape[0]
    if del_pq.shape[1] == 0:           # inert: SENTINEL dels never hit
        del_pq = jnp.full((P, 1), SENTINEL, jnp.int32)
    if ins_pq.shape[1] == 0:           # the oracle's Ki == 0 pad
        ins_pq = jnp.full((P, 1), SENTINEL, jnp.int32)
        prow_pq = jnp.zeros((P, 1), prow.dtype)
        tid_pq = jnp.zeros((P, 1), tid.dtype)
    # the oracle's per-segment stable argsort, hoisted out of the kernel
    iorder = jnp.argsort(ins_pq, axis=1)
    ik = jnp.take_along_axis(ins_pq, iorder, axis=1)
    ip = jnp.take_along_axis(prow_pq, iorder, axis=1)
    it = jnp.take_along_axis(tid_pq, iorder, axis=1)
    return index_merge_pallas(key, prow, tid, del_pq, ik, ip, it,
                              block_slots=block_slots, interpret=interpret)


def _lg(x):
    return max(1, math.ceil(math.log2(max(int(x), 2))))


def index_merge_bytes(P, cap, Q):
    """Modeled HBM bytes per vmapped merge call: P segments of ``cap``
    slots, a (P, Q) masked op batch each.  Three generations:

    * ``argsort`` — the original concat + full-segment sort: every batch
      re-sorts (cap + Q) keys and re-gathers all three payload runs;
    * ``jnp`` — the current gather-form oracle: segment I/O + two rank
      passes, two (cap+1,) step-function scatter/cumsums and the (Q, Q)
      dead-below bool compare it materializes per segment;
    * ``pallas`` — the fused kernel: the three runs stream in and out
      once, op batches once; rank passes are VMEM-local binary searches
      (only the hoisted Ki log Ki insert sort stays in jnp).
    """
    seg_io = 6 * cap                   # read + write key/prow/tid runs
    argsort = P * W * (seg_io + 3 * (cap + Q)
                       + (cap + Q) * _lg(cap + Q)     # full-segment sort
                       + Q * _lg(cap))                # delete probes
    gather = P * (W * (seg_io + 4 * Q                 # masked op batches
                       + Q * _lg(cap) + Q * _lg(Q)    # rank passes + sort
                       + 4 * (cap + 1)                # step scatter+cumsum
                       + 4 * cap)                     # merge-rank gathers
                  + Q * Q)                            # dead-below bools
    fused = P * W * (seg_io + 4 * Q + Q * _lg(Q) + 1)
    return {"argsort": argsort, "jnp": gather, "pallas": fused}
