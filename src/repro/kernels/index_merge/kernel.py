"""Pallas TPU kernel: fused sorted-segment index merge.

One launch fuses everything ``segment_merge_ref`` does per segment — the
delete-compact (searchsorted position + hit test + hole dedup), BOTH rank
passes (the deletes' hole-prefix counts and the inserts' side="right"
merge positions) and the merged gather/scatter that materializes the new
canonical segment — with the overflow count produced in-kernel.

Tiling (the thomas_merge discipline, applied to destination SLOTS instead
of destination rows): grid = (P, capP // block_slots).  Grid dim 0 walks
segments, so the batched (vmapped at the call sites) merge is ONE launch;
grid dim 1 walks destination-slot tiles.  The segment key/payload runs and
the per-op batches use a constant index map along dim 1, so they stay
VMEM-resident while every tile of the same segment executes; only the
(1, block_slots) output tiles move.  Each tile recomputes the cheap
O(K log cap) per-op rank pass from the resident runs and then resolves its
own slots — no cross-tile state, no (Q, Q) dead-below compare and no
(cap+1,) step-function scatters over the whole output domain per batch
element (the jnp reference's traffic; see ops.index_merge_bytes).

Per destination slot ``o`` the kernel answers "which element of
merge(live existing, live incoming) ranks o-th" with two binary searches
over resident arrays: ``j_excl`` = #live incoming below o (search the
strictly-increasing live insert positions) and the hole-rank inverse
D(r) = #holes at live rank ≤ r (search the monotone p - holes_below(p)).
Free slots are canonical (SENTINEL, 0, 0) and the dropped-live-key
overflow is ``max(n_live + n_ins - cap, 0)`` exactly as the oracle counts
it — bit-identical by tests/test_occ_kernels.py's hypothesis sweep.

Runs under ``interpret=True`` off-TPU (the tier-1/CI path); the in-kernel
hole scatter is the same ``.at[].max`` primitive the OCC lock kernel uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.storage.index import SENTINEL


def _first_true(pred, shape, size, n_iters):
    """Vectorized lower bound: smallest idx in [0, size] with pred(idx)
    True, assuming pred is monotone (False..False True..True); ``size`` if
    pred never holds.  pred maps an (shape,) int32 idx array to bool."""
    lo = jnp.zeros(shape, jnp.int32)
    hi = jnp.full(shape, size, jnp.int32)

    def body(_, lh):
        lo, hi = lh
        live = lo < hi
        mid = (lo + hi) // 2                       # in [lo, hi) ⊂ [0, size)
        p = pred(mid)
        return (jnp.where(live & ~p, mid + 1, lo),
                jnp.where(live & p, mid, hi))

    lo, _ = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
    return lo


def _index_merge_kernel(key_ref, prow_ref, tid_ref, dk_ref, ik_ref, ip_ref,
                        it_ref, k2_ref, p2_ref, t2_ref, ov_ref, *,
                        cap, block_slots, n_iters, ki_iters):
    capP = key_ref.shape[1]
    Kd = dk_ref.shape[1]
    Ki = ik_ref.shape[1]
    o32 = jnp.int32
    tile = pl.program_id(1)

    seg_k = key_ref[0, :]                          # (capP,) resident run
    seg_p = prow_ref[0, :]
    seg_t = tid_ref[0, :]
    dk = dk_ref[0, :]                              # (Kd,) SENTINEL = masked
    ik = ik_ref[0, :]                              # (Ki,) pre-sorted asc
    ip = ip_ref[0, :]
    it = it_ref[0, :]

    # -- delete rank pass: position, hit test, dedup'd hole-prefix counts.
    # The oracle's sort(tgt)+uniq dedup becomes a scatter-max of hole flags
    # (same dedup: two dels hitting one slot still make ONE hole) + cumsum.
    pos = _first_true(lambda m: seg_k[jnp.minimum(m, capP - 1)] >= dk,
                      (Kd,), capP, n_iters)
    posc = jnp.minimum(pos, capP - 1)
    hit = (seg_k[posc] == dk) & (dk != SENTINEL)
    hole = jnp.zeros((capP + 1,), o32).at[
        jnp.where(hit, posc, capP)].max(1)
    # hb[p] = holes strictly below slot p (== oracle's holes_before at p)
    hb = jnp.concatenate([jnp.zeros((1,), o32),
                          jnp.cumsum(hole[:capP], dtype=o32)])
    n_dead = hb[capP]
    n_live = jnp.sum(seg_k != SENTINEL, dtype=o32) - n_dead

    # -- insert rank pass: side="right" keeps existing-first tie order;
    # subtracting hb[ss] removes the dead slots still sitting below the
    # searchsorted point (the oracle's Ki×Kd dead_below compare, O(log)).
    n_ilive = jnp.sum(ik != SENTINEL, dtype=o32)
    ss = _first_true(lambda m: seg_k[jnp.minimum(m, capP - 1)] > ik,
                     (Ki,), capP, n_iters)
    j_iota = jnp.arange(Ki, dtype=o32)
    pos_i = j_iota + ss - hb[ss]
    # live prefix strictly increasing; dead tail pushed past every slot
    ipos = jnp.where(j_iota < n_ilive, jnp.minimum(pos_i, capP), capP + 1)
    n_merged = n_live + n_ilive

    # -- destination slots owned by this tile
    o = tile * block_slots + jnp.arange(block_slots, dtype=o32)
    j_excl = _first_true(lambda m: ipos[jnp.minimum(m, Ki - 1)] >= o,
                         (block_slots,), Ki, ki_iters)   # #incoming < o
    jidx = jnp.clip(j_excl, 0, Ki - 1)
    is_inc = (ipos[jidx] == o) & (j_excl < Ki)
    r = o - j_excl                                 # live-existing rank
    # D(r) = #holes at live rank ≤ r: live rank of slot p is p - hb[p]
    # (monotone), so search the first p whose rank exceeds r and count the
    # holes below it — the oracle's d_at cumsum, evaluated point-wise.
    pstar = _first_true(lambda m: (m - hb[m]) > r,
                        (block_slots,), capP, n_iters)
    i_src = jnp.clip(r + hb[pstar], 0, capP - 1)
    valid = o < n_merged
    k2 = jnp.where(valid,
                   jnp.where(is_inc, ik[jidx], seg_k[i_src]), SENTINEL)
    live = k2 != SENTINEL                          # canonical free slots
    k2_ref[0, :] = k2
    p2_ref[0, :] = jnp.where(live,
                             jnp.where(is_inc, ip[jidx], seg_p[i_src]), 0)
    t2_ref[0, :] = jnp.where(live,
                             jnp.where(is_inc, it[jidx], seg_t[i_src]),
                             jnp.uint32(0))
    # every tile of segment p derives the same scalar; last write wins
    ov_ref[0, 0] = jnp.maximum(n_merged - cap, 0).astype(o32)


def _round_up(x, m):
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("block_slots", "interpret"))
def index_merge_pallas(key, prow, tid, del_key, ins_key, ins_prow, ins_tid,
                       *, block_slots=None, interpret=True):
    """Batched fused merge: one launch over all P segments.

    key/prow/tid: (P, cap) sorted canonical segments.  del_key: (P, Kd)
    with SENTINEL = masked out.  ins_key/ins_prow/ins_tid: (P, Ki ≥ 1)
    with each row PRE-SORTED ascending by key (ops.py sorts — the oracle's
    per-segment stable argsort, hoisted out of the kernel).  Returns
    (key', prow', tid' (P, cap), overflow (P,)) bit-identical to
    vmap(segment_merge_ref) over the unsorted batches.
    """
    P, cap = key.shape
    Kd = del_key.shape[1]
    Ki = ins_key.shape[1]
    assert Ki >= 1 and Kd >= 1, "dispatch pads empty op batches"
    if block_slots is None:
        # one tile per segment up to 4096 slots: interpret mode then runs
        # the per-op rank pass once per segment (the monolith cost), while
        # forced smaller blocks exercise the real multi-tile grid in tests
        block_slots = min(_round_up(cap, 128), 4096)
    capP = _round_up(cap, block_slots)
    if capP != cap:
        pad = ((0, 0), (0, capP - cap))
        key = jnp.pad(key, pad, constant_values=SENTINEL)
        prow = jnp.pad(prow, pad)
        tid = jnp.pad(tid, pad)
    kernel = functools.partial(
        _index_merge_kernel, cap=cap, block_slots=block_slots,
        n_iters=int(capP).bit_length() + 1,
        ki_iters=int(Ki).bit_length() + 1)
    seg_spec = pl.BlockSpec((1, capP), lambda p, i: (p, 0))
    k2, p2, t2, ov = pl.pallas_call(
        kernel,
        grid=(P, capP // block_slots),
        in_specs=[
            seg_spec, seg_spec, seg_spec,                  # resident runs
            pl.BlockSpec((1, Kd), lambda p, i: (p, 0)),    # del batch
            pl.BlockSpec((1, Ki), lambda p, i: (p, 0)),    # ins batch
            pl.BlockSpec((1, Ki), lambda p, i: (p, 0)),
            pl.BlockSpec((1, Ki), lambda p, i: (p, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_slots), lambda p, i: (p, i)),
            pl.BlockSpec((1, block_slots), lambda p, i: (p, i)),
            pl.BlockSpec((1, block_slots), lambda p, i: (p, i)),
            pl.BlockSpec((1, 1), lambda p, i: (p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, capP), key.dtype),
            jax.ShapeDtypeStruct((P, capP), prow.dtype),
            jax.ShapeDtypeStruct((P, capP), tid.dtype),
            jax.ShapeDtypeStruct((P, 1), jnp.int32),
        ],
        interpret=interpret,
    )(key, prow, tid, del_key, ins_key, ins_prow, ins_tid)
    return k2[:, :cap], p2[:, :cap], t2[:, :cap], ov[:, 0]
