"""jnp oracle for the fused index-merge kernel.

``segment_merge_ref`` is the exact former ``storage/index.py:segment_apply``
body — the gather-form sorted-run merge (delete-scatter + two searchsorted
rank passes + step-function cumsums) that replaced the original full-segment
argsort.  It stays the semantic source of truth: the Pallas kernel in
``kernel.py`` must be bit-identical to it (enforced by the hypothesis suite
in tests/test_occ_kernels.py), and ``storage.index.segment_apply`` dispatches
here on the jnp path.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.storage.index import SENTINEL


def segment_merge_ref(key, prow, tid, del_key, ins_key, ins_prow, ins_tid):
    """Apply one batch of deletes + inserts to one sorted segment.

    key/prow/tid: (cap,).  del_key: (Kd,) with SENTINEL = masked out.
    ins_key: (Ki,) with SENTINEL = masked out; ins_prow/ins_tid payloads.
    Deletes resolve against the *pre-batch* segment; inserts merge after.
    Returns (key', prow', tid', overflow): the re-sorted canonical segment
    plus the number of LIVE keys dropped because the merge exceeded ``cap``
    (largest-key-first).  Overflow is deterministic and identical on master
    and replica (both apply the same batches), so it never diverges state —
    but it IS data loss; the engine counts it as ``index_overflow`` and can
    raise in strict mode (capacity sizing is the caller's responsibility —
    see IndexSpec).
    """
    cap = key.shape[0]
    Ki = ins_key.shape[0]
    o32 = jnp.int32
    # -- deletes: searchsorted position, exact-match test — the hit slots
    # become holes in the (still untouched, still sorted) existing run
    pos = jnp.clip(jnp.searchsorted(key, del_key), 0, cap - 1).astype(o32)
    hit = (key[pos] == del_key) & (del_key != SENTINEL)
    tgt = jnp.where(hit, pos, cap)                        # (Kd,), cap = miss
    # dedup: two del ops hitting the same slot make ONE hole
    tgt_s = jnp.sort(tgt)
    uniq = jnp.concatenate([tgt_s[:1] < cap,
                            (tgt_s[1:] != tgt_s[:-1]) & (tgt_s[1:] < cap)])
    n_dead = jnp.sum(uniq, dtype=o32)
    # live rank just below each hole: its index minus the holes before it
    holes_before = jnp.cumsum(uniq) - uniq                # (Kd,) exclusive
    r_hole = tgt_s - holes_before.astype(o32)

    # -- inserts: sorted-run merge in GATHER form — the old concat + full-
    # segment argsort is replaced by two step-function cumsums over the
    # output domain plus gathers; only the Ki incoming keys are sorted.
    # Output slot o holds the o-th element of merge(live existing, live
    # incoming): an incoming element when an incoming landed exactly at o,
    # else the live existing element of rank o − (#incoming before o),
    # whose original index adds back the holes the deletes punched.
    if Ki == 0:                                           # delete-only batch
        ins_key = jnp.full((1,), SENTINEL, jnp.int32)
        ins_prow = jnp.zeros((1,), prow.dtype)
        ins_tid = jnp.zeros((1,), tid.dtype)
        Ki = 1
    iorder = jnp.argsort(ins_key)                         # Ki log Ki only
    ik, ip, it = ins_key[iorder], ins_prow[iorder], ins_tid[iorder]
    ilive = ik != SENTINEL
    n_ilive = jnp.sum(ilive, dtype=o32)
    # live-existing count: keys before the first free SENTINEL, minus holes
    n_live = jnp.searchsorted(key, SENTINEL).astype(o32) - n_dead
    # merged position of live incoming j: j + #live existing ≤ ik[j]
    # (side="right" keeps the old stable order: existing first on ties);
    # dead (hole) slots still carry their old keys, so subtract the holes
    # sitting below the searchsorted point (small Ki×Kd compare)
    ss = jnp.searchsorted(key, ik, side="right").astype(o32)
    dead_below = jnp.sum(uniq[None, :] & (tgt_s[None, :] < ss[:, None]),
                         axis=1, dtype=o32)
    pos_i = jnp.arange(Ki, dtype=o32) + ss - dead_below
    # step function J(o) = #incoming at output slots ≤ o (small scatter of
    # the Ki positions + one cumsum — pos_i is strictly increasing over
    # live incoming, so no duplicate live positions)
    inc_at = jnp.zeros((cap + 1,), o32).at[
        jnp.where(ilive, jnp.minimum(pos_i, cap), cap)].add(1)[:cap]
    # step function D(r) = #holes at live rank ≤ r (small scatter + cumsum)
    d_at = jnp.zeros((cap + 1,), o32).at[
        jnp.where(uniq, jnp.clip(r_hole, 0, cap - 1), cap)].add(1)[:cap]
    J, D = jnp.cumsum(jnp.stack([inc_at, d_at]), axis=1)  # one fused pass
    o = jnp.arange(cap, dtype=o32)
    is_inc = inc_at > 0
    j_excl = J - inc_at                                   # #incoming < o
    r = o - j_excl                                        # live-exist rank
    i_src = jnp.clip(r + D[jnp.clip(r, 0, cap - 1)], 0, cap - 1)
    jidx = jnp.clip(j_excl, 0, max(Ki - 1, 0))
    n_merged = n_live + n_ilive
    valid = o < n_merged
    k2 = jnp.where(valid, jnp.where(is_inc, ik[jidx], key[i_src]), SENTINEL)
    live = k2 != SENTINEL                                 # canonical free
    p2 = jnp.where(live, jnp.where(is_inc, ip[jidx], prow[i_src]), 0)
    t2 = jnp.where(live, jnp.where(is_inc, it[jidx], tid[i_src]),
                   jnp.uint32(0))
    overflow = jnp.maximum(n_merged - cap, 0).astype(o32)
    return k2, p2, t2, overflow
