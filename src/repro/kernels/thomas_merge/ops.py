"""Jit'd public wrapper: pads inputs to block multiples and dispatches to the
Pallas kernel (interpret=True on CPU) or the jnp reference path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.thomas_merge.kernel import thomas_merge_pallas
from repro.kernels.thomas_merge.ref import thomas_merge_ref


def _pad_to(x, mult, axis, fill=0):
    n = x.shape[axis]
    target = ((n + mult - 1) // mult) * mult
    if target == n:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - n)
    return jnp.pad(x, widths, constant_values=fill)


def thomas_merge(val, tidw, wrows, wvals, wtids, *, use_pallas=True,
                 block_rows=256, block_k=256, interpret=None):
    """Replication-stream apply (Thomas write rule). Shapes as in ref.py;
    wrows may contain -1 (skip). Pads N to block_rows and K to block_k."""
    if not use_pallas:
        return thomas_merge_ref(val, tidw, wrows, wvals, wtids)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N, C = val.shape
    valp = _pad_to(val, block_rows, 0)
    tidp = _pad_to(tidw, block_rows, 0)
    rowsp = _pad_to(jnp.asarray(wrows, jnp.int32), block_k, 0, fill=-1)
    valsp = _pad_to(jnp.asarray(wvals), block_k, 0)
    tidsp = _pad_to(jnp.asarray(wtids, jnp.uint32), block_k, 0)
    br = min(block_rows, valp.shape[0])
    bk = min(block_k, rowsp.shape[0])
    out_val, out_tid = thomas_merge_pallas(
        valp, tidp, rowsp, valsp, tidsp, block_rows=br, block_k=bk,
        interpret=interpret)
    return out_val[:N], out_tid[:N]
