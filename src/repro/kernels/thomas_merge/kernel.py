"""Pallas TPU kernel: Thomas-write-rule merge of a replication stream.

TPU adaptation of the replica-side apply loop (paper §3/§5): the destination
table is tiled over rows (grid dim 0); each program instance holds one
(block_rows, C) value tile + (block_rows,) TID tile in VMEM and streams the
ENTIRE write batch through VMEM in (block_k,) chunks, keeping a running
arg-max-by-TID per destination row with masked vector compares — no atomics,
no sorting, deterministic.  Writes whose row falls outside the tile are
masked out; duplicate rows resolve to the max TID (strictly-greater rule).

Grid: (N // block_rows,).  For each k-chunk the kernel materializes a
(block_k, block_rows) one-hot-ish comparison, so block sizes are chosen to
keep block_k * block_rows * 4B within a VMEM budget (see ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_kernel(rows_ref, vals_ref, tids_ref, val_ref, tid_ref,
                  out_val_ref, out_tid_ref, *, block_k: int):
    block_rows, C = val_ref.shape
    K = rows_ref.shape[0]
    row0 = pl.program_id(0) * block_rows

    cur_tid = tid_ref[...]                       # (R,) uint32
    cur_val = val_ref[...]                       # (R, C) int32

    # best incoming write per local row: running (tid, index-into-batch)
    best_tid = jnp.zeros((block_rows,), jnp.uint32)
    best_idx = jnp.zeros((block_rows,), jnp.int32)

    n_chunks = K // block_k

    def body(c, carry):
        best_tid, best_idx = carry
        off = c * block_k
        rows = pl.load(rows_ref, (pl.dslice(off, block_k),))       # (Bk,)
        tids = pl.load(tids_ref, (pl.dslice(off, block_k),))       # (Bk,)
        local = rows - row0                                        # (Bk,)
        in_tile = (local >= 0) & (local < block_rows)
        # (Bk, R) match matrix: does write j target local row r?
        r_iota = jax.lax.broadcasted_iota(jnp.int32, (block_k, block_rows), 1)
        match = in_tile[:, None] & (local[:, None] == r_iota)
        cand = jnp.where(match, tids[:, None], jnp.uint32(0))      # (Bk, R)
        chunk_best = jnp.max(cand, axis=0)                         # (R,)
        chunk_idx = jnp.argmax(cand, axis=0).astype(jnp.int32) + off
        take = chunk_best > best_tid
        best_tid = jnp.where(take, chunk_best, best_tid)
        best_idx = jnp.where(take, chunk_idx, best_idx)
        return best_tid, best_idx

    best_tid, best_idx = jax.lax.fori_loop(0, n_chunks, body,
                                           (best_tid, best_idx))

    apply = best_tid > cur_tid                                     # (R,)
    new_val = vals_ref[best_idx, :]                                # (R, C)
    out_val_ref[...] = jnp.where(apply[:, None], new_val, cur_val)
    out_tid_ref[...] = jnp.where(apply, best_tid, cur_tid)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_k",
                                             "interpret"))
def thomas_merge_pallas(val, tidw, wrows, wvals, wtids, *, block_rows=256,
                        block_k=256, interpret=False):
    """val: (N, C) int32; tidw: (N,) uint32; wrows/(K,), wvals/(K,C),
    wtids/(K,).  N % block_rows == 0 and K % block_k == 0 (ops.py pads)."""
    N, C = val.shape
    K = wrows.shape[0]
    assert N % block_rows == 0 and K % block_k == 0
    grid = (N // block_rows,)
    kernel = functools.partial(_merge_kernel, block_k=block_k)
    out_val, out_tid = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K,), lambda i: (0,)),            # rows (streamed)
            pl.BlockSpec((K, C), lambda i: (0, 0)),        # vals
            pl.BlockSpec((K,), lambda i: (0,)),            # tids
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, C), val.dtype),
            jax.ShapeDtypeStruct((N,), tidw.dtype),
        ],
        interpret=interpret,
    )(wrows, wvals, wtids, val, tidw)
    return out_val, out_tid
