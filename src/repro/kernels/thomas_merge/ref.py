"""Pure-jnp oracle for the Thomas-write-rule merge (replication apply).

Semantics: for a batch of writes (row, value, tid), apply each write iff its
TID is strictly greater than the record's current TID; among duplicate rows
the max-TID write wins.  Rows < 0 are skipped.
"""
from __future__ import annotations

import jax.numpy as jnp


def thomas_merge_ref(val, tidw, wrows, wvals, wtids):
    """val: (N, C) int32; tidw: (N,) uint32; wrows: (K,) int32;
    wvals: (K, C) int32; wtids: (K,) uint32 -> (val', tidw')."""
    N, C = val.shape
    rows = jnp.where(wrows >= 0, wrows, N)
    tid_pad = jnp.concatenate([tidw, jnp.zeros((1,), tidw.dtype)])
    merged = tid_pad.at[rows].max(wtids)
    win = (wtids == merged[rows]) & (wtids > tid_pad[rows]) & (wrows >= 0)
    prow = jnp.where(win, rows, N)
    val_pad = jnp.concatenate([val, jnp.zeros((1, C), val.dtype)])
    val_new = val_pad.at[prow].set(wvals)[:N]
    tid_new = tid_pad.at[prow].set(wtids)[:N]
    return val_new, tid_new
