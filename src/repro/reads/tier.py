"""ReadTier: the between-fence serving loop over the snapshot catalog.

Wired into the service epoch pipeline after every commit fence:

  1. ``observe_epoch`` — purge replicas that died with a killed node
     (their retained snapshots are gone; §4.5 recovery re-registers them
     at the next fence stamp), then stamp the engine's committed read
     views into the catalog.  Secondary views refresh on a configurable
     cadence (``sec_refresh_every``) — the modeled cost of materializing
     a queryable snapshot off the replication stream — which is what
     makes ``freshness > 0`` real and the staleness bound meaningful.
  2. ``serve`` — drain the read admission lane, group by home partition,
     load-balance each group across the replicas whose freshness is
     within ``max_staleness_epochs``, and execute one jitted snapshot
     read program per chosen replica.  Transactions with NO replica
     inside the bound re-enter their home partition's OCC queue (the
     fallback path: a bound violation is never served, it is re-routed).

Served reads commit at serve time (group-"commit" at the snapshot they
drained against) into the tier's own LatencyRecorder, so fig12 reports
the read vs write latency split from the same machinery.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.obs import trace as obs
from repro.reads.catalog import SnapshotCatalog
from repro.reads.executor import SnapshotReadExecutor
from repro.service import latency as lat


@dataclass
class ReadTierStats:
    served: int = 0
    batches: int = 0
    fallbacks: int = 0             # reads re-routed to the OCC path
    stale_violations: int = 0      # served past the bound (must stay 0)
    replicas_removed: int = 0      # catalog entries purged by node death
    max_freshness_served: int = 0
    serve_time_s: float = 0.0
    served_by_freshness: dict = field(default_factory=dict)
    mid_epoch_served: int = 0      # k=0 serves below the slab watermark
    mid_epoch_deferred: int = 0    # dirty-partition reads held to the fence


class _DirtyGate:
    """ChangeLog subscriber accumulating the in-flight epoch's per-
    partition write set at slab granularity: ``dirty[p]`` is True once any
    published slab (at-or-below the current slab watermark) wrote
    partition p.  Mid-epoch k=0 reads of CLEAN partitions are provably
    watermark-fresh — the committed snapshot equals the state after
    replaying every published slab — so the tier serves them between
    fences; dirty partitions defer to the fence."""

    needs_write_mask = True

    def __init__(self):
        self.dirty = None          # (P,) bool; None = no slabs published

    def on_slab(self, log, info):
        d = info["dirty"]
        self.dirty = d if self.dirty is None else (self.dirty | d)

    def on_commit(self, epoch, record):
        self.dirty = None

    def on_revert(self, epoch, n_slabs):
        self.dirty = None

    def on_reset(self, val, tid, epoch):
        self.dirty = None


class ReadTier:
    def __init__(self, max_staleness_epochs: int = 0,
                 sec_refresh_every: int = 1, serve_limit: int = 256,
                 retain: int | None = None):
        self.k = int(max_staleness_epochs)
        self.sec_refresh_every = max(1, int(sec_refresh_every))
        self.serve_limit = int(serve_limit)
        self.catalog = SnapshotCatalog(
            n_partitions=0, retain=retain if retain is not None
            else self.k + 2)
        self.executor = SnapshotReadExecutor()
        self.recorder = lat.LatencyRecorder()
        self.stats = ReadTierStats()
        self._gate: _DirtyGate | None = None

    def attach_changelog(self, changelog) -> None:
        """Subscribe the slab-watermark dirty gate to the engine's
        changelog — enables ``serve(..., mid_epoch=True)``."""
        if self._gate is None:
            self._gate = changelog.subscribe(_DirtyGate())

    # ------------------------------------------------------------------
    def observe_epoch(self, engine, metrics: dict | None = None):
        """Commit fence reached: update the catalog from the engine's
        committed read views (and first purge what a failure killed)."""
        ev = (metrics or {}).get("recovery")
        if ev is not None:
            self._on_failure(ev)
        for view in engine.read_views():
            if self.catalog.P == 0:
                self.catalog.P = len(np.asarray(view["cover"]))
            fresh_stamp = (view["kind"] == "full"
                           or int(view["epoch"]) % self.sec_refresh_every == 0
                           or view["id"] not in self.catalog.entries)
            if fresh_stamp:
                self.catalog.stamp(view)
            else:
                self.catalog.announce_epoch(int(view["epoch"]))

    def _on_failure(self, event):
        """A killed node's memory is gone: every copy it hosted leaves the
        catalog (retained snapshots included) until recovery re-stamps."""
        for n in event.failed:
            self.stats.replicas_removed += self.catalog.remove(f"sec{n}")
        if event.case.name in ("FALLBACK_DIST_CC", "UNAVAILABLE"):
            # no full replica survived the failure — it is re-replicated
            # (or disk-reloaded) by recovery and re-stamped at that fence
            self.stats.replicas_removed += self.catalog.remove("full")

    # ------------------------------------------------------------------
    def serve(self, admission, now_s: float = 0.0,
              limit: int | None = None, mid_epoch: bool = False) -> list[dict]:
        """Drain + execute one round of the read lane.  Returns the group
        results [{replica, epoch, freshness, slots, out}, ...] so callers
        (tests, ledgers) can verify the served snapshots.

        mid_epoch=True is the slab-watermark serving mode (requires
        ``attach_changelog``): DURING the in-flight epoch, k=0 reads of
        partitions no published slab has written serve from the committed
        snapshot (provably watermark-fresh); reads of dirty partitions —
        and reads with no freshness-0 replica — re-enter the read lane's
        FRONT and serve at the fence instead of falling back to OCC."""
        if mid_epoch and self._gate is None:
            return []                  # no changelog wired: fence-only mode
        got = admission.drain_reads(limit if limit is not None
                                    else self.serve_limit)
        if not got:
            return []
        k_eff = 0 if mid_epoch else self.k
        dirty = self._gate.dirty if mid_epoch else None
        pool = admission.pool
        slots = np.asarray(got, np.int64)
        homes = pool.home[slots].astype(np.int64)
        groups: dict[str, dict] = {}
        fallback: list[int] = []
        defer: list[int] = []
        for p in np.unique(homes):
            sel = slots[homes == p]
            if dirty is not None and dirty[int(p)]:
                # a slab at-or-below the watermark wrote this partition:
                # the committed snapshot is no longer watermark-fresh here
                defer.extend(int(s) for s in sel)
                continue
            choice = self.catalog.choose(int(p), k_eff, weight=len(sel))
            if choice is None:
                if mid_epoch:
                    defer.extend(int(s) for s in sel)
                else:
                    fallback.extend(int(s) for s in sel)
                continue
            ent, epoch, snap, arow = choice
            g = groups.setdefault(ent.replica_id,
                                  {"ent": ent, "epoch": epoch, "snap": snap,
                                   "slots": [], "arow": []})
            g["slots"].extend(int(s) for s in sel)
            g["arow"].extend([arow] * len(sel))

        results = []
        served: list[np.ndarray] = []
        for rid, g in groups.items():
            freshness = self.catalog.current_epoch - g["epoch"]
            if freshness > k_eff:
                # belt and braces: eligibility already enforced the bound —
                # over-stale data is NEVER returned, it re-routes to OCC
                self.stats.stale_violations += len(g["slots"])
                fallback.extend(g["slots"])
                continue
            gs = np.asarray(g["slots"], np.int64)
            t0 = time.perf_counter()
            out = self.executor.run(g["snap"],
                                    np.asarray(g["arow"], np.int64),
                                    pool.row[gs], pool.kind[gs],
                                    pool.delta[gs])
            jax.block_until_ready(out["val"])
            t1 = time.perf_counter()
            obs.complete("reads.serve_batch", "reads", t0, t1,
                         replica=rid, reads=int(gs.size),
                         freshness=freshness, mid_epoch=mid_epoch)
            self.stats.serve_time_s += t1 - t0
            self.stats.batches += 1
            self.stats.served += gs.size
            self.stats.max_freshness_served = max(
                self.stats.max_freshness_served, freshness)
            byf = self.stats.served_by_freshness
            byf[freshness] = byf.get(freshness, 0) + gs.size
            n = gs.size
            self.recorder.record(pool.tenant[gs], pool.arrival_s[gs],
                                 pool.admit_s[gs], np.full(n, now_s),
                                 np.full(n, now_s),
                                 np.full(n, lat.COMMITTED))
            served.append(gs)
            if mid_epoch:
                self.stats.mid_epoch_served += gs.size
            results.append({"replica": rid, "epoch": g["epoch"],
                            "freshness": freshness, "slots": gs,
                            "out": out})
        if served:
            admission.pool.release(np.concatenate(served))
        if defer:
            admission.requeue_reads_front(defer)
            self.stats.mid_epoch_deferred += len(defer)
            obs.instant("reads.mid_epoch_defer", "reads", reads=len(defer))
        if fallback:
            admission.requeue_reads_occ(fallback)
            self.stats.fallbacks += len(fallback)
        return results

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        p = self.recorder.percentiles()
        s = self.stats
        return {
            "read_served": s.served,
            "read_txn_s": self.recorder.throughput_txn_s(),
            "read_p50_ms": p.p50_ms, "read_p99_ms": p.p99_ms,
            "read_fallbacks": s.fallbacks,
            "read_stale_violations": s.stale_violations,
            "read_max_freshness": s.max_freshness_served,
            "read_by_replica": self.catalog.serves_by_replica(),
            "read_replicas_removed": s.replicas_removed,
            "read_serve_time_s": round(s.serve_time_s, 6),
            "read_mid_epoch_served": s.mid_epoch_served,
            "read_mid_epoch_deferred": s.mid_epoch_deferred,
        }
