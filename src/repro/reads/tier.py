"""ReadTier: the between-fence serving loop over the snapshot catalog.

Wired into the service epoch pipeline after every commit fence:

  1. ``observe_epoch`` — purge replicas that died with a killed node
     (their retained snapshots are gone; §4.5 recovery re-registers them
     at the next fence stamp), then stamp the engine's committed read
     views into the catalog.  Secondary views refresh on a configurable
     cadence (``sec_refresh_every``) — the modeled cost of materializing
     a queryable snapshot off the replication stream — which is what
     makes ``freshness > 0`` real and the staleness bound meaningful.
  2. ``serve`` — drain the read admission lane, group by home partition,
     load-balance each group across the replicas whose freshness is
     within ``max_staleness_epochs``, and execute one jitted snapshot
     read program per chosen replica.  Transactions with NO replica
     inside the bound re-enter their home partition's OCC queue (the
     fallback path: a bound violation is never served, it is re-routed).

Served reads commit at serve time (group-"commit" at the snapshot they
drained against) into the tier's own LatencyRecorder, so fig12 reports
the read vs write latency split from the same machinery.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.reads.catalog import SnapshotCatalog
from repro.reads.executor import SnapshotReadExecutor
from repro.service import latency as lat


@dataclass
class ReadTierStats:
    served: int = 0
    batches: int = 0
    fallbacks: int = 0             # reads re-routed to the OCC path
    stale_violations: int = 0      # served past the bound (must stay 0)
    replicas_removed: int = 0      # catalog entries purged by node death
    max_freshness_served: int = 0
    serve_time_s: float = 0.0
    served_by_freshness: dict = field(default_factory=dict)


class ReadTier:
    def __init__(self, max_staleness_epochs: int = 0,
                 sec_refresh_every: int = 1, serve_limit: int = 256,
                 retain: int | None = None):
        self.k = int(max_staleness_epochs)
        self.sec_refresh_every = max(1, int(sec_refresh_every))
        self.serve_limit = int(serve_limit)
        self.catalog = SnapshotCatalog(
            n_partitions=0, retain=retain if retain is not None
            else self.k + 2)
        self.executor = SnapshotReadExecutor()
        self.recorder = lat.LatencyRecorder()
        self.stats = ReadTierStats()

    # ------------------------------------------------------------------
    def observe_epoch(self, engine, metrics: dict | None = None):
        """Commit fence reached: update the catalog from the engine's
        committed read views (and first purge what a failure killed)."""
        ev = (metrics or {}).get("recovery")
        if ev is not None:
            self._on_failure(ev)
        for view in engine.read_views():
            if self.catalog.P == 0:
                self.catalog.P = len(np.asarray(view["cover"]))
            fresh_stamp = (view["kind"] == "full"
                           or int(view["epoch"]) % self.sec_refresh_every == 0
                           or view["id"] not in self.catalog.entries)
            if fresh_stamp:
                self.catalog.stamp(view)
            else:
                self.catalog.announce_epoch(int(view["epoch"]))

    def _on_failure(self, event):
        """A killed node's memory is gone: every copy it hosted leaves the
        catalog (retained snapshots included) until recovery re-stamps."""
        for n in event.failed:
            self.stats.replicas_removed += self.catalog.remove(f"sec{n}")
        if event.case.name in ("FALLBACK_DIST_CC", "UNAVAILABLE"):
            # no full replica survived the failure — it is re-replicated
            # (or disk-reloaded) by recovery and re-stamped at that fence
            self.stats.replicas_removed += self.catalog.remove("full")

    # ------------------------------------------------------------------
    def serve(self, admission, now_s: float = 0.0,
              limit: int | None = None) -> list[dict]:
        """Drain + execute one round of the read lane.  Returns the group
        results [{replica, epoch, freshness, slots, out}, ...] so callers
        (tests, ledgers) can verify the served snapshots."""
        got = admission.drain_reads(limit if limit is not None
                                    else self.serve_limit)
        if not got:
            return []
        pool = admission.pool
        slots = np.asarray(got, np.int64)
        homes = pool.home[slots].astype(np.int64)
        groups: dict[str, dict] = {}
        fallback: list[int] = []
        for p in np.unique(homes):
            sel = slots[homes == p]
            choice = self.catalog.choose(int(p), self.k, weight=len(sel))
            if choice is None:
                fallback.extend(int(s) for s in sel)
                continue
            ent, epoch, snap, arow = choice
            g = groups.setdefault(ent.replica_id,
                                  {"ent": ent, "epoch": epoch, "snap": snap,
                                   "slots": [], "arow": []})
            g["slots"].extend(int(s) for s in sel)
            g["arow"].extend([arow] * len(sel))

        results = []
        served: list[np.ndarray] = []
        for rid, g in groups.items():
            freshness = self.catalog.current_epoch - g["epoch"]
            if freshness > self.k:
                # belt and braces: eligibility already enforced the bound —
                # over-stale data is NEVER returned, it re-routes to OCC
                self.stats.stale_violations += len(g["slots"])
                fallback.extend(g["slots"])
                continue
            gs = np.asarray(g["slots"], np.int64)
            t0 = time.perf_counter()
            out = self.executor.run(g["snap"],
                                    np.asarray(g["arow"], np.int64),
                                    pool.row[gs], pool.kind[gs],
                                    pool.delta[gs])
            jax.block_until_ready(out["val"])
            self.stats.serve_time_s += time.perf_counter() - t0
            self.stats.batches += 1
            self.stats.served += gs.size
            self.stats.max_freshness_served = max(
                self.stats.max_freshness_served, freshness)
            byf = self.stats.served_by_freshness
            byf[freshness] = byf.get(freshness, 0) + gs.size
            n = gs.size
            self.recorder.record(pool.tenant[gs], pool.arrival_s[gs],
                                 pool.admit_s[gs], np.full(n, now_s),
                                 np.full(n, now_s),
                                 np.full(n, lat.COMMITTED))
            served.append(gs)
            results.append({"replica": rid, "epoch": g["epoch"],
                            "freshness": freshness, "slots": gs,
                            "out": out})
        if served:
            admission.pool.release(np.concatenate(served))
        if fallback:
            admission.requeue_reads_occ(fallback)
            self.stats.fallbacks += len(fallback)
        return results

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        p = self.recorder.percentiles()
        s = self.stats
        return {
            "read_served": s.served,
            "read_txn_s": self.recorder.throughput_txn_s(),
            "read_p50_ms": p.p50_ms, "read_p99_ms": p.p99_ms,
            "read_fallbacks": s.fallbacks,
            "read_stale_violations": s.stale_violations,
            "read_max_freshness": s.max_freshness_served,
            "read_by_replica": self.catalog.serves_by_replica(),
            "read_replicas_removed": s.replicas_removed,
            "read_serve_time_s": round(s.serve_time_s, 6),
        }
