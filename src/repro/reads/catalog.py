"""SnapshotCatalog: which replica can serve a read, and how fresh is it.

Every replica copy (the master's full copy, each node's hosted secondary
block, the single-host replica store) is registered as an entry carrying
its partition coverage and the partition -> array-row mapping of its
physical layout (the secondary copies are home-major ROLLED arrays: node m
hosts node m-1's block, so partition p lives at array row (p + ppn) mod P).

At every commit fence the owning engine publishes its committed snapshot
views (``engine.read_views()``); the catalog STAMPS each entry with the
fence epoch, the per-slab high-watermark the replication ledger recorded
for that epoch, and a reference to the committed ``val/tid`` + index
arrays.  A bounded ring of recent stamped snapshots is retained per
replica so reads may be served at ``freshness = current_epoch -
snapshot_epoch`` anywhere within the configured staleness bound.

Lifecycle: a killed node's hosted copies are ``remove()``d — their
retained snapshots died with the node's memory — and re-registered by the
first post-recovery fence stamp (so freshness restarts from the recovered
epoch, exactly the §4.5 case-2 re-materialization contract).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


@dataclass
class ReplicaEntry:
    replica_id: str
    kind: str                      # "full" | "secondary"
    node: int                      # hosting node (whose memory holds it)
    cover: np.ndarray              # (P,) bool — partitions this copy holds
    row_of_partition: np.ndarray   # (P,) int — partition -> array row
    snaps: deque = field(default_factory=deque)   # (epoch, snap, watermark)
    serves: int = 0                # load-balancing counter

    def latest_epoch(self) -> int | None:
        return self.snaps[-1][0] if self.snaps else None


class SnapshotCatalog:
    def __init__(self, n_partitions: int, retain: int = 4):
        """``retain`` bounds the per-replica ring of stamped snapshots —
        it must cover the staleness window (k + 1) for bound-k serving."""
        self.P = int(n_partitions)
        self.retain = max(1, int(retain))
        self.entries: dict[str, ReplicaEntry] = {}
        self.current_epoch = 0     # last fence epoch any stamp announced

    # -- lifecycle -------------------------------------------------------
    def stamp(self, view: dict):
        """Register/refresh one replica from an engine read view:
        {'id','kind','node','epoch','watermark','cover','row_of_partition',
        'val','tid','idx'}.  Idempotent per (replica, epoch)."""
        rid = view["id"]
        ent = self.entries.get(rid)
        if ent is None:
            ent = ReplicaEntry(
                replica_id=rid, kind=view["kind"], node=int(view["node"]),
                cover=np.asarray(view["cover"], bool),
                row_of_partition=np.asarray(view["row_of_partition"],
                                            np.int64))
            self.entries[rid] = ent
        epoch = int(view["epoch"])
        self.current_epoch = max(self.current_epoch, epoch)
        if ent.snaps and ent.snaps[-1][0] >= epoch:
            return                                  # already stamped
        snap = {"val": view["val"], "tid": view["tid"],
                "idx": view.get("idx") or []}
        ent.snaps.append((epoch, snap, view.get("watermark")))
        while len(ent.snaps) > self.retain:
            ent.snaps.popleft()

    def announce_epoch(self, epoch: int):
        """Advance the catalog clock without stamping (a replica whose view
        was NOT refreshed this fence ages by one)."""
        self.current_epoch = max(self.current_epoch, int(epoch))

    def remove(self, replica_id: str) -> bool:
        """Node death: the copy AND its retained snapshots died with the
        node's memory.  Returns True if the entry existed."""
        return self.entries.pop(replica_id, None) is not None

    # -- freshness + choice ---------------------------------------------
    def freshness(self, replica_id: str) -> int | None:
        ent = self.entries.get(replica_id)
        if ent is None or not ent.snaps:
            return None
        return self.current_epoch - ent.latest_epoch()

    def eligible(self, partition: int, max_staleness: int):
        """Replicas covering ``partition`` whose freshest retained snapshot
        is within the staleness bound: [(entry, epoch, snap, arow), ...]."""
        out = []
        for ent in self.entries.values():
            if not ent.snaps or not ent.cover[partition]:
                continue
            epoch, snap, _wm = ent.snaps[-1]
            if self.current_epoch - epoch <= max_staleness:
                out.append((ent, epoch, snap,
                            int(ent.row_of_partition[partition])))
        return out

    def choose(self, partition: int, max_staleness: int, weight: int = 1):
        """Least-served eligible replica (round-robin load balancing across
        the N secondary copies + the full copy); None = no replica within
        the bound (caller falls back to the OCC path).  ``weight`` — how
        many reads this choice will serve — feeds the balance counter."""
        cands = self.eligible(partition, max_staleness)
        if not cands:
            return None
        ent, epoch, snap, arow = min(cands, key=lambda c: (c[0].serves,
                                                           c[0].replica_id))
        ent.serves += weight
        return ent, epoch, snap, arow

    def serves_by_replica(self) -> dict:
        return {rid: ent.serves for rid, ent in self.entries.items()}
