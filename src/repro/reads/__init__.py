"""Bounded-staleness read-replica serving tier (SCAR-style snapshot reads).

STAR's asymmetric replication materializes a full copy of the database on
the master node and physical partial secondary copies across the mesh —
but until this subsystem they were write targets only.  The read tier
serves read-only transactions directly from those replicas' COMMITTED
two-version snapshots *between* epoch fences, validated by epoch/slab
watermarks instead of OCC:

* :class:`~repro.reads.catalog.SnapshotCatalog` — stamps every replica
  copy with its last-applied fence epoch + slab high-watermark and exposes
  ``freshness(replica) = current_epoch - applied_epoch``;
* :class:`~repro.reads.executor.SnapshotReadExecutor` — one jitted
  batched program of point-read gathers + ``segment_scan`` index probes
  over a chosen replica's ``val/tid`` + index segments, lock-free, every
  result tagged with its snapshot epoch;
* :class:`~repro.reads.tier.ReadTier` — the serving loop: drains the read
  admission lane, load-balances across eligible replicas within the
  ``max_staleness_epochs`` bound, falls back to the OCC path when no
  replica is fresh enough (over-stale data is NEVER returned), and
  removes a killed node's hosted secondary from the catalog until
  recovery re-materializes it.
"""
from repro.reads.catalog import SnapshotCatalog
from repro.reads.executor import SnapshotReadExecutor, reference_read
from repro.reads.tier import ReadTier, ReadTierStats

__all__ = ["ReadTier", "ReadTierStats", "SnapshotCatalog",
           "SnapshotReadExecutor", "reference_read"]
