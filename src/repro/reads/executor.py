"""Vectorized snapshot read executor: one jitted batched program per shape.

Read-only transactions carry only READ point ops and SCAN_READ index
probes (their first ``IDX_OPS`` op slots), so serving a batch needs no
locks, no validation rounds, and no scatter — a fancy-indexed gather of
``val/tid`` plus vmapped ``segment_scan`` probes over the chosen replica's
committed index segments, all inside one jit.  ``arow`` maps each
transaction's home partition to the ARRAY ROW of that partition in the
replica's physical layout (identity for the full copy, the home-major
roll for secondary copies), so the same program serves every replica.

Results are raw committed state — the caller (:class:`ReadTier`) tags
them with the snapshot epoch they were drained against.
``reference_read`` is the numpy oracle the staleness property tests
compare against, bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ops import IDX_OPS, IX_HI, IX_ID, IX_LO, SCAN_READ
from repro.storage.index import SCAN_L, SENTINEL, segment_scan


def _read_program(val, tid, idx_keys, idx_prows, idx_tids, arow, rows,
                  kinds, deltas):
    """val (P,R,C), tid (P,R), idx_* lists of (P,cap_i); arow (B,),
    rows/kinds (B,M), deltas (B,M,C).  Returns the read payload dict."""
    B, M = rows.shape
    out = {"val": val[arow[:, None], rows],          # (B, M, C)
           "tid": tid[arow[:, None], rows]}          # (B, M)
    n_idx = len(idx_keys)
    if not n_idx:
        return out
    K = min(IDX_OPS, M)
    L = SCAN_L
    is_scan = kinds[:, :K] == SCAN_READ              # (B, K)
    lo = deltas[:, :K, IX_LO]
    hi = deltas[:, :K, IX_HI]
    iid = deltas[:, :K, IX_ID]
    scan_key = jnp.full((B, K, L), SENTINEL, jnp.int32)
    scan_prow = jnp.zeros((B, K, L), jnp.int32)
    scan_tid = jnp.zeros((B, K, L), jnp.uint32)
    scan_live = jnp.zeros((B, K, L), bool)
    for i in range(n_idx):
        seg_b = idx_keys[i][arow]                    # (B, cap_i)

        def probe(seg, lo_k, hi_k):
            return jax.vmap(
                lambda l, h: segment_scan(seg, l, h, L + 1))(lo_k, hi_k)

        slots, keys_at, in_r = jax.vmap(probe)(seg_b, lo, hi)  # (B,K,L+1)
        slots, keys_at, in_r = slots[..., :L], keys_at[..., :L], \
            in_r[..., :L]
        sel = (is_scan & (iid == i))[..., None]      # (B, K, 1)
        prow = idx_prows[i][arow[:, None, None], slots]
        ptid = idx_tids[i][arow[:, None, None], slots]
        scan_key = jnp.where(sel, keys_at, scan_key)
        scan_prow = jnp.where(sel, prow, scan_prow)
        scan_tid = jnp.where(sel, ptid, scan_tid)
        scan_live = jnp.where(sel, in_r, scan_live)
    scan_live = scan_live & is_scan[..., None]
    # the scanned window joins the read result: gather the pointed rows
    prow_safe = jnp.clip(scan_prow, 0, val.shape[1] - 1)
    out.update({
        "scan_key": jnp.where(scan_live, scan_key, SENTINEL),
        "scan_prow": jnp.where(scan_live, scan_prow, 0),
        "scan_tid": jnp.where(scan_live, scan_tid, 0),
        "scan_live": scan_live,
        "scan_val": jnp.where(scan_live[..., None],
                              val[arow[:, None, None], prow_safe], 0),
    })
    return out


class SnapshotReadExecutor:
    """Shape-cached jit dispatch over `_read_program`.  Batches pad to the
    next power of two (dummy lanes read row 0 of partition-row 0, results
    sliced away), so live traffic compiles at most log2(B_max) program
    variants per (M, n_indexes) instead of one per instantaneous load."""

    def __init__(self):
        self._jit = jax.jit(_read_program)

    def run(self, snap: dict, arow, rows, kinds, deltas) -> dict:
        idx = snap.get("idx") or []
        arow = np.asarray(arow, np.int32)
        rows = np.asarray(rows, np.int32)
        kinds = np.asarray(kinds, np.int32)
        deltas = np.asarray(deltas, np.int32)
        B = rows.shape[0]
        Bp = 1 << max(0, int(B - 1).bit_length())
        if Bp != B:
            pad = Bp - B
            arow = np.concatenate([arow, np.zeros(pad, np.int32)])
            rows = np.concatenate([rows, np.zeros((pad,) + rows.shape[1:],
                                                  np.int32)])
            kinds = np.concatenate([kinds, np.zeros((pad,) + kinds.shape[1:],
                                                    np.int32)])
            deltas = np.concatenate(
                [deltas, np.zeros((pad,) + deltas.shape[1:], np.int32)])
        out = self._jit(snap["val"], snap["tid"],
                        [ix["key"] for ix in idx],
                        [ix["prow"] for ix in idx],
                        [ix["tid"] for ix in idx],
                        jnp.asarray(arow), jnp.asarray(rows),
                        jnp.asarray(kinds), jnp.asarray(deltas))
        if Bp != B:
            out = {k: v[:B] for k, v in out.items()}
        return out


def reference_read(snap: dict, arow, rows, kinds, deltas) -> dict:
    """Numpy oracle mirroring `_read_program` bit-for-bit (tests only)."""
    val = np.asarray(snap["val"])
    tid = np.asarray(snap["tid"])
    idx = snap.get("idx") or []
    arow = np.asarray(arow, np.int64)
    rows = np.asarray(rows, np.int64)
    kinds = np.asarray(kinds)
    deltas = np.asarray(deltas)
    B, M = rows.shape
    out = {"val": val[arow[:, None], rows], "tid": tid[arow[:, None], rows]}
    if not idx:
        return out
    K, L = min(IDX_OPS, M), SCAN_L
    scan_key = np.full((B, K, L), SENTINEL, np.int32)
    scan_prow = np.zeros((B, K, L), np.int32)
    scan_tid = np.zeros((B, K, L), np.uint32)
    scan_live = np.zeros((B, K, L), bool)
    scan_val = np.zeros((B, K, L, val.shape[2]), np.int32)
    for b in range(B):
        for k in range(K):
            if kinds[b, k] != SCAN_READ:
                continue
            i = int(deltas[b, k, IX_ID])
            lo, hi = int(deltas[b, k, IX_LO]), int(deltas[b, k, IX_HI])
            seg = np.asarray(idx[i]["key"][arow[b]])
            cap = seg.shape[0]
            pos0 = int(np.searchsorted(seg, lo))
            for j in range(L):
                raw = pos0 + j
                s = min(max(raw, 0), cap - 1)
                key = int(seg[s])
                live = raw < cap and lo <= key < hi and key != SENTINEL
                if live:
                    scan_key[b, k, j] = key
                    scan_prow[b, k, j] = np.asarray(idx[i]["prow"][arow[b]])[s]
                    scan_tid[b, k, j] = np.asarray(idx[i]["tid"][arow[b]])[s]
                    scan_live[b, k, j] = True
                    scan_val[b, k, j] = val[arow[b], scan_prow[b, k, j]]
    out.update({"scan_key": scan_key, "scan_prow": scan_prow,
                "scan_tid": scan_tid, "scan_live": scan_live,
                "scan_val": scan_val})
    return out
