"""Incrementally-maintained columnar materialized views over the ChangeLog.

The analytics subscriber the ChangeLog refactor pays for: a columnar
projection of the TPC-C store (the value columns the decision-support
aggregates here read — ``VIEW_COLS``) maintained
incrementally from the SAME ordered op stream the replicas replay,
slab by slab, on whatever device holds the subscriber's arrays.

Correctness rests on the stream's existing guarantees, not new ones:

* partitioned slabs scatter the log's POST-IMAGE values with exactly the
  scatter ``replay_partitioned`` uses (pad-row ``.at[rows_w].set`` per
  queue slot) — the WAL recovery test already pins post-image == replay,
  so the projection is the replayed state's column subset, bit-equal;
* the single-master stream merges under the Thomas write rule
  (``thomas_apply`` on the projected columns) — identical TID
  comparisons pick identical winners, so the projected columns equal
  the replica's.

At every commit fence the working projection is promoted to the
committed one and the CH-benCHmark-style aggregates are computed from it
and STAMPED ``(epoch, aggregates)`` into a bounded history — queryable
between fences (``latest``), with fence-granular time-travel to any
retained epoch (``time_travel``).  ``recompute`` is the from-scratch
oracle over a full committed (P, R, C) value array; the property tests
assert bit-equality at every fence, including across a mid-stream kill +
recovery.  A §4.5 revert snaps the working projection back to committed;
a disk reload rebuilds it via ``on_reset``.

Aggregates (per partition == per warehouse):

* ``revenue``   (P, N_DIST) int64 — Σ order-line amounts per district
  over the retained order ring;
* ``stock_low`` (P,)        int32 — stock rows with quantity below the
  threshold (StockLevel's decision-support cousin);
* ``undelivered`` (P, N_DIST) int32 — NEW-ORDER ring slots not yet
  tombstoned by Delivery (o_id column != 0);
* ``order_latency`` (P, N_DIST, len(LATENCY_BUCKETS)+1) int32 — per-
  district histogram of NewOrder→Delivery latency (in order-ids) over the
  delivered orders retained in the ring: cumulative counts per bucket
  edge plus a trailing total column.

All four read the retained ring state — reused ring slots overwrite in
place, so "revenue" is revenue over the ring window, exactly what the
oracle recomputes.
"""
from __future__ import annotations

from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.replication import thomas_apply
from repro.db.tpcc import N_DIST

#: value columns the views project: col 0 (next_o_id / s_qty / o_id ...),
#: col 2 (order-line amount / d_ytd ...) and col 5 (order latency in
#: order-ids, stamped by Delivery on the orders row)
VIEW_COLS = (0, 2, 5)

#: order-latency histogram bucket edges (latency in order-ids, i.e. how
#: far next_o_id advanced past an order before Delivery consumed it);
#: cumulative counts per edge + a trailing total ("+inf") column
LATENCY_BUCKETS = (1, 2, 4, 8, 16, 32)


class MaterializedViews:
    """ChangeLog subscriber maintaining columnar TPC-C aggregates."""

    def __init__(self, cfg, stock_threshold: int = 15, retain: int = 8):
        self.cfg = cfg
        self.stock_threshold = int(stock_threshold)
        self.retain = int(retain)
        self.proj = None               # (P, R, len(VIEW_COLS)) projection
        self.ptid = None               # (P, R) working TIDs
        self._c_proj = None            # committed projection
        self._c_ptid = None
        self._stamps: deque = deque()  # (epoch, {name: np.ndarray})
        # maintenance counters (analytics bench / summary surface)
        self.slabs_applied = 0
        self.writes_applied = 0
        self.master_merges = 0
        self.commits = 0
        self.reverts = 0
        self._jit_slab = jax.jit(self._apply_slab)
        self._jit_master = jax.jit(self._apply_master)

    # -- stream application ---------------------------------------------
    @staticmethod
    def _apply_slab(proj, ptid, row, vals, tid, write):
        """Scatter one slab's post-image column projection, queue-slot by
        queue-slot — the same pad-row scatter ``replay_partitioned``
        commits with, on the (P, R, len(VIEW_COLS)) projection."""
        R = proj.shape[1]

        def step(carry, slot):
            proj, ptid = carry
            rows_w = jnp.where(slot["write"], slot["row"], R)

            def commit(v, t, r, n, nt):
                v = jnp.concatenate([v, jnp.zeros((1, v.shape[1]),
                                                  v.dtype)])
                t = jnp.concatenate([t, jnp.zeros((1,), t.dtype)])
                return v.at[r].set(n)[:R], t.at[r].set(nt)[:R]

            proj, ptid = jax.vmap(commit)(proj, ptid, rows_w,
                                          slot["val"], slot["tid"])
            return (proj, ptid), None

        slots = jax.tree.map(
            lambda a: jnp.moveaxis(a, 1, 0),
            {"row": row, "val": vals, "tid": tid, "write": write})
        (proj, ptid), _ = jax.lax.scan(step, (proj, ptid), slots)
        return proj, ptid

    @staticmethod
    def _apply_master(proj, ptid, rows, vals, tids):
        """Thomas-merge the single-master stream's projected post-images
        on the flat row space (identical TID comparisons to the replica's
        ``thomas_apply_batch`` — identical winners)."""
        P, R, Cp = proj.shape
        v, t, _ = thomas_apply(proj.reshape(P * R, Cp),
                               ptid.reshape(P * R), rows, vals, tids)
        return v.reshape(P, R, Cp), t.reshape(P, R)

    def on_slab(self, log, info):
        if self.proj is None:
            return
        # cluster slab logs arrive mesh-sharded; the projection lives on
        # one device — gather the slab there (same hop _ReplicaShip pays)
        dev = next(iter(self.proj.devices()))
        log = jax.device_put(
            {k: log[k] for k in ("row", "val", "tid", "write")}, dev)
        vals = jnp.stack([log["val"][..., c] for c in VIEW_COLS], axis=-1)
        self.proj, self.ptid = self._jit_slab(
            self.proj, self.ptid, log["row"], vals, log["tid"],
            log["write"])
        self.slabs_applied += 1
        self.writes_applied += int(np.asarray(log["write"]).sum())

    def on_master(self, stream):
        if self.proj is None or stream["log"] is None:
            return
        dev = next(iter(self.proj.devices()))
        log = jax.device_put(
            {k: stream["log"][k] for k in ("row", "val", "tid", "write")},
            dev)
        C = log["val"].shape[-1]
        rows = jnp.where(log["write"], log["row"], -1).reshape(-1)
        vals = jnp.stack(
            [log["val"].reshape(-1, C)[:, c] for c in VIEW_COLS], axis=-1)
        tids = log["tid"].reshape(-1)
        self.proj, self.ptid = self._jit_master(self.proj, self.ptid,
                                                rows, vals, tids)
        self.master_merges += 1
        self.writes_applied += int(np.asarray(log["write"]).sum())

    # -- fences ----------------------------------------------------------
    def on_commit(self, epoch, record):
        if self.proj is None:
            return
        self._c_proj, self._c_ptid = self.proj, self.ptid
        self.commits += 1
        self._stamp(epoch)

    def on_revert(self, epoch, n_slabs):
        if self._c_proj is None:
            return
        self.proj, self.ptid = self._c_proj, self._c_ptid
        self.reverts += 1

    def on_reset(self, val, tid, epoch):
        """Disk reload (§4.5.1): rebuild the projection from the recovered
        committed arrays and stamp the recovered fence."""
        val = jnp.asarray(val)
        self.proj = jnp.stack([val[..., c] for c in VIEW_COLS], axis=-1)
        self.ptid = jnp.asarray(tid)
        self._c_proj, self._c_ptid = self.proj, self.ptid
        self._stamp(epoch)

    def _stamp(self, epoch):
        epoch = int(epoch)
        if self._stamps and self._stamps[-1][0] == epoch:
            return                                   # idempotent per fence
        self._stamps.append(
            (epoch, self._aggregates(np.asarray(self._c_proj))))
        while len(self._stamps) > self.retain:
            self._stamps.popleft()

    # -- aggregates ------------------------------------------------------
    def _aggregates(self, proj) -> dict:
        """Aggregates off an np (P, R, len(VIEW_COLS)) column projection.
        Host-side numpy on purpose: int64 sums are exact without the x64
        flag, and the fence stamp is the only consumer (once per epoch)."""
        cfg = self.cfg
        P = proj.shape[0]
        ring = cfg.order_ring
        ol = proj[:, cfg.off_order_line:
                  cfg.off_order_line + N_DIST * ring * 15, 1]
        st = proj[:, cfg.off_stock:cfg.off_stock + cfg.n_items, 0]
        no = proj[:, cfg.off_new_order:cfg.off_new_order + N_DIST * ring, 0]
        # Delivery stamps the order's age (in order-ids, always >= 1) in
        # orders col 5; NewOrder's whole-row SET zeroes it on ring reuse,
        # so lat > 0 selects exactly the ring's delivered-and-retained
        # orders.  Counts are exact integers — bit-equal to the oracle.
        lat = proj[:, cfg.off_orders:cfg.off_orders + N_DIST * ring,
                   2].reshape(P, N_DIST, ring)
        live = lat > 0
        return {
            "revenue": ol.astype(np.int64).reshape(
                P, N_DIST, ring * 15).sum(axis=-1),
            "stock_low": (st < self.stock_threshold).sum(
                axis=-1).astype(np.int32),
            "undelivered": (no.reshape(P, N_DIST, ring) != 0).sum(
                axis=-1).astype(np.int32),
            "order_latency": np.stack(
                [(live & (lat <= b)).sum(axis=-1)
                 for b in LATENCY_BUCKETS] + [live.sum(axis=-1)],
                axis=-1).astype(np.int32),
        }

    def recompute(self, val) -> dict:
        """From-scratch oracle: the same aggregates off a full committed
        (P, R, C) value array — what every stamped fence must bit-equal
        (integer sums are exact and order-free)."""
        v = np.asarray(val)
        return self._aggregates(np.stack([v[..., c] for c in VIEW_COLS],
                                         axis=-1))

    # -- queries ---------------------------------------------------------
    @property
    def attached(self) -> bool:
        return self.proj is not None

    def latest(self):
        """(epoch, aggregates) of the freshest committed fence stamp."""
        return self._stamps[-1] if self._stamps else None

    def retained_epochs(self) -> list[int]:
        return [e for e, _ in self._stamps]

    def time_travel(self, epoch: int):
        """The aggregates exactly as stamped at fence ``epoch`` (None if
        no longer retained)."""
        for e, aggs in self._stamps:
            if e == int(epoch):
                return aggs
        return None
