"""ChangeLog: the one ordered op stream every replica consumer rides.

STAR's correctness hinges on a single ordered stream of record + index
operations — the full replica replays it, the physical secondary homes
roll-ship it, the WAL persists it, the read tier's catalog stamps its
watermark, and the fence byte model attributes its slabs.  Before this
module each of those consumers was hand-fed by the engines with its own
slab bookkeeping; now the engines PUBLISH once and every consumer is a
:class:`Subscriber`.

Stream structure (exactly the §5 shape the engines execute):

* an epoch's partitioned phase emits ``S = n_slabs`` ordered **slabs** —
  contiguous queue-slot ranges ``[T*s//S, T*(s+1)//S)`` — published in
  order via :meth:`ChangeLog.publish_slab` while the next slab executes;
* the single-master phase emits one round-ordered **master stream**
  (value post-images + index-op rounds) via :meth:`publish_master`;
* the commit fence retires the epoch via :meth:`commit` — consumed slabs
  move to the committed **slab ledger** ``(epoch, slab)`` (the read
  tier's watermark source, tests pin exactly-once application from it)
  and subscribers see ``on_commit`` with the whole epoch's record;
* a §4.5 revert calls :meth:`revert` — the in-flight record is discarded
  and the slab high-watermark resets, so a re-executed epoch re-publishes
  from slab 0 onto committed state exactly once.

The ledger is a bounded telemetry window: overflow is EXPLICIT drop-
oldest, counted in :attr:`ledger_dropped` and surfaced through engine
stats (it used to be silent truncation — a revert near the bound could
not be audited).

Subscriber protocol (all methods optional, duck-typed)::

    class Subscriber:
        needs_write_mask = False      # True: info carries per-partition
                                      # dirty masks (host transfer cost)
        def on_slab(self, log, info): ...   # ordered, in publish order
        def on_master(self, stream): ...    # {"log","kinds","delta"}
        def on_commit(self, epoch, record): ...
        def on_revert(self, epoch, n_slabs): ...
        def on_reset(self, val, tid, epoch): ...   # disk reload (§4.5.1)

``on_slab``'s ``info`` is ``{"epoch", "slab", "dirty"}`` where ``dirty``
is a (P,) bool per-partition write mask (None unless some subscriber
sets ``needs_write_mask``) — the read tier's mid-epoch slab-watermark
gate feeds on it.  ``on_commit``'s ``record`` is
``{"part": plog | None, "sm": slog | None, "cross_kinds", "cross_delta"}``
— the WAL sink fans it to the per-worker logs inside the fence.

Byte attribution (:meth:`attribute`) is the SINGLE source both engines'
``op_bytes_overlapped`` / ``op_bytes_fence`` stats and the fence network
model derive from, wrapping :func:`repro.core.replication
.epoch_stream_bytes` + :func:`~repro.core.replication.split_overlapped`
— the pinned invariant (overlapped + fence == total == Σ slab sizes) is
tested once against this object instead of per engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs


@dataclass
class Attribution:
    """One epoch's op-stream byte attribution (the single source)."""
    value_bytes_alt: int               # if value replication had shipped
    slab_bytes: list[int] = field(default_factory=list)
    index_op_bytes: int = 0            # index ops riding the stream
    overlapped: int = 0                # shipped DURING execution (head)
    fence: int = 0                     # the unshipped tail the fence waits on

    @property
    def total(self) -> int:
        return sum(self.slab_bytes)


class ChangeLog:
    """Owns one engine's ordered epoch/slab op stream + its subscribers."""

    LEDGER_CAP = 4096                  # committed-slab telemetry window

    def __init__(self, n_slabs: int, ledger_cap: int | None = None):
        assert n_slabs >= 1, n_slabs
        self.n_slabs = int(n_slabs)
        self.ledger_cap = int(ledger_cap if ledger_cap is not None
                              else self.LEDGER_CAP)
        self._subs: list = []
        self._needs_mask = False
        # in-flight epoch record
        self._slab_logs: list = []     # published slab logs, in order
        self._plog_cache = None        # concat of _slab_logs (lazy)
        self._master = None            # {"log","kinds","delta"}
        self.slab_hwm = 0              # published slabs of in-flight epoch
        # committed history
        self.ledger: list[tuple[int, int]] = []    # committed (epoch, slab)
        self.ledger_dropped = 0        # explicit drop-oldest overflow count

    # -- subscribers -----------------------------------------------------
    def subscribe(self, sub):
        """Register a subscriber (fired in registration order — the full
        replica registers before the secondaries before the sinks, so the
        replay order the engines relied on is preserved)."""
        self._subs.append(sub)
        self._needs_mask = any(getattr(s, "needs_write_mask", False)
                               for s in self._subs)
        return sub

    def _fire(self, method: str, *args):
        for sub in self._subs:
            fn = getattr(sub, method, None)
            if fn is not None:
                fn(*args)

    # -- slab framing ----------------------------------------------------
    def slab_bounds(self, T: int) -> list[int]:
        """The §5 slab frame: T queue slots split into ``n_slabs``
        contiguous chunks — the SAME bounds the byte model
        (``repl.slab_op_bytes``) attributes with."""
        S = max(1, min(self.n_slabs, T))
        return [T * s // S for s in range(S + 1)]

    # -- publication (in stream order) -----------------------------------
    def publish_slab(self, log, epoch: int):
        """Publish one committed slab of the partitioned op stream.  Fires
        every subscriber's ``on_slab`` synchronously (the engines call
        this while the NEXT slab executes, so subscriber work overlaps
        execution) and advances the slab high-watermark."""
        dirty = None
        if self._needs_mask:
            # (P,) bool: partitions this slab wrote — host transfer of the
            # write mask, paid only when a subscriber asked for it
            dirty = np.asarray(log["write"]).any(axis=(1, 2))
        info = {"epoch": int(epoch), "slab": self.slab_hwm, "dirty": dirty}
        self._slab_logs.append(log)
        self._plog_cache = None
        # the subscriber seam IS the ship path: one span per published slab
        # covers replica replay + secondary roll-ship + MV apply + WAL
        with obs.span("changelog.slab_ship", cat="ship",
                      epoch=int(epoch), slab=self.slab_hwm,
                      subscribers=len(self._subs)):
            self._fire("on_slab", log, info)
        self.slab_hwm += 1

    def publish_master(self, log, kinds=None, delta=None):
        """Publish the single-master phase's stream: the round-ordered
        value/index log plus the batch's static op arrays (index-op
        replay and WAL recovery re-apply (kind, operand), which the log
        itself does not carry)."""
        self._master = {"log": log, "kinds": kinds, "delta": delta}
        with obs.span("changelog.master_ship", cat="ship",
                      subscribers=len(self._subs)):
            self._fire("on_master", self._master)

    def epoch_plog(self):
        """The in-flight epoch's whole partitioned log — the ordered
        concatenation of its published slabs (cached; slab axis 1)."""
        if self._plog_cache is None:
            if not self._slab_logs:
                return None
            if len(self._slab_logs) == 1:
                self._plog_cache = self._slab_logs[0]
            else:
                self._plog_cache = jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, axis=1),
                    *self._slab_logs)
        return self._plog_cache

    # -- byte attribution (the single source) ----------------------------
    def attribute(self, batch, plog, has_index: bool, pad_fn) -> Attribution:
        """Attribute one epoch's partitioned-stream bytes: per-slab sizes
        on the same ``slab_bounds`` frame, the overlapped/fence split, and
        the index-op share.  All zeros when the batch carries no byte
        tables (see ``repl.epoch_stream_bytes``)."""
        # deferred: repro.core.engine imports this module at its top level
        from repro.core import replication as repl
        vb_alt, slab_bytes, ib = repl.epoch_stream_bytes(
            batch, plog, has_index, self.n_slabs, pad_fn)
        head, tail = repl.split_overlapped(slab_bytes)
        return Attribution(value_bytes_alt=vb_alt, slab_bytes=slab_bytes,
                           index_op_bytes=ib, overlapped=head, fence=tail)

    # -- commit / revert / reset ----------------------------------------
    def commit(self, epoch: int) -> tuple[int, int]:
        """Commit fence: retire the in-flight slabs into the committed
        ledger (explicit drop-oldest at ``ledger_cap``), hand the whole
        epoch record to subscribers, clear the in-flight state.  Returns
        ``(slabs_retired, ledger_entries_dropped)``."""
        shipped = self.slab_hwm
        for s in range(shipped):
            self.ledger.append((int(epoch), s))
        dropped = max(0, len(self.ledger) - self.ledger_cap)
        if dropped:
            del self.ledger[:dropped]          # drop-oldest, counted
            self.ledger_dropped += dropped
        record = {"part": self.epoch_plog(),
                  "sm": self._master["log"] if self._master else None,
                  "cross_kinds": self._master["kinds"] if self._master
                  else None,
                  "cross_delta": self._master["delta"] if self._master
                  else None}
        with obs.span("changelog.commit", cat="fence", epoch=int(epoch),
                      slabs=shipped):
            self._fire("on_commit", int(epoch), record)
        self._clear()
        return shipped, dropped

    def revert(self, epoch: int) -> int:
        """§4.5 revert: discard the in-flight epoch's record and reset the
        slab high-watermark — the re-executed epoch re-publishes from
        slab 0 onto committed state, so every consumer applies each
        committed slab exactly once.  Returns the slabs discarded."""
        discarded = self.slab_hwm
        self._fire("on_revert", int(epoch), discarded)
        self._clear()
        return discarded

    def reset_from_state(self, val, tid, epoch: int):
        """§4.5.1 disk reload: the stream history is gone — subscribers
        rebuild their state from the recovered committed arrays."""
        self._fire("on_reset", val, tid, int(epoch))

    def _clear(self):
        self._slab_logs = []
        self._plog_cache = None
        self._master = None
        self.slab_hwm = 0

    # -- watermark (read-tier stamping) ----------------------------------
    def watermark(self, committed_epoch: int) -> tuple[int, int]:
        """The snapshot watermark the catalog stamps: (last committed
        fence epoch, that epoch's retired slab count from the ledger)."""
        from repro.core import replication as repl
        return repl.snapshot_watermark(committed_epoch, self.ledger)
