"""changelog: the one ordered op stream + its subscribers (CDC for STAR).

``ChangeLog`` owns the epoch/slab-structured record + index op stream
both engines publish; every consumer — full-replica replay, secondary
roll-ship, WAL durability, snapshot-catalog stamping, fence byte
attribution, and the HTAP materialized views — is a ``Subscriber``.
"""
from repro.changelog.log import Attribution, ChangeLog
from repro.changelog.views import MaterializedViews, VIEW_COLS
from repro.changelog.analytics import AnalyticsLane

__all__ = ["Attribution", "ChangeLog", "MaterializedViews", "VIEW_COLS",
           "AnalyticsLane"]
