"""The HTAP analytics lane: CH-benCHmark-style queries off the MVs.

CH-benCHmark runs TPC-C's decision-support cousins concurrently with the
transactional mix; here the analytical side never touches the OCC phases
at all — it reads the epoch-stamped aggregate snapshots the
:class:`~repro.changelog.views.MaterializedViews` subscriber maintains
from the ChangeLog, so queries are answered BETWEEN fences (and during
the in-flight epoch) with fence-consistent results, plus fence-granular
time-travel to any retained epoch.

The lane plugs into ``TxnService``/``ClusterTxnService`` next to the
read tier: ``ensure_attached`` subscribes the views to the engine's
changelog (seeding them from the committed full-replica state) and
``serve`` runs one round of the query mix, stamping per-query latency:

* ``top_revenue``    — top-k (warehouse, district) pairs by ring revenue;
* ``stock_low``      — warehouses ranked by stock-below-threshold count;
* ``undelivered``    — max / total NEW-ORDER backlog depth per district;
* ``order_latency``  — fleet-wide NewOrder→Delivery latency histogram
  (order-id distance buckets) plus the worst district's p-high bucket;
* ``revenue_delta``  — time-travel: revenue movement between the oldest
  and newest retained fence (periodic, exercises the stamp history).
"""
from __future__ import annotations

import time

import numpy as np

from repro.changelog.views import LATENCY_BUCKETS, MaterializedViews
from repro.obs import trace as obs


class AnalyticsLane:
    """Serves the analytical query mix from epoch-stamped MV snapshots."""

    QUERIES = ("top_revenue", "stock_low", "undelivered", "order_latency",
               "revenue_delta")

    def __init__(self, cfg, top_k: int = 5, stock_threshold: int = 15,
                 retain: int = 8, travel_every: int = 4):
        self.views = MaterializedViews(cfg, stock_threshold=stock_threshold,
                                       retain=retain)
        self.top_k = int(top_k)
        self.travel_every = int(travel_every)
        self._attached = False
        self.serves = 0
        self.queries = 0
        self.by_query = {q: 0 for q in self.QUERIES}
        self.query_s = 0.0
        self.lat_ms: list = []
        self.max_epoch_lag = 0
        self.last: dict = {}

    # -- wiring ----------------------------------------------------------
    def ensure_attached(self, engine) -> bool:
        """Subscribe the views to ``engine.changelog``, seeding the
        projection from the committed full-replica state."""
        if self._attached:
            return True
        clog = getattr(engine, "changelog", None)
        if clog is None:
            return False
        val, tid = engine.committed_state()
        clog.subscribe(self.views)
        self.views.on_reset(val, tid, engine.committed_epoch)
        self._attached = True
        return True

    # -- query mix -------------------------------------------------------
    def serve(self, committed_epoch: int, now_s: float | None = None):
        """One round of the analytical mix against the freshest stamp.
        Returns the results dict (also kept in ``self.last``)."""
        stamp = self.views.latest()
        if stamp is None:
            return None
        epoch, aggs = stamp
        self.max_epoch_lag = max(self.max_epoch_lag,
                                 int(committed_epoch) - int(epoch))
        out = {"epoch": int(epoch)}
        t0 = time.perf_counter()
        out["top_revenue"] = self._q_top_revenue(aggs)
        out["stock_low"] = self._q_stock_low(aggs)
        out["undelivered"] = self._q_undelivered(aggs)
        out["order_latency"] = self._q_order_latency(aggs)
        ran = 4
        if self.serves % self.travel_every == 0:
            delta = self._q_revenue_delta()
            if delta is not None:
                out["revenue_delta"] = delta
                ran += 1
        dt = time.perf_counter() - t0
        obs.complete("analytics.serve", "service", t0, t0 + dt,
                     epoch=int(epoch), queries=ran)
        self.query_s += dt
        self.lat_ms.append(1e3 * dt / ran)
        self.serves += 1
        self.queries += ran
        self.last = out
        return out

    def _q_top_revenue(self, aggs):
        self.by_query["top_revenue"] += 1
        rev = aggs["revenue"]
        flat = rev.reshape(-1)
        k = min(self.top_k, flat.size)
        top = np.argsort(flat, kind="stable")[::-1][:k]
        return [(int(i) // rev.shape[1], int(i) % rev.shape[1],
                 int(flat[i])) for i in top]

    def _q_stock_low(self, aggs):
        self.by_query["stock_low"] += 1
        low = aggs["stock_low"]
        return {"total": int(low.sum()), "worst_warehouse": int(low.argmax()),
                "worst_count": int(low.max())}

    def _q_undelivered(self, aggs):
        self.by_query["undelivered"] += 1
        und = aggs["undelivered"]
        return {"total": int(und.sum()), "max_depth": int(und.max()),
                "mean_depth": float(und.mean())}

    def _q_order_latency(self, aggs):
        """Fleet-wide latency histogram: sum the per-district cumulative
        bucket counts; report the distribution plus the district whose
        deliveries lag the most (largest share above the last edge)."""
        self.by_query["order_latency"] += 1
        h = aggs["order_latency"].astype(np.int64)   # (P, N_DIST, NB+1)
        fleet = h.sum(axis=(0, 1))                   # cumulative + total
        total = int(fleet[-1])
        over = h[..., -1] - h[..., -2]               # > last bucket edge
        worst = int(over.reshape(-1).argmax())
        return {
            "buckets": {f"le_{b}": int(fleet[i])
                        for i, b in enumerate(LATENCY_BUCKETS)},
            "delivered": total,
            "over_last_bucket": int(fleet[-1] - fleet[-2]),
            "worst_warehouse": worst // h.shape[1],
            "worst_district": worst % h.shape[1],
            "worst_over": int(over.reshape(-1)[worst]),
        }

    def _q_revenue_delta(self):
        epochs = self.views.retained_epochs()
        if len(epochs) < 2:
            return None
        self.by_query["revenue_delta"] += 1
        old = self.views.time_travel(epochs[0])
        new = self.views.time_travel(epochs[-1])
        d = new["revenue"].astype(np.int64) - old["revenue"].astype(np.int64)
        return {"from_epoch": epochs[0], "to_epoch": epochs[-1],
                "total": int(d.sum()), "max": int(d.max())}

    # -- surfacing -------------------------------------------------------
    def summary(self) -> dict:
        lat = np.asarray(self.lat_ms) if self.lat_ms else np.zeros(1)
        v = self.views
        return {
            "analytics_serves": self.serves,
            "analytics_queries": self.queries,
            "analytics_by_query": dict(self.by_query),
            "analytics_q_p50_ms": float(np.percentile(lat, 50)),
            "analytics_q_p99_ms": float(np.percentile(lat, 99)),
            "analytics_query_s": self.query_s,
            "analytics_max_epoch_lag": self.max_epoch_lag,
            "analytics_retained_epochs": len(v.retained_epochs()),
            "analytics_mv_slabs": v.slabs_applied,
            "analytics_mv_writes": v.writes_applied,
            "analytics_mv_commits": v.commits,
            "analytics_mv_reverts": v.reverts,
        }
