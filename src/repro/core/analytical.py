"""Analytical model (§6.3, Eqs 3-5) — Figures 3 and 10 derive from these.

  T_part(n)  = (n_s*t_s + n_c*t_c)/n                       (3)
  T_nonpart(n) = (n_s + n_c)*t_s                           (4)
  T_STAR(n)  = (n_s/n + n_c)*t_s                           (5)

With K = t_c/t_s and P = n_c/(n_c+n_s):

  I_part(n)    = (K*P - P + 1)/(n*P - P + 1)
  I_nonpart(n) = n/(n*P - P + 1)
  I(n)         = n/(n*P - P + 1)          (STAR speedup over one node)
"""
from __future__ import annotations

import numpy as np


def t_partitioning(n, n_s, n_c, t_s, t_c):
    return (n_s * t_s + n_c * t_c) / n


def t_nonpartitioned(n, n_s, n_c, t_s):
    return (n_s + n_c) * t_s


def t_star(n, n_s, n_c, t_s):
    return (n_s / n + n_c) * t_s


def improvement_over_partitioning(n, P, K):
    P = np.asarray(P, dtype=np.float64)
    return (K * P - P + 1.0) / (n * P - P + 1.0)


def improvement_over_nonpartitioned(n, P):
    P = np.asarray(P, dtype=np.float64)
    return n / (n * P - P + 1.0)


def star_speedup(n, P):
    """I(n) = T_STAR(1)/T_STAR(n) — Figure 3."""
    P = np.asarray(P, dtype=np.float64)
    return n / (n * P - P + 1.0)


def crossover_K(n):
    """STAR beats partitioning-based systems when K > n (§6.3)."""
    return float(n)
