"""Phase-switching controller (§4.3, Eqs 1-2).

    tau_p + tau_s = e                        (1)
    tau_s*t_s / (tau_p*t_p + tau_s*t_s) = P  (2)

t_p, t_s are monitored throughputs (txn/s) of the two phases; P is the
cross-partition fraction; e the iteration time.  Solving:

    tau_s = e * P*t_p / ((1-P)*t_s + P*t_p),    tau_p = e - tau_s

with the paper's edge case P = 0 -> (tau_p, tau_s) = (e, 0).

Adaptive epoch length (SCAR/Lion-style reaction to the observed mix): with
``adaptive=True`` the controller drives ``e_ms`` from the measured
enqueue→formation queue-delay EMA the service layer feeds in through
``observe_latency``.  Under epoch group commit the ideal queue delay is
~e/2 (arrivals wait half an epoch on average), so the controller steers
``e_ms`` toward ``2 * queue_delay`` — longer epochs when measured delay
says batches form slower than the epoch turns (amortize fences), shorter
when the system is underloaded (cut latency) — clamped to
[e_min_ms, e_max_ms] and EMA-smoothed so a burst cannot whipsaw the epoch.
The flag defaults to OFF: fig12's fixed 10 ms epochs stay reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass, field


DEFAULT_ITERATION_MS = 10.0        # paper default (§4.3, §7.4)


def solve_phase_times(e_ms: float, t_p: float, t_s: float, frac_cross: float):
    P = min(max(frac_cross, 0.0), 1.0)
    if P <= 0.0 or t_s <= 0.0:
        return e_ms, 0.0
    if P >= 1.0 or t_p <= 0.0:
        return 0.0, e_ms
    tau_s = e_ms * P * t_p / ((1.0 - P) * t_s + P * t_p)
    return e_ms - tau_s, tau_s


@dataclass
class PhaseController:
    """Tracks real-time throughput telemetry and yields (tau_p, tau_s)."""
    e_ms: float = DEFAULT_ITERATION_MS
    ema: float = 0.5
    t_p: float = 0.0               # partitioned-phase txn/s (EMA)
    t_s: float = 0.0               # single-master txn/s (EMA)
    frac_cross: float = 0.0
    queue_delay_ms: float = 0.0    # measured enqueue→batch-formation (EMA)
    measured_commit_ms: float = 0.0  # measured enqueue→commit-fence (EMA)
    fence_wait_ms: float = 0.0     # cluster: max per-node fence wait (EMA)
    adaptive: bool = False         # drive e_ms from the queue-delay EMA
    e_min_ms: float = 2.0
    e_max_ms: float = 50.0
    adapt_gain: float = 0.25       # per-observation step toward the target
    history: list = field(default_factory=list)

    def observe(self, phase: str, n_txns: int, elapsed_s: float,
                frac_cross: float | None = None):
        if elapsed_s <= 0:
            return
        rate = n_txns / elapsed_s
        if phase == "partitioned":
            self.t_p = rate if self.t_p == 0 else (
                self.ema * rate + (1 - self.ema) * self.t_p)
        else:
            self.t_s = rate if self.t_s == 0 else (
                self.ema * rate + (1 - self.ema) * self.t_s)
        if frac_cross is not None:
            self.frac_cross = frac_cross

    def observe_latency(self, queue_delay_ms: float,
                        commit_latency_ms: float | None = None):
        """Feed *measured* end-to-end latency from the service layer
        (enqueue→formation queue delay, and optionally enqueue→commit-fence)
        so Eq. 1–2 planning and latency reporting reflect live traffic
        instead of the synthetic U(0, e) assumption."""
        if queue_delay_ms >= 0:
            self.queue_delay_ms = queue_delay_ms if self.queue_delay_ms == 0 \
                else (self.ema * queue_delay_ms
                      + (1 - self.ema) * self.queue_delay_ms)
        if commit_latency_ms is not None and commit_latency_ms >= 0:
            self.measured_commit_ms = commit_latency_ms \
                if self.measured_commit_ms == 0 \
                else (self.ema * commit_latency_ms
                      + (1 - self.ema) * self.measured_commit_ms)
        if self.adaptive and self.queue_delay_ms > 0:
            # group-commit ideal: queue delay ≈ e/2 -> steer e toward
            # 2 * measured delay, bounded and low-pass filtered
            target = min(max(2.0 * self.queue_delay_ms, self.e_min_ms),
                         self.e_max_ms)
            self.e_ms += self.adapt_gain * (target - self.e_ms)

    def observe_fence_wait(self, max_wait_ms: float):
        """Cluster coordinator telemetry: the slowest node sets the fence;
        everyone else waits.  The EMA of that worst-case wait quantifies
        per-node skew (fig13 reports it) and is the §4.3 signal a deployment
        would use to rebalance partitions across nodes."""
        if max_wait_ms < 0:
            return
        self.fence_wait_ms = max_wait_ms if self.fence_wait_ms == 0 else (
            self.ema * max_wait_ms + (1 - self.ema) * self.fence_wait_ms)

    def plan(self):
        tau_p, tau_s = solve_phase_times(self.e_ms, self.t_p, self.t_s,
                                         self.frac_cross)
        self.history.append((tau_p, tau_s))
        return tau_p, tau_s

    def expected_mean_latency_ms(self) -> float:
        """§4.3: deferral is symmetric; mean latency ≈ (tau_p + tau_s)/2 —
        used until the service layer reports a measured figure, after which
        the measured enqueue→commit EMA wins."""
        if self.measured_commit_ms > 0:
            return self.measured_commit_ms
        return self.e_ms / 2.0
