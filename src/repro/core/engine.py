"""STAR engine: phase-switched epochs over the storage subsystem (§3-§5).

One engine instance models the cluster: the master view (the designated full
replica) plus a backup replica kept consistent purely through the replication
streams — value replication (Thomas write rule, out-of-order) from the
single-master phase and ordered operation replication from the partitioned
phase (hybrid strategy, §5).  State lives in two ``storage.StorageEngine``
instances (array-resident tables + ordered secondary indexes, two-version
records); index maintenance replays through the same per-round/per-slot
batches the executors installed, so ``replica_consistent()`` verifying
bit-equality at each fence covers indexes as well as records.

The replication fence is no longer free: ``_fence`` pushes the epoch's
stream bytes through the ``baselines.cost_model.Network`` envelope and
reports the modeled inter-node lag as ``t_fence_net_s`` (paper §7.6: TPC-C
saturates the NIC at 4 nodes).

Fault tolerance: ``inject_failure``/``recover`` drive the §4.5 machinery —
revert to the last committed epoch via the two-version records, classify the
failure case, re-master partitions, catch up via Thomas-rule apply.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.cost_model import Network
from repro.changelog.log import ChangeLog
from repro.core import replication as repl
from repro.core.fault import ClusterConfig, make_recovery_plan
from repro.core.partitioned import run_partitioned
from repro.core.phase_switch import PhaseController
from repro.core.single_master import run_single_master
from repro.obs import trace as obs
from repro.storage import IndexSpec, StorageEngine


@dataclass
class EngineStats:
    epochs: int = 0
    committed_single: int = 0
    committed_cross: int = 0
    user_aborts: int = 0
    consume_skips: int = 0          # Delivery districts skipped (stale scan)
    index_overflow: int = 0         # live index keys dropped at capacity
    retries: int = 0
    fences: int = 0
    value_bytes: int = 0
    op_bytes_hybrid: int = 0
    value_bytes_if_not_hybrid: int = 0
    index_op_bytes: int = 0         # index-maintenance ops on the op stream
    op_bytes_overlapped: int = 0    # shipped DURING the partitioned phase
    op_bytes_fence: int = 0         # the unshipped tail the fence waits on
    slabs_shipped: int = 0          # stream slabs applied to replicas
    slabs_discarded: int = 0        # in-flight slabs dropped by a revert
    ledger_dropped: int = 0         # slab-ledger entries aged out at the cap
    part_time_s: float = 0.0
    sm_time_s: float = 0.0
    sm_rounds: int = 0              # OCC rounds executed (kernel launches)
    fence_time_s: float = 0.0
    fence_net_s: float = 0.0


class _ReplicaReplay:
    """ChangeLog subscriber keeping the operation replica consistent: the
    ordered partitioned stream replays per slab (``replay_partitioned``),
    the single-master stream merges under the Thomas write rule with its
    round-ordered index ops (``replay_index_rounds``) — the same §5 hybrid
    strategy the engine used to hand-feed."""

    def __init__(self, eng):
        self.eng = eng

    def on_slab(self, log, info):
        eng = self.eng
        rv, rt, ri = eng._jit_replay(
            eng.replica_store.val, eng.replica_store.tid, log,
            eng.replica_store.indexes if eng.has_index else None,
            kernel=eng.kernel)
        eng.replica_store.val, eng.replica_store.tid = rv, rt
        if eng.has_index:
            eng.replica_store.indexes = ri

    def on_master(self, stream):
        eng = self.eng
        log = stream["log"]
        P, R, C = eng.P, eng.R, eng.C
        rflat_val = eng.replica_store.val.reshape(P * R, C)
        rflat_tid = eng.replica_store.tid.reshape(P * R)
        rv, rt, _ = eng._jit_thomas(rflat_val, rflat_tid, log)
        eng.replica_store.val = rv.reshape(P, R, C)
        eng.replica_store.tid = rt.reshape(P, R)
        if eng.has_index:
            eng.replica_store.indexes = eng._jit_replay_idx(
                eng.replica_store.indexes, stream["kinds"], stream["delta"],
                log["iwrite"], log["tid"], kernel=eng.kernel)

    def on_reset(self, val, tid, epoch):
        self.eng.replica_store.load_state(self.eng.store.snapshot)


class StarEngine:
    def __init__(self, n_partitions: int, rows_per_partition: int,
                 n_cols: int = 10, init_val=None, hybrid_replication=True,
                 max_rounds=16, cluster: ClusterConfig | None = None,
                 iteration_ms: float = 10.0,
                 indexes: list[IndexSpec] | None = None,
                 net: Network | None = None, adaptive_epoch: bool = False,
                 kernel: str = "jnp", strict_index: bool = False,
                 durability=None, n_slabs: int = 4):
        """kernel: "jnp" (reference executors) or "pallas" (fused OCC
        kernels, interpreted off-TPU) — bit-identical results either way.
        strict_index: raise instead of counting when an ordered-index
        segment overflows its capacity (silently dropping the largest key
        otherwise — see storage.index.segment_apply).
        durability: optional ``db.wal.Durability`` — committed epochs
        append their value streams — and, with indexes attached, their
        ordered index-op streams — to per-worker write-ahead logs (flushed
        inside the commit fence) with checkpoints on a cadence;
        ``db.wal.recover_full`` then rebuilds the exact committed state
        (records AND index segments) from disk (§4.5.1's UNAVAILABLE
        case).
        n_slabs: the §5 op-stream overlap model — each epoch's partitioned
        stream ships in ``n_slabs`` chunks, the first ``n_slabs - 1``
        overlapped with execution and only the tail exposed at the fence
        (``n_slabs=1`` reproduces the old ship-everything-at-the-fence
        accounting)."""
        P, R, C = n_partitions, rows_per_partition, n_cols
        self.P, self.R, self.C = P, R, C
        assert kernel in ("jnp", "pallas"), kernel
        self.kernel = kernel
        self.strict_index = strict_index
        self.store = StorageEngine(P, R, C, init_val=init_val,
                                   index_specs=indexes)
        self.replica_store = StorageEngine(P, R, C, init_val=init_val,
                                           index_specs=indexes)
        self.has_index = bool(indexes)
        self.epoch = 1
        # read-tier watermark: the fence epoch the committed snapshots
        # correspond to — 0 until the first epoch's commit fence
        self.committed_epoch = 0
        self.part_seq = jnp.zeros((P,), jnp.uint32)
        self.sm_last_tid = None
        self.hybrid = hybrid_replication
        self.max_rounds = max_rounds
        self.cluster = cluster or ClusterConfig(f=1, k=max(P, 1),
                                                n_partitions=P)
        self.controller = PhaseController(e_ms=iteration_ms,
                                          adaptive=adaptive_epoch)
        self.net = net or Network()
        assert n_slabs >= 1, n_slabs
        self.n_slabs = n_slabs
        self.durability = durability
        self.stats = EngineStats()
        self._jit_part = jax.jit(run_partitioned,
                                 static_argnames=("kernel",))
        self._jit_sm = jax.jit(run_single_master,
                               static_argnames=("max_rounds", "deterministic",
                                                "kernel"))
        self._jit_thomas = jax.jit(repl.thomas_apply_batch)
        self._jit_replay = jax.jit(repl.replay_partitioned,
                                   static_argnames=("kernel",))
        self._jit_replay_idx = jax.jit(repl.replay_index_rounds,
                                       static_argnames=("kernel",))
        # the one ordered op stream: the engine PUBLISHES (slabs, master
        # stream, commit/revert) and every consumer subscribes — the
        # operation replica first (stream order), then the WAL sink
        self.changelog = ChangeLog(n_slabs)
        self.changelog.subscribe(_ReplicaReplay(self))
        if durability is not None:
            from repro.db.wal import WalSink
            durability.attach(self.store.val, self.store.tid,
                              indexes=self.store.indexes
                              if self.has_index else None)
            self.changelog.subscribe(WalSink(
                durability, self.R, self.C,
                np.arange(self.P) % durability.n_workers,
                lambda: (self.store.val, self.store.tid,
                         self.store.indexes if self.has_index else None)))

    # -- dict views kept for callers/tests that read engine state --------
    @property
    def master(self):
        return {"val": self.store.val, "tid": self.store.tid}

    @property
    def replica(self):
        return {"val": self.replica_store.val, "tid": self.replica_store.tid}

    @property
    def snapshot(self):
        return {"val": self.store.snapshot["val"],
                "tid": self.store.snapshot["tid"]}

    # ------------------------------------------------------------------
    @staticmethod
    def _pad_axis(tree, axis: int):
        """Pad a txn pytree to the next power of two along `axis` so epoch
        shapes stay stable across batches (no per-epoch recompilation)."""
        def pad(a):
            n = a.shape[axis]
            target = 1 << max(0, (n - 1).bit_length())
            if target == n:
                return a
            widths = [(0, 0)] * a.ndim
            widths[axis] = (0, target - n)
            return np.pad(a, widths)
        return jax.tree.map(pad, tree)

    def run_epoch(self, batch, ingest=None) -> dict:
        """batch: output of ycsb/tpcc make_batch. Runs partitioned phase,
        fence, single-master phase, fence. Returns epoch metrics.

        ingest: optional zero-arg callable invoked while the partitioned
        phase executes on device (JAX dispatch is async) — the service layer
        hooks host-side batch formation for the *next* epoch here so ingest
        overlaps device execution (double buffering). Its host time is
        reported separately as ``t_ingest_s``."""
        tr = obs.get_tracer()
        t_ep0 = time.perf_counter()
        epoch_u = jnp.uint32(self.epoch)
        ptxn = jax.tree.map(jnp.asarray, self._pad_axis(batch["ptxn"], 1))
        cross = jax.tree.map(jnp.asarray, self._pad_axis(batch["cross"], 0))
        index = self.store.indexes if self.has_index else None

        # ---- partitioned phase (single-partition txns, no CC) ----------
        t0 = time.perf_counter()
        val, tidw, part_out, pstats = self._jit_part(
            self.store.val, self.store.tid, ptxn, epoch_u,
            self.part_seq, index, kernel=self.kernel)
        t_ingest = 0.0
        if ingest is not None:       # overlap host ingest with device exec
            ti = time.perf_counter()
            ingest()
            t_ingest = time.perf_counter() - ti
            tr.complete("service.ingest_overlap", "service", ti,
                        ti + t_ingest, epoch=self.epoch)
        tb = time.perf_counter()
        jax.block_until_ready(val)
        t1 = time.perf_counter()
        tr.complete("engine.partitioned", "phase", t0, t1,
                    epoch=self.epoch)
        # device-attributable time: when host ingest outlasts the device the
        # wall clock measures ingest, not the phase — don't let that deflate
        # the t_p estimate feeding Eq. 1-2 (t_ingest_s reports the overlap)
        t_part = max(t1 - t0 - t_ingest, t1 - tb)
        self.store.val, self.store.tid = val, tidw
        if self.has_index:
            self.store.indexes = part_out["index"]

        # operation replication: publish the epoch's ordered stream as one
        # slab — the replica-replay subscriber applies it, and any other
        # subscriber (materialized views, ...) rides the same publish
        self.changelog.publish_slab(part_out["log"], self.epoch)

        # ---- replication byte accounting, partitioned stream (Fig. 15) --
        # (host-side np on the write mask: the device is already idle here —
        # t_part was measured with block_until_ready above — and fence 1
        # needs the stream bytes to model its network drain; skipped
        # entirely when the batch carries no byte tables)
        vb = 0
        attr = self.changelog.attribute(batch, part_out["log"],
                                        self.has_index,
                                        lambda a: self._pad_axis(a, 1))
        vb_alt, slab_bytes, ib = attr.value_bytes_alt, attr.slab_bytes, \
            attr.index_op_bytes
        ob = attr.total                          # incl. index op bytes now

        # ---- fence 1: all streams applied, snapshot commit --------------
        # §5 overlap: the first n_slabs-1 stream slabs shipped DURING the
        # phase (their transfer hides under t_part); the fence waits only
        # on the unshipped tail slab
        t0 = time.perf_counter()
        ob_head, ob_tail = attr.overlapped, attr.fence
        if self.hybrid:
            t_net1 = self._fence(ob_tail, overlapped_bytes=ob_head,
                                 t_exec_s=t_part)
        else:
            t_net1 = self._fence(vb_alt)
        t_fence1 = time.perf_counter()
        t_f1 = t_fence1 - t0
        tr.complete("engine.fence", "fence", t0, t_fence1, which=1,
                    epoch=self.epoch, tail_bytes=ob_tail if self.hybrid
                    else vb_alt, overlapped_bytes=ob_head)

        # ---- single-master phase (cross-partition txns, Silo OCC) ------
        t0 = time.perf_counter()
        flat_val = self.store.val.reshape(self.P * self.R, self.C)
        flat_tid = self.store.tid.reshape(self.P * self.R)
        B = int(cross["row"].shape[0])
        if B > 0:
            fval, ftid, sm_out, sstats = self._jit_sm(
                flat_val, flat_tid, cross, epoch_u + jnp.uint32(0),
                max_rounds=self.max_rounds,
                index=self.store.indexes if self.has_index else None,
                kernel=self.kernel)
            jax.block_until_ready(fval)
            self.store.val = fval.reshape(self.P, self.R, self.C)
            self.store.tid = ftid.reshape(self.P, self.R)
            if self.has_index:
                self.store.indexes = sm_out["index"]
            # value replication, Thomas write rule (order-free) + the
            # round-ordered index-maintenance stream — published once,
            # applied by every subscriber
            self.changelog.publish_master(
                sm_out["log"],
                kinds=cross["kind"] if self.has_index else None,
                delta=cross["delta"] if self.has_index else None)
        else:
            sstats = {"committed": jnp.int32(0), "retries": jnp.int32(0),
                      "user_aborts": jnp.int32(0), "starved": jnp.int32(0),
                      "writes": jnp.int32(0)}
        t_sm = time.perf_counter() - t0
        # per-round kernel time: the single-master phase is max_rounds
        # identical fused-round launches (one per OCC round)
        t_sm_round = t_sm / self.max_rounds if B > 0 else 0.0
        tr.complete("engine.single_master", "phase", t0, t0 + t_sm,
                    epoch=self.epoch, rounds=self.max_rounds if B else 0)
        if tr.enabled and B > 0:
            # the rounds execute inside ONE jitted call; attribute the
            # measured phase time evenly (the same t_sm_round fig11 reports)
            for r in range(self.max_rounds):
                tr.complete("engine.sm_round", "phase",
                            t0 + r * t_sm_round, t0 + (r + 1) * t_sm_round,
                            epoch=self.epoch, round=r)

        # ---- byte accounting, single-master value stream ----------------
        ib_sm = 0
        if B > 0:
            cw = np.asarray(sm_out["log"]["write"])            # (rounds,B,M)
            if "c_row_bytes" in batch:
                crb = np.broadcast_to(self._pad_axis(batch["c_row_bytes"], 0),
                                      cw.shape[1:])
                vb = int(repl.value_bytes(cw, crb[None]))
            elif batch.get("row_bytes") is not None:
                vb = int(repl.value_bytes(cw, batch["row_bytes"][None, None, :]))
            if self.has_index and (vb or ob):
                # index ops ride the SM stream too — previously uncounted
                # in the fence's modeled bytes (fence-latency attribution)
                ib_sm = repl.index_op_bytes(sm_out["log"]["iwrite"])

        # ---- fence 2: epoch boundary ------------------------------------
        t0 = time.perf_counter()
        t_net2 = self._fence(vb + ib_sm, commit_epoch=self.epoch)
        self.epoch += 1
        t_fence2 = time.perf_counter()
        t_f2 = t_fence2 - t0
        tr.complete("engine.fence", "fence", t0, t_fence2, which=2,
                    epoch=self.epoch - 1, commit=True,
                    value_bytes=vb + ib_sm)

        # ---- controller telemetry ---------------------------------------
        nc = int(sstats["committed"])
        ns = int(pstats["committed"])
        self.controller.observe("partitioned", ns, t_part)
        self.controller.observe("single", nc, t_sm,
                                frac_cross=nc / max(nc + ns, 1))
        tau_p, tau_s = self.controller.plan()

        s = self.stats
        s.epochs += 1
        s.committed_single += ns
        s.committed_cross += nc
        s.user_aborts += int(pstats["user_aborts"]) + int(sstats["user_aborts"])
        s.consume_skips += int(pstats.get("consume_skips", 0)) \
            + int(sstats.get("consume_skips", 0))
        overflow = int(pstats.get("index_overflow", 0)) \
            + int(sstats.get("index_overflow", 0))
        s.index_overflow += overflow
        if self.strict_index and overflow:
            raise RuntimeError(
                f"ordered-index segment overflow: {overflow} live keys "
                f"dropped this epoch (IndexSpec capacity too small)")
        s.retries += int(sstats["retries"])
        s.part_time_s += t_part
        s.sm_time_s += t_sm
        s.sm_rounds += self.max_rounds if B > 0 else 0
        s.fence_time_s += t_f1 + t_f2
        s.value_bytes += vb
        s.op_bytes_hybrid += ob if self.hybrid else vb_alt
        s.value_bytes_if_not_hybrid += vb_alt
        s.index_op_bytes += ib + ib_sm
        if self.hybrid:
            s.op_bytes_overlapped += ob_head
            s.op_bytes_fence += ob_tail
            s.slabs_shipped += len(slab_bytes)
        # per-txn commit outcomes + fence stamps — the service layer maps
        # these back to queued requests (group commit at the epoch fence)
        p_committed = np.asarray(part_out["committed"])          # (P, T_pad)
        c_committed = (np.asarray(sm_out["committed"]) if B > 0
                       else np.zeros(B, bool))                   # (B_pad,)
        m = {"committed_single": ns, "committed_cross": nc,
             "tau_p_ms": tau_p, "tau_s_ms": tau_s,
             "t_part_s": t_part, "t_sm_s": t_sm,
             "t_sm_round_s": t_sm_round,
             "t_ingest_s": t_ingest,
             "t_fence1_s": t_fence1, "t_fence2_s": t_fence2,
             "t_fence_net_s": t_net1 + t_net2,
             "op_bytes_overlapped": ob_head if self.hybrid else 0,
             "op_bytes_fence": ob_tail if self.hybrid else vb_alt,
             "p_committed": p_committed, "c_committed": c_committed,
             "index_overflow": overflow,
             "starved": int(sstats["starved"])}
        if self.has_index:
            # which consume ops were skipped on EXPECT mismatch — the host
            # mirror (tpcc.apply_consume_feedback) re-queues these districts
            m["p_cskip"] = np.asarray(part_out["log"]["cskip"])  # (P,T,K)
            m["c_cskip"] = (np.asarray(sm_out["log"]["cskip"]).any(0)
                            if B > 0 else None)                  # (B_pad,K)
        tr.complete("engine.epoch", "epoch", t_ep0, time.perf_counter(),
                    epoch=self.epoch - 1, committed=ns + nc)
        return m

    # ------------------------------------------------------------------
    def _fence(self, stream_bytes: int = 0, commit_epoch=None,
               overlapped_bytes: int = 0, t_exec_s: float = 0.0) -> float:
        """Replication fence: all outstanding writes applied, then the commit
        point. In-process the streams are applied synchronously above, so the
        fence is the snapshot promotion + epoch bookkeeping; the inter-node
        cost is modeled through the Network envelope and returned (reported
        as ``t_fence_net_s``), not slept.

        ``stream_bytes`` drain entirely inside the fence (the unshipped
        tail); ``overlapped_bytes`` were shipped DURING the preceding
        ``t_exec_s`` of execution (§5 op-stream overlap) and surface at the
        fence only as the residue their transfer did not hide.

        ``commit_epoch`` (fence 2 only) retires the epoch through the
        changelog: the WAL sink appends the committed streams and fsyncs
        every worker's log inside the fence — the disk group commit — and
        the materialized views stamp the fence's aggregate snapshot."""
        self.store.snapshot_commit()
        self.replica_store.snapshot_commit()
        self.stats.fences += 1
        if commit_epoch is not None:
            self.committed_epoch = int(commit_epoch)
            _shipped, dropped = self.changelog.commit(commit_epoch)
            self.stats.ledger_dropped += dropped
        t_net = repl.fence_net_seconds(self.net, stream_bytes,
                                       overlapped_bytes, t_exec_s)
        self.stats.fence_net_s += t_net
        return t_net

    def committed_state(self):
        """The committed full-replica arrays — what a new changelog
        subscriber seeds its projection from."""
        sn = self.store.snapshot
        return sn["val"], sn["tid"]

    def replica_consistent(self) -> bool:
        return self.store.equals(self.replica_store)

    def read_views(self):
        """Committed snapshot views for the read tier's SnapshotCatalog:
        the master copy plus the (single-host) operation replica, both
        covering every partition with the identity row mapping — two
        independently load-balanceable serving copies.  Views reference
        the COMMITTED two-version snapshot, never the working arrays."""
        wm = self.changelog.watermark(self.committed_epoch)
        P = self.P
        cover = np.ones(P, bool)
        rop = np.arange(P, dtype=np.int64)
        views = []
        for rid, kind, store in (("full", "full", self.store),
                                 ("replica", "secondary",
                                  self.replica_store)):
            sn = store.snapshot
            views.append({"id": rid, "kind": kind, "node": 0,
                          "epoch": self.committed_epoch, "watermark": wm,
                          "cover": cover, "row_of_partition": rop,
                          "val": sn["val"], "tid": sn["tid"],
                          "idx": sn["indexes"] if self.has_index else []})
        return views

    # ------------------------------------------------------------------
    # fault tolerance (§4.5)
    # ------------------------------------------------------------------
    def inject_failure(self, failed: set[int], dirty: bool = True):
        """Simulate node failures mid-epoch: optionally scribble uncommitted
        writes into the working version, then run detection + revert."""
        if dirty:
            self.store.val = self.store.val.at[:, 0, 0].add(12345)
            self.store.tid = self.store.tid.at[:, 0].add(jnp.uint32(2))
        plan = make_recovery_plan(self.cluster, failed, self.epoch - 1)
        # revert to last committed epoch (two-version records, §4.5.2 —
        # indexes roll back with the records they point at); in-flight
        # stream slabs are discarded by every subscriber
        self.store.revert_to_snapshot()
        self.replica_store.load_state(self.store.snapshot)
        self.stats.slabs_discarded += self.changelog.revert(self.epoch)
        return plan

    def recover_node(self, plan):
        """Case-1 recovery: copy + Thomas-rule catch-up (here: resync from the
        committed snapshot, which the donor streams guarantee)."""
        self.replica_store.load_state(self.store.snapshot)
        return True
