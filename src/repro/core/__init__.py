from repro.core import analytical, fault, ops, phase_switch, replication, tid
from repro.core.engine import EngineStats, StarEngine
from repro.core.partitioned import run_partitioned
from repro.core.single_master import run_single_master

__all__ = ["analytical", "fault", "ops", "phase_switch", "replication", "tid",
           "EngineStats", "StarEngine", "run_partitioned",
           "run_single_master"]
