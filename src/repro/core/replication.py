"""Replication: value vs operation streams + the Thomas write rule (§3, §5).

* ``thomas_apply`` — out-of-order-safe value replication: apply a write iff
  its TID exceeds the record's current TID.  Duplicates for the same row are
  resolved with a scatter-max on TID first (ties carry identical values, so
  double-apply is idempotent).  This is the replica-side hot loop and has a
  Pallas kernel (repro.kernels.thomas_merge); this jnp version is the
  reference path and oracle.

* ``replay_operations`` — ordered operation replication for the partitioned
  phase (§5): a single writer per partition makes the stream order-correct, so
  replicas re-execute (kind, delta) instead of shipping post-images.

* index replication — ordered-index maintenance (INSERT_IDX/DELETE_IDX/
  SCAN_CONSUME) replays through the SAME ``storage.index.apply_index_ops``
  batches the executors installed: per queue slot for the partitioned
  phase's ordered stream (``replay_partitioned``), per OCC round for the
  single-master stream (``replay_index_rounds``) — so master and replica
  index arrays stay bit-equal and ``replica_consistent()`` covers indexes.

* byte accounting — value bytes use real row sizes, operation bytes the
  operand sizes, reproducing the paper's ~10x TPC-C saving (Fig. 15).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ops import IDX_OPS, apply_op
from repro.storage.index import apply_index_ops

KEY_BYTES = 8
TID_BYTES = 8
# an index-maintenance op ships (key, kind, operand words) on the op stream
INDEX_OP_BYTES = KEY_BYTES + 4 + 8


def thomas_apply(val, tidw, wrows, wvals, wtids):
    """val: (N, C); tidw: (N,); wrows: (K,) int32 (-1 = skip);
    wvals: (K, C); wtids: (K,) uint32.  Returns (val', tidw', applied mask)."""
    N, C = val.shape
    rows = jnp.where(wrows >= 0, wrows, N)
    tid_pad = jnp.concatenate([tidw, jnp.zeros((1,), tidw.dtype)])
    # per-row max incoming TID
    merged = tid_pad.at[rows].max(wtids)
    win = (wtids == merged[rows]) & (wtids > tid_pad[rows]) & (wrows >= 0)
    prows = jnp.where(win, rows, N)
    val_pad = jnp.concatenate([val, jnp.zeros((1, C), val.dtype)])
    val_new = val_pad.at[prows].set(wvals)[:N]
    tid_new = tid_pad.at[prows].set(wtids)[:N]
    return val_new, tid_new, win


def thomas_apply_batch(val, tidw, log):
    """Flatten a phase log {'row','val','tid','write'} into one merge."""
    C = val.shape[1]
    rows = jnp.where(log["write"], log["row"], -1).reshape(-1)
    vals = log["val"].reshape(-1, C)
    tids = log["tid"].reshape(-1)
    return thomas_apply(val, tidw, rows, vals, tids)


def replay_operations(val, tidw, log):
    """Ordered replay for one partition's stream (operation replication).

    log: {'row': (T, M), 'kind': (T, M), 'delta': (T, M, C), 'tid': (T, M),
          'write': (T, M)} — already in commit order (single writer).
    """
    def step(carry, slot):
        val, tidw = carry
        old = val[slot["row"]]                                  # (M, C)
        new = apply_op(slot["kind"], old, slot["delta"])
        w = slot["write"]
        # scatter only write ops (read/padding rows may alias a written row)
        R = val.shape[0]
        rows_w = jnp.where(w, slot["row"], R)
        val = jnp.concatenate([val, jnp.zeros((1, val.shape[1]), val.dtype)]
                              ).at[rows_w].set(new)[:R]
        tidw = jnp.concatenate([tidw, jnp.zeros((1,), tidw.dtype)]
                               ).at[rows_w].set(slot["tid"])[:R]
        return (val, tidw), None

    (val, tidw), _ = jax.lax.scan(step, (val, tidw), log)
    return val, tidw


def replay_partitioned(val, tidw, log, index=None, part_ids=None,
                       kernel: str = "jnp", interpret=None):
    """Ordered replay of the whole partitioned-phase stream, all partitions
    at once (the vectorized form of ``replay_operations``), with optional
    index maintenance.

    val: (P, R, C); tidw: (P, R); log: {'row','kind','delta','tid','write'}
    each (P, T, M, ...) plus 'iwrite' (P, T, K) when index ops were logged.
    index: list of {"key","prow","tid"} (P, cap_i) pytrees.
    part_ids: optional (P,) global partition id per array row (rolled
    secondary-replica layouts pass their home-major permutation).
    kernel: "pallas" replays index maintenance through the fused
    index-merge kernel — the same path the master ran, bit-equal arrays.
    """
    P, T, M = log["row"].shape
    K = min(IDX_OPS, M)

    def step(carry, slot):
        val, tidw, index = carry
        old = jnp.take_along_axis(val, slot["row"][..., None], axis=1)
        new = apply_op(slot["kind"], old, slot["delta"])
        R = val.shape[1]
        rows_w = jnp.where(slot["write"], slot["row"], R)

        def commit(v, t, r, n, nt):
            v = jnp.concatenate([v, jnp.zeros((1, v.shape[1]), v.dtype)])
            t = jnp.concatenate([t, jnp.zeros((1,), t.dtype)])
            return v.at[r].set(n)[:R], t.at[r].set(nt)[:R]

        val, tidw = jax.vmap(commit)(val, tidw, rows_w, new, slot["tid"])
        if index is not None:
            # overflow is identical to the master's (same batches) — the
            # executors already counted it
            index, _ = apply_index_ops(
                index, slot["kind"][:, :K], slot["delta"][:, :K],
                slot["iwrite"], slot["tid"][:, :K], part_ids=part_ids,
                use_pallas=(kernel == "pallas"), interpret=interpret)
        return (val, tidw, index), None

    slots = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), log)   # (T, P, …)
    (val, tidw, index), _ = jax.lax.scan(step, (val, tidw, index), slots)
    return val, tidw, index


def replay_index_rounds(index, kinds, delta, iwrite, tids, part_ids=None,
                        kernel: str = "jnp", interpret=None):
    """Replay the single-master phase's index-maintenance stream.

    Within one OCC round committed index ops hold disjoint position locks,
    so each round's batch commutes internally and rounds are ordered — the
    replica applies the identical per-round ``apply_index_ops`` batches the
    master installed, producing bit-equal index arrays.

    kinds/delta: (B, K≥) static op arrays (same every round);
    iwrite: (rounds, B, K) committed-index-op masks; tids: (rounds, B, M).
    part_ids: optional (P,) global partition id per segment row (partial /
    rolled-secondary replica layouts).
    kernel: "pallas" replays through the fused index-merge kernel.
    """
    K = iwrite.shape[-1]

    def step(index, per_round):
        iw, tid_r = per_round
        return apply_index_ops(index, kinds[:, :K], delta[:, :K], iw,
                               tid_r[:, :K], part_ids=part_ids,
                               use_pallas=(kernel == "pallas"),
                               interpret=interpret)[0], None

    index, _ = jax.lax.scan(step, index, (iwrite, tids))
    return index


# ---------------------------------------------------------------------------
# per-worker WAL streams (durability, §4.5.1/§5)
# ---------------------------------------------------------------------------
def wal_partition_streams(log, R: int, n_workers: int, worker_of_partition):
    """Split one epoch's partitioned-phase log into per-worker WAL streams.

    The op stream is logged in its §5 TRANSFORMED form — the op was applied
    on the primary, the WHOLE post-image ``val`` is logged with its commit
    TID — so recovery can replay any (file, chunk) order under the Thomas
    write rule.  Rows globalize to the flat P*R space (what checkpoints
    store).  Yields ``(worker, rows, vals, tids, mask)`` with non-empty
    masks only.

    log: {'row' (P,T,M), 'val' (P,T,M,C), 'tid' (P,T,M), 'write' (P,T,M)};
    worker_of_partition: (P,) int — e.g. ``p % n_workers`` (single host)
    or ``p // ppn`` (cluster node blocks).
    """
    rows = np.asarray(log["row"])
    P = rows.shape[0]
    grows = rows + np.arange(P, dtype=np.int64)[:, None, None] * R
    vals = np.asarray(log["val"])
    tids = np.asarray(log["tid"])
    wm = np.asarray(log["write"])
    worker_of_partition = np.asarray(worker_of_partition)
    for w in range(n_workers):
        sel = worker_of_partition == w
        if sel.any() and wm[sel].any():
            yield w, grows[sel], vals[sel], tids[sel], wm[sel]


def wal_master_streams(log, R: int, C: int, n_workers: int,
                       worker_of_partition):
    """Split the single-master phase's value stream (already whole-record
    post-images on global rows) to each owner's WAL.  Yields
    ``(worker, rows, vals, tids, mask)`` with non-empty masks only."""
    rows = np.asarray(log["row"]).reshape(-1)
    vals = np.asarray(log["val"]).reshape(-1, C)
    tids = np.asarray(log["tid"]).reshape(-1)
    wm = np.asarray(log["write"]).reshape(-1)
    owner = np.asarray(worker_of_partition)[rows // R]
    for w in range(n_workers):
        m = wm & (owner == w)
        if m.any():
            yield w, rows, vals, tids, m


def wal_index_streams(plog, n_workers: int, worker_of_partition,
                      cross_kinds=None, cross_delta=None, slog=None):
    """Split one epoch's index-maintenance op streams into per-worker WAL
    chunks.  Unlike record post-images (Thomas-merged, order-free), index
    ops replay ORDERED — each op carries a ``step`` id (partitioned queue
    slot t, then single-master round T+r) and recovery re-applies each
    file's chunks step-group by step-group in file order.  A partition's
    ops all land in its owner's file (partitioned ops by construction;
    single-master ops split by the op key's partition), so cross-file
    chunks touch disjoint segments and commute.

    plog: partitioned log with 'kind' (P,T,M), 'delta' (P,T,M,C),
    'iwrite' (P,T,K), 'tid' (P,T,M).  cross_kinds/cross_delta: the
    single-master batch's (B, M)/(B, M, C) op arrays with slog the SM log
    ('iwrite' (rounds,B,K), 'tid' (rounds,B,M)).

    Yields ``(worker, step, kinds, delta, tids)`` flat committed-op arrays
    in step-ascending order, non-empty only.
    """
    from repro.storage.index import PART_SHIFT
    from repro.core.ops import IX_KEY
    worker_of_partition = np.asarray(worker_of_partition)
    T = 0
    per_worker = {w: [] for w in range(n_workers)}
    if plog is not None and "iwrite" in plog:
        iw = np.asarray(plog["iwrite"])                         # (P, T, K)
        P, T, K = iw.shape
        kinds = np.asarray(plog["kind"])[:, :, :K]
        delta = np.asarray(plog["delta"])[:, :, :K]
        tids = np.asarray(plog["tid"])[:, :, :K]
        steps = np.broadcast_to(np.arange(T, dtype=np.int32)[None, :, None],
                                iw.shape)
        for w in range(n_workers):
            sel = worker_of_partition == w
            m = iw[sel]
            if not m.any():
                continue
            # (n_p, T, K) -> (T, n_p, K) so the flat stream is step-major
            order = (1, 0, 2)
            m_t = m.transpose(order).reshape(-1)
            per_worker[w].append((
                steps[sel].transpose(order).reshape(-1)[m_t],
                kinds[sel].transpose(order).reshape(-1)[m_t],
                delta[sel].transpose(1, 0, 2, 3).reshape(
                    -1, delta.shape[-1])[m_t],
                tids[sel].transpose(order).reshape(-1)[m_t]))
    if slog is not None and "iwrite" in slog:
        iw = np.asarray(slog["iwrite"])                         # (r, B, K)
        rounds, B, K = iw.shape
        kinds = np.broadcast_to(np.asarray(cross_kinds)[None, :, :K],
                                iw.shape)
        cross_delta = np.asarray(cross_delta)
        delta = np.broadcast_to(cross_delta[None, :, :K],
                                iw.shape + (cross_delta.shape[-1],))
        tids = np.asarray(slog["tid"])[:, :, :K]
        steps = np.broadcast_to(
            T + np.arange(rounds, dtype=np.int32)[:, None, None], iw.shape)
        part = (delta[..., IX_KEY].astype(np.int64) >> PART_SHIFT)
        owner = worker_of_partition[np.clip(part, 0,
                                            len(worker_of_partition) - 1)]
        flat = iw.reshape(-1)
        for w in range(n_workers):
            m = flat & (owner.reshape(-1) == w)
            if not m.any():
                continue
            per_worker[w].append((
                steps.reshape(-1)[m], kinds.reshape(-1)[m],
                delta.reshape(-1, delta.shape[-1])[m],
                tids.reshape(-1)[m]))
    for w, chunks in per_worker.items():
        if chunks:
            yield (w,
                   np.concatenate([c[0] for c in chunks]),
                   np.concatenate([c[1] for c in chunks]),
                   np.concatenate([c[2] for c in chunks]),
                   np.concatenate([c[3] for c in chunks]))


# ---------------------------------------------------------------------------
# bandwidth accounting (Fig. 15)
# ---------------------------------------------------------------------------
def value_bytes(log_write_mask, row_bytes_per_op) -> jnp.ndarray:
    """Value replication ships the full row (+key+tid) per committed write."""
    return jnp.sum(jnp.where(log_write_mask,
                             row_bytes_per_op + KEY_BYTES + TID_BYTES, 0))


def operation_bytes(log_write_mask, op_bytes_per_op) -> jnp.ndarray:
    """Operation replication ships only (key, kind, operand)."""
    return jnp.sum(jnp.where(log_write_mask,
                             op_bytes_per_op + KEY_BYTES + 4, 0))


def index_op_bytes(iwrite_mask) -> int:
    """Index-maintenance ops ride the SAME op stream as record ops — their
    bytes are fence-relevant too (they were silently uncounted before)."""
    return int(np.sum(np.asarray(iwrite_mask), dtype=np.int64)) \
        * INDEX_OP_BYTES


def slab_op_bytes(wmask, op_tbl, iwrite, n_slabs: int) -> list[int]:
    """Per-slab op-stream bytes: the epoch's T queue slots split into
    ``n_slabs`` contiguous chunks (record ops + index ops per chunk),
    using the same ``T * s // S`` bounds the cluster engine executes its
    stream slabs with.  The sum over slabs is exactly the epoch's total
    op-stream bytes — the invariant the byte-attribution regression test
    pins.  Shared by both engines so the byte model cannot desynchronize
    between fig13 (cluster) and fig15 (single-host)."""
    T = wmask.shape[1]
    S = max(1, min(n_slabs, T))
    bounds = [T * s // S for s in range(S + 1)]
    out = []
    for s in range(S):
        sl = slice(bounds[s], bounds[s + 1])
        b = int(operation_bytes(wmask[:, sl], op_tbl[:, sl]))
        if iwrite is not None:
            b += index_op_bytes(iwrite[:, sl])
        out.append(b)
    return out


def fence_net_seconds(net, fence_bytes: int, overlapped_bytes: int = 0,
                      t_exec_s: float = 0.0) -> float:
    """The modeled inter-node fence cost, shared by both engines:
    ``fence_bytes`` (the unshipped tail) drain entirely inside the fence
    plus two barrier round trips; ``overlapped_bytes`` shipped DURING the
    preceding ``t_exec_s`` of execution and surface only as the residue
    their transfer did not hide."""
    return net.transfer_s(fence_bytes) + 2 * net.rtt_s \
        + max(0.0, net.transfer_s(overlapped_bytes) - t_exec_s)


def epoch_stream_bytes(batch, log, has_index: bool, n_slabs: int,
                       pad_fn) -> tuple[int, list[int], int]:
    """One epoch's partitioned-stream byte accounting, shared by both
    engines so their fence models cannot desynchronize.

    batch carries either per-op tables (``p_row_bytes``/``p_op_bytes``,
    padded to the log's T via ``pad_fn``) or uniform per-op-slot tables
    (``row_bytes``/``op_bytes``); log is the phase's (P, T, M) write log
    (with ``iwrite`` when indexes are attached).  Returns
    ``(value_bytes_alt, per_slab_op_bytes, index_op_bytes)`` — all zeros /
    empty when the batch carries no byte tables."""
    has_tables = "p_row_bytes" in batch \
        or batch.get("row_bytes") is not None
    if not has_tables:
        return 0, [], 0
    wmask = np.asarray(log["write"])
    iw = np.asarray(log["iwrite"]) if has_index else None
    if "p_row_bytes" in batch:
        prb = np.asarray(pad_fn(batch["p_row_bytes"]))
        pob = np.asarray(pad_fn(batch["p_op_bytes"]))
    else:
        prb = np.broadcast_to(
            np.asarray(batch["row_bytes"])[None, None, :], wmask.shape)
        pob = np.broadcast_to(
            np.asarray(batch["op_bytes"])[None, None, :], wmask.shape)
    vb_alt = int(value_bytes(wmask, prb))
    slabs = slab_op_bytes(wmask, pob, iw, n_slabs)
    ib = index_op_bytes(iw) if iw is not None else 0
    return vb_alt, slabs, ib


def split_overlapped(slab_bytes: list[int]) -> tuple[int, int]:
    """Split a per-slab byte list into (overlapped, fence_exposed).

    The fence-exposed tail is the LAST slab that carried committed bytes —
    it ships closest to the fence, so charging it there is the
    conservative attribution (trailing queue slots are often padding, and
    crediting an empty final slab would claim a 100% hide)."""
    if not slab_bytes:
        return 0, 0
    tail_i = max((i for i, b in enumerate(slab_bytes) if b > 0),
                 default=len(slab_bytes) - 1)
    tail = slab_bytes[tail_i]
    return sum(slab_bytes) - tail, tail


def snapshot_watermark(committed_epoch: int, slab_ledger) -> tuple[int, int]:
    """Per-replica applied watermark for the read tier's snapshot catalog:
    (last-applied fence epoch, stream slabs of that epoch the replicas had
    consumed when it committed).  A committed snapshot's watermark always
    covers its whole epoch — the fence waited on the unshipped tail — so
    the slab count is telemetry (how much of the commit the in-phase
    stream hid), while the epoch is the freshness authority."""
    slabs = sum(1 for (e, _s) in slab_ledger if e == committed_epoch)
    return int(committed_epoch), slabs
