"""Replication: value vs operation streams + the Thomas write rule (§3, §5).

* ``thomas_apply`` — out-of-order-safe value replication: apply a write iff
  its TID exceeds the record's current TID.  Duplicates for the same row are
  resolved with a scatter-max on TID first (ties carry identical values, so
  double-apply is idempotent).  This is the replica-side hot loop and has a
  Pallas kernel (repro.kernels.thomas_merge); this jnp version is the
  reference path and oracle.

* ``replay_operations`` — ordered operation replication for the partitioned
  phase (§5): a single writer per partition makes the stream order-correct, so
  replicas re-execute (kind, delta) instead of shipping post-images.

* byte accounting — value bytes use real row sizes, operation bytes the
  operand sizes, reproducing the paper's ~10x TPC-C saving (Fig. 15).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ops import apply_op

KEY_BYTES = 8
TID_BYTES = 8


def thomas_apply(val, tidw, wrows, wvals, wtids):
    """val: (N, C); tidw: (N,); wrows: (K,) int32 (-1 = skip);
    wvals: (K, C); wtids: (K,) uint32.  Returns (val', tidw', applied mask)."""
    N, C = val.shape
    rows = jnp.where(wrows >= 0, wrows, N)
    tid_pad = jnp.concatenate([tidw, jnp.zeros((1,), tidw.dtype)])
    # per-row max incoming TID
    merged = tid_pad.at[rows].max(wtids)
    win = (wtids == merged[rows]) & (wtids > tid_pad[rows]) & (wrows >= 0)
    prows = jnp.where(win, rows, N)
    val_pad = jnp.concatenate([val, jnp.zeros((1, C), val.dtype)])
    val_new = val_pad.at[prows].set(wvals)[:N]
    tid_new = tid_pad.at[prows].set(wtids)[:N]
    return val_new, tid_new, win


def thomas_apply_batch(val, tidw, log):
    """Flatten a phase log {'row','val','tid','write'} into one merge."""
    C = val.shape[1]
    rows = jnp.where(log["write"], log["row"], -1).reshape(-1)
    vals = log["val"].reshape(-1, C)
    tids = log["tid"].reshape(-1)
    return thomas_apply(val, tidw, rows, vals, tids)


def replay_operations(val, tidw, log):
    """Ordered replay for one partition's stream (operation replication).

    log: {'row': (T, M), 'kind': (T, M), 'delta': (T, M, C), 'tid': (T, M),
          'write': (T, M)} — already in commit order (single writer).
    """
    def step(carry, slot):
        val, tidw = carry
        old = val[slot["row"]]                                  # (M, C)
        new = apply_op(slot["kind"], old, slot["delta"])
        w = slot["write"]
        # scatter only write ops (read/padding rows may alias a written row)
        R = val.shape[0]
        rows_w = jnp.where(w, slot["row"], R)
        val = jnp.concatenate([val, jnp.zeros((1, val.shape[1]), val.dtype)]
                              ).at[rows_w].set(new)[:R]
        tidw = jnp.concatenate([tidw, jnp.zeros((1,), tidw.dtype)]
                               ).at[rows_w].set(slot["tid"])[:R]
        return (val, tidw), None

    (val, tidw), _ = jax.lax.scan(step, (val, tidw), log)
    return val, tidw


# ---------------------------------------------------------------------------
# bandwidth accounting (Fig. 15)
# ---------------------------------------------------------------------------
def value_bytes(log_write_mask, row_bytes_per_op) -> jnp.ndarray:
    """Value replication ships the full row (+key+tid) per committed write."""
    return jnp.sum(jnp.where(log_write_mask,
                             row_bytes_per_op + KEY_BYTES + TID_BYTES, 0))


def operation_bytes(log_write_mask, op_bytes_per_op) -> jnp.ndarray:
    """Operation replication ships only (key, kind, operand)."""
    return jnp.sum(jnp.where(log_write_mask,
                             op_bytes_per_op + KEY_BYTES + 4, 0))
