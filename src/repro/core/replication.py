"""Replication: value vs operation streams + the Thomas write rule (§3, §5).

* ``thomas_apply`` — out-of-order-safe value replication: apply a write iff
  its TID exceeds the record's current TID.  Duplicates for the same row are
  resolved with a scatter-max on TID first (ties carry identical values, so
  double-apply is idempotent).  This is the replica-side hot loop and has a
  Pallas kernel (repro.kernels.thomas_merge); this jnp version is the
  reference path and oracle.

* ``replay_operations`` — ordered operation replication for the partitioned
  phase (§5): a single writer per partition makes the stream order-correct, so
  replicas re-execute (kind, delta) instead of shipping post-images.

* index replication — ordered-index maintenance (INSERT_IDX/DELETE_IDX/
  SCAN_CONSUME) replays through the SAME ``storage.index.apply_index_ops``
  batches the executors installed: per queue slot for the partitioned
  phase's ordered stream (``replay_partitioned``), per OCC round for the
  single-master stream (``replay_index_rounds``) — so master and replica
  index arrays stay bit-equal and ``replica_consistent()`` covers indexes.

* byte accounting — value bytes use real row sizes, operation bytes the
  operand sizes, reproducing the paper's ~10x TPC-C saving (Fig. 15).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ops import IDX_OPS, apply_op
from repro.storage.index import apply_index_ops

KEY_BYTES = 8
TID_BYTES = 8


def thomas_apply(val, tidw, wrows, wvals, wtids):
    """val: (N, C); tidw: (N,); wrows: (K,) int32 (-1 = skip);
    wvals: (K, C); wtids: (K,) uint32.  Returns (val', tidw', applied mask)."""
    N, C = val.shape
    rows = jnp.where(wrows >= 0, wrows, N)
    tid_pad = jnp.concatenate([tidw, jnp.zeros((1,), tidw.dtype)])
    # per-row max incoming TID
    merged = tid_pad.at[rows].max(wtids)
    win = (wtids == merged[rows]) & (wtids > tid_pad[rows]) & (wrows >= 0)
    prows = jnp.where(win, rows, N)
    val_pad = jnp.concatenate([val, jnp.zeros((1, C), val.dtype)])
    val_new = val_pad.at[prows].set(wvals)[:N]
    tid_new = tid_pad.at[prows].set(wtids)[:N]
    return val_new, tid_new, win


def thomas_apply_batch(val, tidw, log):
    """Flatten a phase log {'row','val','tid','write'} into one merge."""
    C = val.shape[1]
    rows = jnp.where(log["write"], log["row"], -1).reshape(-1)
    vals = log["val"].reshape(-1, C)
    tids = log["tid"].reshape(-1)
    return thomas_apply(val, tidw, rows, vals, tids)


def replay_operations(val, tidw, log):
    """Ordered replay for one partition's stream (operation replication).

    log: {'row': (T, M), 'kind': (T, M), 'delta': (T, M, C), 'tid': (T, M),
          'write': (T, M)} — already in commit order (single writer).
    """
    def step(carry, slot):
        val, tidw = carry
        old = val[slot["row"]]                                  # (M, C)
        new = apply_op(slot["kind"], old, slot["delta"])
        w = slot["write"]
        # scatter only write ops (read/padding rows may alias a written row)
        R = val.shape[0]
        rows_w = jnp.where(w, slot["row"], R)
        val = jnp.concatenate([val, jnp.zeros((1, val.shape[1]), val.dtype)]
                              ).at[rows_w].set(new)[:R]
        tidw = jnp.concatenate([tidw, jnp.zeros((1,), tidw.dtype)]
                               ).at[rows_w].set(slot["tid"])[:R]
        return (val, tidw), None

    (val, tidw), _ = jax.lax.scan(step, (val, tidw), log)
    return val, tidw


def replay_partitioned(val, tidw, log, index=None):
    """Ordered replay of the whole partitioned-phase stream, all partitions
    at once (the vectorized form of ``replay_operations``), with optional
    index maintenance.

    val: (P, R, C); tidw: (P, R); log: {'row','kind','delta','tid','write'}
    each (P, T, M, ...) plus 'iwrite' (P, T, K) when index ops were logged.
    index: list of {"key","prow","tid"} (P, cap_i) pytrees.
    """
    P, T, M = log["row"].shape
    K = min(IDX_OPS, M)

    def step(carry, slot):
        val, tidw, index = carry
        old = jnp.take_along_axis(val, slot["row"][..., None], axis=1)
        new = apply_op(slot["kind"], old, slot["delta"])
        R = val.shape[1]
        rows_w = jnp.where(slot["write"], slot["row"], R)

        def commit(v, t, r, n, nt):
            v = jnp.concatenate([v, jnp.zeros((1, v.shape[1]), v.dtype)])
            t = jnp.concatenate([t, jnp.zeros((1,), t.dtype)])
            return v.at[r].set(n)[:R], t.at[r].set(nt)[:R]

        val, tidw = jax.vmap(commit)(val, tidw, rows_w, new, slot["tid"])
        if index is not None:
            # overflow is identical to the master's (same batches) — the
            # executors already counted it
            index, _ = apply_index_ops(
                index, slot["kind"][:, :K], slot["delta"][:, :K],
                slot["iwrite"], slot["tid"][:, :K])
        return (val, tidw, index), None

    slots = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), log)   # (T, P, …)
    (val, tidw, index), _ = jax.lax.scan(step, (val, tidw, index), slots)
    return val, tidw, index


def replay_index_rounds(index, kinds, delta, iwrite, tids):
    """Replay the single-master phase's index-maintenance stream.

    Within one OCC round committed index ops hold disjoint position locks,
    so each round's batch commutes internally and rounds are ordered — the
    replica applies the identical per-round ``apply_index_ops`` batches the
    master installed, producing bit-equal index arrays.

    kinds/delta: (B, K≥) static op arrays (same every round);
    iwrite: (rounds, B, K) committed-index-op masks; tids: (rounds, B, M).
    """
    K = iwrite.shape[-1]

    def step(index, per_round):
        iw, tid_r = per_round
        return apply_index_ops(index, kinds[:, :K], delta[:, :K], iw,
                               tid_r[:, :K])[0], None

    index, _ = jax.lax.scan(step, index, (iwrite, tids))
    return index


# ---------------------------------------------------------------------------
# per-worker WAL streams (durability, §4.5.1/§5)
# ---------------------------------------------------------------------------
def wal_partition_streams(log, R: int, n_workers: int, worker_of_partition):
    """Split one epoch's partitioned-phase log into per-worker WAL streams.

    The op stream is logged in its §5 TRANSFORMED form — the op was applied
    on the primary, the WHOLE post-image ``val`` is logged with its commit
    TID — so recovery can replay any (file, chunk) order under the Thomas
    write rule.  Rows globalize to the flat P*R space (what checkpoints
    store).  Yields ``(worker, rows, vals, tids, mask)`` with non-empty
    masks only.

    log: {'row' (P,T,M), 'val' (P,T,M,C), 'tid' (P,T,M), 'write' (P,T,M)};
    worker_of_partition: (P,) int — e.g. ``p % n_workers`` (single host)
    or ``p // ppn`` (cluster node blocks).
    """
    rows = np.asarray(log["row"])
    P = rows.shape[0]
    grows = rows + np.arange(P, dtype=np.int64)[:, None, None] * R
    vals = np.asarray(log["val"])
    tids = np.asarray(log["tid"])
    wm = np.asarray(log["write"])
    worker_of_partition = np.asarray(worker_of_partition)
    for w in range(n_workers):
        sel = worker_of_partition == w
        if sel.any() and wm[sel].any():
            yield w, grows[sel], vals[sel], tids[sel], wm[sel]


def wal_master_streams(log, R: int, C: int, n_workers: int,
                       worker_of_partition):
    """Split the single-master phase's value stream (already whole-record
    post-images on global rows) to each owner's WAL.  Yields
    ``(worker, rows, vals, tids, mask)`` with non-empty masks only."""
    rows = np.asarray(log["row"]).reshape(-1)
    vals = np.asarray(log["val"]).reshape(-1, C)
    tids = np.asarray(log["tid"]).reshape(-1)
    wm = np.asarray(log["write"]).reshape(-1)
    owner = np.asarray(worker_of_partition)[rows // R]
    for w in range(n_workers):
        m = wm & (owner == w)
        if m.any():
            yield w, rows, vals, tids, m


# ---------------------------------------------------------------------------
# bandwidth accounting (Fig. 15)
# ---------------------------------------------------------------------------
def value_bytes(log_write_mask, row_bytes_per_op) -> jnp.ndarray:
    """Value replication ships the full row (+key+tid) per committed write."""
    return jnp.sum(jnp.where(log_write_mask,
                             row_bytes_per_op + KEY_BYTES + TID_BYTES, 0))


def operation_bytes(log_write_mask, op_bytes_per_op) -> jnp.ndarray:
    """Operation replication ships only (key, kind, operand)."""
    return jnp.sum(jnp.where(log_write_mask,
                             op_bytes_per_op + KEY_BYTES + 4, 0))
