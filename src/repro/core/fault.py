"""Fault tolerance (§4.5): failure classification, revert, recovery plans.

Cluster: f nodes with full replicas, k nodes with partial replicas; the k
partial nodes collectively hold ``replicas_per_partition`` copies of each
partition (paper experiments use 2 total copies: primary + secondary hashed
to different nodes).

The coordinator (deployable as a Paxos/Raft replicated state machine — we
model it as the view service) detects failures at the replication fence,
broadcasts the failed set, reverts to the last committed epoch (two-version
records, db.revert_to_snapshot) and selects one of the paper's four recovery
cases (§4.5.3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum


class RecoveryCase(Enum):
    PHASE_SWITCHING = 1          # ≥1 full replica AND ≥1 complete partial set
    FALLBACK_DIST_CC = 2         # no full replica, ≥1 complete partial set
    FULL_ONLY = 3                # ≥1 full replica, no complete partial set
    UNAVAILABLE = 4              # neither — reload from disk checkpoint + logs


@dataclass(frozen=True)
class ClusterConfig:
    """Replica placement.  Two layouts:

    * disjoint (default, ``ppn=None``): nodes 0..f-1 hold ONLY full
      replicas; nodes f..f+k-1 hold the partial replicas, partitions
      hashed across them — ``n_nodes = f + k``;
    * co-located contiguous (``ppn`` set — the paper's deployment and the
      cluster runtime's device mesh): every node holds a contiguous block
      of ``ppn`` primary partitions (node = partition // ppn, matching
      shard_map's contiguous sharding), nodes 0..f-1 ADDITIONALLY hold
      full replicas, and each partition's secondary partial copies land on
      the next nodes round-robin — ``n_nodes = k``.
    """
    f: int                        # nodes with full replicas
    k: int                        # nodes with partial replicas
    n_partitions: int
    replicas_per_partition: int = 2
    ppn: int | None = None        # partitions per node (co-located layout)

    def __post_init__(self):
        if self.ppn is not None:
            assert self.k * self.ppn == self.n_partitions, \
                (self.k, self.ppn, self.n_partitions)
            assert 0 < self.f <= self.k

    @property
    def n_nodes(self):
        return self.k if self.ppn is not None else self.f + self.k

    def primary_of(self, partition: int) -> int:
        """The node that masters ``partition`` in the partitioned phase."""
        if self.ppn is not None:
            return partition // self.ppn
        return self.f + partition % self.k

    def partition_homes(self, partition: int) -> list[int]:
        """Primary + secondaries for a partition among the k partial nodes
        (hashed so primary and secondary land on different nodes, §7.1.3;
        contiguous-block primary + round-robin secondaries when
        co-located)."""
        if self.ppn is not None:
            first = partition // self.ppn
            return [(first + r) % self.k
                    for r in range(min(self.replicas_per_partition, self.k))]
        homes = []
        for r in range(self.replicas_per_partition):
            homes.append(self.f + (partition + r) % self.k)
        return homes


def classify_failure(cfg: ClusterConfig, failed: set[int]) -> RecoveryCase:
    full_alive = any(n not in failed for n in range(cfg.f))
    # a complete partial set exists iff every partition has a live partial home
    complete_partial = all(
        any(h not in failed for h in cfg.partition_homes(p))
        for p in range(cfg.n_partitions))
    if full_alive and complete_partial:
        return RecoveryCase.PHASE_SWITCHING
    if complete_partial:
        return RecoveryCase.FALLBACK_DIST_CC
    if full_alive:
        return RecoveryCase.FULL_ONLY
    return RecoveryCase.UNAVAILABLE


@dataclass
class RecoveryPlan:
    case: RecoveryCase
    revert_to_epoch: int
    remaster: dict                # partition -> new master node
    copy_sources: dict            # recovering node -> source node
    run_mode: str                 # "star" | "dist_cc" | "single_node" | "halt"


def make_recovery_plan(cfg: ClusterConfig, failed: set[int],
                       committed_epoch: int) -> RecoveryPlan:
    case = classify_failure(cfg, failed)
    remaster: dict = {}
    copy_sources: dict = {}
    full_alive = [n for n in range(cfg.f) if n not in failed]
    for p in range(cfg.n_partitions):
        homes = [h for h in cfg.partition_homes(p) if h not in failed]
        if homes:
            remaster[p] = homes[0]
        elif full_alive:
            remaster[p] = full_alive[0]     # case 3: re-master onto full replica
    for n in sorted(failed):
        donors = [m for m in range(cfg.n_nodes) if m not in failed]
        if donors:
            copy_sources[n] = full_alive[0] if full_alive else donors[0]
    run_mode = {
        RecoveryCase.PHASE_SWITCHING: "star",
        RecoveryCase.FALLBACK_DIST_CC: "dist_cc",
        RecoveryCase.FULL_ONLY: "star" if any(
            h not in failed for p in range(cfg.n_partitions)
            for h in cfg.partition_homes(p)) else "single_node",
        RecoveryCase.UNAVAILABLE: "halt",
    }[case]
    return RecoveryPlan(case=case, revert_to_epoch=committed_epoch,
                        remaster=remaster, copy_sources=copy_sources,
                        run_mode=run_mode)


def catch_up(val, tidw, donor_log, thomas_apply):
    """A recovering node copies remote data and applies live updates with the
    Thomas write rule in parallel (§4.5.3 case 1)."""
    return thomas_apply(val, tidw, donor_log["row"], donor_log["val"],
                        donor_log["tid"])


# ---------------------------------------------------------------------------
# live failure injection
# ---------------------------------------------------------------------------
@dataclass
class FaultInjector:
    """Schedules node kills at chosen epochs for the cluster runtime.

    The coordinator polls the injector at every replication fence (a
    killed node's fence message never arrives — the §4.5 missed-heartbeat
    detection); a kill takes effect DURING the scheduled epoch, so that
    epoch's work is never committed: the coordinator reverts to the last
    committed epoch and runs the classified recovery.  ``killed`` tracks
    nodes currently down; recovery revives them once their state is
    restored from a donor or from disk (case-1 copy + catch-up, §4.5.3).

    ``schedule_kill(..., slab=s)`` kills the node MID-STREAM: while the
    scheduled epoch's partitioned phase executes stream slab ``s`` —
    slabs ``0..s-1`` have already shipped to the replicas, so the epoch
    aborts with that prefix of its op stream consumed, exercising the
    §4.5 revert's slab high-watermark (exactly-once re-streaming).
    ``slab=0`` kills before anything shipped (nothing to discard).
    """
    schedule: dict = field(default_factory=dict)    # epoch -> set[node]
    slab_schedule: dict = field(default_factory=dict)  # epoch -> {slab: set}
    killed: set = field(default_factory=set)
    kills_injected: int = 0

    def schedule_kill(self, node: int, epoch: int, slab: int | None = None):
        if slab is None:
            self.schedule.setdefault(int(epoch), set()).add(int(node))
        else:
            self.slab_schedule.setdefault(int(epoch), {}).setdefault(
                int(slab), set()).add(int(node))

    def slab_kills(self, epoch: int) -> dict:
        """Peek the mid-stream kills of ``epoch`` ({slab: nodes}) without
        consuming them — the runtime arms its abort check from this before
        polling the fence."""
        return {s: set(ns)
                for s, ns in self.slab_schedule.get(int(epoch), {}).items()}

    def poll(self, epoch: int) -> set[int]:
        """Nodes newly killed during ``epoch`` (mid-stream kills included —
        by fence time they are just as dead); they join ``killed``."""
        fresh = set(self.schedule.pop(int(epoch), set()))
        for nodes in self.slab_schedule.pop(int(epoch), {}).values():
            fresh |= set(nodes)
        fresh -= self.killed
        self.killed |= fresh
        self.kills_injected += len(fresh)
        return fresh

    def revive(self, nodes):
        for n in nodes:
            self.killed.discard(int(n))
