"""Transaction router (§4.3): classification + re-routing + admission.

"For ease of presentation, we assume that all cross-partition transaction
requests go to the designated master node ... This could be implemented via
router nodes that are aware of the partitioning of the database. If some
transaction accesses multiple partitions on a non-master node, the system
would re-route the request to the master node for later execution."

The router ingests raw (parts, rows, kinds, deltas) transaction arrays,
classifies single- vs cross-partition by inspecting the op partition sets,
routes singles to their home partition queues (the partitioned phase input)
and defers cross txns to the master queue (the single-master phase input).
Mis-declared transactions (claimed single but touching remote partitions)
are detected and re-routed — the paper's re-route case.

Everything is vectorized (argsort + cumulative-count scatter, no per-txn
Python loop): the online admission controller classifies each arrival chunk
through `Router.classify` at wire rate, while `scatter_singles` backs the
offline `route()` path (the epoch batcher drains already-classified
admission queues with its own fixed-shape gather).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class RouterStats:
    singles: int = 0
    cross: int = 0
    rerouted: int = 0
    deferred_epochs: int = 0


def globalize_rows(parts: np.ndarray, rows: np.ndarray, R: int) -> np.ndarray:
    """Partition-local (part, row) -> master's flat global row id."""
    return (parts.astype(np.int64) * R + rows).astype(np.int32)


def scatter_singles(P: int, T: int, M: int, C: int, home: np.ndarray,
                    rows: np.ndarray, kinds: np.ndarray, deltas: np.ndarray,
                    user_abort: np.ndarray):
    """Vectorized (P, T, …) queue formation for single-partition txns.

    home: (n,) home partition per txn; rows/kinds: (n, M); deltas: (n, M, C).
    Returns (ptxn, placed_idx, slot_of, overflow_idx): `placed_idx[k]` is the
    input index landed at (home[placed_idx[k]], slot_of[k]); txns beyond the
    per-partition capacity T overflow in FIFO order (back-pressure).
    """
    n = home.shape[0]
    ptxn = {
        "valid": np.zeros((P, T), bool),
        "row": np.zeros((P, T, M), np.int32),
        "kind": np.zeros((P, T, M), np.int32),
        "delta": np.zeros((P, T, M, C), np.int32),
        "user_abort": np.zeros((P, T), bool),
    }
    if n == 0:
        return ptxn, np.zeros(0, np.int64), np.zeros(0, np.int64), \
            np.zeros(0, np.int64)
    order = np.argsort(home, kind="stable")          # FIFO within partition
    hs = home[order]
    counts = np.bincount(hs, minlength=P)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot = np.arange(n, dtype=np.int64) - starts[hs]
    fit = slot < T
    idx, ps, ss = order[fit], hs[fit], slot[fit]
    ptxn["valid"][ps, ss] = True
    ptxn["row"][ps, ss] = rows[idx]
    ptxn["kind"][ps, ss] = kinds[idx]
    ptxn["delta"][ps, ss] = deltas[idx]
    ptxn["user_abort"][ps, ss] = user_abort[idx]
    return ptxn, idx, ss, order[~fit]


class Router:
    def __init__(self, n_partitions: int, rows_per_partition: int,
                 max_ops: int, n_cols: int = 10):
        self.P = n_partitions
        self.R = rows_per_partition
        self.M = max_ops
        self.C = n_cols
        self.stats = RouterStats()

    def classify(self, parts: np.ndarray, kinds: np.ndarray,
                 declared_home: np.ndarray):
        """parts: (B, M) op partition ids; kinds: (B, M) (0 = READ/pad).

        Returns (is_cross (B,), home (B,)). A txn is cross iff its live ops
        span >1 partition; any txn *declared* single-partition
        (declared_home >= 0) whose ops actually span more is the paper's
        mis-routed case — it must be re-routed to the master queue and is
        counted in ``stats.rerouted``."""
        live = kinds >= 0
        # ops beyond n_ops are padded with part == home, so span test is exact
        span_min = np.where(live, parts, parts.max(initial=0, axis=None)).min(axis=1)
        span_max = np.where(live, parts, 0).max(axis=1)
        is_cross = span_min != span_max
        rerouted = int(np.sum(is_cross & (declared_home >= 0)))
        self.stats.rerouted += rerouted
        self.stats.singles += int(np.sum(~is_cross))
        self.stats.cross += int(np.sum(is_cross))
        return is_cross, np.where(is_cross, -1, span_max)

    def route(self, parts, rows, kinds, deltas, user_abort=None,
              declared_home=None, T: int | None = None):
        """Build the two phase queues from raw txn arrays (B, M, ...).

        T caps the per-partition queue depth (None = fit everything);
        overflowing singles are deferred to the next epoch and counted in
        ``stats.deferred_epochs``."""
        B = parts.shape[0]
        if user_abort is None:
            user_abort = np.zeros(B, bool)
        if declared_home is None:
            declared_home = np.full(B, -1)
        is_cross, home = self.classify(parts, kinds, declared_home)

        single_idx = np.nonzero(~is_cross)[0]
        n_per_part = np.bincount(home[single_idx], minlength=self.P) \
            if single_idx.size else np.zeros(self.P, np.int64)
        if T is None:
            T = max(1, int(n_per_part.max(initial=0)))
        ptxn, placed, _, overflow = scatter_singles(
            self.P, T, self.M, self.C, home[single_idx], rows[single_idx],
            kinds[single_idx], deltas[single_idx], user_abort[single_idx])
        self.stats.deferred_epochs += int(overflow.size)

        cidx = np.nonzero(is_cross)[0]
        cross = {
            "valid": np.ones(len(cidx), bool),
            "row": globalize_rows(parts[cidx], rows[cidx], self.R),
            "kind": kinds[cidx],
            "delta": deltas[cidx],
            "user_abort": user_abort[cidx],
        }
        return {"ptxn": ptxn, "cross": cross,
                "n_single": int(placed.size), "n_cross": len(cidx),
                "overflow_idx": single_idx[overflow]}
