"""Transaction router (§4.3): classification + re-routing + admission.

"For ease of presentation, we assume that all cross-partition transaction
requests go to the designated master node ... This could be implemented via
router nodes that are aware of the partitioning of the database. If some
transaction accesses multiple partitions on a non-master node, the system
would re-route the request to the master node for later execution."

The router ingests raw (parts, rows, kinds, deltas) transaction arrays,
classifies single- vs cross-partition by inspecting the op partition sets,
routes singles to their home partition queues (the partitioned phase input)
and defers cross txns to the master queue (the single-master phase input).
Mis-declared transactions (claimed single but touching remote partitions)
are detected and re-routed — the paper's re-route case.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class RouterStats:
    singles: int = 0
    cross: int = 0
    rerouted: int = 0
    deferred_epochs: int = 0


class Router:
    def __init__(self, n_partitions: int, rows_per_partition: int,
                 max_ops: int, n_cols: int = 10):
        self.P = n_partitions
        self.R = rows_per_partition
        self.M = max_ops
        self.C = n_cols
        self.stats = RouterStats()

    def classify(self, parts: np.ndarray, kinds: np.ndarray,
                 declared_home: np.ndarray):
        """parts: (B, M) op partition ids; kinds: (B, M) (0 = READ/pad).

        Returns (is_cross (B,), home (B,)). A txn is cross iff its live ops
        span >1 partition; txns declared single but spanning more are counted
        as re-routes (the paper's mis-routed case)."""
        live = kinds >= 0
        # ops beyond n_ops are padded with part == home, so span test is exact
        span_min = np.where(live, parts, parts.max(initial=0, axis=None)).min(axis=1)
        span_max = np.where(live, parts, 0).max(axis=1)
        is_cross = span_min != span_max
        rerouted = int(np.sum(is_cross & (declared_home >= 0)
                              & (span_max != declared_home)))
        self.stats.rerouted += rerouted
        self.stats.singles += int(np.sum(~is_cross))
        self.stats.cross += int(np.sum(is_cross))
        return is_cross, np.where(is_cross, -1, span_max)

    def route(self, parts, rows, kinds, deltas, user_abort=None):
        """Build the two phase queues from raw txn arrays (B, M, ...)."""
        B = parts.shape[0]
        if user_abort is None:
            user_abort = np.zeros(B, bool)
        is_cross, home = self.classify(parts, kinds, np.full(B, -1))

        single_idx = np.nonzero(~is_cross)[0]
        T = max(1, int(np.ceil(len(single_idx) / self.P * 1.5)) + 1)
        ptxn = {
            "valid": np.zeros((self.P, T), bool),
            "row": np.zeros((self.P, T, self.M), np.int32),
            "kind": np.zeros((self.P, T, self.M), np.int32),
            "delta": np.zeros((self.P, T, self.M, self.C), np.int32),
            "user_abort": np.zeros((self.P, T), bool),
        }
        fill = np.zeros(self.P, np.int32)
        for i in single_idx:
            p = int(home[i])
            t = fill[p]
            if t >= T:
                self.stats.deferred_epochs += 1   # back-pressure: next epoch
                continue
            ptxn["valid"][p, t] = True
            ptxn["row"][p, t] = rows[i]
            ptxn["kind"][p, t] = kinds[i]
            ptxn["delta"][p, t] = deltas[i]
            ptxn["user_abort"][p, t] = user_abort[i]
            fill[p] += 1

        cidx = np.nonzero(is_cross)[0]
        cross = {
            "valid": np.ones(len(cidx), bool),
            "row": (parts[cidx].astype(np.int64) * self.R
                    + rows[cidx]).astype(np.int32),
            "kind": kinds[cidx],
            "delta": deltas[cidx],
            "user_abort": user_abort[cidx],
        }
        return {"ptxn": ptxn, "cross": cross,
                "n_single": int(fill.sum()), "n_cross": len(cidx)}
