"""Stored-procedure op semantics shared by both phase executors.

Every transaction is a fixed-width list of ops (table, row, kind, delta).
Kinds:
  0 READ      — no write
  1 SET       — overwrite the row with delta
  2 ADD       — row += delta (RMW; models stock/ytd/balance updates)
  3 APPEND    — string concat modeled as a rolling hash + length word
                (col0 = hash-combine, col1 = capped length) — the TPC-C
                Payment c_data op that operation-replication ships cheaply.

Index/scan kinds (params in delta columns, see IX_* layout; these must
occupy the first IDX_OPS op slots of a transaction so the executors'
searchsorted gathers stay bounded):
  6 SCAN_READ    — range-scan an ordered index (delta: lo, hi keys); reads
                   up to SCAN_L index slots + the next-key boundary slot —
                   the scanned range joins the OCC read set (phantoms).
  7 SCAN_CONSUME — scan [lo, hi), validate the first live key equals the
                   declared EXPECT key, delete that index entry and
                   tombstone (zero) its primary row (TPC-C Delivery's
                   oldest-undelivered NEW-ORDER consume).  A mismatch
                   aborts the whole transaction.
  8 INSERT_IDX   — insert (key -> prow) into an index; locks the insertion
                   position (= next-key lock, what scanners validate).
  9 DELETE_IDX   — delete key from an index (no-op when absent).

The same functions implement *operation replay* on replicas: value
replication ships the post-image; operation replication ships (kind, delta)
and recomputes — exactly the paper's §5 distinction.  Index maintenance
replays through ``storage.index.apply_index_ops`` on both sides.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

READ, SET, ADD, APPEND, STOCK_DECR, PAY_CUST = 0, 1, 2, 3, 4, 5
SCAN_READ, SCAN_CONSUME, INSERT_IDX, DELETE_IDX = 6, 7, 8, 9
APPEND_CAP = 500

# index-op delta column layout (int32 words of the op's delta row)
IX_KEY = 0       # insert/delete: full (partition-prefixed) key
IX_LO = 0        # scans: range lo key (shares col 0 — always the key col)
IX_HI = 1        # scans: range hi key (exclusive)
IX_PROW = 1      # insert: partition-local primary row payload
IX_EXPECT = 2    # consume: expected (host-predicted) oldest key
IX_ID = 3        # all index ops: which index (position in the spec list)

IDX_OPS = 12     # index/scan ops live in op slots [0, IDX_OPS)

# Op groups (TPC-C Delivery "skip empty district" semantics): any op may
# declare a guard in its delta's LAST column (GUARD_COL for the standard
# C=10 layout; executors index -1) — 0 = unguarded, g > 0 = the op applies
# only if the SCAN_CONSUME at op slot g-1 validated.  A failed consume
# therefore skips its district's dependent updates (and its own delete/
# tombstone) without aborting the rest of the transaction.  Guards are only
# interpreted when an index is attached (index-enabled workloads own the
# last delta column; plain workloads keep full-width deltas).
GUARD_COL = 9

# Invariant (enforced by the workload generators, relied on by both
# executors' gather-once/scatter-once semantics): a transaction touches each
# row through AT MOST ONE op. Compound updates get a fused kind (PAY_CUST).


def hash_combine(h, x):
    # numpy scalar constants trace as literals (Pallas-kernel-safe)
    return (h * np.int32(1000003) + x) & np.int32(0x7FFFFFFF)


def apply_op(kind, old, delta):
    """kind: (...,) int32; old/delta: (..., C) int32 -> new value."""
    set_v = delta
    add_v = old + delta
    app_v = old
    app_v = app_v.at[..., 0].set(hash_combine(old[..., 0], delta[..., 0]))
    app_v = app_v.at[..., 1].set(
        jnp.minimum(old[..., 1] + delta[..., 1], APPEND_CAP))
    # TPC-C stock update: col0 qty = qty-d if qty-d >= 10 else qty-d+91;
    # col1 ytd += d; col2 order_cnt += 1; col3 remote_cnt += delta[3]
    d = delta[..., 0]
    q = old[..., 0] - d
    stk = old
    stk = stk.at[..., 0].set(jnp.where(q >= 10, q, q + 91))
    stk = stk.at[..., 1].set(old[..., 1] + d)
    stk = stk.at[..., 2].set(old[..., 2] + 1)
    stk = stk.at[..., 3].set(old[..., 3] + delta[..., 3])
    # TPC-C Payment customer row, fused: cols0-1 = c_data rolling hash+len,
    # cols2-4 += (balance, ytd_paid, cnt) — one op so the row is written once
    pay = add_v
    pay = pay.at[..., 0].set(hash_combine(old[..., 0], delta[..., 0]))
    pay = pay.at[..., 1].set(jnp.minimum(old[..., 1] + delta[..., 1], APPEND_CAP))
    k = kind[..., None]
    new = jnp.where(k == SET, set_v, old)
    new = jnp.where(k == ADD, add_v, new)
    new = jnp.where(k == APPEND, app_v, new)
    new = jnp.where(k == STOCK_DECR, stk, new)
    new = jnp.where(k == PAY_CUST, pay, new)
    new = jnp.where(k == SCAN_CONSUME, jnp.zeros_like(old), new)  # tombstone
    return new


def writes_primary(kind):
    """Op scatters a post-image into its primary row (consume tombstones)."""
    return ((kind > READ) & (kind <= PAY_CUST)) | (kind == SCAN_CONSUME)


def writes_index(kind):
    """Op mutates an ordered index (claims an index-slot lock)."""
    return kind >= SCAN_CONSUME


def reads_index(kind):
    """Op's read set includes a scanned index range (phantom validation)."""
    return (kind == SCAN_READ) | (kind == SCAN_CONSUME)


def is_index_kind(kind):
    """Any index/scan op — the primary `row` field is ignored for these
    except SCAN_CONSUME (which tombstones its primary row)."""
    return kind >= SCAN_READ


def is_write_kind(kind):
    """Op needs an OCC lock claim (primary row and/or index slot)."""
    return writes_primary(kind) | writes_index(kind)


def resolve_op_guards(kind, delta, consume_ok, wmask):
    """Apply op-group guards + consume self-masking to one round/slot.

    kind: (..., M); delta: (..., M, C); consume_ok: (..., K) per index-op
    slot; wmask: (..., M) primary-write mask.  Returns (wmask', iwrite_ok)
    where ``iwrite_ok (..., K)`` is the factor to AND into the index-
    maintenance mask.  Shared by both executors AND therefore by both
    replication streams — guard semantics must stay bit-identical on the
    replica for ``replica_consistent()`` to hold.
    """
    K = consume_ok.shape[-1]
    guard = delta[..., -1] * is_write_kind(kind)              # (..., M)
    gok = jnp.take_along_axis(consume_ok,
                              jnp.clip(guard - 1, 0, K - 1), axis=-1)
    guard_ok = jnp.where(guard > 0, gok, True)
    consume_live = jnp.where(kind[..., :K] == SCAN_CONSUME, consume_ok, True)
    wmask = wmask & guard_ok
    wmask = wmask.at[..., :K].set(wmask[..., :K] & consume_live)
    return wmask, consume_live & guard_ok[..., :K]
