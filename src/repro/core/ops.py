"""Stored-procedure op semantics shared by both phase executors.

Every transaction is a fixed-width list of ops (table, row, kind, delta).
Kinds:
  0 READ      — no write
  1 SET       — overwrite the row with delta
  2 ADD       — row += delta (RMW; models stock/ytd/balance updates)
  3 APPEND    — string concat modeled as a rolling hash + length word
                (col0 = hash-combine, col1 = capped length) — the TPC-C
                Payment c_data op that operation-replication ships cheaply.

The same functions implement *operation replay* on replicas: value
replication ships the post-image; operation replication ships (kind, delta)
and recomputes — exactly the paper's §5 distinction.
"""
from __future__ import annotations

import jax.numpy as jnp

READ, SET, ADD, APPEND, STOCK_DECR, PAY_CUST = 0, 1, 2, 3, 4, 5
APPEND_CAP = 500

# Invariant (enforced by the workload generators, relied on by both
# executors' gather-once/scatter-once semantics): a transaction touches each
# row through AT MOST ONE op. Compound updates get a fused kind (PAY_CUST).


def hash_combine(h, x):
    return (h * jnp.int32(1000003) + x) & jnp.int32(0x7FFFFFFF)


def apply_op(kind, old, delta):
    """kind: (...,) int32; old/delta: (..., C) int32 -> new value."""
    set_v = delta
    add_v = old + delta
    app_v = old
    app_v = app_v.at[..., 0].set(hash_combine(old[..., 0], delta[..., 0]))
    app_v = app_v.at[..., 1].set(
        jnp.minimum(old[..., 1] + delta[..., 1], APPEND_CAP))
    # TPC-C stock update: col0 qty = qty-d if qty-d >= 10 else qty-d+91;
    # col1 ytd += d; col2 order_cnt += 1; col3 remote_cnt += delta[3]
    d = delta[..., 0]
    q = old[..., 0] - d
    stk = old
    stk = stk.at[..., 0].set(jnp.where(q >= 10, q, q + 91))
    stk = stk.at[..., 1].set(old[..., 1] + d)
    stk = stk.at[..., 2].set(old[..., 2] + 1)
    stk = stk.at[..., 3].set(old[..., 3] + delta[..., 3])
    # TPC-C Payment customer row, fused: cols0-1 = c_data rolling hash+len,
    # cols2-4 += (balance, ytd_paid, cnt) — one op so the row is written once
    pay = add_v
    pay = pay.at[..., 0].set(hash_combine(old[..., 0], delta[..., 0]))
    pay = pay.at[..., 1].set(jnp.minimum(old[..., 1] + delta[..., 1], APPEND_CAP))
    k = kind[..., None]
    new = jnp.where(k == SET, set_v, old)
    new = jnp.where(k == ADD, add_v, new)
    new = jnp.where(k == APPEND, app_v, new)
    new = jnp.where(k == STOCK_DECR, stk, new)
    new = jnp.where(k == PAY_CUST, pay, new)
    return new


def is_write_kind(kind):
    return kind > READ
