"""Distributed STAR engine on a device mesh (shard_map over partitions).

The single-process :class:`repro.core.engine.StarEngine` validates protocol
semantics; this module is the *cluster* form — the shape that runs on real
hardware:

* database partitions sharded over a 1-D ``part`` mesh axis — one device is
  one paper "node" holding a contiguous block of ``ppn = P / n_nodes``
  primary partitions (the partial replicas);
* **partitioned phase**: ``shard_map`` with NO collectives inside — each
  device runs its partitions' queues serially (H-Store semantics), exactly
  the paper's zero-coordination claim, verified by asserting the phase's
  HLO contains no collective ops;
* **replication fence**: a ``psum`` barrier carrying the per-device commit
  counters — the §4.3 statistics exchange — after which the full replica
  (the master's complete copy, all-gathered once at bootstrap and kept
  consistent by the streams) is updated;
* **single-master phase**: the designated master executes cross-partition
  transactions on its full copy (no 2PC — the paper's core claim), then the
  write stream is scattered back to the partition owners with the Thomas
  write rule.

Beyond the mesh execution, the engine carries what the cluster runtime
(`repro.cluster`) needs for §4.5 fault tolerance: two-version snapshots at
the epoch fence (revert on failure), node-granular memory loss + donor-copy
restore, full-replica rebuild from the partial set, and per-node commit /
fence-wait telemetry so fig12/fig13 can report skew.  Its ``run_epoch``
returns the same metric surface as ``StarEngine.run_epoch`` (absolute fence
stamps, per-slot commit masks, ``t_ingest_s`` for the double-buffered
ingest hook), so ``service.TxnService`` drives either engine unchanged.

On this host the mesh axes are 1-8 forced CPU devices (tests); the same
code paths lower for a TPU slice.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import replication as repl
from repro.core.engine import EngineStats
from repro.core.partitioned import run_partitioned
from repro.core.phase_switch import PhaseController
from repro.core.single_master import run_single_master


def _pad_pow2(tree, axis: int):
    """Pad a txn pytree to the next power of two along `axis` so epoch
    shapes stay stable across batches (no per-epoch recompilation)."""
    def pad(a):
        n = a.shape[axis]
        target = 1 << max(0, (n - 1).bit_length())
        if target == n:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, target - n)
        return np.pad(np.asarray(a), widths)
    return jax.tree.map(pad, tree)


class ClusterStarEngine:
    """f full replicas (the designated master's complete copies) + the
    node-sharded partial replicas (contiguous ``ppn`` partitions per
    device/node)."""

    def __init__(self, mesh, n_partitions: int, rows_per_partition: int,
                 n_cols: int = 10, init_val=None, max_rounds: int = 16,
                 iteration_ms: float = 10.0, adaptive_epoch: bool = False):
        assert "part" in mesh.axis_names
        self.mesh = mesh
        self.n_nodes = int(mesh.shape["part"])
        assert n_partitions % self.n_nodes == 0, \
            (n_partitions, self.n_nodes)
        self.ppn = n_partitions // self.n_nodes
        self.P, self.R, self.C = n_partitions, rows_per_partition, n_cols
        val = (jnp.asarray(init_val, jnp.int32) if init_val is not None
               else jnp.zeros((self.P, self.R, self.C), jnp.int32))
        tid = jnp.zeros((self.P, self.R), jnp.uint32)
        self._shard = NamedSharding(mesh, P("part"))
        # f=1 asymmetric replication, physically: the full replica lives on
        # the DESIGNATED MASTER's device only (node 0) — replicating it
        # across the mesh would execute the op replay and the whole
        # single-master phase redundantly on every device (N x the CPU for
        # f=1 semantics)
        self._master_dev = jax.sharding.SingleDeviceSharding(
            mesh.devices.flat[0])
        # partial replicas: partition-sharded primary copy
        self.part_val = jax.device_put(val, self._shard)
        self.part_tid = jax.device_put(tid, self._shard)
        # full replica (master's complete copy) — on the master node
        self.full_val = jax.device_put(val, self._master_dev)
        self.full_tid = jax.device_put(tid, self._master_dev)
        self.epoch = 1
        self.max_rounds = max_rounds
        self.controller = PhaseController(e_ms=iteration_ms,
                                          adaptive=adaptive_epoch)
        self.stats = EngineStats()
        # per-node telemetry (fig12/fig13 skew): committed txns and modeled
        # fence wait (the slowest node sets the fence; everyone else waits)
        self.node_committed = np.zeros(self.n_nodes, np.int64)
        self.node_fence_wait_s = np.zeros(self.n_nodes)
        self._last_logs = None        # {"part": ..., "sm": ...} for WALs
        self._build()
        self._snap = self._state()

    def _build(self):
        mesh = self.mesh

        def part_phase(val, tid, ptxn, epoch):
            # NO collectives inside: single-partition txns need none (§4.1)
            v, t, out, stats = run_partitioned(val, tid, ptxn, epoch)
            return v, t, out["log"], out["committed"], \
                stats["committed"][None]

        pspec = P("part")
        txn_spec = {k: P("part") for k in
                    ("valid", "row", "kind", "delta", "user_abort")}
        self._part = jax.jit(shard_map(
            part_phase, mesh,
            in_specs=(pspec, pspec, txn_spec, P()),
            out_specs=(pspec, pspec,
                       {k: P("part") for k in
                        ("row", "val", "tid", "write", "kind", "delta")},
                       pspec, pspec)))
        self._bcast = NamedSharding(mesh, P())

        def fence(commit_counts):
            # §4.3: nodes exchange commit statistics; the psum is the barrier
            return jax.lax.psum(commit_counts, "part")

        self._fence_barrier = jax.jit(shard_map(
            fence, mesh, in_specs=(P("part"),), out_specs=P()))

        # single-master phase runs on the master's device only (its full
        # copy lives there) — no 2PC, no cross-device coordination during
        # execution; the write stream ships back through _scatter
        self._sm = jax.jit(
            lambda v, t, txns, epoch: run_single_master(
                v, t, txns, epoch, max_rounds=self.max_rounds))

        ppn, R, C = self.ppn, self.R, self.C

        def scatter_back(part_val, part_tid, rows, vals, tids):
            """Apply the master's write stream to the partition owners:
            each device filters the global stream to its own row range."""
            pid = jax.lax.axis_index("part")
            lo = pid * ppn * R
            local = (rows >= lo) & (rows < lo + ppn * R)
            lrows = jnp.where(local, rows - lo, -1)
            v, t, _ = repl.thomas_apply(part_val.reshape(ppn * R, C),
                                        part_tid.reshape(ppn * R),
                                        lrows, vals, tids)
            return v.reshape(ppn, R, C), t.reshape(ppn, R)

        self._scatter = jax.jit(shard_map(
            scatter_back, mesh,
            in_specs=(pspec, pspec, P(), P(), P()),
            out_specs=(pspec, pspec)))

        # ordered op-stream replay onto the full replica — jitted once;
        # an eager vmap here would retrace EVERY epoch (host-bound)
        self._replay_full = jax.jit(jax.vmap(repl.replay_operations))

    # ------------------------------------------------------------------
    def run_epoch(self, batch, ingest=None, commit=True) -> dict:
        """StarEngine-compatible epoch: partitioned phase (sharded, zero
        collectives), psum fence, single-master phase on the full copy,
        value scatter-back, epoch fence + two-version snapshot commit.

        ingest: optional zero-arg callable overlapped with the partitioned
        phase's device execution (double-buffered host batch formation).
        commit=False runs the phases up TO the epoch fence but never
        commits (no snapshot, no epoch advance, no stats) — the cluster
        runtime uses it for an epoch whose fence a failed node will miss:
        everything the phases wrote is discarded by the §4.5 revert."""
        epoch_u = jnp.uint32(self.epoch)
        ptxn = jax.tree.map(jnp.asarray, _pad_pow2(batch["ptxn"], 1))
        cross = jax.tree.map(jnp.asarray, _pad_pow2(batch["cross"], 0))

        # ---- partitioned phase (no collectives) -------------------------
        t0 = time.perf_counter()
        pv, pt, plog, p_committed, counts = self._part(
            self.part_val, self.part_tid, ptxn, epoch_u)
        t_ingest = 0.0
        if ingest is not None:       # overlap host ingest with device exec
            ti = time.perf_counter()
            ingest()
            t_ingest = time.perf_counter() - ti
        tb = time.perf_counter()
        jax.block_until_ready(pv)
        t1 = time.perf_counter()
        t_part = max(t1 - t0 - t_ingest, t1 - tb)
        self.part_val, self.part_tid = pv, pt
        # replicate the ordered op streams to the full replica (hybrid: the
        # partitioned phase ships OPERATIONS, §5) — the device_put is the
        # op-stream ship from every node to the master's device
        plog_m = jax.device_put(plog, self._master_dev)
        fv, ft = self._replay_full(self.full_val, self.full_tid, plog_m)
        self.full_val, self.full_tid = fv, ft

        # ---- fence 1 (commit-statistics psum barrier) --------------------
        tf0 = time.perf_counter()
        n_single = int(self._fence_barrier(counts)[0])
        t_fence1 = time.perf_counter()

        # ---- single-master phase on the full copy ------------------------
        # B from the RAW batch: padding turns an empty cross batch into 1-2
        # invalid lanes, which would run the full OCC program for nothing
        # (service batches always carry fixed non-zero lane counts)
        t0 = time.perf_counter()
        B = int(batch["cross"]["row"].shape[0])
        slog = None
        if B > 0:
            flat_v = self.full_val.reshape(self.P * self.R, self.C)
            flat_t = self.full_tid.reshape(self.P * self.R)
            fv, ft, out, sstats = self._sm(flat_v, flat_t, cross, epoch_u)
            jax.block_until_ready(fv)
            n_cross = int(sstats["committed"])
            self.full_val = fv.reshape(self.P, self.R, self.C)
            self.full_tid = ft.reshape(self.P, self.R)
            # value-replicate the master's writes back to partition owners
            # (the device_put broadcast is the value-stream ship, §5)
            slog = out["log"]
            w = slog["write"].reshape(-1)
            rows = jax.device_put(
                jnp.where(w, slog["row"].reshape(-1), -1), self._bcast)
            vals = jax.device_put(slog["val"].reshape(-1, self.C),
                                  self._bcast)
            tids = jax.device_put(slog["tid"].reshape(-1), self._bcast)
            self.part_val, self.part_tid = self._scatter(
                self.part_val, self.part_tid, rows, vals, tids)
            c_committed = np.asarray(out["committed"])
            starved = int(sstats["starved"])
            retries = int(sstats["retries"])
            aborts = int(sstats["user_aborts"])
        else:
            n_cross = starved = retries = aborts = 0
            c_committed = np.zeros(0, bool)
        t_sm = time.perf_counter() - t0
        t_sm_round = t_sm / self.max_rounds if B > 0 else 0.0

        # ---- fence 2: epoch boundary + two-version snapshot --------------
        # the fence's contract is "every outstanding stream applied": wait
        # for the master's op-stream replay and the value scatter-back HERE
        # (their time is fence time) — otherwise the master device's replay
        # backlog silently delays the NEXT epoch's partitioned phase and
        # pollutes its measurement
        tf2 = time.perf_counter()
        jax.block_until_ready((self.full_val, self.part_val))
        p_committed = np.asarray(p_committed)                  # (P, T)
        node_c = p_committed.sum(1).reshape(self.n_nodes, -1).sum(1)
        # modeled fence wait: the slowest node's phase time sets the fence;
        # a node's own busy time is proxied by its committed share
        cmax = int(node_c.max()) if node_c.size else 0
        wait = (t_part * (1.0 - node_c / cmax) if cmax > 0
                else np.zeros(self.n_nodes))
        tau_p = tau_s = 0.0
        if commit:
            self.snapshot_commit()
            self.epoch += 1
            self._last_logs = {"part": plog, "sm": slog}
            self.node_committed += node_c
            self.node_fence_wait_s += wait
            self.controller.observe_fence_wait(float(wait.max()) * 1e3)
            self.controller.observe("partitioned", n_single, t_part)
            self.controller.observe("single", n_cross, t_sm,
                                    frac_cross=n_cross
                                    / max(n_cross + n_single, 1))
            tau_p, tau_s = self.controller.plan()
        t_fence2 = time.perf_counter()
        if commit:
            s = self.stats
            s.epochs += 1
            s.committed_single += n_single
            s.committed_cross += n_cross
            s.user_aborts += aborts
            s.retries += retries
            s.part_time_s += t_part
            s.sm_time_s += t_sm
            s.sm_rounds += self.max_rounds if B > 0 else 0
            s.fences += 2
            s.fence_time_s += (t_fence1 - tf0) + (t_fence2 - tf2)

        return {"committed_single": n_single, "committed_cross": n_cross,
                "tau_p_ms": tau_p, "tau_s_ms": tau_s,
                "t_part_s": t_part, "t_sm_s": t_sm,
                "t_sm_round_s": t_sm_round, "t_ingest_s": t_ingest,
                "t_fence1_s": t_fence1, "t_fence2_s": t_fence2,
                "t_fence_net_s": 0.0,
                "p_committed": p_committed, "c_committed": c_committed,
                "starved": starved,
                "node_committed": node_c,
                "node_fence_wait_s": wait}

    # ------------------------------------------------------------------
    # two-version snapshots + node-granular state surgery (§4.5)
    # ------------------------------------------------------------------
    def _state(self):
        return {"part_val": self.part_val, "part_tid": self.part_tid,
                "full_val": self.full_val, "full_tid": self.full_tid}

    def snapshot_commit(self):
        self._snap = self._state()

    def revert_to_snapshot(self):
        """Discard the in-flight epoch on every replica (two-version
        records, §4.5.2)."""
        s = self._snap
        self.part_val, self.part_tid = s["part_val"], s["part_tid"]
        self.full_val, self.full_tid = s["full_val"], s["full_tid"]

    def node_slice(self, node: int) -> slice:
        return slice(node * self.ppn, (node + 1) * self.ppn)

    def scribble_block(self, node: int):
        """Simulate loss of the node's partition block — in BOTH the
        working state and the snapshot (a dead node's snapshot dies with
        it) — so recovery is only correct if it really restores the block
        from a surviving source (full replica or disk).  Callers invoke
        this only when NO partial replica home of the block survives; a
        surviving sibling copy is bit-equal, so the un-scribbled array
        stands in for it."""
        sl = self.node_slice(node)
        junk_v = jnp.int32(-0x5A5A5A5)
        junk_t = jnp.uint32(0xDEAD)
        self.part_val = self.part_val.at[sl].set(junk_v)
        self.part_tid = self.part_tid.at[sl].set(junk_t)
        snap = dict(self._snap)
        snap["part_val"] = snap["part_val"].at[sl].set(junk_v)
        snap["part_tid"] = snap["part_tid"].at[sl].set(junk_t)
        self._snap = snap

    def scribble_full(self):
        """Simulate loss of every full replica (all f holders dead)."""
        junk_v = jnp.int32(-0x5A5A5A5)
        junk_t = jnp.uint32(0xDEAD)
        self.full_val = self.full_val.at[:].set(junk_v)
        self.full_tid = self.full_tid.at[:].set(junk_t)
        snap = dict(self._snap)
        snap["full_val"] = snap["full_val"].at[:].set(junk_v)
        snap["full_tid"] = snap["full_tid"].at[:].set(junk_t)
        self._snap = snap

    def restore_nodes_from_full(self, nodes):
        """§4.5.3 case-1/3 donor copy: rebuild the nodes' partition blocks
        from the (surviving) full replica's committed snapshot, then make
        that the nodes' own committed version.  (Recovery path: the copy
        goes through the host — the full replica lives on the master's
        device, the blocks on the owners'.)"""
        snap = dict(self._snap)
        pv = np.asarray(snap["part_val"]).copy()
        pt = np.asarray(snap["part_tid"]).copy()
        fv = np.asarray(snap["full_val"])
        ft = np.asarray(snap["full_tid"])
        for n in nodes:
            sl = self.node_slice(n)
            pv[sl] = fv[sl]
            pt[sl] = ft[sl]
        snap["part_val"] = jax.device_put(jnp.asarray(pv), self._shard)
        snap["part_tid"] = jax.device_put(jnp.asarray(pt), self._shard)
        self._snap = snap
        self.part_val, self.part_tid = snap["part_val"], snap["part_tid"]
        self.full_val = snap["full_val"]
        self.full_tid = snap["full_tid"]

    def rebuild_full_from_partials(self):
        """§4.5.3 case 2: every partition still has a live partial copy but
        no full replica survives — re-replicate a full copy by gathering
        the committed partial set (the bootstrap all-gather, again)."""
        snap = dict(self._snap)
        fv = jax.device_put(jnp.asarray(snap["part_val"]), self._master_dev)
        ft = jax.device_put(jnp.asarray(snap["part_tid"]), self._master_dev)
        snap["full_val"], snap["full_tid"] = fv, ft
        self._snap = snap
        self.part_val, self.part_tid = snap["part_val"], snap["part_tid"]
        self.full_val, self.full_tid = fv, ft

    def load_committed(self, val, tid):
        """§4.5.1 UNAVAILABLE reload: install a recovered committed state
        (checkpoint + replayed logs) on every replica."""
        val = jnp.asarray(val, jnp.int32).reshape(self.P, self.R, self.C)
        tid = jnp.asarray(tid, jnp.uint32).reshape(self.P, self.R)
        self.part_val = jax.device_put(val, self._shard)
        self.part_tid = jax.device_put(tid, self._shard)
        self.full_val = jax.device_put(val, self._master_dev)
        self.full_tid = jax.device_put(tid, self._master_dev)
        self.snapshot_commit()

    # ------------------------------------------------------------------
    def consistent(self) -> bool:
        """Partial replicas (sharded) == full replica (master copy)."""
        pv = np.asarray(self.part_val)
        fv = np.asarray(self.full_val)
        pt = np.asarray(self.part_tid)
        ft = np.asarray(self.full_tid)
        return bool(np.array_equal(pv, fv) and np.array_equal(pt, ft))

    def partitioned_phase_has_no_collectives(self, batch) -> bool:
        """Compile-time proof of the §4.1 zero-coordination claim."""
        ptxn = jax.tree.map(jnp.asarray, _pad_pow2(batch["ptxn"], 1))
        txt = self._part.lower(self.part_val, self.part_tid, ptxn,
                               jnp.uint32(1)).compile().as_text()
        return not any(op in txt for op in
                       ("all-reduce(", "all-gather(", "collective-permute(",
                        "all-to-all(", "reduce-scatter("))
