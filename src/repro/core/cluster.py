"""Distributed STAR engine on a device mesh (shard_map over partitions).

The single-process :class:`repro.core.engine.StarEngine` validates protocol
semantics; this module is the *cluster* form — the shape that runs on real
hardware:

* database partitions sharded over a 1-D ``part`` mesh axis — one device is
  one paper "node" holding a contiguous block of ``ppn = P / n_nodes``
  primary partitions, plus (``secondary=True``) a PHYSICAL secondary copy
  of the previous node's block in home-major layout — the partial replica
  set is real state, not a modeling convention;
* **partitioned phase**: ``shard_map`` with NO collectives inside — each
  device runs its partitions' queues serially (H-Store semantics), exactly
  the paper's zero-coordination claim, verified by asserting the phase's
  HLO contains no collective ops.  The phase executes in ``n_slabs``
  chunks of queue slots and the committed op stream of each chunk SHIPS to
  the full replica (and the secondary homes) while the next chunk
  executes — the §5 in-phase op-stream overlap — so the replication fence
  waits only on the unshipped tail slab;
* **replication fence**: a ``psum`` barrier carrying the per-device commit
  counters — the §4.3 statistics exchange — reached with every slab but
  the tail already applied;
* **single-master phase**: the designated master executes cross-partition
  transactions on its full copy (no 2PC — the paper's core claim), then
  the write stream is scattered back to the partition owners AND the
  secondary homes with the Thomas write rule; index maintenance replays
  round-ordered on every partial copy.

Ordered secondary indexes (``indexes=[IndexSpec...]``) ride the same
machinery end-to-end: partition-sharded segments inside the shard_map
phase (local ``part_ids`` align global keys with local segments), the full
replica's segments updated by the slab replay, the single-master phase
executing on the full copy's segments — so the full five-transaction
TPC-C mix runs on the cluster runtime with ``replica_consistent()``
covering records and every index segment.

Beyond the mesh execution, the engine carries what the cluster runtime
(`repro.cluster`) needs for §4.5 fault tolerance: two-version snapshots at
the epoch fence (revert on failure — which also discards the in-flight
epoch's consumed stream slabs, tracked by a slab high-watermark so a
re-executed epoch applies each slab exactly once), node-granular memory
loss + donor-copy restore, surviving-secondary block restore, full-replica
rebuild from the partial set, and per-node commit / fence-wait telemetry.
Its ``run_epoch`` returns the same metric surface as
``StarEngine.run_epoch``, so ``service.TxnService`` drives either engine
unchanged.

On this host the mesh axes are 1-8 forced CPU devices (tests); the same
code paths lower for a TPU slice.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.baselines.cost_model import Network
from repro.changelog.log import ChangeLog
from repro.compat import shard_map
from repro.core import replication as repl
from repro.core.engine import EngineStats
from repro.core.partitioned import run_partitioned
from repro.core.phase_switch import PhaseController
from repro.core.single_master import run_single_master
from repro.obs import trace as obs
from repro.storage.index import IndexSpec, make_index


def _pad_pow2(tree, axis: int):
    """Pad a txn pytree to the next power of two along `axis` so epoch
    shapes stay stable across batches (no per-epoch recompilation)."""
    def pad(a):
        n = a.shape[axis]
        target = 1 << max(0, (n - 1).bit_length())
        if target == n:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, target - n)
        return np.pad(np.asarray(a), widths)
    return jax.tree.map(pad, tree)


class _ReplicaShip:
    """ChangeLog subscriber doing the physical replica shipping: each
    published slab device-transfers to the master's device (the §5
    network ship) and replays in order on the full replica, then — rolled
    home-major — onto the physical secondary homes; the single-master
    stream scatters back to the partition owners and secondary homes
    under the Thomas write rule, index rounds replaying on every partial
    copy.  Fires while the NEXT slab executes, so the fence only ever
    waits on the tail."""

    def __init__(self, eng):
        self.eng = eng

    def on_slab(self, log, info):
        eng = self.eng
        with obs.span("replica.replay_full", cat="replay",
                      epoch=info["epoch"], slab=info["slab"]):
            log_m = jax.device_put(log, eng._master_dev)
            eng.full_val, eng.full_tid, fidx = eng._replay_full(
                eng.full_val, eng.full_tid, log_m, eng.full_idx)
            if eng.has_index:
                eng.full_idx = fidx
        if eng.secondary:
            with obs.span("replica.replay_secondary", cat="replay",
                          epoch=info["epoch"], slab=info["slab"]):
                eng.sec_val, eng.sec_tid, sidx = eng._replay_sec(
                    eng.sec_val, eng.sec_tid, log, eng.sec_idx)
                if eng.has_index:
                    eng.sec_idx = sidx

    def on_master(self, stream):
        eng = self.eng
        with obs.span("replica.scatter_back", cat="replay"):
            slog = stream["log"]
            w = slog["write"].reshape(-1)
            rows = jax.device_put(
                jnp.where(w, slog["row"].reshape(-1), -1), eng._bcast)
            vals = jax.device_put(slog["val"].reshape(-1, eng.C), eng._bcast)
            tids = jax.device_put(slog["tid"].reshape(-1), eng._bcast)
            eng.part_val, eng.part_tid = eng._scatter(
                eng.part_val, eng.part_tid, rows, vals, tids)
            if eng.secondary:
                eng.sec_val, eng.sec_tid = eng._scatter_sec(
                    eng.sec_val, eng.sec_tid, rows, vals, tids)
            if eng.has_index:
                kb = jax.device_put(stream["kinds"], eng._bcast)
                db = jax.device_put(stream["delta"], eng._bcast)
                iwb = jax.device_put(slog["iwrite"], eng._bcast)
                tdb = jax.device_put(slog["tid"], eng._bcast)
                eng.part_idx = eng._sm_idx_replay(eng.part_idx, kb, db,
                                                  iwb, tdb)
                if eng.secondary:
                    eng.sec_idx = eng._sm_idx_replay_sec(eng.sec_idx, kb, db,
                                                         iwb, tdb)


class ClusterStarEngine:
    """f full replicas (the designated master's complete copies) + the
    node-sharded partial replicas: each node's contiguous ``ppn``-partition
    primary block plus the physical secondary copy of its predecessor's
    block (round-robin homes, matching ``ClusterConfig.partition_homes``)."""

    LEDGER_CAP = 4096              # committed-slab telemetry window

    def _roll_home(self, tree):
        """The ONE encoding of the home-major secondary layout: array
        row p holds partition (p - ppn) mod P, i.e. node m hosts node
        m-1's block (ClusterConfig.partition_homes round-robin).  Every
        site that materializes, resyncs, reloads, or checks the
        secondary copies goes through this shift."""
        return jax.tree.map(lambda a: jnp.roll(a, self.ppn, axis=0),
                            tree)

    def __init__(self, mesh, n_partitions: int, rows_per_partition: int,
                 n_cols: int = 10, init_val=None, max_rounds: int = 16,
                 iteration_ms: float = 10.0, adaptive_epoch: bool = False,
                 indexes: list[IndexSpec] | None = None,
                 net: Network | None = None, n_slabs: int = 4,
                 secondary: bool | None = None, kernel: str = "jnp"):
        assert "part" in mesh.axis_names
        assert kernel in ("jnp", "pallas"), kernel
        # "pallas" rides the fused kernels everywhere index maintenance /
        # OCC rounds run: the sharded partitioned phase, the single-master
        # phase on the full copy, and every partial-replica replay —
        # bit-identical results either way (interpreted off-TPU)
        self.kernel = kernel
        self.mesh = mesh
        self.n_nodes = int(mesh.shape["part"])
        assert n_partitions % self.n_nodes == 0, \
            (n_partitions, self.n_nodes)
        self.ppn = n_partitions // self.n_nodes
        self.P, self.R, self.C = n_partitions, rows_per_partition, n_cols
        self.index_specs = list(indexes or [])
        self.has_index = bool(self.index_specs)
        self.net = net or Network()
        assert n_slabs >= 1, n_slabs
        self.n_slabs = n_slabs
        # physical secondary partial replicas need a second distinct home
        self.secondary = (self.n_nodes > 1 if secondary is None
                          else (secondary and self.n_nodes > 1))
        val = (jnp.asarray(init_val, jnp.int32) if init_val is not None
               else jnp.zeros((self.P, self.R, self.C), jnp.int32))
        tid = jnp.zeros((self.P, self.R), jnp.uint32)
        self._shard = NamedSharding(mesh, P("part"))
        # f=1 asymmetric replication, physically: the full replica lives on
        # the DESIGNATED MASTER's device only (node 0) — replicating it
        # across the mesh would execute the op replay and the whole
        # single-master phase redundantly on every device (N x the CPU for
        # f=1 semantics)
        self._master_dev = jax.sharding.SingleDeviceSharding(
            mesh.devices.flat[0])
        # partial replicas: partition-sharded primary copy
        self.part_val = jax.device_put(val, self._shard)
        self.part_tid = jax.device_put(tid, self._shard)
        # full replica (master's complete copy) — on the master node
        self.full_val = jax.device_put(val, self._master_dev)
        self.full_tid = jax.device_put(tid, self._master_dev)
        idx0 = [make_index(s, self.P) for s in self.index_specs]
        self.part_idx = jax.device_put(idx0, self._shard)
        self.full_idx = jax.device_put(idx0, self._master_dev)
        # physical secondary copies, home-major: array row p holds
        # partition (p - ppn) mod P, so node m's block holds the SECONDARY
        # copy of node (m-1)'s partitions (ClusterConfig.partition_homes
        # round-robin with replicas_per_partition=2)
        if self.secondary:
            self.sec_val = jax.device_put(self._roll_home(val),
                                          self._shard)
            self.sec_tid = jax.device_put(self._roll_home(tid),
                                          self._shard)
            self.sec_idx = jax.device_put(self._roll_home(idx0),
                                          self._shard)
        else:
            self.sec_val = self.sec_tid = None
            self.sec_idx = []
        self.epoch = 1
        self.max_rounds = max_rounds
        self.controller = PhaseController(e_ms=iteration_ms,
                                          adaptive=adaptive_epoch)
        self.stats = EngineStats()
        # per-node telemetry (fig12/fig13 skew): committed txns and modeled
        # fence wait (the slowest node sets the fence; everyone else waits)
        self.node_committed = np.zeros(self.n_nodes, np.int64)
        self.node_fence_wait_s = np.zeros(self.n_nodes)
        # the one ordered op stream: the engine PUBLISHES (slabs, master
        # stream, commit/revert) and every consumer subscribes — the
        # physical replica shipper first (stream order), then any sink
        # (WAL, materialized views) the runtime/service registers.  The
        # changelog owns the slab high-watermark (in-flight slabs the
        # subscribers consumed; a §4.5 revert discards them so a
        # re-executed epoch applies each slab exactly once) and the
        # committed slab ledger (bounded, explicit drop-oldest — tests
        # assert exactly-once application from it)
        self.changelog = ChangeLog(n_slabs, ledger_cap=self.LEDGER_CAP)
        self.changelog.subscribe(_ReplicaShip(self))
        # read-tier watermark: the fence epoch the committed snapshot
        # (``_snap``) corresponds to — 0 until the first commit
        self.committed_epoch = 0
        self._build()
        self._snap = self._state()

    def _build(self):
        mesh = self.mesh
        ppn, R, C, N = self.ppn, self.R, self.C, self.n_nodes
        has_index = self.has_index
        kernel = self.kernel

        def part_phase(val, tid, index, seq, ptxn, epoch):
            # NO collectives inside: single-partition txns need none (§4.1).
            # part_ids map this block's local segment rows to their global
            # partition ids so index maintenance lands on the right keys.
            pid = jax.lax.axis_index("part")
            part_ids = pid * ppn + jnp.arange(ppn, dtype=jnp.int32)
            v, t, out, stats = run_partitioned(
                val, tid, ptxn, epoch, seq0=seq,
                index=index if has_index else None, part_ids=part_ids,
                kernel=kernel)
            idx = out.get("index", index)
            extras = jnp.stack([stats["committed"],
                                stats["consume_skips"],
                                stats["index_overflow"],
                                stats["user_aborts"]])[None]
            return (v, t, idx, out["seq"], out["log"], out["committed"],
                    extras)

        pspec = P("part")
        txn_spec = {k: P("part") for k in
                    ("valid", "row", "kind", "delta", "user_abort")}
        idx_spec = [{k: P("part") for k in ("key", "prow", "tid")}
                    for _ in self.index_specs]
        log_keys = ["row", "val", "tid", "write", "kind", "delta"]
        if has_index:
            log_keys += ["iwrite", "cskip"]
        log_spec = {k: P("part") for k in log_keys}
        self._part = jax.jit(shard_map(
            part_phase, mesh,
            in_specs=(pspec, pspec, idx_spec, pspec, txn_spec, P()),
            out_specs=(pspec, pspec, idx_spec, pspec, log_spec, pspec,
                       pspec)))
        self._bcast = NamedSharding(mesh, P())
        self._seq0 = jax.device_put(jnp.zeros((self.P,), jnp.uint32),
                                    self._shard)

        def fence(commit_counts):
            # §4.3: nodes exchange commit statistics; the psum is the barrier
            return jax.lax.psum(commit_counts, "part")

        self._fence_barrier = jax.jit(shard_map(
            fence, mesh, in_specs=(P("part"),), out_specs=P()))

        # single-master phase runs on the master's device only (its full
        # copy lives there) — no 2PC, no cross-device coordination during
        # execution; the write stream ships back through the scatters
        self._sm = jax.jit(
            lambda v, t, idx, txns, epoch: run_single_master(
                v, t, txns, epoch, max_rounds=self.max_rounds,
                index=idx if has_index else None, kernel=kernel))

        def scatter_back(part_val, part_tid, rows, vals, tids):
            """Apply the master's write stream to the partition owners:
            each device filters the global stream to its own row range."""
            pid = jax.lax.axis_index("part")
            lo = pid * ppn * R
            local = (rows >= lo) & (rows < lo + ppn * R)
            lrows = jnp.where(local, rows - lo, -1)
            v, t, _ = repl.thomas_apply(part_val.reshape(ppn * R, C),
                                        part_tid.reshape(ppn * R),
                                        lrows, vals, tids)
            return v.reshape(ppn, R, C), t.reshape(ppn, R)

        self._scatter = jax.jit(shard_map(
            scatter_back, mesh,
            in_specs=(pspec, pspec, P(), P(), P()),
            out_specs=(pspec, pspec)))

        def scatter_back_sec(sec_val, sec_tid, rows, vals, tids):
            """Same stream, delivered to each block's SECONDARY home: node
            m's sec block holds node (m-1)'s partitions (home-major)."""
            pid = jax.lax.axis_index("part")
            lo = jnp.mod(pid - 1, N) * ppn * R
            local = (rows >= lo) & (rows < lo + ppn * R)
            lrows = jnp.where(local, rows - lo, -1)
            v, t, _ = repl.thomas_apply(sec_val.reshape(ppn * R, C),
                                        sec_tid.reshape(ppn * R),
                                        lrows, vals, tids)
            return v.reshape(ppn, R, C), t.reshape(ppn, R)

        self._scatter_sec = jax.jit(shard_map(
            scatter_back_sec, mesh,
            in_specs=(pspec, pspec, P(), P(), P()),
            out_specs=(pspec, pspec)))

        # ordered op-stream replay onto the full replica — jitted once; an
        # eager form here would retrace EVERY slab (host-bound).  One slab
        # = one jitted replay of its slot range (records + index ops).
        self._replay_full = jax.jit(
            lambda v, t, log, idx: repl.replay_partitioned(
                v, t, log, idx if has_index else None, kernel=kernel))

        part_ids_sec = (jnp.arange(self.P, dtype=jnp.int32) - ppn) \
            % self.P

        def replay_sec(v, t, log, idx):
            # the roll IS the ship: each block's ordered stream moves to
            # its secondary home (a collective permute on the mesh)
            rl = jax.tree.map(lambda a: jnp.roll(a, ppn, axis=0), log)
            return repl.replay_partitioned(
                v, t, rl, idx if has_index else None,
                part_ids=part_ids_sec, kernel=kernel)

        self._replay_sec = jax.jit(replay_sec)

        if has_index:
            def sm_idx_replay(idx, kinds, delta, iwrite, tids):
                pid = jax.lax.axis_index("part")
                part_ids = pid * ppn + jnp.arange(ppn, dtype=jnp.int32)
                return repl.replay_index_rounds(idx, kinds, delta, iwrite,
                                                tids, part_ids=part_ids,
                                                kernel=kernel)

            def sm_idx_replay_sec(idx, kinds, delta, iwrite, tids):
                pid = jax.lax.axis_index("part")
                part_ids = jnp.mod(
                    pid * ppn + jnp.arange(ppn, dtype=jnp.int32) - ppn,
                    self.P)
                return repl.replay_index_rounds(idx, kinds, delta, iwrite,
                                                tids, part_ids=part_ids,
                                                kernel=kernel)

            bspecs = (idx_spec, P(), P(), P(), P())
            self._sm_idx_replay = jax.jit(shard_map(
                sm_idx_replay, mesh, in_specs=bspecs, out_specs=idx_spec))
            self._sm_idx_replay_sec = jax.jit(shard_map(
                sm_idx_replay_sec, mesh, in_specs=bspecs,
                out_specs=idx_spec))

    # ------------------------------------------------------------------
    @property
    def _slab_hwm(self) -> int:
        """In-flight slabs the subscribers already consumed (changelog
        high-watermark; kept as a property for the runtime/tests)."""
        return self.changelog.slab_hwm

    @property
    def slab_ledger(self) -> list:
        """Committed (epoch, slab) ledger — owned by the changelog."""
        return self.changelog.ledger

    def committed_state(self):
        """(val, tid) of the committed full-replica snapshot — the seed
        state changelog subscribers (MVs, analytics) reset from."""
        return self._snap["full_val"], self._snap["full_tid"]

    def _slab_bounds(self, T: int):
        return self.changelog.slab_bounds(T)

    # ------------------------------------------------------------------
    def run_epoch(self, batch, ingest=None, commit=True,
                  abort_check=None) -> dict:
        """StarEngine-compatible epoch: slab-streamed partitioned phase
        (sharded, zero collectives; each slab's op stream ships to the
        replicas while the next slab executes), psum fence waiting only on
        the tail slab, single-master phase on the full copy, value +
        index-stream scatter-back, epoch fence + two-version snapshot.

        ingest: optional zero-arg callable overlapped with the partitioned
        phase's device execution (double-buffered host batch formation).
        commit=False runs the phases up TO the epoch fence but never
        commits — the cluster runtime uses it for an epoch whose fence a
        failed node will miss: everything the phases wrote (including the
        stream slabs the replicas already consumed, via the slab
        high-watermark) is discarded by the §4.5 revert.
        abort_check: optional callable(slab_idx) -> bool polled after each
        slab's execution dispatch; returning True at slab s kills the
        epoch mid-stream (a node died during the phase) with slabs
        0..s-1 already shipped: remaining slabs never execute or ship."""
        tr = obs.get_tracer()
        t_ep0 = time.perf_counter()
        epoch_u = jnp.uint32(self.epoch)
        ptxn = jax.tree.map(jnp.asarray, _pad_pow2(batch["ptxn"], 1))
        cross = jax.tree.map(jnp.asarray, _pad_pow2(batch["cross"], 0))

        # ---- partitioned phase: slab-chained execution + streaming ------
        T = ptxn["row"].shape[1]
        bounds = self._slab_bounds(T)
        S = len(bounds) - 1
        t0 = time.perf_counter()
        pv, pt, pidx, seq = (self.part_val, self.part_tid, self.part_idx,
                             self._seq0)
        slab_logs, committed_chunks, counts = [], [], None
        aborted_at = None
        for s in range(S):
            slab = jax.tree.map(lambda a: a[:, bounds[s]:bounds[s + 1]],
                                ptxn)
            with tr.span("cluster.slab_execute", cat="phase",
                         epoch=self.epoch, slab=s,
                         txns=bounds[s + 1] - bounds[s]):
                pv, pt, pidx, seq, log, comm, extras = self._part(
                    pv, pt, pidx, seq, slab, epoch_u)
            if s > 0:
                # previous slab's stream ships while THIS slab executes
                self.changelog.publish_slab(slab_logs[s - 1], self.epoch)
            slab_logs.append(log)
            committed_chunks.append(comm)
            counts = extras if counts is None else counts + extras
            if abort_check is not None and abort_check(s):
                aborted_at = s
                break
        t_ingest = 0.0
        if ingest is not None:       # overlap host ingest with device exec
            ti = time.perf_counter()
            ingest()
            t_ingest = time.perf_counter() - ti
            tr.complete("service.ingest_overlap", "service", ti,
                        ti + t_ingest, epoch=self.epoch)
        tb = time.perf_counter()
        jax.block_until_ready(pv)
        t1 = time.perf_counter()
        t_part = max(t1 - t0 - t_ingest, t1 - tb)
        tr.complete("engine.partitioned", "phase", t0, t1,
                    epoch=self.epoch, slabs=S)
        self.part_val, self.part_tid, self.part_idx = pv, pt, pidx

        if aborted_at is not None:
            # mid-stream death: the epoch can never commit; the caller
            # reverts, which discards the slabs already consumed
            return {"aborted_at_slab": aborted_at,
                    "slabs_executed": aborted_at + 1,
                    "slabs_consumed": self._slab_hwm}

        # ---- tail ship: the ONLY stream transfer the fence waits on -----
        with tr.span("fence.tail_ship", cat="fence", epoch=self.epoch,
                     slab=S - 1):
            self.changelog.publish_slab(slab_logs[-1], self.epoch)
        plog = self.changelog.epoch_plog()
        p_committed = (committed_chunks[0] if S == 1 else
                       jnp.concatenate(committed_chunks, axis=1))

        # ---- stream byte attribution (the changelog's single source) ----
        vb = 0
        attr = self.changelog.attribute(batch, plog, self.has_index,
                                        lambda a: _pad_pow2(a, 1))
        vb_alt, slab_bytes, ib = (attr.value_bytes_alt, attr.slab_bytes,
                                  attr.index_op_bytes)
        ob = attr.total
        ob_head, ob_tail = attr.overlapped, attr.fence

        # ---- fence 1 (commit-statistics psum barrier) --------------------
        tf0 = time.perf_counter()
        node_counts = self._fence_barrier(
            jnp.asarray(counts[:, 0], jnp.int32))
        n_single = int(node_counts[0])
        tr.complete("fence.psum", "fence", tf0, time.perf_counter(),
                    epoch=self.epoch, tail_bytes=ob_tail)
        # modeled network: the tail slab drains inside the fence; the head
        # slabs shipped during execution and surface only as un-hidden
        # residue (paper: "negligible" — now measurable instead of assumed)
        t_net1 = repl.fence_net_seconds(self.net, ob_tail, ob_head, t_part)
        t_fence1 = time.perf_counter()

        # ---- single-master phase on the full copy ------------------------
        # B from the RAW batch: padding turns an empty cross batch into 1-2
        # invalid lanes, which would run the full OCC program for nothing
        # (service batches always carry fixed non-zero lane counts)
        t0 = time.perf_counter()
        B = int(batch["cross"]["row"].shape[0])
        slog = None
        ib_sm = 0
        if B > 0:
            flat_v = self.full_val.reshape(self.P * self.R, self.C)
            flat_t = self.full_tid.reshape(self.P * self.R)
            fv, ft, out, sstats = self._sm(flat_v, flat_t, self.full_idx,
                                           cross, epoch_u)
            jax.block_until_ready(fv)
            n_cross = int(sstats["committed"])
            self.full_val = fv.reshape(self.P, self.R, self.C)
            self.full_tid = ft.reshape(self.P, self.R)
            if self.has_index:
                self.full_idx = out["index"]
            # publish the master stream: the subscriber value-replicates
            # the writes back to partition owners and secondary homes (the
            # device_put broadcast is the value-stream ship, §5) and
            # replays the index-op rounds on every partial copy
            slog = out["log"]
            self.changelog.publish_master(slog, kinds=cross["kind"],
                                          delta=cross["delta"])
            if self.has_index:
                ib_sm = repl.index_op_bytes(slog["iwrite"])
            if "c_row_bytes" in batch:
                cw = np.asarray(slog["write"])
                crb = np.broadcast_to(_pad_pow2(batch["c_row_bytes"], 0),
                                      cw.shape[1:])
                vb = int(repl.value_bytes(cw, crb[None]))
            elif batch.get("row_bytes") is not None:
                vb = int(repl.value_bytes(np.asarray(slog["write"]),
                                          batch["row_bytes"][None, None, :]))
            c_committed = np.asarray(out["committed"])
            starved = int(sstats["starved"])
            retries = int(sstats["retries"])
            aborts = int(sstats["user_aborts"])
            sm_skips = int(sstats.get("consume_skips", 0))
            sm_overflow = int(sstats.get("index_overflow", 0))
        else:
            n_cross = starved = retries = aborts = 0
            sm_skips = sm_overflow = 0
            c_committed = np.zeros(0, bool)
        t_sm = time.perf_counter() - t0
        t_sm_round = t_sm / self.max_rounds if B > 0 else 0.0
        tr.complete("engine.single_master", "phase", t0, t0 + t_sm,
                    epoch=self.epoch, rounds=self.max_rounds if B else 0)
        if tr.enabled and B > 0:
            # rounds execute inside ONE jitted call; attribute the measured
            # phase time evenly (the same t_sm_round fig11/fig13 report)
            for r in range(self.max_rounds):
                tr.complete("engine.sm_round", "phase",
                            t0 + r * t_sm_round, t0 + (r + 1) * t_sm_round,
                            epoch=self.epoch, round=r)

        # ---- fence 2: epoch boundary + two-version snapshot --------------
        # the fence's contract is "every outstanding stream applied": wait
        # for the tail replay and the value scatter-back HERE (their time
        # is fence time) — otherwise the master device's replay backlog
        # silently delays the NEXT epoch's partitioned phase
        tf2 = time.perf_counter()
        jax.block_until_ready((self.full_val, self.part_val))
        tr.complete("fence.replay_drain", "fence", tf2,
                    time.perf_counter(), epoch=self.epoch)
        t_net2 = repl.fence_net_seconds(self.net, vb + ib_sm)
        p_committed = np.asarray(p_committed)                  # (P, T)
        node_c = p_committed.sum(1).reshape(self.n_nodes, -1).sum(1)
        # modeled fence wait: the slowest node's phase time sets the fence;
        # a node's own busy time is proxied by its committed share
        cmax = int(node_c.max()) if node_c.size else 0
        wait = (t_part * (1.0 - node_c / cmax) if cmax > 0
                else np.zeros(self.n_nodes))
        tau_p = tau_s = 0.0
        counts_h = np.asarray(counts)
        n_skips = int(counts_h[:, 1].sum()) + sm_skips
        n_overflow = int(counts_h[:, 2].sum()) + sm_overflow
        # partitioned-phase user aborts count too (StarEngine parity)
        aborts += int(counts_h[:, 3].sum())
        if commit:
            self.snapshot_commit()
            self.epoch += 1
            self.node_committed += node_c
            self.node_fence_wait_s += wait
            self.controller.observe_fence_wait(float(wait.max()) * 1e3)
            self.controller.observe("partitioned", n_single, t_part)
            self.controller.observe("single", n_cross, t_sm,
                                    frac_cross=n_cross
                                    / max(n_cross + n_single, 1))
            tau_p, tau_s = self.controller.plan()
        t_fence2 = time.perf_counter()
        tr.complete("engine.fence", "fence", tf2, t_fence2, which=2,
                    epoch=self.epoch - (1 if commit else 0), commit=commit)
        if commit:
            s = self.stats
            s.epochs += 1
            s.committed_single += n_single
            s.committed_cross += n_cross
            s.user_aborts += aborts
            s.consume_skips += n_skips
            s.index_overflow += n_overflow
            s.retries += retries
            s.part_time_s += t_part
            s.sm_time_s += t_sm
            s.sm_rounds += self.max_rounds if B > 0 else 0
            s.fences += 2
            s.fence_time_s += (t_fence1 - tf0) + (t_fence2 - tf2)
            s.fence_net_s += t_net1 + t_net2
            s.value_bytes += vb
            s.op_bytes_hybrid += ob
            s.value_bytes_if_not_hybrid += vb_alt
            s.index_op_bytes += ib + ib_sm
            s.op_bytes_overlapped += ob_head
            s.op_bytes_fence += ob_tail

        m = {"committed_single": n_single, "committed_cross": n_cross,
             "tau_p_ms": tau_p, "tau_s_ms": tau_s,
             "t_part_s": t_part, "t_sm_s": t_sm,
             "t_sm_round_s": t_sm_round, "t_ingest_s": t_ingest,
             "t_fence1_s": t_fence1, "t_fence2_s": t_fence2,
             "t_fence_net_s": t_net1 + t_net2,
             "op_bytes_overlapped": ob_head, "op_bytes_fence": ob_tail,
             "slabs": S,
             "p_committed": p_committed, "c_committed": c_committed,
             "index_overflow": n_overflow,
             "starved": starved,
             "node_committed": node_c,
             "node_fence_wait_s": wait}
        if self.has_index:
            m["p_cskip"] = np.asarray(plog["cskip"])           # (P, T, K)
            m["c_cskip"] = (np.asarray(slog["cskip"]).any(0)
                            if B > 0 else None)                # (B_pad, K)
        tr.complete("engine.epoch", "epoch", t_ep0, time.perf_counter(),
                    epoch=self.epoch - (1 if commit else 0),
                    committed=n_single + n_cross, commit=commit)
        return m

    # ------------------------------------------------------------------
    # two-version snapshots + node-granular state surgery (§4.5)
    # ------------------------------------------------------------------
    def _state(self):
        st = {"part_val": self.part_val, "part_tid": self.part_tid,
              "full_val": self.full_val, "full_tid": self.full_tid,
              "part_idx": self.part_idx, "full_idx": self.full_idx}
        if self.secondary:
            st.update({"sec_val": self.sec_val, "sec_tid": self.sec_tid,
                       "sec_idx": self.sec_idx})
        return st

    def _load_state(self, st):
        self.part_val, self.part_tid = st["part_val"], st["part_tid"]
        self.full_val, self.full_tid = st["full_val"], st["full_tid"]
        self.part_idx, self.full_idx = st["part_idx"], st["full_idx"]
        if self.secondary:
            self.sec_val, self.sec_tid = st["sec_val"], st["sec_tid"]
            self.sec_idx = st["sec_idx"]

    def snapshot_commit(self):
        self._snap = self._state()
        self.committed_epoch = self.epoch
        # the in-flight slabs are now committed state: the changelog
        # retires them into its ledger and fires on_commit (WAL sink, MV
        # stamping) inside the fence.  slabs_shipped counts COMMITTED
        # slabs only, so it stays consistent with the committed-epoch
        # byte split — warm-up and doomed epochs' ships land in
        # slabs_discarded instead
        shipped, dropped = self.changelog.commit(self.epoch)
        self.stats.slabs_shipped += shipped
        self.stats.ledger_dropped += dropped

    def revert_to_snapshot(self):
        """Discard the in-flight epoch on every replica (two-version
        records, §4.5.2) — including every stream slab the subscribers
        consumed mid-phase (changelog revert: the re-executed epoch
        re-publishes from slab 0 onto the reverted base, so each slab
        applies to committed state exactly once)."""
        self._load_state(self._snap)
        self.stats.slabs_discarded += self.changelog.revert(self.epoch)

    def node_slice(self, node: int) -> slice:
        return slice(node * self.ppn, (node + 1) * self.ppn)

    def sec_home(self, node: int) -> int:
        """The node holding the physical secondary copy of ``node``'s
        block (round-robin: the next node)."""
        return (node + 1) % self.n_nodes

    def read_views(self):
        """Committed snapshot views for the read tier's SnapshotCatalog —
        one per physical replica copy: the master's full copy (covers
        every partition, identity row mapping) and each node's hosted
        secondary block (home-major rolled layout: partition p lives at
        array row (p + ppn) mod P; node m's view covers node m-1's
        partitions).  Always the COMMITTED two-version snapshot, so an
        in-flight or reverted epoch is never visible to a read."""
        wm = self.changelog.watermark(self.committed_epoch)
        P = self.P
        views = [{
            "id": "full", "kind": "full", "node": 0,
            "epoch": self.committed_epoch, "watermark": wm,
            "cover": np.ones(P, bool),
            "row_of_partition": np.arange(P, dtype=np.int64),
            "val": self._snap["full_val"], "tid": self._snap["full_tid"],
            "idx": self._snap["full_idx"],
        }]
        if self.secondary:
            rop = (np.arange(P, dtype=np.int64) + self.ppn) % P
            for m in range(self.n_nodes):
                owner = (m - 1) % self.n_nodes
                cover = np.zeros(P, bool)
                cover[self.node_slice(owner)] = True
                views.append({
                    "id": f"sec{m}", "kind": "secondary", "node": m,
                    "epoch": self.committed_epoch, "watermark": wm,
                    "cover": cover, "row_of_partition": rop,
                    "val": self._snap["sec_val"],
                    "tid": self._snap["sec_tid"],
                    "idx": self._snap["sec_idx"],
                })
        return views

    @staticmethod
    def _scribble_tree(tree, sl):
        def scrib(a):
            junk = (jnp.uint32(0xDEAD) if a.dtype == jnp.uint32
                    else jnp.int32(-0x5A5A5A5).astype(a.dtype))
            return a.at[sl].set(junk)
        return jax.tree.map(scrib, tree)

    def scribble_node(self, node: int):
        """Simulate the node's memory dying with it: its primary partition
        block AND the secondary copy it hosted (of its predecessor's
        block), in BOTH the working state and the snapshot — so recovery
        is only correct if it really restores from a surviving source
        (secondary home, full replica, or disk)."""
        sl = self.node_slice(node)
        snap = dict(self._snap)
        names = ["part_val", "part_tid", "part_idx"]
        if self.secondary:
            names += ["sec_val", "sec_tid", "sec_idx"]
        for name in names:
            setattr(self, name, self._scribble_tree(getattr(self, name), sl))
            snap[name] = self._scribble_tree(snap[name], sl)
        self._snap = snap

    def scribble_full(self):
        """Simulate loss of every full replica (all f holders dead)."""
        sl = slice(None)
        snap = dict(self._snap)
        for name in ("full_val", "full_tid", "full_idx"):
            setattr(self, name, self._scribble_tree(getattr(self, name), sl))
            snap[name] = self._scribble_tree(snap[name], sl)
        self._snap = snap

    # -- recovery-time restores (all from the COMMITTED snapshot) --------
    def _restore_blocks(self, nodes, src_val_key: str, src_tid_key: str,
                        src_idx_key: str, src_slice_fn):
        """Rebuild the nodes' primary partition blocks (records + index
        segments) from a surviving source in the committed snapshot, make
        that the committed version everywhere, and resync the rejoining
        secondary homes.  (Recovery path: the copy goes through the host —
        source and destination live on different devices.)"""
        snap = dict(self._snap)
        pv = np.asarray(snap["part_val"]).copy()
        pt = np.asarray(snap["part_tid"]).copy()
        sv = np.asarray(snap[src_val_key])
        st = np.asarray(snap[src_tid_key])
        pidx = jax.tree.map(lambda a: np.asarray(a).copy(),
                            snap["part_idx"])
        sidx = jax.tree.map(np.asarray, snap[src_idx_key])
        for n in nodes:
            sl = self.node_slice(n)
            ssl = src_slice_fn(n)
            pv[sl] = sv[ssl]
            pt[sl] = st[ssl]
            for pi, si in zip(pidx, sidx):
                for k in ("key", "prow", "tid"):
                    pi[k][sl] = si[k][ssl]
        snap["part_val"] = jax.device_put(jnp.asarray(pv), self._shard)
        snap["part_tid"] = jax.device_put(jnp.asarray(pt), self._shard)
        snap["part_idx"] = jax.device_put(
            jax.tree.map(jnp.asarray, pidx), self._shard)
        self._snap = snap
        self._resync_secondary()
        self._load_state(self._snap)

    def restore_nodes_from_full(self, nodes):
        """§4.5.3 case-1/3 donor copy: rebuild the nodes' partition blocks
        from the (surviving) full replica's committed snapshot, then make
        that the nodes' own committed version."""
        self._restore_blocks(nodes, "full_val", "full_tid", "full_idx",
                             self.node_slice)

    def restore_blocks_from_secondary(self, nodes):
        """The actual surviving-copy restore (replaces the old
        committed-snapshot stand-in): a dead node's primary block is
        rebuilt from the PHYSICAL secondary copy its neighbor holds —
        the copy itself, not an un-scribbled convenience alias.  Block
        n's secondary copy sits in its sec home's slice rows."""
        assert self.secondary, "no physical secondary replicas configured"
        self._restore_blocks(nodes, "sec_val", "sec_tid", "sec_idx",
                             lambda n: self.node_slice(self.sec_home(n)))

    def rebuild_full_from_partials(self):
        """§4.5.3 case 2: every partition still has a live partial copy
        but no full replica survives — re-replicate a full copy by
        gathering the committed partial set (the bootstrap all-gather,
        again), index segments included."""
        snap = dict(self._snap)
        fv = jax.device_put(jnp.asarray(snap["part_val"]), self._master_dev)
        ft = jax.device_put(jnp.asarray(snap["part_tid"]), self._master_dev)
        snap["full_val"], snap["full_tid"] = fv, ft
        snap["full_idx"] = jax.device_put(
            jax.tree.map(jnp.asarray, snap["part_idx"]), self._master_dev)
        self._snap = snap
        self._resync_secondary()
        self._load_state(self._snap)

    def _resync_secondary(self):
        """§4.5.3 catch-up for rejoining secondary homes: rebuild the
        home-major secondary arrays from the committed primary set (the
        recovering node re-copies its hosted block)."""
        if not self.secondary:
            return
        snap = dict(self._snap)
        snap["sec_val"] = jax.device_put(
            self._roll_home(snap["part_val"]), self._shard)
        snap["sec_tid"] = jax.device_put(
            self._roll_home(snap["part_tid"]), self._shard)
        snap["sec_idx"] = jax.device_put(
            self._roll_home(snap["part_idx"]), self._shard)
        self._snap = snap

    def load_committed(self, val, tid, indexes=None):
        """§4.5.1 UNAVAILABLE reload: install a recovered committed state
        (checkpoint + replayed logs, index segments included) on every
        replica."""
        val = jnp.asarray(val, jnp.int32).reshape(self.P, self.R, self.C)
        tid = jnp.asarray(tid, jnp.uint32).reshape(self.P, self.R)
        self.part_val = jax.device_put(val, self._shard)
        self.part_tid = jax.device_put(tid, self._shard)
        self.full_val = jax.device_put(val, self._master_dev)
        self.full_tid = jax.device_put(tid, self._master_dev)
        if self.has_index:
            # a recovered state MUST carry index arrays — silently keeping
            # the (scribbled) in-memory segments would commit garbage
            assert indexes is not None, \
                "recovery returned no index arrays for an index engine " \
                "(checkpoint predates index durability?)"
            assert len(indexes) == len(self.index_specs), \
                (len(indexes), len(self.index_specs))
            idx = [{k: jnp.asarray(ix[k]) for k in ("key", "prow", "tid")}
                   for ix in indexes]
            self.part_idx = jax.device_put(idx, self._shard)
            self.full_idx = jax.device_put(idx, self._master_dev)
        if self.secondary:
            self.sec_val = jax.device_put(self._roll_home(val),
                                          self._shard)
            self.sec_tid = jax.device_put(self._roll_home(tid),
                                          self._shard)
            self.sec_idx = jax.device_put(self._roll_home(self.part_idx),
                                          self._shard)
        # the reloaded state is the LAST COMMITTED epoch's — the in-flight
        # epoch (self.epoch) re-executes on top of it after recovery.
        # Deliberately NOT a changelog.commit: a commit here would hand
        # the WAL sink epoch-(e-1) state labeled epoch e, and epoch e's
        # index ops (replayed strictly-after e_c) would be lost on the
        # next recovery.  The stream history is gone — subscribers reset
        # from the recovered arrays instead.
        self._snap = self._state()
        self.committed_epoch = self.epoch - 1
        self.changelog.reset_from_state(val, tid, self.committed_epoch)

    # ------------------------------------------------------------------
    def consistent(self) -> bool:
        """Partial replicas (sharded) == full replica (master copy) ==
        physical secondary copies (rolled home-major layout), records AND
        every index segment."""
        pv = np.asarray(self.part_val)
        fv = np.asarray(self.full_val)
        pt = np.asarray(self.part_tid)
        ft = np.asarray(self.full_tid)
        if not (np.array_equal(pv, fv) and np.array_equal(pt, ft)):
            return False
        for pi, fi in zip(self.part_idx, self.full_idx):
            for k in ("key", "prow", "tid"):
                if not np.array_equal(np.asarray(pi[k]), np.asarray(fi[k])):
                    return False
        if self.secondary:
            if not (np.array_equal(
                        np.asarray(self._roll_home(self.part_val)),
                        np.asarray(self.sec_val))
                    and np.array_equal(
                        np.asarray(self._roll_home(self.part_tid)),
                        np.asarray(self.sec_tid))):
                return False
            for pi, si in zip(self.part_idx, self.sec_idx):
                for k in ("key", "prow", "tid"):
                    if not np.array_equal(
                            np.asarray(self._roll_home(pi[k])),
                            np.asarray(si[k])):
                        return False
        return True

    def partitioned_phase_has_no_collectives(self, batch) -> bool:
        """Compile-time proof of the §4.1 zero-coordination claim."""
        ptxn = jax.tree.map(jnp.asarray, _pad_pow2(batch["ptxn"], 1))
        T = ptxn["row"].shape[1]
        bounds = self._slab_bounds(T)
        slab = jax.tree.map(lambda a: a[:, bounds[0]:bounds[1]], ptxn)
        txt = self._part.lower(self.part_val, self.part_tid, self.part_idx,
                               self._seq0, slab,
                               jnp.uint32(1)).compile().as_text()
        return not any(op in txt for op in
                       ("all-reduce(", "all-gather(", "collective-permute(",
                        "all-to-all(", "reduce-scatter("))
