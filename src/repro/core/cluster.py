"""Distributed STAR engine on a device mesh (shard_map over partitions).

The single-process :class:`repro.core.engine.StarEngine` validates protocol
semantics; this module is the *cluster* form — the shape that runs on real
hardware:

* database partitions sharded over a 1-D ``part`` mesh axis (one device ==
  one paper "node" holding its partition = the partial replicas);
* **partitioned phase**: ``shard_map`` with NO collectives inside — each
  device runs its partition's queue serially (H-Store semantics), exactly
  the paper's zero-coordination claim, verified by asserting the phase's
  HLO contains no collective ops;
* **replication fence**: a ``psum`` barrier carrying the per-device commit
  counters — the §4.3 statistics exchange — after which the full replica
  (the master's complete copy, all-gathered once at bootstrap and kept
  consistent by the streams) is updated;
* **single-master phase**: the designated master executes cross-partition
  transactions on its full copy (no 2PC — the paper's core claim), then the
  write stream is scattered back to the partition owners with the Thomas
  write rule.

On this host the mesh axes are 1-8 forced CPU devices (tests); the same
code paths lower for a TPU slice.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import replication as repl
from repro.core.partitioned import run_partitioned
from repro.core.single_master import run_single_master


class ClusterStarEngine:
    """f=1 full replica (the master's complete copy) + k partial replicas
    (the sharded primary partitions)."""

    def __init__(self, mesh, n_partitions: int, rows_per_partition: int,
                 n_cols: int = 10, init_val=None, max_rounds: int = 16):
        assert "part" in mesh.axis_names
        self.mesh = mesh
        self.P, self.R, self.C = n_partitions, rows_per_partition, n_cols
        val = (jnp.asarray(init_val, jnp.int32) if init_val is not None
               else jnp.zeros((self.P, self.R, self.C), jnp.int32))
        tid = jnp.zeros((self.P, self.R), jnp.uint32)
        shard = NamedSharding(mesh, P("part"))
        # partial replicas: partition-sharded primary copy
        self.part_val = jax.device_put(val, shard)
        self.part_tid = jax.device_put(tid, shard)
        # full replica (master's complete copy) — replicated
        full = NamedSharding(mesh, P())
        self.full_val = jax.device_put(val, full)
        self.full_tid = jax.device_put(tid, full)
        self.epoch = 1
        self.max_rounds = max_rounds
        self._build()

    def _build(self):
        mesh, Pn = self.mesh, self.P

        def part_phase(val, tid, ptxn, epoch):
            # NO collectives inside: single-partition txns need none (§4.1)
            v, t, out, stats = run_partitioned(val, tid, ptxn, epoch)
            return v, t, out["log"], stats["committed"][None]

        pspec = P("part")
        txn_spec = {k: P("part") for k in
                    ("valid", "row", "kind", "delta", "user_abort")}
        self._part = jax.jit(shard_map(
            part_phase, mesh,
            in_specs=(pspec, pspec, txn_spec, P()),
            out_specs=(pspec, pspec,
                       {k: P("part") for k in
                        ("row", "val", "tid", "write", "kind", "delta")},
                       P("part"))))

        def fence(commit_counts):
            # §4.3: nodes exchange commit statistics; the psum is the barrier
            return jax.lax.psum(commit_counts, "part")

        self._fence = jax.jit(shard_map(
            fence, mesh, in_specs=(P("part"),), out_specs=P()))

        # single-master phase runs on the replicated full copy (master's
        # view); jit with replicated shardings — no 2PC, no cross-device
        # coordination during execution
        self._sm = jax.jit(
            lambda v, t, txns, epoch: run_single_master(
                v, t, txns, epoch, max_rounds=self.max_rounds),
            static_argnames=())

        self._thomas_flat = jax.jit(repl.thomas_apply_batch)

        def scatter_back(part_val, part_tid, rows, vals, tids):
            """Apply the master's write stream to the partition owners:
            each device filters the global stream to its own row range."""
            pid = jax.lax.axis_index("part")
            lo = pid * self.R
            local = (rows >= lo) & (rows < lo + self.R)
            lrows = jnp.where(local, rows - lo, -1)
            v, t, _ = repl.thomas_apply(part_val[0], part_tid[0], lrows,
                                        vals, tids)
            return v[None], t[None]

        self._scatter = jax.jit(shard_map(
            scatter_back, mesh,
            in_specs=(pspec, pspec, P(), P(), P()),
            out_specs=(pspec, pspec)))

    # ------------------------------------------------------------------
    def run_epoch(self, batch) -> dict:
        epoch_u = jnp.uint32(self.epoch)
        ptxn = jax.tree.map(jnp.asarray, batch["ptxn"])
        cross = jax.tree.map(jnp.asarray, batch["cross"])

        # ---- partitioned phase (no collectives) -------------------------
        self.part_val, self.part_tid, log, committed = self._part(
            self.part_val, self.part_tid, ptxn, epoch_u)
        # replicate the ordered op streams to the full replica (hybrid: the
        # partitioned phase ships operations, §5)
        fv, ft = jax.vmap(repl.replay_operations)(
            jnp.asarray(self.full_val), jnp.asarray(self.full_tid), log)
        self.full_val, self.full_tid = fv, ft

        # ---- fence 1 (commit-statistics barrier) ------------------------
        n_single = int(self._fence(committed)[0])

        # ---- single-master phase on the full copy ------------------------
        n_cross = 0
        if cross["row"].shape[0] > 0:
            flat_v = self.full_val.reshape(self.P * self.R, self.C)
            flat_t = self.full_tid.reshape(self.P * self.R)
            fv, ft, out, stats = self._sm(flat_v, flat_t, cross, epoch_u)
            n_cross = int(stats["committed"])
            self.full_val = fv.reshape(self.P, self.R, self.C)
            self.full_tid = ft.reshape(self.P, self.R)
            # value-replicate the master's writes back to partition owners
            w = out["log"]["write"].reshape(-1)
            rows = jnp.where(w, out["log"]["row"].reshape(-1), -1)
            vals = out["log"]["val"].reshape(-1, self.C)
            tids = out["log"]["tid"].reshape(-1)
            self.part_val, self.part_tid = self._scatter(
                self.part_val, self.part_tid, rows, vals, tids)

        # ---- fence 2: epoch boundary -------------------------------------
        self.epoch += 1
        return {"committed_single": n_single, "committed_cross": n_cross}

    # ------------------------------------------------------------------
    def consistent(self) -> bool:
        """Partial replicas (sharded) == full replica (master copy)."""
        pv = np.asarray(self.part_val)
        fv = np.asarray(self.full_val)
        pt = np.asarray(self.part_tid)
        ft = np.asarray(self.full_tid)
        return bool(np.array_equal(pv, fv) and np.array_equal(pt, ft))

    def partitioned_phase_has_no_collectives(self, batch) -> bool:
        """Compile-time proof of the §4.1 zero-coordination claim."""
        ptxn = jax.tree.map(jnp.asarray, batch["ptxn"])
        txt = self._part.lower(self.part_val, self.part_tid, ptxn,
                               jnp.uint32(1)).compile().as_text()
        return not any(op in txt for op in
                       ("all-reduce(", "all-gather(", "collective-permute(",
                        "all-to-all(", "reduce-scatter("))
