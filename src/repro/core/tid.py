"""Silo-style TID words (§3 of the paper).

A TID is a uint32:  [ epoch : 8 | sequence : 23 | lock : 1 ].

Criteria for a committing transaction's TID (paper §3):
  (a) larger than the TID of any record in its read/write set,
  (b) larger than the worker's last chosen TID,
  (c) in the current global epoch.

The lock bit lives in the LSB so `tid > other` comparisons order first by
epoch, then sequence — exactly the serial-equivalent order the Thomas write
rule needs.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

EPOCH_BITS = 8
SEQ_BITS = 23
# numpy scalars (not jnp arrays): they trace as literals, so this module's
# functions can run inside Pallas kernel bodies (which reject captured
# device-array constants) — bit-identical arithmetic either way
LOCK_MASK = np.uint32(1)
SEQ_SHIFT = 1
EPOCH_SHIFT = 1 + SEQ_BITS
SEQ_MASK = np.uint32((1 << SEQ_BITS) - 1)
EPOCH_MASK = np.uint32((1 << EPOCH_BITS) - 1)


def make_tid(epoch, seq, locked=False):
    epoch = jnp.asarray(epoch, jnp.uint32)
    seq = jnp.asarray(seq, jnp.uint32)
    t = (epoch << EPOCH_SHIFT) | (seq << SEQ_SHIFT)
    return t | LOCK_MASK if locked else t


def tid_epoch(tid):
    return (jnp.asarray(tid, jnp.uint32) >> EPOCH_SHIFT) & EPOCH_MASK


def tid_seq(tid):
    return (jnp.asarray(tid, jnp.uint32) >> SEQ_SHIFT) & SEQ_MASK


def tid_locked(tid):
    return (jnp.asarray(tid, jnp.uint32) & LOCK_MASK) != 0


def tid_lock(tid):
    return jnp.asarray(tid, jnp.uint32) | LOCK_MASK


def tid_unlock(tid):
    return jnp.asarray(tid, jnp.uint32) & ~LOCK_MASK


def next_tid(epoch, observed_max_tid, last_tid):
    """TID satisfying (a), (b), (c): seq = max(observed, last)+1 in `epoch`.
    TIDs from other epochs contribute seq 0 (epoch bits already dominate the
    ordering, so criterion (a) holds whenever obs is from an epoch <= ours)."""
    e = jnp.asarray(epoch, jnp.uint32)

    def seq_in_epoch(t):
        t = tid_unlock(t)
        return jnp.where(tid_epoch(t) == e, tid_seq(t), np.uint32(0))

    seq = jnp.maximum(seq_in_epoch(observed_max_tid),
                      seq_in_epoch(last_tid)) + np.uint32(1)
    return make_tid(e, seq)
