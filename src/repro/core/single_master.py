"""Single-master phase executor (§4.2): vectorized Silo-variant OCC.

A batch of B transactions runs as B parallel "lanes" (the TPU-native analogue
of Silo worker threads).  Rounds proceed over a shared snapshot:

  read      — gather values + TIDs for every op; range-scan ops additionally
              gather their index window (SCAN_L slots + the next-key slot);
  lock      — writers claim rows via scatter-min of lane id (a deterministic
              global lock order — the paper locks in address order to avoid
              deadlock; lane-id order is our equivalent).  Index writers
              claim the *insertion/deletion position slot* in the same lock
              array (rows and index slots share one flat address space), so
              an insert into a range claims a slot every concurrent scanner
              of that range has in its read set: next-key locking;
  validate  — Silo read validation: a lane aborts (retries next round) if any
              row OR scanned index slot it accessed is claimed by an earlier
              lane this round (§4.2).  This is exactly the paper's read-set
              TID check extended with range (phantom) protection.
              SCAN_CONSUME ops additionally require the first live key of
              their range to equal the declared EXPECT key;
  install   — winners draw TIDs satisfying criteria (a)(b)(c), scatter
              post-images + TIDs, and merge their index maintenance
              (inserts/deletes/consumes) via storage.index.apply_index_ops.

With ``deterministic=True`` the same machinery becomes the Calvin baseline:
lock-order is the pre-assigned global order and read validation is skipped
(deterministic execution never aborts; §7.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tid as tidlib
from repro.core.ops import (IDX_OPS, IX_EXPECT, IX_HI, IX_ID, IX_LO,
                            SCAN_CONSUME, apply_op, is_index_kind,
                            reads_index, resolve_op_guards, writes_index,
                            writes_primary)
from repro.storage.index import SCAN_L, SENTINEL, apply_index_ops, \
    key_partition


def _locate_index_ops(index, kinds, delta, n_rows):
    """Resolve index/scan ops of one round against the current index state.

    kinds: (B, K) int32; delta: (B, K, C).  Returns per-op claim addresses,
    scan-window addresses/validity, gathered TIDs and the first in-range key
    (consume validation), all in the flat row+index address space
    [0, n_rows + sum(P * cap_i)) with `no_addr` = the dump slot.
    """
    B, K = kinds.shape
    P = index[0]["key"].shape[0]
    caps = [idx["key"].shape[1] for idx in index]
    no_addr = n_rows + sum(P * c for c in caps)

    lo = delta[..., IX_LO]                                     # (B, K)
    hi = delta[..., IX_HI]
    iid = delta[..., IX_ID]
    p_of = jnp.clip(key_partition(lo), 0, P - 1)

    is_idx = is_index_kind(kinds)
    claim_addr = jnp.full((B, K), no_addr, jnp.int32)
    claim_tid = jnp.zeros((B, K), jnp.uint32)
    scan_addr = jnp.full((B, K, SCAN_L + 1), no_addr, jnp.int32)
    scan_tid = jnp.zeros((B, K, SCAN_L + 1), jnp.uint32)
    scan_valid = jnp.zeros((B, K, SCAN_L + 1), bool)
    first_key = jnp.full((B, K), SENTINEL, jnp.int32)

    base = n_rows
    ss = jax.vmap(jax.vmap(jnp.searchsorted))
    for i, idx in enumerate(index):
        cap = caps[i]
        mine = is_idx & (iid == i)
        p_g = jnp.where(mine, p_of, 0)
        segk = idx["key"][p_g]                                 # (B, K, cap)
        segt = idx["tid"][p_g]
        pos0 = ss(segk, lo)                                    # (B, K)
        window = pos0[..., None] + jnp.arange(SCAN_L + 1, dtype=jnp.int32)
        slots = jnp.clip(window, 0, cap - 1)
        keys_at = jnp.take_along_axis(segk, slots, axis=-1)    # (B, K, L+1)
        tids_at = jnp.take_along_axis(segt, slots, axis=-1)
        addr0 = base + p_of * cap
        # claim the position slot (insert/delete/consume): next-key locking
        cmask = mine & writes_index(kinds)
        cpos = jnp.clip(pos0, 0, cap - 1)
        claim_addr = jnp.where(cmask, addr0 + cpos, claim_addr)
        claim_tid = jnp.where(
            cmask, jnp.take_along_axis(segt, cpos[..., None], -1)[..., 0],
            claim_tid)
        # scan read set: in-range slots + exactly one boundary slot
        smask = mine & reads_index(kinds)
        in_or_boundary = jnp.concatenate(
            [jnp.ones((B, K, 1), bool), keys_at[..., :-1] < hi[..., None]],
            axis=-1) & (window < cap)
        sv = smask[..., None] & in_or_boundary
        scan_addr = jnp.where(sv, addr0[..., None] + slots, scan_addr)
        scan_tid = jnp.where(sv, tids_at, scan_tid)
        scan_valid = scan_valid | sv
        first_key = jnp.where(mine, keys_at[..., 0], first_key)
        base += P * cap

    consume_ok = (first_key == delta[..., IX_EXPECT]) & (first_key < hi) \
        & (first_key != SENTINEL)
    return {"claim_addr": claim_addr, "claim_tid": claim_tid,
            "scan_addr": scan_addr, "scan_tid": scan_tid,
            "scan_valid": scan_valid, "consume_ok": consume_ok,
            "no_addr": no_addr}


def run_single_master(val, tidw, txns, epoch, max_rounds: int = 16,
                      deterministic: bool = False, last_tid0=None,
                      index=None):
    """val: (N, C) int32 (master's flat view over ALL partitions);
    tidw: (N,) uint32.

    txns: {'valid': (B,), 'row': (B, M) global row, 'kind': (B, M),
           'delta': (B, M, C), 'user_abort': (B,)}.

    index: optional list of ordered-index pytrees {"key","prow","tid"}
    (P, cap_i) — enables SCAN_*/INSERT_IDX/DELETE_IDX op kinds (which must
    occupy op slots [0, IDX_OPS)).  Index maintenance is logged per round
    ("iwrite" mask) for the replica's ordered index-op replay.
    """
    N, C = val.shape
    B, M = txns["row"].shape
    K = min(IDX_OPS, M)
    lanes = jnp.arange(B, dtype=jnp.int32)
    SENTINEL_LANE = jnp.int32(B)

    if index is not None:
        P = index[0]["key"].shape[0]
        NT = N + sum(P * idx["key"].shape[1] for idx in index)
        assert C > 4, "index ops need IX_* param columns + a free guard col"
    else:
        NT = N

    runnable = txns["valid"] & ~txns["user_abort"]
    last_tid = last_tid0 if last_tid0 is not None else jnp.zeros((B,), jnp.uint32)

    def round_fn(state, round_idx):
        (val, tidw, index, committed, last_tid, retries, committed_round,
         skipped) = state
        active = runnable & ~committed                                  # (B,)
        rows, kind, delta = txns["row"], txns["kind"], txns["delta"]

        old = val[rows]                                                 # (B,M,C)
        rtids = tidw[rows]                                              # (B,M)
        # index-enabled workloads own the last delta column (op guards) —
        # it is metadata, never part of the applied value
        delta_v = delta.at[..., -1].set(0) if index is not None else delta
        new = apply_op(kind, old, delta_v)
        wmask = writes_primary(kind) & active[:, None]                  # (B,M)
        # pure index ops carry no meaningful primary row — exclude them from
        # the primary read/validation set (consume's row IS its write target)
        prim_live = (kind >= 0) & (~is_index_kind(kind) | (kind == SCAN_CONSUME))
        amask = active[:, None] & prim_live                             # (B,M)

        if index is not None:
            ix = _locate_index_ops(index, kind[:, :K], delta[:, :K], N)
            has_claim = (ix["claim_addr"] < ix["no_addr"]) & active[:, None]
            # op groups: a guarded op applies only if its consume validated;
            # a failed consume skips its own delete/tombstone too (TPC-C
            # Delivery skips the district, the txn itself still commits)
            wmask, iwrite_ok = resolve_op_guards(kind, delta,
                                                 ix["consume_ok"], wmask)
            iwrite = writes_index(kind[:, :K]) & active[:, None] & iwrite_ok
        # --- lock acquisition: scatter-min lane id over claimed rows/slots
        claim_lane = jnp.where(wmask, lanes[:, None], SENTINEL_LANE)
        lock = jnp.full((NT + 1,), SENTINEL_LANE, jnp.int32)
        lock = lock.at[jnp.where(wmask, rows, NT)].min(claim_lane)
        if index is not None:
            lock = lock.at[jnp.where(has_claim, ix["claim_addr"], NT)].min(
                jnp.where(has_claim, lanes[:, None], SENTINEL_LANE))
        holder = lock[rows]                                             # (B,M)

        wins_all = jnp.all(jnp.where(wmask, holder == lanes[:, None], True), axis=1)
        if index is not None:
            hold_ic = lock[ix["claim_addr"]]                            # (B,K)
            wins_all &= jnp.all(
                jnp.where(has_claim, hold_ic == lanes[:, None], True), axis=1)
        if deterministic:
            # Calvin: deterministic order, no read validation; a txn runs when
            # it holds all its locks (reads included) in global order
            rlock = jnp.full((NT + 1,), SENTINEL_LANE, jnp.int32)
            rlock = rlock.at[jnp.where(amask, rows, NT)].min(
                jnp.where(amask, lanes[:, None], SENTINEL_LANE))
            if index is not None:
                sa = jnp.where(ix["scan_valid"] & active[:, None, None],
                               ix["scan_addr"], NT)
                rlock = rlock.at[sa].min(
                    jnp.where(sa < NT, lanes[:, None, None], SENTINEL_LANE))
                rlock = rlock.at[jnp.where(has_claim, ix["claim_addr"], NT)
                                 ].min(jnp.where(has_claim, lanes[:, None],
                                                 SENTINEL_LANE))
            holder_any = rlock[rows]
            commit_now = active & jnp.all(
                jnp.where(amask, holder_any == lanes[:, None], True), axis=1)
            if index is not None:
                commit_now &= jnp.all(jnp.where(
                    ix["scan_valid"] & active[:, None, None],
                    rlock[ix["scan_addr"]] == lanes[:, None, None], True),
                    axis=(1, 2))
                commit_now &= jnp.all(jnp.where(
                    has_claim, rlock[ix["claim_addr"]] == lanes[:, None],
                    True), axis=1)
        else:
            # Silo validation: abort if an earlier lane writes anything I
            # read — rows AND scanned index slots (phantom protection)
            dirty = holder < lanes[:, None]                             # (B,M)
            read_ok = jnp.all(~(amask & dirty), axis=1)
            if index is not None:
                sdirty = ix["scan_valid"] & active[:, None, None] \
                    & (lock[ix["scan_addr"]] < lanes[:, None, None])
                read_ok &= ~jnp.any(sdirty, axis=(1, 2))
            commit_now = active & wins_all & read_ok

        # --- TID generation (criteria a, b, c)
        obs = jnp.max(jnp.where(amask, rtids, jnp.uint32(0)), axis=1)
        if index is not None:
            obs = jnp.maximum(obs, jnp.max(
                jnp.where(ix["scan_valid"], ix["scan_tid"], jnp.uint32(0)),
                axis=(1, 2)))
            obs = jnp.maximum(obs, jnp.max(
                jnp.where(has_claim, ix["claim_tid"], jnp.uint32(0)), axis=1))
        new_tid = tidlib.next_tid(epoch, obs, last_tid)                 # (B,)

        # --- install: winners only (unique per row by construction)
        w = wmask & commit_now[:, None]
        wrows = jnp.where(w, rows, N)
        val_pad = jnp.concatenate([val, jnp.zeros((1, C), val.dtype)], 0)
        val = val_pad.at[wrows.reshape(-1)].set(
            new.reshape(-1, C))[:N]
        tid_pad = jnp.concatenate([tidw, jnp.zeros((1,), tidw.dtype)], 0)
        tidw = tid_pad.at[wrows.reshape(-1)].set(
            jnp.broadcast_to(new_tid[:, None], (B, M)).reshape(-1))[:N]

        log = {"row": jnp.where(w, rows, -1), "val": new,
               "tid": jnp.broadcast_to(new_tid[:, None], (B, M)), "write": w}
        if index is not None:
            iw = iwrite & commit_now[:, None]                           # (B,K)
            index = apply_index_ops(
                index, kind[:, :K], delta[:, :K], iw,
                jnp.broadcast_to(new_tid[:, None], (B, K)))
            log["iwrite"] = iw

        committed_round = jnp.where(commit_now & ~committed, round_idx,
                                    committed_round)
        committed = committed | commit_now
        last_tid = jnp.where(commit_now, new_tid, last_tid)
        retries = retries + jnp.sum(active & ~commit_now)
        if index is not None:
            skipped = skipped + jnp.sum(
                (kind[:, :K] == SCAN_CONSUME) & ~ix["consume_ok"]
                & commit_now[:, None])
        return (val, tidw, index, committed, last_tid, retries,
                committed_round, skipped), log

    committed0 = jnp.zeros((B,), bool)
    cround0 = jnp.full((B,), -1, jnp.int32)
    (val, tidw, index, committed, last_tid, retries, committed_round,
     skipped), logs = jax.lax.scan(
        round_fn,
        (val, tidw, index, committed0, last_tid, jnp.int32(0), cround0,
         jnp.int32(0)),
        jnp.arange(max_rounds, dtype=jnp.int32))

    stats = {
        "committed": jnp.sum(committed),
        "starved": jnp.sum(runnable & ~committed),
        "user_aborts": jnp.sum(txns["valid"] & txns["user_abort"]),
        "retries": retries,
        "writes": jnp.sum(logs["write"]),
        "consume_skips": skipped,
    }
    # logs stacked over rounds: (rounds, B, M, …) — replication consumes the
    # flattened committed-write stream (Thomas rule makes order irrelevant);
    # index maintenance replays per round via logs["iwrite"] (ordered).
    out = {"log": logs, "committed": committed,
           "committed_round": committed_round, "last_tid": last_tid}
    if index is not None:
        out["index"] = index
    return val, tidw, out, stats
