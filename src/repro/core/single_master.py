"""Single-master phase executor (§4.2): vectorized Silo-variant OCC.

A batch of B transactions runs as B parallel "lanes" (the TPU-native analogue
of Silo worker threads).  Rounds proceed over a shared snapshot:

  read      — gather values + TIDs for every op;
  lock      — writers claim rows via scatter-min of lane id (a deterministic
              global lock order — the paper locks in address order to avoid
              deadlock; lane-id order is our equivalent);
  validate  — Silo read validation: a lane aborts (retries next round) if any
              row it accessed is claimed by an earlier lane this round, i.e.
              its read TIDs would have changed / the row is locked (§4.2);
  install   — winners draw TIDs satisfying criteria (a)(b)(c) and scatter
              post-images + TIDs.

With ``deterministic=True`` the same machinery becomes the Calvin baseline:
lock-order is the pre-assigned global order and read validation is skipped
(deterministic execution never aborts; §7.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tid as tidlib
from repro.core.ops import apply_op, is_write_kind


def run_single_master(val, tidw, txns, epoch, max_rounds: int = 16,
                      deterministic: bool = False, last_tid0=None):
    """val: (N, C) int32 (master's flat view over ALL partitions);
    tidw: (N,) uint32.

    txns: {'valid': (B,), 'row': (B, M) global row, 'kind': (B, M),
           'delta': (B, M, C), 'user_abort': (B,)}.
    """
    N, C = val.shape
    B, M = txns["row"].shape
    lanes = jnp.arange(B, dtype=jnp.int32)
    SENTINEL = jnp.int32(B)

    runnable = txns["valid"] & ~txns["user_abort"]
    last_tid = last_tid0 if last_tid0 is not None else jnp.zeros((B,), jnp.uint32)

    def round_fn(state, round_idx):
        val, tidw, committed, last_tid, retries, committed_round = state
        active = runnable & ~committed                                  # (B,)
        rows, kind, delta = txns["row"], txns["kind"], txns["delta"]

        old = val[rows]                                                 # (B,M,C)
        rtids = tidw[rows]                                              # (B,M)
        new = apply_op(kind, old, delta)
        wmask = is_write_kind(kind) & active[:, None]                   # (B,M)
        amask = active[:, None] & (kind >= 0)                           # all ops

        # --- lock acquisition: scatter-min lane id over claimed rows
        claim_lane = jnp.where(wmask, lanes[:, None], SENTINEL)
        lock = jnp.full((N + 1,), SENTINEL, jnp.int32)
        lock = lock.at[jnp.where(wmask, rows, N)].min(claim_lane)
        holder = lock[rows]                                             # (B,M)

        wins_all = jnp.all(jnp.where(wmask, holder == lanes[:, None], True), axis=1)
        if deterministic:
            # Calvin: deterministic order, no read validation; a txn runs when
            # it holds all its locks (reads included) in global order
            rlock = jnp.full((N + 1,), SENTINEL, jnp.int32)
            rlock = rlock.at[jnp.where(amask, rows, N)].min(
                jnp.where(amask, lanes[:, None], SENTINEL))
            holder_any = rlock[rows]
            commit_now = active & jnp.all(
                jnp.where(amask, holder_any == lanes[:, None], True), axis=1)
        else:
            # Silo validation: abort if an earlier lane writes anything I read
            dirty = holder < lanes[:, None]                             # (B,M)
            read_ok = jnp.all(~(amask & dirty), axis=1)
            commit_now = active & wins_all & read_ok

        # --- TID generation (criteria a, b, c)
        obs = jnp.max(jnp.where(amask, rtids, jnp.uint32(0)), axis=1)
        new_tid = tidlib.next_tid(epoch, obs, last_tid)                 # (B,)

        # --- install: winners only (unique per row by construction)
        w = wmask & commit_now[:, None]
        wrows = jnp.where(w, rows, N)
        val_pad = jnp.concatenate([val, jnp.zeros((1, C), val.dtype)], 0)
        val = val_pad.at[wrows.reshape(-1)].set(
            new.reshape(-1, C))[:N]
        tid_pad = jnp.concatenate([tidw, jnp.zeros((1,), tidw.dtype)], 0)
        tidw = tid_pad.at[wrows.reshape(-1)].set(
            jnp.broadcast_to(new_tid[:, None], (B, M)).reshape(-1))[:N]

        committed_round = jnp.where(commit_now & ~committed, round_idx,
                                    committed_round)
        committed = committed | commit_now
        last_tid = jnp.where(commit_now, new_tid, last_tid)
        retries = retries + jnp.sum(active & ~commit_now)
        log = {"row": jnp.where(w, rows, -1), "val": new,
               "tid": jnp.broadcast_to(new_tid[:, None], (B, M)), "write": w}
        return (val, tidw, committed, last_tid, retries, committed_round), log

    committed0 = jnp.zeros((B,), bool)
    cround0 = jnp.full((B,), -1, jnp.int32)
    (val, tidw, committed, last_tid, retries, committed_round), logs = jax.lax.scan(
        round_fn, (val, tidw, committed0, last_tid, jnp.int32(0), cround0),
        jnp.arange(max_rounds, dtype=jnp.int32))

    stats = {
        "committed": jnp.sum(committed),
        "starved": jnp.sum(runnable & ~committed),
        "user_aborts": jnp.sum(txns["valid"] & txns["user_abort"]),
        "retries": retries,
        "writes": jnp.sum(logs["write"]),
    }
    # logs stacked over rounds: (rounds, B, M, …) — replication consumes the
    # flattened committed-write stream (Thomas rule makes order irrelevant).
    return val, tidw, {"log": logs, "committed": committed,
                       "committed_round": committed_round,
                       "last_tid": last_tid}, stats
