"""Single-master phase executor (§4.2): vectorized Silo-variant OCC.

A batch of B transactions runs as B parallel "lanes" (the TPU-native analogue
of Silo worker threads).  Rounds proceed over a shared snapshot:

  read      — gather values + TIDs for every op; range-scan ops additionally
              gather their index window (SCAN_L slots + the next-key slot);
  lock      — writers claim rows via scatter-min of lane id (a deterministic
              global lock order — the paper locks in address order to avoid
              deadlock; lane-id order is our equivalent).  Index writers
              claim the *insertion/deletion position slot* in the same lock
              array (rows and index slots share one flat address space), so
              an insert into a range claims a slot every concurrent scanner
              of that range has in its read set: next-key locking;
  validate  — Silo read validation: a lane aborts (retries next round) if any
              row OR scanned index slot it accessed is claimed by an earlier
              lane this round (§4.2).  This is exactly the paper's read-set
              TID check extended with range (phantom) protection.
              SCAN_CONSUME ops additionally require the first live key of
              their range to equal the declared EXPECT key;
  install   — winners draw TIDs satisfying criteria (a)(b)(c), scatter
              post-images + TIDs, and merge their index maintenance
              (inserts/deletes/consumes) via storage.index.apply_index_ops.

The round body itself lives in ``repro.kernels.occ``: ``kernel="jnp"`` runs
the reference jnp implementation (ref.py, the parity oracle — the code that
used to be inline here), ``kernel="pallas"`` runs the fused Pallas kernels
(one launch per round for lock/validate/install, plus the fused
searchsorted+window probe) — bit-identical by the parity suite, interpreted
on CPU.

With ``deterministic=True`` the same machinery becomes the Calvin baseline:
lock-order is the pre-assigned global order and read validation is skipped
(deterministic execution never aborts; §7.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ops import (IDX_OPS, SCAN_CONSUME, is_index_kind,
                            resolve_op_guards, writes_index, writes_primary)
from repro.storage.index import apply_index_ops


def run_single_master(val, tidw, txns, epoch, max_rounds: int = 16,
                      deterministic: bool = False, last_tid0=None,
                      index=None, kernel: str = "jnp", interpret=None):
    """val: (N, C) int32 (master's flat view over ALL partitions);
    tidw: (N,) uint32.

    txns: {'valid': (B,), 'row': (B, M) global row, 'kind': (B, M),
           'delta': (B, M, C), 'user_abort': (B,)}.

    index: optional list of ordered-index pytrees {"key","prow","tid"}
    (P, cap_i) — enables SCAN_*/INSERT_IDX/DELETE_IDX op kinds (which must
    occupy op slots [0, IDX_OPS)).  Index maintenance is logged per round
    ("iwrite" mask) for the replica's ordered index-op replay.

    kernel: "jnp" (reference) or "pallas" (fused kernels, interpreted when
    not on TPU).
    """
    # deferred: importing repro.kernels.occ.ops runs repro.core.ops, whose
    # PACKAGE init (repro/core/__init__.py) imports engine -> this module —
    # a module-level import here breaks `import repro.kernels.occ.ops`
    from repro.kernels.occ.ops import locate_index_ops, occ_round

    N, C = val.shape
    B, M = txns["row"].shape
    K = min(IDX_OPS, M)

    if index is not None:
        assert C > 4, "index ops need IX_* param columns + a free guard col"

    runnable = txns["valid"] & ~txns["user_abort"]
    last_tid = last_tid0 if last_tid0 is not None else jnp.zeros((B,), jnp.uint32)

    def round_fn(state, round_idx):
        (val, tidw, index, committed, last_tid, retries, committed_round,
         skipped, overflow) = state
        active = runnable & ~committed                                  # (B,)
        rows, kind, delta = txns["row"], txns["kind"], txns["delta"]

        # index-enabled workloads own the last delta column (op guards) —
        # it is metadata, never part of the applied value
        delta_v = delta.at[..., -1].set(0) if index is not None else delta
        wmask = writes_primary(kind) & active[:, None]                  # (B,M)
        # pure index ops carry no meaningful primary row — exclude them from
        # the primary read/validation set (consume's row IS its write target)
        prim_live = (kind >= 0) & (~is_index_kind(kind) | (kind == SCAN_CONSUME))
        amask = active[:, None] & prim_live                             # (B,M)

        ix = has_claim = None
        if index is not None:
            ix = locate_index_ops(index, kind[:, :K], delta[:, :K], N,
                                  kernel=kernel, interpret=interpret)
            has_claim = (ix["claim_addr"] < ix["no_addr"]) & active[:, None]
            # op groups: a guarded op applies only if its consume validated;
            # a failed consume skips its own delete/tombstone too (TPC-C
            # Delivery skips the district, the txn itself still commits)
            wmask, iwrite_ok = resolve_op_guards(kind, delta,
                                                 ix["consume_ok"], wmask)
            iwrite = writes_index(kind[:, :K]) & active[:, None] & iwrite_ok

        # --- fused round: gather → lock → validate → TID → install ------
        val, tidw, commit_now, new_tid, new, w = occ_round(
            val, tidw, rows, kind, delta_v, wmask, amask, active, epoch,
            last_tid, ix=ix, has_claim=has_claim,
            deterministic=deterministic, kernel=kernel, interpret=interpret)

        log = {"row": jnp.where(w, rows, -1), "val": new,
               "tid": jnp.broadcast_to(new_tid[:, None], (B, M)), "write": w}
        if index is not None:
            iw = iwrite & commit_now[:, None]                           # (B,K)
            index, ov = apply_index_ops(
                index, kind[:, :K], delta[:, :K], iw,
                jnp.broadcast_to(new_tid[:, None], (B, K)),
                use_pallas=(kernel == "pallas"), interpret=interpret)
            overflow = overflow + ov
            log["iwrite"] = iw
            # which consume ops a COMMITTED txn skipped this round — the
            # host mirror re-queues these districts (consume feedback)
            log["cskip"] = (kind[:, :K] == SCAN_CONSUME) \
                & ~ix["consume_ok"] & commit_now[:, None]

        committed_round = jnp.where(commit_now & ~committed, round_idx,
                                    committed_round)
        committed = committed | commit_now
        last_tid = jnp.where(commit_now, new_tid, last_tid)
        retries = retries + jnp.sum(active & ~commit_now)
        if index is not None:
            skipped = skipped + jnp.sum(log["cskip"])
        return (val, tidw, index, committed, last_tid, retries,
                committed_round, skipped, overflow), log

    committed0 = jnp.zeros((B,), bool)
    cround0 = jnp.full((B,), -1, jnp.int32)
    (val, tidw, index, committed, last_tid, retries, committed_round,
     skipped, overflow), logs = jax.lax.scan(
        round_fn,
        (val, tidw, index, committed0, last_tid, jnp.int32(0), cround0,
         jnp.int32(0), jnp.int32(0)),
        jnp.arange(max_rounds, dtype=jnp.int32))

    stats = {
        "committed": jnp.sum(committed),
        "starved": jnp.sum(runnable & ~committed),
        "user_aborts": jnp.sum(txns["valid"] & txns["user_abort"]),
        "retries": retries,
        "writes": jnp.sum(logs["write"]),
        "consume_skips": skipped,
        "index_overflow": overflow,
    }
    # logs stacked over rounds: (rounds, B, M, …) — replication consumes the
    # flattened committed-write stream (Thomas rule makes order irrelevant);
    # index maintenance replays per round via logs["iwrite"] (ordered).
    out = {"log": logs, "committed": committed,
           "committed_round": committed_round, "last_tid": last_tid}
    if index is not None:
        out["index"] = index
    return val, tidw, out, stats
