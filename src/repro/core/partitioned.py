"""Partitioned-phase executor (§4.1): H-Store-style serial execution.

Transactions are pre-routed to their home partition — arrays shaped (P, T, …).
A ``lax.scan`` walks the T queue slots; at slot t every partition executes its
t-th transaction simultaneously (vmap across partitions = the paper's
one-worker-thread-per-partition).  No locks, no read validation — there are no
concurrent accesses within a partition (§4.1) — but TIDs are still generated
and written records tagged, so replication and the Thomas write rule work.

The executor returns the per-partition ordered write log: the operation-
replication stream (§5) replays it in order on replicas.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tid as tidlib
from repro.core.ops import apply_op, is_write_kind


def run_partitioned(val, tidw, ptxn, epoch, seq0=None):
    """val: (P, R, C) int32; tidw: (P, R) uint32.

    ptxn: {'valid': (P,T) bool, 'row': (P,T,M) int32 (partition-local flat
    row), 'kind': (P,T,M) int32, 'delta': (P,T,M,C) int32,
    'user_abort': (P,T) bool}.

    Returns (val', tid', log, stats).  log holds every op slot's post-image
    (P,T,M,...) with a write mask — the replication stream.
    """
    P, T, M = ptxn["row"].shape
    seq = seq0 if seq0 is not None else jnp.zeros((P,), jnp.uint32)

    def step(carry, slot):
        val, tidw, seq = carry
        rows, kind, delta = slot["row"], slot["kind"], slot["delta"]   # (P,M)…
        valid = slot["valid"] & ~slot["user_abort"]                    # (P,)

        old = jnp.take_along_axis(val, rows[..., None], axis=1)        # (P,M,C)
        new = apply_op(kind, old, delta)
        wmask = is_write_kind(kind) & valid[:, None]                   # (P,M)

        rtids = jnp.take_along_axis(tidw, rows, axis=1)                # (P,M)
        obs = jnp.max(rtids, axis=1)
        new_tid = tidlib.next_tid(epoch, obs, tidlib.make_tid(epoch, seq))
        seq = jnp.where(valid, tidlib.tid_seq(new_tid), seq)

        # scatter ONLY write ops (read/padding ops may share a row with a
        # write in the same txn — a duplicate-index scatter would race)
        R = val.shape[1]
        wrows = jnp.where(wmask, rows, R)                               # (P,M)

        def commit(v, t, r, n, nt):
            v = jnp.concatenate([v, jnp.zeros((1, v.shape[1]), v.dtype)])
            t = jnp.concatenate([t, jnp.zeros((1,), t.dtype)])
            return v.at[r].set(n)[:R], t.at[r].set(nt)[:R]

        val, tidw = jax.vmap(commit)(
            val, tidw, wrows, new,
            jnp.broadcast_to(new_tid[:, None], wrows.shape))

        log = {"row": rows, "val": new, "tid": jnp.broadcast_to(new_tid[:, None], (P, M)),
               "write": wmask, "kind": kind, "delta": delta}
        return (val, tidw, seq), (log, valid)

    slots = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), ptxn)        # (T,P,…)
    (val, tidw, seq), (log, committed) = jax.lax.scan(step, (val, tidw, seq), slots)
    log = jax.tree.map(lambda a: jnp.moveaxis(a, 0, 1), log)           # (P,T,…)
    committed = jnp.moveaxis(committed, 0, 1)                          # (P,T)
    stats = {
        "committed": jnp.sum(committed),
        "user_aborts": jnp.sum(ptxn["valid"] & ptxn["user_abort"]),
        "writes": jnp.sum(log["write"]),
    }
    return val, tidw, {"log": log, "committed": committed}, stats
