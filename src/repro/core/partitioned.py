"""Partitioned-phase executor (§4.1): H-Store-style serial execution.

Transactions are pre-routed to their home partition — arrays shaped (P, T, …).
A ``lax.scan`` walks the T queue slots; at slot t every partition executes its
t-th transaction simultaneously (vmap across partitions = the paper's
one-worker-thread-per-partition).  No locks, no read validation — there are no
concurrent accesses within a partition (§4.1) — but TIDs are still generated
and written records tagged, so replication and the Thomas write rule work.

Ordered-index ops execute serially too: scans resolve by ``searchsorted``
against the partition's own index segments (``kernel="pallas"`` dispatches
the probe to the fused scan-window kernel of ``repro.kernels.occ``); a
SCAN_CONSUME whose first live key differs from the host-declared EXPECT key
skips its op group (its own delete/tombstone plus every op guarded by it —
TPC-C Delivery's "skip the district" semantics, counted in
``consume_skips`` and logged per-op in ``log["cskip"]`` so the host mirror
can re-queue the district) while the rest of the transaction commits — the
optimistic host-side sequencing validated on-device.

The executor returns the per-partition ordered write log: the operation-
replication stream (§5) replays it in order on replicas — index maintenance
included (the ``iwrite`` mask per queue slot).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tid as tidlib
from repro.core.ops import (IDX_OPS, SCAN_CONSUME, apply_op,
                            resolve_op_guards, writes_index, writes_primary)
from repro.storage.index import apply_index_ops


def run_partitioned(val, tidw, ptxn, epoch, seq0=None, index=None,
                    kernel: str = "jnp", interpret=None, part_ids=None):
    """val: (P, R, C) int32; tidw: (P, R) uint32.

    ptxn: {'valid': (P,T) bool, 'row': (P,T,M) int32 (partition-local flat
    row), 'kind': (P,T,M) int32, 'delta': (P,T,M,C) int32,
    'user_abort': (P,T) bool}.

    index: optional list of ordered-index pytrees {"key","prow","tid"}
    (P, cap_i) — enables the SCAN_*/INSERT_IDX/DELETE_IDX op kinds (which
    occupy op slots [0, IDX_OPS)).

    kernel: "jnp" (reference) or "pallas" (fused index probe).

    part_ids: optional (P,) int32 — the global partition id each local row
    holds (a shard_map block passes its slice of the global ids so index
    maintenance aligns op keys with the right local segments).

    Returns (val', tid', log, stats).  log holds every op slot's post-image
    (P,T,M,...) with a write mask — the replication stream (plus the
    per-slot "iwrite" index-maintenance mask when an index is attached);
    ``out["seq"]`` carries the final per-partition TID sequence so callers
    chaining the slabs of one epoch thread it into the next call.
    """
    # deferred: importing repro.kernels.occ.ops runs repro.core.ops, whose
    # PACKAGE init (repro/core/__init__.py) imports engine -> this module —
    # a module-level import here breaks `import repro.kernels.occ.ops`
    from repro.kernels.occ.ops import step_index_ops

    P, T, M = ptxn["row"].shape
    K = min(IDX_OPS, M)
    if index is not None:
        assert ptxn["delta"].shape[-1] > 4, \
            "index ops need IX_* param columns + a free guard col"
    seq = seq0 if seq0 is not None else jnp.zeros((P,), jnp.uint32)

    def step(carry, slot):
        val, tidw, seq, index, overflow = carry
        rows, kind, delta = slot["row"], slot["kind"], slot["delta"]   # (P,M)…
        valid = slot["valid"] & ~slot["user_abort"]                    # (P,)

        old = jnp.take_along_axis(val, rows[..., None], axis=1)        # (P,M,C)
        # the last delta column is op-guard metadata when an index is
        # attached — mask it out of the applied (and logged) value stream
        delta_v = delta.at[..., -1].set(0) if index is not None else delta
        new = apply_op(kind, old, delta_v)
        wmask = writes_primary(kind) & valid[:, None]                  # (P,M)
        if index is not None:
            consume_ok, slot_tid = step_index_ops(
                index, kind[:, :K], delta[:, :K], kernel=kernel,
                interpret=interpret)
            # op groups: a failed consume skips its district's guarded
            # updates and its own delete/tombstone; the txn still commits
            wmask, iwrite_ok = resolve_op_guards(kind, delta, consume_ok,
                                                 wmask)

        rtids = jnp.take_along_axis(tidw, rows, axis=1)                # (P,M)
        obs = jnp.max(rtids, axis=1)
        if index is not None:
            obs = jnp.maximum(obs, jnp.max(slot_tid, axis=1))
        new_tid = tidlib.next_tid(epoch, obs, tidlib.make_tid(epoch, seq))
        seq = jnp.where(valid, tidlib.tid_seq(new_tid), seq)

        # scatter ONLY write ops (read/padding ops may share a row with a
        # write in the same txn — a duplicate-index scatter would race)
        R = val.shape[1]
        wrows = jnp.where(wmask, rows, R)                               # (P,M)

        def commit(v, t, r, n, nt):
            v = jnp.concatenate([v, jnp.zeros((1, v.shape[1]), v.dtype)])
            t = jnp.concatenate([t, jnp.zeros((1,), t.dtype)])
            return v.at[r].set(n)[:R], t.at[r].set(nt)[:R]

        val, tidw = jax.vmap(commit)(
            val, tidw, wrows, new,
            jnp.broadcast_to(new_tid[:, None], wrows.shape))

        log = {"row": rows, "val": new, "tid": jnp.broadcast_to(new_tid[:, None], (P, M)),
               "write": wmask, "kind": kind, "delta": delta_v}
        skips = jnp.int32(0)
        if index is not None:
            iw = writes_index(kind[:, :K]) & valid[:, None] & iwrite_ok  # (P,K)
            index, ov = apply_index_ops(
                index, kind[:, :K], delta[:, :K], iw,
                jnp.broadcast_to(new_tid[:, None], (P, K)),
                part_ids=part_ids,
                use_pallas=(kernel == "pallas"), interpret=interpret)
            overflow = overflow + ov
            log["iwrite"] = iw
            # per-op skipped-consume mask — the consume-feedback stream the
            # host mirror uses to re-queue skipped Delivery districts
            log["cskip"] = (kind[:, :K] == SCAN_CONSUME) & ~consume_ok \
                & valid[:, None]
            skips = jnp.sum(log["cskip"])
        return (val, tidw, seq, index, overflow), (log, valid, skips)

    slots = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), ptxn)        # (T,P,…)
    (val, tidw, seq, index, overflow), (log, committed, skips) = jax.lax.scan(
        step, (val, tidw, seq, index, jnp.int32(0)), slots)
    log = jax.tree.map(lambda a: jnp.moveaxis(a, 0, 1), log)           # (P,T,…)
    committed = jnp.moveaxis(committed, 0, 1)                          # (P,T)
    stats = {
        "committed": jnp.sum(committed),
        "user_aborts": jnp.sum(ptxn["valid"] & ptxn["user_abort"]),
        "consume_skips": jnp.sum(skips),
        "writes": jnp.sum(log["write"]),
        "index_overflow": overflow,
    }
    out = {"log": log, "committed": committed, "seq": seq}
    if index is not None:
        out["index"] = index
    return val, tidw, out, stats
