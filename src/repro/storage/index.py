"""Ordered secondary indexes over the array-resident tables.

Each index is a partition-major sorted-key array: ``key (P, cap) int32``
ascending with SENTINEL-padded free slots, a parallel primary-row payload
``prow (P, cap) int32`` (partition-local row the entry points at) and a
per-slot ``tid (P, cap) uint32`` stamped by the transaction that last
created the entry.  Everything is fixed-shape and scan/jit-compatible:

* lookups/range scans are ``jnp.searchsorted`` + a bounded window gather
  (``SCAN_L`` result slots + 1 next-key slot for phantom validation);
* maintenance is a vectorized delete-scatter (searchsorted position, hit
  test, sentinelize) followed by an insert merge — a sorted-run merge of
  (existing segment, argsorted incoming keys): two ``searchsorted`` calls
  compute each run's positions in the merged order and a scatter places
  them, so the O(cap log cap) full-segment argsort per batch is gone (only
  the Ki incoming keys are sorted).  Free slots are canonical
  (key=SENTINEL, prow=0, tid=0) so master and replica arrays stay
  bit-equal under replay.

Key encoding: the partition id lives in the high bits
(``full_key = partition << PART_SHIFT | local_key``), so each partition's
segment is independently sorted *and* the segment is selectable from the
key alone — the single-master phase (which sees the flat global address
space) recovers the segment as ``key >> PART_SHIFT``.

OCC integration (next-key locking): an insert's lock target is the slot
``searchsorted(seg, key)`` — the current *successor* of the inserted key —
and a scan's read set is the window ``[searchsorted(seg, lo), +SCAN_L]``
slots.  Any insert/delete landing inside a concurrently scanned range
therefore claims a slot the scanner read, and Silo validation aborts the
scanner: phantom protection in the same scatter-min lock discipline as row
writes (see core/single_master.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

SENTINEL = np.int32(0x7FFFFFFF)
PART_SHIFT = 24                    # full key = partition << 24 | local key
SCAN_L = 8                         # result slots per scan op (+1 next-key)


@dataclass(frozen=True)
class IndexSpec:
    name: str
    capacity: int                  # slots per partition (fixed)


def make_index(spec: IndexSpec, n_partitions: int):
    P, cap = n_partitions, spec.capacity
    return {"key": jnp.full((P, cap), SENTINEL, jnp.int32),
            "prow": jnp.zeros((P, cap), jnp.int32),
            "tid": jnp.zeros((P, cap), jnp.uint32)}


def full_key(partition, local_key):
    return (jnp.asarray(partition, jnp.int32) << PART_SHIFT) | \
        jnp.asarray(local_key, jnp.int32)


def key_partition(key):
    return jnp.asarray(key, jnp.int32) >> PART_SHIFT


# ---------------------------------------------------------------------------
# one-segment primitives (vmap over partitions / ops at the call sites)
# ---------------------------------------------------------------------------
def segment_apply(key, prow, tid, del_key, ins_key, ins_prow, ins_tid,
                  use_pallas=False, interpret=None):
    """Apply one batch of deletes + inserts to one sorted segment.

    key/prow/tid: (cap,).  del_key: (Kd,) with SENTINEL = masked out.
    ins_key: (Ki,) with SENTINEL = masked out; ins_prow/ins_tid payloads.
    Deletes resolve against the *pre-batch* segment; inserts merge after.
    Returns (key', prow', tid', overflow): the re-sorted canonical segment
    plus the number of LIVE keys dropped because the merge exceeded ``cap``
    (largest-key-first).  Overflow is deterministic and identical on master
    and replica (both apply the same batches), so it never diverges state —
    but it IS data loss; the engine counts it as ``index_overflow`` and can
    raise in strict mode (capacity sizing is the caller's responsibility —
    see IndexSpec).

    ``use_pallas`` dispatches to the fused index-merge kernel
    (repro.kernels.index_merge, interpreted off-TPU) — one launch fusing
    the delete-compact, both rank passes and the merged scatter; results
    are bit-identical to the jnp oracle (``ref.segment_merge_ref``, the
    exact former body of this function).
    """
    if use_pallas:
        from repro.kernels.index_merge.ops import index_merge
        k2, p2, t2, ov = index_merge(
            key[None], prow[None], tid[None], del_key[None], ins_key[None],
            ins_prow[None], ins_tid[None], interpret=interpret)
        return k2[0], p2[0], t2[0], ov[0]
    from repro.kernels.index_merge.ref import segment_merge_ref
    return segment_merge_ref(key, prow, tid, del_key, ins_key, ins_prow,
                             ins_tid)


def segment_scan(key, lo, hi, n_slots: int = SCAN_L + 1, use_pallas=False,
                 interpret=None):
    """Bounded range scan of one sorted segment: the first ``n_slots`` slots
    at/after ``lo`` (the last one is the next-key/boundary slot).

    Returns (slots (n_slots,) int32 positions clipped to cap-1,
             keys_at (n_slots,), in_range (n_slots,) bool) where ``in_range``
    marks live keys in [lo, hi) among the first n_slots-1 result slots.

    ``use_pallas`` dispatches the searchsorted+window probe to the fused
    Pallas scan-window kernel (repro.kernels.occ) — interpreted off-TPU —
    instead of the jnp gather; results are bit-identical.
    """
    cap = key.shape[0]
    if use_pallas:
        from repro.kernels.occ.kernel import scan_window_pallas
        from repro.kernels.occ.ops import resolve_interpret
        interpret = resolve_interpret(interpret)
        pos0, keys_w, _ = scan_window_pallas(
            key, jnp.zeros((cap,), jnp.uint32),
            jnp.asarray(lo, jnp.int32).reshape(1),
            jnp.zeros((1,), jnp.int32), jnp.full((1,), cap, jnp.int32),
            n_slots=n_slots, n_iters=int(cap).bit_length() + 1,
            interpret=interpret)
        pos0 = pos0[0]
        raw = pos0 + jnp.arange(n_slots, dtype=jnp.int32)
        slots = jnp.clip(raw, 0, cap - 1)
        keys_at = keys_w[0]
    else:
        pos0 = jnp.searchsorted(key, lo)
        raw = pos0 + jnp.arange(n_slots, dtype=jnp.int32)
        slots = jnp.clip(raw, 0, cap - 1)
        keys_at = key[slots]
    is_result = jnp.arange(n_slots) < (n_slots - 1)   # last slot = next-key
    in_range = (raw < cap) & is_result & (keys_at >= lo) & (keys_at < hi) \
        & (keys_at != SENTINEL)
    return slots, keys_at, in_range


# ---------------------------------------------------------------------------
# batched maintenance shared by executors and replica replay
# ---------------------------------------------------------------------------
def apply_index_ops(indexes, kinds, delta, win, tids, part_ids=None,
                    use_pallas=False, interpret=None):
    """Apply one batch of committed index-maintenance ops to every index.

    indexes: list of {"key","prow","tid"} (P, cap_i) pytrees.
    kinds: (..., K) int32 op kinds; delta: (..., K, C) op params
    (IX_* column layout, see core.ops); win: (..., K) bool — the op
    committed in this round/step; tids: (..., K) uint32 commit TIDs.
    part_ids: optional (P,) int32 — the GLOBAL partition id each segment
    row holds.  Defaults to ``arange(P)`` (the whole-database layout); a
    shard_map block passes its own slice of the global ids, and the rolled
    secondary-replica arrays pass their home-major permutation, so the
    same op batch lands on the right segments in any layout.

    Returns (indexes', overflow) where ``overflow`` (int32 scalar) counts
    live keys dropped by capacity-exceeding merges across all segments —
    deterministic and replica-identical, surfaced as ``index_overflow``.

    ``use_pallas`` routes every segment merge through the fused Pallas
    index-merge kernel (one launch per index covering all P segments)
    instead of the vmapped jnp oracle — bit-identical outputs; the
    executors and replica replay pass ``kernel == "pallas"`` down here so
    master and replicas run the same code path.

    The SAME function runs in the executors' install phase and in replica
    replay, so both sides evolve bit-equal index arrays from the same
    logical op stream (round/step-ordered; within a batch, lock-disjoint).
    """
    from repro.core.ops import (DELETE_IDX, INSERT_IDX, IX_EXPECT, IX_ID,
                                IX_KEY, IX_PROW, SCAN_CONSUME)
    P = indexes[0]["key"].shape[0]
    kinds = kinds.reshape(-1)
    win = win.reshape(-1)
    delta = delta.reshape(kinds.shape[0], -1)
    iid = delta[:, IX_ID]
    part = key_partition(delta[:, IX_KEY])
    if part_ids is None:
        part_ids = jnp.arange(P, dtype=jnp.int32)
    parts_col = jnp.asarray(part_ids, jnp.int32)[:, None]        # (P, 1)

    out = []
    overflow = jnp.int32(0)
    for i, idx in enumerate(indexes):
        sel_i = win & (iid == i)
        is_del = sel_i & ((kinds == DELETE_IDX) | (kinds == SCAN_CONSUME))
        is_ins = sel_i & (kinds == INSERT_IDX)
        dkey = jnp.where(kinds == SCAN_CONSUME, delta[:, IX_EXPECT],
                         delta[:, IX_KEY])
        del_key = jnp.where(is_del, dkey, SENTINEL)
        ins_key = jnp.where(is_ins, delta[:, IX_KEY], SENTINEL)
        ins_prow = jnp.where(is_ins, delta[:, IX_PROW], 0)
        ins_tid = jnp.where(is_ins, tids.reshape(-1), jnp.uint32(0))
        # partition-align the candidate batch: (P, Q) masked per segment
        mine = parts_col == part[None, :]
        del_pq = jnp.where(mine, del_key[None, :], SENTINEL)
        ins_pq = jnp.where(mine, ins_key[None, :], SENTINEL)
        prow_pq = jnp.where(mine, ins_prow[None, :], 0)
        tid_pq = jnp.where(mine, ins_tid[None, :], jnp.uint32(0))
        if use_pallas:
            from repro.kernels.index_merge.ops import index_merge
            k, p, t, ov = index_merge(
                idx["key"], idx["prow"], idx["tid"], del_pq, ins_pq,
                prow_pq, tid_pq, interpret=interpret)
        else:
            k, p, t, ov = jax.vmap(segment_apply)(
                idx["key"], idx["prow"], idx["tid"], del_pq, ins_pq,
                prow_pq, tid_pq)
        overflow = overflow + jnp.sum(ov)
        out.append({"key": k, "prow": p, "tid": t})
    return out, overflow


# ---------------------------------------------------------------------------
# numpy reference (tests): the oracle the jnp index must agree with
# ---------------------------------------------------------------------------
class ReferenceIndex:
    """Sorted-dict semantics in plain numpy for property tests."""

    def __init__(self):
        self.entries = {}              # key -> (prow, tid)

    def insert(self, key, prow, tid):
        self.entries[int(key)] = (int(prow), int(tid))

    def delete(self, key):
        self.entries.pop(int(key), None)

    def range_scan(self, lo, hi, limit):
        ks = sorted(k for k in self.entries if lo <= k < hi)[:limit]
        return [(k, *self.entries[k]) for k in ks]

    def as_arrays(self, cap):
        ks = sorted(self.entries)[:cap]
        key = np.full(cap, SENTINEL, np.int32)
        prow = np.zeros(cap, np.int32)
        tid = np.zeros(cap, np.uint32)
        for i, k in enumerate(ks):
            key[i] = k
            prow[i], tid[i] = self.entries[k]
        return key, prow, tid
