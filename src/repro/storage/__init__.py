"""Storage subsystem: array-resident tables + ordered secondary indexes.

``StorageEngine`` owns the two-version record arrays (absorbed from
``db/table.py``) and the ``storage.index`` ordered secondary indexes, and
exposes batched ``point_read`` / ``point_write`` / ``range_scan`` ops.  The
phase executors (``core.single_master`` / ``core.partitioned``) validate
scanned ranges via index-slot TIDs and next-key locking — see DESIGN.md.
"""
from repro.storage.engine import (Database, StorageEngine, TableSpec,
                                  flat_tid, flat_val, global_key,
                                  make_database, make_table, snapshot_commit,
                                  revert_to_snapshot)
from repro.storage.index import (IndexSpec, PART_SHIFT, SCAN_L, SENTINEL,
                                 apply_index_ops, full_key, key_partition,
                                 make_index, segment_apply, segment_scan)

__all__ = [
    "Database", "StorageEngine", "TableSpec", "IndexSpec",
    "flat_tid", "flat_val", "global_key", "make_database", "make_table",
    "snapshot_commit", "revert_to_snapshot",
    "PART_SHIFT", "SCAN_L", "SENTINEL", "apply_index_ops", "full_key",
    "key_partition", "make_index", "segment_apply", "segment_scan",
]
