"""StorageEngine: array-resident tables + ordered secondary indexes.

The storage layer owns what ``db/table.py`` used to carry — partition-major
``val (P, cap, C) int32`` / ``tid (P, cap) uint32`` record arrays with two
record versions (working + last committed epoch, the paper's §4.5.2 revert
machinery) — plus the ordered secondary indexes of ``storage.index``, and
exposes the batched storage ops the execution stack is written against:

  point_read(parts, rows)          — batched gather of values + TIDs
  point_write(parts, rows, ...)    — batched scatter of post-images + TIDs
  range_scan(index, part, lo, hi)  — searchsorted window over one segment

``snapshot_commit`` / ``revert_to_snapshot`` cover tables AND indexes, so a
failed epoch rolls index maintenance back with the records it indexed.

State is functional JAX pytrees: the mutating methods rebind attributes on
the Python object, while ``state()``/``load_state()`` expose the pytree for
jitted executors (which thread it through ``lax.scan`` carries).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.storage.index import IndexSpec, make_index, segment_scan


@dataclass(frozen=True)
class TableSpec:
    name: str
    capacity: int            # rows per partition
    n_cols: int              # int32 words per row


Database = dict   # {table: {"val","tid","val_prev","tid_prev"}, "_epoch": u32}


def make_table(spec: TableSpec, n_partitions: int):
    val = jnp.zeros((n_partitions, spec.capacity, spec.n_cols), jnp.int32)
    tid = jnp.zeros((n_partitions, spec.capacity), jnp.uint32)
    return {"val": val, "tid": tid, "val_prev": val, "tid_prev": tid}


def make_database(specs: list[TableSpec], n_partitions: int) -> Database:
    db = {s.name: make_table(s, n_partitions) for s in specs}
    db["_epoch"] = jnp.uint32(1)
    return db


def snapshot_commit(db: Database) -> Database:
    """Promote working version to committed snapshot (runs inside the fence)."""
    out = {}
    for k, t in db.items():
        if k == "_epoch":
            out[k] = t + jnp.uint32(1)
        else:
            out[k] = {"val": t["val"], "tid": t["tid"],
                      "val_prev": t["val"], "tid_prev": t["tid"]}
    return out


def revert_to_snapshot(db: Database) -> Database:
    """Failure: discard everything written in the current (uncommitted) epoch."""
    out = {}
    for k, t in db.items():
        if k == "_epoch":
            out[k] = t
        else:
            out[k] = {"val": t["val_prev"], "tid": t["tid_prev"],
                      "val_prev": t["val_prev"], "tid_prev": t["tid_prev"]}
    return out


# ---------------------------------------------------------------------------
# flat views (single-master phase sees one address space)
# ---------------------------------------------------------------------------
def flat_val(table):
    P, cap, C = table["val"].shape
    return table["val"].reshape(P * cap, C)


def flat_tid(table):
    P, cap = table["tid"].shape
    return table["tid"].reshape(P * cap)


def global_key(partition, idx, capacity):
    return partition * capacity + idx


# ---------------------------------------------------------------------------
# the storage engine
# ---------------------------------------------------------------------------
class StorageEngine:
    """One replica's storage: record arrays + secondary indexes, two-version."""

    def __init__(self, n_partitions: int, rows_per_partition: int,
                 n_cols: int = 10, init_val=None,
                 index_specs: list[IndexSpec] | None = None):
        P, R, C = n_partitions, rows_per_partition, n_cols
        self.P, self.R, self.C = P, R, C
        self.val = (jnp.asarray(init_val, jnp.int32) if init_val is not None
                    else jnp.zeros((P, R, C), jnp.int32))
        self.tid = jnp.zeros((P, R), jnp.uint32)
        self.index_specs = list(index_specs or [])
        self.indexes = [make_index(s, P) for s in self.index_specs]
        self._snap = self.state()

    # -- pytree plumbing for jitted executors ---------------------------
    def state(self):
        # shallow-copy the containers: snapshots must not alias the live
        # index dicts (the arrays themselves are immutable jax values)
        return {"val": self.val, "tid": self.tid,
                "indexes": [dict(ix) for ix in self.indexes]}

    def load_state(self, state):
        self.val, self.tid = state["val"], state["tid"]
        self.indexes = [dict(ix) for ix in state["indexes"]]

    # -- two-version records (§4.5.2), indexes included -----------------
    def snapshot_commit(self):
        self._snap = self.state()

    def revert_to_snapshot(self):
        self.load_state(self._snap)

    @property
    def snapshot(self):
        return self._snap

    # -- batched point ops ----------------------------------------------
    def point_read(self, parts, rows):
        """parts/rows: (...,) int32 -> (vals (..., C), tids (...,))."""
        flat = jnp.asarray(parts) * self.R + jnp.asarray(rows)
        return (self.val.reshape(-1, self.C)[flat],
                self.tid.reshape(-1)[flat])

    def point_write(self, parts, rows, vals, tids):
        """Batched scatter of post-images + TIDs (caller resolves conflicts)."""
        flat = (jnp.asarray(parts) * self.R + jnp.asarray(rows)).reshape(-1)
        self.val = self.val.reshape(-1, self.C).at[flat].set(
            jnp.asarray(vals).reshape(-1, self.C)).reshape(self.P, self.R,
                                                           self.C)
        self.tid = self.tid.reshape(-1).at[flat].set(
            jnp.asarray(tids).reshape(-1)).reshape(self.P, self.R)

    # -- batched index maintenance ---------------------------------------
    def apply_index_batch(self, kinds, delta, win, tids, part_ids=None,
                          use_pallas: bool = False, interpret=None):
        """Apply one committed index-op batch to every index (the same
        ``storage.index.apply_index_ops`` the executors and replica replay
        run).  ``use_pallas`` routes the segment merges through the fused
        Pallas index-merge kernel — bit-identical arrays either way.
        Returns the overflow count (live keys dropped by full segments)."""
        from repro.storage.index import apply_index_ops
        self.indexes, overflow = apply_index_ops(
            self.indexes, kinds, delta, win, tids, part_ids=part_ids,
            use_pallas=use_pallas, interpret=interpret)
        return overflow

    # -- range scan over one index segment ------------------------------
    def index_id(self, name: str) -> int:
        for i, s in enumerate(self.index_specs):
            if s.name == name:
                return i
        raise KeyError(name)

    def range_scan(self, index: str | int, part: int, lo, hi,
                   limit: int = None, use_pallas: bool = False):
        """Scan index ``index`` on partition ``part`` for keys in [lo, hi).

        Returns (keys, prows, tids, mask): fixed-width ``limit`` result
        slots, ``mask`` marking live in-range hits.  ``lo``/``hi`` are full
        (partition-prefixed) keys.  ``use_pallas`` dispatches the probe to
        the fused scan-window kernel (bit-identical).
        """
        from repro.storage.index import SCAN_L
        limit = SCAN_L if limit is None else limit
        i = index if isinstance(index, int) else self.index_id(index)
        idx = self.indexes[i]
        seg_k, seg_p, seg_t = idx["key"][part], idx["prow"][part], \
            idx["tid"][part]
        slots, keys_at, in_range = segment_scan(seg_k, jnp.int32(lo),
                                                jnp.int32(hi), limit + 1,
                                                use_pallas=use_pallas)
        res = slice(0, limit)
        return (keys_at[res], seg_p[slots][res], seg_t[slots][res],
                in_range[res])

    # -- consistency ------------------------------------------------------
    def equals(self, other: "StorageEngine") -> bool:
        if not (bool(jnp.all(self.val == other.val))
                and bool(jnp.all(self.tid == other.tid))):
            return False
        for a, b in zip(self.indexes, other.indexes):
            for f in ("key", "prow", "tid"):
                if not bool(jnp.all(a[f] == b[f])):
                    return False
        return True
