"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e pods — 256 chips/pod in
a (16, 16) = (data, model) layout; the multi-pod mesh prepends a "pod" axis
(2 x 16 x 16 = 512 chips).  In STAR terms each pod holds a complete replica
of the parameters (the "full replica"); optimizer state is owner-sharded
("partial replicas") over the data axis inside each pod.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests / examples)."""
    n = data * model
    devices = jax.devices()[:n]
    return jax.make_mesh((data, model), ("data", "model"), devices=devices)
