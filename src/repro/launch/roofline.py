"""Roofline term derivation from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
memory term     = HLO_bytes / (chips * HBM_bw)
collective term = collective_bytes / (chips * link_bw)

``cost_analysis()`` on the SPMD-partitioned module reports *per-device* flops
and bytes, so the per-chip division is already applied; the collective bytes
are parsed out of the partitioned HLO text (per-device operand sizes summed
over every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field

# TPU v5e hardware envelope (per task spec)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,4096]{1,0}  or  f32[]  or  (bf16[8,128], f32[8])
_SHAPE_RE = re.compile(r"(pred|[sucbf]\d+|bf16)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)
    bytes_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device operand bytes of every collective instruction."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        result_shape, op = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "."):
                kind = c
                break
        if kind is None:
            continue
        # operand bytes: shapes inside the call parens; fall back to result
        paren = line[line.index("("):]
        # strip metadata/attribute tail which can contain shapes in comments
        paren = paren.split("metadata=")[0]
        operand_bytes = _shape_bytes(paren)
        if operand_bytes == 0:
            operand_bytes = _shape_bytes(result_shape)
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + operand_bytes
    return stats


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_counts: dict
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0           # 6*N*D (or active-N) global
    useful_flops_ratio: float = 0.0    # model_flops / (flops_per_device*chips)

    def to_dict(self):
        return asdict(self)


def derive(cost: dict, hlo_text: str, n_chips: int, model_flops: float = 0.0) -> Roofline:
    """Derive the three terms from the partitioned HLO (trip-count aware).

    ``cost_analysis()`` numbers are kept in the record for reference but the
    terms come from :mod:`repro.launch.hlo_analysis`, which multiplies loop
    bodies by their trip counts (scan-over-layers would otherwise be counted
    once).
    """
    from repro.launch import hlo_analysis
    tot = hlo_analysis.analyze(hlo_text)
    flops = tot.flops
    byts = tot.traffic_bytes
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = tot.collective_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    ratio = model_flops / (flops * n_chips) if flops > 0 else 0.0
    return Roofline(
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=tot.collective_bytes,
        collective_counts={k: int(v) for k, v in tot.counts_by_kind.items()},
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_flops_ratio=ratio,
    )


def model_flops_for(cfg, cell) -> float:
    """MODEL_FLOPS = 6*N*D for train (D = tokens per step), 2*N*D for fwd-only."""
    n = cfg.n_active_params()
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    mult = 6 if cell.kind == "train" else 2
    return float(mult) * n * tokens
