"""Trip-count-aware HLO analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program (ours: depth-independent compile) under-reports
flops, bytes and collective traffic by ~n_layers.  This module parses the
post-optimization (SPMD-partitioned, per-device) HLO text, resolves operand
shapes through a per-computation symbol table, extracts loop trip counts from
scan-generated ``while`` conditions, and recursively accumulates:

* flops            — 2*(B*M*N)*K for every ``dot`` (+ convolution estimate);
* traffic bytes    — operand+result bytes of every materializing instruction
                     (post-opt fusions are single instructions, so their IO is
                     a reasonable HBM-traffic proxy);
* collective bytes — max(operand, result) bytes per collective instruction,
                     split by kind.

All quantities are per-device (the module is already partitioned).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(f8e4m3fn|f8e5m2|pred|bf16|[sucf]\d+|token)\[([\d,]*)\]")

_SKIP_IO = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "while", "call", "conditional",
    "partition-id", "replica-id", "domain", "opt-barrier",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*?)\)\s+->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*)$")


def _shapes_in(text: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n))
    return out


def _bytes_of(shapes) -> int:
    return sum(n * _DTYPE_BYTES.get(dt, 4) for dt, n in shapes)


def _elems_of(shapes) -> int:
    return sum(n for _, n in shapes)


@dataclass
class Instr:
    name: str
    op: str
    result: list                  # [(dtype, numel)]
    operands: list                # operand names (no %)
    line: str
    calls: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    symbols: dict = field(default_factory=dict)   # name -> [(dtype, numel)]
    dims: dict = field(default_factory=dict)      # name -> [d0, d1, ...]
    instrs: list = field(default_factory=list)


def parse_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        hm = _COMP_HEADER.match(s) if s.endswith("{") else None
        if hm:
            cur = Computation(hm.group(1))
            comps[cur.name] = cur
            for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\],]+))",
                                  hm.group(2)):
                cur.symbols[pm.group(1)] = _shapes_in(pm.group(2))
                mm = _SHAPE_RE.search(pm.group(2))
                if mm:
                    cur.dims[pm.group(1)] = [int(x) for x in mm.group(2).split(",") if x]
            continue
        if cur is None or s == "}":
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, rest = im.groups()
        # result type: tuple "(...)" (may contain /*index=N*/ comments) or
        # a single "dtype[dims]{layout}" token
        if rest.startswith("("):
            depth = 0
            j = 0
            for j, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            rtype, tail = rest[: j + 1], rest[j + 1:]
        else:
            sp = rest.find(" ")
            if sp < 0:
                continue
            rtype, tail = rest[:sp], rest[sp:]
        om = re.match(r"\s*([\w\-]+)\(", tail)
        if not om:
            continue
        op = om.group(1)
        start = tail.index("(", om.start(1))
        depth, i = 0, start
        while i < len(tail):
            if tail[i] == "(":
                depth += 1
            elif tail[i] == ")":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        arg_text = tail[start + 1: i]
        attrs = tail[i + 1:]
        operands = re.findall(r"%([\w.\-]+)", arg_text)
        result = _shapes_in(rtype)
        instr = Instr(name=name, op=op, result=result, operands=operands,
                      line=rest)
        for am in re.finditer(
                r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)", attrs):
            instr.calls.append(am.group(1))
        for am in re.finditer(r"branch_computations=\{([^}]*)\}", attrs):
            instr.calls.extend(c.strip().lstrip("%") for c in am.group(1).split(","))
        cur.symbols[name] = result
        mm = _SHAPE_RE.search(rtype)
        if mm:
            cur.dims[name] = [int(x) for x in mm.group(2).split(",") if x]
        cur.instrs.append(instr)
    return comps


def _trip_count(comps: dict, cond_name: str) -> int:
    """Scan-generated conditions are `compare(iter, constant(N)), direction=LT`.
    Resolve the constant actually feeding the compare (NOT the max constant in
    the computation — sort/while lowerings carry unrelated large constants)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = {}
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", ins.line)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if ins.op == "compare" and "direction=LT" in ins.line:
            for o in ins.operands:
                if o in consts:
                    return consts[o]
    return max(consts.values()) if consts else 1


@dataclass
class Totals:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    bytes_by_kind: dict = field(default_factory=dict)
    counts_by_kind: dict = field(default_factory=dict)
    n_dots: int = 0
    max_trip: int = 1


def analyze(text: str, entry: str | None = None) -> Totals:
    comps = parse_hlo(text)
    totals = Totals()
    entry_name = entry
    if entry_name is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, flags=re.M)
        entry_name = m.group(1) if m else next(iter(comps))

    on_stack: set[str] = set()

    def walk(cname: str, mult: float, traffic: bool = True):
        comp = comps.get(cname)
        if comp is None or cname in on_stack:
            return
        on_stack.add(cname)
        sym, dims = comp.symbols, comp.dims
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                if bm and cm:
                    trip = _trip_count(comps, cm.group(1))
                    totals.max_trip = max(totals.max_trip, int(trip))
                    walk(bm.group(1), mult * trip, traffic)
                    walk(cm.group(1), mult * trip, False)
                continue
            # fusion internals are already materialized as ONE instruction's
            # IO — walk them for flops/collectives only, not traffic
            sub_traffic = traffic and op in ("call", "conditional")
            for c in ins.calls:
                walk(c, mult, sub_traffic)
            kind = next((c for c in _COLLECTIVES
                         if op == c or op == c + "-start"), None)
            if kind:
                opb = sum(_bytes_of(sym.get(o, [])) for o in ins.operands)
                rb = _bytes_of(ins.result)
                byts = max(opb, rb) * mult
                totals.collective_bytes += byts
                totals.bytes_by_kind[kind] = totals.bytes_by_kind.get(kind, 0) + byts
                totals.counts_by_kind[kind] = totals.counts_by_kind.get(kind, 0) + mult
            if op == "dot":
                lhs_dims = dims.get(ins.operands[0]) if ins.operands else None
                if lhs_dims is not None:
                    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
                    K = 1
                    for idx in ([int(x) for x in m.group(1).split(",") if x] if m else []):
                        if idx < len(lhs_dims):
                            K *= lhs_dims[idx]
                    totals.flops += 2.0 * _elems_of(ins.result) * K * mult
                totals.n_dots += 1
            elif op == "convolution":
                rhs_dims = dims.get(ins.operands[1]) if len(ins.operands) > 1 else None
                kflops = 1
                if rhs_dims:
                    kprod = 1
                    for d in rhs_dims:
                        kprod *= d
                    kflops = max(1, kprod // (max(rhs_dims) if rhs_dims else 1))
                totals.flops += 2.0 * _elems_of(ins.result) * kflops * mult
            if traffic and op not in _SKIP_IO:
                opb = sum(_bytes_of(sym.get(o, [])) for o in ins.operands)
                rb = _bytes_of(ins.result)
                totals.traffic_bytes += (opb + rb) * mult
        on_stack.discard(cname)

    walk(entry_name, 1.0)
    return totals
