"""Sharding policy: PartitionSpecs for params, optimizer state, batches, caches.

Baseline policy (the §Perf hillclimbs mutate this):

* tensor-parallel over ``model``: attention heads, FFN hidden, experts, vocab;
* batch over ``(pod, data)``;
* FSDP (weight sharding over ``data``) for archs flagged ``cfg.fsdp``;
* optimizer state ALWAYS owner-sharded over ``data`` on top of the param spec
  (ZeRO-1) — the STAR "single-master" dense update;
* KV caches: kv-heads over ``model`` when divisible, else sequence-sharded;
* SSM params/state replicated over ``model`` (head counts are not divisible).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(n: int, mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0 and n >= mesh.shape[axis]


def add_data_axis(spec: P, shape: tuple, mesh, min_size: int = 1 << 20) -> P:
    """ZeRO-style: shard the largest free dim over `data` if profitable."""
    if "data" not in mesh.axis_names:
        return spec
    flat = []
    for e in spec:
        flat.extend(e if isinstance(e, tuple) else (e,))
    if "data" in flat:
        return spec
    size = 1
    for s in shape:
        size *= s
    if size < min_size:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best, best_dim = -1, -1
    for i, (s, e) in enumerate(zip(shape, entries)):
        if e is None and s % mesh.shape["data"] == 0 and s > best:
            best, best_dim = s, i
    if best_dim < 0:
        return spec
    entries[best_dim] = "data"
    return P(*entries)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------
def param_specs(cfg: ArchConfig, param_tree, mesh):
    """param_tree: pytree of arrays or ShapeDtypeStructs."""

    def spec_for(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        L = (cfg.n_layers,) if name.startswith("layers/") else ()
        pre = (None,) * len(L)

        def p(*rest):
            return P(*pre, *rest)

        sp = P(*((None,) * len(shape)))
        vocab_tp = (not cfg.batch_over_model) and _div(cfg.padded_vocab, mesh, "model")
        if "norm" in name or "A_log" in name or name.endswith("D") or "dt_bias" in name \
                or "conv_" in name:
            sp = P(*((None,) * len(shape)))
        elif name == "embed":
            sp = P("model", None) if vocab_tp else P(None, None)
        elif name == "lm_head":
            sp = P(None, "model") if vocab_tp else P(None, None)
        elif "frontend" in name:
            sp = P(None, None)
        elif name.endswith("attn/wq"):
            sp = p(None, "model", None) if _div(cfg.n_heads_padded, mesh, "model") else p(None, None, None)
        elif name.endswith("attn/wk") or name.endswith("attn/wv"):
            sp = p(None, "model", None) if _div(cfg.n_kv_heads_padded, mesh, "model") else p(None, None, None)
        elif name.endswith("attn/wo"):
            sp = p("model", None, None) if _div(cfg.n_heads_padded, mesh, "model") else p(None, None, None)
        elif name.endswith("attn/w_uq") or name.endswith("attn/w_uk") or name.endswith("attn/w_uv"):
            sp = p(None, "model", None) if _div(cfg.n_heads_padded, mesh, "model") else p(None, None, None)
        elif name.endswith("attn/w_dq") or name.endswith("attn/w_dkv") or name.endswith("attn/w_kr"):
            sp = p(None, None)
        elif "mlp/w_up" in name or "mlp/w_gate" in name:
            sp = p(None, "model") if _div(cfg.d_ff, mesh, "model") else p(None, None)
        elif "mlp/w_down" in name:
            sp = p("model", None) if _div(cfg.d_ff, mesh, "model") else p(None, None)
        elif "moe/router" in name:
            sp = p(None, None)
        elif "moe/" in name:  # (L, E, a, b) expert weights: experts over model
            sp = p("model", None, None) if _div(cfg.n_experts, mesh, "model") else p(None, None, None)
        elif "ssm/" in name:
            sp = P(*((None,) * len(shape)))

        # batch_over_model archs use the model axis for DATA parallelism —
        # any weight sharded over 'model' there would conflict (same axis on
        # both operand batch and weight) and force giant reshards.
        if cfg.batch_over_model:
            sp = P(*((None,) * len(shape)))
        # embed/lm_head stay vocab-sharded only: GSPMD partitions gathers over
        # a 1-axis-sharded table cleanly but replicates 2-axis-sharded lookups.
        # Norm/scale vectors are too small to be worth a per-layer gather.
        if cfg.fsdp and name not in ("embed", "lm_head") and "norm" not in name:
            sp = add_data_axis(sp, shape, mesh)
        return sp

    return jax.tree_util.tree_map_with_path(spec_for, param_tree)


def opt_specs(cfg: ArchConfig, opt_tree, pspecs, mesh):
    """Optimizer state: param spec + forced `data` owner-sharding (ZeRO-1)."""

    def spec_for(path, leaf):
        name = _path_str(path)
        if name == "step":
            return P()
        # strip the leading master/m/v key, reuse the param spec
        sub = jax.tree_util.tree_map(lambda x: x, pspecs)
        node = sub
        for k in path[1:]:
            key = getattr(k, "key", getattr(k, "idx", None))
            node = node[key]
        return add_data_axis(node, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, opt_tree)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------
def data_specs(batch_tree, mesh, cfg: ArchConfig | None = None,
               kind: str = "train"):
    import numpy as np
    ba = batch_axes(mesh)
    if (cfg is not None and cfg.batch_over_model and kind in ("train", "prefill")
            and "model" in mesh.axis_names):
        ba = ba + ("model",)
    nb = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1

    def spec_for(path, leaf):
        B = leaf.shape[0]
        rest = (None,) * (len(leaf.shape) - 1)
        if ba and B % nb == 0:
            return P(ba, *rest)
        # fall back to (pod, data) only
        ba2 = batch_axes(mesh)
        nb2 = int(np.prod([mesh.shape[a] for a in ba2])) if ba2 else 1
        if ba2 and B % nb2 == 0:
            return P(ba2, *rest)
        return P(None, *rest)

    return jax.tree_util.tree_map_with_path(spec_for, batch_tree)


def cache_specs(cfg: ArchConfig, cache_tree, mesh):
    """KV cache: batch over (pod,data); kv heads over model if divisible,
    else sequence-sharded over model (split-K decode)."""
    ba = batch_axes(mesh)
    import numpy as np
    nb = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1

    def spec_for(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if name == "pos" or "slot_pos" in name:
            return P(*((None,) * len(shape)))
        if name.endswith("/k") or name.endswith("/v"):
            # (L, B, S_alloc, Hkv, Dh)
            bspec = ba if (ba and shape[1] % nb == 0) else None
            if _div(cfg.n_kv_heads_padded, mesh, "model"):
                return P(None, bspec, None, "model", None)
            if shape[2] % mesh.shape["model"] == 0:
                return P(None, bspec, "model", None, None)
            return P(None, bspec, None, None, None)
        if "c_kv" in name or "k_rope" in name:
            # (L, B, S_alloc, r)
            bspec = ba if (ba and shape[1] % nb == 0) else None
            if shape[2] % mesh.shape["model"] == 0:
                return P(None, bspec, "model", None)
            return P(None, bspec, None, None)
        if "ssm/h" in name or "ssm/conv" in name:
            bspec = ba if (ba and shape[1] % nb == 0) else None
            return P(None, bspec, *((None,) * (len(shape) - 2)))
        # fallback: shard batch dim if present at axis 1
        if len(shape) >= 2 and ba and shape[1] % nb == 0:
            return P(None, ba, *((None,) * (len(shape) - 2)))
        return P(*((None,) * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
