import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first init,
and the production meshes need 512 placeholder host devices.  Run:

    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]

Each successful cell writes results/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis and the derived roofline terms (single-pod
only — §Roofline reads these).
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ALL_ARCHS, SHAPES, cell_applicable, get_arch
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import bundle_for

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def cell_path(arch: str, shape: str, multi_pod: bool, tag: str = "") -> Path:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{tag}" if tag else ""
    return RESULTS / f"{arch}__{shape}__{mesh_name}{suffix}.json"


def run_cell(arch: str, shape: str, multi_pod: bool, tag: str = "",
             overrides: dict | None = None) -> dict:
    cfg = get_arch(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPES[shape]
    ok, why = cell_applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape, "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        fn, shapes, in_sh, out_sh, donate = bundle_for(cfg, cell, mesh)
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        print(compiled.memory_analysis())
        print({k: cost.get(k) for k in ("flops", "bytes accessed")})
        hlo = compiled.as_text()

    n_chips = int(len(mesh.devices.flat))
    roof = rl.derive(cost, hlo, n_chips, rl.model_flops_for(cfg, cell))
    argb = getattr(mem, "argument_size_in_bytes", 0)
    outb = getattr(mem, "output_size_in_bytes", 0)
    tmpb = getattr(mem, "temp_size_in_bytes", 0)
    peak = argb + tmpb
    hbm = 16 * (1 << 30)
    rec = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod, "tag": tag,
        "status": "ok", "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "mem": {"argument_bytes": int(argb), "output_bytes": int(outb),
                "temp_bytes": int(tmpb), "peak_bytes": int(peak),
                "fits_16GiB": bool(peak <= hbm)},
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed",
                                          "transcendentals")},
        "roofline": roof.to_dict(),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (int/float/bool parsed)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "False"):
            v = v == "True"
        overrides[k] = v

    RESULTS.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else ALL_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_skip = n_fail = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                path = cell_path(arch, shape, multi_pod, args.tag)
                if args.skip_done and path.exists():
                    print(f"[done] {path.name}")
                    continue
                label = f"{arch} x {shape} x {'2x16x16' if multi_pod else '16x16'}"
                print(f"=== {label}", flush=True)
                try:
                    rec = run_cell(arch, shape, multi_pod, args.tag,
                                   overrides=overrides or None)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                status = rec["status"]
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_fail += status == "error"
                path.write_text(json.dumps(rec, indent=1))
                if status == "ok":
                    r = rec["roofline"]
                    print(f"    ok  peak={rec['mem']['peak_bytes']/2**30:.2f}GiB "
                          f"compute={r['compute_s']*1e3:.2f}ms mem={r['memory_s']*1e3:.2f}ms "
                          f"coll={r['collective_s']*1e3:.2f}ms -> {r['bottleneck']}",
                          flush=True)
                else:
                    print(f"    {status}: {rec.get('reason', rec.get('error'))}",
                          flush=True)
    print(f"dryrun summary: ok={n_ok} skipped={n_skip} failed={n_fail}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
