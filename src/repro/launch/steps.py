"""Step builders shared by the dry-run, the trainer and the serving engine.

Each builder returns ``(fn, in_shapes, in_shardings, out_shardings, donate)``
ready for ``jax.jit(...).lower(*in_shapes)``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.data.pipeline import input_specs
from repro.launch import sharding as shd
from repro.models import transformer as tf
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_fn(cfg: ArchConfig, mesh, hp: AdamWConfig = AdamWConfig()):
    """Single fused step; with cfg.microbatches > 1, gradients accumulate in
    fp32 across a lax.scan of microbatches (the activation working set shrinks
    by the same factor — how large archs fit the 16 GiB HBM budget)."""

    def grad_of(params, b):
        def lf(p):
            return tf.loss_fn(p, b, cfg, mesh=mesh)
        return jax.value_and_grad(lf, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        k = cfg.microbatches
        B = jax.tree.leaves(batch)[0].shape[0]
        if B % k != 0:                 # smoke/tiny batches: no accumulation
            k = 1
        if k == 1:
            (loss, metrics), grads = grad_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape((k, a.shape[0] // k) + a.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, b):
                gsum, lsum = carry
                (loss, metrics), grads = grad_of(params, b)
                gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                    gsum, grads)
                return (gsum, lsum + loss), metrics

            (gsum, lsum), metrics = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / k, gsum)
            loss = lsum / k
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        new_params, new_opt, gnorm = adamw_update(params, grads, opt_state, hp)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_fn(cfg: ArchConfig, mesh, alloc_len: int | None = None):
    def prefill_step(params, batch):
        return tf.prefill(params, batch, cfg, mesh=mesh, alloc_len=alloc_len)

    return prefill_step


def make_decode_fn(cfg: ArchConfig, mesh):
    def decode(params, cache, batch):
        return tf.decode_step(params, cache, batch["tokens"], cfg, mesh=mesh)

    return decode


# ---------------------------------------------------------------------------
# lowering bundles for the dry-run
# ---------------------------------------------------------------------------
def opt_shapes(cfg: ArchConfig, param_shapes):
    return jax.eval_shape(init_opt_state, param_shapes)


def train_bundle(cfg: ArchConfig, cell: ShapeCell, mesh):
    pshapes = tf.params_shape(cfg)
    oshapes = opt_shapes(cfg, pshapes)
    bshapes = input_specs(cfg, cell)
    pspec = shd.param_specs(cfg, pshapes, mesh)
    ospec = shd.opt_specs(cfg, oshapes, pspec, mesh)
    bspec = shd.data_specs(bshapes, mesh, cfg, cell.kind)
    fn = make_train_fn(cfg, mesh)
    in_sh = (shd.named(mesh, pspec), shd.named(mesh, ospec), shd.named(mesh, bspec))
    metric_sh = {k: NamedSharding(mesh, P()) for k in ("ce", "aux", "loss", "grad_norm")}
    out_sh = (in_sh[0], in_sh[1], metric_sh)
    return fn, (pshapes, oshapes, bshapes), in_sh, out_sh, (0, 1)


def prefill_bundle(cfg: ArchConfig, cell: ShapeCell, mesh):
    pshapes = tf.params_shape(cfg)
    bshapes = input_specs(cfg, cell)
    pspec = shd.param_specs(cfg, pshapes, mesh)
    bspec = shd.data_specs(bshapes, mesh, cfg, cell.kind)
    fn = make_prefill_fn(cfg, mesh, alloc_len=cell.seq_len)
    cshapes = jax.eval_shape(
        lambda: tf.init_cache(cfg, cell.global_batch, cell.seq_len))
    cspec = shd.cache_specs(cfg, cshapes, mesh)
    logits_spec = shd.data_specs(
        {"x": jax.ShapeDtypeStruct((cell.global_batch, 1, cfg.vocab_size),
                                   jnp.bfloat16)}, mesh)["x"]
    in_sh = (shd.named(mesh, pspec), shd.named(mesh, bspec))
    out_sh = (NamedSharding(mesh, logits_spec), shd.named(mesh, cspec))
    return fn, (pshapes, bshapes), in_sh, out_sh, ()


def decode_bundle(cfg: ArchConfig, cell: ShapeCell, mesh):
    pshapes = tf.params_shape(cfg)
    bshapes = input_specs(cfg, cell)
    cshapes = jax.eval_shape(
        lambda: tf.init_cache(cfg, cell.global_batch, cell.seq_len))
    pspec = shd.param_specs(cfg, pshapes, mesh)
    bspec = shd.data_specs(bshapes, mesh, cfg, cell.kind)
    cspec = shd.cache_specs(cfg, cshapes, mesh)
    fn = make_decode_fn(cfg, mesh)
    logits_spec = shd.data_specs(
        {"x": jax.ShapeDtypeStruct((cell.global_batch, 1, cfg.vocab_size),
                                   jnp.bfloat16)}, mesh)["x"]
    in_sh = (shd.named(mesh, pspec), shd.named(mesh, cspec), shd.named(mesh, bspec))
    out_sh = (NamedSharding(mesh, logits_spec), shd.named(mesh, cspec))
    return fn, (pshapes, cshapes, bshapes), in_sh, out_sh, (1,)


def bundle_for(cfg: ArchConfig, cell: ShapeCell, mesh):
    if cell.kind == "train":
        return train_bundle(cfg, cell, mesh)
    if cell.kind == "prefill":
        return prefill_bundle(cfg, cell, mesh)
    if cell.kind == "decode":
        return decode_bundle(cfg, cell, mesh)
    raise ValueError(cell.kind)
