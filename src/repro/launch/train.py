"""Training driver: PYTHONPATH=src python -m repro.launch.train --arch <id>
[--smoke] [--steps N] [--seq S] [--batch B] [--ckpt DIR]"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--steps-per-epoch", type=int, default=8)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_arch(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    tr = Trainer(cfg, mesh, TrainerConfig(
        seq_len=args.seq, batch=args.batch, checkpoint_dir=args.ckpt,
        steps_per_epoch=args.steps_per_epoch))
    if args.ckpt:
        meta = tr.restore_from_disk()
        if meta:
            print(f"resumed from step {meta['step']}")
    for chunk in range(0, args.steps, args.steps_per_epoch):
        m = tr.run(min(args.steps_per_epoch, args.steps - chunk))
        print(f"step {m['step']:5d} loss {m['loss']:.4f} "
              f"ce {m['ce']:.4f} gnorm {m['grad_norm']:.3f}", flush=True)
    print(f"done: {tr.step} steps, {tr.commit_log.fences} epoch fences, "
          f"{tr.straggler_events} straggler events")


if __name__ == "__main__":
    main()
