"""Serving driver: PYTHONPATH=src python -m repro.launch.serve --arch <id>
[--smoke] [--batch B] [--prompt-len S] [--gen N]"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as tf
    from repro.serve.engine import ServeEngine

    cfg = get_arch(args.arch, smoke=args.smoke)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")
    mesh = make_host_mesh()
    params = tf.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, mesh=mesh,
                      max_len=args.prompt_len + args.gen)
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({eng.stats.decoded_tokens / dt:.1f} tok/s)")
    print("first row:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
