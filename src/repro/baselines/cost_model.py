"""Calibrated cluster cost model for the evaluation baselines (§7).

The transaction *algorithms* (OCC rounds, lock conflicts, replication
streams) execute for real in the vectorized engine; absolute wall-clock
throughput on a 4-node EC2 cluster is then derived from:

  * measured per-transaction CPU cost on this host (calibration),
  * the paper's hardware envelope: 12 workers/node, 4.8 Gbit/s NIC,
    ~100 us same-AZ RTT.

EXPERIMENTS.md labels every number derived through this model as
"model-derived (calibrated)". Ratios between systems — what Fig. 11/13/16
actually claim — depend only on the message/byte patterns and measured
conflict behaviour, not on the absolute CPU scale factor.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Network:
    bandwidth_Bps: float = 4.8e9 / 8       # 4.8 Gbit/s (paper, iperf)
    rtt_s: float = 100e-6                  # same-AZ round trip
    def transfer_s(self, nbytes: float) -> float:
        return nbytes / self.bandwidth_Bps


@dataclass(frozen=True)
class Node:
    workers: int = 12                      # paper: 12 worker threads/node


@dataclass
class Calibration:
    """Per-txn CPU costs measured on this host (seconds), plus conflict
    telemetry measured from the real executors."""
    t_single_cpu: float                    # single-partition txn, no CC
    t_cross_cpu: float                     # cross-partition txn under OCC
    retry_factor: float = 0.0              # measured retries per committed txn
    value_bytes_per_txn: float = 0.0       # replication payload
    op_bytes_per_txn: float = 0.0          # hybrid replication payload
    remote_reads_per_cross: float = 2.0    # measured avg remote ops


def star_throughput(n_nodes: int, frac_cross: float, cal: Calibration,
                    net: Network = Network(), node: Node = Node(),
                    iteration_s: float = 0.010, hybrid: bool = True,
                    sync_replication: bool = False) -> float:
    """STAR (§6.3 model + fence/network overheads).

    In tau_p all n nodes commit singles in parallel; in tau_s one master
    commits the cross txns. Replication bandwidth can throttle (TPC-C
    saturates the NIC at 4 nodes, §7.6); two fences cost ~2 RTT each.
    """
    P = min(max(frac_cross, 0.0), 1.0)
    rate_p = n_nodes * node.workers / cal.t_single_cpu          # txn/s
    t_cross = cal.t_cross_cpu * (1.0 + cal.retry_factor)
    if sync_replication:
        t_cross += net.rtt_s                                     # hold locks
    rate_s = node.workers / t_cross
    # Eq (5): time shares solved per Eqs (1)-(2)
    denom = (1.0 - P) * rate_s + P * rate_p
    tau_s = iteration_s * P * rate_p / denom if denom > 0 else 0.0
    tau_p = iteration_s - tau_s
    fence_s = 4 * net.rtt_s                                      # 2 fences
    committed = tau_p * rate_p + tau_s * rate_s
    thr = committed / (iteration_s + fence_s)
    # replication bandwidth cap (writes fan out to f+k-1 replicas -> NIC-bound
    # at the master during tau_s, at every node during tau_p)
    bytes_per_txn = cal.op_bytes_per_txn if hybrid else cal.value_bytes_per_txn
    if bytes_per_txn > 0:
        cap = net.bandwidth_Bps / bytes_per_txn
        thr = min(thr, cap)
    return thr


def pb_occ_throughput(frac_cross: float, cal: Calibration,
                      net: Network = Network(), node: Node = Node(),
                      sync_replication: bool = False) -> float:
    """Primary/backup non-partitioned Silo: one primary executes everything
    (insensitive to P); sync replication holds write locks for one RTT."""
    # every txn runs under single-node OCC — same measured conflict regime
    t = cal.t_cross_cpu * (1.0 + cal.retry_factor)
    if sync_replication:
        t = t + net.rtt_s
    thr = node.workers / t
    if cal.value_bytes_per_txn > 0:
        thr = min(thr, net.bandwidth_Bps / cal.value_bytes_per_txn)
    return thr


def dist_throughput(n_nodes: int, frac_cross: float, cal: Calibration,
                    protocol: str = "occ", net: Network = Network(),
                    node: Node = Node(), sync_replication: bool = False) -> float:
    """Partitioning-based systems (Dist.OCC / Dist.S2PL, NO_WAIT).

    Singles run locally; cross txns pay remote-read round trips during
    execution plus commit-protocol round trips: 2PC (2 RTT) when synchronous,
    1 validation round under async + epoch group commit. NO_WAIT aborts
    (measured retry factor) multiply the work.
    """
    P = min(max(frac_cross, 0.0), 1.0)
    t_single = cal.t_single_cpu + (net.rtt_s if sync_replication else 0.0)
    rounds = cal.remote_reads_per_cross * net.rtt_s
    commit = (2 * net.rtt_s) if sync_replication else net.rtt_s
    retry = 1.0 + cal.retry_factor * (2.0 if protocol == "s2pl" else 1.0)
    t_cross = (cal.t_cross_cpu + rounds + commit) * retry
    avg = (1 - P) * t_single + P * t_cross
    thr = n_nodes * node.workers / avg
    if cal.value_bytes_per_txn > 0:
        thr = min(thr, n_nodes * net.bandwidth_Bps / cal.value_bytes_per_txn)
    return thr


def calvin_throughput(n_nodes: int, frac_cross: float, cal: Calibration,
                      lock_threads: int, net: Network = Network(),
                      node: Node = Node()) -> float:
    """Calvin-x (§7.3): x lock-manager threads, 12-x workers. Deterministic:
    no aborts, inputs replicated (cheap); cross txns still need remote reads.
    The lock manager grants ~one txn per x-thread per grant cycle; more lock
    threads help until workers starve."""
    workers = max(node.workers - lock_threads, 1)
    grant_rate = lock_threads / (cal.t_single_cpu * 0.5)      # grants/s
    P = min(max(frac_cross, 0.0), 1.0)
    t_exec = (1 - P) * cal.t_single_cpu + P * (
        cal.t_cross_cpu + cal.remote_reads_per_cross * net.rtt_s * 0.5)
    exec_rate = workers / t_exec
    sync_penalty = 1.0 / (1.0 + 0.05 * lock_threads * P)
    return n_nodes * min(grant_rate, exec_rate) * sync_penalty
