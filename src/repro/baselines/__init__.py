from repro.baselines.calibrate import calibrate
from repro.baselines.cost_model import (Calibration, Network, Node,
                                        calvin_throughput, dist_throughput,
                                        pb_occ_throughput, star_throughput)

__all__ = ["Calibration", "Network", "Node", "calibrate",
           "calvin_throughput", "dist_throughput", "pb_occ_throughput",
           "star_throughput"]
