"""Measure per-transaction CPU costs + conflict telemetry on this host.

Runs the REAL executors (jitted, warmed) over the requested workload and
returns a :class:`Calibration` for the cluster cost model.  The measured
retry factor and replication bytes come from actual OCC rounds and actual
replication streams — only the wall-clock scale is host-specific.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.cost_model import Calibration
from repro.core.partitioned import run_partitioned
from repro.core.single_master import run_single_master


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return (time.perf_counter() - t0) / reps, out


def calibrate(workload: str = "ycsb", n_partitions: int = 4,
              n_txns: int = 2048, cross_ratio: float = 0.5,
              seed: int = 0) -> Calibration:
    if workload == "ycsb":
        from repro.db import ycsb
        cfg = ycsb.YCSBConfig(n_partitions=n_partitions,
                              records_per_partition=100_000,
                              cross_ratio=cross_ratio, seed=seed)
        batch = ycsb.make_batch(cfg, n_txns, seed=seed)
        R = cfg.records_per_partition
        row_bytes_txn = float(np.mean(np.sum(
            batch["row_bytes"][None, :] * 0 + ycsb.ROW_BYTES, axis=0)))
        value_bytes_txn = 1 * (ycsb.ROW_BYTES + 16)       # 1 write op/txn
        op_bytes_txn = value_bytes_txn                     # no YCSB savings
    else:
        from repro.db import tpcc
        cfg = tpcc.TPCCConfig(n_partitions=n_partitions, n_items=10_000,
                              cust_per_district=300, order_ring=256,
                              neworder_cross=cross_ratio,
                              payment_cross=cross_ratio, seed=seed)
        state = tpcc.TPCCState(cfg)
        batch = tpcc.make_batch(cfg, state, n_txns, seed=seed)
        R = cfg.rows_per_partition
        wmask_p = batch["ptxn"]["kind"] > 0
        per_txn_v = (np.sum(batch["p_row_bytes"] * wmask_p + 16 * wmask_p)
                     / max(batch["n_single"], 1))
        per_txn_o = (np.sum(batch["p_op_bytes"] * wmask_p + 12 * wmask_p)
                     / max(batch["n_single"], 1))
        value_bytes_txn = float(per_txn_v)
        op_bytes_txn = float(per_txn_o)

    cross = jax.tree.map(jnp.asarray, batch["cross"])
    epoch = jnp.uint32(1)

    P = batch["ptxn"]["valid"].shape[0]
    val = jnp.zeros((P, R, 10), jnp.int32)
    tid = jnp.zeros((P, R), jnp.uint32)

    fval = val.reshape(P * R, 10)
    ftid = tid.reshape(P * R)
    jit_sm = jax.jit(run_single_master, static_argnames=("max_rounds",))
    # (a) retry factor at REAL concurrency: a cluster validates ~48 txns
    # concurrently (4 nodes x 12 workers), not the whole batch in lockstep —
    # measure conflicts on a 48-lane slice (paper's contention regime).
    lanes = 48
    small = jax.tree.map(lambda a: a[:lanes], cross)
    _, out = _time(jit_sm, fval, ftid, small, epoch, max_rounds=16, reps=1)
    sstats = out[3]
    n_small = max(int(sstats["committed"]), 1)
    retry_factor = float(sstats["retries"]) / n_small

    # (b) conflict-free batch of the same geometry: pure execution cost.
    # NOTE: per-txn cost is calibrated from the SAME vectorized executor for
    # both phases — the serial per-partition scan has different vectorization
    # efficiency on this 1-core host, which would otherwise contaminate the
    # algorithmic single-vs-cross ratio. A single-partition transaction does
    # the same read/compute/write work minus lock+validate; Silo reports that
    # commit-protocol share at ~25% -> t_single = 0.75 * conflict-free cost.
    B, Mops = cross["row"].shape
    nc = dict(cross)
    nc["row"] = jnp.asarray(
        (np.arange(B)[:, None] * Mops + np.arange(Mops)[None, :])
        % (P * R), jnp.int32)
    t_nc, out_nc = _time(jit_sm, fval, ftid, jax.tree.map(jnp.asarray, nc),
                         epoch, max_rounds=8)
    n_nc = max(int(out_nc[3]["committed"]), 1)
    t_cross = t_nc / n_nc          # pure execution; models add (1+retry)
    t_single = 0.75 * t_cross

    remote = 3.0 if workload != "ycsb" else 9.0 * (1 - 1 / max(n_partitions, 1))

    return Calibration(
        t_single_cpu=t_single,
        t_cross_cpu=t_cross,
        retry_factor=retry_factor,
        value_bytes_per_txn=value_bytes_txn,
        op_bytes_per_txn=op_bytes_txn,
        remote_reads_per_cross=remote,
    )
