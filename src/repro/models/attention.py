"""Attention variants: GQA (with partial RoPE / sliding window), and MLA.

Prefill/train use an XLA-level "flash" pattern: queries are processed in
chunks with a ``lax.map`` so the (chunk, S) score tile — not the full (S, S)
matrix — is the peak intermediate.  Decode keeps a slot-indexed KV cache that
supports both full caches and ring buffers (sliding window), with absolute
positions stored per slot so RoPE is applied exactly once, at write time.

MLA decode uses the absorbed form: the latent c_kv is the cache and the
per-head up-projections are folded into the query/output side.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, normal_init, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# shared chunked attention core
# ---------------------------------------------------------------------------
def _attend(q, k, v, q_pos, k_pos, *, causal, window, scale,
            scores_bf16=False):
    """q: (B, Sq, H, Dh) ; k, v: (B, Sk, H, Dh[v]) — kv already head-expanded.

    Head-expanded layout (instead of a (Hkv, G) reshape) keeps the head axis
    cleanly shardable over the ``model`` mesh axis.  fp32 softmax by default;
    scores_bf16 halves the score-tile HBM traffic (perf knob — the Pallas
    flash kernel makes this moot by keeping tiles in VMEM).
    """
    acc_t = jnp.bfloat16 if scores_bf16 else jnp.float32
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=acc_t) * jnp.asarray(scale, acc_t)
    mask = k_pos[None, :] >= 0                                   # valid slots
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    neg = jnp.asarray(-3e38 if not scores_bf16 else -3e38, acc_t)
    scores = jnp.where(mask[None, None], scores, neg)
    m = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    e = jnp.exp((scores - m).astype(acc_t))
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def expand_kv(k, groups: int, index_map=None):
    """(B, S, Hkv, Dh) -> (B, S, H, Dh).  With padded-head TP the q->kv
    assignment is an explicit static gather (grouping is irregular)."""
    if index_map is not None:
        return k[:, :, jnp.asarray(index_map, jnp.int32), :]
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def chunked_attention(q, k, v, q_pos, k_pos, *, causal, window, scale, chunk,
                      scores_bf16=False):
    """Query-chunked exact attention.  Shapes as in :func:`_attend`."""
    B, Sq, H, Dh = q.shape
    if Sq <= chunk or Sq % chunk != 0:
        return _attend(q, k, v, q_pos, k_pos, causal=causal, window=window,
                       scale=scale, scores_bf16=scores_bf16)
    n = Sq // chunk
    qc = q.reshape(B, n, chunk, H, Dh).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(n, chunk)

    # checkpoint each chunk: backward recomputes the (chunk, Sk) score tile
    # instead of saving a stacked (n, B, H, chunk, Sk) probs tensor.
    # NOTE (§Perf iterations 1/3): the while-loop body here degrades GSPMD
    # sharding (full-head f32 q/k gathers per iteration); a static unroll was
    # probed and REGRESSED (co-live chunk buffers, worse collectives), so the
    # map stays and the real fixes are (a) attn_chunk = seq at train shapes
    # (loop-free) and (b) the Pallas flash kernel for long prefill.
    @jax.checkpoint
    def one(args):
        qi, pi = args
        return _attend(qi, k, v, pi, k_pos, causal=causal, window=window,
                       scale=scale, scores_bf16=scores_bf16)

    out = jax.lax.map(one, (qc, pc))                     # (n, B, chunk, H, Dhv)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, out.shape[-1])


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg, dtype) -> dict:
    d, Dh = cfg.d_model, cfg.d_head
    H, Hkv = cfg.n_heads_padded, cfg.n_kv_heads_padded
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": normal_init(ks[0], (d, H, Dh), s, dtype),
        "wk": normal_init(ks[1], (d, Hkv, Dh), s, dtype),
        "wv": normal_init(ks[2], (d, Hkv, Dh), s, dtype),
        "wo": normal_init(ks[3], (H, Dh, d), (H * Dh) ** -0.5, dtype),
    }


def _head_mask(cfg, dtype):
    """(H_pad,) mask: pad heads contribute zero and receive zero grads."""
    if cfg.n_heads_padded == cfg.n_heads:
        return None
    return (jnp.arange(cfg.n_heads_padded) < cfg.n_heads).astype(dtype)


def attention_forward(p, x, cfg, positions):
    """Full-sequence attention (train / prefill).

    x: (B, S, d); positions: (S,) int32.  Returns (y, (k, v)) with k/v post-RoPE
    for cache seeding.
    """
    B, S, _ = x.shape
    Dh = cfg.d_head
    H, Hkv = cfg.n_heads_padded, cfg.n_kv_heads_padded
    idx = cfg.kv_index_map() if cfg.pad_heads_to else None
    G = H // Hkv if idx is None else 1
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    q = apply_rope(q, positions[None, :], cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k, positions[None, :], cfg.rope_theta, cfg.rope_fraction)
    out = chunked_attention(q, expand_kv(k, G, idx), expand_kv(v, G, idx),
                            positions, positions,
                            causal=cfg.causal, window=cfg.sliding_window,
                            scale=Dh ** -0.5, chunk=cfg.attn_chunk,
                            scores_bf16=cfg.attn_scores_bf16)
    mask = _head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    return y, (k, v)


def attention_decode(p, x, cache, cfg):
    """One-token decode. x: (B, 1, d).

    cache: {"k": (B, Salloc, Hkv, Dh), "v": ..., "slot_pos": (Salloc,) int32,
            "pos": () int32 — absolute position of the incoming token}.
    """
    B = x.shape[0]
    Dh = cfg.d_head
    H, Hkv = cfg.n_heads_padded, cfg.n_kv_heads_padded
    idx = cfg.kv_index_map() if cfg.pad_heads_to else None
    G = H // Hkv if idx is None else 1
    pos = cache["pos"]
    S_alloc = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k_new = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v_new = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    pvec = jnp.full((1,), pos, dtype=jnp.int32)
    q = apply_rope(q, pvec[None], cfg.rope_theta, cfg.rope_fraction)
    k_new = apply_rope(k_new, pvec[None], cfg.rope_theta, cfg.rope_fraction)

    slot = jnp.mod(pos, S_alloc)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], jnp.full((1,), pos, jnp.int32), (slot,))

    out = _attend(q, expand_kv(k, G, idx), expand_kv(v, G, idx), pvec, slot_pos,
                  causal=cfg.causal, window=cfg.sliding_window,
                  scale=Dh ** -0.5, scores_bf16=cfg.attn_scores_bf16)
    mask = _head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    new_cache = {"k": k, "v": v, "slot_pos": slot_pos, "pos": pos}  # pos bumped by caller
    return y, new_cache


def init_attn_cache(cfg, batch, seq_len, dtype):
    """Allocate an empty slot cache; sliding-window archs get a ring buffer."""
    S_alloc = seq_len if cfg.sliding_window is None else min(seq_len, cfg.sliding_window)
    shape = (batch, S_alloc, cfg.n_kv_heads_padded, cfg.d_head)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "slot_pos": jnp.full((S_alloc,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 family)
# ---------------------------------------------------------------------------
def init_mla(key, cfg, dtype) -> dict:
    d, H = cfg.d_model, cfg.n_heads_padded
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    return {
        "w_dq": normal_init(ks[0], (d, qr), s, dtype),
        "q_norm": jnp.ones((qr,), dtype),
        "w_uq": normal_init(ks[1], (qr, H, dn + dr), qr ** -0.5, dtype),
        "w_dkv": normal_init(ks[2], (d, kvr), s, dtype),
        "kv_norm": jnp.ones((kvr,), dtype),
        "w_kr": normal_init(ks[3], (d, dr), s, dtype),
        "w_uk": normal_init(ks[4], (kvr, H, dn), kvr ** -0.5, dtype),
        "w_uv": normal_init(ks[5], (kvr, H, dv), kvr ** -0.5, dtype),
        "wo": normal_init(ks[6], (H, dv, d), (H * dv) ** -0.5, dtype),
    }


def _mla_qkv(p, x, cfg, positions):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rms_norm(x @ p["w_dq"], p["q_norm"])
    q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"])            # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions[None, :], cfg.rope_theta)
    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"])             # (B,S,kvr)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], positions[None, :],
                        cfg.rope_theta)[:, :, 0]              # (B,S,dr)
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(p, x, cfg, positions):
    """Expanded-form MLA for train/prefill. Returns (y, (c_kv, k_rope))."""
    B, S, _ = x.shape
    H = cfg.n_heads_padded
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uk"])     # (B,S,H,dn)
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uv"])          # (B,S,H,dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)            # (B,S,H,dn+dr)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (B, S, H, dr))], axis=-1)
    out = chunked_attention(q, k, v, positions, positions,
                            causal=cfg.causal, window=cfg.sliding_window,
                            scale=(dn + dr) ** -0.5, chunk=cfg.attn_chunk,
                            scores_bf16=cfg.attn_scores_bf16)
    mask = _head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    return jnp.einsum("bshe,hed->bsd", out, p["wo"]), (c_kv, k_rope)


def mla_decode(p, x, cache, cfg):
    """Absorbed-form MLA decode: the cache holds only (c_kv, k_rope)."""
    B = x.shape[0]
    H = cfg.n_heads_padded
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    pos = cache["pos"]
    S_alloc = cache["c_kv"].shape[1]
    pvec = jnp.full((1,), pos, dtype=jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(p, x, cfg, pvec)

    slot = jnp.mod(pos, S_alloc)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_new, (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new, (0, slot, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], jnp.full((1,), pos, jnp.int32), (slot,))

    # absorb W_uk into the query: q_lat (B,1,H,kvr)
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"])
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, c_kv, preferred_element_type=jnp.float32)
              + jnp.einsum("bshe,bte->bhst", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * (dn + dr) ** -0.5
    mask = (slot_pos[None, :] >= 0) & (slot_pos[None, :] <= pvec[:, None])
    scores = jnp.where(mask[:, None], scores, NEG_INF)        # (B,H,1,S)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhst,btr->bshr", probs.astype(c_kv.dtype), c_kv)
    out = jnp.einsum("bshr,rhe->bshe", out_lat, p["w_uv"])    # (B,1,H,dv)
    mask = _head_mask(cfg, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, None]
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    return y, {"c_kv": c_kv, "k_rope": k_rope, "slot_pos": slot_pos, "pos": pos}


def init_mla_cache(cfg, batch, seq_len, dtype):
    S_alloc = seq_len if cfg.sliding_window is None else min(seq_len, cfg.sliding_window)
    return {
        "c_kv": jnp.zeros((batch, S_alloc, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, S_alloc, cfg.qk_rope_head_dim), dtype),
        "slot_pos": jnp.full((S_alloc,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }
