"""Model assembly: blocks -> scan-over-layers LM with train/prefill/decode.

Layer parameters are stacked along a leading L axis and consumed with
``lax.scan`` so compile time is depth-independent (critical for the 512-device
dry-runs on this single-core host).  Blocks are rematerialized
(``jax.checkpoint``) when cfg.remat is set.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ArchConfig, BLOCK_ATTN_MLP, BLOCK_ATTN_MOE,
                                BLOCK_HYMBA, BLOCK_MAMBA2, BLOCK_MLA_MLP)
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_lib
from repro.models.layers import init_mlp, mlp_forward, normal_init, rms_norm


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------
def init_layer(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"norm1": jnp.ones((d,), dtype)}
    if cfg.block != BLOCK_MAMBA2:       # mamba2-130m: one mixer per block, no MLP
        p["norm2"] = jnp.ones((d,), dtype)
    if cfg.block in (BLOCK_ATTN_MLP, BLOCK_ATTN_MOE):
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
    if cfg.block == BLOCK_MLA_MLP:
        p["attn"] = attn.init_mla(ks[0], cfg, dtype)
    if cfg.block in (BLOCK_ATTN_MLP, BLOCK_MLA_MLP):
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_gated, dtype)
    if cfg.block == BLOCK_ATTN_MOE:
        p["moe"] = moe_lib.init_moe(ks[1], cfg, dtype)
    if cfg.block == BLOCK_MAMBA2:
        p["ssm"] = m2.init_mamba2(ks[2], cfg, dtype)
    if cfg.block == BLOCK_HYMBA:
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
        p["ssm"] = m2.init_mamba2(ks[2], cfg, dtype)
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_gated, dtype)
        p["attn_norm"] = jnp.ones((d,), dtype)
        p["ssm_norm"] = jnp.ones((d,), dtype)
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_head, k_layers, k_front = jax.random.split(key, 4)
    params = {
        "embed": normal_init(k_emb, (cfg.padded_vocab, cfg.d_model),
                             cfg.d_model ** -0.5, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(k_head, (cfg.d_model, cfg.padded_vocab),
                                        cfg.d_model ** -0.5, dtype)
    if cfg.frontend != "none":
        params["frontend"] = {
            "proj": normal_init(k_front, (cfg.frontend_dim, cfg.d_model),
                                cfg.frontend_dim ** -0.5, dtype)}
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    return params


def params_shape(cfg: ArchConfig):
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


# ---------------------------------------------------------------------------
# blocks (full-sequence form). Return (x, per-layer cache or None)
# ---------------------------------------------------------------------------
def block_forward(lp, x, cfg, positions, mesh=None, want_cache=False):
    h = rms_norm(x, lp["norm1"])
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if cfg.block in (BLOCK_ATTN_MLP, BLOCK_ATTN_MOE):
        y, (k, v) = attn.attention_forward(lp["attn"], h, cfg, positions)
        if want_cache:
            cache = {"k": k, "v": v}
        x = x + y
    elif cfg.block == BLOCK_MLA_MLP:
        y, (c_kv, k_rope) = attn.mla_forward(lp["attn"], h, cfg, positions)
        if want_cache:
            cache = {"c_kv": c_kv, "k_rope": k_rope}
        x = x + y
    elif cfg.block == BLOCK_MAMBA2:
        y, state = m2.mamba2_forward(lp["ssm"], h, cfg, return_state=want_cache)
        if want_cache:
            cache = {"ssm": state}
        return x + y, cache, aux        # single-mixer block: no MLP half
    elif cfg.block == BLOCK_HYMBA:
        ya, (k, v) = attn.attention_forward(lp["attn"], h, cfg, positions)
        ys, state = m2.mamba2_forward(lp["ssm"], h, cfg, return_state=want_cache)
        y = 0.5 * (rms_norm(ya, lp["attn_norm"]) + rms_norm(ys, lp["ssm_norm"]))
        if want_cache:
            cache = {"attn": {"k": k, "v": v}, "ssm": state}
        x = x + y

    h2 = rms_norm(x, lp["norm2"])
    if cfg.block == BLOCK_ATTN_MOE:
        y2, aux = moe_lib.moe_forward(lp["moe"], h2, cfg, mesh=mesh)
    else:
        y2 = mlp_forward(lp["mlp"], h2, cfg.mlp_act)
    return x + y2, cache, aux


def block_decode(lp, x, layer_cache, cfg, mesh=None):
    """One-token step; layer_cache carries 'pos' injected by the caller."""
    h = rms_norm(x, lp["norm1"])
    new_cache = {}
    if cfg.block in (BLOCK_ATTN_MLP, BLOCK_ATTN_MOE):
        y, new_cache = attn.attention_decode(lp["attn"], h, layer_cache, cfg)
        x = x + y
    elif cfg.block == BLOCK_MLA_MLP:
        y, new_cache = attn.mla_decode(lp["attn"], h, layer_cache, cfg)
        x = x + y
    elif cfg.block == BLOCK_MAMBA2:
        y, st = m2.mamba2_decode(lp["ssm"], h, layer_cache["ssm"], cfg)
        new_cache = {"ssm": st, "pos": layer_cache["pos"]}
        return x + y, new_cache         # single-mixer block: no MLP half
    elif cfg.block == BLOCK_HYMBA:
        ac = dict(layer_cache["attn"]); ac["pos"] = layer_cache["pos"]
        ya, nac = attn.attention_decode(lp["attn"], h, ac, cfg)
        ys, nst = m2.mamba2_decode(lp["ssm"], h, layer_cache["ssm"], cfg)
        y = 0.5 * (rms_norm(ya, lp["attn_norm"]) + rms_norm(ys, lp["ssm_norm"]))
        nac.pop("pos")
        new_cache = {"attn": nac, "ssm": nst, "pos": layer_cache["pos"]}
        x = x + y

    h2 = rms_norm(x, lp["norm2"])
    if cfg.block == BLOCK_ATTN_MOE:
        y2, _ = moe_lib.moe_forward(lp["moe"], h2, cfg, mesh=mesh)
    else:
        y2 = mlp_forward(lp["mlp"], h2, cfg.mlp_act)
    return x + y2, new_cache


# ---------------------------------------------------------------------------
# embedding / frontend
# ---------------------------------------------------------------------------
def embed_inputs(params, batch, cfg: ArchConfig):
    """Returns (x (B,S,d), positions (S,), loss_mask (B,S) or None)."""
    if cfg.frontend == "audio_stub":
        frames = batch["frames"]                         # (B, T, frontend_dim)
        x = frames.astype(params["embed"].dtype) @ params["frontend"]["proj"]
        S = x.shape[1]
        return x, jnp.arange(S, dtype=jnp.int32), None
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    mask = None
    if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype) @ params["frontend"]["proj"]
        x = jnp.concatenate([pe, x], axis=1)
        n_patch = pe.shape[1]
        mask = jnp.concatenate(
            [jnp.zeros((x.shape[0], n_patch), jnp.float32),
             jnp.ones((x.shape[0], tokens.shape[1]), jnp.float32)], axis=1)
    S = x.shape[1]
    return x, jnp.arange(S, dtype=jnp.int32), mask


def unembed(params, x, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ w
    if cfg.padded_vocab != cfg.vocab_size:                 # mask pad rows
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------
def _seq_constraint(x, cfg, mesh):
    """Sequence-parallel residual stream: the saved per-layer activation is
    sharded over the model axis between blocks (Megatron-SP style)."""
    if mesh is None or not cfg.seq_shard or cfg.batch_over_model:
        return x
    if "model" not in mesh.axis_names or x.shape[1] % mesh.shape["model"] != 0:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = ba if (ba and x.shape[0] % int(np.prod([mesh.shape[a] for a in ba])) == 0) else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(bspec, "model", None)))


def forward(params, batch, cfg: ArchConfig, mesh=None, want_cache=False,
            unembed_out=True):
    """Returns (logits-or-hidden, caches, aux_loss, mask)."""
    x, positions, mask = embed_inputs(params, batch, cfg)

    def body(carry, lp):
        x, aux = carry
        x = _seq_constraint(x, cfg, mesh)
        x, cache, aux_i = block_forward(lp, x, cfg, positions, mesh=mesh,
                                        want_cache=want_cache)
        # constrain the carry OUT as well: under remat the saved per-layer
        # residual is then sequence-sharded (16x smaller), not replicated
        x = _seq_constraint(x, cfg, mesh)
        return (x, aux + aux_i), cache

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), caches = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                    params["layers"])
    x = rms_norm(x, params["final_norm"])
    if not unembed_out:
        return x, caches, aux, mask
    logits = unembed(params, x, cfg)
    return logits, caches, aux, mask


def chunked_ce(params, x, labels, mask, cfg: ArchConfig, chunk: int = 512):
    """Sequence-chunked fused unembed+CE: the (B, S, V) logits tensor is never
    materialized — each (B, chunk, V) tile is computed, reduced, and (via
    jax.checkpoint) recomputed in the backward pass."""
    B, S, _ = x.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    if S % chunk != 0 or S <= chunk:
        from repro.models.layers import cross_entropy
        return cross_entropy(unembed(params, x, cfg), labels, mask)
    n = S // chunk
    xc = x.reshape(B, n, chunk, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(args):
        x_i, l_i, m_i = args
        logits = unembed(params, x_i, cfg).astype(jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        lab = jnp.take_along_axis(logits, l_i[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - lab) * m_i), jnp.sum(m_i)

    nll, cnt = jax.lax.map(one, (xc, lc, mc))
    return jnp.sum(nll) / jnp.maximum(jnp.sum(cnt), 1.0)


def loss_fn(params, batch, cfg: ArchConfig, mesh=None):
    x, _, aux, mask = forward(params, batch, cfg, mesh=mesh, unembed_out=False)
    labels = batch["labels"]
    if mask is not None:                 # VLM: loss only on text positions
        n_patch = x.shape[1] - labels.shape[1]
        x = x[:, n_patch:]
        mask = mask[:, n_patch:]
    ce = chunked_ce(params, x, labels, mask, cfg)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Empty stacked cache pytree {'layers': (L,...), 'pos': ()}. """
    dtype = jnp.dtype(cfg.dtype)
    if cfg.block in (BLOCK_ATTN_MLP, BLOCK_ATTN_MOE):
        one = attn.init_attn_cache(cfg, batch, max_len, dtype)
        one.pop("pos")
    elif cfg.block == BLOCK_MLA_MLP:
        one = attn.init_mla_cache(cfg, batch, max_len, dtype)
        one.pop("pos")
    elif cfg.block == BLOCK_MAMBA2:
        one = {"ssm": m2.init_mamba2_cache(cfg, batch, dtype)}
    elif cfg.block == BLOCK_HYMBA:
        ac = attn.init_attn_cache(cfg, batch, max_len, dtype)
        ac.pop("pos")
        one = {"attn": ac, "ssm": m2.init_mamba2_cache(cfg, batch, dtype)}
    else:
        raise ValueError(cfg.block)
    L = cfg.n_layers
    layers = jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), one)
    return {"layers": layers, "pos": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg: ArchConfig, mesh=None, alloc_len: int | None = None):
    """Full-sequence prefill; returns (last-token logits, decode-ready cache)."""
    logits, caches, _, _ = forward(params, batch, cfg, mesh=mesh, want_cache=True)
    seq_len = logits.shape[1]
    cache = _prefill_to_cache(caches, cfg, seq_len, alloc_len or seq_len)
    return logits[:, -1:], cache


def _prefill_to_cache(caches, cfg, seq_len: int, alloc_len: int):
    """Convert stacked prefill outputs (k,v / latent / state) into a decode cache.

    alloc_len: cache capacity (>= window for windowed archs). Slot layout is
    position % capacity; prefill entries land at their natural slots.
    """
    pos = jnp.full((), seq_len, jnp.int32)
    cap = alloc_len if cfg.sliding_window is None else min(alloc_len, cfg.sliding_window)

    def to_slots(t):                       # t: (L, B, S, ...) -> (L, B, cap, ...)
        keep = min(seq_len, cap)
        tail = t[:, :, seq_len - keep: seq_len]
        idx = jnp.mod(jnp.arange(seq_len - keep, seq_len), cap)
        out = jnp.zeros(t.shape[:2] + (cap,) + t.shape[3:], t.dtype)
        return out.at[:, :, idx].set(tail)

    keep = min(seq_len, cap)
    sp = jnp.full((cap,), -1, jnp.int32)
    sp = sp.at[jnp.mod(jnp.arange(seq_len - keep, seq_len), cap)].set(
        jnp.arange(seq_len - keep, seq_len, dtype=jnp.int32))

    if cfg.block in (BLOCK_ATTN_MLP, BLOCK_ATTN_MOE):
        k, v = to_slots(caches["k"]), to_slots(caches["v"])
        L = k.shape[0]
        out = {"k": k, "v": v, "slot_pos": jnp.broadcast_to(sp, (L,) + sp.shape)}
    elif cfg.block == BLOCK_MLA_MLP:
        c_kv, k_rope = to_slots(caches["c_kv"]), to_slots(caches["k_rope"])
        L = c_kv.shape[0]
        out = {"c_kv": c_kv, "k_rope": k_rope,
               "slot_pos": jnp.broadcast_to(sp, (L,) + sp.shape)}
    elif cfg.block == BLOCK_MAMBA2:
        out = {"ssm": caches["ssm"]}
    elif cfg.block == BLOCK_HYMBA:
        k, v = to_slots(caches["attn"]["k"]), to_slots(caches["attn"]["v"])
        L = k.shape[0]
        out = {"attn": {"k": k, "v": v,
                        "slot_pos": jnp.broadcast_to(sp, (L,) + sp.shape)},
               "ssm": caches["ssm"]}
    else:
        raise ValueError(cfg.block)
    return {"layers": out, "pos": pos}


def decode_step(params, cache, tokens, cfg: ArchConfig, mesh=None):
    """tokens: (B, 1) -> (logits (B,1,V), new cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = cache["pos"]

    def body(x, inp):
        lp, lc = inp
        lc = dict(lc); lc["pos"] = pos
        x, nc = block_decode(lp, x, lc, cfg, mesh=mesh)
        nc.pop("pos", None)
        return x, nc

    x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    x = rms_norm(x, params["final_norm"])
    logits = unembed(params, x, cfg)
    return logits, {"layers": new_layers, "pos": pos + 1}
