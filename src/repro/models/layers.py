"""Shared model layers: norms, rotary embeddings, MLPs, init helpers.

Everything is functional: params are plain nested dicts of jnp arrays, so the
same code paths serve real execution (smoke tests / examples) and
``jax.eval_shape`` (multi-pod dry-run, no allocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def normal_init(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# rotary position embeddings (supports partial-rotary, e.g. GLM4 / MLA)
# ---------------------------------------------------------------------------
def rope_freqs(d_rot: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot
    return 1.0 / (theta ** exponent)          # (d_rot/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rope_fraction: float = 1.0) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    d_rot = int(d_head * rope_fraction)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    rot, rest = x[..., :d_rot], x[..., d_rot:]
    freqs = rope_freqs(d_rot, theta)                          # (d_rot/2,)
    angles = positions[..., None, None].astype(jnp.float32) * freqs  # (..., S, 1, d_rot/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = rot[..., ::2].astype(jnp.float32), rot[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(rot.shape).astype(x.dtype)
    return jnp.concatenate([rotated, rest], axis=-1)


# ---------------------------------------------------------------------------
# (gated) MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "w_up": normal_init(ks[0], (d_model, d_ff), scale_in, dtype),
        "w_down": normal_init(ks[1], (d_ff, d_model), scale_out, dtype),
    }
    if gated:
        p["w_gate"] = normal_init(ks[2], (d_model, d_ff), scale_in, dtype)
    return p


def mlp_forward(p: dict, x: jax.Array, act: str) -> jax.Array:
    up = x @ p["w_up"]
    if "w_gate" in p:
        up = act_fn(act)(x @ p["w_gate"]) * up
    else:
        up = act_fn(act)(up)
    return up @ p["w_down"]


# ---------------------------------------------------------------------------
# cross entropy (sharded-vocab friendly)
# ---------------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None):
    """logits (B, S, V) any float dtype; labels (B, S) int32. fp32 math."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logit
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
