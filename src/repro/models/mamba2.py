"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Train/prefill uses the chunked SSD algorithm: quadratic attention-like math
inside fixed-size chunks, a linear recurrence across chunks (lax.scan).
Decode is the O(1)-state recurrent step.  ``ngroups=1`` (B/C shared across
heads) as in the published 130m config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init, rms_norm

NEG_INF = -1e30


def init_mamba2(key, cfg, dtype) -> dict:
    d, di, N, H, W = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.n_ssm_heads, cfg.ssm_conv_width)
    ks = jax.random.split(key, 5)
    d_proj = 2 * di + 2 * N + H                     # x, z, B, C, dt
    return {
        "in_proj": normal_init(ks[0], (d, d_proj), d ** -0.5, dtype),
        "conv_w": normal_init(ks[1], (W, di + 2 * N), 0.5, dtype),
        "conv_b": jnp.zeros((di + 2 * N,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),      # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": normal_init(ks[2], (di, d), di ** -0.5, dtype),
    }


def _split_proj(proj, cfg):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    xz, rest = proj[..., : 2 * di], proj[..., 2 * di:]
    x_in, z = xz[..., :di], xz[..., di:]
    Bv, Cv, dt = rest[..., :N], rest[..., N: 2 * N], rest[..., 2 * N:]
    return x_in, z, Bv, Cv, dt


def _causal_conv(u, w, b):
    """u: (B, S, C); w: (W, C) depthwise causal conv via shifted adds."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    S = u.shape[1]
    out = jnp.zeros_like(u)
    for i in range(W):
        out = out + pad[:, i: i + S, :] * w[i]
    return jax.nn.silu(out + b)


def _segsum(logd):
    """logd: (..., Q) -> (..., Q, Q) with [i, j] = sum_{k=j+1..i}, -inf for j>i."""
    Q = logd.shape[-1]
    cs = jnp.cumsum(logd, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, NEG_INF)


def mamba2_forward(p, x, cfg, return_state: bool = False):
    """x: (B, S, d). S must be a multiple of ssm_chunk (or smaller than it)."""
    Bsz, S, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    while S % Q:                 # largest divisor of S <= chunk
        Q -= 1
    nC = S // Q

    proj = x @ p["in_proj"]
    x_in, z, Bv, Cv, dt = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([x_in, Bv, Cv], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    x_in, Bv, Cv = (conv_out[..., :di], conv_out[..., di: di + N],
                    conv_out[..., di + N:])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,S,H)
    A = -jnp.exp(p["A_log"])                                         # (H,)
    logd = dt * A                                                    # (B,S,H) log decay
    xh = x_in.reshape(Bsz, S, H, P)
    xdt = xh.astype(jnp.float32) * dt[..., None]                     # (B,S,H,P)

    # chunk
    cBv = Bv.reshape(Bsz, nC, Q, N).astype(jnp.float32)
    cCv = Cv.reshape(Bsz, nC, Q, N).astype(jnp.float32)
    cxdt = xdt.reshape(Bsz, nC, Q, H, P)
    clogd = logd.reshape(Bsz, nC, Q, H).transpose(0, 1, 3, 2)        # (B,nC,H,Q)

    # intra-chunk (quadratic within chunk)
    L = jnp.exp(_segsum(clogd))                                      # (B,nC,H,Q,Q)
    CB = jnp.einsum("bcin,bcjn->bcij", cCv, cBv)                     # (B,nC,Q,Q)
    M = CB[:, :, None] * L                                           # (B,nC,H,Q,Q)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", M, cxdt)

    # inter-chunk state recurrence
    cs = jnp.cumsum(clogd, axis=-1)                                  # (B,nC,H,Q)
    decay_out = jnp.exp(cs)                                          # prod dA 1..i
    decay_state = jnp.exp(cs[..., -1:] - cs)                         # prod dA i+1..Q
    chunk_states = jnp.einsum("bcjn,bcjhp,bchj->bchpn", cBv, cxdt, decay_state)
    chunk_decay = jnp.exp(cs[..., -1])                               # (B,nC,H)

    def scan_fn(h, inp):
        st, dec = inp                                                # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h                                              # emit state at chunk START

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_last, h_starts = jax.lax.scan(
        scan_fn, h0,
        (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_starts = h_starts.transpose(1, 0, 2, 3, 4)                     # (B,nC,H,P,N)

    y_inter = jnp.einsum("bcin,bchpn->bcihp", cCv, h_starts) * \
        decay_out.transpose(0, 1, 3, 2)[..., None]                   # (B,nC,Q,H,P)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, di).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    out = y @ p["out_proj"]
    if return_state:
        W = cfg.ssm_conv_width
        conv_tail = jnp.pad(conv_in, ((0, 0), (W - 1, 0), (0, 0)))[:, -(W - 1):, :]
        return out, {"h": h_last, "conv": conv_tail}
    return out, None


def mamba2_decode(p, x, cache, cfg):
    """One-token step. x: (B, 1, d); cache: {"h": (B,H,P,N) f32, "conv": (B,W-1,C)}."""
    Bsz = x.shape[0]
    di, N, H, P, W = (cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                      cfg.ssm_head_dim, cfg.ssm_conv_width)
    proj = x @ p["in_proj"]
    x_in, z, Bv, Cv, dt = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([x_in, Bv, Cv], axis=-1)               # (B,1,C)
    window = jnp.concatenate([cache["conv"], conv_in], axis=1)       # (B,W,C)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, p["conv_w"])
                           + p["conv_b"])[:, None, :]
    x_in, Bv, Cv = (conv_out[..., :di], conv_out[..., di: di + N],
                    conv_out[..., di + N:])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                             # (B,H)
    xh = x_in.reshape(Bsz, H, P).astype(jnp.float32)
    h = cache["h"] * dA[..., None, None] + jnp.einsum(
        "bn,bhp,bh->bhpn", Bv[:, 0].astype(jnp.float32), xh, dt)
    y = jnp.einsum("bn,bhpn->bhp", Cv[:, 0].astype(jnp.float32), h)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(Bsz, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    out = y @ p["out_proj"]
    return out, {"h": h, "conv": window[:, 1:, :]}


def init_mamba2_cache(cfg, batch, dtype):
    di, N, H, P, W = (cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                      cfg.ssm_head_dim, cfg.ssm_conv_width)
    return {
        "h": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, W - 1, di + 2 * N), dtype),
    }
