"""Routed mixture-of-experts FFN with sort-based (one-hot-free) dispatch.

Two execution paths with identical math:

* ``moe_apply`` — the per-shard body: local tokens, a contiguous slice of
  experts, capacity-bounded sort-based dispatch, partial-sum combine.  Runs
  standalone on one device (smoke tests) with the full expert set.
* ``moe_forward`` — expert-parallel wrapper: experts are sharded over the
  ``model`` mesh axis, tokens over the ``data`` (+``pod``) axes.  Each model
  rank computes its experts for its data-shard's tokens and the partial
  outputs are combined with a ``psum`` over ``model`` — STAR's
  "single-partition transactions run on their partition, no coordination"
  phase maps exactly onto this expert-local compute; only the combine is a
  collective.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.layers import act_fn, normal_init


def init_moe(key, cfg, dtype) -> dict:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 4)
    p = {
        "router": normal_init(ks[0], (d, E), d ** -0.5, jnp.float32),
        "w_up": normal_init(ks[1], (E, d, ff), d ** -0.5, dtype),
        "w_down": normal_init(ks[2], (E, ff, d), ff ** -0.5, dtype),
    }
    if cfg.mlp_gated:
        p["w_gate"] = normal_init(ks[3], (E, d, ff), d ** -0.5, dtype)
    return p


def moe_capacity(n_tokens: int, cfg) -> int:
    cap = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(min(n_tokens, 16), min(cap, n_tokens))


def route(router, x_flat, cfg):
    """Returns (weights (T,k) f32, expert ids (T,k) i32, aux load-balance loss)."""
    logits = (x_flat.astype(jnp.float32) @ router)                 # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(gates, cfg.top_k)                 # (T, k)
    weights = weights / jnp.maximum(jnp.sum(weights, -1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e f_e * p_e
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(jax.nn.one_hot(ids[:, 0], cfg.n_experts, dtype=jnp.float32), axis=0)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return weights, ids, aux


def moe_apply(p, x_flat, cfg, expert_offset: int, n_local_experts: int,
              axis_name: str | tuple | None = None):
    """Sort-based dispatch over a local expert slice.

    x_flat: (T, d). p holds weights for ONLY the local experts
    (w_up/(w_gate)/w_down first dim = n_local_experts) but the full router.
    """
    T, d = x_flat.shape
    E, k = cfg.n_experts, cfg.top_k
    C = moe_capacity(T, cfg)

    weights, ids, aux = route(p["router"], x_flat, cfg)

    # flatten assignments and sort by expert id
    flat_ids = ids.reshape(-1)                                     # (T*k,)
    flat_w = weights.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order = jnp.argsort(flat_ids, stable=True)
    s_ids, s_w, s_tok = flat_ids[order], flat_w[order], flat_tok[order]

    # position within expert via segment starts
    starts = jnp.searchsorted(s_ids, jnp.arange(E, dtype=s_ids.dtype))
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[s_ids].astype(jnp.int32)

    local = (s_ids >= expert_offset) & (s_ids < expert_offset + n_local_experts)
    keep = local & (pos_in_e < C)
    local_e = jnp.clip(s_ids - expert_offset, 0, n_local_experts - 1)
    dest = jnp.where(keep, local_e * C + pos_in_e, n_local_experts * C)  # drop slot

    # slot tables: which token / weight feeds each capacity slot.  Only int32
    # scatters run at T*k size; the (rows, d_model) gather below touches just
    # E_loc*C rows (not T*k) — this keeps dispatch traffic proportional to
    # the tokens actually routed here.
    n_slots = n_local_experts * C
    slot_tok = jnp.full((n_slots + 1,), T, jnp.int32).at[dest].set(
        s_tok, mode="drop")[:-1]
    slot_w = jnp.zeros((n_slots + 1,), jnp.float32).at[dest].set(
        jnp.where(keep, s_w, 0.0), mode="drop")[:-1]
    valid = slot_tok < T
    safe_tok = jnp.where(valid, slot_tok, 0)

    buf = x_flat[safe_tok] * valid.astype(x_flat.dtype)[:, None]
    buf = buf.reshape(n_local_experts, C, d)

    # expert FFN
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if "w_gate" in p:
        up = act_fn(cfg.mlp_act)(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * up
    else:
        up = act_fn(cfg.mlp_act)(up)
    out = jnp.einsum("ecf,efd->ecd", up, p["w_down"]).reshape(n_slots, d)

    # combine (partial sum over this expert slice): scatter-add slot rows back
    contrib = out * (slot_w * valid).astype(out.dtype)[:, None]
    y = jnp.zeros((T, d), x_flat.dtype).at[safe_tok].add(
        contrib.astype(x_flat.dtype))
    if axis_name is not None:
        y = jax.lax.psum(y, axis_name)
        aux = jax.lax.pmean(aux, axis_name)
    return y, aux


def moe_forward(p, x, cfg, mesh=None):
    """x: (B, S, d) -> (y, aux). Expert-parallel over the ``model`` axis."""
    B, S, d = x.shape
    if mesh is None or "model" not in mesh.axis_names or cfg.n_experts % mesh.shape["model"] != 0:
        y, aux = moe_apply(p, x.reshape(-1, d), cfg, 0, cfg.n_experts)
        return y.reshape(B, S, d), aux

    m = mesh.shape["model"]
    e_loc = cfg.n_experts // m
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nb = math.prod(mesh.shape[a] for a in batch_axes)
    bspec = P(batch_axes, None, None) if B % nb == 0 else P(None, None, None)
    expert_spec = {
        k: (P(None) if k == "router" else P("model", None, None))
        for k in p
    }

    def body(p_loc, x_loc):
        off = jax.lax.axis_index("model") * e_loc
        T = x_loc.shape[0] * x_loc.shape[1]
        y, aux = moe_apply(p_loc, x_loc.reshape(T, d), cfg, off, e_loc,
                           axis_name="model")
        # make aux truly replicated across every mesh axis
        aux = jax.lax.pmean(aux, axis_name=batch_axes) if batch_axes else aux
        return y.reshape(x_loc.shape), aux

    y, aux = shard_map(
        body, mesh,
        in_specs=(expert_spec, bspec),
        out_specs=(bspec, P()),
    )(p, x)
    return y, aux
