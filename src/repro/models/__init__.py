from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, loss_fn, params_shape,
                                      prefill)

__all__ = ["decode_step", "forward", "init_cache", "init_params", "loss_fn",
           "params_shape", "prefill"]
