"""Batched serving engine: prefill + decode with the slot cache.

Maps STAR's serving story: the model replica serves reads ("read committed"
on non-master nodes, §4.3) while training epochs commit elsewhere;
``load_params``/Thomas-rule merge lets a newer committed epoch be swapped in
between decode steps without draining the batch.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf


@dataclass
class ServeStats:
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    param_swaps: int = 0


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, mesh=None, max_len: int = 512):
        self.cfg, self.mesh, self.max_len = cfg, mesh, max_len
        self.params = params
        self.params_tid = 0
        self.stats = ServeStats()
        self._prefill = jax.jit(
            lambda p, b: tf.prefill(p, b, cfg, mesh=mesh, alloc_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t: tf.decode_step(p, c, t, cfg, mesh=mesh))

    def load_params(self, params, tid: int):
        """Thomas-rule swap: only a strictly newer committed epoch applies."""
        if tid > self.params_tid:
            self.params, self.params_tid = params, tid
            self.stats.param_swaps += 1
            return True
        return False

    def generate(self, prompts: jax.Array, n_tokens: int,
                 greedy: bool = True, rng=None):
        """prompts: (B, S) int32 -> (B, n_tokens) int32."""
        B, S = prompts.shape
        logits, cache = self._prefill(self.params, {"tokens": prompts})
        self.stats.prefill_tokens += B * S
        outs = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for i in range(n_tokens):
            outs.append(tok)
            logits, cache = self._decode(self.params, cache, tok)
            if greedy:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            else:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(
                    sub, logits[:, -1].astype(jnp.float32))[:, None].astype(jnp.int32)
            self.stats.decoded_tokens += B
        return jnp.concatenate(outs, axis=1)
