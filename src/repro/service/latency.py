"""End-to-end latency accounting for the online service (§4.3, Fig. 12).

Every request carries four stamps on the service clock (seconds since
service start): ``arrival`` (client emitted it), ``admit`` (admission
accepted it into a bounded queue), ``form`` (the batcher drained it into an
epoch batch) and ``commit`` (the epoch's commit fence — group commit, so all
transactions of an epoch share one commit stamp).  The recorder accumulates
completed requests columnar-style and reports measured percentiles — these
replace the synthetic U(0, e) latency model the offline benchmarks used.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

COMMITTED, USER_ABORTED, SHED = 0, 1, 2

_COLS = ("tenant", "arrival_s", "admit_s", "form_s", "commit_s", "status")


@dataclass
class LatencySummary:
    n: int
    p50_ms: float
    p99_ms: float
    p999_ms: float
    mean_ms: float

    def __str__(self):
        return (f"n={self.n} p50={self.p50_ms:.2f}ms p99={self.p99_ms:.2f}ms "
                f"p99.9={self.p999_ms:.2f}ms mean={self.mean_ms:.2f}ms")


class LatencyRecorder:
    """Columnar accumulator of per-request stamps; chunks are appended per
    epoch (vectorized) and concatenated lazily at report time."""

    def __init__(self):
        self._chunks: list[dict] = []
        self._cache = None
        self.started_s = 0.0
        self.finished_s = 0.0

    def record(self, tenant, arrival_s, admit_s, form_s, commit_s, status):
        """All args are equal-length 1-D arrays (one row per request)."""
        n = len(arrival_s)
        if n == 0:
            return
        self._chunks.append({
            "tenant": np.asarray(tenant, np.int32),
            "arrival_s": np.asarray(arrival_s, np.float64),
            "admit_s": np.asarray(admit_s, np.float64),
            "form_s": np.asarray(form_s, np.float64),
            "commit_s": np.asarray(commit_s, np.float64),
            "status": np.asarray(status, np.int32),
        })
        self._cache = None

    # ------------------------------------------------------------------
    def _table(self):
        if self._cache is None:
            if not self._chunks:
                self._cache = {c: np.zeros(0) for c in _COLS}
            else:
                self._cache = {c: np.concatenate([ch[c] for ch in self._chunks])
                               for c in _COLS}
        return self._cache

    def latencies_ms(self, start="arrival_s", end="commit_s", tenant=None,
                     status=COMMITTED):
        """Per-request (end - start) in ms for completed requests."""
        t = self._table()
        sel = np.ones(len(t["status"]), bool)
        if status is not None:
            sel &= t["status"] == status
        if tenant is not None:
            sel &= t["tenant"] == tenant
        return (t[end][sel] - t[start][sel]) * 1e3

    def percentiles(self, start="arrival_s", end="commit_s", tenant=None):
        lat = self.latencies_ms(start, end, tenant)
        if lat.size == 0:
            return LatencySummary(0, float("nan"), float("nan"),
                                  float("nan"), float("nan"))
        return LatencySummary(
            int(lat.size),
            float(np.percentile(lat, 50)), float(np.percentile(lat, 99)),
            float(np.percentile(lat, 99.9)), float(lat.mean()))

    def committed(self, tenant=None) -> int:
        t = self._table()
        sel = t["status"] == COMMITTED
        if tenant is not None:
            sel &= t["tenant"] == tenant
        return int(sel.sum())

    def throughput_txn_s(self) -> float:
        """Sustained committed txn/s over the measured service interval."""
        span = self.finished_s - self.started_s
        return self.committed() / span if span > 0 else 0.0

    def mean_queue_delay_ms(self, last_chunk_only=True) -> float:
        """enqueue→batch-formation delay — the PhaseController telemetry."""
        chunks = self._chunks[-1:] if last_chunk_only else self._chunks
        ds = [c["form_s"] - c["arrival_s"] for c in chunks
              if len(c["arrival_s"])]
        if not ds:
            return -1.0
        d = np.concatenate(ds)
        return float(d.mean() * 1e3)
