"""Online transaction service: clients → admission → batcher → StarEngine.

The epoch loop is pipelined two-deep: while the device executes epoch k, the
engine's ``ingest`` hook pulls new arrivals from the clients, runs admission,
and forms batch k+1 on the host (double buffering, §4.3's "the data plane
never idles on ingest").  At each epoch's commit fence the service stamps
every transaction of the batch with the fence time (group commit), feeds the
measured queue delay and commit latency into the `PhaseController` (so Eqs
1–2 plan from observed traffic, not synthetic numbers), retires completed
requests to the `LatencyRecorder`, and re-queues starved OCC transactions at
the front of the master queue.

The service runs on the wall clock: open-loop arrival timelines map onto
seconds-since-start, so if the engine cannot keep up, queues fill and
admission control sheds or backpressures — measurably, not by assumption.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.engine import StarEngine
from repro.obs import trace as obs
from repro.obs.metrics import MetricsRegistry
from repro.service import latency as lat
from repro.service.admission import (AdmissionConfig, AdmissionController,
                                     BACKPRESSURE)
from repro.service.batcher import EpochBatcher
from repro.service.clients import slice_request


@dataclass
class ServiceStats:
    epochs: int = 0
    committed: int = 0
    user_aborted: int = 0
    starved_requeues: int = 0
    ingest_time_s: float = 0.0
    epoch_time_s: float = 0.0


class TxnService:
    def __init__(self, engine: StarEngine, clients: list,
                 admission_cfg: AdmissionConfig | None = None,
                 slots_per_partition: int = 64, master_lanes: int = 64,
                 max_ops: int | None = None, feedback=None,
                 node_of_partition=None, read_tier=None, analytics=None,
                 metrics: MetricsRegistry | None = None):
        """feedback: optional callable(batch, metrics) invoked after every
        epoch's commit fence — the service-level consume-feedback hook
        (e.g. ``lambda b, m: tpcc.apply_consume_feedback(state, b, m)``
        re-queues Delivery districts the device skipped).
        node_of_partition: cluster deployments pass the partition→node map
        so admission enforces per-node queue bounds and attributes
        shed/depth telemetry per node (see ClusterTxnService).
        read_tier: optional ``reads.ReadTier`` — declared-read-only
        transactions route to a bounded read lane and are served from
        replica snapshots between fences instead of burning OCC slots.
        analytics: optional ``changelog.AnalyticsLane`` — incrementally
        maintained materialized views subscribe to the engine's changelog
        and the CH-style query mix serves between fences from the
        epoch-stamped aggregate snapshots.
        metrics: optional ``obs.MetricsRegistry`` (one is created if not
        given) — the engine/service/read-tier stats dataclasses register
        into it and ``_observe_epoch`` records a per-epoch snapshot."""
        self.engine = engine
        self.clients = list(clients)
        self.feedback = feedback
        self.read_tier = read_tier
        self.analytics = analytics
        M = max_ops if max_ops is not None else self.clients[0].source.M
        self.admission = AdmissionController(
            engine.P, engine.R, M, engine.C, cfg=admission_cfg,
            node_of_partition=node_of_partition,
            read_lane=read_tier is not None)
        src = self.clients[0].source
        self.batcher = EpochBatcher(self.admission, slots_per_partition,
                                    master_lanes, row_bytes=src.row_bytes,
                                    op_bytes=src.op_bytes)
        self.recorder = lat.LatencyRecorder()
        self.stats = ServiceStats()
        self._t0 = None
        self._deadline = float("inf")
        # one metrics namespace: the stats dataclasses register as live
        # objects (snapshot-time reads, never hand-merged), the lane
        # summaries and the kernel-launch counter come in as providers
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.metrics.register_object("engine", engine.stats)
        self.metrics.register_object("service", self.stats)
        self.metrics.register_object("admission", self.admission.stats)
        if read_tier is not None:
            self.metrics.register_object("reads", read_tier.stats)
        if analytics is not None:
            self.metrics.register_provider(
                "analytics",
                lambda: {k.removeprefix("analytics_"): v
                         for k, v in analytics.summary().items()})
        self.metrics.register_provider("kernels", obs.kernel_launch_counts)

    # ------------------------------------------------------------------
    def clock(self) -> float:
        return time.perf_counter() - self._t0

    def _ingest(self, now_s: float):
        """Pull due arrivals from every client and run admission. New
        arrivals stop at the deadline so the drain phase terminates."""
        until = min(now_s, self._deadline)
        with obs.span("service.admission", cat="service"):
            for c in self.clients:
                req = c.pull(until)
                if req is None:
                    continue
                rejected = self.admission.offer(req, now_s)
                if rejected.any():
                    rej = slice_request(req, rejected)
                    if self.admission.cfg.policy == BACKPRESSURE:
                        c.push_back(rej)
                    else:
                        c.on_shed(rej, until)   # client sees the rejection

    def _complete(self, plan, metrics):
        """Commit fence reached: stamp, retire, re-queue starved."""
        pool, rec = self.admission.pool, self.recorder
        commit_s = metrics["t_fence2_s"] - self._t0
        P, T = plan.p_idx.shape

        p_slots = plan.p_idx.reshape(-1)
        p_live = p_slots >= 0
        p_slots = p_slots[p_live]
        p_ok = metrics["p_committed"][:, :T].reshape(-1)[p_live]

        B = plan.c_idx.size
        c_slots = plan.c_idx
        c_ok = metrics["c_committed"][:B] if B else np.zeros(0, bool)

        # starved OCC lanes (valid, not aborted, not committed) retry next
        # epoch from the FRONT of the master queue
        c_aborted = pool.user_abort[c_slots] if B else np.zeros(0, bool)
        starved = ~c_ok & ~c_aborted
        if starved.any():
            self.admission.requeue_master_front(c_slots[starved])
            self.stats.starved_requeues += int(starved.sum())
        done_c = c_slots[~starved]
        done_c_ok = c_ok[~starved]

        slots = np.concatenate([p_slots, done_c])
        ok = np.concatenate([p_ok, done_c_ok])
        status = np.where(ok, lat.COMMITTED, lat.USER_ABORTED)
        rec.record(pool.tenant[slots], pool.arrival_s[slots],
                   pool.admit_s[slots], pool.form_s[slots],
                   np.full(slots.size, commit_s), status)
        self.stats.committed += int(ok.sum())
        self.stats.user_aborted += int((~ok).sum())

        # notify closed-loop clients (tenant-keyed)
        now = self.clock()
        for c in self.clients:
            if hasattr(c, "on_complete"):
                n = int((pool.tenant[slots] == c.tenant).sum())
                if n:
                    c.on_complete(n, now)

        # measured telemetry → Eq. 1–2 planning + latency model (the last
        # recorded chunk is exactly this epoch's completions)
        if slots.size:
            qd = rec.mean_queue_delay_ms()
            cl = float((commit_s - pool.arrival_s[slots]).mean()) * 1e3
            self.engine.controller.observe_latency(qd, cl)
        pool.release(slots)

    # ------------------------------------------------------------------
    def warmup(self, n: int = 2):
        """Compile both phase programs before the clock starts: the batcher
        emits FIXED shapes, so an empty formed batch compiles the exact
        programs live traffic will reuse (no mid-run jit stalls)."""
        self._t0 = time.perf_counter()
        for _ in range(n):
            batch, plan = self.batcher.form(0.0)
            assert plan.total == 0, "warmup must run before clients are pulled"
            self.engine.run_epoch(batch)

    def run(self, duration_s: float = 1.0, max_epochs: int | None = None,
            idle_sleep_s: float = 0.0002, warmup_epochs: int = 2) -> dict:
        """Serve until `duration_s` of wall clock (and the pipeline drains of
        admitted work) or `max_epochs`. Returns a summary dict."""
        if warmup_epochs:
            self.warmup(warmup_epochs)
        self._t0 = time.perf_counter()
        self._deadline = duration_s
        self.recorder.started_s = 0.0
        if self.read_tier is not None:
            self.read_tier.recorder.started_s = 0.0
            self.read_tier.observe_epoch(self.engine)   # initial catalog
            clog = getattr(self.engine, "changelog", None)
            if clog is not None:
                # mid-epoch slab-watermark serving rides the changelog
                self.read_tier.attach_changelog(clog)
        if self.analytics is not None:
            self.analytics.ensure_attached(self.engine)
        self._ingest(self.clock())
        batch, plan = self.batcher.form(self.clock())
        nxt = {}

        def ingest_hook():
            self._ingest(self.clock())
            with obs.span("service.batch_form", cat="service"):
                nxt["formed"] = self.batcher.form(self.clock())
            if self.read_tier is not None:
                # mid-epoch: k=0 serves of partitions below the slab
                # watermark, overlapped with device execution; dirty
                # partitions defer to the fence
                self.read_tier.serve(self.admission, self.clock(),
                                     mid_epoch=True)

        while True:
            if max_epochs is not None and self.stats.epochs >= max_epochs:
                break
            past_deadline = self.clock() >= duration_s
            if past_deadline and plan.total == 0 and self.admission.depth() == 0:
                break
            if not past_deadline and plan.total == 0 \
                    and self.admission.depth() == 0:
                time.sleep(idle_sleep_s)     # open-loop arrivals are sparse
                self._ingest(self.clock())
                batch, plan = self.batcher.form(self.clock())
                continue
            nxt.clear()
            t0 = time.perf_counter()
            m = self.engine.run_epoch(batch, ingest=ingest_hook)
            self.stats.epoch_time_s += time.perf_counter() - t0
            self.stats.ingest_time_s += m["t_ingest_s"]
            self.stats.epochs += 1
            if self.feedback is not None:
                self.feedback(batch, m)
            self._complete(plan, m)
            self._observe_epoch(m)
            if self.read_tier is not None:
                # commit fence passed: refresh the snapshot catalog, then
                # serve the read lane BETWEEN fences from the committed
                # replica snapshots (no OCC slots burned)
                self.read_tier.observe_epoch(self.engine, m)
                self.read_tier.serve(self.admission, self.clock())
            if self.analytics is not None:
                # the HTAP lane: queries answered from the epoch-stamped
                # MV snapshots the changelog commit just refreshed
                self.analytics.serve(self.engine.committed_epoch,
                                     self.clock())
            batch, plan = nxt["formed"]

        self.recorder.finished_s = self.clock()
        if self.read_tier is not None:
            self.read_tier.recorder.finished_s = self.clock()
        return self.summary()

    def _observe_epoch(self, metrics: dict):
        """Per-epoch telemetry hook: one registry snapshot per committed
        epoch (ClusterTxnService extends it with per-node sampling and
        recovery-event collection)."""
        self.metrics.snapshot(self.engine.committed_epoch)

    def summary(self) -> dict:
        rec, adm = self.recorder, self.admission.stats
        p = rec.percentiles()
        out = {
            "epochs": self.stats.epochs,
            "committed": self.stats.committed,
            "user_aborted": self.stats.user_aborted,
            "throughput_txn_s": rec.throughput_txn_s(),
            "p50_ms": p.p50_ms, "p99_ms": p.p99_ms, "p999_ms": p.p999_ms,
            "mean_ms": p.mean_ms,
            "offered": adm.offered, "admitted": adm.admitted,
            "shed": adm.shed,
            "backpressured": adm.backpressured,
            "dropped_retries": sum(getattr(c, "dropped_retries", 0)
                                   for c in self.clients),
            "starved_requeues": self.stats.starved_requeues,
            "rerouted": self.admission.router.stats.rerouted,
            "max_part_depth": adm.max_part_depth,
            "max_master_depth": adm.max_master_depth,
            "ingest_overlap_s": self.stats.ingest_time_s,
            "epoch_time_s": self.stats.epoch_time_s,
        }
        if self.read_tier is not None:
            out.update(self.read_tier.summary())
            out["write_committed"] = self.stats.committed
            out["write_txn_s"] = out["throughput_txn_s"]
            out["combined_txn_s"] = (out["throughput_txn_s"]
                                     + out["read_txn_s"])
        if self.analytics is not None:
            out.update(self.analytics.summary())
        return out
