"""Epoch-pipelined batch formation (§4.1/§4.2 device feeds).

Drains the admission queues into the engine's device formats — single-
partition txns to (P, T, M, …) partitioned-phase arrays, master-queue txns
to (B, M, …) single-master OCC lanes — with FIXED T/B shapes so the jitted
epoch executes one compiled program regardless of instantaneous load
(invalid lanes are masked out, never executed).

The service double-buffers: while the device executes epoch k, the engine's
``ingest`` hook calls back into `pull → offer → form` on the host, so batch
k+1 is ready the moment the fence of epoch k returns and neither side idles
on the other (the TPU/CPU never waits on ingest, ingest never waits on the
fence).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.service.admission import AdmissionController


@dataclass
class BatchPlan:
    """Maps a formed batch back to pool slots for commit stamping."""
    p_idx: np.ndarray          # (P, T) pool slot or -1
    c_idx: np.ndarray          # (B,)  pool slot
    n_single: int
    n_cross: int

    @property
    def total(self):
        return self.n_single + self.n_cross


class EpochBatcher:
    def __init__(self, admission: AdmissionController, slots_per_partition: int,
                 master_lanes: int, row_bytes=None, op_bytes=None):
        """slots_per_partition (T) and master_lanes (B) fix the device batch
        shape — powers of two keep the engine's pad-to-pow2 a no-op."""
        self.adm = admission
        self.T = int(slots_per_partition)
        self.B = int(master_lanes)
        self.row_bytes = row_bytes     # optional (M,) for Fig. 15 accounting
        self.op_bytes = op_bytes

    def form(self, now_s: float):
        """Drain queues into one epoch batch. Returns (batch, plan)."""
        adm, pool = self.adm, self.adm.pool
        P, T, B = adm.P, self.T, self.B
        M, C = pool.M, pool.C

        p_idx = np.full((P, T), -1, np.int64)
        for p in range(P):
            got = adm.drain_singles(p, T)
            p_idx[p, :len(got)] = got
        c_idx = np.array(adm.drain_master(B), np.int64)

        flat = p_idx.reshape(-1)
        pvalid = flat >= 0
        safe = np.where(pvalid, flat, 0)
        ptxn = {
            "valid": pvalid.reshape(P, T),
            "row": pool.row[safe].reshape(P, T, M),
            "kind": pool.kind[safe].reshape(P, T, M),
            "delta": pool.delta[safe].reshape(P, T, M, C),
            "user_abort": (pool.user_abort[safe] & pvalid).reshape(P, T),
        }
        # fixed-width master lanes: pad c_idx to B with invalid lanes
        n_cross = int(c_idx.size)
        cpad = np.full(B, 0, np.int64)
        cpad[:n_cross] = c_idx
        cross = {
            "valid": np.arange(B) < n_cross,
            "row": pool.row[cpad].reshape(B, M),
            "kind": pool.kind[cpad].reshape(B, M),
            "delta": pool.delta[cpad].reshape(B, M, C),
            "user_abort": pool.user_abort[cpad] & (np.arange(B) < n_cross),
        }
        live = np.concatenate([flat[pvalid], c_idx])
        pool.form_s[live] = now_s

        batch = {"ptxn": ptxn, "cross": cross,
                 "n_single": int(pvalid.sum()), "n_cross": n_cross}
        if self.row_bytes is not None:
            batch["row_bytes"] = self.row_bytes
            batch["op_bytes"] = self.op_bytes
        return batch, BatchPlan(p_idx, np.array(cpad[:n_cross], np.int64),
                                int(pvalid.sum()), n_cross)
