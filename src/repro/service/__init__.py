"""Online transaction service layer in front of `StarEngine` (§4.3).

clients → admission (bounded queues, shed/backpressure, re-route) →
epoch-pipelined batcher (double-buffered against device execution) →
engine → commit-fence latency stamping.
"""
from repro.service.admission import (AdmissionConfig, AdmissionController,
                                     BACKPRESSURE, RequestPool, SHED)
from repro.service.batcher import BatchPlan, EpochBatcher
from repro.service.clients import (ClosedLoopClient, OpenLoopClient,
                                   TPCCSource, YCSBSource)
from repro.service.latency import LatencyRecorder, LatencySummary
from repro.service.service import ServiceStats, TxnService

__all__ = [
    "AdmissionConfig", "AdmissionController", "BACKPRESSURE", "BatchPlan",
    "ClosedLoopClient", "EpochBatcher", "LatencyRecorder", "LatencySummary",
    "OpenLoopClient", "RequestPool", "SHED", "ServiceStats", "TPCCSource",
    "TxnService", "YCSBSource",
]
