"""Admission control (§4.3 router nodes + bounded ingest).

Arriving request chunks are classified by the vectorized `core.router`:
single-partition transactions enter their home partition's bounded FIFO
queue (the partitioned-phase feed), cross-partition — and mis-declared
"single" — transactions enter the bounded master queue (the single-master
feed).  When a queue is full the controller applies the configured policy:

  shed         — reject the excess outright (client sees an error; the load
                 generator counts it) — queues never grow without bound;
  backpressure — refuse the excess but report it back to the caller, who
                 retries next tick (open-loop clients keep a bounded retry
                 buffer; closed-loop clients simply stall).

Admitted requests live in a columnar `RequestPool` (structure-of-arrays,
grow-by-doubling, free-list recycling) so the epoch batcher can drain queues
into the engine's device formats with pure fancy-indexed gathers.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from repro.core.router import Router, globalize_rows

SHED, BACKPRESSURE = "shed", "backpressure"


@dataclass
class AdmissionConfig:
    part_queue_cap: int = 256       # per-partition single-partition bound
    master_queue_cap: int = 1024    # cross-partition (master node) bound
    policy: str = SHED              # "shed" | "backpressure"
    # cluster: total bound across ONE NODE's partition queues (requires
    # node_of_partition on the controller) — a hot node sheds before its
    # partitions individually fill, modeling per-node ingest memory
    node_queue_cap: int | None = None
    # read tier: bound on the snapshot-read lane (active only when the
    # controller is built with read_lane=True)
    read_queue_cap: int = 1024


@dataclass
class AdmissionStats:
    offered: int = 0
    admitted: int = 0
    shed: int = 0
    backpressured: int = 0
    requeued: int = 0               # starved OCC txns pushed back (front)
    max_part_depth: int = 0
    max_master_depth: int = 0
    max_read_depth: int = 0
    # per-queue rejection attribution — the array is ALWAYS sized P + 2:
    # index p < P = partition p's queue, index P = the master queue,
    # index P + 1 = the read-tier lane (0 when no read lane is wired);
    # cluster telemetry groups the first P + 1 by node (node_shed)
    rejected_by_queue: np.ndarray | None = None


class RequestPool:
    """Columnar in-flight request store. `row` holds partition-local rows
    for singles and pre-globalized master rows for cross txns."""

    def __init__(self, max_ops: int, n_cols: int, capacity: int = 2048):
        self.M, self.C = max_ops, n_cols
        self.capacity = 0
        self._grow(capacity)
        self.live = 0

    def _grow(self, new_cap: int):
        def extend(name, shape, dtype):
            new = np.zeros(shape, dtype)
            if self.capacity:
                new[:self.capacity] = getattr(self, name)
            setattr(self, name, new)
        extend("row", (new_cap, self.M), np.int32)
        extend("kind", (new_cap, self.M), np.int32)
        extend("delta", (new_cap, self.M, self.C), np.int32)
        extend("user_abort", (new_cap,), bool)
        extend("is_cross", (new_cap,), bool)
        extend("home", (new_cap,), np.int32)
        extend("tenant", (new_cap,), np.int32)
        extend("txn_id", (new_cap,), np.int64)
        extend("arrival_s", (new_cap,), np.float64)
        extend("admit_s", (new_cap,), np.float64)
        extend("form_s", (new_cap,), np.float64)
        self._free = list(range(new_cap - 1, self.capacity - 1, -1)) + \
            (self._free if self.capacity else [])
        self.capacity = new_cap

    def alloc(self, n: int) -> np.ndarray:
        while len(self._free) < n:
            self._grow(self.capacity * 2)
        idx = np.array([self._free.pop() for _ in range(n)], np.int64)
        self.live += n
        return idx

    def release(self, idx: np.ndarray):
        self._free.extend(int(i) for i in idx)
        self.live -= len(idx)


class AdmissionController:
    """Bounded per-partition + master queues over a shared request pool."""

    def __init__(self, n_partitions: int, rows_per_partition: int,
                 max_ops: int, n_cols: int = 10,
                 cfg: AdmissionConfig | None = None,
                 router: Router | None = None,
                 pool: RequestPool | None = None,
                 node_of_partition=None, read_lane: bool = False):
        self.P, self.R = n_partitions, rows_per_partition
        self.cfg = cfg or AdmissionConfig()
        self.router = router or Router(n_partitions, rows_per_partition,
                                       max_ops, n_cols)
        self.pool = pool or RequestPool(max_ops, n_cols)
        self.part_queues = [deque() for _ in range(n_partitions)]
        self.master_queue = deque()
        # read tier: declared-read-only single-home transactions bypass the
        # OCC queues into this bounded lane (drained by reads.ReadTier)
        self.read_lane = bool(read_lane)
        self.read_queue = deque()
        # cluster: which node owns each partition's queue (per-node caps
        # + per-node shed/depth telemetry); None = single-node service
        self.node_of_partition = (np.asarray(node_of_partition, np.int64)
                                  if node_of_partition is not None else None)
        self.stats = AdmissionStats()
        # sized P + 2 unconditionally (read-lane slot is zero without a
        # read lane) so every consumer indexes one fixed layout
        self.stats.rejected_by_queue = np.zeros(n_partitions + 2, np.int64)

    # ------------------------------------------------------------------
    def offer(self, req: dict, now_s: float):
        """Classify + admit one arrival chunk.

        req: {'parts' (B,M), 'rows' (B,M), 'kinds', 'deltas', 'user_abort',
        'home' (declared home, -1 = undeclared), 'txn_id', 'tenant',
        'arrival_s'}.  Returns a boolean `rejected` mask over the chunk
        (True = not admitted this tick: shed or backpressured)."""
        B = req["parts"].shape[0]
        self.stats.offered += B
        if B == 0:
            return np.zeros(0, bool)
        is_cross, home = self.router.classify(
            req["parts"], req["kinds"], req["home"])

        admitted = np.zeros(B, bool)
        dest = np.where(is_cross, -1, home).astype(np.int64)
        # read tier: declared-read-only single-home transactions take the
        # bounded read lane instead of the OCC queues
        ro = req.get("read_only")
        to_read = (np.asarray(ro, bool) & ~is_cross
                   if self.read_lane and ro is not None
                   else np.zeros(B, bool))
        # per-node ingest budget (cluster): a node's partition queues share
        # one bound on top of the per-partition caps
        node_budget = None
        if self.node_of_partition is not None \
                and self.cfg.node_queue_cap is not None:
            n_nodes = int(self.node_of_partition.max()) + 1
            depth = np.zeros(n_nodes, np.int64)
            for p, q in enumerate(self.part_queues):
                depth[self.node_of_partition[p]] += len(q)
            node_budget = np.maximum(self.cfg.node_queue_cap - depth, 0)
        # singles, per home partition (≤P small iterations, vectorized body)
        for p in np.unique(dest[dest >= 0]):
            q = self.part_queues[p]
            room = max(0, self.cfg.part_queue_cap - len(q))
            if node_budget is not None:
                n = self.node_of_partition[p]
                room = min(room, int(node_budget[n]))
            sel = np.nonzero((dest == p) & ~to_read)[0]
            take = sel[:room]
            if node_budget is not None:
                node_budget[self.node_of_partition[p]] -= len(take)
            admitted[take] = True
        cross_sel = np.nonzero(is_cross)[0]
        cross_take = cross_sel[:max(0, self.cfg.master_queue_cap
                                    - len(self.master_queue))]
        admitted[cross_take] = True
        read_sel = np.nonzero(to_read)[0]
        read_take = read_sel[:max(0, self.cfg.read_queue_cap
                                  - len(self.read_queue))]
        admitted[read_take] = True

        aidx = np.nonzero(admitted)[0]
        if aidx.size:
            pool, slots = self.pool, self.pool.alloc(aidx.size)
            # cross rows are globalized once, here, at admission
            pool.row[slots] = np.where(
                is_cross[aidx, None],
                globalize_rows(req["parts"][aidx], req["rows"][aidx], self.R),
                req["rows"][aidx])
            pool.kind[slots] = req["kinds"][aidx]
            pool.delta[slots] = req["deltas"][aidx]
            pool.user_abort[slots] = req["user_abort"][aidx]
            pool.is_cross[slots] = is_cross[aidx]
            pool.home[slots] = np.where(is_cross[aidx], -1, home[aidx])
            pool.tenant[slots] = req["tenant"][aidx]
            pool.txn_id[slots] = req["txn_id"][aidx]
            pool.arrival_s[slots] = req["arrival_s"][aidx]
            pool.admit_s[slots] = now_s
            for k, i in zip(aidx, slots):
                if to_read[k]:
                    self.read_queue.append(int(i))
                elif is_cross[k]:
                    self.master_queue.append(int(i))
                else:
                    self.part_queues[int(home[k])].append(int(i))

        rejected = ~admitted
        n_rej = int(rejected.sum())
        self.stats.admitted += int(aidx.size)
        if n_rej:
            rq = np.where(dest[rejected] >= 0, dest[rejected], self.P)
            rq = np.where(to_read[rejected], self.P + 1, rq)
            np.add.at(self.stats.rejected_by_queue, rq, 1)
        if self.cfg.policy == SHED:
            self.stats.shed += n_rej
        else:
            self.stats.backpressured += n_rej
        self.stats.max_part_depth = max(
            self.stats.max_part_depth,
            max((len(q) for q in self.part_queues), default=0))
        self.stats.max_master_depth = max(self.stats.max_master_depth,
                                          len(self.master_queue))
        self.stats.max_read_depth = max(self.stats.max_read_depth,
                                        len(self.read_queue))
        return rejected

    # ------------------------------------------------------------------
    def drain_singles(self, p: int, limit: int) -> list[int]:
        q = self.part_queues[p]
        return [q.popleft() for _ in range(min(limit, len(q)))]

    def drain_master(self, limit: int) -> list[int]:
        q = self.master_queue
        return [q.popleft() for _ in range(min(limit, len(q)))]

    def requeue_master_front(self, slots):
        """Starved OCC transactions re-enter at the FRONT, preserving FIFO."""
        self.master_queue.extendleft(reversed([int(s) for s in slots]))
        self.stats.requeued += len(slots)

    # -- read tier -------------------------------------------------------
    def drain_reads(self, limit: int) -> list[int]:
        q = self.read_queue
        return [q.popleft() for _ in range(min(limit, len(q)))]

    def requeue_reads_front(self, slots):
        """Mid-epoch deferral: reads whose home partition a published slab
        already dirtied re-enter the READ lane at the front (in their
        original order) — they serve at the next fence, not via OCC."""
        self.read_queue.extendleft(reversed([int(s) for s in slots]))
        self.stats.requeued += len(slots)

    def requeue_reads_occ(self, slots):
        """Staleness-bound fallback: reads with NO replica inside the bound
        re-enter their home partition's OCC queue at the FRONT (they are
        the oldest admitted work) — over-stale data is never served, the
        transaction executes fence-fresh through the normal phases."""
        for s in reversed([int(s) for s in slots]):
            self.part_queues[int(self.pool.home[s])].appendleft(int(s))
        self.stats.requeued += len(slots)

    def read_depth(self) -> int:
        return len(self.read_queue)

    def depth(self) -> int:
        return sum(len(q) for q in self.part_queues) \
            + len(self.master_queue) + len(self.read_queue)

    def depths(self):
        """(per-partition queue depths (P,), master queue depth)."""
        return (np.array([len(q) for q in self.part_queues], np.int64),
                len(self.master_queue))
