"""Open-loop and closed-loop client simulators (§7 workloads, served live).

Open-loop clients emit requests on their own timeline at a configured rate —
Poisson, uniform, or bursty (square-wave rate modulation) inter-arrivals —
independent of how fast the service drains them; this is the arrival model
under which admission control and queue growth are meaningful.  Closed-loop
clients keep a fixed number of requests outstanding and only issue a new one
when a previous one commits (the paper's §7 load generators).

Payloads come from the streaming workload sources (`ycsb.make_raw` /
`tpcc.make_raw`); multi-tenant mixes are just several clients with distinct
tenant ids feeding one service.
"""
from __future__ import annotations

import numpy as np

from repro.db import tpcc, ycsb

_GEN_CHUNK = 256      # payload pre-generation granularity

_REQ_FIELDS = ("parts", "rows", "kinds", "deltas", "user_abort", "home",
               "read_only", "txn_id", "tenant", "arrival_s")


def empty_request(M: int, C: int) -> dict:
    return {"parts": np.zeros((0, M), np.int32),
            "rows": np.zeros((0, M), np.int32),
            "kinds": np.zeros((0, M), np.int32),
            "deltas": np.zeros((0, M, C), np.int32),
            "user_abort": np.zeros(0, bool),
            "home": np.zeros(0, np.int32),
            "read_only": np.zeros(0, bool),
            "txn_id": np.zeros(0, np.int64),
            "tenant": np.zeros(0, np.int32),
            "arrival_s": np.zeros(0, np.float64)}


def concat_requests(chunks: list[dict]) -> dict:
    chunks = [c for c in chunks if c["parts"].shape[0]]
    if not chunks:
        return None
    return {k: np.concatenate([c[k] for c in chunks]) for k in _REQ_FIELDS}


def slice_request(req: dict, mask_or_idx) -> dict:
    return {k: req[k][mask_or_idx] for k in _REQ_FIELDS}


class YCSBSource:
    """Streaming YCSB payload generator (skew via cfg.zipf_theta etc.)."""

    def __init__(self, cfg: ycsb.YCSBConfig, seed: int = 0):
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self.M, self.C = ycsb.M, ycsb.C
        self.row_bytes = np.full((ycsb.M,), ycsb.ROW_BYTES, np.int32)
        self.op_bytes = self.row_bytes.copy()

    def generate(self, n: int) -> dict:
        raw = ycsb.make_raw(self.cfg, n, self.rng)
        # clients declare their home; cross txns go undeclared (-1) straight
        # to the master queue, mis-declared singles get re-route detected
        raw["home"] = np.where(raw.pop("declared_cross"), -1,
                               raw["home"]).astype(np.int32)
        return raw


class TPCCSource:
    """Streaming NewOrder/Payment generator (shared sequencer state)."""

    def __init__(self, cfg: tpcc.TPCCConfig, state: tpcc.TPCCState | None = None,
                 seed: int = 0):
        self.cfg = cfg
        self.state = state or tpcc.TPCCState(cfg)
        self.rng = np.random.default_rng(seed)
        self.M, self.C = tpcc.M, tpcc.C
        self.row_bytes = None          # per-txn bytes: not batch-uniform
        self.op_bytes = None
        self._emitted = 0

    def generate(self, n: int) -> dict:
        raw = tpcc.make_raw(self.cfg, self.state, n, self.rng,
                            txn_offset=self._emitted)
        self._emitted += n
        raw["home"] = np.where(raw.pop("declared_cross"), -1,
                               raw["home"]).astype(np.int32)
        raw.pop("row_bytes"), raw.pop("op_bytes")
        return raw

    def unclaim(self, req: dict):
        """Unwind the mirror effects of requests that will NEVER execute
        (shed by admission, dropped from the retry buffer): a Delivery's
        claimed orders go back to the front of the undelivered queues
        instead of stranding in ``pending_claims`` forever, and a shed
        NewOrder's mirror entry (undelivered push, last-order, ring
        contents, ledger) is erased so Delivery never chases an order the
        device has no index entry for."""
        if self.cfg.mix != "full":
            return
        kinds, deltas = req["kinds"], req["deltas"]
        for i in range(kinds.shape[0]):
            tpcc.unwind_never_executed(self.state, kinds[i, :tpcc.IDX_OPS],
                                       deltas[i, :tpcc.IDX_OPS])


class OpenLoopClient:
    """Emits requests at `rate_txn_s` regardless of service progress.

    process: 'poisson' (Exp inter-arrivals), 'uniform' (1/rate), or 'bursty'
    (square wave: rate*burst_factor for the first half of every
    burst_period_s, rate/burst_factor for the second half).
    Backpressured requests go to a bounded retry buffer re-offered first;
    overflow beyond `retry_cap` is dropped and counted."""

    def __init__(self, source, rate_txn_s: float, process: str = "poisson",
                 burst_factor: float = 4.0, burst_period_s: float = 0.2,
                 tenant: int = 0, seed: int = 0, retry_cap: int = 4096):
        self.source = source
        self.rate = float(rate_txn_s)
        self.process = process
        self.burst_factor = burst_factor
        self.burst_period_s = burst_period_s
        self.tenant = tenant
        self.rng = np.random.default_rng(seed ^ 0x5EED)
        self.retry_cap = retry_cap
        self.retry: dict | None = None
        self.dropped_retries = 0
        self.emitted = 0
        self._t = 0.0                 # arrival-time cursor
        self._pending: dict | None = None   # generated but not yet due

    # ------------------------------------------------------------------
    def _gaps(self, n):
        if self.process == "poisson":
            return self.rng.exponential(1.0 / self.rate, n)
        if self.process == "uniform":
            return np.full(n, 1.0 / self.rate)
        if self.process == "bursty":
            # square-wave rate modulation, normalized so the time-averaged
            # arrival rate stays `rate` (half period high, half period low)
            f = self.burst_factor
            norm = 2.0 / (f + 1.0 / f)
            gaps = np.empty(n)
            t = self._t
            for i in range(n):     # sequential: each gap shifts the phase
                phase = (t % self.burst_period_s) / self.burst_period_s
                r = self.rate * norm * (f if phase < 0.5 else 1.0 / f)
                gaps[i] = self.rng.exponential(1.0 / r)
                t += gaps[i]
            return gaps
        raise ValueError(f"unknown arrival process {self.process!r}")

    def _generate_chunk(self):
        gaps = self._gaps(_GEN_CHUNK)
        arrivals = self._t + np.cumsum(gaps)
        self._t = float(arrivals[-1])
        req = self.source.generate(_GEN_CHUNK)
        req["arrival_s"] = arrivals
        req["tenant"] = np.full(_GEN_CHUNK, self.tenant, np.int32)
        req["txn_id"] = np.arange(self.emitted, self.emitted + _GEN_CHUNK,
                                  dtype=np.int64)
        self.emitted += _GEN_CHUNK
        return req

    def pull(self, until_s: float) -> dict | None:
        """All requests (retries first) with arrival time <= until_s."""
        chunks = []
        if self.retry is not None:
            chunks.append(self.retry)
            self.retry = None
        while True:
            if self._pending is not None:
                due = self._pending["arrival_s"] <= until_s
                if due.any():
                    chunks.append(slice_request(self._pending, due))
                    rest = ~due
                    self._pending = slice_request(self._pending, rest) \
                        if rest.any() else None
                if self._pending is not None:
                    break              # earliest undelivered is in the future
            if self._t > until_s:
                break
            self._pending = self._generate_chunk()
        return concat_requests(chunks)

    def on_shed(self, req: dict, now_s: float):
        """Shed requests are gone — an open-loop client just keeps emitting.
        Sources with host-mirror claims (TPC-C Delivery) unwind them."""
        unclaim = getattr(self.source, "unclaim", None)
        if unclaim is not None:
            unclaim(req)

    def shutdown(self):
        """End of a serving run: requests generated ahead of their arrival
        time (the lookahead chunk) and buffered retries will never execute
        — unwind their host-mirror effects (TPC-C claims/NewOrder entries)
        through the same channel sheds use."""
        unclaim = getattr(self.source, "unclaim", None)
        for buf in (self._pending, self.retry):
            if buf is not None and unclaim is not None:
                unclaim(buf)
        self._pending = self.retry = None

    def push_back(self, req: dict):
        """Backpressured requests: retry next tick (bounded buffer)."""
        merged = concat_requests([c for c in (self.retry, req)
                                  if c is not None])
        if merged is None:
            return
        n = merged["parts"].shape[0]
        if n > self.retry_cap:
            dropped = n - self.retry_cap
            self.dropped_retries += dropped
            unclaim = getattr(self.source, "unclaim", None)
            if unclaim is not None:    # oldest overflow is dropped for good
                unclaim(slice_request(merged, np.arange(dropped)))
            merged = slice_request(merged, np.arange(dropped, n))
        self.retry = merged


class ClosedLoopClient:
    """Keeps `n_outstanding` requests in flight; a commit triggers the next
    issue (plus optional think time)."""

    def __init__(self, source, n_outstanding: int, tenant: int = 1,
                 think_time_s: float = 0.0, seed: int = 0):
        self.source = source
        self.n_outstanding = int(n_outstanding)
        self.tenant = tenant
        self.think_time_s = think_time_s
        self.rng = np.random.default_rng(seed ^ 0xC105ED)
        self.emitted = 0
        self.in_flight = 0
        self._due: list[float] = [0.0] * self.n_outstanding  # issue times

    def _issue(self, n, now_s):
        req = self.source.generate(n)
        req["arrival_s"] = np.full(n, now_s, np.float64)
        req["tenant"] = np.full(n, self.tenant, np.int32)
        req["txn_id"] = np.arange(self.emitted, self.emitted + n,
                                  dtype=np.int64)
        self.emitted += n
        self.in_flight += n
        return req

    def pull(self, until_s: float) -> dict | None:
        due = [t for t in self._due if t <= until_s]
        if not due:
            return None
        self._due = [t for t in self._due if t > until_s]
        return self._issue(len(due), until_s)

    def on_complete(self, n: int, now_s: float):
        """n of this client's requests reached the commit fence."""
        self.in_flight -= n
        think = self.rng.exponential(self.think_time_s, n) \
            if self.think_time_s > 0 else np.zeros(n)
        self._due.extend((now_s + t) for t in think)

    def push_back(self, req: dict):
        """Backpressure for a closed-loop client = the slot frees instantly
        and reissues on the next pull."""
        n = req["parts"].shape[0]
        self.in_flight -= n
        self._due.extend([0.0] * n)

    def on_shed(self, req: dict, now_s: float):
        """A shed request is an error the client observes: the slot frees
        and reissues — it must NOT leak from the outstanding window."""
        n = req["parts"].shape[0]
        self.in_flight -= n
        self._due.extend([now_s] * n)
