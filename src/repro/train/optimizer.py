"""AdamW with fp32 master weights (params live in bf16 for compute).

Pure-pytree implementation (no optax in this environment). The optimizer
state is what STAR-DP owner-shards over the ``data`` axis (the "single-master"
dense update — see repro.train.star_dp / DESIGN.md §2.2).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params) -> dict:
    # copy=True: fp32 params must NOT alias their master copies (donation)
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, opt_state, hp: AdamWConfig):
    """Returns (new_params (param dtype), new_opt_state, grad_norm)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hp.grad_clip / (gnorm + 1e-9))
    lr = hp.lr * jnp.minimum(1.0, step.astype(jnp.float32) / hp.warmup_steps)
    b1t = 1.0 - hp.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - hp.b2 ** step.astype(jnp.float32)

    def upd(master, m, v, g):
        g = g.astype(jnp.float32) * scale
        m = hp.b1 * m + (1 - hp.b1) * g
        v = hp.b2 * v + (1 - hp.b2) * jnp.square(g)
        mh = m / b1t
        vh = v / b2t
        new_master = master - lr * (mh / (jnp.sqrt(vh) + hp.eps)
                                    + hp.weight_decay * master)
        return new_master, m, v

    flat_master, treedef = jax.tree.flatten(opt_state["master"])
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_g = jax.tree.leaves(grads)
    new = [upd(a, b, c, d) for a, b, c, d in zip(flat_master, flat_m, flat_v, flat_g)]
    new_master = jax.tree.unflatten(treedef, [x[0] for x in new])
    new_m = jax.tree.unflatten(treedef, [x[1] for x in new])
    new_v = jax.tree.unflatten(treedef, [x[2] for x in new])
    param_dtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params = jax.tree.map(lambda mp, dt: mp.astype(dt), new_master, param_dtypes)
    return new_params, {"master": new_master, "m": new_m, "v": new_v,
                        "step": step}, gnorm
