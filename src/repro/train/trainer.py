"""Host training loop: STAR-DP epoch commits, fault recovery, elasticity.

Responsibilities:
  * builds the mesh + jitted step (repro.launch.steps);
  * streams deterministic synthetic batches;
  * fences every ``steps_per_epoch`` steps (in-memory commit + optional disk
    checkpoint via repro.train.checkpoint);
  * ``inject_failure()`` reverts to the last committed epoch and replays —
    the run converges to the same step count with no state divergence;
  * straggler mitigation: per-step wall-time watchdog — steps slower than
    ``straggler_factor`` x the running median are counted and surfaced so a
    cluster controller can re-shard (here: telemetry + forced fence);
  * elastic rescale: ``reshard(new_mesh)`` re-places params/opt on a
    different mesh (device_put with the new NamedSharding) — scale-up/down
    between epochs without restarting the process.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.pipeline import make_batch
from repro.launch import sharding as shd
from repro.launch.steps import make_train_fn
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.star_dp import EpochCommitLog, replication_bytes


@dataclass
class TrainerConfig:
    seq_len: int = 128
    batch: int = 8
    steps_per_epoch: int = 8
    checkpoint_dir: str | None = None
    straggler_factor: float = 3.0
    hp: AdamWConfig = field(default_factory=AdamWConfig)


class Trainer:
    def __init__(self, cfg: ArchConfig, mesh, tcfg: TrainerConfig):
        self.cfg, self.mesh, self.tcfg = cfg, mesh, tcfg
        from repro.models import transformer as tf
        self.params = tf.init_params(cfg, jax.random.key(0))
        self.opt_state = init_opt_state(self.params)
        self.step = 0
        self.commit_log = EpochCommitLog(tcfg.steps_per_epoch)
        self.commit_log.maybe_fence(0, self.params, self.opt_state)
        self.straggler_events = 0
        self._times: list[float] = []
        self.metrics_history: list[dict] = []
        self._build()

    def _build(self):
        pspec = shd.param_specs(self.cfg, self.params, self.mesh)
        ospec = shd.opt_specs(self.cfg, self.opt_state, pspec, self.mesh)
        self._psh = shd.named(self.mesh, pspec)
        self._osh = shd.named(self.mesh, ospec)
        self.params = jax.device_put(self.params, self._psh)
        self.opt_state = jax.device_put(self.opt_state, self._osh)
        fn = make_train_fn(self.cfg, self.mesh, self.tcfg.hp)
        self._step_fn = jax.jit(fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def run(self, n_steps: int, seed: int = 0):
        for _ in range(n_steps):
            batch = make_batch(self.cfg, "train", self.tcfg.seq_len,
                               self.tcfg.batch, seed=seed * 1_000_003 + self.step)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step += 1
            self._watch_stragglers(dt)
            self.metrics_history.append(
                {k: float(v) for k, v in metrics.items()} | {"step": self.step})
            if self.commit_log.maybe_fence(self.step, self.params,
                                           self.opt_state):
                if self.tcfg.checkpoint_dir:
                    save_checkpoint(self.tcfg.checkpoint_dir, self.step,
                                    self.params, self.opt_state,
                                    {"epoch": self.step // self.tcfg.steps_per_epoch})
        return self.metrics_history[-1]

    def _watch_stragglers(self, dt: float):
        self._times.append(dt)
        if len(self._times) >= 5:
            med = float(np.median(self._times[-20:]))
            if dt > self.tcfg.straggler_factor * med:
                self.straggler_events += 1

    # ------------------------------------------------------------------
    # fault tolerance
    # ------------------------------------------------------------------
    def inject_failure(self):
        """Node failure mid-epoch: uncommitted steps are lost; revert to the
        last committed epoch (STAR §4.5: epoch group commit + revert)."""
        c = self.commit_log.revert()
        self.params, self.opt_state, self.step = c.params, c.opt_state, c.step
        return c.step

    def restore_from_disk(self):
        from repro.models import transformer as tf
        out = restore_checkpoint(self.tcfg.checkpoint_dir, self.params,
                                 self.opt_state)
        if out is None:
            return None
        self.params, self.opt_state, meta = out
        self.params = jax.device_put(self.params, self._psh)
        self.opt_state = jax.device_put(self.opt_state, self._osh)
        self.step = meta["step"]
        self.commit_log.maybe_fence(self.step, self.params, self.opt_state)
        return meta

    # ------------------------------------------------------------------
    # elasticity
    # ------------------------------------------------------------------
    def reshard(self, new_mesh):
        """Scale the cluster between epochs: re-place state on a new mesh."""
        self.mesh = new_mesh
        host = jax.tree.map(np.asarray, self.params)
        host_opt = jax.tree.map(np.asarray, self.opt_state)
        self.params, self.opt_state = host, host_opt
        self._build()

    def replication_report(self):
        """Hybrid replication accounting on the current gradient (Fig. 15
        analogue for STAR-DP)."""
        batch = make_batch(self.cfg, "train", self.tcfg.seq_len,
                           self.tcfg.batch, seed=123)
        from repro.models import transformer as tf

        def lf(p):
            return tf.loss_fn(p, batch, self.cfg, mesh=self.mesh)[0]
        grads = jax.grad(lf)(self.params)
        return replication_bytes(self.params, grads)
