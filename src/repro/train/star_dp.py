"""STAR-DP: the paper's asymmetric-replication protocol applied to training.

Mapping (DESIGN.md §2.2):

* **epoch group commit** — training proceeds in commit epochs of K steps;
  the fence at each boundary snapshots (params, opt state, step) as the last
  *committed* state.  Any failure reverts to it — the paper's two-version
  revert (§4.5.2) at trainer granularity.
* **version-tagged replication (Thomas write rule)** — every replica carries
  a step-TID per tensor group; ``merge_replicas`` applies incoming tensors
  iff their TID is newer.  Out-of-order / duplicated broadcasts (elastic
  workers, async parameter serving) converge to the newest state.
* **hybrid replication** — dense tensors replicate by value; sparse updates
  (MoE expert deltas, embedding-row deltas) replicate as operations
  ``(indices, delta)`` and are re-applied — the §5 bandwidth optimization.
  ``replication_bytes`` quantifies both (the Fig.15 analogue for training).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# epoch commit / revert
# ---------------------------------------------------------------------------
@dataclass
class CommitState:
    epoch: int
    step: int
    params: object
    opt_state: object


class EpochCommitLog:
    """In-memory committed snapshot + fence bookkeeping."""

    def __init__(self, steps_per_epoch: int = 8):
        self.steps_per_epoch = steps_per_epoch
        self.committed: CommitState | None = None
        self.fences = 0

    def maybe_fence(self, step: int, params, opt_state) -> bool:
        if step % self.steps_per_epoch != 0:
            return False
        epoch = step // self.steps_per_epoch
        # the fence: all replication streams quiesce (synchronous in-process),
        # then the snapshot becomes the commit point. Deep-copied so donated
        # step buffers can't invalidate the committed epoch (at scale this is
        # the second of the two record versions, §4.5.2).
        snap_p = jax.tree.map(jnp.copy, params)
        snap_o = jax.tree.map(jnp.copy, opt_state)
        self.committed = CommitState(epoch, step, snap_p, snap_o)
        self.fences += 1
        return True

    def revert(self) -> CommitState:
        if self.committed is None:
            raise RuntimeError("no committed epoch to revert to")
        return self.committed


# ---------------------------------------------------------------------------
# Thomas-rule replica merge
# ---------------------------------------------------------------------------
def merge_replicas(dst_params, dst_tid: int, src_params, src_tid: int):
    """Apply src iff strictly newer (per-replica TID = global step)."""
    if src_tid <= dst_tid:
        return dst_params, dst_tid
    return src_params, src_tid


def merge_tensor_groups(dst: dict, src: dict):
    """Group-granular merge: {name: (tensor, tid)} — newest tid wins per
    group; order/duplication of messages is irrelevant (Thomas rule)."""
    out = dict(dst)
    for name, (tensor, tid) in src.items():
        if name not in out or tid > out[name][1]:
            out[name] = (tensor, tid)
    return out


# ---------------------------------------------------------------------------
# hybrid replication streams
# ---------------------------------------------------------------------------
def dense_value_stream(params) -> int:
    """Bytes to replicate the full dense state (value replication)."""
    return int(sum(np.prod(p.shape) * p.dtype.itemsize
                   for p in jax.tree.leaves(params)))


def sparse_operation_stream(param, row_indices, delta_rows):
    """Operation replication for a row-sparse update: ship (indices, delta)
    and replay on the replica. Returns (apply_fn, bytes)."""
    nbytes = int(row_indices.size * 4
                 + np.prod(delta_rows.shape) * delta_rows.dtype.itemsize)

    def apply_fn(replica_param):
        return replica_param.at[row_indices].add(delta_rows)

    return apply_fn, nbytes


def sparse_rows_touched(grads_row_norms, threshold: float = 0.0):
    """Rows with non-zero gradient — the 'single-partition transactions' of
    training: embedding rows / experts touched only by local data."""
    return jnp.nonzero(grads_row_norms > threshold)[0]


@dataclass
class ReplicationStats:
    value_bytes: int = 0
    op_bytes: int = 0

    @property
    def savings(self) -> float:
        return self.value_bytes / max(self.op_bytes, 1)


def replication_bytes(params, grads, sparse_paths=("embed", "moe")) -> ReplicationStats:
    """Hybrid accounting: sparse-path tensors ship (touched rows, delta);
    dense tensors ship full values. grads: same pytree as params."""
    stats = ReplicationStats()
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_g = jax.tree.leaves(grads)
    for (path, p), g in zip(flat_p, flat_g):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        nbytes = int(np.prod(p.shape)) * p.dtype.itemsize
        if any(s in name for s in sparse_paths) and g.ndim >= 2:
            rows = g.reshape(g.shape[0], -1)
            touched = jnp.sum(jnp.any(rows != 0, axis=1))
            row_bytes = int(np.prod(p.shape[1:])) * p.dtype.itemsize
            stats.op_bytes += int(touched) * (row_bytes + 4)
            stats.value_bytes += nbytes
        else:
            stats.op_bytes += nbytes
            stats.value_bytes += nbytes
    return stats
