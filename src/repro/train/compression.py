"""Gradient compression for the cross-pod replication stream.

STAR's hybrid replication insight — ship the cheap representation when the
stream structure allows it (§5) — applied to the training runtime's widest
link: the cross-pod gradient all-reduce. Two composable codecs with
error-feedback (residual carrying), the standard trick that keeps SGD
convergence under biased compression:

* ``topk``  — operation-style: ship (indices, values) of the largest-|g|
              fraction per tensor;
* ``int8``  — value-style: per-tensor affine quantization.

``CompressedAllReduce`` owns the error-feedback state and reports the bytes
shipped vs dense — the training analogue of Fig. 15's accounting.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


def topk_encode(g, frac: float = 0.01):
    """Returns (idx, vals, shape) for the top-|g| fraction of entries."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    return idx.astype(jnp.int32), vals, g.shape


def topk_decode(idx, vals, shape, dtype):
    flat = jnp.zeros((int(np.prod(shape)),), dtype)
    return flat.at[idx].set(vals.astype(dtype)).reshape(shape)


def int8_encode(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


@dataclass
class CompressionStats:
    dense_bytes: int = 0
    shipped_bytes: int = 0

    @property
    def ratio(self) -> float:
        return self.dense_bytes / max(self.shipped_bytes, 1)


class CompressedAllReduce:
    """Error-feedback compressor for a gradient pytree."""

    def __init__(self, codec: str = "topk", frac: float = 0.01):
        assert codec in ("topk", "int8", "none")
        self.codec, self.frac = codec, frac
        self.residual = None
        self.stats = CompressionStats()

    def __call__(self, grads):
        """Compress+decompress (the lossy channel) with error feedback.
        Returns the gradient actually applied; callers all-reduce the
        compressed representation on real multi-pod hardware."""
        if self.codec == "none":
            return grads
        if self.residual is None:
            self.residual = jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads)

        new_resid, out = [], []
        flat_g = jax.tree.leaves(grads)
        flat_r = jax.tree.leaves(self.residual)
        for g, r in zip(flat_g, flat_r):
            acc = g.astype(jnp.float32) + r
            nbytes = acc.size * g.dtype.itemsize
            if self.codec == "topk":
                idx, vals, shape = topk_encode(acc, self.frac)
                sent = topk_decode(idx, vals, shape, jnp.float32)
                self.stats.shipped_bytes += int(idx.size * (4 + 4))
            else:
                q, scale = int8_encode(acc)
                sent = int8_decode(q, scale, jnp.float32)
                self.stats.shipped_bytes += int(q.size + 4)
            self.stats.dense_bytes += int(nbytes)
            new_resid.append(acc - sent)
            out.append(sent.astype(g.dtype))
        treedef = jax.tree.structure(grads)
        self.residual = jax.tree.unflatten(treedef, new_resid)
        return jax.tree.unflatten(treedef, out)
