"""Disk checkpointing with epoch-commit semantics.

Checkpoints are written at epoch fences only, so on-disk state is always a
committed epoch; restore picks the NEWEST complete checkpoint (Thomas-rule
style: highest step wins, partial/corrupt directories are skipped).  Arrays
are saved leaf-per-file via numpy (no orbax in this environment); the pytree
structure is rebuilt from the key paths.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:      # npz has no bf16: store f32
            arr = arr.astype(np.float32)   # (bf16 -> f32 is lossless)
        out[key] = arr
    return out


def _unflatten_into(template, flat):
    def rebuild(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = flat[key]
        return jnp.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape)
    return jax.tree_util.tree_map_with_path(rebuild, template)


def save_checkpoint(directory, step: int, params, opt_state, extra: dict | None = None):
    d = Path(directory) / f"step_{step:010d}"
    tmp = d.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    np.savez(tmp / "params.npz", **_flatten(params))
    np.savez(tmp / "opt.npz", **_flatten(opt_state))
    (tmp / "meta.json").write_text(json.dumps(
        {"step": step, **(extra or {})}))
    tmp.rename(d)                                   # atomic commit point
    return d


def latest_checkpoint(directory) -> Path | None:
    d = Path(directory)
    if not d.exists():
        return None
    cands = sorted([p for p in d.iterdir()
                    if p.is_dir() and p.name.startswith("step_")
                    and (p / "meta.json").exists()])
    return cands[-1] if cands else None


def restore_checkpoint(directory, params_template, opt_template):
    ckpt = latest_checkpoint(directory)
    if ckpt is None:
        return None
    meta = json.loads((ckpt / "meta.json").read_text())
    pz = np.load(ckpt / "params.npz")
    oz = np.load(ckpt / "opt.npz")
    params = _unflatten_into(params_template, dict(pz))
    opt = _unflatten_into(opt_template, dict(oz))
    return params, opt, meta
