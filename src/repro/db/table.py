"""Compatibility shim — the table machinery moved to ``repro.storage``.

The array-resident two-version tables (§4.5.2) now live in
``repro.storage.engine`` next to the ordered secondary indexes; this module
re-exports the original names so existing imports keep working.
"""
from repro.storage.engine import (Database, TableSpec, flat_tid, flat_val,  # noqa: F401
                                  global_key, make_database, make_table,
                                  snapshot_commit, revert_to_snapshot)
