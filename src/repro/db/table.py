"""Array-resident tables with two record versions (fault tolerance, §4.5.2).

A table is partition-major: ``val (P, cap, C) int32``, ``tid (P, cap) uint32``.
``*_prev`` hold the last *committed epoch* snapshot; at every replication
fence ``snapshot_commit`` promotes the working version, and on failure
``revert_to_snapshot`` restores it (the paper's two-version revert).

Columns are int32 words — a hardware-friendly stand-in for the paper's byte
fields (YCSB: 10x10-byte columns -> 10 words + padding; TPC-C rows are
word-packed per repro.db.tpcc). DESIGN.md logs this adaptation.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class TableSpec:
    name: str
    capacity: int            # rows per partition
    n_cols: int              # int32 words per row


Database = dict   # {table: {"val","tid","val_prev","tid_prev"}, "_epoch": u32}


def make_table(spec: TableSpec, n_partitions: int):
    val = jnp.zeros((n_partitions, spec.capacity, spec.n_cols), jnp.int32)
    tid = jnp.zeros((n_partitions, spec.capacity), jnp.uint32)
    return {"val": val, "tid": tid, "val_prev": val, "tid_prev": tid}


def make_database(specs: list[TableSpec], n_partitions: int) -> Database:
    db = {s.name: make_table(s, n_partitions) for s in specs}
    db["_epoch"] = jnp.uint32(1)
    return db


def snapshot_commit(db: Database) -> Database:
    """Promote working version to committed snapshot (runs inside the fence)."""
    out = {}
    for k, t in db.items():
        if k == "_epoch":
            out[k] = t + jnp.uint32(1)
        else:
            out[k] = {"val": t["val"], "tid": t["tid"],
                      "val_prev": t["val"], "tid_prev": t["tid"]}
    return out


def revert_to_snapshot(db: Database) -> Database:
    """Failure: discard everything written in the current (uncommitted) epoch."""
    out = {}
    for k, t in db.items():
        if k == "_epoch":
            out[k] = t
        else:
            out[k] = {"val": t["val_prev"], "tid": t["tid_prev"],
                      "val_prev": t["val_prev"], "tid_prev": t["tid_prev"]}
    return out


# ---------------------------------------------------------------------------
# flat views (single-master phase sees one address space)
# ---------------------------------------------------------------------------
def flat_val(table):
    P, cap, C = table["val"].shape
    return table["val"].reshape(P * cap, C)


def flat_tid(table):
    P, cap = table["tid"].shape
    return table["tid"].reshape(P * cap)


def global_key(partition, idx, capacity):
    return partition * capacity + idx
