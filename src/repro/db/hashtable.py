"""Open-addressing hash index over dense arrays (vectorized linear probing).

The paper's tables are hash tables (§3).  Pointer-chasing has no TPU analogue,
so the index is a power-of-two slot array probed with vectorized gathers; a
batch of lookups is a (B, max_probes) gather fan-out resolved with argmax.
Used by the generic key->row path and exercised directly by tests; YCSB/TPC-C
primary keys also have direct-index fast paths (DESIGN.md §2.1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(-1)


def make_index(n_slots: int):
    assert n_slots & (n_slots - 1) == 0, "n_slots must be a power of two"
    return {"key": jnp.full((n_slots,), EMPTY, jnp.int32),
            "row": jnp.full((n_slots,), EMPTY, jnp.int32)}


def _hash(key, n_slots):
    k = jnp.asarray(key, jnp.uint32)
    k = (k ^ (k >> 16)) * jnp.uint32(0x45d9f3b)
    k = (k ^ (k >> 16)) * jnp.uint32(0x45d9f3b)
    k = k ^ (k >> 16)
    return (k & jnp.uint32(n_slots - 1)).astype(jnp.int32)


def insert(index, keys, rows, max_probes: int = 32):
    """Sequential batch insert (scan) — index build is a setup-time op."""
    n_slots = index["key"].shape[0]

    def put(idx, kr):
        key, row = kr
        h = _hash(key, n_slots)

        def body(state):
            i, _ = state
            return i + 1, (h + i + 1) % n_slots

        def cond(state):
            i, slot = state
            return (idx["key"][slot] != EMPTY) & (i < max_probes)

        _, slot = jax.lax.while_loop(cond, body, (jnp.int32(0), h))
        return {"key": idx["key"].at[slot].set(key),
                "row": idx["row"].at[slot].set(row)}, None

    index, _ = jax.lax.scan(put, index, (keys, rows))
    return index


def lookup(index, keys, max_probes: int = 32):
    """Vectorized probe: (B,) keys -> (B,) rows (-1 if absent)."""
    n_slots = index["key"].shape[0]
    h = _hash(keys, n_slots)                                  # (B,)
    probes = (h[:, None] + jnp.arange(max_probes)[None, :]) % n_slots
    probe_keys = index["key"][probes]                         # (B, max_probes)
    hit = probe_keys == keys[:, None]
    any_hit = jnp.any(hit, axis=1)
    first = jnp.argmax(hit, axis=1)
    rows = index["row"][probes[jnp.arange(keys.shape[0]), first]]
    return jnp.where(any_hit, rows, EMPTY)
