"""Durability: per-worker write-ahead logs + fuzzy checkpoints (§4.5.1, §5).

Log entry = (key, value words, TID) — TID embeds the epoch.  Operation-
replication messages are transformed before logging: the op is applied first
and the WHOLE record value is logged (paper §5), so recovery can replay logs
in ANY order under the Thomas write rule.

Checkpoints are fuzzy (no freeze): the checkpointer scans (value, TID) while
writers proceed; recovery loads the checkpoint and replays all logs since the
checkpoint's start epoch e_c, again Thomas-rule-merged.  ``recover`` is
exercised by tests end-to-end (crash -> reload -> bit-identical state).
"""
from __future__ import annotations

import json
import os
import struct
from pathlib import Path

import numpy as np

HEADER = struct.Struct("<IIQ")     # n_entries, n_cols, epoch


class WriteAheadLog:
    def __init__(self, directory: str | Path, worker_id: int = 0):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.path = self.dir / f"wal_{worker_id:03d}.log"
        self._fh = open(self.path, "ab")
        self.pending_rows: list[np.ndarray] = []
        self.pending_vals: list[np.ndarray] = []
        self.pending_tids: list[np.ndarray] = []

    def append(self, rows, vals, tids, write_mask):
        """Buffer committed writes (arrays of any shape; mask selects)."""
        m = np.asarray(write_mask).reshape(-1)
        rows = np.asarray(rows).reshape(-1)[m]
        vals = np.asarray(vals).reshape(-1, np.asarray(vals).shape[-1])[m]
        tids = np.asarray(tids).reshape(-1)[m]
        if rows.size:
            self.pending_rows.append(rows.astype(np.int64))
            self.pending_vals.append(vals.astype(np.int32))
            self.pending_tids.append(tids.astype(np.uint32))

    def flush(self, epoch: int):
        """Periodic flush; also called inside the replication fence."""
        if not self.pending_rows:
            return 0
        rows = np.concatenate(self.pending_rows)
        vals = np.concatenate(self.pending_vals)
        tids = np.concatenate(self.pending_tids)
        self._fh.write(HEADER.pack(len(rows), vals.shape[1], epoch))
        self._fh.write(rows.tobytes())
        self._fh.write(vals.tobytes())
        self._fh.write(tids.tobytes())
        self._fh.flush()
        os.fsync(self._fh.fileno())
        n = len(rows)
        self.pending_rows, self.pending_vals, self.pending_tids = [], [], []
        return n

    def close(self):
        self._fh.close()

    @staticmethod
    def read_entries(path: Path, since_epoch: int = 0):
        out = []
        raw = Path(path).read_bytes()
        off = 0
        while off < len(raw):
            n, c, epoch = HEADER.unpack_from(raw, off)
            off += HEADER.size
            rows = np.frombuffer(raw, np.int64, n, off); off += 8 * n
            vals = np.frombuffer(raw, np.int32, n * c, off).reshape(n, c)
            off += 4 * n * c
            tids = np.frombuffer(raw, np.uint32, n, off); off += 4 * n
            if epoch >= since_epoch:
                out.append((rows, vals, tids))
        return out


def write_checkpoint(directory: str | Path, val: np.ndarray, tid: np.ndarray,
                     epoch: int):
    """Fuzzy checkpoint: records e_c; logs earlier than e_c become dead."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    np.save(d / "ckpt_val.npy", np.asarray(val))
    np.save(d / "ckpt_tid.npy", np.asarray(tid))
    (d / "ckpt_meta.json").write_text(json.dumps({"epoch": int(epoch)}))


def recover(directory: str | Path, shuffle_seed: int | None = None):
    """Load checkpoint + replay all WALs since e_c with the Thomas rule.
    Returns (val, tid, epoch).

    ``shuffle_seed`` permutes the replay order of every (file, flush-chunk)
    pair before applying — the Thomas rule makes recovery order-free (each
    entry is a whole-record post-image tagged with its commit TID, whose
    epoch lives in the high bits), so any permutation must produce the
    identical state; tests exercise this directly."""
    from repro.core.replication import thomas_apply
    import jax.numpy as jnp
    d = Path(directory)
    meta = json.loads((d / "ckpt_meta.json").read_text())
    val = jnp.asarray(np.load(d / "ckpt_val.npy"))
    tid = jnp.asarray(np.load(d / "ckpt_tid.npy"))
    shape = val.shape
    fval = val.reshape(-1, shape[-1])
    ftid = tid.reshape(-1)
    chunks = []
    for wal in sorted(d.glob("wal_*.log")):
        chunks.extend(WriteAheadLog.read_entries(wal, meta["epoch"]))
    if shuffle_seed is not None:
        np.random.default_rng(shuffle_seed).shuffle(chunks)
    for rows, vals, tids in chunks:
        fval, ftid, _ = thomas_apply(
            fval, ftid, jnp.asarray(rows, jnp.int32), jnp.asarray(vals),
            jnp.asarray(tids))
    return fval.reshape(shape), ftid.reshape(shape[:-1]), meta["epoch"]


# ---------------------------------------------------------------------------
# live-execution durability: per-worker WALs + checkpoint cadence
# ---------------------------------------------------------------------------
class Durability:
    """Drives the dormant WAL/checkpoint machinery from live execution.

    One instance serves one engine (single-host ``StarEngine`` or one
    ``ClusterRuntime``): each worker (paper: node; here: partition group)
    appends its committed value stream to its own ``WriteAheadLog``, all
    logs flush inside the epoch's commit fence, and every
    ``checkpoint_every`` epochs the committed state is checkpointed fuzzily
    (writers proceed; the checkpoint records its start epoch e_c and
    recovery replays all logs since e_c — over-replay is idempotent under
    the Thomas rule).  An epoch-0 checkpoint of the initial state is
    written at attach time so recovery works before the first cadence
    checkpoint.

    TID epochs are 8 bits (``core.tid``): log retention beyond 255 epochs
    past the checkpoint would alias the Thomas ordering, so the cadence
    must stay well below that — asserted here.
    """

    def __init__(self, directory: str | Path, n_workers: int = 1,
                 checkpoint_every: int = 8):
        assert 0 < checkpoint_every < 200, checkpoint_every
        self.dir = Path(directory)
        self.n_workers = n_workers
        self.checkpoint_every = checkpoint_every
        self.wals = [WriteAheadLog(self.dir, w) for w in range(n_workers)]
        self.entries_logged = 0
        self.checkpoints = 0
        self.last_ckpt_epoch = 0

    def attach(self, val, tid):
        """Write the epoch-0 baseline checkpoint of the initial state —
        unless the directory already holds one (an engine resuming after a
        crash keeps the existing checkpoint + logs: recovery replays from
        the recorded e_c, and overwriting with the fresh engine's initial
        state would discard the durable history)."""
        if not (self.dir / "ckpt_meta.json").exists():
            write_checkpoint(self.dir, np.asarray(val), np.asarray(tid), 0)

    def log(self, worker: int, rows, vals, tids, write_mask):
        """Buffer one committed write stream chunk (global flat rows)."""
        self.wals[worker % self.n_workers].append(rows, vals, tids,
                                                  write_mask)

    def log_epoch_streams(self, plog, slog, R: int, C: int,
                          worker_of_partition):
        """Fan one committed epoch's streams out to the per-worker logs:
        the partitioned op stream in its §5 transformed form and the
        master's value stream split by row owner (see
        ``replication.wal_partition_streams`` / ``wal_master_streams``).
        ``worker_of_partition``: (P,) int map — ``p % n_workers`` on the
        single-host engine, ``p // ppn`` on the cluster's node blocks."""
        from repro.core import replication as repl
        if plog is not None:
            for w, rows, vals, tids, mask in repl.wal_partition_streams(
                    plog, R, self.n_workers, worker_of_partition):
                self.log(w, rows, vals, tids, mask)
        if slog is not None:
            for w, rows, vals, tids, mask in repl.wal_master_streams(
                    slog, R, C, self.n_workers, worker_of_partition):
                self.log(w, rows, vals, tids, mask)

    def commit_epoch(self, epoch: int, val=None, tid=None) -> int:
        """Inside the commit fence: fsync every worker's log; on cadence,
        also checkpoint the (committed) state passed in.  Returns the
        number of entries flushed."""
        n = sum(w.flush(epoch) for w in self.wals)
        self.entries_logged += n
        if val is not None and epoch - self.last_ckpt_epoch >= \
                self.checkpoint_every:
            write_checkpoint(self.dir, np.asarray(val), np.asarray(tid),
                             epoch)
            self.checkpoints += 1
            self.last_ckpt_epoch = epoch
        return n

    def close(self):
        for w in self.wals:
            w.close()
