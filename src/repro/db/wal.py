"""Durability: per-worker write-ahead logs + fuzzy checkpoints (§4.5.1, §5).

Two record kinds per log entry:

* ``KIND_RECORD`` — (key, value words, TID).  Operation-replication
  messages are transformed before logging: the op is applied first and the
  WHOLE record value is logged (paper §5), so recovery can replay record
  chunks in ANY order under the Thomas write rule.
* ``KIND_INDEX`` — the ordered-index maintenance op stream
  (step, kind, IX_* operand columns, TID).  Index ops are NOT
  Thomas-mergeable: recovery replays each file's index chunks in file
  order, step-group by step-group, exactly once (strictly after the
  checkpoint epoch).  A partition's index ops all land in its owner's
  file, so chunks from different files touch disjoint segments and
  commute — per-file order is the only order that matters.

Checkpoints are fuzzy for records (the checkpointer scans (value, TID)
while writers proceed; over-replay is idempotent under the Thomas rule)
and epoch-aligned for indexes (the index arrays are snapshotted at the
commit fence of e_c and index chunks replay only for epochs > e_c —
exactly-once, since double-applying an insert would duplicate the key).
``recover`` / ``recover_full`` are exercised by tests end-to-end
(crash -> reload -> bit-identical state, indexes included).
"""
from __future__ import annotations

import json
import os
import struct
from pathlib import Path

import numpy as np

HEADER = struct.Struct("<BIIQ")    # kind, n_entries, n_cols, epoch
KIND_RECORD = 0
KIND_INDEX = 1
MAGIC = b"WAL2"                    # format marker: refuses pre-v2 files
                                   # instead of mis-parsing them on resume


class WriteAheadLog:
    def __init__(self, directory: str | Path, worker_id: int = 0):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.path = self.dir / f"wal_{worker_id:03d}.log"
        fresh = not self.path.exists() or self.path.stat().st_size == 0
        if not fresh:
            # resume-after-crash appends to the existing file: refuse a
            # pre-v2 log NOW rather than corrupting it and only finding
            # out at recovery time (the one moment the WAL matters)
            with open(self.path, "rb") as fh:
                if fh.read(len(MAGIC)) != MAGIC:
                    raise ValueError(
                        f"{self.path}: not a {MAGIC.decode()} write-ahead "
                        "log — refusing to append to a pre-v2 file; start "
                        "a fresh log directory")
        self._fh = open(self.path, "ab")
        if fresh:
            self._fh.write(MAGIC)
            self._fh.flush()
        self.pending_rows: list[np.ndarray] = []
        self.pending_vals: list[np.ndarray] = []
        self.pending_tids: list[np.ndarray] = []
        self.pending_idx: list[tuple] = []     # (step, kinds, delta, tids)

    def append(self, rows, vals, tids, write_mask):
        """Buffer committed writes (arrays of any shape; mask selects)."""
        m = np.asarray(write_mask).reshape(-1)
        rows = np.asarray(rows).reshape(-1)[m]
        vals = np.asarray(vals).reshape(-1, np.asarray(vals).shape[-1])[m]
        tids = np.asarray(tids).reshape(-1)[m]
        if rows.size:
            self.pending_rows.append(rows.astype(np.int64))
            self.pending_vals.append(vals.astype(np.int32))
            self.pending_tids.append(tids.astype(np.uint32))

    def append_index_ops(self, step, kinds, delta, tids):
        """Buffer one committed index-op stream chunk (flat, step-major —
        see ``replication.wal_index_streams``)."""
        step = np.asarray(step).astype(np.int32).reshape(-1)
        if step.size:
            self.pending_idx.append(
                (step, np.asarray(kinds, np.int32).reshape(-1),
                 np.asarray(delta, np.int32).reshape(step.size, -1),
                 np.asarray(tids, np.uint32).reshape(-1)))

    def flush(self, epoch: int):
        """Periodic flush; also called inside the replication fence."""
        n_total = 0
        wrote = False
        if self.pending_rows:
            rows = np.concatenate(self.pending_rows)
            vals = np.concatenate(self.pending_vals)
            tids = np.concatenate(self.pending_tids)
            self._fh.write(HEADER.pack(KIND_RECORD, len(rows),
                                       vals.shape[1], epoch))
            self._fh.write(rows.tobytes())
            self._fh.write(vals.tobytes())
            self._fh.write(tids.tobytes())
            n_total += len(rows)
            wrote = True
            self.pending_rows, self.pending_vals, self.pending_tids = \
                [], [], []
        if self.pending_idx:
            step = np.concatenate([c[0] for c in self.pending_idx])
            kinds = np.concatenate([c[1] for c in self.pending_idx])
            delta = np.concatenate([c[2] for c in self.pending_idx])
            tids = np.concatenate([c[3] for c in self.pending_idx])
            self._fh.write(HEADER.pack(KIND_INDEX, len(step),
                                       delta.shape[1], epoch))
            self._fh.write(step.tobytes())
            self._fh.write(kinds.tobytes())
            self._fh.write(delta.tobytes())
            self._fh.write(tids.tobytes())
            n_total += len(step)
            wrote = True
            self.pending_idx = []
        if wrote:
            self._fh.flush()
            os.fsync(self._fh.fileno())
        return n_total

    def close(self):
        self._fh.close()

    @staticmethod
    def read_entries(path: Path, since_epoch: int = 0):
        """Record chunks (Thomas-mergeable post-images) at/after
        ``since_epoch``, in file order."""
        return [payload for kind, epoch, payload in
                WriteAheadLog.read_all(path)
                if kind == KIND_RECORD and epoch >= since_epoch]

    @staticmethod
    def read_all(path: Path):
        """Every entry as (kind, epoch, payload) in file order.  Record
        payload: (rows, vals, tids); index payload:
        (step, kinds, delta, tids)."""
        out = []
        raw = Path(path).read_bytes()
        if not raw:
            return out
        if raw[:len(MAGIC)] != MAGIC:
            raise ValueError(
                f"{path}: not a {MAGIC.decode()} write-ahead log — the "
                "file predates the record-kind format (re-parse would "
                "reconstruct garbage); start a fresh log directory")
        off = len(MAGIC)
        while off < len(raw):
            kind, n, c, epoch = HEADER.unpack_from(raw, off)
            off += HEADER.size
            if kind == KIND_RECORD:
                rows = np.frombuffer(raw, np.int64, n, off); off += 8 * n
                vals = np.frombuffer(raw, np.int32, n * c, off).reshape(n, c)
                off += 4 * n * c
                tids = np.frombuffer(raw, np.uint32, n, off); off += 4 * n
                out.append((kind, epoch, (rows, vals, tids)))
            else:
                step = np.frombuffer(raw, np.int32, n, off); off += 4 * n
                kinds = np.frombuffer(raw, np.int32, n, off); off += 4 * n
                delta = np.frombuffer(raw, np.int32, n * c, off).reshape(n, c)
                off += 4 * n * c
                tids = np.frombuffer(raw, np.uint32, n, off); off += 4 * n
                out.append((kind, epoch, (step, kinds, delta, tids)))
        return out


def write_checkpoint(directory: str | Path, val: np.ndarray, tid: np.ndarray,
                     epoch: int, indexes=None):
    """Fuzzy checkpoint: records e_c; logs earlier than e_c become dead.
    ``indexes`` (optional list of {"key","prow","tid"}) snapshot alongside
    — index chunks replay strictly AFTER e_c (exactly-once), so the index
    arrays must be the state at e_c's commit fence."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    np.save(d / "ckpt_val.npy", np.asarray(val))
    np.save(d / "ckpt_tid.npy", np.asarray(tid))
    n_idx = 0 if indexes is None else len(indexes)
    for i in range(n_idx):
        for fld in ("key", "prow", "tid"):
            np.save(d / f"ckpt_idx{i}_{fld}.npy",
                    np.asarray(indexes[i][fld]))
    (d / "ckpt_meta.json").write_text(
        json.dumps({"epoch": int(epoch), "n_indexes": n_idx}))


def recover(directory: str | Path, shuffle_seed: int | None = None):
    """Load checkpoint + replay all record WAL chunks since e_c with the
    Thomas rule.  Returns (val, tid, epoch) — records only; index-aware
    callers use :func:`recover_full`.

    ``shuffle_seed`` permutes the replay order of every (file, flush-chunk)
    pair before applying — the Thomas rule makes record recovery order-free
    (each entry is a whole-record post-image tagged with its commit TID,
    whose epoch lives in the high bits), so any permutation must produce
    the identical state; tests exercise this directly."""
    val, tid, _, epoch = recover_full(directory, shuffle_seed=shuffle_seed)
    return val, tid, epoch


def iter_changelog(directory: str | Path, since_epoch: int = 0):
    """The durable changelog as an ordered stream source: every surviving
    entry across the per-worker logs, yielded as ``(kind, epoch, payload)``
    with kind ``"record"`` or ``"index"``, per-file in file order (the only
    order the stream guarantees — cross-file chunks commute by
    construction).

    The two kinds carry the stream's two ordering disciplines past a
    checkpoint at ``since_epoch``: record chunks are Thomas-mergeable
    post-images and replay for every epoch AT or after it (over-replay of
    the checkpointed epoch is idempotent under the Thomas rule — the fuzzy
    checkpoint may straddle it), while index chunks replay exactly-once
    and only STRICTLY after it (the checkpointed index arrays already
    contain ``since_epoch``)."""
    d = Path(directory)
    for wal in sorted(d.glob("wal_*.log")):
        for kind, epoch, payload in WriteAheadLog.read_all(wal):
            if kind == KIND_RECORD and epoch >= since_epoch:
                yield "record", epoch, payload
            elif kind == KIND_INDEX and epoch > since_epoch:
                yield "index", epoch, payload


def recover_full(directory: str | Path, shuffle_seed: int | None = None):
    """Checkpoint + replay of the durable changelog, indexes included.
    Returns (val, tid, indexes | None, epoch).

    Record chunks Thomas-merge in any order (``shuffle_seed`` exercises
    that); index chunks replay per file in file order, grouped by their
    step ids, only for epochs strictly after the checkpoint epoch
    (exactly-once — the checkpointed index arrays already contain e_c).
    Both arrive through :func:`iter_changelog` — recovery is just another
    changelog consumer, reading the stream from disk instead of live."""
    from repro.core.replication import thomas_apply
    from repro.storage.index import apply_index_ops
    import jax.numpy as jnp
    d = Path(directory)
    meta = json.loads((d / "ckpt_meta.json").read_text())
    e_c = meta["epoch"]
    val = jnp.asarray(np.load(d / "ckpt_val.npy"))
    tid = jnp.asarray(np.load(d / "ckpt_tid.npy"))
    n_idx = int(meta.get("n_indexes", 0))
    indexes = None
    if n_idx:
        indexes = [{fld: jnp.asarray(np.load(d / f"ckpt_idx{i}_{fld}.npy"))
                    for fld in ("key", "prow", "tid")} for i in range(n_idx)]
    shape = val.shape
    fval = val.reshape(-1, shape[-1])
    ftid = tid.reshape(-1)
    chunks, idx_chunks = [], []
    for kind, epoch, payload in iter_changelog(d, since_epoch=e_c):
        if kind == "record":
            chunks.append(payload)
        else:
            idx_chunks.append((epoch, payload))
    if shuffle_seed is not None:
        np.random.default_rng(shuffle_seed).shuffle(chunks)
    for rows, vals, tids in chunks:
        fval, ftid, _ = thomas_apply(
            fval, ftid, jnp.asarray(rows, jnp.int32), jnp.asarray(vals),
            jnp.asarray(tids))
    if indexes is not None:
        # per-file order is already epoch-ascending; replay each chunk's
        # step groups in order (ops within a step group commuted live)
        for _, (step, kinds, delta, tids) in idx_chunks:
            for s in np.unique(step):          # np.unique sorts ascending
                m = step == s
                indexes, _ = apply_index_ops(
                    indexes, jnp.asarray(kinds[m]), jnp.asarray(delta[m]),
                    jnp.ones(int(m.sum()), bool), jnp.asarray(tids[m]))
    return (fval.reshape(shape), ftid.reshape(shape[:-1]), indexes, e_c)


# ---------------------------------------------------------------------------
# live-execution durability: per-worker WALs + checkpoint cadence
# ---------------------------------------------------------------------------
class Durability:
    """Drives the dormant WAL/checkpoint machinery from live execution.

    One instance serves one engine (single-host ``StarEngine`` or one
    ``ClusterRuntime``): each worker (paper: node; here: partition group)
    appends its committed value stream — and, for index-bearing workloads,
    its ordered index-op stream — to its own ``WriteAheadLog``, all logs
    flush inside the epoch's commit fence, and every ``checkpoint_every``
    epochs the committed state is checkpointed (fuzzily for records;
    epoch-aligned index arrays ride along so index replay stays
    exactly-once).  An epoch-0 checkpoint of the initial state is written
    at attach time so recovery works before the first cadence checkpoint.

    TID epochs are 8 bits (``core.tid``): log retention beyond 255 epochs
    past the checkpoint would alias the Thomas ordering, so the cadence
    must stay well below that — asserted here.
    """

    def __init__(self, directory: str | Path, n_workers: int = 1,
                 checkpoint_every: int = 8):
        assert 0 < checkpoint_every < 200, checkpoint_every
        self.dir = Path(directory)
        self.n_workers = n_workers
        self.checkpoint_every = checkpoint_every
        self.wals = [WriteAheadLog(self.dir, w) for w in range(n_workers)]
        self.entries_logged = 0
        self.checkpoints = 0
        self.last_ckpt_epoch = 0

    def attach(self, val, tid, indexes=None):
        """Write the epoch-0 baseline checkpoint of the initial state —
        unless the directory already holds one (an engine resuming after a
        crash keeps the existing checkpoint + logs: recovery replays from
        the recorded e_c, and overwriting with the fresh engine's initial
        state would discard the durable history)."""
        if not (self.dir / "ckpt_meta.json").exists():
            write_checkpoint(self.dir, np.asarray(val), np.asarray(tid), 0,
                             indexes=indexes)

    def log(self, worker: int, rows, vals, tids, write_mask):
        """Buffer one committed write stream chunk (global flat rows)."""
        self.wals[worker % self.n_workers].append(rows, vals, tids,
                                                  write_mask)

    def log_epoch_streams(self, plog, slog, R: int, C: int,
                          worker_of_partition, cross_kinds=None,
                          cross_delta=None):
        """Fan one committed epoch's streams out to the per-worker logs:
        the partitioned op stream in its §5 transformed form, the master's
        value stream split by row owner, and — when the logs carry index
        maintenance — the ordered index-op stream split by segment owner
        (see ``replication.wal_partition_streams`` /
        ``wal_master_streams`` / ``wal_index_streams``).
        ``worker_of_partition``: (P,) int map — ``p % n_workers`` on the
        single-host engine, ``p // ppn`` on the cluster's node blocks.
        ``cross_kinds``/``cross_delta``: the single-master batch's static
        op arrays (index-op recovery re-applies (kind, operand), which the
        SM log itself does not carry)."""
        from repro.core import replication as repl
        if plog is not None:
            for w, rows, vals, tids, mask in repl.wal_partition_streams(
                    plog, R, self.n_workers, worker_of_partition):
                self.log(w, rows, vals, tids, mask)
        if slog is not None:
            for w, rows, vals, tids, mask in repl.wal_master_streams(
                    slog, R, C, self.n_workers, worker_of_partition):
                self.log(w, rows, vals, tids, mask)
        has_pidx = plog is not None and "iwrite" in plog
        has_sidx = slog is not None and "iwrite" in slog \
            and cross_kinds is not None
        if has_pidx or has_sidx:
            for w, step, kinds, delta, tids in repl.wal_index_streams(
                    plog if has_pidx else None, self.n_workers,
                    worker_of_partition, cross_kinds=cross_kinds,
                    cross_delta=cross_delta,
                    slog=slog if has_sidx else None):
                self.wals[w % self.n_workers].append_index_ops(
                    step, kinds, delta, tids)

    def commit_epoch(self, epoch: int, val=None, tid=None,
                     indexes=None) -> int:
        """Inside the commit fence: fsync every worker's log; on cadence,
        also checkpoint the (committed) state passed in.  Returns the
        number of entries flushed."""
        n = sum(w.flush(epoch) for w in self.wals)
        self.entries_logged += n
        if val is not None and epoch - self.last_ckpt_epoch >= \
                self.checkpoint_every:
            write_checkpoint(self.dir, np.asarray(val), np.asarray(tid),
                             epoch, indexes=indexes)
            self.checkpoints += 1
            self.last_ckpt_epoch = epoch
        return n

    def close(self):
        for w in self.wals:
            w.close()


class WalSink:
    """ChangeLog subscriber: WAL appends as a changelog sink.

    At every commit fence the changelog hands over the whole epoch's
    record — the partitioned op stream (already §5-transformed to
    post-images), the single-master stream, and the batch's static index
    op arrays — and the sink fans it to the per-worker logs and group-
    commits them (flush + fsync + cadence checkpoint) inside the fence.
    ``snapshot_provider`` returns the engine's committed
    ``(val, tid, indexes | None)`` for the cadence checkpoint.

    Doomed epochs never reach ``on_commit`` (the engine reverts instead
    of committing), so the durable stream only ever contains committed
    slabs — exactly the pre-refactor behavior.
    """

    def __init__(self, durability: Durability, R: int, C: int,
                 worker_of_partition, snapshot_provider):
        self.d = durability
        self.R, self.C = int(R), int(C)
        self.worker_of_partition = np.asarray(worker_of_partition)
        self.snapshot_provider = snapshot_provider

    def on_commit(self, epoch, record):
        from repro.obs import trace as obs
        with obs.span("fence.wal_sink", cat="fence", epoch=int(epoch)):
            self.d.log_epoch_streams(record["part"], record["sm"],
                                     self.R, self.C,
                                     self.worker_of_partition,
                                     cross_kinds=record["cross_kinds"],
                                     cross_delta=record["cross_delta"])
            val, tid, indexes = self.snapshot_provider()
            self.d.commit_epoch(epoch, val, tid, indexes=indexes)
