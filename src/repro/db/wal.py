"""Durability: per-worker write-ahead logs + fuzzy checkpoints (§4.5.1, §5).

Log entry = (key, value words, TID) — TID embeds the epoch.  Operation-
replication messages are transformed before logging: the op is applied first
and the WHOLE record value is logged (paper §5), so recovery can replay logs
in ANY order under the Thomas write rule.

Checkpoints are fuzzy (no freeze): the checkpointer scans (value, TID) while
writers proceed; recovery loads the checkpoint and replays all logs since the
checkpoint's start epoch e_c, again Thomas-rule-merged.  ``recover`` is
exercised by tests end-to-end (crash -> reload -> bit-identical state).
"""
from __future__ import annotations

import json
import os
import struct
from pathlib import Path

import numpy as np

HEADER = struct.Struct("<IIQ")     # n_entries, n_cols, epoch


class WriteAheadLog:
    def __init__(self, directory: str | Path, worker_id: int = 0):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.path = self.dir / f"wal_{worker_id:03d}.log"
        self._fh = open(self.path, "ab")
        self.pending_rows: list[np.ndarray] = []
        self.pending_vals: list[np.ndarray] = []
        self.pending_tids: list[np.ndarray] = []

    def append(self, rows, vals, tids, write_mask):
        """Buffer committed writes (arrays of any shape; mask selects)."""
        m = np.asarray(write_mask).reshape(-1)
        rows = np.asarray(rows).reshape(-1)[m]
        vals = np.asarray(vals).reshape(-1, np.asarray(vals).shape[-1])[m]
        tids = np.asarray(tids).reshape(-1)[m]
        if rows.size:
            self.pending_rows.append(rows.astype(np.int64))
            self.pending_vals.append(vals.astype(np.int32))
            self.pending_tids.append(tids.astype(np.uint32))

    def flush(self, epoch: int):
        """Periodic flush; also called inside the replication fence."""
        if not self.pending_rows:
            return 0
        rows = np.concatenate(self.pending_rows)
        vals = np.concatenate(self.pending_vals)
        tids = np.concatenate(self.pending_tids)
        self._fh.write(HEADER.pack(len(rows), vals.shape[1], epoch))
        self._fh.write(rows.tobytes())
        self._fh.write(vals.tobytes())
        self._fh.write(tids.tobytes())
        self._fh.flush()
        os.fsync(self._fh.fileno())
        n = len(rows)
        self.pending_rows, self.pending_vals, self.pending_tids = [], [], []
        return n

    def close(self):
        self._fh.close()

    @staticmethod
    def read_entries(path: Path, since_epoch: int = 0):
        out = []
        raw = Path(path).read_bytes()
        off = 0
        while off < len(raw):
            n, c, epoch = HEADER.unpack_from(raw, off)
            off += HEADER.size
            rows = np.frombuffer(raw, np.int64, n, off); off += 8 * n
            vals = np.frombuffer(raw, np.int32, n * c, off).reshape(n, c)
            off += 4 * n * c
            tids = np.frombuffer(raw, np.uint32, n, off); off += 4 * n
            if epoch >= since_epoch:
                out.append((rows, vals, tids))
        return out


def write_checkpoint(directory: str | Path, val: np.ndarray, tid: np.ndarray,
                     epoch: int):
    """Fuzzy checkpoint: records e_c; logs earlier than e_c become dead."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    np.save(d / "ckpt_val.npy", np.asarray(val))
    np.save(d / "ckpt_tid.npy", np.asarray(tid))
    (d / "ckpt_meta.json").write_text(json.dumps({"epoch": int(epoch)}))


def recover(directory: str | Path):
    """Load checkpoint + replay all WALs since e_c with the Thomas rule.
    Returns (val, tid, epoch)."""
    from repro.core.replication import thomas_apply
    import jax.numpy as jnp
    d = Path(directory)
    meta = json.loads((d / "ckpt_meta.json").read_text())
    val = jnp.asarray(np.load(d / "ckpt_val.npy"))
    tid = jnp.asarray(np.load(d / "ckpt_tid.npy"))
    shape = val.shape
    fval = val.reshape(-1, shape[-1])
    ftid = tid.reshape(-1)
    for wal in sorted(d.glob("wal_*.log")):
        for rows, vals, tids in WriteAheadLog.read_entries(wal, meta["epoch"]):
            fval, ftid, _ = thomas_apply(
                fval, ftid, jnp.asarray(rows, jnp.int32), jnp.asarray(vals),
                jnp.asarray(tids))
    return fval.reshape(shape), ftid.reshape(shape[:-1]), meta["epoch"]
