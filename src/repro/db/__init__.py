from repro.db.table import Database, TableSpec, make_database, snapshot_commit, revert_to_snapshot

__all__ = ["Database", "TableSpec", "make_database", "snapshot_commit",
           "revert_to_snapshot"]
