"""YCSB workload (§7.1.1): 1 table, 10 int-word columns, 10 ops/txn,
90/10 read/write, uniform access, 200K records/partition (scalable), default
10% cross-partition transactions.

The generator emits the unified txn format consumed by both executors:
single-partition txns routed per partition (P, T, M) and cross-partition txns
as a flat batch (B, M) with global rows.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.ops import READ, SET

C = 10             # int32 words per row
M = 10             # ops per transaction
ROW_BYTES = 100    # paper: 10 columns x 10 random bytes


@dataclass(frozen=True)
class YCSBConfig:
    n_partitions: int
    records_per_partition: int = 200_000
    cross_ratio: float = 0.10
    write_ops: int = 1             # of 10 -> the 90/10 mix
    seed: int = 0
    # --- access skew (paper default: uniform). zipf_theta > 0 draws row
    # ids rank-ordered from a bounded Zipf(theta); hot_set_size/
    # hot_access_frac overlay a hot-key scenario (frac of ops hit the first
    # hot_set_size rows uniformly) on top of whichever base distribution.
    zipf_theta: float = 0.0
    hot_set_size: int = 0
    hot_access_frac: float = 0.0

    @property
    def total_rows(self):
        return self.n_partitions * self.records_per_partition


_ZIPF_CDF_CACHE: dict = {}


def _zipf_cdf(n: int, theta: float):
    """Inverse-CDF table for a bounded rank-ordered Zipf over n keys."""
    key = (n, round(theta, 6))
    if key not in _ZIPF_CDF_CACHE:
        w = np.arange(1, n + 1, dtype=np.float64) ** -theta
        _ZIPF_CDF_CACHE[key] = np.cumsum(w / w.sum())
    return _ZIPF_CDF_CACHE[key]


def sample_rows(cfg: YCSBConfig, rng: np.random.Generator, shape):
    """Draw partition-local row ids under the configured access skew.
    Uniform by default (one rng call — bit-identical to the seed generator);
    rank r is row id r, so high theta concentrates load on low row ids."""
    if cfg.zipf_theta > 0.0:
        cdf = _zipf_cdf(cfg.records_per_partition, cfg.zipf_theta)
        rows = np.searchsorted(cdf, rng.random(shape)).astype(np.int32)
    else:
        rows = rng.integers(0, cfg.records_per_partition, shape).astype(np.int32)
    if cfg.hot_set_size > 0 and cfg.hot_access_frac > 0.0:
        hot = rng.random(shape) < cfg.hot_access_frac
        rows = np.where(hot, rng.integers(0, cfg.hot_set_size, shape),
                        rows).astype(np.int32)
    return rows


def make_raw(cfg: YCSBConfig, n_txns: int, rng: np.random.Generator):
    """Raw unrouted request arrays — the streaming-generator core shared by
    the offline `make_batch` and the online service clients.

    Returns {'parts' (B,M), 'rows' (B,M), 'kinds' (B,M), 'deltas' (B,M,C),
    'user_abort' (B,), 'home' (B,), 'declared_cross' (B,)} where `home` is
    the partition the client *declares* (routers must detect mis-declared
    singles themselves)."""
    P = cfg.n_partitions

    is_cross = rng.random(n_txns) < cfg.cross_ratio
    home = rng.integers(0, P, n_txns).astype(np.int32)

    # op partitions: single-partition -> home; cross -> random partitions
    op_part = np.repeat(home[:, None], M, axis=1)
    cross_parts = rng.integers(0, P, (n_txns, M)).astype(np.int32)
    # ensure cross txns touch ≥2 partitions: first op stays home
    cross_parts[:, 0] = home
    op_part = np.where(is_cross[:, None], cross_parts, op_part)

    op_idx = sample_rows(cfg, rng, (n_txns, M))
    kinds = np.full((n_txns, M), READ, np.int32)
    wpos = rng.integers(0, M, (n_txns, cfg.write_ops))
    for j in range(cfg.write_ops):
        kinds[np.arange(n_txns), wpos[:, j]] = SET
    deltas = rng.integers(0, 2**31 - 1, (n_txns, M, C), dtype=np.int64).astype(np.int32)

    return {"parts": op_part.astype(np.int32), "rows": op_idx, "kinds": kinds,
            "deltas": deltas, "user_abort": np.zeros(n_txns, bool),
            "home": home, "declared_cross": is_cross,
            # read-tier eligibility: an all-READ op list (write_ops=0
            # configs) can be served from a replica snapshot
            "read_only": (kinds == READ).all(axis=1)}


def route_single(cfg, home, rows, kinds, deltas, T):
    """Group single-partition txns by home partition into (P, T, M) arrays."""
    P = cfg.n_partitions
    n = home.shape[0]
    out = {
        "valid": np.zeros((P, T), bool),
        "row": np.zeros((P, T, M), np.int32),
        "kind": np.zeros((P, T, M), np.int32),
        "delta": np.zeros((P, T, M, C), np.int32),
        "user_abort": np.zeros((P, T), bool),
    }
    fill = np.zeros(P, np.int32)
    for i in range(n):
        p = home[i]
        t = fill[p]
        if t >= T:
            continue
        out["valid"][p, t] = True
        out["row"][p, t] = rows[i]
        out["kind"][p, t] = kinds[i]
        out["delta"][p, t] = deltas[i]
        fill[p] += 1
    return out, int(fill.sum())


def make_batch(cfg: YCSBConfig, n_txns: int, seed: int | None = None):
    """Returns dict with 'ptxn' (P,T,…), 'cross' (B,M,…), metadata."""
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    R = cfg.records_per_partition
    raw = make_raw(cfg, n_txns, rng)
    P = cfg.n_partitions
    is_cross, home = raw["declared_cross"], raw["home"]
    op_part, op_idx = raw["parts"], raw["rows"]
    kinds, deltas = raw["kinds"], raw["deltas"]

    single = ~is_cross
    n_single = int(single.sum())
    T = max(1, int(np.ceil(n_single / P * 1.3)) + 2)
    ptxn, routed = route_single(
        cfg, home[single], op_idx[single], kinds[single], deltas[single], T)

    cross = {
        "valid": np.ones(int(is_cross.sum()), bool),
        "row": (op_part[is_cross].astype(np.int64) * R
                + op_idx[is_cross]).astype(np.int32),
        "kind": kinds[is_cross],
        "delta": deltas[is_cross],
        "user_abort": np.zeros(int(is_cross.sum()), bool),
    }
    row_bytes = np.full((M,), ROW_BYTES, np.int32)
    # paper §7.5: a YCSB write updates the whole record -> op bytes = row bytes
    return {
        "ptxn": ptxn, "cross": cross,
        "n_single": routed, "n_cross": int(is_cross.sum()),
        "row_bytes": row_bytes, "op_bytes": row_bytes.copy(),
    }


def schema_rows(cfg: YCSBConfig):
    return cfg.records_per_partition
