"""YCSB workload (§7.1.1): 1 table, 10 int-word columns, 10 ops/txn,
90/10 read/write, uniform access, 200K records/partition (scalable), default
10% cross-partition transactions.

The generator emits the unified txn format consumed by both executors:
single-partition txns routed per partition (P, T, M) and cross-partition txns
as a flat batch (B, M) with global rows.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.ops import READ, SET

C = 10             # int32 words per row
M = 10             # ops per transaction
ROW_BYTES = 100    # paper: 10 columns x 10 random bytes


@dataclass(frozen=True)
class YCSBConfig:
    n_partitions: int
    records_per_partition: int = 200_000
    cross_ratio: float = 0.10
    write_ops: int = 1             # of 10 -> the 90/10 mix
    seed: int = 0

    @property
    def total_rows(self):
        return self.n_partitions * self.records_per_partition


def route_single(cfg, home, rows, kinds, deltas, T):
    """Group single-partition txns by home partition into (P, T, M) arrays."""
    P = cfg.n_partitions
    n = home.shape[0]
    out = {
        "valid": np.zeros((P, T), bool),
        "row": np.zeros((P, T, M), np.int32),
        "kind": np.zeros((P, T, M), np.int32),
        "delta": np.zeros((P, T, M, C), np.int32),
        "user_abort": np.zeros((P, T), bool),
    }
    fill = np.zeros(P, np.int32)
    for i in range(n):
        p = home[i]
        t = fill[p]
        if t >= T:
            continue
        out["valid"][p, t] = True
        out["row"][p, t] = rows[i]
        out["kind"][p, t] = kinds[i]
        out["delta"][p, t] = deltas[i]
        fill[p] += 1
    return out, int(fill.sum())


def make_batch(cfg: YCSBConfig, n_txns: int, seed: int | None = None):
    """Returns dict with 'ptxn' (P,T,…), 'cross' (B,M,…), metadata."""
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    P, R = cfg.n_partitions, cfg.records_per_partition

    is_cross = rng.random(n_txns) < cfg.cross_ratio
    home = rng.integers(0, P, n_txns).astype(np.int32)

    # op partitions: single-partition -> home; cross -> random partitions
    op_part = np.repeat(home[:, None], M, axis=1)
    cross_parts = rng.integers(0, P, (n_txns, M)).astype(np.int32)
    # ensure cross txns touch ≥2 partitions: first op stays home
    cross_parts[:, 0] = home
    op_part = np.where(is_cross[:, None], cross_parts, op_part)

    op_idx = rng.integers(0, R, (n_txns, M)).astype(np.int32)
    kinds = np.full((n_txns, M), READ, np.int32)
    wpos = rng.integers(0, M, (n_txns, cfg.write_ops))
    for j in range(cfg.write_ops):
        kinds[np.arange(n_txns), wpos[:, j]] = SET
    deltas = rng.integers(0, 2**31 - 1, (n_txns, M, C), dtype=np.int64).astype(np.int32)

    single = ~is_cross
    n_single = int(single.sum())
    T = max(1, int(np.ceil(n_single / P * 1.3)) + 2)
    ptxn, routed = route_single(
        cfg, home[single], op_idx[single], kinds[single], deltas[single], T)

    cross = {
        "valid": np.ones(int(is_cross.sum()), bool),
        "row": (op_part[is_cross].astype(np.int64) * R
                + op_idx[is_cross]).astype(np.int32),
        "kind": kinds[is_cross],
        "delta": deltas[is_cross],
        "user_abort": np.zeros(int(is_cross.sum()), bool),
    }
    row_bytes = np.full((M,), ROW_BYTES, np.int32)
    # paper §7.5: a YCSB write updates the whole record -> op bytes = row bytes
    return {
        "ptxn": ptxn, "cross": cross,
        "n_single": routed, "n_cross": int(is_cross.sum()),
        "row_bytes": row_bytes, "op_bytes": row_bytes.copy(),
    }


def schema_rows(cfg: YCSBConfig):
    return cfg.records_per_partition
